//! Serving example (the paper's LTPP scenario as a service): the
//! coordinator routes, batches and executes requests on the PJRT
//! artifact — python nowhere on this path. Reports the latency and
//! throughput the serving layer achieves.
//!
//!     make artifacts && cargo run --release --example serve_requests

use star::config::AccelConfig;
use star::coordinator::{Backend, BatcherConfig, Request, Router, Server, ServerConfig, Variant};
use star::runtime::engine::artifacts_available;
use star::sim::dram::DramChannel;
use star::sim::pipeline::FeatureSet;
use star::tensor::Mat;
use star::util::Rng;
use std::collections::BTreeMap;

fn main() -> star::Result<()> {
    let dir = star::runtime::manifest::default_dir();
    let router = Router::new(vec![
        Variant { name: "sparse_attention_tiny".into(), model: "tiny".into(), max_t: 32, s: 256 },
        Variant { name: "sparse_attention".into(), model: "gpt2".into(), max_t: 128, s: 1024 },
    ]);
    let mut rng = Rng::new(3);
    let backend = if artifacts_available(&dir) {
        let mut contexts = BTreeMap::new();
        contexts.insert(
            "sparse_attention_tiny".to_string(),
            (Mat::randn(256, 32, 1.0, &mut rng), Mat::randn(256, 32, 1.0, &mut rng)),
        );
        contexts.insert(
            "sparse_attention".to_string(),
            (Mat::randn(1024, 64, 1.0, &mut rng), Mat::randn(1024, 64, 1.0, &mut rng)),
        );
        println!("backend: PJRT ({dir:?})");
        Backend::Pjrt { artifact_dir: dir, contexts }
    } else {
        println!("backend: simulator (run `make artifacts` for real numerics)");
        Backend::Sim {
            feats: FeatureSet::star(),
            accel: AccelConfig::default(),
            dram: DramChannel::accel_256(),
            d: 64,
            h: 768,
            keep: 0.2,
            time_scale: 1.0,
        }
    };
    let server = Server::start(
        router,
        backend,
        ServerConfig { batcher: BatcherConfig { target_t: 32, max_wait_s: 2e-3 }, workers: 2 },
    );

    // A Poisson-ish open-loop client: 96 requests across both buckets.
    let mut rxs = Vec::new();
    for id in 0..96u64 {
        let (model, s, d) = if id % 3 == 0 { ("gpt2", 1024, 64) } else { ("tiny", 256, 32) };
        let t = 4 * rng.range(1, 5);
        let mut req = Request::new(id, model, t, s, 0.0);
        req.q = Some(Mat::randn(t, d, 1.0, &mut rng));
        rxs.push(server.submit(req)?);
        if id % 8 == 7 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.output.is_some() || resp.variant.starts_with("rejected") == false {
            ok += 1;
        }
    }
    let snap = server.shutdown();
    println!("served {ok}/96 requests");
    println!("{}", snap.render());
    Ok(())
}
