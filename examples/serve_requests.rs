//! Serving example (the paper's LTPP scenario as a service): the
//! coordinator routes, batches and executes requests on the native
//! sparse-attention pipeline — real numerics, python nowhere on this
//! path. Reports the latency and throughput the serving layer achieves,
//! including the per-stage pipeline breakdown.
//!
//!     cargo run --release --example serve_requests

use star::coordinator::{Backend, BatcherConfig, Request, Router, Server, ServerConfig, Variant};
use star::pipeline::PipelineConfig;
use star::tensor::Mat;
use star::util::Rng;
use std::collections::BTreeMap;

fn main() -> star::Result<()> {
    let router = Router::new(vec![
        Variant { name: "sparse_attention_tiny".into(), model: "tiny".into(), max_t: 32, s: 256 },
        Variant { name: "sparse_attention".into(), model: "gpt2".into(), max_t: 128, s: 1024 },
    ]);
    let mut rng = Rng::new(3);
    let mut contexts = BTreeMap::new();
    contexts.insert(
        "sparse_attention_tiny".to_string(),
        (Mat::randn(256, 32, 1.0, &mut rng), Mat::randn(256, 32, 1.0, &mut rng)),
    );
    contexts.insert(
        "sparse_attention".to_string(),
        (Mat::randn(1024, 64, 1.0, &mut rng), Mat::randn(1024, 64, 1.0, &mut rng)),
    );
    println!("backend: native sparse-attention pipeline (STAR config)");
    let backend = Backend::native(PipelineConfig::star().with_threads(1), contexts);
    let server = Server::start(
        router,
        backend,
        ServerConfig { batcher: BatcherConfig { target_t: 32, max_wait_s: 2e-3 }, workers: 2 },
    );

    // A Poisson-ish open-loop client: 96 requests across both buckets.
    let mut rxs = Vec::new();
    for id in 0..96u64 {
        let (model, s, d) = if id % 3 == 0 { ("gpt2", 1024, 64) } else { ("tiny", 256, 32) };
        let t = 4 * rng.range(1, 5);
        let mut req = Request::new(id, model, t, s, 0.0);
        req.q = Some(Mat::randn(t, d, 1.0, &mut rng));
        rxs.push(server.submit(req)?);
        if id % 8 == 7 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
    let mut ok = 0;
    for rx in rxs {
        let resp = rx.recv()?;
        if resp.output.is_some() {
            ok += 1;
        }
    }
    let snap = server.shutdown();
    println!("served {ok}/96 requests with real outputs");
    println!("{}", snap.render());
    Ok(())
}
