//! Spatial scaling study: Spatial-STAR throughput across mesh sizes and
//! dataflows for an ultra-long sequence (the Sec. VI-E scalability
//! claim), plus the DRAttention/MRCA ablation at each size — and then
//! the same dataflow **executed** by the sequence-sharded pipeline,
//! with bit-parity against the single-core engine asserted.
//!
//!     cargo run --release --example spatial_scaling

use star::config::SpatialConfig;
use star::pipeline::{PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline};
use star::spatial::sim::{spatial_run, CoreKind, Dataflow};
use star::tensor::Mat;
use star::util::Rng;

fn main() {
    let s = 32768;
    println!("Spatial-STAR scaling at S={s} (d=64, H=768, keep 20%)\n");
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>10}",
        "mesh", "Ring TOPS", "DRAttn TOPS", "+MRCA TOPS", "MRCA gain"
    );
    for (rows, cols) in [(2usize, 2usize), (3, 3), (4, 4), (5, 5), (6, 6)] {
        let mut cfg = SpatialConfig::mesh5x5();
        cfg.mesh_rows = rows;
        cfg.mesh_cols = cols;
        let ring = spatial_run(&cfg, CoreKind::Star, Dataflow::RingAttention, s, 64, 768, 0.2);
        let dra = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionNaive, s, 64, 768, 0.2);
        let full = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, 64, 768, 0.2);
        println!(
            "{:<8} {:>14.1} {:>14.1} {:>14.1} {:>9.2}x",
            format!("{rows}x{cols}"),
            ring.eff_tops(),
            dra.eff_tops(),
            full.eff_tops(),
            ring.total_s / full.total_s,
        );
    }
    println!("\nScalability: workload per core shrinks as the mesh grows; the Q-ring");
    println!("extends by time steps only (Sec. VI-E), so arbitrarily long sequences");
    println!("map to more steps, not more storage.");

    // ---- Executed, not simulated: the sequence-sharded engine runs the
    // same dataflow on worker threads. Outputs must equal the
    // single-core pipeline bit for bit at every worker count.
    let (t, s_exec, d) = (192usize, 2048usize, 64usize);
    println!("\nExecutable Spatial-STAR at T={t}, S={s_exec}, d={d} (keep 20%):\n");
    let mut rng = Rng::new(5);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s_exec, d, 1.0, &mut rng);
    let v = Mat::randn(s_exec, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let cfg = PipelineConfig::star().with_threads(1);
    let t0 = std::time::Instant::now();
    let single = SparseAttentionPipeline::new(cfg).run(&inputs);
    let single_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("{:<10} {:>10.1} ms {:>8}", "1 core", single_ms, "1.00x");
    for workers in [2usize, 4] {
        let t0 = std::time::Instant::now();
        let r = ShardedPipeline::new(cfg, workers).run(&inputs);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        assert_eq!(
            r.out.max_abs_diff(&single.out),
            0.0,
            "sharded output must equal the single-core pipeline bit for bit"
        );
        assert_eq!(r.selection, single.selection, "selection must not drift");
        println!(
            "{:<10} {:>10.1} ms {:>7.2}x   ring {} steps, {} payload bytes",
            format!("{} workers", r.shards),
            ms,
            single_ms / ms,
            r.ring_steps,
            r.ring_payload_bytes,
        );
    }
    println!("\nThe analytic model above predicts the trend; the executed engine");
    println!("proves the math never changes while doing it (see also");
    println!("`star bench spatial-exec`).");
}
