//! Spatial scaling study: Spatial-STAR throughput across mesh sizes and
//! dataflows for an ultra-long sequence (the Sec. VI-E scalability
//! claim), plus the DRAttention/MRCA ablation at each size.
//!
//!     cargo run --release --example spatial_scaling

use star::config::SpatialConfig;
use star::spatial::sim::{spatial_run, CoreKind, Dataflow};

fn main() {
    let s = 32768;
    println!("Spatial-STAR scaling at S={s} (d=64, H=768, keep 20%)\n");
    println!(
        "{:<8} {:>14} {:>14} {:>14} {:>10}",
        "mesh", "Ring TOPS", "DRAttn TOPS", "+MRCA TOPS", "MRCA gain"
    );
    for (rows, cols) in [(2usize, 2usize), (3, 3), (4, 4), (5, 5), (6, 6)] {
        let mut cfg = SpatialConfig::mesh5x5();
        cfg.mesh_rows = rows;
        cfg.mesh_cols = cols;
        let ring = spatial_run(&cfg, CoreKind::Star, Dataflow::RingAttention, s, 64, 768, 0.2);
        let dra = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionNaive, s, 64, 768, 0.2);
        let full = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, 64, 768, 0.2);
        println!(
            "{:<8} {:>14.1} {:>14.1} {:>14.1} {:>9.2}x",
            format!("{rows}x{cols}"),
            ring.eff_tops(),
            dra.eff_tops(),
            full.eff_tops(),
            ring.total_s / full.total_s,
        );
    }
    println!("\nScalability: workload per core shrinks as the mesh grows; the Q-ring");
    println!("extends by time steps only (Sec. VI-E), so arbitrarily long sequences");
    println!("map to more steps, not more storage.");
}
