//! Quickstart: load the AOT-compiled sparse-attention artifact and run
//! it from rust — the minimal three-layer round trip.
//!
//!     make artifacts && cargo run --release --example quickstart

use star::runtime::engine::artifacts_available;
use star::runtime::Engine;
use star::tensor::Mat;
use star::util::Rng;

fn main() -> star::Result<()> {
    let dir = star::runtime::manifest::default_dir();
    if !artifacts_available(&dir) {
        eprintln!("no artifacts at {dir:?}; run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::load_dir(&dir)?;
    println!("PJRT platform: {}", engine.platform());
    println!("compiled artifacts: {:?}", engine.names());

    // The tiny serving bucket: T=32 queries over a 256-token context.
    let entry = engine.get("sparse_attention_tiny").expect("tiny artifact");
    let (t, d) = (entry.entry.inputs[0][0], entry.entry.inputs[0][1]);
    let s = entry.entry.inputs[1][0];
    let mut rng = Rng::new(7);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);

    let t0 = std::time::Instant::now();
    let out = engine.run("sparse_attention_tiny", &[q.clone(), k.clone(), v.clone()])?;
    let dt = t0.elapsed();
    println!("sparse attention: [{t}, {d}] x [{s}, {d}] -> [{}, {}] in {dt:?}", out[0].rows, out[0].cols);

    // Compare against the dense oracle computed in rust.
    let inp = star::attention::AttnInputs::new(&q, &k, &v);
    let mut c = star::arith::OpCounter::new();
    let dense = star::attention::dense_attention(&inp, usize::MAX, &mut c);
    println!("rel err vs dense oracle: {:.4} (top-25%% sparse, Gaussian inputs)", out[0].rel_err(&dense));
    println!("first output row (head): {:?}", &out[0].row(0)[..4.min(d)]);
    Ok(())
}
