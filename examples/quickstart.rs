//! Quickstart: run the native sparse-attention pipeline — predict →
//! top-k → KV-gen → SU-FA, tiled and parallel — and compare against the
//! dense oracle. No artifacts needed; everything executes in-process.
//!
//!     cargo run --release --example quickstart

use star::arith::{EquivWeights, OpCounter};
use star::attention::{dense_attention, AttnInputs};
use star::config::ModelConfig;
use star::pipeline::{PipelineConfig, PipelineInputs, SparseAttentionPipeline};
use star::util::Rng;
use star::workload::AttnWorkload;

fn main() -> star::Result<()> {
    // One attention head of the `tiny` preset: T=32 queries over a
    // 256-token context, with activations X and projections W_k/W_v so
    // the pipeline runs cross-phase prediction and on-demand KV-gen.
    let model = ModelConfig::preset("tiny").expect("tiny preset");
    let mut rng = Rng::new(7);
    let wl = AttnWorkload::generate(&model, 256, 32, &mut rng);

    let pipe = SparseAttentionPipeline::star(0.2);
    let t0 = std::time::Instant::now();
    let r = pipe.run(&PipelineInputs::from_workload(&wl));
    let dt = t0.elapsed();
    println!(
        "STAR pipeline: [{}, {}] x [{}, {}] -> [{}, {}] in {dt:?} ({} tiles, auto threads)",
        wl.t(),
        wl.d(),
        wl.s(),
        wl.d(),
        r.out.rows,
        r.out.cols,
        r.tiles,
    );
    println!(
        "selection: keep={} / {}  density={:.3}  SADS rho={:.2}  stalls={}",
        r.keep,
        wl.s(),
        r.density(wl.s()),
        r.rho_mean,
        r.stalls,
    );

    // Per-stage breakdown — the cross-stage view the paper argues for.
    let ew = EquivWeights::default();
    println!("per-stage equivalent adds:");
    for (name, c) in [
        ("predict", &r.ops.predict),
        ("topk", &r.ops.topk),
        ("kv_gen", &r.ops.kv_gen),
        ("formal", &r.ops.formal),
    ] {
        println!("  {name:<8} {:>12.0}  ({c})", c.equivalent_adds(&ew));
    }
    let (stage, secs) = r.timing.bottleneck();
    println!("bottleneck stage: {stage} ({:.2} ms busy)", secs * 1e3);

    // Compare against the dense oracle computed in rust.
    let inp = AttnInputs::new(&wl.q, &wl.k, &wl.v);
    let mut cd = OpCounter::new();
    let dense = dense_attention(&inp, usize::MAX, &mut cd);
    println!("rel err vs dense oracle: {:.4}", r.out.rel_err(&dense));

    // Attention-side complexity vs dense, with fig18(b)'s accounting:
    // plain Q/K/V inputs so neither side carries the KV-projection work
    // (the full-stack run above also pays cross-phase K̂ estimation and
    // on-demand KV generation, which dense attention alone doesn't do —
    // comparing those totals against `cd` would be apples to oranges).
    let ra = pipe.run(&PipelineInputs::qkv(&wl.q, &wl.k, &wl.v));
    println!(
        "attention complexity kept vs dense: {:.1}%",
        100.0 * ra.equivalent_adds(&ew) / cd.equivalent_adds(&ew),
    );

    // Sanity anchor: the dense-oracle pipeline config reproduces dense
    // attention through the very same tiled machinery.
    let dense_pipe = SparseAttentionPipeline::new(PipelineConfig::dense_oracle());
    let rd = dense_pipe.run(&PipelineInputs::qkv(&wl.q, &wl.k, &wl.v));
    println!("dense-oracle parity: max |Δ| = {:.2e}", rd.out.max_abs_diff(&dense));
    Ok(())
}
