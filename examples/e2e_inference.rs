//! End-to-end driver: a small transformer model (4 layers of the
//! AOT-compiled block, ~1.3M parameters at H=128) served through the
//! full stack — PJRT artifacts for the numerics, the coordinator's
//! batching for the request flow — plus the cycle-level simulator
//! projecting the same workload onto the STAR ASIC. Reports
//! latency/throughput per layer and end to end (EXPERIMENTS.md §E2E).
//!
//!     make artifacts && cargo run --release --example e2e_inference

use star::config::AccelConfig;
use star::runtime::engine::artifacts_available;
use star::runtime::Engine;
use star::sim::dram::DramChannel;
use star::sim::pipeline::{simulate, FeatureSet, WorkloadShape};
use star::tensor::Mat;
use star::util::{Rng, Summary};

const LAYERS: usize = 4;

fn main() -> star::Result<()> {
    let dir = star::runtime::manifest::default_dir();
    if !artifacts_available(&dir) {
        eprintln!("no artifacts at {dir:?}; run `make artifacts` first");
        return Ok(());
    }
    let engine = Engine::load_dir(&dir)?;
    let entry = engine.get("transformer_block").expect("block artifact");
    let (s, h) = (entry.entry.inputs[0][0], entry.entry.inputs[0][1]);
    println!("e2e model: {LAYERS} layers, S={s}, H={h} (sparse attention inside each block)");

    // Per-layer weights (fixed seed — a 'checkpoint').
    let mut rng = Rng::new(2024);
    let layers: Vec<Vec<Mat>> = (0..LAYERS)
        .map(|_| {
            entry.entry.inputs[1..]
                .iter()
                .map(|shape| Mat::randn(shape[0], shape[1], (1.0 / (h as f32).sqrt()) * 1.0, &mut rng))
                .collect()
        })
        .collect();

    // Serve a stream of sequences through the 4-layer stack.
    let mut lat = Summary::new();
    let mut per_layer = Summary::new();
    let n_seqs: usize = 16;
    let t_all = std::time::Instant::now();
    for i in 0..n_seqs as u64 {
        let mut x = Mat::randn(s, h, 1.0, &mut Rng::new(100 + i));
        let t0 = std::time::Instant::now();
        for weights in &layers {
            let mut inputs = vec![x.clone()];
            inputs.extend(weights.iter().cloned());
            let t1 = std::time::Instant::now();
            let out = engine.run("transformer_block", &inputs)?;
            per_layer.add(t1.elapsed().as_secs_f64());
            x = out.into_iter().next().unwrap();
        }
        lat.add(t0.elapsed().as_secs_f64());
        for v in &x.data {
            assert!(v.is_finite(), "activations must stay finite through the stack");
        }
    }
    let wall = t_all.elapsed().as_secs_f64();
    println!(
        "PJRT (CPU, interpret-mode Pallas): per-layer p50 = {:.2} ms, per-seq p50 = {:.2} ms, \
         throughput = {:.1} seq/s ({:.0} tok/s)",
        1e3 * per_layer.median(),
        1e3 * lat.median(),
        n_seqs as f64 / wall,
        (n_seqs * s) as f64 / wall,
    );

    // The same workload projected on the STAR ASIC by the simulator.
    let shape = WorkloadShape::new(s, s, 32, h, 0.2);
    let r = simulate(&shape, &FeatureSet::star(), &AccelConfig::default(), &DramChannel::accel_256());
    println!(
        "STAR ASIC projection: {:.1} us/layer-head-group, {:.0} GOPS, {:.0} GOPS/W",
        r.total_s * 1e6,
        r.eff_gops,
        r.energy_eff_gops_w()
    );
    Ok(())
}
