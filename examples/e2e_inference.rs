//! End-to-end driver: the full serving stack — router → dynamic batcher
//! → worker pool — executing *real* sparse attention through the native
//! pipeline backend, plus the cycle-level simulator projecting the same
//! configuration onto the STAR ASIC (one `PipelineConfig` describes
//! both). Reports latency/throughput and the per-stage breakdown.
//!
//!     cargo run --release --example e2e_inference

use star::config::AccelConfig;
use star::coordinator::{Backend, BatcherConfig, Request, Router, Server, ServerConfig, Variant};
use star::pipeline::PipelineConfig;
use star::sim::dram::DramChannel;
use star::sim::pipeline::{simulate, WorkloadShape};
use star::tensor::Mat;
use star::util::Rng;
use std::collections::BTreeMap;

fn main() -> star::Result<()> {
    let (s, d, h) = (1024usize, 64usize, 768usize);
    let pipeline = PipelineConfig::star().with_threads(1);

    // KV context per variant (a fixed 'session' the requests attend into).
    let mut rng = Rng::new(2024);
    let mut contexts = BTreeMap::new();
    contexts.insert(
        "sparse_attention".to_string(),
        (Mat::randn(s, d, 1.0, &mut rng), Mat::randn(s, d, 1.0, &mut rng)),
    );
    let router = Router::new(vec![Variant {
        name: "sparse_attention".into(),
        model: "gpt2".into(),
        max_t: 128,
        s,
    }]);
    let server = Server::start(
        router,
        Backend::native(pipeline, contexts),
        ServerConfig { batcher: BatcherConfig { target_t: 128, max_wait_s: 2e-3 }, workers: 2 },
    );

    // An open-loop client: 64 requests of 8–32 query rows each.
    let n: u64 = 64;
    let t_all = std::time::Instant::now();
    let mut rxs = Vec::new();
    for id in 0..n {
        let t = 8 * rng.range(1, 5);
        let mut req = Request::new(id, "gpt2", t, s, 0.0);
        req.q = Some(Mat::randn(t, d, 1.0, &mut rng));
        rxs.push(server.submit(req)?);
    }
    let mut rows_served = 0usize;
    for rx in rxs {
        let resp = rx.recv().expect("response");
        let out = resp.output.expect("native backend returns real outputs");
        assert!(out.data.iter().all(|x| x.is_finite()), "outputs must stay finite");
        rows_served += out.rows;
    }
    let wall = t_all.elapsed().as_secs_f64();
    let snap = server.shutdown();
    println!("native serving (predict -> top-k -> KV-gen -> SU-FA in-process):");
    println!("{}", snap.render());
    println!(
        "end-to-end: {n} requests, {rows_served} query rows in {:.1} ms ({:.0} rows/s)",
        wall * 1e3,
        rows_served as f64 / wall,
    );

    // The very configuration just served, projected on the STAR ASIC:
    // the pipeline config converts losslessly to the simulator's
    // FeatureSet (same stage axes, same scheduling flags).
    let shape = WorkloadShape::new(128, s, d, h, pipeline.keep_ratio);
    let r = simulate(
        &shape,
        &pipeline.feature_set(),
        &AccelConfig::default(),
        &DramChannel::accel_256(),
    );
    println!(
        "STAR ASIC projection (same FeatureSet): {:.1} us/batch, {:.0} GOPS, {:.0} GOPS/W",
        r.total_s * 1e6,
        r.eff_gops,
        r.energy_eff_gops_w(),
    );
    Ok(())
}
