//! Multi-turn decode serving example: three concurrent conversations
//! decode against the paged KV-cache through the full coordinator stack
//! (router → continuous batcher → native session-aware backend), mixed
//! with stateless prefill traffic. The page pool is deliberately too
//! small for all sessions, so LRU eviction and bit-identical
//! re-materialization happen live — watch the `kvcache:` metrics line.
//!
//!     cargo run --release --example decode_session

use star::coordinator::{Backend, BatcherConfig, Request, Router, Server, ServerConfig, Variant};
use star::kvcache::{SessionConfig, SessionStore};
use star::pipeline::PipelineConfig;
use star::tensor::Mat;
use star::util::Rng;
use std::collections::BTreeMap;

fn main() -> star::Result<()> {
    let d = 32usize;
    let pipeline = PipelineConfig::star().with_tile(16).with_threads(1);
    // 6 pages × 16 tokens = 96 cached tokens, but each of the three
    // sessions grows to 72 tokens: the pool *must* evict and
    // re-materialize (decode outputs stay bit-identical regardless).
    let store = SessionStore::new(SessionConfig::for_pipeline(&pipeline, d, 6));

    let mut rng = Rng::new(11);
    let mut contexts = BTreeMap::new();
    contexts.insert(
        "sparse_attention".to_string(),
        (Mat::randn(256, d, 1.0, &mut rng), Mat::randn(256, d, 1.0, &mut rng)),
    );
    let router = Router::new(vec![Variant {
        name: "sparse_attention".into(),
        model: "tiny".into(),
        max_t: 64,
        s: 256,
    }]);
    let server = Server::start(
        router,
        Backend::native_with_sessions(pipeline, contexts, store),
        ServerConfig { batcher: BatcherConfig { target_t: 32, max_wait_s: 2e-3 }, workers: 2 },
    );

    let sessions: [u64; 3] = [101, 102, 103];
    let mut next_id = 0u64;
    let mut submit_decode =
        |server: &Server, rng: &mut Rng, sid: u64, tokens: usize, len_after: usize| {
            let q = Mat::randn(tokens, d, 1.0, rng);
            let k = Mat::randn(tokens, d, 1.0, rng);
            let v = Mat::randn(tokens, d, 1.0, rng);
            next_id += 1;
            server.submit(Request::decode(next_id, "tiny", sid, q, k, v, len_after, 0.0))
        };

    // Turn 0: each conversation opens with a 48-token prefill, chunked
    // into three 16-token pieces through the same decode path (so every
    // request respects the t ≤ target_t admission rule). A session's
    // next chunk is submitted only after its previous one returned —
    // decode steps of one session are causally ordered — while chunks of
    // *different* sessions fly together and mix with stateless prefill
    // traffic in the same batches.
    let mut served = 0usize;
    for c in 0..3usize {
        let mut pending = Vec::new();
        for &sid in &sessions {
            pending.push(submit_decode(&server, &mut rng, sid, 16, 16 * (c + 1))?);
        }
        // Stateless prefill traffic rides the same batches.
        let mut req = Request::new(1000 + c as u64, "tiny", 8, 256, 0.0);
        req.q = Some(Mat::randn(8, d, 1.0, &mut rng));
        pending.push(server.submit(req)?);
        for rx in pending {
            let resp = rx.recv()?;
            let out = resp.output.expect("turn-0 output");
            assert!(out.data.iter().all(|x| x.is_finite()));
            served += out.rows;
        }
    }
    println!("turn 0: prefilled {} rows across {} sessions + background", served, sessions.len());

    // Turns 1..=3: 8-token decode chunks per conversation. Steps of
    // *different* sessions are in flight together (continuous batching);
    // a session's next step waits for its previous response.
    let mut len = 48usize;
    for turn in 1..=3 {
        len += 8;
        let mut pending = Vec::new();
        for &sid in &sessions {
            pending.push(submit_decode(&server, &mut rng, sid, 8, len)?);
        }
        let mut rows = 0usize;
        for rx in pending {
            let resp = rx.recv()?;
            let out = resp.output.expect("decode output");
            assert_eq!(out.cols, d);
            rows += out.rows;
        }
        println!("turn {turn}: decoded {rows} rows at session length {len}");
    }

    let snap = server.shutdown();
    println!("{}", snap.render());
    assert!(snap.decode_steps > 0, "decode steps served");
    assert!(snap.cache_sessions_evicted > 0, "pool was sized to force eviction");
    assert!(snap.cache_pages_rematerialized > 0, "evicted sessions came back");
    println!(
        "ok: {} decode steps, {} cached-page hits, {} evictions, {} pages re-materialized",
        snap.decode_steps,
        snap.cache_page_hits,
        snap.cache_sessions_evicted,
        snap.cache_pages_rematerialized
    );
    Ok(())
}
