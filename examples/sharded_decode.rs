//! Partitioned-KV-cache sharded decode, demonstrated directly against
//! its parity contract: every shard count produces the bit-identical
//! output stream of the single-core decode engine. Shards score and
//! propose top-k candidates from their owned key ranges; the home
//! worker merges the proposals and runs the unchanged stage-3/4 core,
//! so only the candidate-scatter payload grows with the shard count —
//! never the numerics. After the opening chunk warms the workspace
//! pools, steady-state decode performs zero hot-path allocations (the
//! example installs the counting allocator to prove it).
//!
//!     cargo run --release --example sharded_decode

use star::kvcache::{SessionConfig, SessionStore};
use star::pipeline::{PipelineConfig, ShardedPipeline, SparseAttentionPipeline, WorkspacePool};
use star::tensor::Mat;
use star::util::allocmeter::CountingAllocator;
use star::util::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() -> star::Result<()> {
    let (d, prefill, steps) = (32usize, 96usize, 24usize);
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1);
    let total = prefill + steps;
    let mut rng = Rng::new(7);
    let q = Mat::randn(total, d, 1.0, &mut rng);
    let k = Mat::randn(total, d, 1.0, &mut rng);
    let v = Mat::randn(total, d, 1.0, &mut rng);
    let sub = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));

    // Single-core reference: one 96-token prefill chunk, then
    // single-token decode steps — the stream every shard count replays.
    let single = SparseAttentionPipeline::new(cfg);
    let mut ref_store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
    let ref_pool = WorkspacePool::new();
    let mut reference = Vec::with_capacity(steps + 1);
    let chunk = (sub(&q, 0, prefill), sub(&k, 0, prefill), sub(&v, 0, prefill));
    reference.push(
        single
            .decode_step_pooled(&mut ref_store, 1, &chunk.0, &chunk.1, &chunk.2, &ref_pool)?
            .out,
    );
    for p in prefill..total {
        let r = single.decode_step_pooled(
            &mut ref_store,
            1,
            &sub(&q, p, p + 1),
            &sub(&k, p, p + 1),
            &sub(&v, p, p + 1),
            &ref_pool,
        )?;
        reference.push(r.out);
    }

    println!("sharded decode vs single-core: {prefill}-token prefill + {steps} steps, d={d}");
    for w in [1usize, 2, 4, 8] {
        let sharded = ShardedPipeline::new(cfg, w);
        let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
        let pool = WorkspacePool::new();
        // The opening chunk warms the per-worker workspace pools; the
        // steady state after it must allocate nothing on the hot path.
        let r0 =
            sharded.decode_step_pooled(&mut store, 1, &chunk.0, &chunk.1, &chunk.2, &pool)?;
        assert_eq!(r0.out.max_abs_diff(&reference[0]), 0.0, "prefill chunk diverged at w={w}");
        let mut payload = r0.ring_payload_bytes;
        let mut hot = 0u64;
        let mut max_abs = 0.0f32;
        for (i, p) in (prefill..total).enumerate() {
            let r = sharded.decode_step_pooled(
                &mut store,
                1,
                &sub(&q, p, p + 1),
                &sub(&k, p, p + 1),
                &sub(&v, p, p + 1),
                &pool,
            )?;
            payload += r.ring_payload_bytes;
            hot += r.hot_path_allocs;
            max_abs = max_abs.max(r.out.max_abs_diff(&reference[i + 1]));
        }
        assert_eq!(max_abs, 0.0, "shard count {w} diverged from the single-core engine");
        assert_eq!(hot, 0, "warm sharded decode must not allocate on the hot path");
        println!(
            "  shards={w}: max|Δ|={max_abs} (bit-identical), \
             scatter payload={payload}B, hot_path_allocs: {hot}"
        );
    }
    println!("ok: every shard count decodes bit-identically to the single-core engine");
    Ok(())
}
