"""AOT lowering: JAX (L2, embedding the L1 Pallas kernels) → HLO text
artifacts + manifest.json for the rust runtime.

HLO *text* is the interchange format, NOT a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/load_hlo.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# ---------------------------------------------------------------------
# Artifact registry: name → (fn, input shapes). Shapes are the serving
# buckets the rust coordinator routes to (see examples/).
# ---------------------------------------------------------------------

# The quickstart / serving bucket: T=128 queries over S=1024 context.
ATTN_T, ATTN_S, ATTN_D = 128, 1024, 64
# Small variant for fast examples and the e2e tiny model.
TINY_T, TINY_S, TINY_D = 32, 256, 32
# Transformer block for the e2e example.
BLOCK_S, BLOCK_H = 64, 128


def registry():
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    entries = {
        "sparse_attention": (
            lambda q, k, v: (model.sparse_attention(q, k, v, keep_ratio=0.2),),
            [sd((ATTN_T, ATTN_D), f32), sd((ATTN_S, ATTN_D), f32), sd((ATTN_S, ATTN_D), f32)],
        ),
        "sparse_attention_tiny": (
            lambda q, k, v: (model.sparse_attention(q, k, v, keep_ratio=0.25),),
            [sd((TINY_T, TINY_D), f32), sd((TINY_S, TINY_D), f32), sd((TINY_S, TINY_D), f32)],
        ),
        "dense_attention_tiny": (
            lambda q, k, v: (model.dense_attention(q, k, v),),
            [sd((TINY_T, TINY_D), f32), sd((TINY_S, TINY_D), f32), sd((TINY_S, TINY_D), f32)],
        ),
        "transformer_block": (
            lambda x, wq, wk, wv, wo, w1, w2: (
                model.transformer_block(x, wq, wk, wv, wo, w1, w2),
            ),
            [
                sd((BLOCK_S, BLOCK_H), f32),
                sd((BLOCK_H, BLOCK_H), f32),
                sd((BLOCK_H, BLOCK_H), f32),
                sd((BLOCK_H, BLOCK_H), f32),
                sd((BLOCK_H, BLOCK_H), f32),
                sd((BLOCK_H, 4 * BLOCK_H), f32),
                sd((4 * BLOCK_H, BLOCK_H), f32),
            ],
        ),
    }
    return entries


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--only", default=None, help="lower just one entry")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"artifacts": []}
    for name, (fn, specs) in registry().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        # Output shapes from an eval_shape pass (no execution).
        outs = jax.eval_shape(fn, *specs)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [list(s.shape) for s in specs],
                "outputs": [list(o.shape) for o in outs],
            }
        )
        print(f"lowered {name}: {len(text)} chars -> {fname}")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['artifacts'])} artifacts in {args.out_dir}")


if __name__ == "__main__":
    main()
