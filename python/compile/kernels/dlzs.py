"""L1 Pallas kernel: DLZS approximate score prediction (Sec. IV-A).

Multiplier-free estimation of Q·Kᵀ: the second operand is reduced to
sign × 2^(MSB position) (Eq. 3 with mantissa ≈ 1), so each "multiply"
is a shift — on the STAR ASIC a barrel shifter, on TPU a cheap
exponent-add. Only ONE operand is coded (differential), which halves
conversion work and error versus the symmetric scheme (Fig. 8(b)).

Inputs carry integer values in float32 (the quantization to INT-`w`
happens in the L2 graph). ``interpret=True`` as everywhere on this CPU
build path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lz_approx(y):
    """sign(y) · 2^floor(log2 |y|), with 0 → 0 (the LZ format's value)."""
    mag = jnp.abs(y)
    exp = jnp.floor(jnp.log2(jnp.maximum(mag, 1.0)))
    return jnp.where(mag > 0, jnp.sign(y) * jnp.exp2(exp), 0.0)


def _dlzs_kernel(x_ref, y_ref, o_ref):
    """o = x @ lz(y).T for one [bt, d] × [bs, d] tile pair."""
    x = x_ref[...]
    y = _lz_approx(y_ref[...])
    # PSP behaviour: the sign is applied by *pre-flipping* the shifted
    # operand, which in value-space is exactly this signed product.
    o_ref[...] = x @ y.T


def dlzs_scores(x, y, *, block_t: int = 64):
    """Approximate x @ y.T with y LZ-coded. x [T, d], y [S, d] → [T, S]."""
    t, d = x.shape
    s = y.shape[0]
    bt = min(block_t, t)
    assert t % bt == 0, f"T={t} must divide into block_t={bt}"
    return pl.pallas_call(
        _dlzs_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, s), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, s), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
