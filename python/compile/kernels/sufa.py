"""L1 Pallas kernel: Sorted-Updating FlashAttention (SU-FA, Sec. IV-C).

The kernel consumes Q plus the *gathered, descending-sorted* K/V
selection produced by the top-k stage (the gather happens in the L2 jax
graph — Pallas sees dense [T, keep, d] tiles). Because tiles arrive in
descending estimated-score order, the running max is fixed by the FIRST
tile: the per-tile max-refresh comparisons and the exp-rescaling of the
accumulator — FlashAttention's non-matmul overhead (Fig. 5) — disappear
from the steady-state loop. A single clamp guards against DLZS
mispredicted maxima (the "tailored engine" behaviour).

Pallas runs with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md
§Hardware-Adaptation for the VMEM/MXU tiling story).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile width along the selection axis (B_c in the paper's notation).
DEFAULT_BC = 16


def _sufa_kernel(q_ref, kg_ref, vg_ref, o_ref, *, bc: int):
    """Kernel body: one program instance owns a block of T rows.

    q  [bt, d]        query rows
    kg [bt, keep, d]  gathered keys, descending estimated score
    vg [bt, keep, d]  gathered values, same order
    o  [bt, d]        output rows
    """
    q = q_ref[...]
    kg = kg_ref[...]
    vg = vg_ref[...]
    bt, keep, d = kg.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    # --- tile 0: the only place a max reduction happens -----------------
    s0 = jnp.einsum("td,tkd->tk", q, kg[:, :bc, :]) * scale  # [bt, <=bc]
    m = jnp.max(s0, axis=-1, keepdims=True)  # row max, fixed hereafter
    e0 = jnp.exp(s0 - m)
    l = jnp.sum(e0, axis=-1, keepdims=True)  # running sum
    acc = jnp.einsum("tk,tkd->td", e0, vg[:, :bc, :])  # un-normalized O

    # --- steady state: descending order ⇒ no max refresh, no rescale ----
    n_tiles = (keep + bc - 1) // bc
    if n_tiles > 1:
        # Pad the selection axis so dynamic slices stay in bounds.
        pad = n_tiles * bc - keep
        if pad:
            kg = jnp.pad(kg, ((0, 0), (0, pad), (0, 0)))
            vg = jnp.pad(vg, ((0, 0), (0, pad), (0, 0)))

        def body(i, carry):
            l, acc = carry
            start = i * bc
            k_tile = jax.lax.dynamic_slice_in_dim(kg, start, bc, axis=1)
            v_tile = jax.lax.dynamic_slice_in_dim(vg, start, bc, axis=1)
            s = jnp.einsum("td,tkd->tk", q, k_tile) * scale
            # Tailored-engine clamp: a mispredicted max cannot overflow
            # the accumulator (scores above m saturate, no rescale).
            e = jnp.exp(jnp.minimum(s - m, 0.0))
            # Mask the tail of the last (ragged) tile.
            col = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            e = jnp.where(col < keep, e, 0.0)
            l = l + jnp.sum(e, axis=-1, keepdims=True)
            acc = acc + jnp.einsum("tk,tkd->td", e, v_tile)
            return l, acc

        l, acc = jax.lax.fori_loop(1, n_tiles, body, (l, acc))

    o_ref[...] = (acc / l).astype(o_ref.dtype)


def _sufa_pallas(q, kg, vg, bc: int, block_t: int):
    t, d = q.shape
    keep = kg.shape[1]
    bt = min(block_t, t)
    assert t % bt == 0, f"T={t} must be a multiple of block_t={bt}"
    grid = (t // bt,)
    return pl.pallas_call(
        functools.partial(_sufa_kernel, bc=min(bc, keep)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, keep, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bt, keep, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), q.dtype),
        interpret=True,
    )(q, kg, vg)


def _sufa_math(q, kg, vg):
    """The same math in plain jnp — used only to derive the VJP (Pallas
    interpret mode has no reverse-mode rule), so the L2 model remains
    differentiable end to end."""
    d = q.shape[-1]
    s = jnp.einsum("td,tkd->tk", q, kg) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
    e = jnp.exp(s - m)
    l = jnp.sum(e, axis=-1, keepdims=True)
    return jnp.einsum("tk,tkd->td", e / l, vg)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _sufa(q, kg, vg, bc, block_t):
    return _sufa_pallas(q, kg, vg, bc, block_t)


def _sufa_fwd(q, kg, vg, bc, block_t):
    return _sufa_pallas(q, kg, vg, bc, block_t), (q, kg, vg)


def _sufa_bwd(bc, block_t, res, g):
    q, kg, vg = res
    _, vjp = jax.vjp(_sufa_math, q, kg, vg)
    return vjp(g)


_sufa.defvjp(_sufa_fwd, _sufa_bwd)


def sufa_attention(q, kg, vg, *, bc: int = DEFAULT_BC, block_t: int = 32):
    """SU-FA over a gathered selection.

    q  [T, d] float32
    kg [T, keep, d] gathered keys, descending estimated-score order
    vg [T, keep, d] gathered values

    Returns O [T, d]. The T axis is gridded in blocks of `block_t`
    (BlockSpec expresses the HBM→VMEM schedule; on a real TPU each block
    is double-buffered into VMEM and fed to the MXU). Differentiable via
    a custom VJP over the equivalent jnp form.
    """
    return _sufa(q, kg, vg, bc, block_t)
