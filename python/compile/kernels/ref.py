"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in this package is validated against these references at
build time (pytest) before `aot.py` is allowed to emit artifacts. The
references mirror the paper's algorithm definitions:

* dense softmax attention (Eq. 1/2),
* masked (top-k selected) attention -- the mathematical object SU-FA
  computes,
* the DLZS approximate multiply (Eq. 3/4) used for sparsity prediction,
* the full three-stage dynamic-sparsity pipeline (predict -> top-k ->
  formal compute) that `model.py` lowers.
"""

import jax
import jax.numpy as jnp


def dense_attention(q, k, v):
    """O = softmax(Q K^T / sqrt(d)) V -- the vanilla baseline."""
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def masked_attention(q, k, v, mask):
    """Softmax attention restricted to the selected keys.

    `mask` is [T, S] boolean; non-selected scores contribute nothing
    (exactly what the formal-compute stage executes on the kept pairs).
    """
    d = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    neg = jnp.finfo(scores.dtype).min
    scores = jnp.where(mask, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    # Rows with zero selected keys produce zeros, not NaN.
    p = jnp.where(mask.any(axis=-1, keepdims=True), p, 0.0)
    return p @ v


def lz_magnitude(x_int, w=8):
    """Leading-zero approximate magnitude: keep only the MSB (Eq. 3 with
    the mantissa approximated as 1): |x| -> 2^floor(log2 |x|)."""
    mag = jnp.abs(x_int)
    exp = jnp.floor(jnp.log2(jnp.maximum(mag, 1).astype(jnp.float32)))
    pow2 = jnp.exp2(exp)
    return jnp.where(mag > 0, pow2, 0.0).astype(jnp.float32)


def dlzs_matmul(x_int, y_int, w=8):
    """Differential LZ approximate matmul (Eq. 4b): x @ y.T with only the
    SECOND operand LZ-coded (mantissa -> 1). Returns float32 scores."""
    y_approx = jnp.sign(y_int).astype(jnp.float32) * lz_magnitude(y_int, w)
    return x_int.astype(jnp.float32) @ y_approx.T


def slzs_matmul(x_int, y_int, w=8):
    """Symmetric LZ matmul (FACT baseline): both operands LZ-coded."""
    xa = jnp.sign(x_int).astype(jnp.float32) * lz_magnitude(x_int, w)
    ya = jnp.sign(y_int).astype(jnp.float32) * lz_magnitude(y_int, w)
    return xa @ ya.T


def quantize(x, bits=8):
    """Symmetric per-tensor quantization to signed `bits`-bit integers."""
    qmax = 2 ** (bits - 1) - 1
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32), scale


def predict_scores(q, x, wk, bits=8):
    """Cross-phase DLZS prediction (Sec. IV-A).

    Phase 1.1: K-hat = X . LZ(W_k)  (weights pre-coded offline).
    Phase 1.2: A-hat = LZ(Q) . K-hat^T  (Q is the coded operand, not K,
    to avoid error accumulation).
    Returns approximate scores [T, S] (float32, unscaled).
    """
    xq, _ = quantize(x, bits)
    wq, _ = quantize(wk, bits)
    qq, _ = quantize(q, bits)
    # Phase 1.1 -- differential: X full precision, W_k LZ-coded.
    k_hat = dlzs_matmul(xq, wq.T, bits)  # [S, d]
    # Phase 1.2 -- differential the other way: Q LZ-coded.
    k_int = jnp.round(
        k_hat / jnp.maximum(jnp.max(jnp.abs(k_hat)), 1e-8) * 127
    ).astype(jnp.int32)
    a_hat = dlzs_matmul(k_int, qq, bits).T  # [S,T] -> [T,S]
    return a_hat


def topk_mask(scores, keep):
    """Per-row top-`keep` boolean mask [T, S] from (approximate) scores."""
    t, s = scores.shape
    keep = max(1, min(keep, s))
    thresh = jnp.sort(scores, axis=-1)[:, s - keep][:, None]
    return scores >= thresh


def topk_indices_desc(scores, keep):
    """Per-row top-`keep` indices, sorted by score descending -- the order
    SU-FA consumes (the first tile carries the running max).

    Implemented with argsort rather than lax.top_k: top_k lowers to a
    `topk(..., largest=true)` HLO instruction that the xla_extension
    0.5.1 text parser (the rust runtime's loader) rejects; `sort` round-
    trips fine and is semantically identical here.
    """
    idx = jnp.argsort(-scores, axis=-1)
    return idx[:, :keep]


def sufa_reference(q, k, v, idx):
    """Sorted-updating attention over the selected (descending-sorted)
    keys -- mathematically identical to masked softmax over `idx`."""
    d = q.shape[-1]
    kg = k[idx]  # [T, keep, d]
    vg = v[idx]
    s = jnp.einsum("td,tkd->tk", q, kg) / jnp.sqrt(jnp.asarray(d, q.dtype))
    m = s[:, 0:1]  # descending order: the first element is the max
    e = jnp.exp(s - m)
    l = e.sum(axis=-1, keepdims=True)
    return jnp.einsum("tk,tkd->td", e / l, vg)


def sparse_attention_pipeline(q, x, wk, wv, keep_ratio=0.2, bits=8):
    """The full three-stage DS pipeline (the paper's Fig. 6 workflow).

    1. pre-compute: cross-phase DLZS estimate of A-hat,
    2. top-k: per-row selection (descending order),
    3. on-demand KV + formal compute: exact K/V only where needed,
       SU-FA softmax over the sorted selection.
    """
    s_len = x.shape[0]
    keep = max(1, int(round(s_len * keep_ratio)))
    a_hat = predict_scores(q, x, wk, bits)
    idx = topk_indices_desc(a_hat, keep)
    # On-demand generation modeled densely in the oracle (the accelerator
    # generates only the union of selected rows -- same numerics).
    k = x @ wk
    v = x @ wv
    return sufa_reference(q, k, v, idx)
