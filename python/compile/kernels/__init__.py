# L1: Pallas kernels for the paper's compute hot-spots + their oracle.
