"""L2: the JAX model — the paper's sparse-attention pipeline plus a tiny
transformer block, calling the L1 Pallas kernels so everything lowers
into one HLO module per entry point.

These functions are what `aot.py` lowers to HLO text; the rust runtime
executes the artifacts, so the code here must be pure and shape-static.
"""

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.dlzs import dlzs_scores
from compile.kernels.sufa import sufa_attention


def sparse_attention(q, k, v, keep_ratio=0.2, bits=8):
    """The STAR formal path given materialized K/V: DLZS-estimate scores
    (L1 kernel), select per-row top-k, gather descending, SU-FA (L1
    kernel).

    q [T, d], k [S, d], v [S, d] → O [T, d].
    """
    t, d = q.shape
    s = k.shape[0]
    keep = max(1, int(round(s * keep_ratio)))
    # Pre-compute stage: quantize + DLZS multiplier-free estimate.
    qq, _ = ref.quantize(q, bits)
    kq, _ = ref.quantize(k, bits)
    a_hat = dlzs_scores(qq.astype(jnp.float32), kq.astype(jnp.float32))
    # Top-k stage: per-row selection, descending (SU-FA's input order).
    idx = ref.topk_indices_desc(a_hat, keep)
    # Formal stage: gather the survivors, run the SU-FA kernel.
    kg = k[idx]
    vg = v[idx]
    return sufa_attention(q, kg, vg)


def cross_phase_attention(q, x, wk, wv, keep_ratio=0.2, bits=8):
    """The full cross-phase pipeline from raw activations X: K̂ via the
    pre-coded weights, Â via DLZS, on-demand K/V generation, SU-FA."""
    s = x.shape[0]
    keep = max(1, int(round(s * keep_ratio)))
    a_hat = ref.predict_scores(q, x, wk, bits)
    idx = ref.topk_indices_desc(a_hat, keep)
    # On-demand generation: the graph computes K/V densely (XLA has no
    # scatter-compute primitive), but only gathered rows feed SU-FA —
    # the accelerator realizes the same semantics with a binary mask.
    k = x @ wk
    v = x @ wv
    return sufa_attention(q, k[idx], v[idx])


def dense_attention(q, k, v):
    """Vanilla dense attention entry point (the comparison baseline)."""
    return ref.dense_attention(q, k, v)


def init_block_params(key, hidden, ffn_mult=4):
    """Parameters for one pre-norm transformer block (single head group)."""
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    scale = 1.0 / jnp.sqrt(hidden)
    return {
        "wq": jax.random.normal(k1, (hidden, hidden)) * scale,
        "wk": jax.random.normal(k2, (hidden, hidden)) * scale,
        "wv": jax.random.normal(k3, (hidden, hidden)) * scale,
        "wo": jax.random.normal(k4, (hidden, hidden)) * scale,
        "w1": jax.random.normal(k5, (hidden, ffn_mult * hidden)) * scale,
        "w2": jax.random.normal(k6, (ffn_mult * hidden, hidden)) * scale,
    }


def _layernorm(x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps)


def transformer_block(x, wq, wk, wv, wo, w1, w2, keep_ratio=0.2):
    """One pre-norm transformer block whose attention is the STAR sparse
    pipeline. x [S, H] → [S, H]. Single head group (the multi-head
    split is orchestrated by the rust coordinator per head)."""
    h = _layernorm(x)
    q = h @ wq
    k = h @ wk
    v = h @ wv
    attn = sparse_attention(q, k, v, keep_ratio=keep_ratio)
    x = x + attn @ wo
    h = _layernorm(x)
    x = x + jax.nn.relu(h @ w1) @ w2
    return x
