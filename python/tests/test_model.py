"""L2 model tests: pipeline semantics, shapes, and AOT lowering."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


def test_sparse_attention_close_to_dense_at_modest_sparsity():
    """keep 50% of a redundant context ≈ dense output (the premise of
    dynamic sparsity)."""
    t, s, d = 32, 128, 32
    q, k, v = rand(0, (t, d)), rand(1, (s, d)), rand(2, (s, d))
    sparse = model.sparse_attention(q, k, v, keep_ratio=0.5)
    dense = model.dense_attention(q, k, v)
    err = np.max(np.abs(np.asarray(sparse) - np.asarray(dense)))
    assert err < 0.35, f"sparse vs dense divergence {err}"


def test_sparse_attention_keep_one_selects_argmax():
    t, s, d = 8, 64, 16
    q, k, v = rand(3, (t, d)), rand(4, (s, d)), rand(5, (s, d))
    out = model.sparse_attention(q, k, v, keep_ratio=1.0 / s)
    assert out.shape == (t, d)
    assert np.isfinite(np.asarray(out)).all()


def test_cross_phase_pipeline_matches_ref():
    t, s, h, d = 16, 64, 48, 16
    q = rand(6, (t, d))
    x = rand(7, (s, h))
    wk = rand(8, (h, d), 0.2)
    wv = rand(9, (h, d), 0.2)
    got = model.cross_phase_attention(q, x, wk, wv, keep_ratio=0.25)
    want = ref.sparse_attention_pipeline(q, x, wk, wv, keep_ratio=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_transformer_block_shapes_and_grad():
    s, hdim = 32, 64
    params = model.init_block_params(jax.random.PRNGKey(0), hdim)
    x = rand(10, (s, hdim))

    def loss(x):
        y = model.transformer_block(
            x,
            params["wq"],
            params["wk"],
            params["wv"],
            params["wo"],
            params["w1"],
            params["w2"],
            keep_ratio=0.5,
        )
        return jnp.sum(y**2)

    y = model.transformer_block(
        x, params["wq"], params["wk"], params["wv"], params["wo"], params["w1"], params["w2"]
    )
    assert y.shape == (s, hdim)
    g = jax.grad(loss)(x)
    assert g.shape == x.shape
    assert np.isfinite(np.asarray(g)).all(), "block must be differentiable (L2 fwd/bwd)"


def test_registry_entries_lower_and_manifest_schema(tmp_path):
    """Every registry entry lowers to HLO text; the manifest matches the
    rust runtime's schema."""
    entries = aot.registry()
    assert set(entries) >= {
        "sparse_attention",
        "sparse_attention_tiny",
        "dense_attention_tiny",
        "transformer_block",
    }
    # Lower just the tiny entry for speed, through the real main().
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--only", "dense_attention_tiny"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest["artifacts"]) == 1
    entry = manifest["artifacts"][0]
    assert entry["name"] == "dense_attention_tiny"
    assert entry["inputs"] == [[32, 32], [256, 32], [256, 32]]
    assert entry["outputs"] == [[32, 32]]
    hlo = (tmp_path / entry["file"]).read_text()
    assert hlo.startswith("HloModule"), hlo[:80]
    assert "f32[32,32]" in hlo


def test_quantize_roundtrip_bounds():
    x = rand(11, (64, 64), 5.0)
    q, scale = ref.quantize(x, 8)
    assert int(jnp.max(jnp.abs(q))) <= 127
    err = np.max(np.abs(np.asarray(q * scale - x)))
    assert err <= float(scale) * 0.5 + 1e-6
