"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes; assert_allclose against ref.py is THE core
correctness signal before artifacts are allowed to exist.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dlzs import dlzs_scores
from compile.kernels.sufa import sufa_attention

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------
# SU-FA kernel
# ---------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([4, 8, 16, 32]),
    s=st.sampled_from([16, 40, 64, 128]),
    d=st.sampled_from([4, 8, 16, 32]),
    keep_frac=st.sampled_from([0.1, 0.25, 0.5, 1.0]),
    seed=st.integers(0, 2**16),
)
def test_sufa_matches_masked_oracle(t, s, d, keep_frac, seed):
    """With a TRUE-score descending selection, SU-FA is exact."""
    q = rand(seed, (t, d))
    k = rand(seed + 1, (s, d))
    v = rand(seed + 2, (s, d))
    keep = max(1, int(round(s * keep_frac)))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    idx = ref.topk_indices_desc(scores, keep)
    out = sufa_attention(q, k[idx], v[idx], block_t=min(32, t))
    want = ref.sufa_reference(q, k, v, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([4, 16]),
    s=st.sampled_from([32, 64]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_sufa_full_selection_equals_dense(t, s, d, seed):
    """keep = S with descending order reproduces dense attention."""
    q = rand(seed, (t, d))
    k = rand(seed + 1, (s, d))
    v = rand(seed + 2, (s, d))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    idx = ref.topk_indices_desc(scores, s)
    out = sufa_attention(q, k[idx], v[idx], block_t=min(32, t))
    want = ref.dense_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_sufa_masked_equivalence_exact_order():
    """SU-FA output == masked softmax over the same selection."""
    t, s, d, keep = 8, 64, 16, 16
    q, k, v = rand(0, (t, d)), rand(1, (s, d)), rand(2, (s, d))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    idx = ref.topk_indices_desc(scores, keep)
    mask = jnp.zeros((t, s), bool).at[jnp.arange(t)[:, None], idx].set(True)
    out = sufa_attention(q, k[idx], v[idx], block_t=8)
    want = ref.masked_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_sufa_estimated_order_small_error():
    """With DLZS-estimated ordering the clamp may fire; the result must
    stay close to the exact masked softmax over the same selection."""
    t, s, d, keep = 16, 128, 32, 32
    q, k, v = rand(3, (t, d)), rand(4, (s, d)), rand(5, (s, d))
    qq, _ = ref.quantize(q)
    kq, _ = ref.quantize(k)
    a_hat = ref.dlzs_matmul(qq, kq)
    idx = ref.topk_indices_desc(a_hat, keep)
    out = sufa_attention(q, k[idx], v[idx], block_t=16)
    mask = jnp.zeros((t, s), bool).at[jnp.arange(t)[:, None], idx].set(True)
    want = ref.masked_attention(q, k, v, mask)
    err = np.max(np.abs(np.asarray(out) - np.asarray(want)))
    assert err < 0.05, f"estimated-order SU-FA error {err}"


def test_sufa_single_tile_and_ragged_tail():
    """keep < bc (single tile) and keep % bc != 0 (ragged tail)."""
    t, s, d = 8, 64, 8
    q, k, v = rand(6, (t, d)), rand(7, (s, d)), rand(8, (s, d))
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    for keep in [3, 17, 33]:
        idx = ref.topk_indices_desc(scores, keep)
        out = sufa_attention(q, k[idx], v[idx], block_t=8)
        want = ref.sufa_reference(q, k, v, idx)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5, err_msg=f"keep={keep}"
        )


# ---------------------------------------------------------------------
# DLZS kernel
# ---------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    t=st.sampled_from([4, 16, 64]),
    s=st.sampled_from([16, 64, 128]),
    d=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_dlzs_kernel_matches_ref(t, s, d, seed):
    q = rand(seed, (t, d), 3.0)
    k = rand(seed + 9, (s, d), 3.0)
    qq, _ = ref.quantize(q)
    kq, _ = ref.quantize(k)
    out = dlzs_scores(qq.astype(jnp.float32), kq.astype(jnp.float32), block_t=t)
    want = ref.dlzs_matmul(qq, kq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_dlzs_better_than_slzs():
    """Fig. 8(b): single-sided coding loses less information."""
    q = rand(10, (64, 32), 3.0)
    k = rand(11, (128, 32), 3.0)
    qq, _ = ref.quantize(q)
    kq, _ = ref.quantize(k)
    exact = (qq.astype(jnp.float32) @ kq.astype(jnp.float32).T)
    d_err = np.abs(np.asarray(ref.dlzs_matmul(qq, kq) - exact)).mean()
    s_err = np.abs(np.asarray(ref.slzs_matmul(qq, kq) - exact)).mean()
    assert d_err < s_err, f"DLZS err {d_err} !< SLZS err {s_err}"


def test_dlzs_topk_hit_rate_high():
    """Fig. 17(a): DLZS top-20% hit rate is high. (I.i.d. Gaussian scores
    are the WORST case — no dominant tokens; real attention rows (Type
    I/II) push it >97%, which the rust hit-rate bench measures.)"""
    t, s, d = 64, 256, 64
    q, k = rand(12, (t, d)), rand(13, (s, d))
    qq, _ = ref.quantize(q)
    kq, _ = ref.quantize(k)
    keep = s // 5
    approx_idx = np.asarray(ref.topk_indices_desc(ref.dlzs_matmul(qq, kq), keep))
    exact_idx = np.asarray(ref.topk_indices_desc(q @ k.T, keep))
    hits = np.mean(
        [len(set(a) & set(e)) / keep for a, e in zip(approx_idx, exact_idx)]
    )
    assert hits > 0.85, f"DLZS hit rate {hits}"


def test_lz_magnitude_is_power_of_two():
    xs = jnp.asarray([-7, -4, -1, 0, 1, 2, 3, 5, 100, 127], jnp.int32)
    mags = np.asarray(ref.lz_magnitude(xs))
    for x, m in zip(np.asarray(xs), mags):
        if x == 0:
            assert m == 0
        else:
            assert m == 2 ** int(np.floor(np.log2(abs(x))))
            assert m <= abs(x) < 2 * m
