//! `star` — the STAR coordinator binary.
//!
//! Subcommands:
//!   bench <name|all>        regenerate a paper table/figure
//!   bench traffic           measured-vs-modeled memory-traffic
//!                           reconciliation across all three execution
//!                           paths (writes BENCH_traffic.json)
//!   bench check             perf-regression gate: re-run the gated
//!                           benches and compare against the committed
//!                           BENCH_*.json baselines (nonzero exit on
//!                           regression)
//!   sim [--model M]...      single-core cycle-level simulation
//!   spatial [--mesh 5x5]    multi-core spatial simulation
//!   serve [--requests N]    run the LTPP serving loop (native pipeline
//!                           by default; --sim for the simulator backend;
//!                           --shards N pins the sequence-sharded worker
//!                           count; PJRT artifacts with the `pjrt` feature)
//!   dse [--seq S]           sub-segment design-space exploration
//!   trace [out.json]        run a reference workload on all three
//!                           execution paths with tracing enabled and
//!                           write a Chrome trace-event JSON
//!   info                    list configuration presets (and artifacts
//!                           under the `pjrt` feature)
//!
//! `STAR_TRACE=1` enables span tracing for any subcommand (e.g.
//! `STAR_TRACE=1 star bench decode` meters the traced hot path).
//! `STAR_TRAFFIC=1` enables byte-traffic counting the same way, so
//! served metrics and traced spans carry measured byte counts.

use star::cli::Args;
use star::util::allocmeter::CountingAllocator;
use star::config::{AccelConfig, ModelConfig, SpatialConfig};
use star::coordinator::{Backend, BatcherConfig, Request, Router, Server, ServerConfig, Variant};
use star::pipeline::PipelineConfig;
use star::sim::dram::DramChannel;
use star::sim::pipeline::{simulate, FeatureSet, WorkloadShape};
use star::spatial::sim::{spatial_run, CoreKind, Dataflow};
use star::util::logging;
use star::Result;

// Meter heap allocations per thread (one counter bump per alloc) so
// `star bench decode` / `spatial-exec` report a real `hot_path_allocs`
// — the zero-allocation regression guard of the tile engine.
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() {
    logging::init_from_env();
    // STAR_TRACE=1 turns span tracing on for any subcommand, so the
    // benches' zero-allocation guards also meter the traced hot path.
    if std::env::var("STAR_TRACE").map(|v| !v.is_empty() && v != "0").unwrap_or(false) {
        star::obs::set_enabled(true);
    }
    if std::env::var("STAR_TRAFFIC").map(|v| !v.is_empty() && v != "0").unwrap_or(false) {
        star::obs::traffic::set_enabled(true);
    }
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("bench") => {
            let name = args.positional.first().map(String::as_str).unwrap_or("all");
            star::bench::run(name)
        }
        Some("sim") => cmd_sim(args),
        Some("spatial") => cmd_spatial(args),
        Some("serve") => cmd_serve(args),
        Some("dse") => cmd_dse(args),
        Some("trace") => cmd_trace(args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: star <bench|sim|spatial|serve|dse|trace|info> [--options]\n\
                 benches: {:?}",
                star::bench::ALL
            );
            Ok(())
        }
    }
}

fn cmd_sim(args: &Args) -> Result<()> {
    let model = ModelConfig::preset(args.get_or("model", "gpt2"))
        .ok_or_else(|| anyhow::anyhow!("unknown model preset"))?;
    let t = args.get_usize("tp", 128);
    let s = args.get_usize("seq", model.seq_len);
    let keep = args.get_f64("keep", 0.2);
    let shape = WorkloadShape::new(t, s, model.head_dim(), model.hidden, keep);
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    let r = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
    println!(
        "STAR single-core: model={} T={t} S={s} keep={keep}\n\
         latency = {:.3} ms   eff = {:.0} GOPS   energy-eff = {:.0} GOPS/W\n\
         MAT share = {:.1}%   DRAM = {}   stalls = {}",
        model.name,
        r.total_s * 1e3,
        r.eff_gops,
        r.energy_eff_gops_w(),
        100.0 * r.mat_fraction(),
        star::util::fmt_bytes(r.dram_bytes as f64),
        r.stall_cycles,
    );
    Ok(())
}

fn cmd_spatial(args: &Args) -> Result<()> {
    let cfg = match args.get_or("mesh", "5x5") {
        "6x6" => SpatialConfig::mesh6x6(),
        _ => SpatialConfig::mesh5x5(),
    };
    let s = args.get_usize("seq", 16384);
    let r = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, 64, 768, 0.2);
    println!(
        "Spatial-STAR {}x{}: S={s}  latency = {:.3} ms  throughput = {:.1} TOPS  \
         exposed comm = {:.1} us  NoC = {}",
        cfg.mesh_rows,
        cfg.mesh_cols,
        r.total_s * 1e3,
        r.eff_tops(),
        r.exposed_comm_s * 1e6,
        star::util::fmt_bytes(r.noc_bytes as f64),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.get_usize("requests", 64);
    let router = Router::new(vec![Variant {
        name: "sparse_attention".into(),
        model: "gpt2".into(),
        max_t: 128,
        s: 1024,
    }]);
    let backend = pick_serve_backend(args);
    let server = Server::start(router, backend, ServerConfig {
        batcher: BatcherConfig { target_t: 128, max_wait_s: 2e-3 },
        workers: 2,
    });
    let mut rng = star::util::Rng::new(2);
    let mut rxs = Vec::new();
    for id in 0..n as u64 {
        let t = 8 * rng.range(1, 5);
        let mut req = Request::new(id, "gpt2", t, 1024, 0.0);
        req.q = Some(star::tensor::Mat::randn(t, 64, 1.0, &mut rng));
        rxs.push(server.submit(req)?);
    }
    // One over-target prefill (t > target_t = 128): admitted onto the
    // sequence-sharded pipeline instead of being rejected.
    let t_wide = 192;
    let mut wide = Request::new(n as u64, "gpt2", t_wide, 1024, 0.0);
    wide.q = Some(star::tensor::Mat::randn(t_wide, 64, 1.0, &mut rng));
    rxs.push(server.submit(wide)?);
    for rx in rxs {
        let _ = rx.recv();
    }
    let snap = server.shutdown();
    println!("{}", snap.render());
    Ok(())
}

/// Backend selection for `star serve`: PJRT artifacts when compiled with
/// the `pjrt` feature and artifacts exist, the cycle-level simulator
/// under `--sim`, and the native sparse-attention pipeline otherwise.
fn pick_serve_backend(args: &Args) -> Backend {
    if args.flag("sim") {
        println!("serving with the simulated backend (--sim)");
        return Backend::Sim {
            feats: FeatureSet::star(),
            accel: AccelConfig::default(),
            dram: DramChannel::accel_256(),
            d: 64,
            h: 768,
            keep: 0.2,
            time_scale: 1.0,
        };
    }
    let contexts = serve_contexts();
    #[cfg(feature = "pjrt")]
    {
        let dir = star::runtime::manifest::default_dir();
        if star::runtime::engine::artifacts_available(&dir) && !args.flag("native") {
            println!("serving with the PJRT backend from {dir:?}");
            return Backend::Pjrt { artifact_dir: dir, contexts };
        }
    }
    println!("serving with the native sparse-attention pipeline");
    let pipeline = PipelineConfig::star().with_threads(1);
    // Session-aware by default: decode requests share a paged KV-cache
    // sized to the pipeline's tile (64 pages ≈ 4k cached tokens).
    let store = star::kvcache::SessionStore::new(star::kvcache::SessionConfig::for_pipeline(
        &pipeline, 64, 64,
    ));
    // Over-target prefill runs sequence-sharded; `--shards N` pins the
    // worker count (0 = one per core — outputs are identical either way).
    Backend::native_with_sessions(pipeline, contexts, store)
        .with_shards(args.get_usize("shards", 0))
}

/// The fixed gpt2-shaped KV context both serve backends attend into.
fn serve_contexts() -> std::collections::BTreeMap<String, (star::tensor::Mat, star::tensor::Mat)> {
    let mut contexts = std::collections::BTreeMap::new();
    let mut rng = star::util::Rng::new(1);
    contexts.insert(
        "sparse_attention".to_string(),
        (
            star::tensor::Mat::randn(1024, 64, 1.0, &mut rng),
            star::tensor::Mat::randn(1024, 64, 1.0, &mut rng),
        ),
    );
    contexts
}

fn cmd_dse(args: &Args) -> Result<()> {
    let s = args.get_usize("seq", 1024);
    let keep = args.get_f64("keep", 0.2);
    let mut rng = star::util::Rng::new(42);
    let gen = star::workload::ScoreGen::default();
    let rows = gen.rows(64, s, &mut rng);
    let res = star::sparsity::dse::explore_segments(
        &rows,
        keep,
        5.0,
        16,
        &[2, 4, 8, 16, 32],
        &star::sparsity::dse::DseWeights::default(),
    );
    println!("DSE over sub-segment count (S={s}, keep={keep}):");
    for c in &res.evaluated {
        println!(
            "  n={:<3} sort={:<12.0} sufa={:<12.0} recall={:.3} obj={:.0}",
            c.segments, c.cost_sort, c.cost_sufa, c.recall, c.objective
        );
    }
    println!("best: n={} (objective {:.0})", res.best.segments, res.best.objective);
    Ok(())
}

/// `star trace [out.json]` — capture a steady-state Chrome trace.
///
/// Runs one reference workload through all three execution paths (batch
/// prefill, autoregressive decode, sequence-sharded prefill) on a single
/// warm [`star::pipeline::WorkspacePool`] with tracing enabled, asserts
/// the traced warm hot path metered **zero** heap allocations, and
/// writes the captured spans as a Chrome trace-event JSON (load it in
/// `chrome://tracing` or <https://ui.perfetto.dev>).
fn cmd_trace(args: &Args) -> Result<()> {
    use star::obs::{chrome_trace, validate_chrome_trace, ExecPath, Stage};
    use star::pipeline::{PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool};
    use star::tensor::Mat;

    let out_path = args.positional.first().map(String::as_str).unwrap_or("trace.json");
    star::obs::set_enabled(true);
    // Count byte traffic too, so every exported span carries its
    // measured `bytes` attribution in `args`.
    star::obs::traffic::set_enabled(true);

    let d = 64;
    let cfg = PipelineConfig::star().with_keep(0.2).with_tile(16).with_threads(1);
    let pipe = SparseAttentionPipeline::new(cfg);
    let sharded = ShardedPipeline::new(cfg, 2);
    let pool = WorkspacePool::new();
    let mut rng = star::util::Rng::new(7);
    let q = Mat::randn(64, d, 1.0, &mut rng);
    let k = Mat::randn(512, d, 1.0, &mut rng);
    let v = Mat::randn(512, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let sub = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));

    // Cold passes warm the pooled workspaces; their spans are drained
    // and discarded so the trace shows steady state only.
    pipe.run_pooled(&inputs, &pool);
    sharded.run_pooled(&inputs, &pool);
    let mut store = star::kvcache::SessionStore::new(star::kvcache::SessionConfig::for_pipeline(
        &cfg, d, 0,
    ));
    pipe.decode_step_pooled(&mut store, 1, &sub(&q, 0, 8), &sub(&k, 0, 8), &sub(&v, 0, 8), &pool)?;
    let mut warmup = Vec::new();
    pool.drain_spans(&mut warmup);

    // Warm, traced passes — the spans that land in the file. Their
    // metered stage cores must not touch the heap even while recording.
    let mut hot = 0u64;
    hot += pipe.run_pooled(&inputs, &pool).hot_path_allocs;
    hot += sharded.run_pooled(&inputs, &pool).hot_path_allocs;
    for step in 0..4usize {
        let lo = 8 + step;
        let r = pipe.decode_step_pooled(
            &mut store,
            1,
            &sub(&q, lo, lo + 1),
            &sub(&k, lo, lo + 1),
            &sub(&v, lo, lo + 1),
            &pool,
        )?;
        hot += r.hot_path_allocs;
    }
    anyhow::ensure!(
        hot == 0,
        "traced warm hot path allocated ({hot} allocs) — tracing must stay allocation-free"
    );

    let mut spans = Vec::new();
    pool.drain_spans(&mut spans);
    let have = |st: Stage, p: ExecPath| spans.iter().any(|s| s.stage == st && s.path == p);
    for st in [Stage::Predict, Stage::Topk, Stage::KvGen, Stage::Formal] {
        for p in [ExecPath::Prefill, ExecPath::Decode, ExecPath::Sharded] {
            anyhow::ensure!(
                have(st, p),
                "trace missing {} spans on the {} path",
                st.name(),
                p.name()
            );
        }
    }
    anyhow::ensure!(
        have(Stage::Ring, ExecPath::Sharded) && have(Stage::Merge, ExecPath::Sharded),
        "trace missing the sharded ring/merge phases"
    );

    let doc = chrome_trace(&spans);
    let events = validate_chrome_trace(&doc).map_err(|e| anyhow::anyhow!("invalid trace: {e}"))?;
    std::fs::write(out_path, doc.pretty())?;
    println!(
        "wrote {events} trace events ({} steady-state spans; {} warm-up spans discarded) to {out_path}",
        spans.len(),
        warmup.len()
    );
    println!("hot-path allocations during traced passes: {hot}");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("model presets:");
    for m in ModelConfig::suite() {
        println!(
            "  {:<12} H={:<5} heads={:<3} layers={:<3} S={}",
            m.name, m.hidden, m.heads, m.layers, m.seq_len
        );
    }
    println!("pipeline presets:");
    for (name, cfg) in [
        ("star", PipelineConfig::star()),
        ("ds_baseline", PipelineConfig::ds_baseline()),
        ("dense_oracle", PipelineConfig::dense_oracle()),
    ] {
        println!(
            "  {:<12} predict={:?} topk={:?} formal={:?} keep={} tile={}",
            name, cfg.predict, cfg.topk, cfg.formal, cfg.keep_ratio, cfg.tile_t
        );
    }
    #[cfg(feature = "pjrt")]
    {
        let dir = star::runtime::manifest::default_dir();
        if star::runtime::engine::artifacts_available(&dir) {
            let m = star::runtime::Manifest::load(&dir)?;
            println!("artifacts in {dir:?}:");
            for e in &m.entries {
                println!("  {:<24} {:?} -> {:?}", e.name, e.inputs, e.outputs);
            }
        } else {
            println!("no artifacts at {dir:?} (run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("PJRT runtime disabled (build with --features pjrt to list artifacts)");
    Ok(())
}
