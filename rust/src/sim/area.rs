//! Area model: the Fig. 21 breakdown of the STAR accelerator at 28 nm.
//!
//! Anchored on the paper's totals — 5.69 mm², 949.85 mW, with the LP part
//! (DLZS + SADS) at 18.1% of area and 14.1% of power — and on each unit's
//! datapath widths from [`crate::config::AccelConfig`]. Used by Table III
//! (area efficiency) and the Fig. 21 bench.

use crate::config::AccelConfig;

/// Area/power of one architectural unit.
#[derive(Clone, Debug)]
pub struct UnitBudget {
    pub name: &'static str,
    pub area_mm2: f64,
    pub power_mw: f64,
}

/// Full-chip budget (Fig. 21).
#[derive(Clone, Debug)]
pub struct ChipBudget {
    pub units: Vec<UnitBudget>,
}

impl ChipBudget {
    /// Build the budget for an accelerator configuration. Per-unit
    /// densities are calibrated so the *default* config reproduces the
    /// paper's totals; other configs scale linearly in datapath width.
    pub fn for_config(cfg: &AccelConfig) -> ChipBudget {
        let d = AccelConfig::default();
        // Paper anchors at the default config (28 nm, 1 GHz).
        let total_area = 5.69;
        let total_power = 949.85;
        // Shares: LP (DLZS+SADS) 18.1% area / 14.1% power; the rest split
        // across PE array (KV gen + score matmuls), SU-FA engine, scheduler
        // and SRAM in proportions typical of MAC-dominated designs.
        let shares: [(&'static str, f64, f64); 6] = [
            ("dlzs-unit", 0.101, 0.079),
            ("sads-unit", 0.080, 0.062),
            ("pe-array", 0.392, 0.468),
            ("sufa-unit", 0.153, 0.186),
            ("scheduler", 0.044, 0.035),
            ("sram", 0.230, 0.170),
        ];
        let scale = |name: &str| -> f64 {
            match name {
                "dlzs-unit" => cfg.dlzs_lanes as f64 / d.dlzs_lanes as f64,
                "sads-unit" => cfg.sads_lanes as f64 / d.sads_lanes as f64,
                "pe-array" => cfg.pe_macs_per_cycle as f64 / d.pe_macs_per_cycle as f64,
                "sufa-unit" => cfg.sufa_exp_units as f64 / d.sufa_exp_units as f64,
                // SRAM macro area grows sublinearly with capacity (bank
                // periphery amortizes — CACTI-like exponent, calibrated so
                // the Sec. III-A example of 5 MB ⇒ ~5.7 mm² holds).
                "sram" => (cfg.sram_bytes as f64 / d.sram_bytes as f64).powf(0.55),
                _ => 1.0,
            }
        };
        let units = shares
            .iter()
            .map(|&(name, ashare, pshare)| UnitBudget {
                name,
                area_mm2: total_area * ashare * scale(name),
                power_mw: total_power * pshare * scale(name),
            })
            .collect();
        ChipBudget { units }
    }

    pub fn total_area_mm2(&self) -> f64 {
        self.units.iter().map(|u| u.area_mm2).sum()
    }

    pub fn total_power_mw(&self) -> f64 {
        self.units.iter().map(|u| u.power_mw).sum()
    }

    /// Area share of the LP (prediction) part — DLZS + SADS.
    pub fn lp_area_share(&self) -> f64 {
        let lp: f64 = self
            .units
            .iter()
            .filter(|u| u.name == "dlzs-unit" || u.name == "sads-unit")
            .map(|u| u.area_mm2)
            .sum();
        lp / self.total_area_mm2()
    }

    /// Power share of the LP part.
    pub fn lp_power_share(&self) -> f64 {
        let lp: f64 = self
            .units
            .iter()
            .filter(|u| u.name == "dlzs-unit" || u.name == "sads-unit")
            .map(|u| u.power_mw)
            .sum();
        lp / self.total_power_mw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_reproduces_paper_totals() {
        let b = ChipBudget::for_config(&AccelConfig::default());
        assert!((b.total_area_mm2() - 5.69).abs() < 0.01, "area {}", b.total_area_mm2());
        assert!((b.total_power_mw() - 949.85).abs() < 1.0, "power {}", b.total_power_mw());
    }

    #[test]
    fn lp_shares_match_fig21() {
        let b = ChipBudget::for_config(&AccelConfig::default());
        assert!((b.lp_area_share() - 0.181).abs() < 0.005, "{}", b.lp_area_share());
        assert!((b.lp_power_share() - 0.141).abs() < 0.005, "{}", b.lp_power_share());
    }

    #[test]
    fn area_scales_with_datapath() {
        let mut cfg = AccelConfig::default();
        cfg.pe_macs_per_cycle *= 2;
        let b = ChipBudget::for_config(&cfg);
        assert!(b.total_area_mm2() > 5.69);
        let pe = b.units.iter().find(|u| u.name == "pe-array").unwrap();
        assert!((pe.area_mm2 - 2.0 * 5.69 * 0.392).abs() < 0.01);
    }

    #[test]
    fn sram_area_tracks_capacity() {
        // The Sec. III-A(2) example: 5 MB of SRAM ⇒ ~5.7 mm² at 28 nm.
        let mut cfg = AccelConfig::default();
        cfg.sram_bytes = 5 * 1024 * 1024;
        let b = ChipBudget::for_config(&cfg);
        let sram = b.units.iter().find(|u| u.name == "sram").unwrap();
        assert!((4.0..8.0).contains(&sram.area_mm2), "5MB SRAM area {}", sram.area_mm2);
    }
}
