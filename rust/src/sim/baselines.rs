//! SOTA accelerator baselines: FACT, Energon, ELSA, SpAtten, Simba/NVDLA.
//!
//! Two layers of modeling:
//!
//! 1. **Published specs** (Table III): the numbers the papers report, with
//!    the tech-normalization rule of the Table III footnote
//!    (f ∝ s, power ∝ (1/s)(1.0/Vdd)², s = tech/28nm) so comparisons are
//!    apples-to-apples at 28 nm / 1.0 V.
//! 2. **Behavioral models**: each baseline mapped onto the cycle-level
//!    simulator as a [`FeatureSet`] + [`AccelConfig`], used where the
//!    paper runs the baselines on *its* workloads (Fig. 3, Fig. 24(c)(d)).

use super::energy::normalize_to_28nm;
use super::pipeline::{FeatureSet, FormalKind, PredictKind, TopkKind};
use crate::config::AccelConfig;

/// Published datasheet row for one accelerator (Table III).
#[derive(Clone, Debug)]
pub struct BaselineSpec {
    pub name: &'static str,
    pub tech_nm: f64,
    pub freq_hz: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    /// Effective (sparsity-counted) throughput, GOPS, as published.
    pub throughput_gops: f64,
    /// Energy efficiency as published in Table III (GOPS/W, already
    /// normalized to 28 nm / 1.0 V by the paper's rule).
    pub energy_eff_gops_w: f64,
    /// Area efficiency as published in Table III (GOPS/mm², 28 nm-normalized).
    pub area_eff_gops_mm2: f64,
    /// Optimization coverage: computation only, or compute + memory.
    pub memory_optimized: bool,
    /// Cross-stage coordinated (only STAR).
    pub cross_stage: bool,
}

impl BaselineSpec {
    /// Energy efficiency normalized to 28 nm / 1.0 V, GOPS/W (Table III row).
    pub fn energy_eff_28nm(&self) -> f64 {
        self.energy_eff_gops_w
    }

    /// Area efficiency normalized to 28 nm, GOPS/mm² (Table III row).
    pub fn area_eff_28nm(&self) -> f64 {
        self.area_eff_gops_mm2
    }

    /// Raw GOPS/W from this row's own throughput/power, re-normalized with
    /// the footnote rule — used to sanity-check the published rows.
    pub fn energy_eff_raw_28nm(&self) -> f64 {
        let (gops, watts) = normalize_to_28nm(self.throughput_gops, self.power_w, self.tech_nm, 1.0);
        gops / watts
    }
}

/// Table III rows (published numbers; STAR's row is what our simulator is
/// calibrated against).
pub fn table3_specs() -> Vec<BaselineSpec> {
    vec![
        BaselineSpec {
            name: "FACT",
            tech_nm: 28.0,
            freq_hz: 500e6,
            area_mm2: 6.03,
            power_w: 0.22,
            throughput_gops: 928.0,
            energy_eff_gops_w: 2754.0,
            area_eff_gops_mm2: 154.0,
            memory_optimized: false,
            cross_stage: false,
        },
        BaselineSpec {
            name: "Energon",
            tech_nm: 45.0,
            freq_hz: 1e9,
            area_mm2: 4.20,
            power_w: 2.72,
            throughput_gops: 1153.0,
            energy_eff_gops_w: 450.0,
            area_eff_gops_mm2: 709.0,
            memory_optimized: false,
            cross_stage: false,
        },
        BaselineSpec {
            name: "ELSA",
            tech_nm: 40.0,
            freq_hz: 1e9,
            area_mm2: 1.26,
            power_w: 1.5,
            throughput_gops: 1090.0,
            energy_eff_gops_w: 1004.0,
            area_eff_gops_mm2: 1765.0,
            memory_optimized: false,
            cross_stage: false,
        },
        BaselineSpec {
            name: "STAR",
            tech_nm: 28.0,
            freq_hz: 1e9,
            area_mm2: 5.69,
            power_w: 3.45,
            throughput_gops: 24423.0,
            energy_eff_gops_w: 7183.0,
            area_eff_gops_mm2: 4292.0,
            memory_optimized: true,
            cross_stage: true,
        },
    ]
}

/// Which accelerator a behavioral model mimics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    /// FACT (ISCA'23): symmetric leading-zero (SLZS) eager prediction,
    /// vanilla top-k, stage-serial execution.
    Fact,
    /// Energon (TCAD'22): multi-round low-bit filtering, stage-serial.
    Energon,
    /// ELSA (ISCA'21): hashing-based approximation ≈ low-bit prediction +
    /// per-row thresholding, stage-serial.
    Elsa,
    /// SpAtten (HPCA'21): cascade token/head pruning; coarse top-k with
    /// progressive KV reduction, stage-serial.
    Spatten,
    /// Simba-style NVDLA core: dense SIMD MACs, no sparsity machinery.
    Simba,
    /// The full STAR core.
    Star,
}

impl Baseline {
    pub fn name(self) -> &'static str {
        match self {
            Baseline::Fact => "FACT",
            Baseline::Energon => "Energon",
            Baseline::Elsa => "ELSA",
            Baseline::Spatten => "SpAtten",
            Baseline::Simba => "Simba",
            Baseline::Star => "STAR",
        }
    }

    /// Map the baseline onto the simulator's feature axes.
    pub fn features(self) -> FeatureSet {
        match self {
            Baseline::Star => FeatureSet::star(),
            Baseline::Simba => FeatureSet::dense_asic(),
            Baseline::Fact => FeatureSet {
                predict: PredictKind::Slzs,
                topk: TopkKind::Threshold,
                formal: FormalKind::Dense,
                on_demand_kv: false,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: false,
            },
            Baseline::Energon => FeatureSet {
                // Multi-round filter ≈ two low-bit prediction passes; we
                // model one pass here and account the second in `config`
                // by halving prediction lanes.
                predict: PredictKind::LowBitMul,
                topk: TopkKind::Threshold,
                formal: FormalKind::Dense,
                on_demand_kv: false,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: false,
            },
            Baseline::Elsa => FeatureSet {
                predict: PredictKind::LowBitMul,
                topk: TopkKind::Threshold,
                formal: FormalKind::Dense,
                on_demand_kv: false,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: false,
            },
            Baseline::Spatten => FeatureSet {
                predict: PredictKind::LowBitMul,
                topk: TopkKind::Threshold,
                formal: FormalKind::Dense,
                // SpAtten's cascade pruning progressively shrinks KV, which
                // we approximate as on-demand generation.
                on_demand_kv: true,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: false,
            },
        }
    }

    /// An [`AccelConfig`] scaled to the baseline's published datapath.
    pub fn config(self) -> AccelConfig {
        let d = AccelConfig::default();
        match self {
            Baseline::Star => d,
            Baseline::Simba => AccelConfig {
                // Simba PE cluster: dense MACs only, no prediction units.
                pe_macs_per_cycle: 4096,
                dlzs_lanes: 1,
                sads_lanes: 1,
                sufa_exp_units: 32,
                sram_bytes: 512 * 1024,
                ..d
            },
            Baseline::Fact => AccelConfig {
                freq_hz: 500e6,
                pe_macs_per_cycle: 4096,
                dlzs_lanes: 1024,
                sads_lanes: 256,
                sufa_exp_units: 32,
                sram_bytes: 192 * 1024,
                ..d
            },
            Baseline::Energon => AccelConfig {
                tech_nm: 45.0,
                pe_macs_per_cycle: 2048,
                dlzs_lanes: 512, // halved: pays two filter rounds
                sads_lanes: 256,
                sufa_exp_units: 32,
                sram_bytes: 128 * 1024,
                ..d
            },
            Baseline::Elsa => AccelConfig {
                tech_nm: 40.0,
                pe_macs_per_cycle: 1024,
                dlzs_lanes: 1024,
                sads_lanes: 256,
                sufa_exp_units: 16,
                sram_bytes: 96 * 1024,
                ..d
            },
            Baseline::Spatten => AccelConfig {
                pe_macs_per_cycle: 4096,
                dlzs_lanes: 512,
                sads_lanes: 512,
                sufa_exp_units: 32,
                // SpAtten's published design carries ~384 kB of SRAM.
                sram_bytes: 384 * 1024,
                ..d
            },
        }
    }

    /// Baselines compared in the spatial lateral study (Fig. 24(c)(d)).
    pub fn spatial_suite() -> [Baseline; 3] {
        [Baseline::Simba, Baseline::Spatten, Baseline::Star]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::dram::DramChannel;
    use crate::sim::pipeline::{simulate, WorkloadShape};

    #[test]
    fn table3_normalized_ratios_match_paper() {
        // Paper: STAR is 2.6× / 15.9× / 7.2× more energy-efficient than
        // FACT / Energon / ELSA after tech normalization, and 27.1× /
        // 6.1× / 2.4× more area-efficient.
        let specs = table3_specs();
        let star = specs.iter().find(|s| s.name == "STAR").unwrap();
        let fact = specs.iter().find(|s| s.name == "FACT").unwrap();
        let energon = specs.iter().find(|s| s.name == "Energon").unwrap();
        let elsa = specs.iter().find(|s| s.name == "ELSA").unwrap();

        let e_ratio = |b: &BaselineSpec| star.energy_eff_28nm() / b.energy_eff_28nm();
        assert!((e_ratio(fact) - 2.6).abs() < 0.3, "FACT energy ratio {}", e_ratio(fact));
        assert!((e_ratio(energon) - 15.9).abs() < 2.5, "Energon energy ratio {}", e_ratio(energon));
        assert!((e_ratio(elsa) - 7.2).abs() < 1.5, "ELSA energy ratio {}", e_ratio(elsa));

        let a_ratio = |b: &BaselineSpec| star.area_eff_28nm() / b.area_eff_28nm();
        assert!((a_ratio(fact) - 27.1).abs() < 3.0, "FACT area ratio {}", a_ratio(fact));
        assert!((a_ratio(energon) - 6.1).abs() < 2.0, "Energon area ratio {}", a_ratio(energon));
        assert!((a_ratio(elsa) - 2.4).abs() < 1.0, "ELSA area ratio {}", a_ratio(elsa));
    }

    #[test]
    fn star_outruns_every_behavioral_baseline() {
        let shape = WorkloadShape::new(128, 2048, 64, 768, 0.2);
        let dram = DramChannel::accel_256();
        let star = simulate(&shape, &FeatureSet::star(), &Baseline::Star.config(), &dram);
        for b in [Baseline::Fact, Baseline::Energon, Baseline::Elsa, Baseline::Spatten, Baseline::Simba] {
            let r = simulate(&shape, &b.features(), &b.config(), &dram);
            assert!(
                star.total_s < r.total_s,
                "STAR {} !< {} {}",
                star.total_s,
                b.name(),
                r.total_s
            );
        }
    }

    #[test]
    fn serial_baselines_get_memory_bound_at_high_tp() {
        // Fig. 3: FACT/Energon MAT fraction grows with token parallelism
        // and averages ~72% at high TP.
        let dram = DramChannel::ddr4();
        for b in [Baseline::Fact, Baseline::Energon] {
            let lo = simulate(
                &WorkloadShape::new(64, 2048, 64, 768, 0.25),
                &b.features(),
                &b.config(),
                &dram,
            );
            let hi = simulate(
                &WorkloadShape::new(512, 2048, 64, 768, 0.25),
                &b.features(),
                &b.config(),
                &dram,
            );
            // The Â-spill component of MAT grows with TP for both; the
            // *total* MAT share stays dominant for Energon (1 GHz) but is
            // partially hidden behind FACT's 500 MHz compute in our
            // overlap model (EXPERIMENTS.md §Fig3 discusses this).
            assert!(
                hi.predict.mem_s > lo.predict.mem_s,
                "{}: Â spill traffic should grow with TP",
                b.name()
            );
            if b == Baseline::Energon {
                assert!(hi.mat_fraction() > 0.5, "{} MAT {}", b.name(), hi.mat_fraction());
            }
        }
    }

    #[test]
    fn names_and_suite() {
        assert_eq!(Baseline::Spatten.name(), "SpAtten");
        assert_eq!(Baseline::spatial_suite().len(), 3);
    }
}
