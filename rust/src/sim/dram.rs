//! Off-chip DRAM channel model: bandwidth, latency, energy, and simple
//! contention (the Ramulator substitution — DESIGN.md §2).
//!
//! The model is transaction-level: a transfer of B bytes on a channel with
//! bandwidth `bw` and access latency `lat` takes `lat + B/bw` seconds and
//! costs `8·B·pJ_bit` picojoules. Contention from `sharers` cores divides
//! the bandwidth (the Fig. 23(b) setting: 512 GB/s shared by 25 cores →
//! 20.5 GB/s effective).

/// A DRAM channel.
#[derive(Clone, Copy, Debug)]
pub struct DramChannel {
    /// Peak bandwidth, bytes/s.
    pub bw: f64,
    /// First-word access latency, seconds.
    pub latency: f64,
    /// Energy per bit moved, picojoules.
    pub pj_per_bit: f64,
}

impl DramChannel {
    /// HBM2-class channel (Table IV: 512 GB/s, 100 ns, 6 pJ/bit).
    pub fn hbm2() -> DramChannel {
        DramChannel { bw: 512e9, latency: 100e-9, pj_per_bit: 6.0 }
    }

    /// DDR4-class channel (Sec. III-A(2): 25.6 GB/s, ~15 pJ/bit).
    pub fn ddr4() -> DramChannel {
        DramChannel { bw: 25.6e9, latency: 60e-9, pj_per_bit: 15.0 }
    }

    /// Single-core accelerator channel (Fig. 23(a): 256 GB/s).
    pub fn accel_256() -> DramChannel {
        DramChannel { bw: 256e9, latency: 100e-9, pj_per_bit: 6.0 }
    }

    /// Effective channel when shared equally by `sharers` cores.
    pub fn shared_by(&self, sharers: usize) -> DramChannel {
        DramChannel { bw: self.bw / sharers.max(1) as f64, ..*self }
    }

    /// Time to move `bytes` in one streaming transaction.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency + bytes as f64 / self.bw
        }
    }

    /// Time for `bytes` split into `bursts` dependent transactions (e.g.
    /// per-tile fetches that cannot be coalesced).
    pub fn burst_time(&self, bytes: u64, bursts: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            self.latency * bursts.max(1) as f64 + bytes as f64 / self.bw
        }
    }

    /// Energy in joules for `bytes` moved.
    pub fn energy_j(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.pj_per_bit * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_includes_latency() {
        let ch = DramChannel::hbm2();
        let t = ch.transfer_time(512_000_000_000);
        assert!((t - (100e-9 + 1.0)).abs() < 1e-6);
        assert_eq!(ch.transfer_time(0), 0.0);
    }

    #[test]
    fn sharing_divides_bandwidth() {
        let ch = DramChannel::hbm2().shared_by(25);
        assert!((ch.bw - 20.48e9).abs() < 1e6); // the paper's 20.5 GB/s
    }

    #[test]
    fn bursts_pay_latency_repeatedly() {
        let ch = DramChannel::hbm2();
        let coalesced = ch.transfer_time(1 << 20);
        let bursty = ch.burst_time(1 << 20, 1024);
        assert!(bursty > coalesced);
        assert!((bursty - coalesced - 1023.0 * 100e-9).abs() < 1e-9);
    }

    #[test]
    fn ddr_two_orders_below_sram_bw() {
        // Sec. III-A(2): off-chip ~two orders of magnitude below on-chip.
        let sram_bw = crate::sim::sram::Sram::new(1).bw;
        assert!(sram_bw / DramChannel::ddr4().bw > 100.0);
    }

    #[test]
    fn energy_linear_in_bytes() {
        let ch = DramChannel::hbm2();
        assert!((ch.energy_j(1000) - 1000.0 * 8.0 * 6.0 * 1e-12).abs() < 1e-18);
    }
}
