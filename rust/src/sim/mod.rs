//! Cycle-level and energy/area modeling of the STAR accelerator and its
//! comparison points.
//!
//! The paper evaluates RTL (Synopsys DC, TSMC 28 nm) + CACTI + Ramulator +
//! a cycle-level simulator; none of that toolchain exists here, so this
//! module is the substitution (DESIGN.md §2): analytic per-unit cycle
//! models anchored on the paper's own reported throughputs, a pJ/op energy
//! model with the paper's tech-scaling rule, and a bandwidth/latency memory
//! system. Absolute numbers are *models*; the benches compare shapes and
//! ratios, which is what the substitution preserves.
//!
//! * [`energy`] — pJ/op tables at 28 nm + tech/voltage scaling (Table III
//!   footnote), SRAM/DRAM per-bit energies.
//! * [`area`]   — per-unit area model and the Fig. 21 breakdown.
//! * [`sram`], [`dram`] — the memory system.
//! * [`units`]  — cycle models for the six STAR units (Fig. 12).
//! * [`pipeline`] — the single-core simulator: stage-serial vs cross-stage
//!   tiled execution, feature flags for every ablation of Fig. 20/22/23.
//! * [`gpu`]    — the A100 roofline comparison model.
//! * [`baselines`] — FACT / Energon / ELSA / SpAtten / Simba models.

pub mod area;
pub mod baselines;
pub mod dram;
pub mod energy;
pub mod gpu;
pub mod pipeline;
pub mod sram;
pub mod units;

pub use energy::{EnergyBreakdown, EnergyModel};
pub use pipeline::{simulate, FeatureSet, FormalKind, PredictKind, SimReport, TopkKind, WorkloadShape};
