//! A100 GPU comparison model (the paper's Fig. 19 / Fig. 20 / Fig. 22
//! baseline).
//!
//! The paper measures TensorRT-LLM on a real A100; we cannot. The
//! substitution (DESIGN.md §2) is a calibrated throughput model anchored
//! on the paper's *own* measurements rather than on datasheet rooflines:
//!
//! * Table III implies the A100 sustains ≈ 24423/9.2 ≈ 2.7 effective
//!   TOPS on the paper's LTPP attention jobs (≈ 1% of FP16 peak — the
//!   mix of tall-skinny GEMMs, softmax, INT16-equivalent precision and
//!   framework overhead keeps tensor cores mostly idle).
//! * Fig. 20 implies the dense 16-TOPS-class ASIC datapath beats the
//!   dense GPU by 1.5×, consistent with the same effective utilization.
//! * `nvidia-smi`-measured *dynamic* power (total − idle) on these jobs
//!   is a small fraction of the 400 W board power (Fig. 22(b) implies
//!   ≈ 25–30 W).
//! * Naive LP (sparsity prediction) on the GPU yields only 1.08×–1.78×
//!   because SIMT warps cannot exploit token-granular sparsity.

use super::pipeline::WorkloadShape;

/// GPU device model: peak compute, memory bandwidth, power, and the
/// calibrated effective utilization.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// Peak dense FP16 tensor-core throughput, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Board power, watts.
    pub power_w: f64,
    /// Dynamic (idle-subtracted) power fraction on attention jobs.
    pub dynamic_frac: f64,
    /// Sustained fraction of peak on the paper's LTPP attention jobs
    /// (attention + on-the-fly KV projection, INT16-equivalent).
    pub eff_util: f64,
    /// Fraction of nominally-skippable work a SIMT datapath actually
    /// skips under an irregular token-level sparsity mask.
    pub sparse_skip_eff: f64,
    /// LP prediction-stage overhead as a fraction of the dense job.
    pub lp_overhead: f64,
}

impl GpuModel {
    /// NVIDIA A100-80GB SXM: 312 TFLOPS FP16 TC, 2.04 TB/s HBM2e, 400 W,
    /// with utilization calibrated to the paper's measurements.
    pub fn a100() -> GpuModel {
        GpuModel {
            peak_flops: 312e12,
            hbm_bw: 2.04e12,
            power_w: 400.0,
            dynamic_frac: 0.065,
            eff_util: 0.010,
            sparse_skip_eff: 0.50,
            lp_overhead: 0.12,
        }
    }

    /// Dense-equivalent FLOPs of the whole job: attention (QKᵀ + PV) plus
    /// the on-demand K/V projections — the same accounting the
    /// accelerator simulator uses.
    pub fn job_flops(shape: &WorkloadShape) -> f64 {
        4.0 * shape.t as f64 * shape.s as f64 * shape.d as f64
            + 4.0 * shape.s as f64 * shape.h as f64 * shape.d as f64
    }

    /// HBM bytes for one FP16 job (X, Q in; O out; KV transient).
    pub fn job_bytes(shape: &WorkloadShape) -> f64 {
        let e = 2.0;
        (shape.s * shape.h) as f64
            + ((shape.t + 2 * shape.s) * shape.d + shape.t * shape.d) as f64 * e
    }

    /// Execution time of the dense job.
    pub fn dense_job_time(&self, shape: &WorkloadShape) -> f64 {
        let tc = Self::job_flops(shape) / (self.peak_flops * self.eff_util);
        let tm = Self::job_bytes(shape) / self.hbm_bw;
        tc.max(tm)
    }

    /// Execution time with the LP (sparsity-prediction) mechanism ported
    /// naively onto the GPU: the prediction pass is pure overhead, and
    /// only `sparse_skip_eff` of the pruned work is actually saved.
    pub fn lp_job_time(&self, shape: &WorkloadShape) -> f64 {
        let dense = self.dense_job_time(shape);
        let predict = self.lp_overhead * dense;
        let saved = (1.0 - shape.keep_ratio) * self.sparse_skip_eff;
        predict + dense * (1.0 - saved)
    }

    /// Speedup of LP-on-GPU over dense-on-GPU; the paper measures
    /// 1.08×–1.78× for this quantity.
    pub fn lp_gain(&self, shape: &WorkloadShape) -> f64 {
        self.dense_job_time(shape) / self.lp_job_time(shape)
    }

    /// Dynamic energy of a job (idle-subtracted, per the paper's
    /// `nvidia-smi` methodology).
    pub fn energy_j(&self, time_s: f64) -> f64 {
        self.dynamic_frac * self.power_w * time_s
    }

    /// Dynamic power, watts.
    pub fn dynamic_w(&self) -> f64 {
        self.dynamic_frac * self.power_w
    }

    /// Effective throughput in GOPS on the dense-equivalent accounting.
    pub fn eff_gops(&self, shape: &WorkloadShape, time_s: f64) -> f64 {
        Self::job_flops(shape) / time_s / 1e9
    }

    /// Energy efficiency in GOPS/W on a dense job.
    pub fn dense_gops_per_w(&self, shape: &WorkloadShape) -> f64 {
        let t = self.dense_job_time(shape);
        self.eff_gops(shape, t) / self.dynamic_w()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> WorkloadShape {
        WorkloadShape::new(128, 4096, 128, 4096, 0.2)
    }

    #[test]
    fn lp_gain_in_paper_band() {
        // Fig. 19: naive LP on the A100 yields 1.08×–1.78×.
        let gpu = GpuModel::a100();
        for s in [1024usize, 2048, 4096, 8192] {
            for k in [0.15, 0.2, 0.25] {
                let shape = WorkloadShape::new(128, s, 128, 4096, k);
                let g = gpu.lp_gain(&shape);
                assert!((1.05..1.9).contains(&g), "gain {g} at S={s} k={k}");
            }
        }
    }

    #[test]
    fn effective_throughput_matches_table3_implication() {
        // Table III: STAR 24423 GOPS at up to 9.2× over the GPU ⇒ the GPU
        // sustains ~2–4 effective TOPS on these jobs.
        let gpu = GpuModel::a100();
        let t = gpu.dense_job_time(&shape());
        let gops = gpu.eff_gops(&shape(), t);
        assert!((1500.0..5000.0).contains(&gops), "GPU effective GOPS {gops}");
    }

    #[test]
    fn dynamic_power_in_measured_band() {
        // Fig. 22(b) implies ~25–30 W idle-subtracted on attention jobs.
        let gpu = GpuModel::a100();
        assert!((20.0..40.0).contains(&gpu.dynamic_w()), "{}", gpu.dynamic_w());
    }

    #[test]
    fn energy_scales_with_time() {
        let gpu = GpuModel::a100();
        assert!((gpu.energy_j(2.0) - 2.0 * gpu.energy_j(1.0)).abs() < 1e-9);
    }

    #[test]
    fn gpu_efficiency_two_orders_below_star() {
        // Fig. 22(b): STAR reaches 49.8×–71.2× the GPU's GOPS/W; the GPU
        // lands around 7183 / 71 ≈ 100 GOPS/W.
        let gpu = GpuModel::a100();
        let eff = gpu.dense_gops_per_w(&shape());
        assert!((50.0..250.0).contains(&eff), "GPU GOPS/W {eff}");
    }
}
