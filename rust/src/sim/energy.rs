//! Energy model: per-operation energies at TSMC 28 nm / 1.0 V plus the
//! paper's tech-scaling normalization (Table III footnote: `f ∝ s`,
//! `P_core ∝ (1/s)(1.0/Vdd)²` with `s = Tech/28 nm`).
//!
//! Per-op values are standard 28 nm datapath numbers (Horowitz ISSCC'14
//! style), chosen so that the relative costs match the paper's accounting:
//! an exponentiation is ~an order of magnitude above a multiply, DRAM is
//! orders of magnitude above SRAM (Sec. III-A(2): DRAM 5–20 pJ/bit vs SRAM
//! 0.1 pJ/bit).

use crate::arith::OpCounter;

/// Per-operation dynamic energies in picojoules (28 nm, 1.0 V).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// INT8-class add (the prediction datapath accumulator).
    pub add_pj: f64,
    /// INT16/FP16-class multiply (formal-compute MAC).
    pub mul_pj: f64,
    pub cmp_pj: f64,
    pub div_pj: f64,
    /// Exponential unit evaluation (LUT + interpolation pipeline).
    pub exp_pj: f64,
    /// Barrel shift (DLZS "multiply").
    pub shift_pj: f64,
    /// Leading-zero priority encode.
    pub lz_encode_pj: f64,
    /// On-chip SRAM access energy per bit.
    pub sram_pj_per_bit: f64,
    /// Off-chip DRAM access energy per bit.
    pub dram_pj_per_bit: f64,
    /// PSP saving: fraction of sign-induced bit-flip energy avoided per
    /// shift (Fig. 8a right); folded into `shift_pj` when enabled.
    pub psp_saving: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            add_pj: 0.03,
            mul_pj: 0.8,
            cmp_pj: 0.03,
            div_pj: 3.0,
            exp_pj: 6.0,
            shift_pj: 0.05,
            lz_encode_pj: 0.04,
            sram_pj_per_bit: 0.1,
            dram_pj_per_bit: 6.0, // HBM2-class (Table IV); DDR4 would be ~15
            psp_saving: 0.3,
        }
    }
}

impl EnergyModel {
    /// DDR4-class off-chip memory (the Sec. III-A(2) example).
    pub fn with_ddr4(self) -> Self {
        EnergyModel { dram_pj_per_bit: 15.0, ..self }
    }

    /// Scale this 28 nm model to another technology node, following the
    /// paper's normalization: energy/op ∝ s·Vdd² relative to 28 nm/1.0 V
    /// (power ∝ (1/s)Vdd⁻² with f ∝ s ⇒ energy ∝ ...; we apply the same
    /// rule the paper uses to normalize *to* 28 nm, inverted).
    pub fn scaled_to(&self, tech_nm: f64, vdd: f64) -> EnergyModel {
        let s = tech_nm / 28.0;
        let f = s * vdd * vdd;
        EnergyModel {
            add_pj: self.add_pj * f,
            mul_pj: self.mul_pj * f,
            cmp_pj: self.cmp_pj * f,
            div_pj: self.div_pj * f,
            exp_pj: self.exp_pj * f,
            shift_pj: self.shift_pj * f,
            lz_encode_pj: self.lz_encode_pj * f,
            sram_pj_per_bit: self.sram_pj_per_bit * f,
            dram_pj_per_bit: self.dram_pj_per_bit, // IO energy does not scale with core tech
            psp_saving: self.psp_saving,
        }
    }

    /// Dynamic energy (picojoules) of a counted op mix, `psp` controlling
    /// whether shifts enjoy the pre-flip saving.
    pub fn of_ops(&self, c: &OpCounter, psp: bool) -> f64 {
        let shift_pj = if psp { self.shift_pj * (1.0 - self.psp_saving) } else { self.shift_pj };
        c.add as f64 * self.add_pj
            + c.mul as f64 * self.mul_pj
            + c.cmp as f64 * self.cmp_pj
            + c.div as f64 * self.div_pj
            + c.exp as f64 * self.exp_pj
            + c.shift as f64 * shift_pj
            + c.lz_encode as f64 * self.lz_encode_pj
            + c.sram_bytes as f64 * 8.0 * self.sram_pj_per_bit
            + c.dram_bytes as f64 * 8.0 * self.dram_pj_per_bit
    }
}

/// Energy totals per category, in joules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub compute_j: f64,
    pub sram_j: f64,
    pub dram_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.sram_j + self.dram_j
    }
}

/// The paper's Table III normalization: scale a (throughput, power) pair
/// reported at `tech_nm`/`vdd` to 28 nm / 1.0 V. Returns (gops, watts).
pub fn normalize_to_28nm(gops: f64, watts: f64, tech_nm: f64, vdd: f64) -> (f64, f64) {
    let s = tech_nm / 28.0;
    // f ∝ s: a 45 nm design at 1 GHz runs s× faster at 28 nm.
    let gops_n = gops * s;
    // P_core ∝ (1/s)(1.0/Vdd)².
    let watts_n = watts * (1.0 / s) * (1.0 / vdd).powi(2);
    (gops_n, watts_n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::OpKind;

    #[test]
    fn dram_orders_of_magnitude_above_sram() {
        let m = EnergyModel::default();
        assert!(m.dram_pj_per_bit / m.sram_pj_per_bit >= 50.0);
    }

    #[test]
    fn exp_much_costlier_than_mul() {
        let m = EnergyModel::default();
        assert!(m.exp_pj / m.mul_pj >= 5.0);
        assert!(m.mul_pj / m.shift_pj >= 10.0, "shifts must be far cheaper than multiplies");
    }

    #[test]
    fn psp_reduces_shift_energy() {
        let m = EnergyModel::default();
        let mut c = OpCounter::new();
        c.tally(OpKind::Shift, 1000);
        assert!(m.of_ops(&c, true) < m.of_ops(&c, false));
    }

    #[test]
    fn of_ops_counts_memory() {
        let m = EnergyModel::default();
        let mut c = OpCounter::new();
        c.dram(1); // one byte
        let e = m.of_ops(&c, false);
        assert!((e - 8.0 * m.dram_pj_per_bit).abs() < 1e-12);
    }

    #[test]
    fn tech_scaling_45_to_28() {
        // Energon: 45 nm, 1153 GOPS, 2.72 W. Normalized to 28 nm it must
        // get faster and (per the paper's rule) lower-power per op.
        let (g, w) = normalize_to_28nm(1153.0, 2.72, 45.0, 1.0);
        assert!(g > 1153.0);
        assert!(w < 2.72);
        // Efficiency 450 GOPS/W → paper's normalized comparison keeps
        // STAR 15.9× ahead; just sanity-check the direction & magnitude.
        let eff = g / w;
        assert!((eff / (1153.0 / 2.72) - (45.0f64 / 28.0).powi(2)).abs() < 1.0);
    }

    #[test]
    fn scaled_model_roundtrip_identity() {
        let m = EnergyModel::default();
        let same = m.scaled_to(28.0, 1.0);
        assert!((same.mul_pj - m.mul_pj).abs() < 1e-12);
        let m45 = m.scaled_to(45.0, 1.0);
        assert!(m45.mul_pj > m.mul_pj);
    }
}
