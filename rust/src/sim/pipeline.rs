//! The single-core cycle-level simulator: composes the unit models of
//! [`super::units`] with the memory system under either the baselines'
//! stage-serial execution or STAR's cross-stage tiled pipeline.
//!
//! Every ablation the paper's architecture evaluation runs maps to a
//! [`FeatureSet`]:
//!
//! | Paper configuration | FeatureSet |
//! |---|---|
//! | dense ASIC (Fig. 20 start) | `FeatureSet::dense_asic()` |
//! | + LP (naive) | `predict = LowBitMul, topk = Vanilla` |
//! | + DLZS/SADS engines | `predict = DlzsCross, topk = Sads` |
//! | + SU-FA (no tailored engine) | `formal = SufaDescend, sufa_tailored = false` |
//! | + tailored SU-FA engine | `sufa_tailored = true` |
//! | + RASS + tiled dataflow | `tiled_dataflow = true, oo_scheduler = true` |
//! | full STAR | `FeatureSet::star()` |

use super::dram::DramChannel;
use super::energy::{EnergyBreakdown, EnergyModel};
use super::sram::{Sram, WorkingSets};
use super::units::{SoftmaxKind, StageWork, Units};
use crate::arith::OpCounter;
use crate::config::AccelConfig;

/// Prediction-stage scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictKind {
    /// Cross-phase DLZS (STAR).
    DlzsCross,
    /// Symmetric LZ on both operands (FACT-style).
    Slzs,
    /// Low-bit multiply (4-bit MSB) prediction.
    LowBitMul,
    /// No prediction (dense execution).
    None,
}

/// Top-k engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopkKind {
    Sads,
    /// Full per-row selection, O(S·S·k) (the algorithmic DS baseline).
    Vanilla,
    /// Multi-round threshold filtering (Energon/ELSA-class engines).
    Threshold,
    /// No top-k (dense execution).
    None,
}

/// Formal-compute softmax scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FormalKind {
    SufaDescend,
    SufaAscend,
    Flash2,
    Dense,
}

/// Architecture feature flags (the ablation axes).
#[derive(Clone, Copy, Debug)]
pub struct FeatureSet {
    pub predict: PredictKind,
    pub topk: TopkKind,
    pub formal: FormalKind,
    /// Generate only the KV rows some query selected.
    pub on_demand_kv: bool,
    /// Cross-stage tiled dataflow: intermediates never spill to DRAM.
    pub tiled_dataflow: bool,
    /// Tiled out-of-order scheduler (RASS): hides stage-boundary bubbles.
    pub oo_scheduler: bool,
    /// Tailored SU-FA engine: absorbs max-misprediction stalls.
    pub sufa_tailored: bool,
}

impl FeatureSet {
    /// Full STAR configuration.
    pub fn star() -> FeatureSet {
        FeatureSet {
            predict: PredictKind::DlzsCross,
            topk: TopkKind::Sads,
            formal: FormalKind::SufaDescend,
            on_demand_kv: true,
            tiled_dataflow: true,
            oo_scheduler: true,
            sufa_tailored: true,
        }
    }

    /// Dense ASIC datapath (no sparsity machinery at all).
    pub fn dense_asic() -> FeatureSet {
        FeatureSet {
            predict: PredictKind::None,
            topk: TopkKind::None,
            formal: FormalKind::Dense,
            on_demand_kv: false,
            tiled_dataflow: false,
            oo_scheduler: false,
            sufa_tailored: false,
        }
    }

    /// Generic DS accelerator baseline (Fig. 18a "baseline"): 4-bit-mul
    /// prediction, vanilla sorting, traditional FA, stage-serial.
    pub fn ds_baseline() -> FeatureSet {
        FeatureSet {
            predict: PredictKind::LowBitMul,
            topk: TopkKind::Vanilla,
            formal: FormalKind::Flash2,
            on_demand_kv: false,
            tiled_dataflow: false,
            oo_scheduler: false,
            sufa_tailored: false,
        }
    }
}

/// Workload shape handed to the simulator.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadShape {
    pub t: usize,
    pub s: usize,
    pub d: usize,
    pub h: usize,
    /// Top-k keep ratio (1.0 under dense execution).
    pub keep_ratio: f64,
    /// Override for the KV-union ratio (generated rows / S). `None`
    /// keeps the [`StageWork::new`] heuristic; measured reconciliation
    /// (`star bench traffic`) injects the *observed* ratio so the model
    /// predicts the exact union the execution produced. Deliberately
    /// un-clamped: per-tile regeneration makes Σunion exceed S.
    pub union_ratio: Option<f64>,
}

impl WorkloadShape {
    pub fn new(t: usize, s: usize, d: usize, h: usize, keep_ratio: f64) -> WorkloadShape {
        WorkloadShape { t, s, d, h, keep_ratio, union_ratio: None }
    }

    /// Pin the KV-union ratio instead of the heuristic (see field docs).
    pub fn with_union_ratio(mut self, r: f64) -> WorkloadShape {
        self.union_ratio = Some(r);
        self
    }

    fn stage_work(&self, feats: &FeatureSet) -> StageWork {
        let k = match feats.topk {
            TopkKind::None => 1.0,
            _ => self.keep_ratio,
        };
        let mut w = StageWork::new(self.t, self.s, self.d, self.h, k);
        if let Some(r) = self.union_ratio {
            w.union_ratio = r;
        }
        w
    }

    /// Dense-equivalent useful ops of the whole job (the accounting
    /// sparse accelerators report effective GOPS against): QKᵀ + PV plus
    /// the K/V projections the job performs (mul+add each).
    pub fn dense_equivalent_ops(&self) -> f64 {
        4.0 * self.t as f64 * self.s as f64 * self.d as f64
            + 4.0 * self.s as f64 * self.h as f64 * self.d as f64
    }
}

/// Per-stage timing entry.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTime {
    pub compute_s: f64,
    pub mem_s: f64,
    /// DRAM bytes this stage's memory stream moves (spills included) —
    /// the modeled side of the measured-vs-modeled reconciliation.
    pub dram_bytes: u64,
}

impl StageTime {
    /// Stage wall time: compute and its memory stream overlap.
    pub fn wall(&self) -> f64 {
        self.compute_s.max(self.mem_s)
    }
}

/// Simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub predict: StageTime,
    pub topk: StageTime,
    pub kv_gen: StageTime,
    pub formal: StageTime,
    /// End-to-end latency, seconds.
    pub total_s: f64,
    /// Memory-access time exposed on the critical path (the Fig. 3 MAT).
    pub mat_s: f64,
    pub energy: EnergyBreakdown,
    pub ops: OpCounter,
    pub dram_bytes: u64,
    /// Dense-equivalent throughput in GOPS.
    pub eff_gops: f64,
    /// SU-FA stall cycles (0 with the tailored engine).
    pub stall_cycles: u64,
    /// Modeled resident KV bytes (generated/loaded rows × 2d × element
    /// width) — what a decode cache append materializes for this shape.
    pub kv_resident_bytes: u64,
}

impl SimReport {
    pub fn energy_eff_gops_w(&self) -> f64 {
        let w = self.energy.total_j() / self.total_s;
        self.eff_gops / w
    }

    /// Fraction of total latency that is exposed memory-access time.
    pub fn mat_fraction(&self) -> f64 {
        self.mat_s / self.total_s
    }
}

/// Simulate one attention job on an accelerator.
pub fn simulate(
    shape: &WorkloadShape,
    feats: &FeatureSet,
    cfg: &AccelConfig,
    dram: &DramChannel,
) -> SimReport {
    let units = Units::from_config(cfg);
    let em = EnergyModel::default().scaled_to(cfg.tech_nm, 1.0);
    let sram = Sram::new(cfg.sram_bytes);
    let w = shape.stage_work(feats);
    let cyc = |n: u64| n as f64 / cfg.freq_hz;
    let f = 2u64; // INT16 element bytes

    let mut ops = OpCounter::new();
    let mut dram_bytes: u64 = 0;
    let mut compute_e = 0.0; // pJ
    let mut stall_cycles = 0u64;

    // ---------------- Prediction stage ----------------
    let (p_cycles, p_ops, psp) = match feats.predict {
        PredictKind::DlzsCross => {
            let (cy, o) = units.dlzs.cross_phase(&w);
            (cy, o, true)
        }
        PredictKind::Slzs => {
            let (cy, o) = units.dlzs.slzs_attention(&w);
            (cy, o, false)
        }
        PredictKind::LowBitMul => {
            let (cy, o) = units.lowbit.attention(&w);
            (cy, o, false)
        }
        PredictKind::None => (0, OpCounter::new(), false),
    };
    compute_e += em.of_ops(&p_ops, psp);
    ops.merge(&p_ops);

    // Prediction inputs from DRAM. Cross-phase DLZS predicts straight
    // from X (int8) + the pre-converted LZ(W_k); SLZS/low-bit schemes
    // predict against the generated K instead (Q + K loads, no X here —
    // X is charged to their KV-generation stage).
    let mut p_dram = (w.t * w.d) as u64;
    match feats.predict {
        PredictKind::DlzsCross => p_dram += (w.s * w.h) as u64,
        PredictKind::Slzs | PredictKind::LowBitMul => p_dram += (w.s * w.d) as u64,
        PredictKind::None => p_dram = 0,
    }
    // Stage-serial executions spill the estimated Â when it overflows SRAM.
    let ws = WorkingSets { t: w.t, s: w.s, d: w.d, ew: f as usize };
    let mut p_spill = 0u64;
    if feats.predict != PredictKind::None && !feats.tiled_dataflow {
        let spill = sram.spill(ws.ahat()) as u64;
        p_spill = 2 * spill; // write out + read back in the top-k stage
    }
    dram_bytes += p_dram + p_spill;
    let predict = StageTime {
        compute_s: cyc(p_cycles),
        mem_s: dram.transfer_time(p_dram + p_spill),
        dram_bytes: p_dram + p_spill,
    };

    // ---------------- Top-k stage ----------------
    let (t_cycles, t_ops) = match feats.topk {
        TopkKind::Sads => units.sads.sads(&w),
        TopkKind::Vanilla => units.sads.vanilla(&w),
        TopkKind::Threshold => units.sads.threshold(&w),
        TopkKind::None => (0, OpCounter::new()),
    };
    compute_e += em.of_ops(&t_ops, false);
    ops.merge(&t_ops);
    let topk = StageTime { compute_s: cyc(t_cycles), mem_s: 0.0, dram_bytes: 0 };

    // ---------------- KV generation / load ----------------
    // STAR (and cascade-pruning designs) generate KV on demand from X.
    // Conventional DS accelerators (FACT/Energon/ELSA) receive KV
    // precomputed by a separate QKV engine and must LOAD it from DRAM —
    // zero PE work here, full K+V traffic (this is exactly the IO the
    // paper's cross-phase mechanism removes).
    let kv_precomputed = !feats.on_demand_kv && feats.predict != PredictKind::None;
    let gen_rows;
    let (g_cycles, mut g_dram) = if kv_precomputed {
        gen_rows = w.s as u64;
        // End-to-end accounting: the upstream QKV engine read X (int8)
        // and wrote K+V to DRAM before this accelerator reads them back.
        let kv = gen_rows * (2 * w.d) as u64 * f;
        let upstream = if w.h > 0 { (w.s * w.h) as u64 + kv } else { 0 };
        (0u64, upstream + kv)
    } else {
        let union = if feats.on_demand_kv { w.union_ratio } else { 1.0 };
        let (cycles, g_ops) = units.pe.kv_generation(&w, union);
        compute_e += em.of_ops(&g_ops, false);
        ops.merge(&g_ops);
        gen_rows = (w.s as f64 * union).ceil() as u64;
        // X rows stream from DRAM (int8).
        (cycles, gen_rows * w.h as u64)
    };
    // Generated KV stays on chip under the tiled dataflow, else spills.
    if !kv_precomputed && !feats.tiled_dataflow {
        let kv_bytes = gen_rows * (2 * w.d) as u64 * f;
        let spill = (kv_bytes as usize).saturating_sub(sram.bytes / 2) as u64;
        g_dram += 2 * spill;
    }
    dram_bytes += g_dram;
    let kv_gen =
        StageTime { compute_s: cyc(g_cycles), mem_s: dram.transfer_time(g_dram), dram_bytes: g_dram };

    // ---------------- Formal compute ----------------
    let (mm_cycles, mm_ops) = units.pe.formal_matmuls(&w);
    let kind = match feats.formal {
        FormalKind::SufaDescend => SoftmaxKind::SufaDescend,
        FormalKind::SufaAscend => SoftmaxKind::SufaAscend,
        FormalKind::Flash2 => SoftmaxKind::Flash2,
        FormalKind::Dense => SoftmaxKind::Dense,
    };
    let (sm_cycles, sm_ops) = units.sufa.softmax(&w, kind);
    compute_e += em.of_ops(&mm_ops, false) + em.of_ops(&sm_ops, false);
    ops.merge(&mm_ops);
    ops.merge(&sm_ops);

    // SU-FA without the tailored engine: max-misprediction stalls flush the
    // update pipeline (Fig. 20: "Max value errors often causing circuit
    // stalls" — direct SU-FA gains only 1.3× vs 1.8× tailored).
    let mut f_cycles = mm_cycles.max(sm_cycles);
    if matches!(feats.formal, FormalKind::SufaDescend | FormalKind::SufaAscend)
        && !feats.sufa_tailored
    {
        let tiles = (w.t as u64) * (w.keep as u64).div_ceil(w.bc as u64);
        let stall_rate = 0.15; // per-tile misprediction probability
        let flush = 24u64; // pipeline flush penalty, cycles
        stall_cycles = ((tiles as f64) * stall_rate) as u64 * flush;
        f_cycles += stall_cycles;
    }

    // Formal-stage DRAM: dense softmax without tiling spills the full
    // score matrix; output O always goes out. Without the cross-stage
    // tiled dataflow the formal stage must also read back whatever KV
    // spilled to DRAM during generation (stage-serial designs cannot
    // stream generated KV straight into the formal units).
    let mut f_dram = (w.t * w.d) as u64 * f;
    if feats.formal == FormalKind::Dense && !feats.tiled_dataflow {
        // After top-k pruning only the kept columns are materialized.
        let ws_formal = WorkingSets { t: w.t, s: w.keep, d: w.d, ew: f as usize };
        let spill = sram.spill(ws_formal.dense_scores() + ws_formal.dense_kv()) as u64;
        f_dram += 2 * spill;
    } else if !feats.tiled_dataflow {
        let kv_bytes = gen_rows * (2 * w.d) as u64 * f;
        f_dram += (kv_bytes as usize).saturating_sub(sram.bytes / 2) as u64;
    }
    dram_bytes += f_dram;
    let formal =
        StageTime { compute_s: cyc(f_cycles), mem_s: dram.transfer_time(f_dram), dram_bytes: f_dram };

    // ---------------- Composition ----------------
    let stages = [&predict, &topk, &kv_gen, &formal];
    let (total_s, mat_s) = if feats.tiled_dataflow {
        // Cross-stage tiling: stages stream tile-by-tile and overlap; the
        // slowest stream bounds throughput. Without the OoO scheduler the
        // pipeline pays fill/drain bubbles at each stage boundary.
        let bottleneck = stages.iter().map(|s| s.wall()).fold(0.0, f64::max);
        let sum_compute: f64 = stages.iter().map(|s| s.compute_s).sum();
        let bubble = if feats.oo_scheduler { 0.02 } else { 0.12 };
        let total = bottleneck + bubble * sum_compute;
        // Exposed MAT under overlap: memory stream time above compute time.
        let compute_max = stages.iter().map(|s| s.compute_s).fold(0.0, f64::max);
        let mem_max = stages.iter().map(|s| s.mem_s).fold(0.0, f64::max);
        (total, (mem_max - compute_max).max(0.0))
    } else {
        // Stage-serial: each stage runs to completion; its memory stream
        // overlaps only its own compute.
        let total: f64 = stages.iter().map(|s| s.wall()).sum();
        let mat: f64 = stages.iter().map(|s| (s.mem_s - s.compute_s).max(0.0)).sum();
        (total, mat)
    };

    // Energy: compute pJ + SRAM pJ (counted in of_ops via sram_bytes) are
    // inside compute_e; DRAM energy from the channel model.
    let sram_j = ops.sram_bytes as f64 * 8.0 * em.sram_pj_per_bit * 1e-12;
    let compute_j = compute_e * 1e-12 - sram_j;
    let dram_j = dram.energy_j(dram_bytes) * (em.dram_pj_per_bit / 6.0);
    let energy = EnergyBreakdown { compute_j, sram_j, dram_j };

    let eff_gops = shape.dense_equivalent_ops() / total_s / 1e9;
    SimReport {
        predict,
        topk,
        kv_gen,
        formal,
        total_s,
        mat_s,
        energy,
        ops,
        dram_bytes,
        eff_gops,
        stall_cycles,
        kv_resident_bytes: gen_rows * (2 * w.d) as u64 * f,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> WorkloadShape {
        WorkloadShape::new(128, 2048, 64, 768, 0.2)
    }

    #[test]
    fn star_beats_ds_baseline() {
        // LTPP shape (T = 512): the regime the paper targets, where the
        // baseline's Â/score spills and precomputed-KV loads dominate.
        let cfg = AccelConfig::default();
        let dram = DramChannel::accel_256();
        let ltpp = WorkloadShape::new(512, 2048, 64, 768, 0.2);
        let star = simulate(&ltpp, &FeatureSet::star(), &cfg, &dram);
        let base = simulate(&ltpp, &FeatureSet::ds_baseline(), &cfg, &dram);
        assert!(star.total_s < base.total_s, "star {} !< base {}", star.total_s, base.total_s);
        assert!(star.energy.total_j() < base.energy.total_j());
        assert!(star.dram_bytes < base.dram_bytes, "star {} !< base {}", star.dram_bytes, base.dram_bytes);
    }

    #[test]
    fn star_beats_dense_asic_by_sparsity_margin() {
        let cfg = AccelConfig::default();
        let dram = DramChannel::accel_256();
        let star = simulate(&shape(), &FeatureSet::star(), &cfg, &dram);
        let dense = simulate(&shape(), &FeatureSet::dense_asic(), &cfg, &dram);
        let speedup = dense.total_s / star.total_s;
        assert!(speedup > 1.5, "speedup {speedup}");
    }

    #[test]
    fn tiled_dataflow_cuts_dram_traffic() {
        let cfg = AccelConfig::default();
        let dram = DramChannel::accel_256();
        let mut serial = FeatureSet::star();
        serial.tiled_dataflow = false;
        serial.oo_scheduler = false;
        let tiled = simulate(&shape(), &FeatureSet::star(), &cfg, &dram);
        let ser = simulate(&shape(), &serial, &cfg, &dram);
        assert!(tiled.dram_bytes <= ser.dram_bytes);
    }

    #[test]
    fn untailored_sufa_stalls() {
        let cfg = AccelConfig::default();
        let dram = DramChannel::accel_256();
        let mut raw = FeatureSet::star();
        raw.sufa_tailored = false;
        let tailored = simulate(&shape(), &FeatureSet::star(), &cfg, &dram);
        let stalled = simulate(&shape(), &raw, &cfg, &dram);
        assert_eq!(tailored.stall_cycles, 0);
        assert!(stalled.stall_cycles > 0);
        assert!(stalled.total_s >= tailored.total_s);
    }

    #[test]
    fn effective_gops_in_paper_ballpark() {
        // Table III: STAR ≈ 24423 GOPS effective. Our calibrated model
        // should land within ~2× of that on a representative LTPP job.
        let cfg = AccelConfig::default();
        let dram = DramChannel::accel_256();
        let s = WorkloadShape::new(128, 4096, 128, 4096, 0.2);
        let r = simulate(&s, &FeatureSet::star(), &cfg, &dram);
        assert!(
            (15_000.0..60_000.0).contains(&r.eff_gops),
            "eff GOPS {} out of calibration band",
            r.eff_gops
        );
    }

    #[test]
    fn mat_fraction_rises_with_parallelism_for_serial_designs() {
        // Fig. 3: stage-serial DS accelerators (FACT/Energon-class:
        // low-bit predict, threshold top-k, untiled softmax) become
        // memory-bound as TP grows — MAT averages ~72% at high TP on
        // DDR-class bandwidth.
        let cfg = AccelConfig { sram_bytes: 128 * 1024, ..AccelConfig::default() };
        let dram = DramChannel::ddr4();
        let feats = FeatureSet {
            predict: PredictKind::LowBitMul,
            topk: TopkKind::Threshold,
            formal: FormalKind::Flash2,
            on_demand_kv: false,
            tiled_dataflow: false,
            oo_scheduler: false,
            sufa_tailored: false,
        };
        let high = simulate(&WorkloadShape::new(512, 2048, 64, 768, 0.25), &feats, &cfg, &dram);
        // MAT dominates (the paper's 72%-average claim), and the Â-spill
        // component of it (prediction-stage exposed memory time) grows
        // with TP — the row-dependency effect Fig. 3 illustrates.
        assert!(high.mat_fraction() > 0.5, "high-TP MAT {}", high.mat_fraction());
        let low = simulate(&WorkloadShape::new(32, 2048, 64, 768, 0.25), &feats, &cfg, &dram);
        let exposed = |r: &SimReport| (r.predict.mem_s - r.predict.compute_s).max(0.0);
        assert!(exposed(&high) > exposed(&low), "Â spill should grow with TP");
    }

    #[test]
    fn throughput_saturates_with_sram_for_star() {
        // Fig. 23(a): STAR saturates by ~316 kB.
        let dram = DramChannel::accel_256();
        let sweep: Vec<f64> = [64usize, 128, 256, 316, 512]
            .iter()
            .map(|&kb| {
                let cfg = AccelConfig { sram_bytes: kb * 1024, ..AccelConfig::default() };
                simulate(&shape(), &FeatureSet::star(), &cfg, &dram).eff_gops
            })
            .collect();
        let last = sweep[sweep.len() - 1];
        let at316 = sweep[3];
        assert!((last - at316).abs() / last < 0.05, "no saturation: {sweep:?}");
    }
}
