//! Cycle/op models for the STAR units (Fig. 12).
//!
//! Each unit turns a stage's work into (cycles, op counts). The models are
//! throughput-style: `cycles = ops / lanes`, which matches a fully
//! pipelined datapath; serialization effects (stage bubbles, stalls,
//! memory waits) are composed in [`super::pipeline`].

use crate::arith::{OpCounter, OpKind};
use crate::config::AccelConfig;
use crate::util::ceil_div;

/// Work description for one attention head-group job.
#[derive(Clone, Copy, Debug)]
pub struct StageWork {
    /// Queries in parallel.
    pub t: usize,
    /// Context length.
    pub s: usize,
    /// Head dimension.
    pub d: usize,
    /// Hidden dimension (for KV generation).
    pub h: usize,
    /// Keys kept per row (absolute).
    pub keep: usize,
    /// SADS segments.
    pub segments: usize,
    /// SADS survivor ratio ρ.
    pub rho: f64,
    /// Fraction of keys in the union of all rows' selections (on-demand KV).
    pub union_ratio: f64,
    /// SU-FA tile size.
    pub bc: usize,
}

impl StageWork {
    /// Reasonable defaults for a (t, s, d, h) job with keep-ratio `k`.
    pub fn new(t: usize, s: usize, d: usize, h: usize, k: f64) -> StageWork {
        let keep = ((s as f64 * k).round() as usize).clamp(1, s);
        StageWork {
            t,
            s,
            d,
            h,
            keep,
            // DSE-style sub-segment sizing: ~256-element segments
            // (Appendix A; n = 4 at the paper's S = 1024 example).
            segments: (s / 256).clamp(2, 64),
            rho: 0.4, // the paper's typical ρ at r = 5
            union_ratio: (1.5 * k).min(1.0),
            bc: 16,
        }
    }
}

/// DLZS prediction unit: shift+accumulate lanes.
pub struct DlzsUnit {
    pub lanes: usize,
}

impl DlzsUnit {
    /// Cross-phase prediction: phase 1.1 (K̂ = X·LZ(W_k), no online encode)
    /// + phase 1.2 (Â = LZ(Q)·K̂ᵀ).
    pub fn cross_phase(&self, w: &StageWork) -> (u64, OpCounter) {
        let mut c = OpCounter::new();
        let shifts = (w.s * w.h * w.d + w.t * w.s * w.d) as u64;
        c.tally(OpKind::Shift, shifts);
        c.tally(OpKind::Add, shifts);
        c.tally(OpKind::LzEncode, (w.t * w.d) as u64); // Q only
        // Compact code loads for W_k; int8 activations.
        c.sram((w.s * w.h) as u64 + (w.h * w.d) as u64 + (w.t * w.d) as u64);
        c.sram((w.t * w.s) as u64); // Â tile writes (1 B/score)
        (shifts.div_ceil(self.lanes as u64), c)
    }

    /// SLZS attention-only prediction (FACT-style): K comes from the dense
    /// KV path; both Q and K pay online LZ conversion and full-width loads.
    pub fn slzs_attention(&self, w: &StageWork) -> (u64, OpCounter) {
        let mut c = OpCounter::new();
        let shifts = (w.t * w.s * w.d) as u64;
        c.tally(OpKind::Shift, shifts);
        c.tally(OpKind::Add, shifts);
        c.tally(OpKind::LzEncode, ((w.t + w.s) * w.d) as u64);
        c.sram((2 * (w.t + w.s) * w.d) as u64); // full 8-bit operands ×2 phases
        c.sram((w.t * w.s) as u64);
        (shifts.div_ceil(self.lanes as u64), c)
    }
}

/// Low-bit multiplier array (the 4-bit-MSB prediction baseline).
pub struct LowBitPredictUnit {
    pub macs_per_cycle: usize,
}

impl LowBitPredictUnit {
    pub fn attention(&self, w: &StageWork) -> (u64, OpCounter) {
        let mut c = OpCounter::new();
        let macs = (w.t * w.s * w.d) as u64;
        c.tally(OpKind::Mul, macs);
        c.tally(OpKind::Add, macs);
        c.sram((2 * (w.t + w.s) * w.d) as u64);
        c.sram((w.t * w.s) as u64);
        (macs.div_ceil(self.macs_per_cycle as u64), c)
    }
}

/// SADS sorting unit: comparator lanes.
pub struct SadsUnit {
    pub lanes: usize,
}

impl SadsUnit {
    /// Distributed sorting with sphere-radius pruning (Sec. IV-B
    /// complexity): per row ≈ 2S (max + filter) + ρ·S·keep/n (selection)
    /// + keep·n (merge).
    pub fn sads(&self, w: &StageWork) -> (u64, OpCounter) {
        let n = w.segments.max(1);
        let per_row = 2.0 * w.s as f64
            + w.rho * w.s as f64 * w.keep as f64 / n as f64
            + (w.keep * n) as f64;
        let cmps = (w.t as f64 * per_row) as u64;
        let mut c = OpCounter::new();
        c.tally(OpKind::Cmp, cmps);
        c.sram((w.t * w.s) as u64); // Â reads
        c.sram((w.t * w.keep * 2) as u64); // index writes
        (cmps.div_ceil(self.lanes as u64), c)
    }

    /// Vanilla top-k: keep passes of a full-row scan (Sec. III-A(1)).
    pub fn vanilla(&self, w: &StageWork) -> (u64, OpCounter) {
        let cmps = (w.t * w.keep * w.s) as u64;
        let mut c = OpCounter::new();
        c.tally(OpKind::Cmp, cmps);
        c.sram((w.t * w.s * w.keep.min(8)) as u64); // repeated row scans
        c.sram((w.t * w.keep * 2) as u64);
        (cmps.div_ceil(self.lanes as u64), c)
    }

    /// Multi-round threshold filter (Energon/ELSA-class selection): two
    /// full-row comparison passes against refined thresholds.
    pub fn threshold(&self, w: &StageWork) -> (u64, OpCounter) {
        let cmps = (2 * w.t * w.s) as u64;
        let mut c = OpCounter::new();
        c.tally(OpKind::Cmp, cmps);
        c.sram((2 * w.t * w.s) as u64); // Â read per round
        c.sram((w.t * w.keep * 2) as u64);
        (cmps.div_ceil(self.lanes as u64), c)
    }
}

/// PE array: INT16 MACs for KV generation and the formal-stage matmuls.
pub struct PeArray {
    pub macs_per_cycle: usize,
}

impl PeArray {
    /// KV generation; `union_ratio` < 1 for on-demand generation.
    pub fn kv_generation(&self, w: &StageWork, union_ratio: f64) -> (u64, OpCounter) {
        let rows = (w.s as f64 * union_ratio).ceil() as u64;
        let macs = rows * (w.h * w.d * 2) as u64; // K and V
        let mut c = OpCounter::new();
        c.tally(OpKind::Mul, macs);
        c.tally(OpKind::Add, macs);
        c.sram(rows * (w.h * 2) as u64); // X rows (INT16)
        c.sram(rows * (w.d * 2 * 2) as u64); // K,V writes
        (macs.div_ceil(self.macs_per_cycle as u64), c)
    }

    /// Formal-stage matmuls over `keep` keys per row: QKᵀ + PV.
    pub fn formal_matmuls(&self, w: &StageWork) -> (u64, OpCounter) {
        let macs = (2 * w.t * w.keep * w.d) as u64;
        let mut c = OpCounter::new();
        c.tally(OpKind::Mul, macs);
        c.tally(OpKind::Add, macs);
        c.sram((w.t * w.keep * 2 * 2) as u64); // score tile read/write
        (macs.div_ceil(self.macs_per_cycle as u64), c)
    }
}

/// SU-FA execution unit: exponential lanes + the update datapath.
pub struct SufaUnit {
    pub exp_units: usize,
}

/// Which softmax/update scheme the formal stage runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SoftmaxKind {
    /// Descending sorted updating (the paper's SU-FA).
    SufaDescend,
    /// Ascending sorted updating (Fig. 11b comparison).
    SufaAscend,
    /// FlashAttention-2 online softmax.
    Flash2,
    /// Row-complete softmax (vanilla; requires the whole row on chip).
    Dense,
}

impl SufaUnit {
    /// Cycle/op cost of the softmax-side work for the formal stage.
    /// Returns (cycles, ops). Matmul work is accounted in [`PeArray`].
    pub fn softmax(&self, w: &StageWork, kind: SoftmaxKind) -> (u64, OpCounter) {
        let mut c = OpCounter::new();
        let tiles = ceil_div(w.keep, w.bc).max(1) as u64;
        let t = w.t as u64;
        let keep = w.keep as u64;
        let d = w.d as u64;
        match kind {
            SoftmaxKind::SufaDescend => {
                // One max reduction on the first tile; then pure accumulate.
                c.tally(OpKind::Cmp, t * (w.bc.min(w.keep) as u64 - 1));
                c.tally(OpKind::Exp, t * keep);
                c.tally(OpKind::Add, t * (2 * keep));
                c.tally(OpKind::Div, t);
                c.tally(OpKind::Mul, t * d);
            }
            SoftmaxKind::SufaAscend => {
                c.tally(OpKind::Cmp, t * keep.saturating_sub(tiles)); // in-tile maxes
                c.tally(OpKind::Exp, t * (keep + (tiles - 1)));
                c.tally(OpKind::Add, t * (2 * keep + (tiles - 1)));
                c.tally(OpKind::Mul, t * ((tiles - 1) * (d + 1) + d));
                c.tally(OpKind::Div, t);
            }
            SoftmaxKind::Flash2 => {
                c.tally(OpKind::Cmp, t * (keep + 2 * (tiles - 1)));
                c.tally(OpKind::Exp, t * (keep + (tiles - 1)));
                c.tally(OpKind::Add, t * (2 * keep + (tiles - 1)));
                c.tally(OpKind::Mul, t * ((tiles - 1) * (d + 1) + d));
                c.tally(OpKind::Div, t);
            }
            SoftmaxKind::Dense => {
                c.tally(OpKind::Cmp, t * (keep - 1));
                c.tally(OpKind::Exp, t * keep);
                c.tally(OpKind::Add, t * (2 * keep));
                c.tally(OpKind::Div, t * keep);
            }
        }
        // The exponential lanes bound the softmax throughput.
        let cycles = c.exp.max(1).div_ceil(self.exp_units as u64);
        (cycles, c)
    }
}

/// Build the units from an accelerator config.
pub struct Units {
    pub dlzs: DlzsUnit,
    pub lowbit: LowBitPredictUnit,
    pub sads: SadsUnit,
    pub pe: PeArray,
    pub sufa: SufaUnit,
}

impl Units {
    pub fn from_config(cfg: &AccelConfig) -> Units {
        Units {
            dlzs: DlzsUnit { lanes: cfg.dlzs_lanes },
            lowbit: LowBitPredictUnit { macs_per_cycle: cfg.pe_macs_per_cycle },
            sads: SadsUnit { lanes: cfg.sads_lanes },
            pe: PeArray { macs_per_cycle: cfg.pe_macs_per_cycle },
            sufa: SufaUnit { exp_units: cfg.sufa_exp_units },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work() -> StageWork {
        StageWork::new(128, 2048, 64, 768, 0.2)
    }

    #[test]
    fn dlzs_cross_phase_is_multiplier_free() {
        let u = DlzsUnit { lanes: 2048 };
        let (cycles, c) = u.cross_phase(&work());
        assert_eq!(c.mul, 0);
        assert!(c.shift > 0 && cycles > 0);
        // Only Q is encoded online.
        assert_eq!(c.lz_encode, (128 * 64) as u64);
    }

    #[test]
    fn slzs_encodes_both_sides() {
        let u = DlzsUnit { lanes: 2048 };
        let (_, c) = u.slzs_attention(&work());
        assert_eq!(c.lz_encode, ((128 + 2048) * 64) as u64);
    }

    #[test]
    fn sads_far_cheaper_than_vanilla() {
        let u = SadsUnit { lanes: 1024 };
        let w = work();
        let (cs, _) = u.sads(&w);
        let (cv, _) = u.vanilla(&w);
        let ratio = cs as f64 / cv as f64;
        assert!(ratio < 0.2, "sads/vanilla cycle ratio {ratio}");
    }

    #[test]
    fn on_demand_kv_saves_macs() {
        let pe = PeArray { macs_per_cycle: 8192 };
        let w = work();
        let (c_dense, _) = pe.kv_generation(&w, 1.0);
        let (c_od, _) = pe.kv_generation(&w, 0.3);
        assert!((c_od as f64 / c_dense as f64 - 0.3).abs() < 0.01);
    }

    #[test]
    fn sufa_descend_cheapest_softmax() {
        let u = SufaUnit { exp_units: 128 };
        let w = work();
        let (_, cd) = u.softmax(&w, SoftmaxKind::SufaDescend);
        let (_, ca) = u.softmax(&w, SoftmaxKind::SufaAscend);
        let (_, cf) = u.softmax(&w, SoftmaxKind::Flash2);
        assert!(cd.exp < ca.exp && ca.exp <= cf.exp);
        assert!(cd.mul < ca.mul);
        assert!(cd.cmp < cf.cmp);
        // Fig. 11b: ascend ≈ flash2 minus the comparisons.
        assert!(ca.cmp < cf.cmp);
    }

    #[test]
    fn stagework_defaults_sane() {
        let w = StageWork::new(4, 100, 8, 32, 0.25);
        assert_eq!(w.keep, 25);
        assert!((w.union_ratio - 0.375).abs() < 1e-12);
        let w2 = StageWork::new(4, 100, 8, 32, 0.9);
        assert_eq!(w2.union_ratio, 1.0);
    }
}
