//! On-chip SRAM model: capacity, bandwidth, and working-set fit checks.
//!
//! SRAM is the hinge of the whole paper: the row-wise dependencies of
//! top-k/softmax force intermediates on chip, and when they don't fit they
//! spill to DRAM (Sec. III-A(2)). The model answers two questions: does a
//! stage's working set fit, and how long does on-chip streaming take.

/// SRAM bank array.
#[derive(Clone, Copy, Debug)]
pub struct Sram {
    pub bytes: usize,
    /// Aggregate read+write bandwidth in bytes/s (the paper quotes 19 TB/s
    /// class on-chip bandwidth).
    pub bw: f64,
}

impl Sram {
    /// The paper's single-core STAR on-chip budget: 316 kB. Also the
    /// reference point the software tile engine reports its
    /// [`crate::pipeline::TileWorkspace`] capacity against
    /// (`workspace_bytes` in the pipeline reports and bench JSON —
    /// DESIGN.md §8).
    pub const STAR_BUDGET_BYTES: usize = 316 * 1024;

    pub fn new(bytes: usize) -> Sram {
        Sram { bytes, bw: 19e12 }
    }

    /// The modeled single-core STAR SRAM array
    /// ([`Sram::STAR_BUDGET_BYTES`]).
    pub fn star_single_core() -> Sram {
        Sram::new(Sram::STAR_BUDGET_BYTES)
    }

    pub fn fits(&self, working_set: usize) -> bool {
        working_set <= self.bytes
    }

    /// Bytes that overflow the capacity (0 when it fits).
    pub fn spill(&self, working_set: usize) -> usize {
        working_set.saturating_sub(self.bytes)
    }

    /// Time to stream `bytes` through the SRAM ports.
    pub fn stream_time(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bw
    }
}

/// Working-set calculator for the DS stages of a (T, S, d_h) attention
/// workload, in bytes. Element width `ew` (2 for INT16/FP16).
#[derive(Clone, Copy, Debug)]
pub struct WorkingSets {
    pub t: usize,
    pub s: usize,
    pub d: usize,
    pub ew: usize,
}

impl WorkingSets {
    /// Estimated attention matrix Â (1 byte/score in the prediction path).
    pub fn ahat(&self) -> usize {
        self.t * self.s
    }

    /// Full-precision score tile for the formal stage (per tile of width
    /// `bc`, T rows).
    pub fn score_tile(&self, bc: usize) -> usize {
        self.t * bc * self.ew
    }

    /// Q + O + running (m, l) state resident during SU-FA.
    pub fn sufa_state(&self) -> usize {
        self.t * self.d * self.ew * 2 + self.t * 2 * self.ew
    }

    /// KV tile of width `bc`.
    pub fn kv_tile(&self, bc: usize) -> usize {
        2 * bc * self.d * self.ew
    }

    /// Dense (untiled) softmax working set: the whole T×S score matrix in
    /// formal precision — what the baselines must hold (or spill).
    pub fn dense_scores(&self) -> usize {
        self.t * self.s * self.ew
    }

    /// Full K+V residency (no on-demand generation).
    pub fn dense_kv(&self) -> usize {
        2 * self.s * self.d * self.ew
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_and_spill() {
        let s = Sram::new(1024);
        assert!(s.fits(1024));
        assert!(!s.fits(1025));
        assert_eq!(s.spill(1500), 476);
        assert_eq!(s.spill(10), 0);
    }

    #[test]
    fn bloom7b_t512_needs_megabytes() {
        // The Sec. III-A(2) example: Bloom-7B (d_h=128), T=512, S=4096:
        // dense scores at INT16 = 512·4096·2 = 4 MiB — the "substantial
        // 5 MB of SRAM" ballpark once KV residency is added.
        let ws = WorkingSets { t: 512, s: 4096, d: 128, ew: 2 };
        let need = ws.dense_scores() + ws.dense_kv();
        assert!(need > 4 * 1024 * 1024, "need {need}");
        assert!(!Sram::new(316 * 1024).fits(need));
    }

    #[test]
    fn tiled_working_set_fits_316kb() {
        // STAR's point: with cross-stage tiling, the resident set is tiles
        // + SU-FA state, which fits the 316 kB budget even at T=128.
        let ws = WorkingSets { t: 128, s: 16384, d: 128, ew: 2 };
        let tiled = ws.score_tile(16) + ws.kv_tile(16) + ws.sufa_state();
        assert!(Sram::new(316 * 1024).fits(tiled), "tiled set {tiled}");
        assert!(!Sram::new(316 * 1024).fits(ws.dense_scores()));
    }

    #[test]
    fn star_budget_constant_matches_paper() {
        let s = Sram::star_single_core();
        assert_eq!(s.bytes, 316 * 1024);
        assert_eq!(Sram::STAR_BUDGET_BYTES, 316 * 1024);
    }

    #[test]
    fn stream_time_linear() {
        let s = Sram::new(1024);
        assert!((s.stream_time(19_000_000_000_000) - 1.0).abs() < 1e-9);
    }
}
