//! Configuration system: model shape presets (the paper's benchmark suite),
//! accelerator configuration, sparsity configuration and the spatial-mesh
//! configuration (Table IV). Configs serialize to/from the JSON subset in
//! [`crate::util::json`].

use crate::util::json::Json;

/// Transformer model shapes. These are the models of the paper's evaluation
/// (Table II / Figs. 16–19); we use them as *shape presets* for workload
/// generation — see DESIGN.md §4 for the accuracy-experiment substitution.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Hidden dimension H.
    pub hidden: usize,
    /// Number of attention heads N_h.
    pub heads: usize,
    /// Number of transformer layers.
    pub layers: usize,
    /// Default / maximum sequence length used in experiments.
    pub seq_len: usize,
    /// Decoder-style (causal) attention?
    pub causal: bool,
}

impl ModelConfig {
    /// Per-head dimension d_h = H / N_h.
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Named presets matching the paper's benchmark suite.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let (hidden, heads, layers, seq_len, causal) = match name {
            "bert-base" => (768, 12, 12, 512, false),
            "bert-large" => (1024, 16, 24, 512, false),
            "vit" => (768, 12, 12, 197, false),
            "pvt" => (512, 8, 12, 1024, false),
            "gpt2" => (768, 12, 12, 1024, true),
            "bloom-1b7" => (2048, 16, 24, 2048, true),
            "opt-6b7" => (4096, 32, 32, 2048, true),
            "llama-7b" => (4096, 32, 32, 4096, true),
            "llama-13b" => (5120, 40, 40, 4096, true),
            "tiny" => (128, 4, 2, 256, true), // e2e example model
            _ => return None,
        };
        Some(ModelConfig { name: name.to_string(), hidden, heads, layers, seq_len, causal })
    }

    /// All presets used by the benchmark suite.
    pub fn suite() -> Vec<ModelConfig> {
        ["bert-base", "bert-large", "vit", "pvt", "gpt2", "bloom-1b7", "llama-7b", "llama-13b"]
            .iter()
            .map(|n| ModelConfig::preset(n).unwrap())
            .collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("hidden", Json::num(self.hidden as f64)),
            ("heads", Json::num(self.heads as f64)),
            ("layers", Json::num(self.layers as f64)),
            ("seq_len", Json::num(self.seq_len as f64)),
            ("causal", Json::Bool(self.causal)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            hidden: j.get("hidden")?.as_usize()?,
            heads: j.get("heads")?.as_usize()?,
            layers: j.get("layers")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            causal: j.get("causal")?.as_bool()?,
        })
    }
}

/// Sparsity configuration: the knobs of the three DS stages.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsityConfig {
    /// Top-k ratio γ ∈ (0, 1]: fraction of keys retained per query row.
    pub topk_ratio: f64,
    /// Number of SADS sub-segments n per row.
    pub segments: usize,
    /// Sphere radius r for early termination (score units).
    pub radius: f32,
    /// Magnitude bitwidth W of the prediction datapath.
    pub predict_bits: u32,
}

impl Default for SparsityConfig {
    fn default() -> Self {
        // Paper defaults: γ ∈ [0.15, 0.2] preferred, n = 4, r = 5.
        SparsityConfig { topk_ratio: 0.2, segments: 4, radius: 5.0, predict_bits: 7 }
    }
}

impl SparsityConfig {
    /// The "standard" configuration (0% accuracy-loss budget).
    pub fn standard() -> Self {
        SparsityConfig { topk_ratio: 0.25, ..Default::default() }
    }

    /// The "aggressive" configuration (≤2% loss budget).
    pub fn aggressive() -> Self {
        SparsityConfig { topk_ratio: 0.15, ..Default::default() }
    }

    /// Keys retained for a row of length `s`.
    pub fn keep(&self, s: usize) -> usize {
        ((s as f64 * self.topk_ratio).round() as usize).clamp(1, s)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("topk_ratio", Json::num(self.topk_ratio)),
            ("segments", Json::num(self.segments as f64)),
            ("radius", Json::num(self.radius as f64)),
            ("predict_bits", Json::num(self.predict_bits as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<SparsityConfig> {
        Some(SparsityConfig {
            topk_ratio: j.get("topk_ratio")?.as_f64()?,
            segments: j.get("segments")?.as_usize()?,
            radius: j.get("radius")?.as_f64()? as f32,
            predict_bits: j.get("predict_bits")?.as_usize()? as u32,
        })
    }
}

/// Single-core STAR accelerator configuration (Sec. V-A).
#[derive(Clone, Debug, PartialEq)]
pub struct AccelConfig {
    /// Clock frequency in Hz (paper: 1 GHz at 28 nm).
    pub freq_hz: f64,
    /// Queries processed in parallel (paper: 128).
    pub query_parallel: usize,
    /// PE array MACs per cycle (KV on-demand generation + QK/AV matmuls).
    pub pe_macs_per_cycle: usize,
    /// DLZS shifter lanes per cycle.
    pub dlzs_lanes: usize,
    /// SADS comparator lanes per cycle.
    pub sads_lanes: usize,
    /// SU-FA exponentiation units.
    pub sufa_exp_units: usize,
    /// On-chip SRAM bytes.
    pub sram_bytes: usize,
    /// Off-chip DRAM bandwidth bytes/s.
    pub dram_bw: f64,
    /// Process node in nm (for energy/area scaling).
    pub tech_nm: f64,
}

impl Default for AccelConfig {
    fn default() -> Self {
        AccelConfig {
            freq_hz: 1e9,
            query_parallel: 128,
            // Sized so peak dense throughput lands at the paper's 24423 GOPS
            // order: 8192 MACs ≈ 16.4 TOPS dense + sparsity ≈ paper's GOPS.
            pe_macs_per_cycle: 8192,
            // Shift-add lanes are cheap (the LP part is only 18.1% of
            // area, Fig. 21), so the DLZS unit is twice the PE width —
            // prediction must never be the steady-state bottleneck.
            dlzs_lanes: 16384,
            sads_lanes: 4096,
            sufa_exp_units: 128,
            sram_bytes: 316 * 1024, // the Fig. 23(a) saturation point
            dram_bw: 256e9,         // Fig. 23(a): 256 GB/s
            tech_nm: 28.0,
        }
    }
}

impl AccelConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("freq_hz", Json::num(self.freq_hz)),
            ("query_parallel", Json::num(self.query_parallel as f64)),
            ("pe_macs_per_cycle", Json::num(self.pe_macs_per_cycle as f64)),
            ("dlzs_lanes", Json::num(self.dlzs_lanes as f64)),
            ("sads_lanes", Json::num(self.sads_lanes as f64)),
            ("sufa_exp_units", Json::num(self.sufa_exp_units as f64)),
            ("sram_bytes", Json::num(self.sram_bytes as f64)),
            ("dram_bw", Json::num(self.dram_bw)),
            ("tech_nm", Json::num(self.tech_nm)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<AccelConfig> {
        Some(AccelConfig {
            freq_hz: j.get("freq_hz")?.as_f64()?,
            query_parallel: j.get("query_parallel")?.as_usize()?,
            pe_macs_per_cycle: j.get("pe_macs_per_cycle")?.as_usize()?,
            dlzs_lanes: j.get("dlzs_lanes")?.as_usize()?,
            sads_lanes: j.get("sads_lanes")?.as_usize()?,
            sufa_exp_units: j.get("sufa_exp_units")?.as_usize()?,
            sram_bytes: j.get("sram_bytes")?.as_usize()?,
            dram_bw: j.get("dram_bw")?.as_f64()?,
            tech_nm: j.get("tech_nm")?.as_f64()?,
        })
    }
}

/// Spatial-architecture configuration (Table IV).
#[derive(Clone, Debug, PartialEq)]
pub struct SpatialConfig {
    /// Mesh rows (paper: 5 or 6).
    pub mesh_rows: usize,
    /// Mesh cols.
    pub mesh_cols: usize,
    /// Die-to-die link bandwidth bytes/s (Table IV: 250 GB/s).
    pub link_bw: f64,
    /// Die-to-die link latency seconds (Table IV: 20 ns).
    pub link_latency: f64,
    /// Die-to-die energy pJ/bit (Table IV: 1.0).
    pub link_pj_per_bit: f64,
    /// Total (shared) DRAM bandwidth bytes/s (Table IV HBM2: 512 GB/s).
    pub dram_bw_total: f64,
    /// DRAM access latency seconds (Table IV: 100 ns).
    pub dram_latency: f64,
    /// DRAM energy pJ/bit (Table IV: 6.0).
    pub dram_pj_per_bit: f64,
    /// Per-core accelerator config.
    pub core: AccelConfig,
}

impl SpatialConfig {
    /// The paper's 5×5 configuration.
    pub fn mesh5x5() -> Self {
        SpatialConfig {
            mesh_rows: 5,
            mesh_cols: 5,
            link_bw: 250e9,
            link_latency: 20e-9,
            link_pj_per_bit: 1.0,
            dram_bw_total: 512e9,
            dram_latency: 100e-9,
            dram_pj_per_bit: 6.0,
            core: AccelConfig { sram_bytes: 412 * 1024, ..AccelConfig::default() },
        }
    }

    /// The paper's 6×6 scaling configuration.
    pub fn mesh6x6() -> Self {
        SpatialConfig { mesh_rows: 6, mesh_cols: 6, ..Self::mesh5x5() }
    }

    pub fn cores(&self) -> usize {
        self.mesh_rows * self.mesh_cols
    }

    /// Effective per-core DRAM bandwidth under full contention — the paper
    /// quotes 512 GB/s total → 20.5 GB/s per core for 5×5.
    pub fn dram_bw_per_core(&self) -> f64 {
        self.dram_bw_total / self.cores() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_integer_head_dims() {
        for m in ModelConfig::suite() {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
            assert!(m.head_dim() >= 32);
        }
    }

    #[test]
    fn llama13b_shape() {
        let m = ModelConfig::preset("llama-13b").unwrap();
        assert_eq!(m.hidden, 5120);
        assert_eq!(m.heads, 40);
        assert_eq!(m.head_dim(), 128);
        assert!(m.causal);
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(ModelConfig::preset("nope").is_none());
    }

    #[test]
    fn model_json_roundtrip() {
        let m = ModelConfig::preset("gpt2").unwrap();
        let j = m.to_json();
        assert_eq!(ModelConfig::from_json(&j).unwrap(), m);
        // Through text too.
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(ModelConfig::from_json(&j2).unwrap(), m);
    }

    #[test]
    fn sparsity_keep_clamped() {
        let c = SparsityConfig { topk_ratio: 0.25, ..Default::default() };
        assert_eq!(c.keep(1024), 256);
        assert_eq!(c.keep(1), 1);
        let tiny = SparsityConfig { topk_ratio: 1e-9, ..Default::default() };
        assert_eq!(tiny.keep(1000), 1);
    }

    #[test]
    fn accel_json_roundtrip() {
        let a = AccelConfig::default();
        assert_eq!(AccelConfig::from_json(&a.to_json()).unwrap(), a);
    }

    #[test]
    fn spatial_per_core_bandwidth_matches_paper() {
        let s = SpatialConfig::mesh5x5();
        // 512 GB/s / 25 = 20.48 GB/s ≈ the paper's "20.5 GB/s per core".
        assert!((s.dram_bw_per_core() - 20.48e9).abs() < 1e6);
        assert_eq!(SpatialConfig::mesh6x6().cores(), 36);
    }
}
