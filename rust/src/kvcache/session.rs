//! Sessions over the paged pool: ownership, LRU eviction and
//! re-materialization.
//!
//! A [`SessionStore`] keys decode state by session id. Each session owns
//! a list of pages in the shared [`PagedKvCache`] plus its **host-side
//! token history** (the durable truth — in a real deployment the
//! activations the KV regenerates from). Eviction is whole-session and
//! LRU: when the pool is at capacity, the least-recently-touched *other*
//! session loses its pages (history survives). The next decode step of
//! an evicted session re-materializes its pages from history — charged
//! as DRAM reload + requantization in the step's [`StageOps`] — and
//! rebuilds **bit-identical** metadata, because page operands are
//! quantized per row ([`crate::arith::quantize_row`]).

use super::page::{CacheStats, KvPage, PagedKvCache, PageId};
use crate::arith::{IntBits, OpKind};
use crate::pipeline::{PipelineConfig, StageOps};
use crate::sim::pipeline::PredictKind;
use crate::sparsity::bits_for;
use crate::tensor::Mat;
use std::collections::BTreeMap;

/// Construction knobs for a [`SessionStore`].
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Tokens per page. Size it to the pipeline's query-tile size so
    /// cached state composes with cross-stage tiling
    /// ([`SessionConfig::for_pipeline`]).
    pub page_size: usize,
    /// Head dimension of the cached K/V rows.
    pub d: usize,
    /// Maximum resident pages across all sessions (0 = unbounded).
    pub capacity_pages: usize,
    /// Magnitude bitwidth W of the cached prediction operands; must
    /// match the serving pipeline's `predict_bits` (enforced by
    /// `decode_step`).
    pub predict_bits: u32,
    /// The serving pipeline's prediction scheme — determines which
    /// append-time conversion work is charged (SLZS pays the key-side
    /// LZ encode once per appended token; DLZS never encodes keys).
    pub predict: PredictKind,
}

impl SessionConfig {
    /// A config with the STAR default prediction scheme/bitwidth.
    pub fn new(page_size: usize, d: usize, capacity_pages: usize) -> SessionConfig {
        SessionConfig {
            page_size,
            d,
            capacity_pages,
            predict_bits: 7,
            predict: PredictKind::DlzsCross,
        }
    }

    /// Page size, predict bitwidth and scheme drawn from the pipeline
    /// that will serve the sessions — one config source, no drift.
    pub fn for_pipeline(cfg: &PipelineConfig, d: usize, capacity_pages: usize) -> SessionConfig {
        SessionConfig {
            page_size: cfg.tile_t,
            d,
            capacity_pages,
            predict_bits: cfg.predict_bits,
            predict: cfg.predict,
        }
    }
}

/// Per-session state.
#[derive(Clone, Debug, Default)]
struct Session {
    /// Host-side K history, row-major `[len, d]`.
    hist_k: Vec<f32>,
    /// Host-side V history.
    hist_v: Vec<f32>,
    len: usize,
    /// Resident pages in append order; empty ⇒ evicted (or brand new).
    pages: Vec<PageId>,
    last_touch: u64,
}

/// What one [`SessionStore::append`] call did beyond appending.
#[derive(Clone, Debug, Default)]
pub struct AppendOutcome {
    /// Global position of the first appended token.
    pub start: usize,
    /// Sessions evicted to make room (LRU order).
    pub evicted_sessions: Vec<u64>,
    /// Pages rebuilt from history because this session had been evicted.
    pub rematerialized_pages: usize,
    /// Tokens those rebuilt pages hold (the session length at
    /// re-materialization time; 0 when nothing was rebuilt) — the exact
    /// row count behind the re-materialization byte traffic.
    pub rematerialized_tokens: usize,
}

/// The paged KV-cache session store.
#[derive(Clone, Debug)]
pub struct SessionStore {
    cfg: SessionConfig,
    bits: IntBits,
    cache: PagedKvCache,
    sessions: BTreeMap<u64, Session>,
    clock: u64,
}

impl SessionStore {
    /// An empty store over a fresh page pool.
    pub fn new(cfg: SessionConfig) -> SessionStore {
        assert!(cfg.page_size > 0 && cfg.d > 0, "page_size and d must be positive");
        SessionStore {
            bits: bits_for(cfg.predict_bits),
            cache: PagedKvCache::new(cfg.page_size, cfg.d, cfg.capacity_pages),
            sessions: BTreeMap::new(),
            clock: 0,
            cfg,
        }
    }

    /// The store's construction knobs.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Tokens stored for a session (0 for unknown ids).
    pub fn len(&self, sid: u64) -> usize {
        self.sessions.get(&sid).map(|s| s.len).unwrap_or(0)
    }

    /// Whether the session holds no tokens (unknown ids are empty).
    pub fn is_empty(&self, sid: u64) -> bool {
        self.len(sid) == 0
    }

    /// Whether the session id has ever been appended to.
    pub fn contains(&self, sid: u64) -> bool {
        self.sessions.contains_key(&sid)
    }

    /// Whether the session's pages are currently in the pool.
    pub fn is_resident(&self, sid: u64) -> bool {
        self.sessions.get(&sid).map(|s| !s.pages.is_empty()).unwrap_or(false)
    }

    /// Sessions tracked (resident or evicted).
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Pages currently resident across all sessions.
    pub fn resident_pages(&self) -> usize {
        self.cache.resident_pages()
    }

    /// Lifetime cache counters (allocations, evictions, hits…).
    pub fn stats(&self) -> CacheStats {
        self.cache.stats
    }

    /// Count resident pages served to a decode read (cache hits).
    pub fn record_hits(&mut self, pages: u64) {
        self.cache.stats.page_hits += pages;
    }

    /// Append new tokens' K/V rows to a session (creating it on first
    /// use), re-materializing evicted pages first and evicting LRU
    /// *other* sessions when the pool is full. Errors only when this
    /// session alone cannot fit the pool — checked **up front**, before
    /// any state changes, so a failed append never leaves a partial
    /// chunk behind (a retry would otherwise duplicate context).
    pub fn append(
        &mut self,
        sid: u64,
        k: &Mat,
        v: &Mat,
        ops: &mut StageOps,
    ) -> crate::Result<AppendOutcome> {
        anyhow::ensure!(k.rows == v.rows, "K/V row count mismatch ({} vs {})", k.rows, v.rows);
        anyhow::ensure!(
            k.cols == self.cfg.d && v.cols == self.cfg.d,
            "K/V head dim ({}, {}) != store head dim {}",
            k.cols,
            v.cols,
            self.cfg.d
        );
        if self.cfg.capacity_pages > 0 {
            // Other sessions can always be evicted, so the only hard
            // failure is this session alone outgrowing the pool. With
            // this pre-check, the allocation loop below cannot fail.
            let needed = (self.len(sid) + k.rows).div_ceil(self.cfg.page_size);
            anyhow::ensure!(
                needed <= self.cfg.capacity_pages,
                "kv-cache capacity ({} pages of {} tokens) exhausted by session {sid} alone \
                 (needs {needed} pages)",
                self.cfg.capacity_pages,
                self.cfg.page_size
            );
        }
        self.touch(sid);
        let mut evicted = Vec::new();
        let (rematerialized_pages, rematerialized_tokens) =
            self.rematerialize(sid, ops, &mut evicted)?;
        let start = self.sessions.get(&sid).unwrap().len;
        for i in 0..k.rows {
            self.push_row(sid, k.row(i), v.row(i), &mut evicted)?;
        }
        // Appended KV is generated on chip (SRAM write) together with its
        // frozen prediction operand.
        ops.kv_gen.sram((4 * 2 * k.rows * self.cfg.d) as u64);
        ops.predict.sram((2 * k.rows * self.cfg.d) as u64);
        if self.cfg.predict == PredictKind::Slzs {
            // SLZS pays the key-side LZ conversion once, here — decode
            // steps read the frozen codes.
            ops.predict.tally(OpKind::LzEncode, (k.rows * self.cfg.d) as u64);
        }
        self.cache.stats.appended_tokens += k.rows as u64;
        Ok(AppendOutcome {
            start,
            evicted_sessions: evicted,
            rematerialized_pages,
            rematerialized_tokens,
        })
    }

    /// Drop a finished session, returning its pages to the pool.
    pub fn remove(&mut self, sid: u64) {
        if let Some(s) = self.sessions.remove(&sid) {
            for pid in s.pages {
                self.cache.free_page(pid);
            }
        }
    }

    /// The session's resident pages in append order: key `j` lives in
    /// page `j / page_size`, row `j % page_size`.
    pub fn pages_of(&self, sid: u64) -> Vec<&KvPage> {
        match self.sessions.get(&sid) {
            None => Vec::new(),
            Some(s) => {
                assert!(
                    s.len == 0 || !s.pages.is_empty(),
                    "session {sid} read while evicted (append re-materializes first)"
                );
                s.pages.iter().map(|&pid| self.cache.get(pid)).collect()
            }
        }
    }

    /// Gather the K/V rows of the given (sorted, absolute) key indices
    /// into compact matrices — the formal stage's cache read.
    pub fn gather(&self, sid: u64, keys: &[usize]) -> (Mat, Mat) {
        super::page::gather_rows(&self.pages_of(sid), self.cfg.page_size, keys, self.cfg.d)
    }

    fn touch(&mut self, sid: u64) {
        let clock = self.clock;
        self.clock += 1;
        self.sessions.entry(sid).or_default().last_touch = clock;
    }

    /// Rebuild an evicted session's pages from host history, returning
    /// (pages built, tokens they hold). Rebuilt operands are
    /// bit-identical to the originals (per-row scales).
    fn rematerialize(
        &mut self,
        sid: u64,
        ops: &mut StageOps,
        evicted: &mut Vec<u64>,
    ) -> crate::Result<(usize, usize)> {
        let needs = {
            let s = self.sessions.get(&sid).unwrap();
            s.len > 0 && s.pages.is_empty()
        };
        if !needs {
            return Ok((0, 0));
        }
        // Move the history out instead of cloning it (it can be thousands
        // of tokens), rebuild, then reinstall — including on the (defended
        // against, see `append`'s capacity pre-check) error path.
        let (hist_k, hist_v, len) = {
            let s = self.sessions.get_mut(&sid).unwrap();
            (std::mem::take(&mut s.hist_k), std::mem::take(&mut s.hist_v), s.len)
        };
        let built = self.rebuild_pages(sid, &hist_k, &hist_v, len, evicted);
        let s = self.sessions.get_mut(&sid).unwrap();
        s.hist_k = hist_k;
        s.hist_v = hist_v;
        let built = built?;
        // Evicted KV comes back from off-chip memory and is requantized
        // (SLZS additionally re-encodes the rebuilt key operands).
        let d = self.cfg.d;
        ops.kv_gen.dram((4 * 2 * len * d) as u64);
        ops.predict.sram((2 * len * d) as u64);
        if self.cfg.predict == PredictKind::Slzs {
            ops.predict.tally(OpKind::LzEncode, (len * d) as u64);
        }
        self.cache.stats.pages_rematerialized += built as u64;
        Ok((built, len))
    }

    /// The page-building loop of [`SessionStore::rematerialize`]: fresh
    /// pages fill sequentially, so a page boundary is exactly `i %
    /// page_size == 0`.
    fn rebuild_pages(
        &mut self,
        sid: u64,
        hist_k: &[f32],
        hist_v: &[f32],
        len: usize,
        evicted: &mut Vec<u64>,
    ) -> crate::Result<usize> {
        let d = self.cfg.d;
        let ps = self.cfg.page_size;
        let mut built = 0usize;
        let mut cur: Option<PageId> = None;
        for i in 0..len {
            if i % ps == 0 {
                let pid = self.alloc_for(sid, evicted)?;
                self.sessions.get_mut(&sid).unwrap().pages.push(pid);
                cur = Some(pid);
                built += 1;
            }
            self.cache.get_mut(cur.unwrap()).push(
                &hist_k[i * d..(i + 1) * d],
                &hist_v[i * d..(i + 1) * d],
                self.bits,
                self.cfg.predict_bits,
            );
        }
        Ok(built)
    }

    fn push_row(
        &mut self,
        sid: u64,
        k_row: &[f32],
        v_row: &[f32],
        evicted: &mut Vec<u64>,
    ) -> crate::Result<()> {
        let need_page = {
            let s = self.sessions.get(&sid).unwrap();
            s.pages.last().map(|&pid| self.cache.get(pid).is_full()).unwrap_or(true)
        };
        if need_page {
            let pid = self.alloc_for(sid, evicted)?;
            self.sessions.get_mut(&sid).unwrap().pages.push(pid);
        }
        let pid = *self.sessions.get(&sid).unwrap().pages.last().unwrap();
        self.cache.get_mut(pid).push(k_row, v_row, self.bits, self.cfg.predict_bits);
        let s = self.sessions.get_mut(&sid).unwrap();
        s.hist_k.extend_from_slice(k_row);
        s.hist_v.extend_from_slice(v_row);
        s.len += 1;
        Ok(())
    }

    fn alloc_for(&mut self, sid: u64, evicted: &mut Vec<u64>) -> crate::Result<PageId> {
        loop {
            if let Some(pid) = self.cache.alloc() {
                return Ok(pid);
            }
            match self.evict_lru_other(sid) {
                Some(victim) => evicted.push(victim),
                None => anyhow::bail!(
                    "kv-cache capacity ({} pages of {} tokens) exhausted by session {sid} alone",
                    self.cfg.capacity_pages,
                    self.cfg.page_size
                ),
            }
        }
    }

    fn evict_lru_other(&mut self, keep: u64) -> Option<u64> {
        let victim = self
            .sessions
            .iter()
            .filter(|(id, s)| **id != keep && !s.pages.is_empty())
            .min_by_key(|(_, s)| s.last_touch)
            .map(|(id, _)| *id)?;
        let pages = std::mem::take(&mut self.sessions.get_mut(&victim).unwrap().pages);
        self.cache.stats.pages_evicted += pages.len() as u64;
        self.cache.stats.sessions_evicted += 1;
        for pid in pages {
            self.cache.free_page(pid);
        }
        Some(victim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toks(n: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        (Mat::randn(n, d, 1.0, &mut rng), Mat::randn(n, d, 1.0, &mut rng))
    }

    fn store(page_size: usize, d: usize, cap: usize) -> SessionStore {
        SessionStore::new(SessionConfig::new(page_size, d, cap))
    }

    #[test]
    fn append_builds_pages_and_history() {
        let mut st = store(2, 4, 0);
        let (k, v) = toks(5, 4, 1);
        let mut ops = StageOps::default();
        let out = st.append(7, &k, &v, &mut ops).unwrap();
        assert_eq!(out.start, 0);
        assert_eq!(st.len(7), 5);
        assert_eq!(st.resident_pages(), 3, "5 tokens / page_size 2");
        let pages = st.pages_of(7);
        assert_eq!(pages[2].len(), 1, "last page partially filled");
        assert_eq!(pages[1].k_row(0), k.row(2));
        // Second append continues at position 5.
        let (k2, v2) = toks(1, 4, 2);
        let out2 = st.append(7, &k2, &v2, &mut ops).unwrap();
        assert_eq!(out2.start, 5);
        assert_eq!(st.pages_of(7)[2].len(), 2);
    }

    #[test]
    fn lru_eviction_and_rematerialization_round_trip() {
        // Pool of 2 pages × 2 tokens; two sessions cannot both stay.
        let mut st = store(2, 4, 2);
        let mut ops = StageOps::default();
        let (ka, va) = toks(3, 4, 3);
        st.append(1, &ka, &va, &mut ops).unwrap(); // fills the pool (2 pages)
        let (kb, vb) = toks(2, 4, 4);
        let out = st.append(2, &kb, &vb, &mut ops).unwrap();
        assert_eq!(out.evicted_sessions, vec![1], "LRU victim is session 1");
        assert!(!st.is_resident(1));
        assert!(st.is_resident(2));
        assert_eq!(st.len(1), 3, "history survives eviction");
        // Touching session 1 again re-materializes bit-identical pages
        // (evicting session 2 in turn) and the new token extends them.
        let (k1, v1) = toks(1, 4, 5);
        let out = st.append(1, &k1, &v1, &mut ops).unwrap();
        assert_eq!(out.rematerialized_pages, 2);
        assert_eq!(out.evicted_sessions, vec![2]);
        assert_eq!(st.len(1), 4);
        let pages = st.pages_of(1);
        assert_eq!(pages[0].k_row(1), ka.row(1));
        assert_eq!(pages[0].qk_row(1).len(), 4);
        assert_eq!(pages[1].k_row(1), k1.row(0), "appended token lands after history");
        let stats = st.stats();
        assert_eq!(stats.sessions_evicted, 2);
        assert!(stats.pages_rematerialized >= 2);
    }

    #[test]
    fn single_session_over_capacity_errors_atomically() {
        let mut st = store(2, 4, 2);
        let mut ops = StageOps::default();
        let (k, v) = toks(5, 4, 6); // needs 3 pages, pool holds 2
        let err = st.append(1, &k, &v, &mut ops).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // The failed append left no state behind: a retry with a smaller
        // chunk starts from scratch instead of duplicating context.
        assert_eq!(st.len(1), 0);
        assert_eq!(st.resident_pages(), 0);
        let (k2, v2) = toks(4, 4, 7);
        let out = st.append(1, &k2, &v2, &mut ops).unwrap();
        assert_eq!(out.start, 0);
        assert_eq!(st.len(1), 4);
    }

    #[test]
    fn gather_reads_back_exact_rows() {
        let mut st = store(3, 8, 0);
        let mut ops = StageOps::default();
        let (k, v) = toks(10, 8, 7);
        st.append(4, &k, &v, &mut ops).unwrap();
        let keys = [0usize, 3, 4, 9];
        let (gk, gv) = st.gather(4, &keys);
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(gk.row(i), k.row(key));
            assert_eq!(gv.row(i), v.row(key));
        }
    }

    #[test]
    fn remove_frees_pool_space() {
        let mut st = store(2, 4, 2);
        let mut ops = StageOps::default();
        let (k, v) = toks(4, 4, 8);
        st.append(1, &k, &v, &mut ops).unwrap();
        st.remove(1);
        assert!(!st.contains(1));
        assert_eq!(st.resident_pages(), 0);
        // The freed pool accepts a new session without eviction.
        let (k2, v2) = toks(4, 4, 9);
        let out = st.append(2, &k2, &v2, &mut ops).unwrap();
        assert!(out.evicted_sessions.is_empty());
    }
}
