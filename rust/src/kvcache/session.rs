//! Sessions over the paged pool: page tables, copy-on-write prefix
//! sharing, page-granular eviction and re-materialization.
//!
//! A [`SessionStore`] keys decode state by session id. Each session owns
//! a **page table** (`Vec<Option<PageRef>>`) over the shared, refcounted
//! [`PagedKvCache`] plus its **host-side token history** (the durable
//! truth — in a real deployment the activations the KV regenerates
//! from). Three residency mechanisms compound:
//!
//! * **Page-granular eviction.** When the pool is at capacity the
//!   coldest page of the least-recently-touched *other* session is
//!   dropped (oldest-written page first — early-prefix pages are the
//!   cold end of causal attention). Touching a long session faults back
//!   only its missing pages, not its whole history.
//! * **Copy-on-write prefix sharing.** Every appended row extends a
//!   running FNV-1a chain hash over the session's K/V prefix; a registry
//!   maps chain values to resident pages, so a session whose prefix
//!   matches another's (system prompts, multi-turn fan-out) *attaches*
//!   to the existing page — refcounted — instead of building a copy.
//!   Divergence inside a shared page triggers a split: the diverging
//!   session rebuilds its private prefix rows from history and writes
//!   there. Chain hashes are verified against actual page content before
//!   any attach, so a hash collision can never alias different rows.
//! * **Re-materialization.** A missing page is rebuilt from host history
//!   — charged as DRAM reload + requantization in the step's
//!   [`StageOps`] — and is **bit-identical** to the original, because
//!   page operands are quantized per row
//!   ([`crate::arith::quantize_row`]). Rebuilds first try the share
//!   registry: if a content-identical page is still resident (a sharing
//!   peer kept it warm), the session re-attaches for free.

use super::page::{CacheStats, KvPage, PagedKvCache, PageId, ResidencyMode};
use crate::arith::{IntBits, OpKind};
use crate::pipeline::{PipelineConfig, StageOps};
use crate::sim::pipeline::PredictKind;
use crate::sparsity::bits_for;
use crate::tensor::Mat;
use std::collections::BTreeMap;

/// Construction knobs for a [`SessionStore`].
#[derive(Clone, Copy, Debug)]
pub struct SessionConfig {
    /// Tokens per page. Size it to the pipeline's query-tile size so
    /// cached state composes with cross-stage tiling
    /// ([`SessionConfig::for_pipeline`]).
    pub page_size: usize,
    /// Head dimension of the cached K/V rows.
    pub d: usize,
    /// Maximum resident pages across all sessions (0 = unbounded).
    pub capacity_pages: usize,
    /// Magnitude bitwidth W of the cached prediction operands; must
    /// match the serving pipeline's `predict_bits` (enforced by
    /// `decode_step`).
    pub predict_bits: u32,
    /// The serving pipeline's prediction scheme — determines which
    /// append-time conversion work is charged (SLZS pays the key-side
    /// LZ encode once per appended token; DLZS never encodes keys).
    pub predict: PredictKind,
    /// What resident pages store: [`ResidencyMode::Exact`] (default,
    /// bit-exact serving path) or [`ResidencyMode::QuantizedOnly`]
    /// (opt-in, ~4× fewer resident bytes, lossy at the stage 3–4 gather
    /// only — selection stays bit-identical).
    pub residency: ResidencyMode,
    /// Enable copy-on-write prefix sharing across sessions (default on;
    /// bit-invisible to decode because attaches are content-verified).
    pub prefix_sharing: bool,
}

impl SessionConfig {
    /// A config with the STAR default prediction scheme/bitwidth.
    pub fn new(page_size: usize, d: usize, capacity_pages: usize) -> SessionConfig {
        SessionConfig {
            page_size,
            d,
            capacity_pages,
            predict_bits: 7,
            predict: PredictKind::DlzsCross,
            residency: ResidencyMode::Exact,
            prefix_sharing: true,
        }
    }

    /// Page size, predict bitwidth and scheme drawn from the pipeline
    /// that will serve the sessions — one config source, no drift.
    pub fn for_pipeline(cfg: &PipelineConfig, d: usize, capacity_pages: usize) -> SessionConfig {
        SessionConfig {
            page_size: cfg.tile_t,
            d,
            capacity_pages,
            predict_bits: cfg.predict_bits,
            predict: cfg.predict,
            residency: ResidencyMode::Exact,
            prefix_sharing: true,
        }
    }

    /// Builder: switch the resident-page layout.
    pub fn with_residency(mut self, residency: ResidencyMode) -> SessionConfig {
        self.residency = residency;
        self
    }

    /// Builder: toggle copy-on-write prefix sharing.
    pub fn with_prefix_sharing(mut self, on: bool) -> SessionConfig {
        self.prefix_sharing = on;
        self
    }
}

/// One entry of a session's page table.
#[derive(Clone, Copy, Debug)]
struct PageRef {
    id: PageId,
    /// Store clock at the last write/attach into this page — the
    /// coldness key for page-granular eviction.
    touch: u64,
}

/// Per-session state.
#[derive(Clone, Debug, Default)]
struct Session {
    /// Host-side K history, row-major `[len, d]`.
    hist_k: Vec<f32>,
    /// Host-side V history.
    hist_v: Vec<f32>,
    len: usize,
    /// Page table: entry `p` covers tokens `[p·page_size, …)`; `None` ⇒
    /// that page is currently evicted. Length is always
    /// `len.div_ceil(page_size)`.
    pages: Vec<Option<PageRef>>,
    /// FNV-1a chain hash of the K/V prefix after each row — the prefix
    /// fingerprint the share registry is keyed by.
    row_chains: Vec<u64>,
    last_touch: u64,
}

impl Session {
    fn fully_resident(&self) -> bool {
        self.len > 0 && self.pages.iter().all(|p| p.is_some())
    }
}

const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Extend a prefix chain hash by one token's K/V rows (FNV-1a over the
/// little-endian f32 bytes). The chain covers the *whole* prefix, so
/// equal chains mean equal position *and* content — repeated content at
/// different offsets never aliases.
fn chain_row(prev: u64, k_row: &[f32], v_row: &[f32]) -> u64 {
    let mut h = prev;
    for &x in k_row.iter().chain(v_row) {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// What one [`SessionStore::append`] call did beyond appending.
#[derive(Clone, Debug, Default)]
pub struct AppendOutcome {
    /// Global position of the first appended token.
    pub start: usize,
    /// Sessions that lost at least one page to make room (first-eviction
    /// order, deduplicated).
    pub evicted_sessions: Vec<u64>,
    /// Pages rebuilt from history because they had been evicted
    /// (share-registry re-attaches are free and not counted here).
    pub rematerialized_pages: usize,
    /// Tokens those rebuilt pages hold (0 when nothing was rebuilt) —
    /// the exact row count behind the re-materialization byte traffic,
    /// now page-granular.
    pub rematerialized_tokens: usize,
}

/// Point-in-time residency accounting of a [`SessionStore`] — what the
/// pool physically holds versus what the sessions logically address.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResidencySnapshot {
    /// Pages resident in the pool (shared pages counted once).
    pub resident_pages: usize,
    /// Resident pages referenced by more than one page-table entry.
    pub shared_pages: usize,
    /// Measured heap bytes of all resident page payloads.
    pub resident_bytes: usize,
    /// Tokens addressable across all sessions (each counted per session
    /// even when physically shared).
    pub logical_tokens: usize,
    /// `logical_tokens × 8d` — the f32 K+V bytes a flat cache would
    /// keep; `resident_bytes / logical_bytes` is the compression ratio
    /// sharing + quantized residency buy.
    pub logical_bytes: usize,
    /// Sessions whose every page is resident.
    pub resident_sessions: usize,
    /// Sessions tracked (resident or not).
    pub sessions: usize,
}

/// The paged KV-cache session store.
#[derive(Clone, Debug)]
pub struct SessionStore {
    cfg: SessionConfig,
    bits: IntBits,
    cache: PagedKvCache,
    sessions: BTreeMap<u64, Session>,
    /// Prefix chain hash → a resident page whose rows realize that
    /// prefix tail. First writer wins; entries are dropped when their
    /// page slot is actually freed.
    shared: BTreeMap<u64, PageId>,
    /// Reverse index: page slot → chain hashes registered to it (only
    /// hashes whose insert won), for O(rows) cleanup on free.
    shared_rev: BTreeMap<usize, Vec<u64>>,
    clock: u64,
}

impl SessionStore {
    /// An empty store over a fresh page pool.
    pub fn new(cfg: SessionConfig) -> SessionStore {
        assert!(cfg.page_size > 0 && cfg.d > 0, "page_size and d must be positive");
        let bits = bits_for(cfg.predict_bits);
        if cfg.residency == ResidencyMode::QuantizedOnly {
            assert!(
                bits.qmax() <= 127,
                "quantized-only residency stores i8 operands: predict_bits {} needs {:?}",
                cfg.predict_bits,
                bits
            );
        }
        SessionStore {
            bits,
            cache: PagedKvCache::with_mode(
                cfg.page_size,
                cfg.d,
                cfg.capacity_pages,
                cfg.residency,
                cfg.predict == PredictKind::Slzs,
            ),
            sessions: BTreeMap::new(),
            shared: BTreeMap::new(),
            shared_rev: BTreeMap::new(),
            clock: 0,
            cfg,
        }
    }

    /// The store's construction knobs.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// Tokens stored for a session (0 for unknown ids).
    pub fn len(&self, sid: u64) -> usize {
        self.sessions.get(&sid).map(|s| s.len).unwrap_or(0)
    }

    /// Whether the session holds no tokens (unknown ids are empty).
    pub fn is_empty(&self, sid: u64) -> bool {
        self.len(sid) == 0
    }

    /// Whether the session id has ever been appended to.
    pub fn contains(&self, sid: u64) -> bool {
        self.sessions.contains_key(&sid)
    }

    /// Whether *all* the session's pages are currently in the pool.
    pub fn is_resident(&self, sid: u64) -> bool {
        self.sessions.get(&sid).map(|s| s.fully_resident()).unwrap_or(false)
    }

    /// Sessions tracked (resident or evicted).
    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Pages currently resident across all sessions.
    pub fn resident_pages(&self) -> usize {
        self.cache.resident_pages()
    }

    /// Lifetime cache counters (allocations, evictions, hits…).
    pub fn stats(&self) -> CacheStats {
        self.cache.stats
    }

    /// Point-in-time residency accounting (resident vs logical bytes,
    /// shared pages, fully resident sessions).
    pub fn residency(&self) -> ResidencySnapshot {
        let logical_tokens: usize = self.sessions.values().map(|s| s.len).sum();
        ResidencySnapshot {
            resident_pages: self.cache.resident_pages(),
            shared_pages: self.cache.shared_pages(),
            resident_bytes: self.cache.resident_bytes(),
            logical_tokens,
            logical_bytes: logical_tokens * 8 * self.cfg.d,
            resident_sessions: self.sessions.values().filter(|s| s.fully_resident()).count(),
            sessions: self.sessions.len(),
        }
    }

    /// Count resident pages served to a decode read (cache hits).
    pub fn record_hits(&mut self, pages: u64) {
        self.cache.stats.page_hits += pages;
    }

    /// Append new tokens' K/V rows to a session (creating it on first
    /// use), re-materializing this session's missing pages first and
    /// evicting the coldest pages of LRU *other* sessions when the pool
    /// is full. Errors only when this session alone cannot fit the pool
    /// — checked **up front**, before any state changes, so a failed
    /// append never leaves a partial chunk behind (a retry would
    /// otherwise duplicate context).
    pub fn append(
        &mut self,
        sid: u64,
        k: &Mat,
        v: &Mat,
        ops: &mut StageOps,
    ) -> crate::Result<AppendOutcome> {
        anyhow::ensure!(k.rows == v.rows, "K/V row count mismatch ({} vs {})", k.rows, v.rows);
        anyhow::ensure!(
            k.cols == self.cfg.d && v.cols == self.cfg.d,
            "K/V head dim ({}, {}) != store head dim {}",
            k.cols,
            v.cols,
            self.cfg.d
        );
        if self.cfg.capacity_pages > 0 {
            // Other sessions' pages can always be evicted, so the only
            // hard failure is this session alone outgrowing the pool
            // (counting every page private — sharing only relaxes this).
            // With this pre-check, the allocation loops below cannot
            // fail: at any alloc point this session references at most
            // `needed − 1` distinct slots, so after evicting every other
            // session at least one slot frees.
            let needed = (self.len(sid) + k.rows).div_ceil(self.cfg.page_size);
            anyhow::ensure!(
                needed <= self.cfg.capacity_pages,
                "kv-cache capacity ({} pages of {} tokens) exhausted by session {sid} alone \
                 (needs {needed} pages)",
                self.cfg.capacity_pages,
                self.cfg.page_size
            );
        }
        self.touch(sid);
        let mut evicted = Vec::new();
        let (rematerialized_pages, rematerialized_tokens) =
            self.ensure_resident(sid, ops, &mut evicted)?;
        let start = self.sessions.get(&sid).unwrap().len;
        for i in 0..k.rows {
            self.push_row(sid, k.row(i), v.row(i), &mut evicted)?;
        }
        // Appended KV is generated on chip (SRAM write) together with its
        // frozen prediction operand.
        ops.kv_gen.sram((4 * 2 * k.rows * self.cfg.d) as u64);
        ops.predict.sram((2 * k.rows * self.cfg.d) as u64);
        if self.cfg.predict == PredictKind::Slzs {
            // SLZS pays the key-side LZ conversion once, here — decode
            // steps read the frozen codes.
            ops.predict.tally(OpKind::LzEncode, (k.rows * self.cfg.d) as u64);
        }
        self.cache.stats.appended_tokens += k.rows as u64;
        Ok(AppendOutcome {
            start,
            evicted_sessions: evicted,
            rematerialized_pages,
            rematerialized_tokens,
        })
    }

    /// Drop a finished session, releasing its page references (shared
    /// pages survive until their last sharer goes).
    pub fn remove(&mut self, sid: u64) {
        if let Some(s) = self.sessions.remove(&sid) {
            for r in s.pages.into_iter().flatten() {
                self.release(r.id);
            }
        }
    }

    /// The session's resident pages in append order: key `j` lives in
    /// page `j / page_size`, row `j % page_size`.
    pub fn pages_of(&self, sid: u64) -> Vec<&KvPage> {
        match self.sessions.get(&sid) {
            None => Vec::new(),
            Some(s) => {
                assert!(
                    s.len == 0 || s.pages.iter().all(|p| p.is_some()),
                    "session {sid} read while partially evicted (append re-materializes first)"
                );
                s.pages.iter().flatten().map(|r| self.cache.get(r.id)).collect()
            }
        }
    }

    /// Gather the K/V rows of the given (sorted, absolute) key indices
    /// into compact matrices — the formal stage's cache read.
    pub fn gather(&self, sid: u64, keys: &[usize]) -> (Mat, Mat) {
        super::page::gather_rows(&self.pages_of(sid), self.cfg.page_size, keys, self.cfg.d)
    }

    fn touch(&mut self, sid: u64) {
        let clock = self.clock;
        self.clock += 1;
        self.sessions.entry(sid).or_default().last_touch = clock;
    }

    /// Register a prefix chain for a page we just wrote (first writer
    /// wins, so a chain always points at the earliest resident page
    /// realizing that prefix).
    fn register_chain(&mut self, chain: u64, pid: PageId) {
        if !self.cfg.prefix_sharing {
            return;
        }
        if let std::collections::btree_map::Entry::Vacant(e) = self.shared.entry(chain) {
            e.insert(pid);
            self.shared_rev.entry(pid.0).or_default().push(chain);
        }
    }

    /// Release one reference; when the slot actually frees, drop its
    /// registry entries so a reused slot can never satisfy a stale hash.
    fn release(&mut self, pid: PageId) {
        if self.cache.free_page(pid) {
            if let Some(hashes) = self.shared_rev.remove(&pid.0) {
                for h in hashes {
                    if self.shared.get(&h) == Some(&pid) {
                        self.shared.remove(&h);
                    }
                }
            }
        }
    }

    /// A share-registry candidate for `fill` rows starting at history
    /// offset `lo` — content-verified, so collisions cannot alias.
    fn share_candidate(
        &self,
        chains: &[u64],
        hist_k: &[f32],
        hist_v: &[f32],
        lo: usize,
        fill: usize,
    ) -> Option<PageId> {
        if !self.cfg.prefix_sharing || fill == 0 {
            return None;
        }
        let d = self.cfg.d;
        let pid = *self.shared.get(&chains[lo + fill - 1])?;
        let page = self.cache.get(pid);
        let (ks, vs) = (&hist_k[lo * d..(lo + fill) * d], &hist_v[lo * d..(lo + fill) * d]);
        (page.len() >= fill && page.prefix_matches(fill, ks, vs, self.bits)).then_some(pid)
    }

    /// Make every page of `sid` resident: re-attach to still-resident
    /// shared pages where the registry has a content-identical match
    /// (free), rebuild the rest from host history (charged as DRAM
    /// reload + requantization). Returns (pages rebuilt, tokens they
    /// hold). Rebuilt operands are bit-identical to the originals
    /// (per-row scales).
    fn ensure_resident(
        &mut self,
        sid: u64,
        ops: &mut StageOps,
        evicted: &mut Vec<u64>,
    ) -> crate::Result<(usize, usize)> {
        let needs = {
            let s = self.sessions.get(&sid).unwrap();
            s.pages.iter().any(|p| p.is_none())
        };
        if !needs {
            return Ok((0, 0));
        }
        // Move the session's host state out instead of cloning it (it
        // can be thousands of tokens), rebuild, then reinstall —
        // including on the (defended against, see `append`'s capacity
        // pre-check) error path.
        let (hist_k, hist_v, chains, mut pages, len, touch) = {
            let s = self.sessions.get_mut(&sid).unwrap();
            (
                std::mem::take(&mut s.hist_k),
                std::mem::take(&mut s.hist_v),
                std::mem::take(&mut s.row_chains),
                std::mem::take(&mut s.pages),
                s.len,
                s.last_touch,
            )
        };
        let ps = self.cfg.page_size;
        let d = self.cfg.d;
        debug_assert_eq!(pages.len(), len.div_ceil(ps));
        let mut built_pages = 0usize;
        let mut built_tokens = 0usize;
        let mut result = Ok(());
        for p in 0..pages.len() {
            if pages[p].is_some() {
                continue;
            }
            let lo = p * ps;
            let fill = (len - lo).min(ps);
            if let Some(pid) = self.share_candidate(&chains, &hist_k, &hist_v, lo, fill) {
                self.cache.retain(pid);
                self.cache.stats.pages_shared += 1;
                pages[p] = Some(PageRef { id: pid, touch });
                continue;
            }
            let pid = match self.alloc_for(sid, evicted) {
                Ok(pid) => pid,
                Err(e) => {
                    result = Err(e);
                    break;
                }
            };
            for i in lo..lo + fill {
                self.cache.get_mut(pid).push(
                    &hist_k[i * d..(i + 1) * d],
                    &hist_v[i * d..(i + 1) * d],
                    self.bits,
                    self.cfg.predict_bits,
                );
                self.register_chain(chains[i], pid);
            }
            pages[p] = Some(PageRef { id: pid, touch });
            built_pages += 1;
            built_tokens += fill;
        }
        let s = self.sessions.get_mut(&sid).unwrap();
        s.hist_k = hist_k;
        s.hist_v = hist_v;
        s.row_chains = chains;
        s.pages = pages;
        result?;
        if built_tokens > 0 {
            // Rebuilt KV comes back from off-chip memory and is
            // requantized (SLZS additionally re-encodes the rebuilt key
            // operands) — charged for the rebuilt pages only.
            ops.kv_gen.dram((4 * 2 * built_tokens * d) as u64);
            ops.predict.sram((2 * built_tokens * d) as u64);
            if self.cfg.predict == PredictKind::Slzs {
                ops.predict.tally(OpKind::LzEncode, (built_tokens * d) as u64);
            }
            self.cache.stats.pages_rematerialized += built_pages as u64;
        }
        Ok((built_pages, built_tokens))
    }

    fn push_row(
        &mut self,
        sid: u64,
        k_row: &[f32],
        v_row: &[f32],
        evicted: &mut Vec<u64>,
    ) -> crate::Result<()> {
        let ps = self.cfg.page_size;
        let d = self.cfg.d;
        let (len, prev_chain, touch) = {
            let s = self.sessions.get(&sid).unwrap();
            (s.len, s.row_chains.last().copied().unwrap_or(FNV_SEED), s.last_touch)
        };
        let chain = chain_row(prev_chain, k_row, v_row);
        let p = len / ps;
        let in_page = len % ps;
        let mut write_to = None;
        if in_page == 0 {
            // Page boundary: attach to a content-identical shared page
            // when the registry has one, else open a private page.
            let candidate = (|| {
                if !self.cfg.prefix_sharing {
                    return None;
                }
                let pid = *self.shared.get(&chain)?;
                let page = self.cache.get(pid);
                (page.len() >= 1 && page.row_matches(0, k_row, v_row, self.bits)).then_some(pid)
            })();
            let r = if let Some(pid) = candidate {
                self.cache.retain(pid);
                self.cache.stats.pages_shared += 1;
                PageRef { id: pid, touch }
            } else {
                let pid = self.alloc_for(sid, evicted)?;
                write_to = Some(pid);
                PageRef { id: pid, touch }
            };
            self.sessions.get_mut(&sid).unwrap().pages.push(Some(r));
        } else {
            let pid = self.sessions.get(&sid).unwrap().pages[p]
                .expect("mid-page append into a non-resident page")
                .id;
            let page = self.cache.get(pid);
            if page.len() == in_page {
                // We are the frontier: extend in place. Valid even when
                // shared — other sharers' reads are capped by their own
                // lengths, so rows past their prefix are invisible.
                write_to = Some(pid);
            } else if page.row_matches(in_page, k_row, v_row, self.bits) {
                // Still on the shared prefix: advance without writing.
            } else {
                // Divergence inside a shared page: copy-on-write split.
                // Release our reference *first* so the capacity
                // pre-check's guarantee holds (the old slot frees when
                // we were the last sharer).
                let (pk, pv, pchains) = {
                    let s = self.sessions.get_mut(&sid).unwrap();
                    s.pages[p] = None;
                    let lo = p * ps;
                    (
                        s.hist_k[lo * d..(lo + in_page) * d].to_vec(),
                        s.hist_v[lo * d..(lo + in_page) * d].to_vec(),
                        s.row_chains[lo..lo + in_page].to_vec(),
                    )
                };
                self.release(pid);
                let fresh = self.alloc_for(sid, evicted)?;
                for i in 0..in_page {
                    self.cache.get_mut(fresh).push(
                        &pk[i * d..(i + 1) * d],
                        &pv[i * d..(i + 1) * d],
                        self.bits,
                        self.cfg.predict_bits,
                    );
                    self.register_chain(pchains[i], fresh);
                }
                self.cache.stats.cow_splits += 1;
                self.sessions.get_mut(&sid).unwrap().pages[p] =
                    Some(PageRef { id: fresh, touch });
                write_to = Some(fresh);
            }
        }
        if let Some(pid) = write_to {
            self.cache.get_mut(pid).push(k_row, v_row, self.bits, self.cfg.predict_bits);
            self.register_chain(chain, pid);
        }
        let s = self.sessions.get_mut(&sid).unwrap();
        if let Some(r) = s.pages[p].as_mut() {
            r.touch = touch;
        }
        s.hist_k.extend_from_slice(k_row);
        s.hist_v.extend_from_slice(v_row);
        s.row_chains.push(chain);
        s.len += 1;
        Ok(())
    }

    fn alloc_for(&mut self, sid: u64, evicted: &mut Vec<u64>) -> crate::Result<PageId> {
        loop {
            if let Some(pid) = self.cache.alloc() {
                return Ok(pid);
            }
            match self.evict_one_page(sid) {
                Some(victim) => {
                    if !evicted.contains(&victim) {
                        evicted.push(victim);
                    }
                }
                None => anyhow::bail!(
                    "kv-cache capacity ({} pages of {} tokens) exhausted by session {sid} alone",
                    self.cfg.capacity_pages,
                    self.cfg.page_size
                ),
            }
        }
    }

    /// Drop the coldest page of the coldest *other* session: LRU session
    /// by `last_touch`, then (exclusively owned pages first, so a slot
    /// actually frees) the page least recently written, oldest first.
    /// Returns the victim session id; `None` when no other session has
    /// resident pages. Each call drops exactly one page reference, so
    /// the `alloc_for` loop always terminates.
    fn evict_one_page(&mut self, keep: u64) -> Option<u64> {
        let victim = self
            .sessions
            .iter()
            .filter(|(id, s)| **id != keep && s.pages.iter().any(|p| p.is_some()))
            .min_by_key(|(_, s)| s.last_touch)
            .map(|(id, _)| *id)?;
        let (idx, pid, was_fully_resident) = {
            let s = &self.sessions[&victim];
            let (idx, r) = s
                .pages
                .iter()
                .enumerate()
                .filter_map(|(i, p)| p.as_ref().map(|r| (i, r)))
                .min_by_key(|(i, r)| (self.cache.refcount(r.id) > 1, r.touch, *i))?;
            (idx, r.id, s.fully_resident())
        };
        self.sessions.get_mut(&victim).unwrap().pages[idx] = None;
        self.cache.stats.pages_evicted += 1;
        if was_fully_resident {
            self.cache.stats.sessions_evicted += 1;
        }
        self.release(pid);
        Some(victim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toks(n: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        (Mat::randn(n, d, 1.0, &mut rng), Mat::randn(n, d, 1.0, &mut rng))
    }

    fn store(page_size: usize, d: usize, cap: usize) -> SessionStore {
        SessionStore::new(SessionConfig::new(page_size, d, cap))
    }

    #[test]
    fn append_builds_pages_and_history() {
        let mut st = store(2, 4, 0);
        let (k, v) = toks(5, 4, 1);
        let mut ops = StageOps::default();
        let out = st.append(7, &k, &v, &mut ops).unwrap();
        assert_eq!(out.start, 0);
        assert_eq!(st.len(7), 5);
        assert_eq!(st.resident_pages(), 3, "5 tokens / page_size 2");
        let pages = st.pages_of(7);
        assert_eq!(pages[2].len(), 1, "last page partially filled");
        assert_eq!(pages[1].k_row(0), k.row(2));
        // Second append continues at position 5.
        let (k2, v2) = toks(1, 4, 2);
        let out2 = st.append(7, &k2, &v2, &mut ops).unwrap();
        assert_eq!(out2.start, 5);
        assert_eq!(st.pages_of(7)[2].len(), 2);
    }

    #[test]
    fn lru_eviction_and_rematerialization_round_trip() {
        // Pool of 2 pages × 2 tokens; two sessions cannot both stay.
        let mut st = store(2, 4, 2);
        let mut ops = StageOps::default();
        let (ka, va) = toks(3, 4, 3);
        st.append(1, &ka, &va, &mut ops).unwrap(); // fills the pool (2 pages)
        let (kb, vb) = toks(2, 4, 4);
        let out = st.append(2, &kb, &vb, &mut ops).unwrap();
        assert_eq!(out.evicted_sessions, vec![1], "LRU victim is session 1");
        assert!(!st.is_resident(1), "session 1 lost its coldest page");
        assert!(st.is_resident(2));
        assert_eq!(st.len(1), 3, "history survives eviction");
        // Page-granular: only page 0 was needed, page 1 stayed resident.
        assert_eq!(st.stats().pages_evicted, 1);
        // Touching session 1 again re-materializes *only the missing
        // page*, bit-identical (evicting session 2 in turn), and the new
        // token extends the surviving page.
        let (k1, v1) = toks(1, 4, 5);
        let out = st.append(1, &k1, &v1, &mut ops).unwrap();
        assert_eq!(out.rematerialized_pages, 1, "only the evicted page rebuilds");
        assert_eq!(out.rematerialized_tokens, 2);
        assert_eq!(out.evicted_sessions, vec![2]);
        assert_eq!(st.len(1), 4);
        let pages = st.pages_of(1);
        assert_eq!(pages[0].k_row(1), ka.row(1));
        assert_eq!(pages[0].qk_row(1).len(), 4);
        assert_eq!(pages[1].k_row(1), k1.row(0), "appended token lands after history");
        let stats = st.stats();
        assert_eq!(stats.sessions_evicted, 2, "both sessions broke full residency once");
        assert!(stats.pages_rematerialized >= 1);
    }

    #[test]
    fn single_session_over_capacity_errors_atomically() {
        let mut st = store(2, 4, 2);
        let mut ops = StageOps::default();
        let (k, v) = toks(5, 4, 6); // needs 3 pages, pool holds 2
        let err = st.append(1, &k, &v, &mut ops).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
        // The failed append left no state behind: a retry with a smaller
        // chunk starts from scratch instead of duplicating context.
        assert_eq!(st.len(1), 0);
        assert_eq!(st.resident_pages(), 0);
        let (k2, v2) = toks(4, 4, 7);
        let out = st.append(1, &k2, &v2, &mut ops).unwrap();
        assert_eq!(out.start, 0);
        assert_eq!(st.len(1), 4);
    }

    #[test]
    fn gather_reads_back_exact_rows() {
        let mut st = store(3, 8, 0);
        let mut ops = StageOps::default();
        let (k, v) = toks(10, 8, 7);
        st.append(4, &k, &v, &mut ops).unwrap();
        let keys = [0usize, 3, 4, 9];
        let (gk, gv) = st.gather(4, &keys);
        for (i, &key) in keys.iter().enumerate() {
            assert_eq!(gk.row(i), k.row(key));
            assert_eq!(gv.row(i), v.row(key));
        }
    }

    #[test]
    fn remove_frees_pool_space() {
        let mut st = store(2, 4, 2);
        let mut ops = StageOps::default();
        let (k, v) = toks(4, 4, 8);
        st.append(1, &k, &v, &mut ops).unwrap();
        st.remove(1);
        assert!(!st.contains(1));
        assert_eq!(st.resident_pages(), 0);
        // The freed pool accepts a new session without eviction.
        let (k2, v2) = toks(4, 4, 9);
        let out = st.append(2, &k2, &v2, &mut ops).unwrap();
        assert!(out.evicted_sessions.is_empty());
    }

    #[test]
    fn common_prefix_shares_pages_until_divergence() {
        // 8 tokens of shared prompt (2 full pages of 4), then each
        // session takes its own continuation.
        let mut st = store(4, 8, 0);
        let mut ops = StageOps::default();
        let (kp, vp) = toks(8, 8, 10);
        st.append(1, &kp, &vp, &mut ops).unwrap();
        assert_eq!(st.resident_pages(), 2);
        st.append(2, &kp, &vp, &mut ops).unwrap();
        assert_eq!(st.resident_pages(), 2, "identical prefix attaches, no copies");
        assert_eq!(st.stats().pages_shared, 2);
        let snap = st.residency();
        assert_eq!(snap.shared_pages, 2);
        assert_eq!(snap.logical_tokens, 16);
        // Both sessions read the same bits back.
        for sid in [1, 2] {
            let (gk, gv) = st.gather(sid, &[0, 3, 7]);
            assert_eq!(gk.row(0), kp.row(0));
            assert_eq!(gk.row(2), kp.row(7));
            assert_eq!(gv.row(1), vp.row(3));
        }
        // Divergent continuations land in private pages.
        let (k1, v1) = toks(4, 8, 11);
        let (k2, v2) = toks(4, 8, 12);
        st.append(1, &k1, &v1, &mut ops).unwrap();
        st.append(2, &k2, &v2, &mut ops).unwrap();
        assert_eq!(st.resident_pages(), 4, "2 shared + 2 private continuation pages");
        assert_eq!(st.gather(1, &[8]).0.row(0), k1.row(0));
        assert_eq!(st.gather(2, &[8]).0.row(0), k2.row(0));
        assert_eq!(st.stats().cow_splits, 0, "divergence at a page boundary needs no split");
    }

    #[test]
    fn divergence_inside_shared_page_splits_copy_on_write() {
        let mut st = store(4, 8, 0);
        let mut ops = StageOps::default();
        let (kp, vp) = toks(6, 8, 13); // 1.5 pages of shared prompt
        st.append(1, &kp, &vp, &mut ops).unwrap();
        st.append(2, &kp, &vp, &mut ops).unwrap();
        assert_eq!(st.resident_pages(), 2, "partial tail page shared too");
        // Session 2 is at the shared page's frontier: its divergent
        // token extends the page in place (session 1's reads are capped
        // by its own length, so the extra row is invisible to it).
        let (k2, v2) = toks(1, 8, 14);
        st.append(2, &k2, &v2, &mut ops).unwrap();
        assert_eq!(st.stats().cow_splits, 0, "the frontier never splits");
        assert_eq!(st.resident_pages(), 2);
        assert_eq!(st.gather(1, &[5]).0.row(0), kp.row(5));
        assert_eq!(st.gather(2, &[4]).0.row(0), kp.row(4));
        assert_eq!(st.gather(2, &[6]).0.row(0), k2.row(0));
        // Session 1 now appends its *own* continuation, diverging from
        // what session 2 wrote at that slot: copy-on-write split — rows
        // [4,6) are rebuilt into a private page and the fork lands there.
        let (k1, v1) = toks(2, 8, 15);
        st.append(1, &k1, &v1, &mut ops).unwrap();
        assert_eq!(st.stats().cow_splits, 1, "the laggard splits on divergence");
        assert_eq!(st.resident_pages(), 3);
        assert_eq!(st.gather(1, &[4]).0.row(0), kp.row(4), "pre-fork rows copied");
        assert_eq!(st.gather(1, &[6]).0.row(0), k1.row(0));
        assert_eq!(st.gather(2, &[6]).0.row(0), k2.row(0), "session 2 unaffected");
    }

    #[test]
    fn shared_pages_survive_until_last_sharer_leaves() {
        let mut st = store(4, 8, 0);
        let mut ops = StageOps::default();
        let (kp, vp) = toks(4, 8, 16);
        for sid in 1..=3 {
            st.append(sid, &kp, &vp, &mut ops).unwrap();
        }
        assert_eq!(st.resident_pages(), 1, "three sessions, one physical page");
        st.remove(1);
        st.remove(2);
        assert_eq!(st.resident_pages(), 1, "last sharer keeps the page");
        assert_eq!(st.gather(3, &[0]).0.row(0), kp.row(0));
        st.remove(3);
        assert_eq!(st.resident_pages(), 0, "refcounts drain to an empty pool");
        assert_eq!(st.residency().resident_bytes, 0);
    }

    #[test]
    fn quantized_only_residency_shrinks_resident_bytes() {
        let (k, v) = toks(32, 16, 17);
        let mut ops = StageOps::default();
        let mut exact = store(8, 16, 0);
        exact.append(1, &k, &v, &mut ops).unwrap();
        let mut quant = SessionStore::new(
            SessionConfig::new(8, 16, 0).with_residency(ResidencyMode::QuantizedOnly),
        );
        quant.append(1, &k, &v, &mut ops).unwrap();
        let (eb, qb) = (exact.residency().resident_bytes, quant.residency().resident_bytes);
        assert!(eb >= 3 * qb, "exact {eb} vs quantized {qb}");
        // Dequantized gathers stay within one quantization step per
        // element; the frozen scales bound the error.
        let (gk, gv) = quant.gather(1, &[0, 15, 31]);
        for (i, &key) in [0usize, 15, 31].iter().enumerate() {
            let page = &quant.pages_of(1)[key / 8];
            let (ks, vs) = (page.k_scale(key % 8), page.v_scale(key % 8));
            for (a, b) in gk.row(i).iter().zip(k.row(key)) {
                assert!((a - b).abs() <= ks, "{a} vs {b}");
            }
            for (a, b) in gv.row(i).iter().zip(v.row(key)) {
                assert!((a - b).abs() <= vs, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn evicted_shared_page_reattaches_from_registry() {
        // Pool of 3: sessions 1 and 2 share one prompt page; filling the
        // pool evicts session 1's reference, but the page itself stays
        // resident (session 2 still holds it), so session 1's next
        // append re-attaches for free instead of rebuilding.
        let mut st = store(2, 4, 3);
        let mut ops = StageOps::default();
        let (kp, vp) = toks(2, 4, 18);
        st.append(1, &kp, &vp, &mut ops).unwrap();
        st.append(2, &kp, &vp, &mut ops).unwrap();
        assert_eq!(st.resident_pages(), 1);
        // Session 3 needs 3 pages: evicts 1's and 2's references.
        let (k3, v3) = toks(6, 4, 19);
        let out = st.append(3, &k3, &v3, &mut ops).unwrap();
        assert_eq!(out.evicted_sessions, vec![1, 2]);
        assert_eq!(st.resident_pages(), 3);
        st.remove(3);
        let shared_before = st.stats().pages_shared;
        let (k1, v1) = toks(1, 4, 20);
        let out = st.append(1, &k1, &v1, &mut ops).unwrap();
        // The prompt page was gone for real (both refs dropped), so this
        // rebuild is genuine…
        assert_eq!(out.rematerialized_pages, 1);
        // …and session 2 now re-attaches to session 1's rebuilt page.
        let (k2, v2) = toks(1, 4, 21);
        let out = st.append(2, &k2, &v2, &mut ops).unwrap();
        assert_eq!(out.rematerialized_pages, 0, "registry re-attach, no rebuild");
        assert!(st.stats().pages_shared > shared_before);
        assert_eq!(st.gather(2, &[0]).0.row(0), kp.row(0));
        assert_eq!(st.gather(2, &[2]).0.row(0), k2.row(0));
    }
}
