//! KV pages and the block-granular page pool.
//!
//! A [`KvPage`] holds up to `page_size` tokens' K/V rows **plus the
//! cached prediction metadata** for those keys: each K row quantized with
//! its own per-row scale at append time (see
//! [`crate::arith::quantize_row`]). Freezing the operand per row is what
//! makes cached prediction bit-identical to re-running a full prefill —
//! a row's quantization never depends on tokens appended later.
//!
//! The [`PagedKvCache`] is the pool: fixed-capacity slots with a free
//! list and capacity accounting. *Which* pages belong to which session —
//! and who gets evicted — is the [`super::session::SessionStore`]'s job;
//! the pool only allocates, frees and counts.

use crate::arith::{quantize_row, IntBits, LzCode};

/// Index of a page slot in the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PageId(pub usize);

/// One fixed-capacity KV page plus cached predict metadata.
#[derive(Clone, Debug)]
pub struct KvPage {
    capacity: usize,
    d: usize,
    len: usize,
    /// K rows, row-major `[len, d]` within a `capacity × d` budget.
    k: Vec<f32>,
    /// V rows, row-major `[len, d]`.
    v: Vec<f32>,
    /// Cached predict operands: per-row quantized K values (`[len, d]`).
    qk: Vec<i32>,
    /// LZ codes of `qk` (`[len, d]`), frozen at append — read by the
    /// SLZS scheme so decode never re-encodes cached keys.
    k_codes: Vec<LzCode>,
    /// Per-row quantization scales, frozen at append.
    k_scales: Vec<f32>,
}

impl KvPage {
    /// An empty page for `capacity` tokens of head dimension `d`.
    pub fn new(capacity: usize, d: usize) -> KvPage {
        assert!(capacity > 0 && d > 0, "page must have positive capacity and head dim");
        KvPage {
            capacity,
            d,
            len: 0,
            k: Vec::with_capacity(capacity * d),
            v: Vec::with_capacity(capacity * d),
            qk: Vec::with_capacity(capacity * d),
            k_codes: Vec::with_capacity(capacity * d),
            k_scales: Vec::with_capacity(capacity),
        }
    }

    /// Tokens currently stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the page holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether another token would not fit.
    pub fn is_full(&self) -> bool {
        self.len == self.capacity
    }

    /// Maximum tokens per page.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Head dimension of the cached rows.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Append one token's K/V rows and freeze its prediction metadata:
    /// the row quantized at `bits` with its own scale, plus the LZ codes
    /// of the quantized values at magnitude bitwidth `w`.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32], bits: IntBits, w: u32) {
        assert!(!self.is_full(), "push into a full page");
        assert_eq!(k_row.len(), self.d);
        assert_eq!(v_row.len(), self.d);
        self.k.extend_from_slice(k_row);
        self.v.extend_from_slice(v_row);
        let (q, scale) = quantize_row(k_row, bits);
        self.k_codes.extend(q.iter().map(|&x| LzCode::encode(x, w)));
        self.qk.extend(q);
        self.k_scales.push(scale);
        self.len += 1;
    }

    /// The f32 K row at in-page index `i`.
    pub fn k_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        &self.k[i * self.d..(i + 1) * self.d]
    }

    /// The f32 V row at in-page index `i`.
    pub fn v_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        &self.v[i * self.d..(i + 1) * self.d]
    }

    /// The cached quantized K operand of row `i`.
    pub fn qk_row(&self, i: usize) -> &[i32] {
        debug_assert!(i < self.len);
        &self.qk[i * self.d..(i + 1) * self.d]
    }

    /// The frozen LZ codes of row `i`'s quantized K operand.
    pub fn k_codes_row(&self, i: usize) -> &[LzCode] {
        debug_assert!(i < self.len);
        &self.k_codes[i * self.d..(i + 1) * self.d]
    }

    /// The frozen per-row quantization scale of row `i`.
    pub fn k_scale(&self, i: usize) -> f32 {
        self.k_scales[i]
    }

    fn reset(&mut self, capacity: usize, d: usize) {
        self.capacity = capacity;
        self.d = d;
        self.len = 0;
        self.k.clear();
        self.v.clear();
        self.qk.clear();
        self.k_codes.clear();
        self.k_scales.clear();
    }
}

/// Gather the K/V rows of the given (sorted, absolute) key indices from
/// a session's pages (append order, `page_size`-token pages) into
/// compact matrices — the formal stage's cache read. Shared by
/// [`super::session::SessionStore::gather`] and the decode executor.
pub fn gather_rows(
    pages: &[&KvPage],
    page_size: usize,
    keys: &[usize],
    d: usize,
) -> (crate::tensor::Mat, crate::tensor::Mat) {
    use crate::tensor::Mat;
    let mut k = Mat::zeros(0, 0);
    let mut v = Mat::zeros(0, 0);
    gather_rows_into(pages, page_size, keys, d, &mut k, &mut v);
    (k, v)
}

/// [`gather_rows`] writing into caller-provided staging buffers (which
/// are [`crate::tensor::Mat::reset`] to `keys.len() × d` — no allocation
/// once they have the capacity). This is the only cache-read gather; the
/// allocating entry point wraps it.
pub fn gather_rows_into(
    pages: &[&KvPage],
    page_size: usize,
    keys: &[usize],
    d: usize,
    k: &mut crate::tensor::Mat,
    v: &mut crate::tensor::Mat,
) {
    k.reset(keys.len(), d);
    v.reset(keys.len(), d);
    for (i, &key) in keys.iter().enumerate() {
        let page = pages[key / page_size];
        k.row_mut(i).copy_from_slice(page.k_row(key % page_size));
        v.row_mut(i).copy_from_slice(page.v_row(key % page_size));
    }
}

/// Lifetime counters of a page pool / session store.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Tokens appended across all sessions.
    pub appended_tokens: u64,
    /// Pages handed out (fresh allocations and reused free slots).
    pub pages_allocated: u64,
    /// Pages reclaimed by LRU session eviction.
    pub pages_evicted: u64,
    /// Whole-session evictions.
    pub sessions_evicted: u64,
    /// Pages rebuilt from session history after an eviction.
    pub pages_rematerialized: u64,
    /// Resident pages served to decode formal-compute reads (cache hits).
    pub page_hits: u64,
}

/// Block-granular page pool with capacity accounting.
#[derive(Clone, Debug)]
pub struct PagedKvCache {
    page_size: usize,
    d: usize,
    /// Maximum resident pages (0 = unbounded).
    capacity_pages: usize,
    slots: Vec<KvPage>,
    /// Slot indices available for reuse.
    free: Vec<usize>,
    /// Lifetime counters (allocations, evictions, hits…).
    pub stats: CacheStats,
}

impl PagedKvCache {
    /// An empty pool of `capacity_pages` pages (0 = unbounded), each
    /// holding `page_size` tokens of head dimension `d`.
    pub fn new(page_size: usize, d: usize, capacity_pages: usize) -> PagedKvCache {
        assert!(page_size > 0 && d > 0, "page_size and d must be positive");
        PagedKvCache {
            page_size,
            d,
            capacity_pages,
            slots: Vec::new(),
            free: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Head dimension of the cached rows.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Resident (allocated, not freed) pages.
    pub fn resident_pages(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Maximum resident pages (0 = unbounded).
    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    /// Whether one more page can be allocated without eviction.
    pub fn has_room(&self) -> bool {
        self.capacity_pages == 0 || self.resident_pages() < self.capacity_pages
    }

    /// Allocate an empty page; `None` when at capacity (the caller must
    /// evict first).
    pub fn alloc(&mut self) -> Option<PageId> {
        if !self.has_room() {
            return None;
        }
        self.stats.pages_allocated += 1;
        if let Some(slot) = self.free.pop() {
            let (ps, d) = (self.page_size, self.d);
            self.slots[slot].reset(ps, d);
            Some(PageId(slot))
        } else {
            self.slots.push(KvPage::new(self.page_size, self.d));
            Some(PageId(self.slots.len() - 1))
        }
    }

    /// Return a page to the free list.
    pub fn free_page(&mut self, id: PageId) {
        debug_assert!(!self.free.contains(&id.0), "double free of page {}", id.0);
        self.free.push(id.0);
    }

    /// Read a page by id.
    pub fn get(&self, id: PageId) -> &KvPage {
        &self.slots[id.0]
    }

    /// Mutate a page by id (append path).
    pub fn get_mut(&mut self, id: PageId) -> &mut KvPage {
        &mut self.slots[id.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_push_and_read_back() {
        let mut p = KvPage::new(4, 3);
        p.push(&[1.0, -2.0, 0.5], &[0.1, 0.2, 0.3], IntBits::Int8, 7);
        p.push(&[0.0, 0.0, 0.0], &[1.0, 1.0, 1.0], IntBits::Int8, 7);
        assert_eq!(p.len(), 2);
        assert!(!p.is_full());
        assert_eq!(p.k_row(0), &[1.0, -2.0, 0.5]);
        assert_eq!(p.v_row(1), &[1.0, 1.0, 1.0]);
        // Zero row: quantizes to zeros with a finite scale; codes carry
        // the zero sentinel.
        assert!(p.qk_row(1).iter().all(|&q| q == 0));
        assert!(p.k_codes_row(1).iter().all(|c| c.is_zero()));
        assert!(p.k_scale(1).is_finite());
    }

    #[test]
    fn metadata_is_frozen_per_row() {
        // The quantized operand of row 0 must not change when row 1 (with
        // a much larger magnitude) arrives — the decode-parity invariant.
        let mut p = KvPage::new(2, 2);
        p.push(&[1.0, 0.5], &[0.0, 0.0], IntBits::Int8, 7);
        let before: Vec<i32> = p.qk_row(0).to_vec();
        let codes_before: Vec<LzCode> = p.k_codes_row(0).to_vec();
        let scale_before = p.k_scale(0);
        p.push(&[100.0, -50.0], &[0.0, 0.0], IntBits::Int8, 7);
        assert_eq!(p.qk_row(0), &before[..]);
        assert_eq!(p.k_codes_row(0), &codes_before[..]);
        assert_eq!(p.k_scale(0), scale_before);
    }

    #[test]
    fn pool_capacity_accounting() {
        let mut pool = PagedKvCache::new(8, 4, 2);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert_eq!(pool.resident_pages(), 2);
        assert!(pool.alloc().is_none(), "at capacity");
        pool.free_page(a);
        assert_eq!(pool.resident_pages(), 1);
        let c = pool.alloc().expect("freed slot reusable");
        assert_eq!(c, a, "free list reuses slots");
        assert!(pool.get(c).is_empty(), "reused page starts empty");
        assert_eq!(pool.stats.pages_allocated, 3);
    }

    #[test]
    fn unbounded_pool_never_refuses() {
        let mut pool = PagedKvCache::new(4, 2, 0);
        for _ in 0..64 {
            assert!(pool.alloc().is_some());
        }
        assert_eq!(pool.resident_pages(), 64);
    }
}
