//! KV pages and the block-granular, refcounted page pool.
//!
//! A [`KvPage`] holds up to `page_size` tokens' K/V state **plus the
//! cached prediction metadata** for those keys: each K row quantized with
//! its own per-row scale at append time (see
//! [`crate::arith::quantize_row`]). Freezing the operand per row is what
//! makes cached prediction bit-identical to re-running a full prefill —
//! a row's quantization never depends on tokens appended later.
//!
//! Pages come in two [`ResidencyMode`]s:
//!
//! * [`ResidencyMode::Exact`] (the default serving path) keeps the f32
//!   K/V rows resident next to the quantized operands. Gather reads are
//!   bit-exact copies; decode parity holds to the bit.
//! * [`ResidencyMode::QuantizedOnly`] drops the f32 rows: resident state
//!   is the per-row quantized K *and* V (`i8`, valid whenever the
//!   predict bitwidth fits 8 magnitude bits) plus their scales. Stages
//!   1–2 read the identical integer operands, so **selection is
//!   bit-identical across modes**; only the stage 3–4 gather dequantizes
//!   (`k̂ = q · scale`), which is lossy and therefore opt-in.
//!
//! The [`PagedKvCache`] is the pool: fixed-capacity slots with a free
//! list, **per-slot refcounts** (copy-on-write prefix sharing holds one
//! reference per sharing session) and capacity accounting. *Which* pages
//! belong to which session — and which page gets evicted — is the
//! [`super::session::SessionStore`]'s job; the pool only allocates,
//! retains, releases and counts.

use crate::arith::{quantize_row, IntBits, LzCode};

/// Index of a page slot in the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PageId(pub usize);

/// What a resident page physically stores.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ResidencyMode {
    /// f32 K/V rows resident next to the frozen quantized operands.
    /// Gathers are bit-exact; this is the default serving path.
    #[default]
    Exact,
    /// Only the per-row quantized operands (K *and* V as `i8` + scales)
    /// stay resident; gathers dequantize on demand. ~`4d/(d+4)`× fewer
    /// resident bytes per token, lossy at stage 3–4 only — selection
    /// stays bit-identical because stages 1–2 already read the same
    /// integers. Requires the predict bitwidth to fit 8 magnitude bits.
    QuantizedOnly,
}

/// One fixed-capacity KV page plus cached predict metadata.
#[derive(Clone, Debug)]
pub struct KvPage {
    capacity: usize,
    d: usize,
    len: usize,
    mode: ResidencyMode,
    /// Whether frozen LZ codes are stored (always in [`ResidencyMode::Exact`];
    /// only for the SLZS predictor in quantized-only mode, which is the
    /// one consumer of [`KvPage::k_codes_row`] on the decode path).
    store_codes: bool,
    /// K rows, row-major `[len, d]` — empty in quantized-only mode.
    k: Vec<f32>,
    /// V rows, row-major `[len, d]` — empty in quantized-only mode.
    v: Vec<f32>,
    /// Cached predict operands: per-row quantized K values (`[len, d]`)
    /// — empty in quantized-only mode (see `qk8`).
    qk: Vec<i32>,
    /// Quantized-only resident K operands (`[len, d]`, i8).
    qk8: Vec<i8>,
    /// Quantized-only resident V rows (`[len, d]`, i8).
    qv8: Vec<i8>,
    /// LZ codes of the quantized K (`[len, d]`), frozen at append — read
    /// by the SLZS scheme so decode never re-encodes cached keys.
    k_codes: Vec<LzCode>,
    /// Per-row K quantization scales, frozen at append.
    k_scales: Vec<f32>,
    /// Per-row V quantization scales (quantized-only mode).
    v_scales: Vec<f32>,
}

impl KvPage {
    /// An empty [`ResidencyMode::Exact`] page for `capacity` tokens of
    /// head dimension `d`.
    pub fn new(capacity: usize, d: usize) -> KvPage {
        KvPage::with_mode(capacity, d, ResidencyMode::Exact, true)
    }

    /// An empty page with an explicit residency mode. `store_codes`
    /// keeps the frozen LZ codes resident (ignored — always on — in
    /// exact mode, where the codes are part of the frozen operand set).
    pub fn with_mode(
        capacity: usize,
        d: usize,
        mode: ResidencyMode,
        store_codes: bool,
    ) -> KvPage {
        assert!(capacity > 0 && d > 0, "page must have positive capacity and head dim");
        let exact = mode == ResidencyMode::Exact;
        let store_codes = exact || store_codes;
        let fcap = if exact { capacity * d } else { 0 };
        let qcap = if exact { 0 } else { capacity * d };
        KvPage {
            capacity,
            d,
            len: 0,
            mode,
            store_codes,
            k: Vec::with_capacity(fcap),
            v: Vec::with_capacity(fcap),
            qk: Vec::with_capacity(fcap),
            qk8: Vec::with_capacity(qcap),
            qv8: Vec::with_capacity(qcap),
            k_codes: Vec::with_capacity(if store_codes { capacity * d } else { 0 }),
            k_scales: Vec::with_capacity(capacity),
            v_scales: Vec::with_capacity(qcap.min(capacity)),
        }
    }

    /// Tokens currently stored.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the page holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether another token would not fit.
    pub fn is_full(&self) -> bool {
        self.len == self.capacity
    }

    /// Maximum tokens per page.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Head dimension of the cached rows.
    pub fn d(&self) -> usize {
        self.d
    }

    /// What this page keeps resident.
    pub fn mode(&self) -> ResidencyMode {
        self.mode
    }

    /// Append one token's K/V rows and freeze its prediction metadata:
    /// the row quantized at `bits` with its own scale, plus the LZ codes
    /// of the quantized values at magnitude bitwidth `w`.
    ///
    /// In quantized-only mode the f32 rows are *not* kept: K and V are
    /// each quantized per row (same scheme as the predict operand), and
    /// `bits` must fit `i8` — enforced by the session store at
    /// construction, debug-asserted here.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32], bits: IntBits, w: u32) {
        assert!(!self.is_full(), "push into a full page");
        assert_eq!(k_row.len(), self.d);
        assert_eq!(v_row.len(), self.d);
        let (q, scale) = quantize_row(k_row, bits);
        if self.store_codes {
            self.k_codes.extend(q.iter().map(|&x| LzCode::encode(x, w)));
        }
        match self.mode {
            ResidencyMode::Exact => {
                self.k.extend_from_slice(k_row);
                self.v.extend_from_slice(v_row);
                self.qk.extend(q);
            }
            ResidencyMode::QuantizedOnly => {
                debug_assert!(
                    q.iter().all(|&x| (-128..=127).contains(&x)),
                    "quantized-only residency needs operands that fit i8"
                );
                self.qk8.extend(q.iter().map(|&x| x as i8));
                let (qv, v_scale) = quantize_row(v_row, bits);
                self.qv8.extend(qv.iter().map(|&x| x as i8));
                self.v_scales.push(v_scale);
            }
        }
        self.k_scales.push(scale);
        self.len += 1;
    }

    /// The f32 K row at in-page index `i` (exact mode only).
    pub fn k_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        debug_assert_eq!(self.mode, ResidencyMode::Exact, "no f32 K resident");
        &self.k[i * self.d..(i + 1) * self.d]
    }

    /// The f32 V row at in-page index `i` (exact mode only).
    pub fn v_row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len);
        debug_assert_eq!(self.mode, ResidencyMode::Exact, "no f32 V resident");
        &self.v[i * self.d..(i + 1) * self.d]
    }

    /// The cached quantized K operand of row `i` (exact mode only —
    /// quantized-only pages store the same integers as `i8`, see
    /// [`KvPage::qk8_row`]).
    pub fn qk_row(&self, i: usize) -> &[i32] {
        debug_assert!(i < self.len);
        debug_assert_eq!(self.mode, ResidencyMode::Exact, "use qk8_row");
        &self.qk[i * self.d..(i + 1) * self.d]
    }

    /// The cached quantized K operand of row `i` as `i8`
    /// (quantized-only mode). Widening to `i32` recovers exactly the
    /// integers [`KvPage::qk_row`] would hold — scores are bit-identical
    /// across modes.
    pub fn qk8_row(&self, i: usize) -> &[i8] {
        debug_assert!(i < self.len);
        debug_assert_eq!(self.mode, ResidencyMode::QuantizedOnly, "use qk_row");
        &self.qk8[i * self.d..(i + 1) * self.d]
    }

    /// The frozen LZ codes of row `i`'s quantized K operand.
    pub fn k_codes_row(&self, i: usize) -> &[LzCode] {
        debug_assert!(i < self.len);
        debug_assert!(self.store_codes, "codes not resident on this page");
        &self.k_codes[i * self.d..(i + 1) * self.d]
    }

    /// The frozen per-row K quantization scale of row `i`.
    pub fn k_scale(&self, i: usize) -> f32 {
        self.k_scales[i]
    }

    /// The frozen per-row V quantization scale of row `i`
    /// (quantized-only mode).
    pub fn v_scale(&self, i: usize) -> f32 {
        debug_assert_eq!(self.mode, ResidencyMode::QuantizedOnly);
        self.v_scales[i]
    }

    /// Copy (exact) or dequantize (quantized-only) the K row at in-page
    /// index `i` into `dst` — the gather read. No allocation.
    pub fn copy_k_into(&self, i: usize, dst: &mut [f32]) {
        debug_assert!(i < self.len);
        debug_assert_eq!(dst.len(), self.d);
        match self.mode {
            ResidencyMode::Exact => dst.copy_from_slice(self.k_row(i)),
            ResidencyMode::QuantizedOnly => {
                let scale = self.k_scales[i];
                let q = &self.qk8[i * self.d..(i + 1) * self.d];
                for (o, &x) in dst.iter_mut().zip(q) {
                    *o = x as f32 * scale;
                }
            }
        }
    }

    /// Copy (exact) or dequantize (quantized-only) the V row at in-page
    /// index `i` into `dst` — the gather read. No allocation.
    pub fn copy_v_into(&self, i: usize, dst: &mut [f32]) {
        debug_assert!(i < self.len);
        debug_assert_eq!(dst.len(), self.d);
        match self.mode {
            ResidencyMode::Exact => dst.copy_from_slice(self.v_row(i)),
            ResidencyMode::QuantizedOnly => {
                let scale = self.v_scales[i];
                let q = &self.qv8[i * self.d..(i + 1) * self.d];
                for (o, &x) in dst.iter_mut().zip(q) {
                    *o = x as f32 * scale;
                }
            }
        }
    }

    /// Whether rows `[0, rows)` of this page hold exactly the given
    /// history slice — the content check behind prefix share-attach and
    /// the non-divergent fast path of copy-on-write. In exact mode the
    /// comparison is bitwise on the f32 rows; in quantized-only mode it
    /// compares what is actually resident (re-quantizing the candidate
    /// rows), so a "false share" can only equate rows whose resident
    /// state — everything decode ever reads — is already identical.
    pub fn prefix_matches(&self, rows: usize, hist_k: &[f32], hist_v: &[f32], bits: IntBits) -> bool {
        if rows > self.len {
            return false;
        }
        debug_assert_eq!(hist_k.len(), rows * self.d);
        debug_assert_eq!(hist_v.len(), rows * self.d);
        for i in 0..rows {
            if !self.row_matches(i, &hist_k[i * self.d..(i + 1) * self.d], &hist_v[i * self.d..(i + 1) * self.d], bits)
            {
                return false;
            }
        }
        true
    }

    /// [`KvPage::prefix_matches`] for a single row.
    pub fn row_matches(&self, i: usize, k_row: &[f32], v_row: &[f32], bits: IntBits) -> bool {
        debug_assert!(i < self.len);
        match self.mode {
            ResidencyMode::Exact => self.k_row(i) == k_row && self.v_row(i) == v_row,
            ResidencyMode::QuantizedOnly => {
                let (qk, ks) = quantize_row(k_row, bits);
                if ks.to_bits() != self.k_scales[i].to_bits() {
                    return false;
                }
                let mine = &self.qk8[i * self.d..(i + 1) * self.d];
                if !qk.iter().zip(mine).all(|(&a, &b)| a == b as i32) {
                    return false;
                }
                let (qv, vs) = quantize_row(v_row, bits);
                if vs.to_bits() != self.v_scales[i].to_bits() {
                    return false;
                }
                let mine = &self.qv8[i * self.d..(i + 1) * self.d];
                qv.iter().zip(mine).all(|(&a, &b)| a == b as i32)
            }
        }
    }

    /// Measured heap bytes this page keeps resident for its current
    /// `len` tokens (payload vectors only; the modeled-vs-measured gap —
    /// e.g. [`LzCode`] is 12 in-memory bytes for a ~4-bit code — is
    /// documented in DESIGN.md §13).
    pub fn resident_bytes(&self) -> usize {
        self.k.len() * 4
            + self.v.len() * 4
            + self.qk.len() * 4
            + self.qk8.len()
            + self.qv8.len()
            + self.k_codes.len() * std::mem::size_of::<LzCode>()
            + self.k_scales.len() * 4
            + self.v_scales.len() * 4
    }

    /// Bytes a gather read actually moves per row in this page's mode:
    /// `8d` f32 in exact mode, `2d + 8` (two i8 operands + two scales)
    /// in quantized-only mode. Keeps the measured traffic byte-exact
    /// against the reconciliation gate on the default path.
    pub fn gather_row_bytes(&self) -> usize {
        match self.mode {
            ResidencyMode::Exact => 8 * self.d,
            ResidencyMode::QuantizedOnly => 2 * self.d + 8,
        }
    }

    fn reset(&mut self, capacity: usize, d: usize, mode: ResidencyMode, store_codes: bool) {
        self.capacity = capacity;
        self.d = d;
        self.len = 0;
        self.mode = mode;
        self.store_codes = mode == ResidencyMode::Exact || store_codes;
        self.k.clear();
        self.v.clear();
        self.qk.clear();
        self.qk8.clear();
        self.qv8.clear();
        self.k_codes.clear();
        self.k_scales.clear();
        self.v_scales.clear();
    }
}

/// Gather the K/V rows of the given (sorted, absolute) key indices from
/// a session's pages (append order, `page_size`-token pages) into
/// compact matrices — the formal stage's cache read. Shared by
/// [`super::session::SessionStore::gather`] and the decode executor.
pub fn gather_rows(
    pages: &[&KvPage],
    page_size: usize,
    keys: &[usize],
    d: usize,
) -> (crate::tensor::Mat, crate::tensor::Mat) {
    use crate::tensor::Mat;
    let mut k = Mat::zeros(0, 0);
    let mut v = Mat::zeros(0, 0);
    gather_rows_into(pages, page_size, keys, d, &mut k, &mut v);
    (k, v)
}

/// [`gather_rows`] writing into caller-provided staging buffers (which
/// are [`crate::tensor::Mat::reset`] to `keys.len() × d` — no allocation
/// once they have the capacity). This is the only cache-read gather; the
/// allocating entry point wraps it. Each page copies (exact) or
/// dequantizes (quantized-only) per its own residency mode.
pub fn gather_rows_into(
    pages: &[&KvPage],
    page_size: usize,
    keys: &[usize],
    d: usize,
    k: &mut crate::tensor::Mat,
    v: &mut crate::tensor::Mat,
) {
    k.reset(keys.len(), d);
    v.reset(keys.len(), d);
    for (i, &key) in keys.iter().enumerate() {
        let page = pages[key / page_size];
        page.copy_k_into(key % page_size, k.row_mut(i));
        page.copy_v_into(key % page_size, v.row_mut(i));
    }
}

/// Lifetime counters of a page pool / session store.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    /// Tokens appended across all sessions.
    pub appended_tokens: u64,
    /// Pages handed out (fresh allocations and reused free slots).
    pub pages_allocated: u64,
    /// Page references dropped by eviction (page-granular: one count per
    /// page reference an eviction takes, whether or not the slot frees).
    pub pages_evicted: u64,
    /// Sessions whose residency an eviction broke: counted when a
    /// **fully resident** session loses its first page. The old
    /// whole-session-LRU semantics are a special case (losing any page
    /// used to mean losing them all), so readers of the per-session
    /// counter keep working.
    pub sessions_evicted: u64,
    /// Pages rebuilt from session history after an eviction.
    pub pages_rematerialized: u64,
    /// Resident pages served to decode formal-compute reads (cache hits).
    pub page_hits: u64,
    /// Prefix share-attaches: a session mapped an existing page instead
    /// of building its own (each adds one refcount to a shared page).
    pub pages_shared: u64,
    /// Copy-on-write splits: a session diverged inside a shared page and
    /// rebuilt a private copy of its prefix rows.
    pub cow_splits: u64,
}

/// Block-granular, refcounted page pool with capacity accounting.
#[derive(Clone, Debug)]
pub struct PagedKvCache {
    page_size: usize,
    d: usize,
    /// Maximum resident pages (0 = unbounded).
    capacity_pages: usize,
    mode: ResidencyMode,
    store_codes: bool,
    slots: Vec<KvPage>,
    /// Per-slot reference counts (0 = free). Prefix sharing holds one
    /// reference per sharing session.
    refs: Vec<u32>,
    /// Slot indices available for reuse.
    free: Vec<usize>,
    /// Lifetime counters (allocations, evictions, hits…).
    pub stats: CacheStats,
}

impl PagedKvCache {
    /// An empty [`ResidencyMode::Exact`] pool of `capacity_pages` pages
    /// (0 = unbounded), each holding `page_size` tokens of head
    /// dimension `d`.
    pub fn new(page_size: usize, d: usize, capacity_pages: usize) -> PagedKvCache {
        PagedKvCache::with_mode(page_size, d, capacity_pages, ResidencyMode::Exact, true)
    }

    /// [`PagedKvCache::new`] with an explicit residency mode for the
    /// pages it vends. `store_codes` keeps frozen LZ codes resident in
    /// quantized-only mode (needed by the SLZS predictor only).
    pub fn with_mode(
        page_size: usize,
        d: usize,
        capacity_pages: usize,
        mode: ResidencyMode,
        store_codes: bool,
    ) -> PagedKvCache {
        assert!(page_size > 0 && d > 0, "page_size and d must be positive");
        PagedKvCache {
            page_size,
            d,
            capacity_pages,
            mode,
            store_codes,
            slots: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    /// Tokens per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Head dimension of the cached rows.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Residency mode of the pages this pool vends.
    pub fn mode(&self) -> ResidencyMode {
        self.mode
    }

    /// Resident (allocated, not freed) pages.
    pub fn resident_pages(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Resident pages currently shared (refcount > 1).
    pub fn shared_pages(&self) -> usize {
        self.refs.iter().filter(|&&r| r > 1).count()
    }

    /// Measured heap bytes of all resident pages' payloads.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .iter()
            .zip(&self.refs)
            .filter(|(_, &r)| r > 0)
            .map(|(p, _)| p.resident_bytes())
            .sum()
    }

    /// Maximum resident pages (0 = unbounded).
    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    /// Whether one more page can be allocated without eviction.
    pub fn has_room(&self) -> bool {
        self.capacity_pages == 0 || self.resident_pages() < self.capacity_pages
    }

    /// Allocate an empty page at refcount 1; `None` when at capacity
    /// (the caller must evict first).
    pub fn alloc(&mut self) -> Option<PageId> {
        if !self.has_room() {
            return None;
        }
        self.stats.pages_allocated += 1;
        if let Some(slot) = self.free.pop() {
            let (ps, d, mode, sc) = (self.page_size, self.d, self.mode, self.store_codes);
            self.slots[slot].reset(ps, d, mode, sc);
            debug_assert_eq!(self.refs[slot], 0, "free slot {slot} still referenced");
            self.refs[slot] = 1;
            Some(PageId(slot))
        } else {
            self.slots.push(KvPage::with_mode(self.page_size, self.d, self.mode, self.store_codes));
            self.refs.push(1);
            Some(PageId(self.slots.len() - 1))
        }
    }

    /// Take an additional reference on a resident page (prefix sharing).
    pub fn retain(&mut self, id: PageId) {
        debug_assert!(self.refs[id.0] > 0, "retain of free page {}", id.0);
        self.refs[id.0] += 1;
    }

    /// Current reference count of a slot (0 = free).
    pub fn refcount(&self, id: PageId) -> u32 {
        self.refs[id.0]
    }

    /// Drop one reference; returns `true` when this was the last one and
    /// the slot went back on the free list.
    pub fn free_page(&mut self, id: PageId) -> bool {
        debug_assert!(self.refs[id.0] > 0, "double free of page {}", id.0);
        self.refs[id.0] -= 1;
        if self.refs[id.0] == 0 {
            debug_assert!(!self.free.contains(&id.0), "double free of page {}", id.0);
            self.free.push(id.0);
            true
        } else {
            false
        }
    }

    /// Read a page by id.
    pub fn get(&self, id: PageId) -> &KvPage {
        &self.slots[id.0]
    }

    /// Mutate a page by id (append path).
    pub fn get_mut(&mut self, id: PageId) -> &mut KvPage {
        &mut self.slots[id.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_push_and_read_back() {
        let mut p = KvPage::new(4, 3);
        p.push(&[1.0, -2.0, 0.5], &[0.1, 0.2, 0.3], IntBits::Int8, 7);
        p.push(&[0.0, 0.0, 0.0], &[1.0, 1.0, 1.0], IntBits::Int8, 7);
        assert_eq!(p.len(), 2);
        assert!(!p.is_full());
        assert_eq!(p.k_row(0), &[1.0, -2.0, 0.5]);
        assert_eq!(p.v_row(1), &[1.0, 1.0, 1.0]);
        // Zero row: quantizes to zeros with a finite scale; codes carry
        // the zero sentinel.
        assert!(p.qk_row(1).iter().all(|&q| q == 0));
        assert!(p.k_codes_row(1).iter().all(|c| c.is_zero()));
        assert!(p.k_scale(1).is_finite());
    }

    #[test]
    fn metadata_is_frozen_per_row() {
        // The quantized operand of row 0 must not change when row 1 (with
        // a much larger magnitude) arrives — the decode-parity invariant.
        let mut p = KvPage::new(2, 2);
        p.push(&[1.0, 0.5], &[0.0, 0.0], IntBits::Int8, 7);
        let before: Vec<i32> = p.qk_row(0).to_vec();
        let codes_before: Vec<LzCode> = p.k_codes_row(0).to_vec();
        let scale_before = p.k_scale(0);
        p.push(&[100.0, -50.0], &[0.0, 0.0], IntBits::Int8, 7);
        assert_eq!(p.qk_row(0), &before[..]);
        assert_eq!(p.k_codes_row(0), &codes_before[..]);
        assert_eq!(p.k_scale(0), scale_before);
    }

    #[test]
    fn quantized_page_keeps_identical_operands_and_dequantizes() {
        let (k_row, v_row) = ([1.0f32, -2.0, 0.5, 0.25], [0.5f32, -1.0, 2.0, 0.0]);
        let mut exact = KvPage::new(2, 4);
        let mut quant = KvPage::with_mode(2, 4, ResidencyMode::QuantizedOnly, false);
        exact.push(&k_row, &v_row, IntBits::Int8, 7);
        quant.push(&k_row, &v_row, IntBits::Int8, 7);
        // Stages 1–2 read the same integers and scale → identical scores.
        let widened: Vec<i32> = quant.qk8_row(0).iter().map(|&x| x as i32).collect();
        assert_eq!(widened, exact.qk_row(0));
        assert_eq!(quant.k_scale(0).to_bits(), exact.k_scale(0).to_bits());
        // The gather read dequantizes within one quantization step.
        let mut kd = [0.0f32; 4];
        let mut vd = [0.0f32; 4];
        quant.copy_k_into(0, &mut kd);
        quant.copy_v_into(0, &mut vd);
        for (got, want) in kd.iter().zip(&k_row) {
            assert!((got - want).abs() <= quant.k_scale(0), "{got} vs {want}");
        }
        for (got, want) in vd.iter().zip(&v_row) {
            assert!((got - want).abs() <= quant.v_scale(0), "{got} vs {want}");
        }
        // And the resident footprint is the point: ≥3× smaller.
        assert!(
            exact.resident_bytes() >= 3 * quant.resident_bytes(),
            "exact {} vs quantized {}",
            exact.resident_bytes(),
            quant.resident_bytes()
        );
        assert_eq!(exact.gather_row_bytes(), 8 * 4);
        assert_eq!(quant.gather_row_bytes(), 2 * 4 + 8);
    }

    #[test]
    fn row_matches_compares_resident_state() {
        let mut p = KvPage::new(2, 2);
        p.push(&[1.0, 2.0], &[3.0, 4.0], IntBits::Int8, 7);
        assert!(p.row_matches(0, &[1.0, 2.0], &[3.0, 4.0], IntBits::Int8));
        assert!(!p.row_matches(0, &[1.0, 2.5], &[3.0, 4.0], IntBits::Int8));
        let mut q = KvPage::with_mode(2, 2, ResidencyMode::QuantizedOnly, false);
        q.push(&[1.0, 2.0], &[3.0, 4.0], IntBits::Int8, 7);
        assert!(q.row_matches(0, &[1.0, 2.0], &[3.0, 4.0], IntBits::Int8));
        assert!(!q.row_matches(0, &[2.0, 1.0], &[3.0, 4.0], IntBits::Int8));
    }

    #[test]
    fn pool_capacity_accounting() {
        let mut pool = PagedKvCache::new(8, 4, 2);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert_eq!(pool.resident_pages(), 2);
        assert!(pool.alloc().is_none(), "at capacity");
        pool.free_page(a);
        assert_eq!(pool.resident_pages(), 1);
        let c = pool.alloc().expect("freed slot reusable");
        assert_eq!(c, a, "free list reuses slots");
        assert!(pool.get(c).is_empty(), "reused page starts empty");
        assert_eq!(pool.stats.pages_allocated, 3);
    }

    #[test]
    fn refcounts_share_and_release() {
        let mut pool = PagedKvCache::new(4, 2, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        assert_eq!(pool.refcount(a), 2);
        assert_eq!(pool.shared_pages(), 1);
        assert!(!pool.free_page(a), "first release keeps the page resident");
        assert_eq!(pool.resident_pages(), 1);
        assert_eq!(pool.shared_pages(), 0);
        assert!(pool.free_page(a), "last release frees the slot");
        assert_eq!(pool.resident_pages(), 0);
        assert_eq!(pool.refcount(a), 0);
    }

    #[test]
    fn unbounded_pool_never_refuses() {
        let mut pool = PagedKvCache::new(4, 2, 0);
        for _ in 0..64 {
            assert!(pool.alloc().is_some());
        }
        assert_eq!(pool.resident_pages(), 64);
    }
}
