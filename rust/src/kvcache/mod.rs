//! Paged KV-cache + decode-session subsystem.
//!
//! STAR's cross-stage tiling coordinates the four pipeline stages
//! *within* one run; this module extends the same idea across **time**:
//! decode step `t` reuses the prediction metadata and generated KV of
//! steps `0..t` instead of recomputing them. Concretely:
//!
//! * [`page`] — [`KvPage`] (K/V rows + frozen per-row quantized predict
//!   operands) and [`PagedKvCache`], the block-granular pool with
//!   capacity accounting. Pages are sized to the pipeline's query-tile
//!   size so cached state composes with cross-stage tiling.
//! * [`session`] — [`SessionStore`]: sessions keyed by id over
//!   refcounted page tables, **page-granular** LRU eviction (coldest
//!   page of the coldest session), copy-on-write prefix sharing across
//!   sessions, and page-granular re-materialization from host history
//!   after eviction. [`ResidencyMode`] opts a store into quantized-only
//!   residency (~4× fewer resident bytes, selection-identical, lossy at
//!   the formal gather only).
//! * [`predict`] — [`QueryOperand`] / [`score_row`]: incremental DLZS /
//!   SLZS / low-bit prediction of one query row against cached page
//!   operands, with **per-row** quantization scales on both sides.
//!
//! The per-row scales are the load-bearing design decision: a frozen
//! key operand never depends on later tokens, and a query operand never
//! depends on its batch, so N single-token
//! [`crate::pipeline::SparseAttentionPipeline::decode_step`] calls are
//! bit-identical to one length-N causal prefill — for every chunking,
//! tile size, thread count, and across eviction/re-materialization
//! (property-tested in `rust/tests/prop_decode_parity.rs`).

pub mod page;
pub mod predict;
pub mod session;

pub use page::{
    gather_rows, gather_rows_into, CacheStats, KvPage, PageId, PagedKvCache, ResidencyMode,
};
pub use predict::{score_row, score_row_into, score_row_range_into, QueryOperand};
pub use session::{AppendOutcome, ResidencySnapshot, SessionConfig, SessionStore};
