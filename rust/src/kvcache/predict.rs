//! Incremental (per-row) sparse prediction over cached page operands.
//!
//! The batch pipeline prepares prediction operands once per run with
//! *globally* chosen quantization scales ([`crate::sparsity::PreparedPredict`]).
//! That is the right contract for one-shot prefill, but it cannot be
//! cached across decode steps: a global scale changes whenever a new
//! token extends the tensor, which would silently requantize every
//! cached key. The decode path therefore uses **per-row scales** on both
//! sides — each K row's operand is frozen when the token is appended
//! ([`super::page::KvPage::push`]) and each query row is encoded with a
//! scale drawn from that row alone ([`QueryOperand::encode`]). Scoring a
//! query at sequence position `p` then depends only on tokens `0..=p`,
//! which makes N single-token decode steps bit-identical to one length-N
//! causal prefill for every chunking, tile size and thread count.

use super::page::KvPage;
use crate::arith::{dlzs_mul, quantize_row_into, slzs_mul, truncate_msb, LzCode, OpCounter, OpKind};
use crate::sim::pipeline::PredictKind;
use crate::sparsity::bits_for;

/// One query row's prediction operand: the row quantized with its own
/// scale, LZ-encoded or MSB-truncated as the scheme requires.
#[derive(Clone, Debug)]
pub struct QueryOperand {
    /// Original f32 row (oracle scoring under [`PredictKind::None`]).
    raw: Vec<f32>,
    /// Quantized row (low-bit path: already MSB-truncated).
    q: Vec<i32>,
    /// LZ codes of the quantized row (DLZS/SLZS schemes only).
    codes: Vec<LzCode>,
    scale: f32,
    kind: PredictKind,
    w: u32,
}

impl QueryOperand {
    /// An empty operand whose buffers [`QueryOperand::encode_into`] can
    /// reuse across decode rows — the workspace-resident spelling of
    /// [`QueryOperand::encode`].
    pub fn reusable() -> QueryOperand {
        QueryOperand {
            raw: Vec::new(),
            q: Vec::new(),
            codes: Vec::new(),
            scale: 1.0,
            kind: PredictKind::None,
            w: 0,
        }
    }

    /// Encode one query row for the given scheme, charging the encode
    /// ops the datapath pays per decode step.
    pub fn encode(row: &[f32], kind: PredictKind, w: u32, c: &mut OpCounter) -> QueryOperand {
        let mut op = QueryOperand::reusable();
        op.encode_into(row, kind, w, c);
        op
    }

    /// [`QueryOperand::encode`] re-encoding in place: the raw, quantized
    /// and code buffers are cleared and refilled, so a reused operand
    /// allocates nothing once warm. This is the only encoder (the
    /// allocating entry point wraps it), so reused and fresh operands
    /// are bit-identical by construction.
    pub fn encode_into(&mut self, row: &[f32], kind: PredictKind, w: u32, c: &mut OpCounter) {
        let d = row.len();
        let scale = match kind {
            PredictKind::None => {
                self.q.clear();
                1.0
            }
            _ => quantize_row_into(row, bits_for(w), &mut self.q),
        };
        self.codes.clear();
        match kind {
            PredictKind::DlzsCross | PredictKind::Slzs => {
                c.tally(OpKind::LzEncode, d as u64);
                c.sram(d as u64); // compact code store (~1 byte/code)
                self.codes.extend(self.q.iter().map(|&x| LzCode::encode(x, w)));
            }
            PredictKind::LowBitMul => {
                let msb = 4.min(w);
                for v in self.q.iter_mut() {
                    *v = truncate_msb(*v, msb);
                }
                c.sram((d * 2) as u64);
            }
            PredictKind::None => {}
        }
        self.raw.clear();
        self.raw.extend_from_slice(row);
        self.scale = scale;
        self.kind = kind;
        self.w = w;
    }

    /// Head dimension of the encoded row.
    pub fn d(&self) -> usize {
        self.raw.len()
    }

    /// Pre-grow the operand buffers for head dimension `d`, so the next
    /// [`QueryOperand::encode_into`] allocates nothing.
    pub fn reserve(&mut self, d: usize) {
        if self.raw.capacity() < d {
            self.raw.reserve(d - self.raw.len());
        }
        if self.q.capacity() < d {
            self.q.reserve(d - self.q.len());
        }
        if self.codes.capacity() < d {
            self.codes.reserve(d - self.codes.len());
        }
    }

    /// Bytes of heap capacity currently held (workspace accounting).
    pub fn capacity_bytes(&self) -> usize {
        self.raw.capacity() * std::mem::size_of::<f32>()
            + self.q.capacity() * std::mem::size_of::<i32>()
            + self.codes.capacity() * std::mem::size_of::<LzCode>()
    }
}

/// Score one query row against keys `0..limit` of a session's resident
/// pages (concatenated in append order). Returns `limit` scores already
/// in logit units (`attn_scale` applied). Key `j`'s score depends only
/// on the query row and key `j`'s frozen operand — the bit-identity
/// anchor of the decode subsystem.
pub fn score_row(
    qop: &QueryOperand,
    pages: &[&KvPage],
    limit: usize,
    attn_scale: f32,
    c: &mut OpCounter,
) -> Vec<f32> {
    let mut out = Vec::with_capacity(limit);
    score_row_into(qop, pages, limit, attn_scale, c, &mut out);
    out
}

/// [`score_row`] writing into a caller-provided buffer (cleared, then
/// filled — no allocation once it has the capacity). This is the only
/// cached-operand scorer; the allocating entry point wraps it.
pub fn score_row_into(
    qop: &QueryOperand,
    pages: &[&KvPage],
    limit: usize,
    attn_scale: f32,
    c: &mut OpCounter,
    out: &mut Vec<f32>,
) {
    let d = qop.d();
    out.clear();
    'pages: for page in pages {
        for r in 0..page.len() {
            if out.len() == limit {
                break 'pages;
            }
            debug_assert_eq!(page.d(), d, "query/page head-dim mismatch");
            out.push(score_key(qop, page, r, attn_scale));
        }
    }
    assert_eq!(out.len(), limit, "session shorter than requested limit");
    charge_scored_span(qop, limit, d, c);
}

/// Score one query row against the *global* key range `key_lo..key_hi`
/// of a session's resident pages — the sharded-decode spelling of
/// [`score_row_into`]. Writes `key_hi - key_lo` scores (the range's
/// scores, in key order). Because key `j`'s score depends only on the
/// query row and key `j`'s frozen operand, and the charged ops are
/// linear in the span, any partition of `0..limit` into ranges scores —
/// and charges — exactly what one whole-row [`score_row_into`] call
/// does, bit for bit per key and count for count per op class.
pub fn score_row_range_into(
    qop: &QueryOperand,
    pages: &[&KvPage],
    key_lo: usize,
    key_hi: usize,
    attn_scale: f32,
    c: &mut OpCounter,
    out: &mut Vec<f32>,
) {
    let d = qop.d();
    out.clear();
    let span = key_hi.saturating_sub(key_lo);
    if span == 0 {
        return;
    }
    let mut base = 0usize; // global position of the current page's row 0
    'pages: for page in pages {
        let len = page.len();
        if base + len <= key_lo {
            base += len; // whole page before the range: skip it
            continue;
        }
        let r0 = key_lo.saturating_sub(base);
        for r in r0..len {
            if base + r >= key_hi {
                break 'pages;
            }
            debug_assert_eq!(page.d(), d, "query/page head-dim mismatch");
            out.push(score_key(qop, page, r, attn_scale));
        }
        base += len;
    }
    assert_eq!(out.len(), span, "session shorter than requested range");
    charge_scored_span(qop, span, d, c);
}

/// Score global key `r`-within-`page` against the encoded query row —
/// the one per-key scoring arm behind both [`score_row_into`] and
/// [`score_row_range_into`], so the whole-row and range spellings can
/// never drift.
#[inline]
fn score_key(qop: &QueryOperand, page: &KvPage, r: usize, attn_scale: f32) -> f32 {
    use super::page::ResidencyMode;
    let d = qop.d();
    match qop.kind {
        PredictKind::None => {
            // Oracle scores: exact dot product, nothing charged.
            // Quantized-only pages keep no f32 K — dequantize in flight.
            let mut dot = 0.0f32;
            match page.mode() {
                ResidencyMode::Exact => {
                    let krow = page.k_row(r);
                    for p in 0..d {
                        dot += qop.raw[p] * krow[p];
                    }
                }
                ResidencyMode::QuantizedOnly => {
                    let scale = page.k_scale(r);
                    let krow = page.qk8_row(r);
                    for p in 0..d {
                        dot += qop.raw[p] * (krow[p] as f32 * scale);
                    }
                }
            }
            dot * attn_scale
        }
        PredictKind::DlzsCross => {
            // Differential: plain quantized K, LZ-encoded Q (the
            // same operand roles as PreparedPredict's DLZS arm).
            // Quantized-only pages store the same integers as i8:
            // widening recovers them exactly, so scores — and therefore
            // top-k selection — are bit-identical across modes.
            let mut acc = 0i64;
            match page.mode() {
                ResidencyMode::Exact => {
                    let krow = page.qk_row(r);
                    for p in 0..d {
                        acc += dlzs_mul(krow[p], qop.codes[p]);
                    }
                }
                ResidencyMode::QuantizedOnly => {
                    let krow = page.qk8_row(r);
                    for p in 0..d {
                        acc += dlzs_mul(krow[p] as i32, qop.codes[p]);
                    }
                }
            }
            acc as f32 * (qop.scale * page.k_scale(r)) * attn_scale
        }
        PredictKind::Slzs => {
            // Symmetric: both sides LZ-encoded. The key-side codes
            // were frozen (and their conversion charged) at append
            // — the caching win; decode only reads them. Quantized-only
            // pools keep the codes resident for this scheme.
            let kcodes = page.k_codes_row(r);
            let mut acc = 0i64;
            for p in 0..d {
                acc += slzs_mul(kcodes[p], qop.codes[p]);
            }
            acc as f32 * (qop.scale * page.k_scale(r)) * attn_scale
        }
        PredictKind::LowBitMul => {
            let msb = 4.min(qop.w);
            let mut acc = 0i64;
            match page.mode() {
                ResidencyMode::Exact => {
                    let krow = page.qk_row(r);
                    for p in 0..d {
                        acc += truncate_msb(krow[p], msb) as i64 * qop.q[p] as i64;
                    }
                }
                ResidencyMode::QuantizedOnly => {
                    let krow = page.qk8_row(r);
                    for p in 0..d {
                        acc += truncate_msb(krow[p] as i32, msb) as i64 * qop.q[p] as i64;
                    }
                }
            }
            acc as f32 * (qop.scale * page.k_scale(r)) * attn_scale
        }
    }
}

/// Per-product accounting for `n` scored keys, mirroring
/// `PreparedPredict::score_rows` with m = 1 — linear in `n`, so a
/// partition of a row into ranges charges exactly the whole-row total.
fn charge_scored_span(qop: &QueryOperand, n: usize, d: usize, c: &mut OpCounter) {
    match qop.kind {
        PredictKind::None => {}
        PredictKind::DlzsCross | PredictKind::Slzs => {
            c.tally(OpKind::Shift, (n * d) as u64);
            c.tally(OpKind::Add, (n * d) as u64);
            c.sram((n * d * 2) as u64);
        }
        PredictKind::LowBitMul => {
            c.tally(OpKind::Mul, (n * d) as u64);
            c.tally(OpKind::Add, (n * d) as u64);
            c.sram((n * d * 2) as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::IntBits;
    use crate::tensor::{topk_indices, Mat};
    use crate::util::Rng;

    fn pages_from(k: &Mat, v: &Mat, page_size: usize) -> Vec<KvPage> {
        let mut pages = Vec::new();
        for i in 0..k.rows {
            if pages.last().map(|p: &KvPage| p.is_full()).unwrap_or(true) {
                pages.push(KvPage::new(page_size, k.cols));
            }
            pages.last_mut().unwrap().push(k.row(i), v.row(i), IntBits::Int8, 7);
        }
        pages
    }

    #[test]
    fn encode_into_reuses_dirty_operand_bit_identically() {
        // The workspace contract: re-encoding a different row (and a
        // different scheme) into a used operand equals a fresh encode —
        // operand contents, scales AND charged ops.
        let mut rng = Rng::new(21);
        let rows: Vec<Vec<f32>> =
            (0..4).map(|_| (0..16).map(|_| rng.normal_f32(0.0, 2.0)).collect()).collect();
        let kinds = [
            PredictKind::DlzsCross,
            PredictKind::LowBitMul,
            PredictKind::Slzs,
            PredictKind::None,
        ];
        let mut reused = QueryOperand::reusable();
        for (row, kind) in rows.iter().zip(kinds) {
            let mut cw = OpCounter::new();
            let fresh = QueryOperand::encode(row, kind, 7, &mut cw);
            let mut cg = OpCounter::new();
            reused.encode_into(row, kind, 7, &mut cg);
            assert_eq!(reused.raw, fresh.raw, "{kind:?}");
            assert_eq!(reused.q, fresh.q, "{kind:?}");
            assert_eq!(reused.codes, fresh.codes, "{kind:?}");
            assert_eq!(reused.scale, fresh.scale, "{kind:?}");
            assert_eq!(cg, cw, "{kind:?} op drift");
        }
    }

    #[test]
    fn scores_are_chunking_invariant() {
        // The same keys split across different page sizes must yield the
        // exact same scores for the same query row.
        let mut rng = Rng::new(11);
        let (s, d) = (37, 16);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let q = Mat::randn(1, d, 1.0, &mut rng);
        for kind in [PredictKind::DlzsCross, PredictKind::Slzs, PredictKind::LowBitMul] {
            let mut c = OpCounter::new();
            let qop = QueryOperand::encode(q.row(0), kind, 7, &mut c);
            let mut reference: Option<Vec<f32>> = None;
            for page_size in [1usize, 4, 16, 64] {
                let pages = pages_from(&k, &v, page_size);
                let refs: Vec<&KvPage> = pages.iter().collect();
                let got = score_row(&qop, &refs, s, 0.25, &mut c);
                match &reference {
                    None => reference = Some(got),
                    Some(want) => assert_eq!(&got, want, "{kind:?} page_size={page_size}"),
                }
            }
        }
    }

    #[test]
    fn limit_sees_only_the_causal_prefix() {
        let mut rng = Rng::new(12);
        let (s, d) = (24, 8);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let q = Mat::randn(1, d, 1.0, &mut rng);
        let mut c = OpCounter::new();
        let qop = QueryOperand::encode(q.row(0), PredictKind::DlzsCross, 7, &mut c);
        let pages = pages_from(&k, &v, 5);
        let refs: Vec<&KvPage> = pages.iter().collect();
        let full = score_row(&qop, &refs, s, 1.0, &mut c);
        for limit in [1usize, 5, 13, 24] {
            let partial = score_row(&qop, &refs, limit, 1.0, &mut c);
            assert_eq!(partial, full[..limit], "limit={limit}");
        }
    }

    #[test]
    fn range_scores_partition_to_whole_row_bitwise() {
        // A partition of 0..limit into arbitrary ranges must reproduce
        // the whole-row scores bit for bit AND the whole-row op charges
        // count for count — the sharded-decode predict-parity anchor.
        let mut rng = Rng::new(14);
        let (s, d) = (41, 16);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let q = Mat::randn(1, d, 1.0, &mut rng);
        for kind in [
            PredictKind::None,
            PredictKind::DlzsCross,
            PredictKind::Slzs,
            PredictKind::LowBitMul,
        ] {
            let mut enc = OpCounter::new();
            let qop = QueryOperand::encode(q.row(0), kind, 7, &mut enc);
            // Page size 7 so range cuts straddle page boundaries.
            let pages = pages_from(&k, &v, 7);
            let refs: Vec<&KvPage> = pages.iter().collect();
            for limit in [1usize, 7, 29, 41] {
                let mut cw = OpCounter::new();
                let whole = score_row(&qop, &refs, limit, 0.25, &mut cw);
                for cuts in [vec![limit], vec![1, limit], vec![3, 7, 20, limit]] {
                    if cuts.iter().any(|&c| c > limit) {
                        continue;
                    }
                    let mut cp = OpCounter::new();
                    let mut got: Vec<f32> = Vec::new();
                    let mut buf = Vec::new();
                    let mut lo = 0usize;
                    for &hi in &cuts {
                        score_row_range_into(&qop, &refs, lo, hi, 0.25, &mut cp, &mut buf);
                        got.extend_from_slice(&buf);
                        lo = hi;
                    }
                    assert_eq!(got, whole, "{kind:?} limit={limit} cuts={cuts:?}");
                    assert_eq!(cp, cw, "{kind:?} limit={limit} cuts={cuts:?} op drift");
                }
            }
        }
    }

    #[test]
    fn quantized_only_pages_score_bit_identically() {
        // The residency claim behind ResidencyMode::QuantizedOnly: the
        // i8 operands widen back to the exact integers the exact-mode
        // pages hold, so every predict scheme scores — and therefore
        // selects — identically. Only the stage 3–4 gather is lossy.
        use super::super::page::ResidencyMode;
        let mut rng = Rng::new(23);
        let (s, d) = (29, 16);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let q = Mat::randn(1, d, 1.0, &mut rng);
        let exact_pages = pages_from(&k, &v, 8);
        let mut quant_pages = Vec::new();
        for i in 0..s {
            if quant_pages.last().map(|p: &KvPage| p.is_full()).unwrap_or(true) {
                quant_pages.push(KvPage::with_mode(8, d, ResidencyMode::QuantizedOnly, true));
            }
            quant_pages.last_mut().unwrap().push(k.row(i), v.row(i), IntBits::Int8, 7);
        }
        let er: Vec<&KvPage> = exact_pages.iter().collect();
        let qr: Vec<&KvPage> = quant_pages.iter().collect();
        for kind in [
            PredictKind::None,
            PredictKind::DlzsCross,
            PredictKind::Slzs,
            PredictKind::LowBitMul,
        ] {
            let mut c = OpCounter::new();
            let qop = QueryOperand::encode(q.row(0), kind, 7, &mut c);
            let se = score_row(&qop, &er, s, 0.25, &mut c);
            let sq = score_row(&qop, &qr, s, 0.25, &mut c);
            match kind {
                // Oracle scoring reads f32 K, which quantized pages no
                // longer hold exactly — close, not bit-equal.
                PredictKind::None => {
                    for (a, b) in se.iter().zip(&sq) {
                        assert!((a - b).abs() < 0.5, "{kind:?}: {a} vs {b}");
                    }
                }
                _ => assert_eq!(se, sq, "{kind:?} scores drift across residency modes"),
            }
        }
    }

    #[test]
    fn dlzs_cached_scores_keep_topk_fidelity() {
        // Per-row-scale DLZS over cached operands should still rank the
        // true top keys highly (same property the batch predictor has).
        let mut rng = Rng::new(13);
        let (s, d) = (96, 32);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let q = Mat::randn(1, d, 1.0, &mut rng);
        let exact: Vec<f32> = (0..s)
            .map(|j| (0..d).map(|p| q.at(0, p) * k.at(j, p)).sum())
            .collect();
        let mut c = OpCounter::new();
        let qop = QueryOperand::encode(q.row(0), PredictKind::DlzsCross, 7, &mut c);
        let pages = pages_from(&k, &v, 16);
        let refs: Vec<&KvPage> = pages.iter().collect();
        let est = score_row(&qop, &refs, s, 1.0, &mut c);
        assert!(c.mul == 0 && c.shift > 0, "DLZS stays multiplier-free");
        let kk = 24;
        let te = topk_indices(&exact, kk);
        let tp = topk_indices(&est, kk);
        let hits = te.iter().filter(|x| tp.contains(x)).count();
        let rate = hits as f64 / kk as f64;
        assert!(rate > 0.7, "cached DLZS hit rate {rate}");
    }
}
