//! The multi-core spatial simulator: per-core model × dataflow ×
//! mesh/DRAM configuration (the ASTRA-sim substitution, DESIGN.md §2).
//!
//! Regenerates:
//! * Fig. 23(b) — SRAM sweep under the 5×5 mesh with shared DRAM,
//! * Fig. 24(a)(b) — DRAttention / MRCA ablation on 5×5 and 6×6,
//! * Fig. 24(c)(d) — lateral comparison of Spatial-Simba /
//!   Spatial-SpAtten / Spatial-STAR.

use super::drattention::{drattention_run, RingMapping};
use super::ring::ring_attention_run;
use crate::config::SpatialConfig;
use crate::sim::baselines::Baseline;
use crate::sim::pipeline::FeatureSet;

/// Which compute core populates the mesh nodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoreKind {
    /// Simba/NVDLA-style dense SIMD MAC core (Fig. 24 baseline).
    Simba,
    /// SpAtten sparse-attention core.
    Spatten,
    /// Full STAR core.
    Star,
    /// STAR datapath *without* SU-FA and RASS (the Fig. 23(b) baseline).
    StarNoMemOpt,
}

impl CoreKind {
    pub fn name(self) -> &'static str {
        match self {
            CoreKind::Simba => "Spatial-Simba",
            CoreKind::Spatten => "Spatial-SpAtten",
            CoreKind::Star => "Spatial-STAR",
            CoreKind::StarNoMemOpt => "Spatial-STAR(no mem-opt)",
        }
    }

    pub fn features(self) -> FeatureSet {
        match self {
            CoreKind::Simba => Baseline::Simba.features(),
            CoreKind::Spatten => Baseline::Spatten.features(),
            CoreKind::Star => FeatureSet::star(),
            CoreKind::StarNoMemOpt => {
                let mut f = FeatureSet::star();
                f.formal = crate::sim::pipeline::FormalKind::Dense;
                f.tiled_dataflow = false;
                f.oo_scheduler = false;
                f.sufa_tailored = false;
                f
            }
        }
    }

    /// Keep-ratio the core actually achieves (dense cores keep all keys).
    pub fn keep_ratio(self, requested: f64) -> f64 {
        match self {
            CoreKind::Simba => 1.0,
            _ => requested,
        }
    }
}

/// Which dataflow orchestrates the mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataflow {
    /// Ring-Attention baseline: KV circulates over all nodes.
    RingAttention,
    /// DRAttention with the naive logical-ring mapping (no MRCA).
    DrAttentionNaive,
    /// DRAttention + MRCA (the full Spatial-STAR dataflow).
    DrAttentionMrca,
}

impl Dataflow {
    pub fn name(self) -> &'static str {
        match self {
            Dataflow::RingAttention => "Ring-Attention",
            Dataflow::DrAttentionNaive => "DRAttention",
            Dataflow::DrAttentionMrca => "DRAttention+MRCA",
        }
    }
}

/// Uniform result across dataflows.
#[derive(Clone, Debug)]
pub struct SpatialReport {
    pub core: CoreKind,
    pub dataflow: Dataflow,
    pub total_s: f64,
    pub eff_gops: f64,
    pub exposed_comm_s: f64,
    pub noc_bytes: u64,
}

impl SpatialReport {
    pub fn eff_tops(&self) -> f64 {
        self.eff_gops / 1e3
    }
}

/// Run one spatial configuration on one attention layer.
pub fn spatial_run(
    cfg: &SpatialConfig,
    core: CoreKind,
    dataflow: Dataflow,
    s: usize,
    d: usize,
    h: usize,
    keep_ratio: f64,
) -> SpatialReport {
    let feats = core.features();
    let k = core.keep_ratio(keep_ratio);
    let mut core_cfg = cfg.clone();
    core_cfg.core = match core {
        CoreKind::Simba => Baseline::Simba.config(),
        CoreKind::Spatten => Baseline::Spatten.config(),
        _ => cfg.core.clone(),
    };
    match dataflow {
        Dataflow::RingAttention => {
            let r = ring_attention_run(&core_cfg, &feats, s, d, h, k);
            SpatialReport {
                core,
                dataflow,
                total_s: r.total_s,
                eff_gops: r.eff_gops,
                exposed_comm_s: r.exposed_comm_s,
                noc_bytes: r.noc_bytes,
            }
        }
        Dataflow::DrAttentionNaive | Dataflow::DrAttentionMrca => {
            let mapping = if dataflow == Dataflow::DrAttentionMrca {
                RingMapping::Mrca
            } else {
                RingMapping::NaiveWrap
            };
            let r = drattention_run(&core_cfg, &feats, mapping, s, d, h, k);
            SpatialReport {
                core,
                dataflow,
                total_s: r.total_s,
                eff_gops: r.eff_gops,
                exposed_comm_s: r.exposed_comm_s,
                noc_bytes: r.noc_bytes,
            }
        }
    }
}

/// The Fig. 24(a)/(b) ablation triple: (ring baseline, +DRAttention,
/// +MRCA) gains relative to the ring baseline.
pub fn ablation_gains(cfg: &SpatialConfig, s: usize, d: usize, h: usize, k: f64) -> (f64, f64) {
    let base = spatial_run(cfg, CoreKind::Star, Dataflow::RingAttention, s, d, h, k);
    let dra = spatial_run(cfg, CoreKind::Star, Dataflow::DrAttentionNaive, s, d, h, k);
    let full = spatial_run(cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, d, h, k);
    (base.total_s / dra.total_s, base.total_s / full.total_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(core: CoreKind, df: Dataflow) -> SpatialReport {
        spatial_run(&SpatialConfig::mesh5x5(), core, df, 16384, 64, 768, 0.2)
    }

    #[test]
    fn spatial_star_dominates_lateral_comparison() {
        // Fig. 24(c): Spatial-STAR > Spatial-SpAtten > Spatial-Simba.
        let simba = run(CoreKind::Simba, Dataflow::RingAttention);
        let spatten = run(CoreKind::Spatten, Dataflow::RingAttention);
        let star = run(CoreKind::Star, Dataflow::DrAttentionMrca);
        assert!(spatten.eff_gops > simba.eff_gops, "spatten {} !> simba {}", spatten.eff_gops, simba.eff_gops);
        assert!(star.eff_gops > spatten.eff_gops, "star {} !> spatten {}", star.eff_gops, spatten.eff_gops);
    }

    #[test]
    fn ablation_gains_ordered() {
        // Fig. 24(a): DRAttention alone ≈ 3.1×, +MRCA more.
        let (dra, full) = ablation_gains(&SpatialConfig::mesh5x5(), 16384, 64, 768, 0.2);
        assert!(dra > 1.0, "DRAttention gain {dra}");
        assert!(full >= dra, "full {full} !>= dra {dra}");
    }

    #[test]
    fn mem_opt_matters_under_shared_dram() {
        // Fig. 23(b): without SU-FA/RASS/tiling the shared-DRAM mesh is
        // memory-bound.
        let with_opt = run(CoreKind::Star, Dataflow::DrAttentionMrca);
        let without = run(CoreKind::StarNoMemOpt, Dataflow::DrAttentionMrca);
        assert!(
            with_opt.eff_gops > 2.0 * without.eff_gops,
            "with {} vs without {}",
            with_opt.eff_gops,
            without.eff_gops
        );
    }

    #[test]
    fn names() {
        assert_eq!(CoreKind::Star.name(), "Spatial-STAR");
        assert_eq!(Dataflow::DrAttentionMrca.name(), "DRAttention+MRCA");
    }
}
