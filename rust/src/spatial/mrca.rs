//! MRCA — Mesh-friendly Ring Communication Algorithm (Alg. 1).
//!
//! DRAttention needs ring-style circulation of Q chunks, but a physical
//! 2D mesh has no wrap-around links. MRCA realizes a *logically
//! equivalent* orchestration with neighbor-only transfers: **progress
//! waves** spread chunks outward from their origin in both directions;
//! at half time the transferred chunks are **replicated** locally, and
//! **reflux tides** then carry the copies back so every CU computes
//! against every chunk exactly once within N steps.
//!
//! The paper prints Alg. 1 for the 5-unit (odd) case, where replication
//! happens at step ⌊N/2⌋+1. For even N the same formulas hold with the
//! replication step at ⌈N/2⌉ — the two coincide for odd N, so we
//! implement the unified rule (replication at ⌈N/2⌉; reflux sends for
//! t > ⌊N/2⌋ except the replication step) and verify completeness for
//! every N with [`verify_schedule`].
//!
//! CUs and chunks are 1-indexed (1..=N) to match the paper's notation.

/// One chunk transfer: `src` forwards `chunk` to the adjacent `dest`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Send {
    pub src: usize,
    pub dest: usize,
    pub chunk: usize,
}

/// The sends of one time step.
#[derive(Clone, Debug, Default)]
pub struct StepSends {
    pub step: usize,
    pub sends: Vec<Send>,
    /// Sends at the replication step keep a local copy at the source.
    pub replicate: bool,
}

/// Build the full N-step MRCA schedule for `n` CUs on a 1D mesh.
pub fn mrca_schedule(n: usize) -> Vec<StepSends> {
    assert!(n >= 1, "need at least one CU");
    let half = n / 2; // ⌊N/2⌋
    let rep_step = n.div_ceil(2); // ⌈N/2⌉: replication step
    let mut steps = Vec::with_capacity(n);
    for t in 1..=n {
        let mut sends = Vec::new();
        for src in 1..=n {
            // Progress wave, upward (lines 4–6).
            if t <= src && src < n {
                sends.push(Send { src, dest: src + 1, chunk: src - t + 1 });
            }
            // Progress wave, downward (lines 7–9).
            if 1 < src && src <= n - t + 1 {
                sends.push(Send { src, dest: src - 1, chunk: src + t - 1 });
            }
            // Reflux tides (lines 10–19), except at the replication step.
            if t > half && t != rep_step && n >= 2 {
                if t - half <= src && src < t {
                    sends.push(Send { src, dest: src + 1, chunk: src + n - t + 1 });
                }
                if n - t + 1 < src && src < n - t + 1 + half {
                    // src + t − n − 1, ordered to stay in usize range
                    // (the guard gives src + t > n + 1).
                    sends.push(Send { src, dest: src - 1, chunk: src + t - n - 1 });
                }
            }
        }
        steps.push(StepSends { step: t, sends, replicate: t == rep_step });
    }
    steps
}

/// Result of checking a schedule.
#[derive(Clone, Debug)]
pub struct ScheduleCheck {
    /// Every (CU, chunk) pair computed exactly once within N steps.
    pub complete: bool,
    /// Max chunks resident on any CU at any step.
    pub max_resident: usize,
    /// Max sends issued by one CU in one step (router port pressure).
    pub max_sends_per_cu: usize,
    /// Which chunk each CU computed at each step: `compute[t-1][cu-1]`.
    pub compute: Vec<Vec<usize>>,
}

/// Simulate the schedule and verify the MRCA invariants:
///
/// 1. every transfer is between adjacent CUs,
/// 2. a chunk is only sent by a CU that currently holds it,
/// 3. each CU computes each chunk exactly once over the N steps
///    (one chunk per step — the ring-equivalence property).
///
/// Residency model: a send moves the chunk (copy-and-drop) except at the
/// replication step, where the source keeps a copy; a resident chunk is
/// dropped once it has been computed here and has no future sends from
/// this CU (this is what bounds storage).
pub fn verify_schedule(n: usize, steps: &[StepSends]) -> Result<ScheduleCheck, String> {
    let mut resident: Vec<Vec<bool>> = vec![vec![false; n + 1]; n + 1]; // [cu][chunk]
    for cu in 1..=n {
        resident[cu][cu] = true;
    }
    let mut computed: Vec<Vec<bool>> = vec![vec![false; n + 1]; n + 1];
    let mut compute_log = Vec::with_capacity(n);
    let mut max_resident = 1;
    let mut max_sends_per_cu = 0;

    for (ti, step) in steps.iter().enumerate() {
        let t = ti + 1;
        // -- validity of sends against current residency --
        let mut sends_by_cu = vec![0usize; n + 1];
        for s in &step.sends {
            if s.src.abs_diff(s.dest) != 1 {
                return Err(format!("step {t}: non-neighbor send {s:?}"));
            }
            if !(1..=n).contains(&s.chunk) {
                return Err(format!("step {t}: chunk id out of range {s:?}"));
            }
            if !resident[s.src][s.chunk] {
                return Err(format!("step {t}: {s:?} but chunk not resident at src"));
            }
            sends_by_cu[s.src] += 1;
        }
        max_sends_per_cu = max_sends_per_cu.max(sends_by_cu.iter().copied().max().unwrap_or(0));

        // -- compute assignment: prefer a resident chunk that is leaving
        //    and never returns to this CU --
        let mut row = Vec::with_capacity(n);
        for cu in 1..=n {
            let cands: Vec<usize> =
                (1..=n).filter(|&c| resident[cu][c] && !computed[cu][c]).collect();
            let Some(&first) = cands.first() else {
                return Err(format!("step {t}: CU{cu} has no uncomputed resident chunk"));
            };
            let outgoing: Vec<usize> =
                step.sends.iter().filter(|s| s.src == cu).map(|s| s.chunk).collect();
            let returns = |c: usize| {
                steps[t..].iter().any(|st| st.sends.iter().any(|s| s.dest == cu && s.chunk == c))
            };
            let pick = cands
                .iter()
                .copied()
                .find(|&c| outgoing.contains(&c) && !returns(c))
                .unwrap_or(first);
            computed[cu][pick] = true;
            row.push(pick);
        }
        compute_log.push(row);

        // -- apply the sends --
        let snapshot = resident.clone();
        for s in &step.sends {
            if snapshot[s.src][s.chunk] {
                resident[s.dest][s.chunk] = true;
                if !step.replicate {
                    resident[s.src][s.chunk] = false;
                }
            }
        }
        // -- drop dead chunks (computed here, never sent from here again) --
        for cu in 1..=n {
            for c in 1..=n {
                if resident[cu][c] && computed[cu][c] {
                    let needed = steps[t..]
                        .iter()
                        .any(|st| st.sends.iter().any(|s| s.src == cu && s.chunk == c));
                    if !needed {
                        resident[cu][c] = false;
                    }
                }
            }
        }
        let cur_max = (1..=n).map(|cu| (1..=n).filter(|&c| resident[cu][c]).count()).max().unwrap();
        max_resident = max_resident.max(cur_max);
    }

    let complete = (1..=n).all(|cu| (1..=n).all(|c| computed[cu][c]));
    Ok(ScheduleCheck {
        complete,
        max_resident,
        max_sends_per_cu,
        compute: compute_log,
    })
}

/// Total chunk-hops of the schedule (each send is one neighbor hop) —
/// the NoC traffic MRCA pays per ring rotation of one chunk unit.
pub fn total_hops(steps: &[StepSends]) -> usize {
    steps.iter().map(|s| s.sends.len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_n5_step1_and_2() {
        let sched = mrca_schedule(5);
        // Step 1: every interior CU launches both waves with its own chunk.
        let s1 = &sched[0];
        assert!(s1.sends.contains(&Send { src: 1, dest: 2, chunk: 1 }));
        assert!(s1.sends.contains(&Send { src: 2, dest: 3, chunk: 2 }));
        assert!(s1.sends.contains(&Send { src: 2, dest: 1, chunk: 2 }));
        assert!(s1.sends.contains(&Send { src: 5, dest: 4, chunk: 5 }));
        // Step 2 (paper text): CU2 forwards chunk1 up and chunk3 down.
        let s2 = &sched[1];
        assert!(s2.sends.contains(&Send { src: 2, dest: 3, chunk: 1 }));
        assert!(s2.sends.contains(&Send { src: 2, dest: 1, chunk: 3 }));
    }

    #[test]
    fn paper_example_n5_reflux_step4() {
        // Paper: at step 4, CU3 transfers chunk1 to CU2 and chunk5 to CU4.
        let sched = mrca_schedule(5);
        let s4 = &sched[3];
        assert!(s4.sends.contains(&Send { src: 3, dest: 2, chunk: 1 }));
        assert!(s4.sends.contains(&Send { src: 3, dest: 4, chunk: 5 }));
    }

    #[test]
    fn replication_at_ceil_half() {
        assert!(mrca_schedule(5)[2].replicate); // step 3 = ⌈5/2⌉
        assert!(mrca_schedule(6)[2].replicate); // step 3 = ⌈6/2⌉
        assert!(!mrca_schedule(5)[3].replicate);
    }

    #[test]
    fn complete_for_all_mesh_sizes() {
        // 1..=16 covers every row/column length of the 5×5 and 6×6 meshes
        // and beyond.
        for n in 1..=16 {
            let sched = mrca_schedule(n);
            assert_eq!(sched.len(), n);
            let chk = verify_schedule(n, &sched)
                .unwrap_or_else(|e| panic!("N={n}: schedule invalid: {e}"));
            assert!(chk.complete, "N={n}: schedule incomplete");
        }
    }

    #[test]
    fn compute_is_one_chunk_per_cu_per_step() {
        let sched = mrca_schedule(5);
        let chk = verify_schedule(5, &sched).unwrap();
        assert_eq!(chk.compute.len(), 5);
        for row in &chk.compute {
            assert_eq!(row.len(), 5);
        }
        // Column cu-1 across steps is a permutation of 1..=5.
        for cu in 1..=5usize {
            let mut seen: Vec<usize> = chk.compute.iter().map(|r| r[cu - 1]).collect();
            seen.sort_unstable();
            assert_eq!(seen, vec![1, 2, 3, 4, 5]);
        }
        // Step 1: each CU computes its own chunk (Fig. 15).
        assert_eq!(chk.compute[0], vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn storage_stays_bounded() {
        for n in 2..=16 {
            let chk = verify_schedule(n, &mrca_schedule(n)).unwrap();
            // Paper: ≤2 chunks during progress waves; replication can
            // transiently add one more.
            assert!(chk.max_resident <= 3, "N={n}: max resident {}", chk.max_resident);
            // Five-direction router: ≤2 outgoing chunk sends per step.
            assert!(chk.max_sends_per_cu <= 2, "N={n}: {} sends", chk.max_sends_per_cu);
        }
    }

    #[test]
    fn hop_count_close_to_ring() {
        // A wrap-around ring moves N chunks × N-1 steps = N(N-1) hops.
        // MRCA pays the same order (replication adds O(N)).
        for n in [5usize, 6, 8] {
            let hops = total_hops(&mrca_schedule(n));
            let ring = n * (n - 1);
            // Reflux adds up to ~50% extra hops on even N (replication
            // copies travel twice); still O(N²) like the ideal ring.
            assert!(
                hops as f64 <= 1.6 * ring as f64 && hops >= ring - n,
                "N={n}: {hops} hops vs ring {ring}"
            );
        }
    }
}
