//! 2D-mesh Network-on-Chip model (Table IV).
//!
//! Transaction-level: messages are routed dimension-order (X then Y);
//! every directed link between adjacent routers has the Table IV
//! bandwidth/latency; a *step* accumulates the bytes each link must carry
//! and its serialization time is set by the most-loaded link (input-queued
//! routers ⇒ a link is a serial resource) plus the hop latency of the
//! longest path. DRAM sits on both vertical edges of the mesh
//! (Fig. 13): a memory transaction travels over the NoC to the nearer
//! edge and shares the total DRAM bandwidth with every other core.

use crate::config::SpatialConfig;
use std::collections::BTreeMap;

/// Router coordinate (row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Coord {
    pub row: usize,
    pub col: usize,
}

impl Coord {
    pub fn manhattan(&self, other: &Coord) -> usize {
        self.row.abs_diff(other.row) + self.col.abs_diff(other.col)
    }
}

/// A directed link between two adjacent routers (node ids).
pub type Link = (usize, usize);

/// The mesh fabric.
#[derive(Clone, Debug)]
pub struct Mesh {
    pub rows: usize,
    pub cols: usize,
    /// Per-link bandwidth, bytes/s.
    pub link_bw: f64,
    /// Per-hop latency, seconds.
    pub hop_latency: f64,
    /// Link energy, pJ/bit.
    pub link_pj_per_bit: f64,
}

impl Mesh {
    pub fn from_config(cfg: &SpatialConfig) -> Mesh {
        Mesh {
            rows: cfg.mesh_rows,
            cols: cfg.mesh_cols,
            link_bw: cfg.link_bw,
            hop_latency: cfg.link_latency,
            link_pj_per_bit: cfg.link_pj_per_bit,
        }
    }

    pub fn nodes(&self) -> usize {
        self.rows * self.cols
    }

    pub fn id(&self, c: Coord) -> usize {
        debug_assert!(c.row < self.rows && c.col < self.cols);
        c.row * self.cols + c.col
    }

    pub fn coord(&self, id: usize) -> Coord {
        debug_assert!(id < self.nodes());
        Coord { row: id / self.cols, col: id % self.cols }
    }

    /// Dimension-order (X-first) route between two nodes, as a list of
    /// directed links.
    pub fn xy_route(&self, from: usize, to: usize) -> Vec<Link> {
        let (a, b) = (self.coord(from), self.coord(to));
        let mut links = Vec::with_capacity(a.manhattan(&b));
        let mut cur = a;
        while cur.col != b.col {
            let next = Coord {
                row: cur.row,
                col: if b.col > cur.col { cur.col + 1 } else { cur.col - 1 },
            };
            links.push((self.id(cur), self.id(next)));
            cur = next;
        }
        while cur.row != b.row {
            let next = Coord {
                row: if b.row > cur.row { cur.row + 1 } else { cur.row - 1 },
                col: cur.col,
            };
            links.push((self.id(cur), self.id(next)));
            cur = next;
        }
        links
    }

    /// Hops from a node to its nearer vertical DRAM edge (plus one hop
    /// onto the memory controller).
    pub fn hops_to_dram(&self, id: usize) -> usize {
        let c = self.coord(id);
        c.col.min(self.cols - 1 - c.col) + 1
    }

    /// Node ids in snake order (see [`snake_coords`]).
    pub fn snake_order(&self) -> Vec<usize> {
        snake_coords(self.rows, self.cols).into_iter().map(|c| self.id(c)).collect()
    }
}

/// Boustrophedon ("snake") traversal of a `rows × cols` grid: row 0
/// left→right, row 1 right→left, and so on. Consecutive positions are
/// always mesh neighbors, so a logical ring of workers laid out in snake
/// order forwards its payload over single-hop links everywhere except
/// the wrap-around (which MRCA's progress/reflux schedule absorbs —
/// Alg. 1). The executable sharded pipeline
/// ([`crate::pipeline::ShardedPipeline`]) places its workers with this
/// order so its ring matches the mesh the analytic simulator models.
pub fn snake_coords(rows: usize, cols: usize) -> Vec<Coord> {
    let mut out = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        if r % 2 == 0 {
            for c in 0..cols {
                out.push(Coord { row: r, col: c });
            }
        } else {
            for c in (0..cols).rev() {
                out.push(Coord { row: r, col: c });
            }
        }
    }
    out
}

/// Traffic accumulated over one communication step: bytes per directed
/// link. Serialization time of the step is governed by the hottest link.
#[derive(Clone, Debug, Default)]
pub struct StepTraffic {
    bytes_per_link: BTreeMap<Link, u64>,
    /// Longest routed path in hops (sets the pipeline-fill latency).
    max_hops: usize,
    total_bytes_hops: u64,
}

impl StepTraffic {
    pub fn new() -> StepTraffic {
        StepTraffic::default()
    }

    /// Route `bytes` from `from` to `to` and accumulate on every link of
    /// the path.
    pub fn send(&mut self, mesh: &Mesh, from: usize, to: usize, bytes: u64) {
        if from == to || bytes == 0 {
            return;
        }
        let route = mesh.xy_route(from, to);
        self.max_hops = self.max_hops.max(route.len());
        for link in &route {
            *self.bytes_per_link.entry(*link).or_insert(0) += bytes;
            self.total_bytes_hops += bytes;
        }
    }

    /// Bytes on the most-loaded link.
    pub fn max_link_bytes(&self) -> u64 {
        self.bytes_per_link.values().copied().max().unwrap_or(0)
    }

    /// Number of distinct links used.
    pub fn links_used(&self) -> usize {
        self.bytes_per_link.len()
    }

    /// Wall time of this step's communication: worst-link serialization
    /// (wormhole flits stream, so hop latency is paid once per path) plus
    /// the longest path's hop latency.
    pub fn time(&self, mesh: &Mesh) -> f64 {
        if self.bytes_per_link.is_empty() {
            return 0.0;
        }
        self.max_link_bytes() as f64 / mesh.link_bw + self.max_hops as f64 * mesh.hop_latency
    }

    /// NoC energy of the step in joules (every byte pays per-hop energy).
    pub fn energy_j(&self, mesh: &Mesh) -> f64 {
        self.total_bytes_hops as f64 * 8.0 * mesh.link_pj_per_bit * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh5() -> Mesh {
        Mesh { rows: 5, cols: 5, link_bw: 250e9, hop_latency: 20e-9, link_pj_per_bit: 1.0 }
    }

    #[test]
    fn id_coord_roundtrip() {
        let m = mesh5();
        for id in 0..m.nodes() {
            assert_eq!(m.id(m.coord(id)), id);
        }
    }

    #[test]
    fn xy_route_goes_x_first() {
        let m = mesh5();
        let from = m.id(Coord { row: 0, col: 0 });
        let to = m.id(Coord { row: 2, col: 3 });
        let route = m.xy_route(from, to);
        assert_eq!(route.len(), 5);
        // First three hops move along the row (X), then two along Y.
        assert_eq!(route[0], (0, 1));
        assert_eq!(route[2], (2, 3));
        assert_eq!(route[3], (3, 8));
        // Each hop is between adjacent routers.
        for (a, b) in &route {
            assert_eq!(m.coord(*a).manhattan(&m.coord(*b)), 1);
        }
    }

    #[test]
    fn route_to_self_is_empty() {
        let m = mesh5();
        assert!(m.xy_route(7, 7).is_empty());
    }

    #[test]
    fn contention_serializes_on_shared_link() {
        let m = mesh5();
        // Two flows sharing the (0,1)->(0,2) link vs two disjoint flows.
        let mut shared = StepTraffic::new();
        shared.send(&m, 0, 3, 1 << 20);
        shared.send(&m, 1, 4, 1 << 20);
        let mut disjoint = StepTraffic::new();
        disjoint.send(&m, 0, 1, 1 << 20);
        disjoint.send(&m, 5, 6, 1 << 20);
        assert!(shared.time(&m) > disjoint.time(&m));
        assert_eq!(disjoint.max_link_bytes(), 1 << 20);
        assert_eq!(shared.max_link_bytes(), 2 << 20);
    }

    #[test]
    fn dram_edge_distance() {
        let m = mesh5();
        assert_eq!(m.hops_to_dram(m.id(Coord { row: 2, col: 0 })), 1);
        assert_eq!(m.hops_to_dram(m.id(Coord { row: 2, col: 2 })), 3);
        assert_eq!(m.hops_to_dram(m.id(Coord { row: 2, col: 4 })), 1);
    }

    #[test]
    fn snake_order_is_neighbor_contiguous() {
        for (rows, cols) in [(1usize, 4usize), (2, 3), (5, 5)] {
            let coords = snake_coords(rows, cols);
            assert_eq!(coords.len(), rows * cols);
            for w in coords.windows(2) {
                assert_eq!(w[0].manhattan(&w[1]), 1, "{w:?} not adjacent");
            }
        }
        let m = mesh5();
        let order = m.snake_order();
        assert_eq!(order[4], 4);
        assert_eq!(order[5], 9, "row 1 starts at its right edge");
    }

    #[test]
    fn energy_counts_hops() {
        let m = mesh5();
        let mut t = StepTraffic::new();
        t.send(&m, 0, 2, 1000); // 2 hops
        let expect = 2.0 * 1000.0 * 8.0 * 1.0 * 1e-12;
        assert!((t.energy_j(&m) - expect).abs() < 1e-18);
    }
}
