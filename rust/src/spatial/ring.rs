//! Ring-Attention baseline (ICLR'23) on the mesh (the Fig. 24 baseline).
//!
//! KV shards circulate among *all* R·C units on a logical ring laid over
//! the mesh as a boustrophedon (snake). Q sub-blocks stay home. Per step
//! every unit forwards its current KV shard (K and V, `S/(R·C)` rows) to
//! the ring successor and computes its local Q against the arriving
//! shard. The ring has R·C steps (vs DRAttention's C), the payload is
//! the full KV shard, and the wrap-around edge — absent on a physical
//! mesh — is relayed store-and-forward across the mesh boundary, adding
//! tail latency to every step. No topology- or sparsity-aware comm
//! optimizations (matching the paper's baseline configuration).

use super::mesh::{Coord, Mesh, StepTraffic};
use crate::config::SpatialConfig;
use crate::sim::dram::DramChannel;
use crate::sim::pipeline::{simulate, FeatureSet, WorkloadShape};

/// Report of one Ring-Attention execution.
#[derive(Clone, Debug)]
pub struct RingReport {
    pub steps: usize,
    pub total_s: f64,
    pub compute_s: f64,
    pub exposed_comm_s: f64,
    pub dram_s: f64,
    pub noc_energy_j: f64,
    pub eff_gops: f64,
    pub noc_bytes: u64,
}

impl RingReport {
    pub fn eff_tops(&self) -> f64 {
        self.eff_gops / 1e3
    }
}

/// Snake (boustrophedon) ring order over the mesh: row 0 left→right,
/// row 1 right→left, ... Every consecutive pair is mesh-adjacent except
/// the final wrap-around back to the start.
pub fn snake_order(mesh: &Mesh) -> Vec<usize> {
    let mut order = Vec::with_capacity(mesh.nodes());
    for r in 0..mesh.rows {
        if r % 2 == 0 {
            for c in 0..mesh.cols {
                order.push(mesh.id(Coord { row: r, col: c }));
            }
        } else {
            for c in (0..mesh.cols).rev() {
                order.push(mesh.id(Coord { row: r, col: c }));
            }
        }
    }
    order
}

/// The *non-topology-aware* ring order the baseline actually uses: plain
/// rank order (row-major node ids), oblivious to mesh adjacency — row
/// boundaries and the wrap-around become multi-hop transfers.
pub fn rank_order(mesh: &Mesh) -> Vec<usize> {
    (0..mesh.nodes()).collect()
}

/// KV shard payload bytes for `t_local` keys: K + V rows, INT16.
pub fn kv_payload_bytes(keys_local: usize, d: usize) -> u64 {
    (keys_local * 2 * d * 2) as u64
}

/// Run Ring-Attention for one layer.
pub fn ring_attention_run(
    cfg: &SpatialConfig,
    feats: &FeatureSet,
    s: usize,
    d: usize,
    h: usize,
    keep_ratio: f64,
) -> RingReport {
    let mesh = Mesh::from_config(cfg);
    let units = mesh.nodes();
    let t_local = (s / units).max(1); // queries per unit (fixed)
    let k_local = (s / units).max(1); // keys per circulating shard

    let dram = DramChannel {
        bw: cfg.dram_bw_per_core(),
        latency: cfg.dram_latency,
        pj_per_bit: cfg.dram_pj_per_bit,
    };

    // Per-step compute: local Q against one arriving shard. KV (and the
    // K̂ prediction codes, which travel with the shard) are generated
    // once in step 1; the marginal visit is simulated with h = 0 to
    // exclude exactly that per-shard work.
    let shape_full = WorkloadShape::new(t_local, k_local, d, h, keep_ratio);
    let shape_marg = WorkloadShape::new(t_local, k_local, d, 0, keep_ratio);
    let rep_full = simulate(&shape_full, feats, &cfg.core, &dram);
    let rep = simulate(&shape_marg, feats, &cfg.core, &dram);
    let marginal_s = rep.total_s;
    let step1_s = marginal_s
        + rep_full.kv_gen.compute_s
        + (rep_full.predict.compute_s - rep.predict.compute_s).max(0.0);

    // Per-step communication: every unit forwards its shard to its ring
    // successor in *rank* order (no topology awareness). Without a
    // tailored communication algorithm the routers store-and-forward the
    // whole shard at each hop, so a transfer of `hops` hops costs
    // hops × (serialization + hop latency), and the step is a barrier:
    // it ends when the slowest transfer lands. There is also no
    // compute/communication overlap (no double-buffering in the
    // baseline), so steps pay compute + comm serially.
    let payload = kv_payload_bytes(k_local, d);
    let order = rank_order(&mesh);
    let mut traffic = StepTraffic::new();
    let mut worst_hops = 0usize;
    let mut total_hops = 0usize;
    for i in 0..units {
        let from = order[i];
        let to = order[(i + 1) % units];
        let hops = mesh.coord(from).manhattan(&mesh.coord(to));
        worst_hops = worst_hops.max(hops);
        total_hops += hops;
        traffic.send(&mesh, from, to, payload);
    }
    let store_forward_s =
        worst_hops as f64 * (payload as f64 / mesh.link_bw + mesh.hop_latency);
    let comm_step_s = traffic.time(&mesh).max(store_forward_s);
    let step_bytes = total_hops as u64 * payload;

    // Initial loads: X shards to generate local KV (int8) + Q (INT16),
    // final O store.
    let x_bytes = (units * k_local * h) as u64;
    let qo_bytes = (2 * units * t_local * d * 2) as u64;
    let dram_total = DramChannel {
        bw: cfg.dram_bw_total,
        latency: cfg.dram_latency,
        pj_per_bit: cfg.dram_pj_per_bit,
    };
    let dram_s = dram_total.transfer_time(x_bytes + qo_bytes);

    // No overlap in the baseline: each of the `units` steps pays its
    // compute then its (barrier) communication.
    let mut compute_s = 0.0;
    let mut exposed = 0.0;
    let mut wall = 0.0;
    for step in 0..units {
        let c = if step == 0 { step1_s } else { marginal_s };
        compute_s += c;
        wall += c + comm_step_s;
        exposed += comm_step_s;
    }
    let total_s = dram_s + wall + marginal_s * 0.05;

    let noc_bytes = step_bytes * units as u64;
    let dense_ops = 4.0 * s as f64 * s as f64 * d as f64;
    RingReport {
        steps: units,
        total_s,
        compute_s,
        exposed_comm_s: exposed,
        dram_s,
        noc_energy_j: noc_bytes as f64 * 8.0 * mesh.link_pj_per_bit * 1e-12,
        eff_gops: dense_ops / total_s / 1e9,
        noc_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spatial::drattention::{drattention_run, RingMapping};

    #[test]
    fn snake_is_adjacent_except_wrap() {
        let mesh = Mesh::from_config(&SpatialConfig::mesh5x5());
        let order = snake_order(&mesh);
        assert_eq!(order.len(), 25);
        for w in order.windows(2) {
            assert_eq!(mesh.coord(w[0]).manhattan(&mesh.coord(w[1])), 1);
        }
        // Wrap-around is NOT adjacent — that's the whole problem.
        let wrap = mesh.coord(order[24]).manhattan(&mesh.coord(order[0]));
        assert!(wrap > 1, "wrap distance {wrap}");
    }

    #[test]
    fn drattention_beats_ring_baseline() {
        // Fig. 24(a): DRAttention ≈ 3.1× over Ring-Attention, and MRCA
        // raises it further.
        let cfg = SpatialConfig::mesh5x5();
        let star = FeatureSet::star();
        let ring = ring_attention_run(&cfg, &star, 16384, 64, 768, 0.2);
        let dra = drattention_run(&cfg, &star, RingMapping::NaiveWrap, 16384, 64, 768, 0.2);
        let full = drattention_run(&cfg, &star, RingMapping::Mrca, 16384, 64, 768, 0.2);
        assert!(dra.total_s < ring.total_s, "dra {} !< ring {}", dra.total_s, ring.total_s);
        assert!(full.total_s <= dra.total_s);
        // Ring moves far more NoC bytes (KV ≫ Q over 25 vs 5 steps).
        assert!(ring.noc_bytes > full.noc_bytes);
    }

    #[test]
    fn ring_has_more_steps_than_drattention() {
        let cfg = SpatialConfig::mesh5x5();
        let star = FeatureSet::star();
        let ring = ring_attention_run(&cfg, &star, 8192, 64, 768, 0.2);
        let dra = drattention_run(&cfg, &star, RingMapping::Mrca, 8192, 64, 768, 0.2);
        assert_eq!(ring.steps, 25);
        assert_eq!(dra.steps, 5);
    }
}
