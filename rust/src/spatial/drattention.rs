//! DRAttention — Distributed Ring-flow-based Attention (Sec. V-B-1).
//!
//! Partitioning on an R×C mesh (paper: 5×5):
//!
//! * **Q** is split along the sequence into R·C sub-blocks of
//!   `S/(R·C)` queries; one per STAR unit.
//! * **X** is split into C column shards of `S/C` rows; every unit in a
//!   column generates (on demand) the KV rows of its column's shard.
//! * Each row of the mesh runs a logical ring of length C: a unit
//!   computes its resident Q sub-block against the local KV shard while
//!   concurrently forwarding the Q sub-block (plus running max `m`,
//!   partial sum `l`, and the partial output accumulator) to the next
//!   unit. After C steps every Q sub-block has met every KV shard.
//!
//! The ring is realized either by **MRCA** (neighbor-only, congestion
//! free — Alg. 1) or by the **naive mapping** that relays the wrap-around
//! transfer store-and-forward across the whole row (the mismatch penalty
//! MRCA removes; Fig. 24 ablation).

use super::mesh::{Coord, Mesh, StepTraffic};
use super::mrca::{mrca_schedule, StepSends};
use crate::config::SpatialConfig;
use crate::sim::dram::DramChannel;
use crate::sim::pipeline::{simulate, FeatureSet, WorkloadShape};

/// How the logical ring is mapped onto the mesh row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RingMapping {
    /// MRCA progress-wave/reflux schedule (neighbor-only).
    Mrca,
    /// Naive logical ring: the wrap-around edge is relayed
    /// store-and-forward through every unit of the row.
    NaiveWrap,
}

/// Report of one DRAttention execution.
#[derive(Clone, Debug)]
pub struct DrAttentionReport {
    /// Ring steps executed (= mesh columns).
    pub steps: usize,
    /// End-to-end wall time, seconds (loads + steps + epilogue).
    pub total_s: f64,
    /// Time spent in per-step compute (max across units, summed).
    pub compute_s: f64,
    /// Communication time exposed beyond compute overlap.
    pub exposed_comm_s: f64,
    /// Initial DRAM load + final store time.
    pub dram_s: f64,
    /// NoC energy, joules.
    pub noc_energy_j: f64,
    /// Core compute+memory energy, joules.
    pub core_energy_j: f64,
    /// Dense-equivalent throughput, GOPS (whole mesh).
    pub eff_gops: f64,
    /// Bytes moved on the NoC.
    pub noc_bytes: u64,
}

impl DrAttentionReport {
    pub fn eff_tops(&self) -> f64 {
        self.eff_gops / 1e3
    }
}

/// Payload of one circulating Q sub-block in bytes: Q (t×d), the partial
/// output accumulator (t×d), and the running (m, l) state (2×t), INT16.
pub fn q_payload_bytes(t_local: usize, d: usize) -> u64 {
    ((t_local * d) * 2 + (t_local * d) * 2 + 2 * t_local * 2) as u64
}

/// Run DRAttention for one attention layer over sequence length `s`,
/// head dim `d`, hidden `h`, with per-core features `feats`.
pub fn drattention_run(
    cfg: &SpatialConfig,
    feats: &FeatureSet,
    mapping: RingMapping,
    s: usize,
    d: usize,
    h: usize,
    keep_ratio: f64,
) -> DrAttentionReport {
    let mesh = Mesh::from_config(cfg);
    let (rows, cols) = (cfg.mesh_rows, cfg.mesh_cols);
    let units = rows * cols;
    let t_local = (s / units).max(1); // queries per unit
    let s_local = (s / cols).max(1); // keys per column shard

    // Per-core DRAM channel: total bandwidth shared by all cores.
    let dram = DramChannel {
        bw: cfg.dram_bw_per_core(),
        latency: cfg.dram_latency,
        pj_per_bit: cfg.dram_pj_per_bit,
    };

    // ---- per-step core model -------------------------------------------
    // Per-shard work (X load, the K̂ phase of cross-phase DLZS, on-demand
    // KV generation) happens ONCE, in step 1; later steps only pay the
    // visiting-Q work: Â prediction, SADS, formal compute. Simulating
    // the marginal visit with h = 0 zeroes exactly the per-shard terms
    // while keeping the Â/top-k/formal path (and its SRAM-spill traffic,
    // which is what the Fig. 23(b) memory study measures).
    let shape_full = WorkloadShape::new(t_local, s_local, d, h, keep_ratio);
    let shape_marg = WorkloadShape::new(t_local, s_local, d, 0, keep_ratio);
    let rep_full = simulate(&shape_full, feats, &cfg.core, &dram);
    let rep = simulate(&shape_marg, feats, &cfg.core, &dram);
    let marginal_s = rep.total_s;
    let step1_s = marginal_s
        + rep_full.kv_gen.compute_s
        + (rep_full.predict.compute_s - rep.predict.compute_s).max(0.0);
    let core_energy_per_step = rep.energy.total_j();

    // ---- per-step communication ----------------------------------------
    let payload = q_payload_bytes(t_local, d);
    let comm_step_s = match mapping {
        RingMapping::Mrca => {
            // Worst step of the MRCA schedule across all rows at once.
            let sched = mrca_schedule(cols);
            sched
                .iter()
                .map(|st| mrca_step_time(&mesh, st, rows, payload))
                .fold(0.0, f64::max)
        }
        RingMapping::NaiveWrap => {
            // Interior transfers stream in one hop; the wrap-around edge
            // is relayed store-and-forward across cols-1 hops, and in a
            // rotating ring *some* chunk crosses the boundary every step.
            let interior = payload as f64 / mesh.link_bw + mesh.hop_latency;
            let wrap = (cols - 1) as f64 * (payload as f64 / mesh.link_bw + mesh.hop_latency);
            interior.max(wrap)
        }
    };

    // NoC bytes per step: every unit forwards one Q payload (MRCA sends
    // ≈ the same volume, amortized; wrap relay re-sends over cols-1 links).
    let step_bytes = match mapping {
        RingMapping::Mrca => units as u64 * payload,
        RingMapping::NaiveWrap => {
            ((cols - 1) + (cols - 1) * rows + (cols - 1) * units / cols) as u64 * payload
                + units as u64 * payload
        }
    };

    // ---- initial loads / final store over shared DRAM -------------------
    // X column shards (int8, loaded once per column — broadcast down the
    // column via the NoC), Q sub-blocks (INT16), O write-back (INT16).
    let x_bytes = (cols * s_local * h) as u64;
    let q_bytes = (units * t_local * d * 2) as u64;
    let o_bytes = (units * t_local * d * 2) as u64;
    let dram_total = DramChannel {
        bw: cfg.dram_bw_total,
        latency: cfg.dram_latency,
        pj_per_bit: cfg.dram_pj_per_bit,
    };
    let dram_s = dram_total.transfer_time(x_bytes + q_bytes + o_bytes);

    // ---- compose ---------------------------------------------------------
    // Each of the `cols` ring steps: compute overlaps communication.
    let mut compute_s = 0.0;
    let mut exposed = 0.0;
    let mut wall = 0.0;
    for step in 0..cols {
        let c = if step == 0 { step1_s } else { marginal_s };
        compute_s += c;
        wall += c.max(comm_step_s);
        exposed += (comm_step_s - c).max(0.0);
    }
    // Naive mapping: the boundary chunk has no wrap link; it is relayed
    // store-and-forward across the cols-1 interior routers AFTER the
    // step's own transfers complete (a chunk sits at the boundary on
    // every step of a rotating ring), so each synchronous step ends
    // with the relay chain exposed as a barrier tail — the tail latency
    // MRCA's reflux tide eliminates (Sec. V-B-2).
    if mapping == RingMapping::NaiveWrap {
        let relay_chain =
            (cols - 1) as f64 * (payload as f64 / mesh.link_bw + mesh.hop_latency);
        wall += relay_chain * cols as f64;
        exposed += relay_chain * cols as f64;
    }
    // Epilogue: final rescale/normalize of each unit's own Q output.
    let epilogue = marginal_s * 0.05;
    let total_s = dram_s + wall + epilogue;

    let noc_bytes = step_bytes * cols as u64;
    let noc_energy_j = noc_bytes as f64 * 8.0 * mesh.link_pj_per_bit * 1e-12
        * mean_hops(&mesh) as f64;
    let core_energy_j = core_energy_per_step * cols as f64 * units as f64
        / 1.0_f64.max(1.0);

    // Whole-layer dense-equivalent ops: S queries × S keys.
    let dense_ops = 4.0 * s as f64 * s as f64 * d as f64;
    DrAttentionReport {
        steps: cols,
        total_s,
        compute_s,
        exposed_comm_s: exposed,
        dram_s,
        noc_energy_j,
        core_energy_j,
        eff_gops: dense_ops / total_s / 1e9,
        noc_bytes,
    }
}

/// Time of one MRCA step when all `rows` rows execute it simultaneously:
/// map the 1-indexed CU ids onto each mesh row and accumulate link
/// traffic (rows are disjoint, but this also charges hop latency).
fn mrca_step_time(mesh: &Mesh, st: &StepSends, rows: usize, payload: u64) -> f64 {
    let mut traffic = StepTraffic::new();
    for r in 0..rows {
        for s in &st.sends {
            let from = mesh.id(Coord { row: r, col: s.src - 1 });
            let to = mesh.id(Coord { row: r, col: s.dest - 1 });
            traffic.send(mesh, from, to, payload);
        }
    }
    traffic.time(mesh)
}

fn mean_hops(_mesh: &Mesh) -> f64 {
    1.0 // DRAttention/MRCA transfers are neighbor-only
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SpatialConfig {
        SpatialConfig::mesh5x5()
    }

    #[test]
    fn mrca_beats_naive_wrap() {
        let c = cfg();
        let star = FeatureSet::star();
        let m = drattention_run(&c, &star, RingMapping::Mrca, 16384, 64, 768, 0.2);
        let n = drattention_run(&c, &star, RingMapping::NaiveWrap, 16384, 64, 768, 0.2);
        assert!(m.total_s <= n.total_s, "mrca {} !<= naive {}", m.total_s, n.total_s);
        assert!(m.noc_bytes < n.noc_bytes);
    }

    #[test]
    fn throughput_scales_with_mesh() {
        let star = FeatureSet::star();
        let r5 = drattention_run(&cfg(), &star, RingMapping::Mrca, 32768, 64, 768, 0.2);
        let r6 = drattention_run(
            &SpatialConfig::mesh6x6(),
            &star,
            RingMapping::Mrca,
            32768,
            64,
            768,
            0.2,
        );
        // More cores → higher aggregate throughput (sub-linear is fine:
        // shared DRAM bandwidth contention).
        assert!(r6.eff_gops > r5.eff_gops * 0.9, "5x5 {} vs 6x6 {}", r5.eff_gops, r6.eff_gops);
    }

    #[test]
    fn q_payload_much_smaller_than_kv_shard() {
        // The DRAttention claim: Q payload << the KV volume a KV-rotating
        // ring must move per step for the same partitioning.
        let t_local = 16384 / 25;
        let d = 64;
        let q = q_payload_bytes(t_local, d);
        let kv_shard = (t_local * 2 * d * 2) as u64; // K+V INT16 per unit shard
        assert!(q <= kv_shard + 4 * t_local as u64 + 8);
    }

    #[test]
    fn compute_dominates_for_long_sequences() {
        // Fig. 14: if compute time exceeds Q-transfer time there is no
        // exposed communication overhead.
        let c = cfg();
        let r = drattention_run(&c, &FeatureSet::star(), RingMapping::Mrca, 65536, 64, 768, 0.2);
        assert!(
            r.exposed_comm_s < 0.2 * r.total_s,
            "exposed {} vs total {}",
            r.exposed_comm_s,
            r.total_s
        );
    }
}
