//! The spatial (multi-core) extension of STAR: a 2D-mesh NoC of STAR
//! cores running the DRAttention dataflow via the MRCA communication
//! algorithm (Sec. V-B).
//!
//! * [`mesh`] — the 2D mesh Network-on-Chip: dimension-order (XY) routed,
//!   input-queued routers modeled at transaction level with per-link
//!   contention, plus edge-attached DRAM (Table IV).
//! * [`mrca`] — Alg. 1, the Mesh-friendly Ring Communication Algorithm:
//!   progress waves + reflux tides realize a logical ring on a physical
//!   1D mesh without wrap-around links. Includes the correctness checker.
//! * [`drattention`] — the Distributed Ring-flow Attention dataflow:
//!   Q sub-blocks (plus running (m, l) softmax state) circulate; X/KV
//!   stays column-resident; compute overlaps communication.
//! * [`ring`] — the Ring-Attention (ICLR'23) baseline: KV circulates on a
//!   logical ring naively mapped onto the mesh (wrap-around hop pays the
//!   full mesh diameter), no topology awareness.
//! * [`sim`] — the multi-core simulator composing a per-core model
//!   (STAR / SpAtten / Simba) with a dataflow and the shared-DRAM NoC;
//!   regenerates Fig. 23(b) and Fig. 24.

pub mod drattention;
pub mod mesh;
pub mod mrca;
pub mod ring;
pub mod sim;

pub use drattention::{drattention_run, DrAttentionReport};
pub use mesh::{snake_coords, Coord, Mesh, StepTraffic};
pub use mrca::{mrca_schedule, verify_schedule, Send, StepSends};
pub use ring::{ring_attention_run, RingReport};
pub use sim::{spatial_run, CoreKind, Dataflow, SpatialReport};
