//! Synthetic workload generators.
//!
//! Two levels:
//! 1. **Score-level** ([`ScoreGen`]): raw attention-logit rows with a
//!    controlled Type I/II/III mix and a depth-dependent separability trend
//!    (deeper layers → more distinguishable scores, the Fig. 17a effect).
//! 2. **Tensor-level** ([`AttnWorkload`]): full Q/K/V/X/W_k tensors for one
//!    head of a model preset, for end-to-end runs through prediction →
//!    top-k → SU-FA and through the cycle-level simulator.

use crate::config::ModelConfig;
use crate::sparsity::distribution::ClassifyParams;
use crate::sparsity::DistType;
use crate::tensor::Mat;
use crate::util::Rng;

/// Target fractions for the three row types (Fig. 9).
#[derive(Clone, Copy, Debug)]
pub struct TypeMixSpec {
    pub type1: f64,
    pub type2: f64,
    pub type3: f64,
}

impl TypeMixSpec {
    /// Decoder-model mix (GPT/LLaMA): ~22% Type I, ~78% Type II, ~0% III.
    pub fn decoder() -> Self {
        TypeMixSpec { type1: 0.22, type2: 0.78, type3: 0.0 }
    }

    /// Encoder-model mix (BERT): ~12% Type I, ~83% Type II, ~5% III.
    pub fn encoder() -> Self {
        TypeMixSpec { type1: 0.12, type2: 0.83, type3: 0.05 }
    }

    /// The paper's overall average: 73% Type II dominates.
    pub fn average() -> Self {
        TypeMixSpec { type1: 0.22, type2: 0.73, type3: 0.05 }
    }
}

/// Generator for synthetic attention-logit rows.
#[derive(Clone, Debug)]
pub struct ScoreGen {
    pub mix: TypeMixSpec,
    /// Base logit std; higher → sharper softmax.
    pub sigma: f32,
    /// Regions used to plant Type II/III structure (matches SADS n).
    pub regions: usize,
}

impl Default for ScoreGen {
    fn default() -> Self {
        ScoreGen { mix: TypeMixSpec::average(), sigma: 1.0, regions: 4 }
    }
}

impl ScoreGen {
    /// Generate one row of length `s` of the given type.
    pub fn row_of_type(&self, s: usize, ty: DistType, rng: &mut Rng) -> Vec<f32> {
        let mut row: Vec<f32> = (0..s).map(|_| rng.normal_f32(0.0, self.sigma)).collect();
        let region_len = s.div_ceil(self.regions);
        match ty {
            DistType::TypeI => {
                // 1–3 dominant spikes far above everything else (distinct
                // positions: accidental double-planting would distort mass).
                let spikes = rng.range(1, 4);
                for j in rng.sample_indices(s, spikes) {
                    row[j] = 8.0 * self.sigma + rng.f32() * 2.0;
                }
            }
            DistType::TypeII => {
                // A few moderately-large tokens planted in EVERY region.
                for r in 0..self.regions {
                    let lo = r * region_len;
                    let hi = ((r + 1) * region_len).min(s);
                    if lo >= hi {
                        continue;
                    }
                    for j in rng.sample_indices(hi - lo, 3.min(hi - lo)) {
                        row[lo + j] = 3.0 * self.sigma + rng.f32();
                    }
                }
            }
            DistType::TypeIII => {
                // Many large tokens piled into one region, with a narrow
                // value spread so no single token dominates the mass.
                let r = rng.below(self.regions);
                let lo = r * region_len;
                let hi = ((r + 1) * region_len).min(s);
                let count = ((hi - lo) / 2).max(8).min(hi - lo);
                for j in rng.sample_indices(hi - lo, count) {
                    row[lo + j] = 4.0 * self.sigma + 0.3 * rng.f32();
                }
            }
        }
        row
    }

    /// Sample a row type from the mix.
    pub fn sample_type(&self, rng: &mut Rng) -> DistType {
        let u = rng.f64();
        if u < self.mix.type1 {
            DistType::TypeI
        } else if u < self.mix.type1 + self.mix.type2 {
            DistType::TypeII
        } else {
            DistType::TypeIII
        }
    }

    /// Generate `n` rows of length `s` following the mix.
    pub fn rows(&self, n: usize, s: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let ty = self.sample_type(rng);
                self.row_of_type(s, ty, rng)
            })
            .collect()
    }

    /// Rows for a given layer of a `depth`-layer model: deeper layers get
    /// sharper (more separable) score distributions — the mechanism behind
    /// the paper's rising layer-wise hit rate (Fig. 17a).
    pub fn layer_rows(
        &self,
        layer: usize,
        depth: usize,
        n: usize,
        s: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<f32>> {
        assert!(layer < depth);
        let sharpen = 1.0 + 1.5 * layer as f32 / depth.max(1) as f32;
        let g = ScoreGen { sigma: self.sigma * sharpen, ..self.clone() };
        g.rows(n, s, rng)
    }

    /// Default classifier params consistent with this generator.
    pub fn classify_params(&self) -> ClassifyParams {
        ClassifyParams { regions: self.regions, ..ClassifyParams::default() }
    }
}

/// Tensor-level workload for one attention head.
#[derive(Clone, Debug)]
pub struct AttnWorkload {
    pub model: ModelConfig,
    /// Input activations X [S, H] (for on-demand KV generation).
    pub x: Mat,
    /// Key/value projection slices for this head: [H, d_h].
    pub wk: Mat,
    pub wv: Mat,
    /// Query tensor [T, d_h] (T queries processed in parallel).
    pub q: Mat,
    /// Exact K = X·W_k and V = X·W_v (oracles; hardware generates on demand).
    pub k: Mat,
    pub v: Mat,
}

impl AttnWorkload {
    /// Build a head workload: T parallel queries against an S-token context.
    pub fn generate(model: &ModelConfig, s: usize, t: usize, rng: &mut Rng) -> AttnWorkload {
        let h = model.hidden;
        let d = model.head_dim();
        // Activation/weight scales chosen to yield logits with O(1..4) std
        // after the 1/√d scaling — the regime real transformers live in.
        let x = Mat::randn(s, h, 1.0, rng);
        let wk = Mat::randn(h, d, 1.0 / (h as f32).sqrt(), rng);
        let wv = Mat::randn(h, d, 1.0 / (h as f32).sqrt(), rng);
        let k = x.matmul(&wk);
        let v = x.matmul(&wv);
        let q = Mat::randn(t, d, 1.0, rng);
        AttnWorkload { model: model.clone(), x, wk, wv, q, k, v }
    }

    pub fn s(&self) -> usize {
        self.x.rows
    }

    pub fn t(&self) -> usize {
        self.q.rows
    }

    pub fn d(&self) -> usize {
        self.q.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::distribution::{classify_row, TypeMix};

    #[test]
    fn planted_types_classify_correctly() {
        let g = ScoreGen::default();
        let mut rng = Rng::new(1);
        let p = g.classify_params();
        let mut ok = 0;
        let n = 60;
        for ty in [DistType::TypeI, DistType::TypeII, DistType::TypeIII] {
            for _ in 0..n {
                let row = g.row_of_type(256, ty, &mut rng);
                if classify_row(&row, &p) == ty {
                    ok += 1;
                }
            }
        }
        let acc = ok as f64 / (3 * n) as f64;
        assert!(acc > 0.8, "planted-type classification accuracy {acc}");
    }

    #[test]
    fn generated_mix_tracks_spec() {
        let g = ScoreGen { mix: TypeMixSpec::average(), ..Default::default() };
        let mut rng = Rng::new(2);
        let rows = g.rows(400, 256, &mut rng);
        let mix = TypeMix::of(&rows, &g.classify_params());
        assert!((mix.type2 - 0.73).abs() < 0.15, "type2 {}", mix.type2);
        assert!(mix.type2 > mix.type1 && mix.type1 > mix.type3);
    }

    #[test]
    fn deeper_layers_more_separable() {
        // Proxy: top-16 softmax mass grows with depth.
        let g = ScoreGen::default();
        let mut rng = Rng::new(3);
        let mass = |rows: &[Vec<f32>]| -> f64 {
            let mut acc = 0.0;
            for r in rows {
                let top = crate::tensor::topk_indices(r, 16);
                let m = r.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let tot: f64 = r.iter().map(|&x| ((x - m) as f64).exp()).sum();
                acc += top.iter().map(|&j| ((r[j] - m) as f64).exp()).sum::<f64>() / tot;
            }
            acc / rows.len() as f64
        };
        let shallow = mass(&g.layer_rows(0, 12, 50, 256, &mut rng));
        let deep = mass(&g.layer_rows(11, 12, 50, 256, &mut rng));
        assert!(deep > shallow, "deep {deep} !> shallow {shallow}");
    }

    #[test]
    fn workload_shapes_consistent() {
        let m = ModelConfig::preset("tiny").unwrap();
        let mut rng = Rng::new(4);
        let w = AttnWorkload::generate(&m, 64, 16, &mut rng);
        assert_eq!(w.s(), 64);
        assert_eq!(w.t(), 16);
        assert_eq!(w.d(), m.head_dim());
        assert_eq!(w.k.rows, 64);
        assert_eq!(w.k.cols, m.head_dim());
        // K really is X·W_k.
        let k2 = w.x.matmul(&w.wk);
        assert!(w.k.max_abs_diff(&k2) < 1e-5);
    }

    #[test]
    fn logit_scale_reasonable() {
        let m = ModelConfig::preset("tiny").unwrap();
        let mut rng = Rng::new(5);
        let w = AttnWorkload::generate(&m, 128, 8, &mut rng);
        let scale = 1.0 / (w.d() as f32).sqrt();
        let mut a = w.q.matmul(&w.k.transpose());
        a.scale(scale);
        let std = {
            let mean: f32 = a.data.iter().sum::<f32>() / a.data.len() as f32;
            (a.data.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / a.data.len() as f32).sqrt()
        };
        assert!((0.2..6.0).contains(&std), "logit std {std}");
    }
}
