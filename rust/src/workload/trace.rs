//! Request traces for the LTPP serving experiments.
//!
//! A trace is a sequence of attention requests (arrival time, sequence
//! length, query parallelism) that the coordinator replays. Traces
//! round-trip through JSON so experiments are reproducible and shareable.

use crate::util::json::Json;
use crate::util::Rng;

/// One request in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRequest {
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
    /// Context length S.
    pub seq_len: usize,
    /// Queries processed in parallel T (prefill chunk or decode batch).
    pub queries: usize,
    /// Model preset name.
    pub model: String,
}

/// A replayable request trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
}

impl RequestTrace {
    /// Poisson arrivals with rate `lambda` req/s, log-uniform sequence
    /// lengths in [s_min, s_max], fixed query parallelism.
    pub fn poisson(
        n: usize,
        lambda: f64,
        s_min: usize,
        s_max: usize,
        queries: usize,
        model: &str,
        rng: &mut Rng,
    ) -> RequestTrace {
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(lambda);
            let ls = (s_min as f64).ln() + rng.f64() * ((s_max as f64).ln() - (s_min as f64).ln());
            let seq_len = ls.exp().round() as usize;
            requests.push(TraceRequest {
                arrival: t,
                seq_len: seq_len.clamp(s_min, s_max),
                queries,
                model: model.to_string(),
            });
        }
        RequestTrace { requests }
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.requests
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("arrival", Json::num(r.arrival)),
                        ("seq_len", Json::num(r.seq_len as f64)),
                        ("queries", Json::num(r.queries as f64)),
                        ("model", Json::str(&r.model)),
                    ])
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Option<RequestTrace> {
        let arr = j.as_arr()?;
        let mut requests = Vec::with_capacity(arr.len());
        for r in arr {
            requests.push(TraceRequest {
                arrival: r.get("arrival")?.as_f64()?,
                seq_len: r.get("seq_len")?.as_usize()?,
                queries: r.get("queries")?.as_usize()?,
                model: r.get("model")?.as_str()?.to_string(),
            });
        }
        Some(RequestTrace { requests })
    }

    /// Write to a file as pretty JSON.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    /// Load from a JSON file.
    pub fn load(path: &std::path::Path) -> crate::Result<RequestTrace> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        RequestTrace::from_json(&j).ok_or_else(|| anyhow::anyhow!("malformed trace"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_monotone() {
        let mut rng = Rng::new(1);
        let tr = RequestTrace::poisson(100, 50.0, 128, 4096, 64, "gpt2", &mut rng);
        assert_eq!(tr.requests.len(), 100);
        for w in tr.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        assert!(tr.requests.iter().all(|r| (128..=4096).contains(&r.seq_len)));
    }

    #[test]
    fn mean_interarrival_close_to_rate() {
        let mut rng = Rng::new(2);
        let tr = RequestTrace::poisson(2000, 100.0, 256, 256, 1, "tiny", &mut rng);
        let total = tr.requests.last().unwrap().arrival;
        let mean = total / 2000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean interarrival {mean}");
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Rng::new(3);
        let tr = RequestTrace::poisson(10, 10.0, 128, 1024, 32, "bloom-1b7", &mut rng);
        let j = tr.to_json();
        let back = RequestTrace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        for (a, b) in tr.requests.iter().zip(&back.requests) {
            assert_eq!(a.seq_len, b.seq_len);
            assert_eq!(a.model, b.model);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
        }
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(4);
        let tr = RequestTrace::poisson(5, 10.0, 128, 256, 8, "tiny", &mut rng);
        let path = std::env::temp_dir().join("star_trace_test.json");
        tr.save(&path).unwrap();
        let back = RequestTrace::load(&path).unwrap();
        assert_eq!(back.requests.len(), 5);
        std::fs::remove_file(&path).ok();
    }
}
