//! Request traces for the LTPP serving experiments.
//!
//! A trace is a sequence of attention requests (arrival time, sequence
//! length, query parallelism) that the coordinator replays. Traces
//! round-trip through JSON so experiments are reproducible and shareable.

use crate::util::json::Json;
use crate::util::Rng;

/// One request in a trace.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRequest {
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
    /// Context length S (for decode steps: session length after this
    /// step's tokens are appended).
    pub seq_len: usize,
    /// Queries processed in parallel T (prefill chunk or decode chunk).
    pub queries: usize,
    /// Model preset name.
    pub model: String,
    /// Decode-session id for multi-turn traces (`None` = stateless
    /// prefill request). Steps of one session share the id and must
    /// replay in arrival order.
    pub session: Option<u64>,
}

impl TraceRequest {
    /// Whether this request decodes against a session.
    pub fn is_decode(&self) -> bool {
        self.session.is_some()
    }
}

/// A replayable request trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RequestTrace {
    pub requests: Vec<TraceRequest>,
}

impl RequestTrace {
    /// Poisson arrivals with rate `lambda` req/s, log-uniform sequence
    /// lengths in [s_min, s_max], fixed query parallelism.
    pub fn poisson(
        n: usize,
        lambda: f64,
        s_min: usize,
        s_max: usize,
        queries: usize,
        model: &str,
        rng: &mut Rng,
    ) -> RequestTrace {
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            t += rng.exponential(lambda);
            let ls = (s_min as f64).ln() + rng.f64() * ((s_max as f64).ln() - (s_min as f64).ln());
            let seq_len = ls.exp().round() as usize;
            requests.push(TraceRequest {
                arrival: t,
                seq_len: seq_len.clamp(s_min, s_max),
                queries,
                model: model.to_string(),
                session: None,
            });
        }
        RequestTrace { requests }
    }

    /// Multi-turn decode trace: `sessions` conversations arriving as a
    /// Poisson process with rate `session_lambda` (sessions/s). Each
    /// session opens with a `prefill_len`-token prefill, then emits
    /// `decode_tokens` single-token decode steps at `token_rate`
    /// tokens/s (exponential gaps). Requests are globally sorted by
    /// arrival, so concurrent sessions interleave — exactly the mix
    /// continuous batching must handle.
    pub fn multi_turn(
        sessions: usize,
        prefill_len: usize,
        decode_tokens: usize,
        session_lambda: f64,
        token_rate: f64,
        model: &str,
        rng: &mut Rng,
    ) -> RequestTrace {
        let mut requests = Vec::with_capacity(sessions * (1 + decode_tokens));
        let mut start = 0.0f64;
        for sid in 0..sessions as u64 {
            start += rng.exponential(session_lambda);
            requests.push(TraceRequest {
                arrival: start,
                seq_len: prefill_len,
                queries: prefill_len,
                model: model.to_string(),
                session: Some(sid),
            });
            let mut t = start;
            for step in 0..decode_tokens {
                t += rng.exponential(token_rate);
                requests.push(TraceRequest {
                    arrival: t,
                    seq_len: prefill_len + step + 1,
                    queries: 1,
                    model: model.to_string(),
                    session: Some(sid),
                });
            }
        }
        requests.sort_by(|a, b| {
            a.arrival.partial_cmp(&b.arrival).unwrap().then(a.session.cmp(&b.session))
        });
        RequestTrace { requests }
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.requests
                .iter()
                .map(|r| {
                    let mut fields = vec![
                        ("arrival", Json::num(r.arrival)),
                        ("seq_len", Json::num(r.seq_len as f64)),
                        ("queries", Json::num(r.queries as f64)),
                        ("model", Json::str(&r.model)),
                    ];
                    if let Some(sid) = r.session {
                        fields.push(("session", Json::num(sid as f64)));
                    }
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Option<RequestTrace> {
        let arr = j.as_arr()?;
        let mut requests = Vec::with_capacity(arr.len());
        for r in arr {
            requests.push(TraceRequest {
                arrival: r.get("arrival")?.as_f64()?,
                seq_len: r.get("seq_len")?.as_usize()?,
                queries: r.get("queries")?.as_usize()?,
                model: r.get("model")?.as_str()?.to_string(),
                // Optional for backward compatibility with stateless traces.
                session: r.get("session").and_then(|s| s.as_usize()).map(|s| s as u64),
            });
        }
        Some(RequestTrace { requests })
    }

    /// Write to a file as pretty JSON.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    /// Load from a JSON file.
    pub fn load(path: &std::path::Path) -> crate::Result<RequestTrace> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        RequestTrace::from_json(&j).ok_or_else(|| anyhow::anyhow!("malformed trace"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_arrivals_monotone() {
        let mut rng = Rng::new(1);
        let tr = RequestTrace::poisson(100, 50.0, 128, 4096, 64, "gpt2", &mut rng);
        assert_eq!(tr.requests.len(), 100);
        for w in tr.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        assert!(tr.requests.iter().all(|r| (128..=4096).contains(&r.seq_len)));
    }

    #[test]
    fn mean_interarrival_close_to_rate() {
        let mut rng = Rng::new(2);
        let tr = RequestTrace::poisson(2000, 100.0, 256, 256, 1, "tiny", &mut rng);
        let total = tr.requests.last().unwrap().arrival;
        let mean = total / 2000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean interarrival {mean}");
    }

    #[test]
    fn multi_turn_structure() {
        let mut rng = Rng::new(6);
        let tr = RequestTrace::multi_turn(3, 64, 5, 2.0, 40.0, "tiny", &mut rng);
        assert_eq!(tr.requests.len(), 3 * (1 + 5));
        // Globally sorted by arrival.
        for w in tr.requests.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        for sid in 0..3u64 {
            let steps: Vec<&TraceRequest> =
                tr.requests.iter().filter(|r| r.session == Some(sid)).collect();
            assert_eq!(steps.len(), 6);
            // First step is the prefill, then single-token decodes with a
            // context that grows by one per step.
            assert_eq!((steps[0].queries, steps[0].seq_len), (64, 64));
            for (i, s) in steps[1..].iter().enumerate() {
                assert_eq!(s.queries, 1);
                assert_eq!(s.seq_len, 64 + i + 1);
                assert!(s.is_decode());
            }
            // Per-session arrivals stay ordered after the global sort.
            for w in steps.windows(2) {
                assert!(w[1].arrival >= w[0].arrival);
            }
        }
    }

    #[test]
    fn multi_turn_json_roundtrip_keeps_sessions() {
        let mut rng = Rng::new(7);
        let tr = RequestTrace::multi_turn(2, 32, 3, 5.0, 50.0, "gpt2", &mut rng);
        let back = RequestTrace::from_json(&Json::parse(&tr.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(tr.requests.len(), back.requests.len());
        for (a, b) in tr.requests.iter().zip(&back.requests) {
            assert_eq!(a.session, b.session);
            assert_eq!(a.seq_len, b.seq_len);
            assert_eq!(a.queries, b.queries);
        }
        // Stateless traces still parse (no session field in their JSON).
        let stateless = RequestTrace::poisson(4, 10.0, 128, 256, 8, "tiny", &mut rng);
        let s = stateless.to_json().to_string();
        assert!(!s.contains("session"));
        let back = RequestTrace::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert!(back.requests.iter().all(|r| !r.is_decode()));
    }

    #[test]
    fn json_roundtrip() {
        let mut rng = Rng::new(3);
        let tr = RequestTrace::poisson(10, 10.0, 128, 1024, 32, "bloom-1b7", &mut rng);
        let j = tr.to_json();
        let back = RequestTrace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        for (a, b) in tr.requests.iter().zip(&back.requests) {
            assert_eq!(a.seq_len, b.seq_len);
            assert_eq!(a.model, b.model);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
        }
    }

    #[test]
    fn file_roundtrip() {
        let mut rng = Rng::new(4);
        let tr = RequestTrace::poisson(5, 10.0, 128, 256, 8, "tiny", &mut rng);
        let path = std::env::temp_dir().join("star_trace_test.json");
        tr.save(&path).unwrap();
        let back = RequestTrace::load(&path).unwrap();
        assert_eq!(back.requests.len(), 5);
        std::fs::remove_file(&path).ok();
    }
}
