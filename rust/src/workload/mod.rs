//! Workload generation and traces.
//!
//! The paper's accuracy-side experiments run on real LLMs we cannot host
//! here; DESIGN.md §4 explains the substitution: synthetic attention whose
//! row-score distributions follow the *measured* Type I/II/III mix of
//! Fig. 9 (≈73% Type II, ≈22% Type I in decoder models, ≈0–5% Type III),
//! plus full QKV tensor workloads shaped by the model presets in
//! [`crate::config::ModelConfig`].

pub mod gen;
pub mod trace;

pub use gen::{AttnWorkload, ScoreGen, TypeMixSpec};
pub use trace::{RequestTrace, TraceRequest};
