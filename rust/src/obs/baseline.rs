//! The perf-regression gate: compare a fresh `BENCH_*.json` document
//! against a committed baseline under noise-aware per-metric-class
//! tolerances (DESIGN.md §11).
//!
//! The comparison is a pure function over two parsed JSON documents —
//! no filesystem, no clock — so the gate logic is unit-testable with
//! doctored baselines. `star bench check` (see [`crate::bench`]) owns
//! the IO: it loads the committed files, re-runs the benches into a
//! temp directory, and exits nonzero when any [`BaselineReport`] holds
//! a regression.
//!
//! Metrics are discovered by walking the baseline document and
//! classifying leaf keys by name ([`MetricClass::of_key`]): throughput
//! counters may drop up to 10 % before the gate trips (wall-clock noise
//! on shared CI runners), tail latencies may rise up to 25 %, measured
//! byte counters must match **exactly** (they are deterministic pure
//! functions of shape + selection — see [`super::traffic`]), and
//! `hot_path_allocs` must be exactly zero in the fresh run regardless
//! of what the baseline recorded. Array values (table `rows`) are not
//! walked: positional compares are brittle under row insertion, and
//! every gated metric is exposed as a named object field.

use crate::util::json::Json;

/// Relative throughput drop tolerated before flagging (noise window for
/// wall-clock-derived rates on shared machines).
pub const THROUGHPUT_DROP_TOL: f64 = 0.10;
/// Relative tail-latency rise tolerated before flagging.
pub const TAIL_LATENCY_RISE_TOL: f64 = 0.25;

/// How a metric is judged against its baseline value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricClass {
    /// Higher is better; regression when fresh < baseline × (1 − 10 %).
    Throughput,
    /// Lower is better; regression when fresh > baseline × (1 + 25 %).
    TailLatency,
    /// Deterministic byte counter; regression on any mismatch (a
    /// legitimate change re-baselines explicitly).
    Bytes,
    /// Must be exactly zero in the fresh run (the zero-allocation
    /// contract), whatever the baseline holds.
    ExactZero,
}

impl MetricClass {
    /// Classify a JSON object key; `None` means the field is not gated.
    pub fn of_key(key: &str) -> Option<MetricClass> {
        if key == "hot_path_allocs" {
            Some(MetricClass::ExactZero)
        } else if key == "tokens_per_s" || key.ends_with("gflops") || key.ends_with("_per_s") {
            Some(MetricClass::Throughput)
        } else if key == "p99" {
            Some(MetricClass::TailLatency)
        } else if key == "bytes" || key.ends_with("_bytes") {
            Some(MetricClass::Bytes)
        } else {
            None
        }
    }

    /// Judge `fresh` against `base`; `Some(reason)` on regression.
    pub fn check(self, base: f64, fresh: f64) -> Option<String> {
        match self {
            MetricClass::Throughput => {
                if fresh < base * (1.0 - THROUGHPUT_DROP_TOL) {
                    Some(format!(
                        "throughput {fresh:.3} below baseline {base:.3} − {:.0}%",
                        THROUGHPUT_DROP_TOL * 100.0
                    ))
                } else {
                    None
                }
            }
            MetricClass::TailLatency => {
                if fresh > base * (1.0 + TAIL_LATENCY_RISE_TOL) {
                    Some(format!(
                        "tail latency {fresh:.4} above baseline {base:.4} + {:.0}%",
                        TAIL_LATENCY_RISE_TOL * 100.0
                    ))
                } else {
                    None
                }
            }
            MetricClass::Bytes => {
                if fresh != base {
                    Some(format!("byte counter {fresh} != baseline {base} (exact match required)"))
                } else {
                    None
                }
            }
            MetricClass::ExactZero => {
                if fresh != 0.0 {
                    Some(format!("expected exactly 0, measured {fresh}"))
                } else {
                    None
                }
            }
        }
    }
}

/// Result of comparing one fresh bench document against its baseline.
#[derive(Clone, Debug, Default)]
pub struct BaselineReport {
    /// Bench name (the `BENCH_<name>.json` stem).
    pub bench: String,
    /// Gated metrics found in the baseline and compared.
    pub compared: usize,
    /// Regressions, one `"path: reason"` line each.
    pub regressions: Vec<String>,
    /// Gated baseline metrics absent (or non-numeric) in the fresh run
    /// — treated as regressions by [`BaselineReport::is_ok`]: a metric
    /// silently disappearing is exactly what a gate must catch.
    pub missing: Vec<String>,
}

impl BaselineReport {
    /// Gate verdict: no regressions and no vanished metrics.
    pub fn is_ok(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Compare a fresh bench document against its committed baseline. Pure:
/// both documents are already parsed; the caller owns file IO.
pub fn compare_benches(bench: &str, baseline: &Json, fresh: &Json) -> BaselineReport {
    let mut report = BaselineReport { bench: bench.to_string(), ..BaselineReport::default() };
    walk("", baseline, fresh, &mut report);
    report
}

fn walk(path: &str, base: &Json, fresh: &Json, report: &mut BaselineReport) {
    let Json::Obj(bo) = base else { return };
    for (key, bval) in bo {
        let sub = if path.is_empty() { key.clone() } else { format!("{path}.{key}") };
        let fval = fresh.get(key);
        match bval {
            Json::Obj(_) => {
                // Descend only when the fresh side is also an object;
                // a vanished subtree surfaces via its gated leaves.
                if let Some(f) = fval {
                    walk(&sub, bval, f, report);
                } else if subtree_has_gated(bval) {
                    report.missing.push(sub);
                }
            }
            Json::Num(b) => {
                let Some(class) = MetricClass::of_key(key) else { continue };
                match fval.and_then(|f| f.as_f64()) {
                    None => report.missing.push(sub),
                    Some(f) => {
                        report.compared += 1;
                        if let Some(reason) = class.check(*b, f) {
                            report.regressions.push(format!("{sub}: {reason}"));
                        }
                    }
                }
            }
            // Arrays (table rows) are positional — not gated here.
            _ => {}
        }
    }
}

fn subtree_has_gated(v: &Json) -> bool {
    match v {
        Json::Obj(o) => o.iter().any(|(k, v)| {
            (matches!(v, Json::Num(_)) && MetricClass::of_key(k).is_some()) || subtree_has_gated(v)
        }),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(tokens_per_s: f64, p99: f64, hot: f64, bytes: f64) -> Json {
        Json::obj(vec![
            ("bench", Json::str("decode")),
            ("tokens_per_s", Json::num(tokens_per_s)),
            (
                "step_latency_ms",
                Json::obj(vec![("p50", Json::num(p99 / 2.0)), ("p99", Json::num(p99))]),
            ),
            ("hot_path_allocs", Json::num(hot)),
            (
                "traffic",
                Json::obj(vec![("q_ingest_bytes", Json::num(bytes))]),
            ),
        ])
    }

    #[test]
    fn identical_docs_pass() {
        let b = doc(100.0, 2.0, 0.0, 4096.0);
        let r = compare_benches("decode", &b, &b);
        assert!(r.is_ok(), "{:?}", r);
        // tokens_per_s + p99 + hot_path_allocs + q_ingest_bytes.
        assert_eq!(r.compared, 4);
    }

    #[test]
    fn throughput_window_is_noise_aware() {
        let b = doc(100.0, 2.0, 0.0, 64.0);
        // 5% slower: inside the window.
        assert!(compare_benches("decode", &b, &doc(95.0, 2.0, 0.0, 64.0)).is_ok());
        // 15% slower: regression.
        let r = compare_benches("decode", &b, &doc(85.0, 2.0, 0.0, 64.0));
        assert!(!r.is_ok());
        assert!(r.regressions[0].contains("tokens_per_s"), "{:?}", r.regressions);
        // Faster is never a regression.
        assert!(compare_benches("decode", &b, &doc(250.0, 2.0, 0.0, 64.0)).is_ok());
    }

    #[test]
    fn tail_latency_rise_flags_but_p50_is_not_gated() {
        let b = doc(100.0, 2.0, 0.0, 64.0);
        assert!(compare_benches("decode", &b, &doc(100.0, 2.4, 0.0, 64.0)).is_ok());
        let r = compare_benches("decode", &b, &doc(100.0, 3.0, 0.0, 64.0));
        assert_eq!(r.regressions.len(), 1);
        assert!(r.regressions[0].starts_with("step_latency_ms.p99"));
    }

    #[test]
    fn bytes_must_match_exactly_and_allocs_must_be_zero() {
        let b = doc(100.0, 2.0, 0.0, 4096.0);
        let r = compare_benches("decode", &b, &doc(100.0, 2.0, 0.0, 4097.0));
        assert!(r.regressions.iter().any(|m| m.contains("q_ingest_bytes")), "{:?}", r);
        // An injected hot-path allocation trips the gate even though the
        // "relative" change from 0 is undefined.
        let r = compare_benches("decode", &b, &doc(100.0, 2.0, 3.0, 4096.0));
        assert!(r.regressions.iter().any(|m| m.contains("hot_path_allocs")), "{:?}", r);
    }

    #[test]
    fn vanished_metric_is_a_failure() {
        let b = doc(100.0, 2.0, 0.0, 64.0);
        let fresh = Json::obj(vec![("bench", Json::str("decode"))]);
        let r = compare_benches("decode", &b, &fresh);
        assert!(!r.is_ok());
        assert!(r.missing.iter().any(|m| m == "tokens_per_s"), "{:?}", r.missing);
        assert!(
            r.missing.iter().any(|m| m.contains("step_latency_ms") || m.contains("traffic")),
            "vanished subtrees with gated leaves must be reported: {:?}",
            r.missing
        );
    }

    #[test]
    fn unclassified_fields_are_ignored() {
        let b = Json::obj(vec![("wall_s", Json::num(1.0)), ("rows", Json::num(5.0))]);
        let f = Json::obj(vec![("wall_s", Json::num(99.0)), ("rows", Json::num(1.0))]);
        let r = compare_benches("x", &b, &f);
        assert!(r.is_ok());
        assert_eq!(r.compared, 0);
    }
}
