//! Span-based tracer: per-worker preallocated ring buffers of fixed-size
//! span records, emitted from the tile-engine stage bodies.
//!
//! The tracer is subordinate to the zero-allocation contract it observes
//! (DESIGN.md §8): [`SpanRing::record`] is an index write into storage
//! reserved ahead of time, so it is legal *inside* the metered stage
//! windows, and the warm-workspace property tests assert
//! `hot_path_allocs == 0` with tracing enabled. The disabled path is one
//! relaxed atomic load and a branch.
//!
//! Mechanics:
//!
//! * A process-wide monotonic epoch ([`set_enabled`] pins it on first
//!   enable) turns `Instant`s into `u64` nanosecond ticks, so a span is
//!   plain-old-data: stage + execution path + tile/row id + worker/shard +
//!   session + start/end ticks.
//! * Each [`TileWorkspace`](crate::pipeline::TileWorkspace) owns one
//!   [`SpanRing`] — workspaces are per-worker and live in the
//!   [`WorkspacePool`](crate::pipeline::WorkspacePool), so ring storage
//!   survives across requests exactly like the stage buffers do. Ring
//!   storage is reserved in the front-end preambles (outside the metered
//!   windows) via [`SpanRing::reserve_if_enabled`], and only when tracing
//!   is on — a disabled tracer costs zero bytes.
//! * When the ring is full the oldest span is overwritten (the ring keeps
//!   the *most recent* [`RING_CAPACITY`] spans per worker); draining
//!   returns spans oldest-first and resets the ring.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Spans retained per worker ring before overwrite.
pub const RING_CAPACITY: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Turn tracing on or off process-wide. Enabling pins the monotonic
/// epoch; rings reserve storage lazily at the next front-end preamble.
pub fn set_enabled(on: bool) {
    if on {
        let _ = EPOCH.get_or_init(Instant::now);
    }
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is tracing enabled? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Nanoseconds from the trace epoch to `t` (0 if tracing never enabled).
#[inline]
pub fn ns_since_epoch(t: Instant) -> u64 {
    match EPOCH.get() {
        Some(e) => t.saturating_duration_since(*e).as_nanos() as u64,
        None => 0,
    }
}

/// Pipeline stage a span measures (the paper's four stages plus the
/// sharded engine's ring-transfer and candidate-merge phases).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Stage {
    #[default]
    Predict,
    Topk,
    KvGen,
    Formal,
    /// Sharded only: forwarding the Q block + candidates to the ring
    /// neighbor and waiting for the incoming block.
    Ring,
    /// Sharded only: the home worker's distributed top-k merge.
    Merge,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Predict => "predict",
            Stage::Topk => "topk",
            Stage::KvGen => "kv_gen",
            Stage::Formal => "formal",
            Stage::Ring => "ring",
            Stage::Merge => "merge",
        }
    }
}

/// Which front-end produced a span.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum ExecPath {
    #[default]
    Prefill,
    Decode,
    Sharded,
}

impl ExecPath {
    pub fn name(self) -> &'static str {
        match self {
            ExecPath::Prefill => "prefill",
            ExecPath::Decode => "decode",
            ExecPath::Sharded => "sharded",
        }
    }
}

/// One fixed-size span record (plain old data, `Copy`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Span {
    pub stage: Stage,
    pub path: ExecPath,
    /// Query-tile index (prefill/sharded Q block) or absolute row
    /// position (decode).
    pub id: u32,
    /// Worker index (prefill/decode) or shard index (sharded).
    pub worker: u32,
    /// Decode session id; 0 for stateless runs.
    pub session: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Bytes the stage body moved during this span (the workspace
    /// [`TrafficCounter`](super::traffic::TrafficCounter) delta); 0 when
    /// traffic counting is disabled.
    pub bytes: u64,
}

impl Span {
    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

/// Per-worker span ring buffer, owned by a `TileWorkspace`.
#[derive(Debug, Default)]
pub struct SpanRing {
    /// Reserved to `RING_CAPACITY` and filled with defaults on reserve;
    /// `record` only index-writes, so it never reallocates.
    buf: Vec<Span>,
    next: usize,
    filled: usize,
    /// Worker/shard index stamped into spans; set by the front-end
    /// preamble, outside the metered windows.
    pub worker: u32,
    /// Session id stamped into spans (decode); 0 for stateless runs.
    pub session: u64,
}

impl SpanRing {
    pub fn new() -> Self {
        SpanRing::default()
    }

    /// Reserve ring storage iff tracing is enabled. Must be called from a
    /// front-end preamble, OUTSIDE the metered allocation windows; after
    /// it, `record` is allocation-free forever.
    pub fn reserve_if_enabled(&mut self) {
        if enabled() && self.buf.is_empty() {
            self.buf = vec![Span::default(); RING_CAPACITY];
        }
    }

    /// Record a span from two `Instant`s (the stage body's existing
    /// timing reads) plus the bytes the stage moved. No-op when tracing
    /// is disabled or the ring was never reserved; never allocates.
    #[inline]
    pub fn record(
        &mut self,
        stage: Stage,
        path: ExecPath,
        id: u32,
        t0: Instant,
        t1: Instant,
        bytes: u64,
    ) {
        if !enabled() || self.buf.is_empty() {
            return;
        }
        self.buf[self.next] = Span {
            stage,
            path,
            id,
            worker: self.worker,
            session: self.session,
            start_ns: ns_since_epoch(t0),
            end_ns: ns_since_epoch(t1),
            bytes,
        };
        self.next = (self.next + 1) % self.buf.len();
        self.filled = (self.filled + 1).min(self.buf.len());
    }

    /// Spans currently held.
    pub fn len(&self) -> usize {
        self.filled
    }

    pub fn is_empty(&self) -> bool {
        self.filled == 0
    }

    /// Bytes of reserved ring storage (0 until tracing first enables).
    pub fn capacity_bytes(&self) -> usize {
        self.buf.capacity() * std::mem::size_of::<Span>()
    }

    /// Append held spans to `out`, oldest first, and reset the ring
    /// (storage stays reserved).
    pub fn drain_into(&mut self, out: &mut Vec<Span>) {
        if self.filled == self.buf.len() && !self.buf.is_empty() {
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
        } else {
            out.extend_from_slice(&self.buf[..self.filled]);
        }
        self.next = 0;
        self.filled = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0t1() -> (Instant, Instant) {
        let t0 = Instant::now();
        (t0, t0 + std::time::Duration::from_nanos(500))
    }

    #[test]
    fn disabled_ring_records_nothing_and_holds_no_storage() {
        // Do not toggle the global flag here (tests share the process);
        // an unreserved ring drops records regardless of the flag.
        let mut r = SpanRing::new();
        let (t0, t1) = t0t1();
        r.record(Stage::Predict, ExecPath::Prefill, 0, t0, t1, 0);
        assert_eq!(r.len(), 0);
        assert_eq!(r.capacity_bytes(), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_drains_in_order() {
        set_enabled(true);
        let mut r = SpanRing::new();
        r.reserve_if_enabled();
        let (t0, t1) = t0t1();
        for i in 0..(RING_CAPACITY + 10) as u32 {
            r.record(Stage::Formal, ExecPath::Decode, i, t0, t1, u64::from(i));
        }
        assert_eq!(r.len(), RING_CAPACITY);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out.len(), RING_CAPACITY);
        // Oldest surviving span is #10; order is monotone in id.
        assert_eq!(out.first().unwrap().id, 10);
        assert_eq!(out.last().unwrap().id, (RING_CAPACITY + 10 - 1) as u32);
        assert!(out.windows(2).all(|w| w[0].id < w[1].id));
        assert_eq!(r.len(), 0);
        assert!(r.capacity_bytes() > 0, "drain keeps storage reserved");
    }

    #[test]
    fn spans_carry_context_and_ticks() {
        set_enabled(true);
        let mut r = SpanRing::new();
        r.reserve_if_enabled();
        r.worker = 3;
        r.session = 42;
        let (t0, t1) = t0t1();
        r.record(Stage::KvGen, ExecPath::Sharded, 7, t0, t1, 640);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        let s = out[0];
        assert_eq!((s.worker, s.session, s.id), (3, 42, 7));
        assert_eq!(s.bytes, 640);
        assert_eq!(s.stage, Stage::KvGen);
        assert_eq!(s.path, ExecPath::Sharded);
        assert!(s.end_ns >= s.start_ns);
        assert_eq!(s.dur_ns(), s.end_ns - s.start_ns);
    }
}
