//! Chrome trace-event JSON export for captured spans.
//!
//! Produces the `chrome://tracing` / Perfetto "JSON Object Format": a
//! `traceEvents` array of complete (`ph: "X"`) events with microsecond
//! timestamps. Each execution path maps to a process row (pid 1/2/3 for
//! prefill/decode/sharded, named via `process_name` metadata events) and
//! each worker/shard to a thread row, so the viewer lays the trace out as
//! the paper's cross-stage timeline: one lane per core, stage spans
//! interleaving along it.

use super::trace::{ExecPath, Span};
use crate::util::json::Json;

fn pid(path: ExecPath) -> f64 {
    match path {
        ExecPath::Prefill => 1.0,
        ExecPath::Decode => 2.0,
        ExecPath::Sharded => 3.0,
    }
}

/// Build the Chrome trace-event JSON document for `spans`. Events are
/// sorted by start tick (the viewer requires nothing, but monotonic `ts`
/// makes the file diff- and validation-friendly); durations are clamped
/// to ≥ 1 ns so no event renders as zero-width.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let mut sorted: Vec<&Span> = spans.iter().collect();
    sorted.sort_by_key(|s| (s.start_ns, s.end_ns, s.worker));

    let mut events = Vec::with_capacity(sorted.len() + 6);
    // Name the per-path process rows (metadata events, ts-less).
    for path in [ExecPath::Prefill, ExecPath::Decode, ExecPath::Sharded] {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid(path))),
            ("args", Json::obj(vec![("name", Json::str(path.name()))])),
        ]));
    }
    // Name each thread row once per distinct (pid, tid): "worker N" on
    // the pooled paths, "shard N" on the ring.
    let mut lanes: Vec<(ExecPath, u32)> = sorted.iter().map(|s| (s.path, s.worker)).collect();
    lanes.sort_by_key(|&(p, w)| (pid(p) as u64, w));
    lanes.dedup();
    for (path, worker) in lanes {
        let label = match path {
            ExecPath::Sharded => format!("shard {worker}"),
            _ => format!("worker {worker}"),
        };
        events.push(Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(pid(path))),
            ("tid", Json::num(worker as f64)),
            ("args", Json::obj(vec![("name", Json::str(&label))])),
        ]));
    }
    for s in sorted {
        let dur_ns = s.dur_ns().max(1);
        events.push(Json::obj(vec![
            ("name", Json::str(s.stage.name())),
            ("cat", Json::str(s.path.name())),
            ("ph", Json::str("X")),
            ("ts", Json::num(s.start_ns as f64 / 1e3)),
            ("dur", Json::num(dur_ns as f64 / 1e3)),
            ("pid", Json::num(pid(s.path))),
            ("tid", Json::num(s.worker as f64)),
            (
                "args",
                Json::obj(vec![
                    ("id", Json::num(s.id as f64)),
                    ("session", Json::num(s.session as f64)),
                    ("bytes", Json::num(s.bytes as f64)),
                ]),
            ),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
    ])
}

/// Validate a Chrome trace document: a `traceEvents` array whose `X`
/// events carry name/ts/dur/pid/tid plus a numeric `args.bytes`, with
/// strictly positive durations, non-decreasing timestamps, and a
/// `thread_name` metadata event for every (pid, tid) lane an `X` event
/// uses. Returns the number of `X` events.
pub fn validate_chrome_trace(doc: &Json) -> Result<usize, String> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut named_lanes: Vec<(u64, u64)> = Vec::new();
    for e in events {
        if e.get("ph").and_then(|p| p.as_str()) == Some("M")
            && e.get("name").and_then(|n| n.as_str()) == Some("thread_name")
        {
            let (Some(p), Some(t)) = (
                e.get("pid").and_then(|v| v.as_f64()),
                e.get("tid").and_then(|v| v.as_f64()),
            ) else {
                return Err("thread_name metadata without pid/tid".to_string());
            };
            named_lanes.push((p as u64, t as u64));
        }
    }
    let mut n = 0usize;
    let mut last_ts = f64::NEG_INFINITY;
    for (i, e) in events.iter().enumerate() {
        let ph = e.get("ph").and_then(|p| p.as_str()).ok_or(format!("event {i}: missing ph"))?;
        if ph != "X" {
            continue;
        }
        for key in ["name", "ts", "dur", "pid", "tid"] {
            if e.get(key).is_none() {
                return Err(format!("event {i}: missing {key}"));
            }
        }
        let ts = e.get("ts").and_then(|t| t.as_f64()).ok_or(format!("event {i}: bad ts"))?;
        let dur = e.get("dur").and_then(|d| d.as_f64()).ok_or(format!("event {i}: bad dur"))?;
        if dur <= 0.0 {
            return Err(format!("event {i}: zero-duration span"));
        }
        if ts < last_ts {
            return Err(format!("event {i}: non-monotonic ts ({ts} after {last_ts})"));
        }
        let bytes = e
            .get("args")
            .and_then(|a| a.get("bytes"))
            .and_then(|b| b.as_f64())
            .ok_or(format!("event {i}: missing numeric args.bytes"))?;
        if bytes < 0.0 {
            return Err(format!("event {i}: negative args.bytes"));
        }
        let lane = (
            e.get("pid").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            e.get("tid").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        );
        if !named_lanes.contains(&lane) {
            return Err(format!(
                "event {i}: lane pid={} tid={} has no thread_name metadata",
                lane.0, lane.1
            ));
        }
        last_ts = ts;
        n += 1;
    }
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Stage;

    fn span(stage: Stage, path: ExecPath, start: u64, end: u64) -> Span {
        Span { stage, path, id: 1, worker: 0, session: 0, start_ns: start, end_ns: end, bytes: 320 }
    }

    #[test]
    fn export_is_valid_and_roundtrips() {
        let spans = vec![
            span(Stage::Topk, ExecPath::Prefill, 2_000, 3_000),
            span(Stage::Predict, ExecPath::Prefill, 1_000, 2_000),
            span(Stage::Formal, ExecPath::Sharded, 4_000, 9_000),
        ];
        let doc = chrome_trace(&spans);
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 3);
        // Writer/parser round trip through the textual form.
        let reparsed = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(validate_chrome_trace(&reparsed).unwrap(), 3);
        // Events got sorted: predict (1µs) precedes topk (2µs).
        let evs = reparsed.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<&Json> =
            evs.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")).collect();
        assert_eq!(xs[0].get("name").unwrap().as_str(), Some("predict"));
        assert_eq!(xs[0].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(xs[0].get("dur").unwrap().as_f64(), Some(1.0));
        // Every X event carries its byte attribution.
        for x in &xs {
            assert_eq!(x.get("args").unwrap().get("bytes").unwrap().as_f64(), Some(320.0));
        }
        // One thread_name lane per distinct (pid, tid): prefill worker 0
        // and shard 0.
        let lanes: Vec<String> = evs
            .iter()
            .filter(|e| e.get("name").and_then(|n| n.as_str()) == Some("thread_name"))
            .map(|e| {
                e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string()
            })
            .collect();
        assert_eq!(lanes, vec!["worker 0".to_string(), "shard 0".to_string()]);
    }

    #[test]
    fn zero_duration_spans_are_clamped_not_emitted_as_zero() {
        let doc = chrome_trace(&[span(Stage::KvGen, ExecPath::Decode, 500, 500)]);
        assert_eq!(validate_chrome_trace(&doc).unwrap(), 1);
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace(&Json::obj(vec![])).is_err());
        let bad = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str("predict")),
                ("ts", Json::num(1.0)),
                ("dur", Json::num(0.0)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(0.0)),
            ])]),
        )]);
        assert!(validate_chrome_trace(&bad).unwrap_err().contains("zero-duration"));
        // An X event without args.bytes fails even on a named lane.
        let lane_meta = Json::obj(vec![
            ("name", Json::str("thread_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str("worker 0"))])),
        ]);
        let x = |args: Json| {
            Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str("predict")),
                ("ts", Json::num(1.0)),
                ("dur", Json::num(1.0)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(0.0)),
                ("args", args),
            ])
        };
        let no_bytes = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![lane_meta.clone(), x(Json::obj(vec![("id", Json::num(1.0))]))]),
        )]);
        assert!(validate_chrome_trace(&no_bytes).unwrap_err().contains("args.bytes"));
        // An X event on an unnamed lane fails.
        let unnamed = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![x(Json::obj(vec![("bytes", Json::num(64.0))]))]),
        )]);
        assert!(validate_chrome_trace(&unnamed).unwrap_err().contains("thread_name"));
        // Both present validates.
        let good = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![lane_meta, x(Json::obj(vec![("bytes", Json::num(64.0))]))]),
        )]);
        assert_eq!(validate_chrome_trace(&good).unwrap(), 1);
    }
}
