//! Measured byte-level memory-traffic accounting for the execution
//! paths — the counterpart of the *modeled* charges in
//! [`crate::arith::OpCounter`] and the *simulated* per-stage DRAM stream
//! of [`crate::sim::pipeline`].
//!
//! A [`TrafficCounter`] is a plain bag of `u64` byte counters with the
//! same zero-allocation discipline as [`super::trace::SpanRing`]: one
//! lives inside every [`crate::pipeline::TileWorkspace`], the stage
//! bodies bump it with pure integer arithmetic inside the metered
//! allocation windows, and the pool drains it after a run. Counting is
//! gated on a process-wide flag ([`set_enabled`]) so an untraced run
//! pays one relaxed atomic load per stage and the counted/uncounted
//! executions are bit-identical (property-tested in
//! `tests/prop_traffic.rs`).
//!
//! # DRAM-class vs SRAM-class counters
//!
//! The paper's traffic story distinguishes bytes that cross the chip
//! boundary from bytes that circulate in on-chip buffers. The counter
//! mirrors that split:
//!
//! * **DRAM-class ingest/egress** (`q_ingest`, `key_ingest`, `x_ingest`,
//!   `out_egress`): each datum is counted **once**, at the site where it
//!   first enters (or finally leaves) the tile pipeline. These are pure
//!   functions of shape + selection — identical at every thread count —
//!   and are the side reconciled against the cycle simulator's per-stage
//!   DRAM predictions (`star bench traffic`, DESIGN.md §11).
//! * **SRAM-class movement** (`score_write`, `score_read`,
//!   `operand_read`, `kv_gather`, `formal_kv`, `accum`): repeated
//!   traffic through the workspace-resident tile buffers — the bytes
//!   cross-stage tiling keeps *off* DRAM.
//! * **Ring + cache** (`ring_payload`, `cache_append`, `cache_remat`):
//!   sharded interconnect payloads and paged-KV-cache page traffic.
//!
//! Scheduler behavior (chunk grabs, steals, per-worker tile counts) is
//! schedule-dependent — it legitimately differs between runs — so it
//! lives in the separate [`SchedStats`] and is excluded from the
//! byte-reproducibility contract.

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Globally enable or disable traffic counting. Disabled counting sites
/// cost one relaxed atomic load; enabling never changes outputs,
/// selections or stalls (bit-invisibility is property-tested).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether traffic counting is currently enabled.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Byte-level traffic counters for one workspace / one run / one
/// metrics window (the same struct serves all three granularities;
/// [`TrafficCounter::merge`] is an order-independent field-wise sum).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TrafficCounter {
    // ---- DRAM-class ingest/egress (counted once per datum) ----
    /// Query rows staged into the formal-compute tile (f32).
    pub q_ingest_bytes: u64,
    /// Key-side bytes read to build the score operand: the Kᵀ transpose
    /// or prepared-operand build in prefill/sharded, the per-row operand
    /// freeze at cache append in decode (f32).
    pub key_ingest_bytes: u64,
    /// Activation rows streamed for on-demand KV generation (f32).
    pub x_ingest_bytes: u64,
    /// Output rows written out of the formal stage (f32).
    pub out_egress_bytes: u64,
    // ---- SRAM-class movement (workspace-resident tile buffers) ----
    /// Estimated-score tile writes (f32).
    pub score_write_bytes: u64,
    /// Score reads by the top-k stage (f32).
    pub score_read_bytes: u64,
    /// Quantized/encoded operand reads during scoring (~1 B/element;
    /// f32 reads for the oracle score path).
    pub operand_read_bytes: u64,
    /// Gathered K/V rows staged into the workspace union buffers: f32
    /// reads (`8d`/row) from exact-residency pages, dequantizing i8
    /// reads (`2d + 8`/row) from quantized-only pages.
    pub kv_gather_bytes: u64,
    /// K/V rows streamed through the formal kernel (f32, per selected
    /// key — the SU-FA operand stream).
    pub formal_kv_bytes: u64,
    /// SU-FA accumulator traffic: logit read+write per selected key.
    pub accum_bytes: u64,
    // ---- Sharded ring + paged KV cache ----
    /// Q-block payload bytes sent over the sharded ring (wire bytes).
    pub ring_payload_bytes: u64,
    /// f32 K/V bytes appended to cache pages.
    pub cache_append_bytes: u64,
    /// f32 K/V bytes re-materialized from host history into pages.
    pub cache_remat_bytes: u64,
}

impl TrafficCounter {
    /// A zeroed counter.
    pub fn new() -> TrafficCounter {
        TrafficCounter::default()
    }

    /// Field-wise sum. Commutative and associative, so merge order —
    /// and therefore worker scheduling — cannot change the totals.
    pub fn merge(&mut self, o: &TrafficCounter) {
        self.q_ingest_bytes += o.q_ingest_bytes;
        self.key_ingest_bytes += o.key_ingest_bytes;
        self.x_ingest_bytes += o.x_ingest_bytes;
        self.out_egress_bytes += o.out_egress_bytes;
        self.score_write_bytes += o.score_write_bytes;
        self.score_read_bytes += o.score_read_bytes;
        self.operand_read_bytes += o.operand_read_bytes;
        self.kv_gather_bytes += o.kv_gather_bytes;
        self.formal_kv_bytes += o.formal_kv_bytes;
        self.accum_bytes += o.accum_bytes;
        self.ring_payload_bytes += o.ring_payload_bytes;
        self.cache_append_bytes += o.cache_append_bytes;
        self.cache_remat_bytes += o.cache_remat_bytes;
    }

    /// Drain: return the current counts and reset to zero.
    pub fn take(&mut self) -> TrafficCounter {
        std::mem::take(self)
    }

    /// Sum of every byte counter — the per-span `bytes` attribution the
    /// Chrome trace export carries in `args`.
    pub fn total_bytes(&self) -> u64 {
        self.q_ingest_bytes
            + self.key_ingest_bytes
            + self.x_ingest_bytes
            + self.out_egress_bytes
            + self.score_write_bytes
            + self.score_read_bytes
            + self.operand_read_bytes
            + self.kv_gather_bytes
            + self.formal_kv_bytes
            + self.accum_bytes
            + self.ring_payload_bytes
            + self.cache_append_bytes
            + self.cache_remat_bytes
    }

    /// DRAM-class subtotal (the side reconciled against the simulator).
    pub fn dram_class_bytes(&self) -> u64 {
        self.q_ingest_bytes + self.key_ingest_bytes + self.x_ingest_bytes + self.out_egress_bytes
    }

    /// SRAM-class subtotal (tile-buffer movement).
    pub fn sram_class_bytes(&self) -> u64 {
        self.score_write_bytes
            + self.score_read_bytes
            + self.operand_read_bytes
            + self.kv_gather_bytes
            + self.formal_kv_bytes
            + self.accum_bytes
    }

    /// `(name, value)` view over every counter, in declaration order —
    /// the one list the JSON writers, the Prometheus exposition and the
    /// schema cross-readers share.
    pub fn fields(&self) -> [(&'static str, u64); 13] {
        [
            ("q_ingest_bytes", self.q_ingest_bytes),
            ("key_ingest_bytes", self.key_ingest_bytes),
            ("x_ingest_bytes", self.x_ingest_bytes),
            ("out_egress_bytes", self.out_egress_bytes),
            ("score_write_bytes", self.score_write_bytes),
            ("score_read_bytes", self.score_read_bytes),
            ("operand_read_bytes", self.operand_read_bytes),
            ("kv_gather_bytes", self.kv_gather_bytes),
            ("formal_kv_bytes", self.formal_kv_bytes),
            ("accum_bytes", self.accum_bytes),
            ("ring_payload_bytes", self.ring_payload_bytes),
            ("cache_append_bytes", self.cache_append_bytes),
            ("cache_remat_bytes", self.cache_remat_bytes),
        ]
    }
}

/// Work-stealing scheduler counters for one parallel section (or a
/// cumulative metrics window). Unlike [`TrafficCounter`], these are
/// *schedule-dependent* — a fast worker legitimately claims more chunks
/// on one run than the next — so they are reported separately and
/// excluded from the byte-reproducibility contract.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedStats {
    /// Worker threads that participated.
    pub workers: u64,
    /// Successful chunk claims off the shared cursor.
    pub chunk_grabs: u64,
    /// Claims beyond each worker's first — extra chunks a worker came
    /// back for instead of idling (the work-stealing events).
    pub steals: u64,
    /// Tiles (or decode rows / sharded Q blocks) executed.
    pub tiles: u64,
    /// Tiles run by the busiest worker.
    pub max_worker_tiles: u64,
}

impl SchedStats {
    /// Stats for a degenerate single-worker section.
    pub fn single(tiles: u64) -> SchedStats {
        let grabs = u64::from(tiles > 0);
        SchedStats { workers: 1, chunk_grabs: grabs, steals: 0, tiles, max_worker_tiles: tiles }
    }

    /// Busiest-worker load relative to a perfect split
    /// (`max_worker_tiles / (tiles / workers)`; 1.0 is perfectly
    /// balanced). Cumulative windows report the aggregate ratio.
    pub fn imbalance(&self) -> f64 {
        if self.tiles == 0 || self.workers == 0 {
            return 1.0;
        }
        self.max_worker_tiles as f64 * self.workers as f64 / self.tiles as f64
    }

    /// Aggregate another section into this window: counts sum, worker
    /// width takes the maximum.
    pub fn merge(&mut self, o: &SchedStats) {
        self.workers = self.workers.max(o.workers);
        self.chunk_grabs += o.chunk_grabs;
        self.steals += o.steals;
        self.tiles += o.tiles;
        self.max_worker_tiles += o.max_worker_tiles;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(seed: u64) -> TrafficCounter {
        let mut t = TrafficCounter::new();
        t.q_ingest_bytes = seed;
        t.key_ingest_bytes = 2 * seed;
        t.x_ingest_bytes = 3 * seed;
        t.out_egress_bytes = 5 * seed;
        t.score_write_bytes = 7 * seed;
        t.score_read_bytes = 11 * seed;
        t.operand_read_bytes = 13 * seed;
        t.kv_gather_bytes = 17 * seed;
        t.formal_kv_bytes = 19 * seed;
        t.accum_bytes = 23 * seed;
        t.ring_payload_bytes = 29 * seed;
        t.cache_append_bytes = 31 * seed;
        t.cache_remat_bytes = 37 * seed;
        t
    }

    #[test]
    fn merge_is_order_independent() {
        let (a, b, c) = (sample(3), sample(5), sample(8));
        let mut x = TrafficCounter::new();
        x.merge(&a);
        x.merge(&b);
        x.merge(&c);
        let mut y = TrafficCounter::new();
        y.merge(&c);
        y.merge(&a);
        y.merge(&b);
        assert_eq!(x, y);
    }

    #[test]
    fn take_drains_and_resets() {
        let mut t = sample(4);
        let got = t.take();
        assert_eq!(got, sample(4));
        assert_eq!(t, TrafficCounter::default());
    }

    #[test]
    fn totals_cover_every_field() {
        let t = sample(1);
        let field_sum: u64 = t.fields().iter().map(|(_, v)| v).sum();
        assert_eq!(t.total_bytes(), field_sum);
        assert_eq!(
            t.total_bytes(),
            t.dram_class_bytes() + t.sram_class_bytes() + t.ring_payload_bytes
                + t.cache_append_bytes
                + t.cache_remat_bytes
        );
    }

    #[test]
    fn sched_imbalance_ratio() {
        let s = SchedStats { workers: 4, chunk_grabs: 9, steals: 5, tiles: 80, max_worker_tiles: 40 };
        assert!((s.imbalance() - 2.0).abs() < 1e-12);
        assert_eq!(SchedStats::single(7).imbalance(), 1.0);
        assert_eq!(SchedStats::default().imbalance(), 1.0);
        let mut m = SchedStats::single(10);
        m.merge(&s);
        assert_eq!(m.workers, 4);
        assert_eq!(m.tiles, 90);
        assert_eq!(m.chunk_grabs, 10);
    }

    #[test]
    fn enable_flag_roundtrips() {
        let was = enabled();
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(was);
    }
}
