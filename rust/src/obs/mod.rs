//! Observability: zero-allocation span tracing + fixed-storage histograms
//! (DESIGN.md §9).
//!
//! Three pieces, layered so that observing the system never perturbs the
//! properties under observation (bit-identical outputs, zero hot-path
//! allocations):
//!
//! * [`trace`] — a span tracer. Per-worker ring buffers of fixed-size
//!   [`Span`] records live inside the pooled `TileWorkspace`s; the
//!   tile-engine stage bodies stamp predict/top-k/KV-gen/SU-FA spans (and
//!   the sharded engine its ring/merge phases) from the `Instant` reads
//!   they already perform for `StageTiming`. Disabled cost: one relaxed
//!   atomic load per stage. Enabled cost: one index write — storage is
//!   reserved in the unmetered front-end preambles, so recording is legal
//!   inside the metered allocation windows.
//! * [`hist`] — HDR-style log-bucketed [`Histogram`]s (fixed arrays, no
//!   dependencies) behind the serving metrics and the bench percentiles:
//!   O(1) allocation-free record, mergeable, order-independent, with a
//!   saturating overflow bucket and exact min/max/mean.
//! * [`traffic`] — measured byte-level traffic counters with the same
//!   zero-allocation discipline: a [`TrafficCounter`] per pooled
//!   workspace, bumped by the stage bodies inside the metered windows,
//!   reconciled against the cycle simulator's per-stage DRAM predictions
//!   by `star bench traffic` (DESIGN.md §11).
//! * [`chrome`] / [`prom`] — exporters: Chrome trace-event JSON
//!   (`star trace <out.json>`, loadable in `chrome://tracing`/Perfetto)
//!   and Prometheus-style text exposition of the metrics histograms.
//! * [`baseline`] — the perf-regression gate: loads committed
//!   `BENCH_*.json` baselines and compares a fresh run under noise-aware
//!   per-metric-class tolerances (`star bench check`).

pub mod baseline;
pub mod chrome;
pub mod hist;
pub mod prom;
pub mod trace;
pub mod traffic;

pub use baseline::{compare_benches, BaselineReport, MetricClass};
pub use chrome::{chrome_trace, validate_chrome_trace};
pub use hist::{HistSummary, Histogram};
pub use trace::{enabled, set_enabled, ExecPath, Span, SpanRing, Stage};
pub use traffic::{SchedStats, TrafficCounter};
