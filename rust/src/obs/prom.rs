//! Prometheus-style text exposition of histogram summaries.
//!
//! The serving metrics render as the classic `summary` metric family:
//! `{quantile="…"}` sample lines plus `_sum`/`_count`, one family per
//! histogram. This is the text format a scrape endpoint would serve; here
//! it is produced on demand next to the human-readable
//! [`MetricsSnapshot::render`](crate::coordinator::MetricsSnapshot::render).

use super::hist::HistSummary;

/// Append one summary-family exposition for `h` under `name` (base units
/// already applied by the caller — e.g. seconds). `labels` is either ""
/// or a `key="value"` list without braces, merged into each sample line.
pub fn write_summary(out: &mut String, name: &str, help: &str, labels: &str, h: &HistSummary) {
    write_summary_family(out, name, help, &[(labels, h)]);
}

/// Append one summary family carrying several labeled series (the
/// HELP/TYPE header is emitted once — exposition-format rule for
/// families that differ only by label, e.g. `class` or `stage`).
pub fn write_summary_family(out: &mut String, name: &str, help: &str, series: &[(&str, &HistSummary)]) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} summary");
    for (labels, h) in series {
        let sep = if labels.is_empty() { "" } else { "," };
        for (q, v) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)] {
            let _ = writeln!(out, "{name}{{{labels}{sep}quantile=\"{q}\"}} {v}");
        }
        let brace = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{brace} {}", h.mean * h.count as f64);
        let _ = writeln!(out, "{name}_count{brace} {}", h.count);
    }
}

/// Append a single gauge/counter sample.
pub fn write_value(out: &mut String, name: &str, help: &str, kind: &str, v: f64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {v}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_shape() {
        let h = HistSummary { count: 4, min: 1.0, max: 8.0, mean: 4.0, p50: 3.0, p95: 7.0, p99: 8.0 };
        let mut out = String::new();
        write_summary(&mut out, "star_request_latency_seconds", "end-to-end latency", "", &h);
        assert!(out.contains("# TYPE star_request_latency_seconds summary"));
        assert!(out.contains("star_request_latency_seconds{quantile=\"0.5\"} 3"));
        assert!(out.contains("star_request_latency_seconds_sum 16"));
        assert!(out.contains("star_request_latency_seconds_count 4"));

        let mut labeled = String::new();
        write_summary(&mut labeled, "star_ttft_seconds", "time to first token", "class=\"prefill\"", &h);
        assert!(labeled.contains("star_ttft_seconds{class=\"prefill\",quantile=\"0.95\"} 7"));
        assert!(labeled.contains("star_ttft_seconds_count{class=\"prefill\"} 4"));

        let mut g = String::new();
        write_value(&mut g, "star_requests_total", "admitted requests", "counter", 42.0);
        assert!(g.contains("star_requests_total 42"));
    }

    #[test]
    fn family_emits_one_header_for_many_series() {
        let h = HistSummary { count: 1, min: 2.0, max: 2.0, mean: 2.0, p50: 2.0, p95: 2.0, p99: 2.0 };
        let mut out = String::new();
        write_summary_family(
            &mut out,
            "star_stage_seconds",
            "per-stage busy time",
            &[("stage=\"predict\"", &h), ("stage=\"topk\"", &h)],
        );
        assert_eq!(out.matches("# TYPE star_stage_seconds summary").count(), 1);
        assert!(out.contains("star_stage_seconds{stage=\"predict\",quantile=\"0.5\"} 2"));
        assert!(out.contains("star_stage_seconds_count{stage=\"topk\"} 1"));
    }
}
