//! Prometheus-style text exposition of histogram summaries.
//!
//! The serving metrics render as the classic `summary` metric family:
//! `{quantile="…"}` sample lines plus `_sum`/`_count`, one family per
//! histogram. This is the text format a scrape endpoint would serve; here
//! it is produced on demand next to the human-readable
//! [`MetricsSnapshot::render`](crate::coordinator::MetricsSnapshot::render).

use super::hist::{bucket_high, HistSummary, Histogram, N_BUCKETS};

/// Append one summary-family exposition for `h` under `name` (base units
/// already applied by the caller — e.g. seconds). `labels` is either ""
/// or a `key="value"` list without braces, merged into each sample line.
pub fn write_summary(out: &mut String, name: &str, help: &str, labels: &str, h: &HistSummary) {
    write_summary_family(out, name, help, &[(labels, h)]);
}

/// Append one summary family carrying several labeled series (the
/// HELP/TYPE header is emitted once — exposition-format rule for
/// families that differ only by label, e.g. `class` or `stage`).
pub fn write_summary_family(out: &mut String, name: &str, help: &str, series: &[(&str, &HistSummary)]) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} summary");
    for (labels, h) in series {
        let sep = if labels.is_empty() { "" } else { "," };
        for (q, v) in [("0.5", h.p50), ("0.95", h.p95), ("0.99", h.p99)] {
            let _ = writeln!(out, "{name}{{{labels}{sep}quantile=\"{q}\"}} {v}");
        }
        let brace = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{brace} {}", h.mean * h.count as f64);
        let _ = writeln!(out, "{name}_count{brace} {}", h.count);
    }
}

/// Append one classic-histogram exposition for a full log-bucketed
/// [`Histogram`]: cumulative `_bucket{le="…"}` samples (occupied buckets
/// only — legal, the series stays cumulative), the mandatory `+Inf`
/// bucket, and `_sum`/`_count`. `scale` converts recorded integer units
/// to base units (e.g. `1e-9` for nanosecond samples exposed in
/// seconds); each `le` bound is the bucket's inclusive upper value.
pub fn write_histogram(
    out: &mut String,
    name: &str,
    help: &str,
    labels: &str,
    h: &Histogram,
    scale: f64,
) {
    write_histogram_family(out, name, help, &[(labels, h)], scale);
}

/// Append one histogram family carrying several labeled series under a
/// single HELP/TYPE header (same exposition-format rule as
/// [`write_summary_family`]).
pub fn write_histogram_family(
    out: &mut String,
    name: &str,
    help: &str,
    series: &[(&str, &Histogram)],
    scale: f64,
) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (labels, h) in series {
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for idx in 0..N_BUCKETS {
            let c = h.count_at(idx);
            if c == 0 {
                continue;
            }
            cum += c;
            let le = bucket_high(idx) as f64 * scale;
            let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", h.count());
        let brace = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{brace} {}", h.sum() * scale);
        let _ = writeln!(out, "{name}_count{brace} {}", h.count());
    }
}

/// Append a single gauge/counter sample.
pub fn write_value(out: &mut String, name: &str, help: &str, kind: &str, v: f64) {
    use std::fmt::Write;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {v}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_shape() {
        let h = HistSummary { count: 4, min: 1.0, max: 8.0, mean: 4.0, p50: 3.0, p95: 7.0, p99: 8.0 };
        let mut out = String::new();
        write_summary(&mut out, "star_request_latency_seconds", "end-to-end latency", "", &h);
        assert!(out.contains("# TYPE star_request_latency_seconds summary"));
        assert!(out.contains("star_request_latency_seconds{quantile=\"0.5\"} 3"));
        assert!(out.contains("star_request_latency_seconds_sum 16"));
        assert!(out.contains("star_request_latency_seconds_count 4"));

        let mut labeled = String::new();
        write_summary(&mut labeled, "star_ttft_seconds", "time to first token", "class=\"prefill\"", &h);
        assert!(labeled.contains("star_ttft_seconds{class=\"prefill\",quantile=\"0.95\"} 7"));
        assert!(labeled.contains("star_ttft_seconds_count{class=\"prefill\"} 4"));

        let mut g = String::new();
        write_value(&mut g, "star_requests_total", "admitted requests", "counter", 42.0);
        assert!(g.contains("star_requests_total 42"));
    }

    #[test]
    fn histogram_exposition_is_cumulative_and_conformant() {
        let mut h = Histogram::new();
        for v in [3u64, 3, 10, 1000, 1_000_000] {
            h.record(v);
        }
        let mut out = String::new();
        write_histogram(&mut out, "star_lat_ns", "latency histogram", "", &h, 1.0);
        assert!(out.contains("# TYPE star_lat_ns histogram"), "{out}");
        // Exact buckets below 2·SUB: value 3 holds both samples.
        assert!(out.contains("star_lat_ns_bucket{le=\"3\"} 2"), "{out}");
        assert!(out.contains("star_lat_ns_bucket{le=\"+Inf\"} 5"), "{out}");
        assert!(out.contains("star_lat_ns_count 5"), "{out}");
        assert!(out.contains(&format!("star_lat_ns_sum {}", h.sum())), "{out}");
        // Text-format conformance: every _bucket line carries a parseable
        // `le`, bounds strictly increase, and counts are non-decreasing
        // with the +Inf bucket equal to the total count.
        let mut prev_le = f64::NEG_INFINITY;
        let mut prev_c = 0u64;
        let mut saw_inf = false;
        for line in out.lines().filter(|l| l.starts_with("star_lat_ns_bucket")) {
            let le_raw = line.split("le=\"").nth(1).unwrap().split('"').next().unwrap();
            let le = if le_raw == "+Inf" {
                saw_inf = true;
                f64::INFINITY
            } else {
                le_raw.parse::<f64>().unwrap()
            };
            let c: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(le > prev_le, "le bounds must increase: {line}");
            assert!(c >= prev_c, "cumulative counts must not decrease: {line}");
            prev_le = le;
            prev_c = c;
        }
        assert!(saw_inf, "mandatory +Inf bucket missing:\n{out}");
        assert_eq!(prev_c, h.count());

        // Labeled family: one header, label merged before `le`.
        let mut fam = String::new();
        write_histogram_family(
            &mut fam,
            "star_stage_ns",
            "per-stage",
            &[("stage=\"predict\"", &h), ("stage=\"topk\"", &h)],
            1.0,
        );
        assert_eq!(fam.matches("# TYPE star_stage_ns histogram").count(), 1);
        assert!(fam.contains("star_stage_ns_bucket{stage=\"predict\",le=\"3\"} 2"), "{fam}");
        assert!(fam.contains("star_stage_ns_count{stage=\"topk\"} 5"), "{fam}");
        // The scale converts bounds to base units.
        let mut scaled = String::new();
        write_histogram(&mut scaled, "star_lat_seconds", "latency", "", &h, 1e-9);
        assert!(scaled.contains("le=\"0.000000003\"") || scaled.contains("le=\"3e-9\""), "{scaled}");
    }

    #[test]
    fn family_emits_one_header_for_many_series() {
        let h = HistSummary { count: 1, min: 2.0, max: 2.0, mean: 2.0, p50: 2.0, p95: 2.0, p99: 2.0 };
        let mut out = String::new();
        write_summary_family(
            &mut out,
            "star_stage_seconds",
            "per-stage busy time",
            &[("stage=\"predict\"", &h), ("stage=\"topk\"", &h)],
        );
        assert_eq!(out.matches("# TYPE star_stage_seconds summary").count(), 1);
        assert!(out.contains("star_stage_seconds{stage=\"predict\",quantile=\"0.5\"} 2"));
        assert!(out.contains("star_stage_seconds_count{stage=\"topk\"} 1"));
    }
}
