//! Log-bucketed histograms (HDR-style, fixed storage, no dependencies).
//!
//! [`Histogram`] records unsigned integer samples (serving code uses
//! nanoseconds; batch occupancy uses row counts) into a fixed array of
//! log₂ buckets with [`SUB`] linear sub-buckets per octave, bounding the
//! relative quantization error at `1/SUB` (≈3%) while keeping `record`
//! allocation-free and O(1). Values up to `2·SUB` are exact. Values above
//! [`MAX_TRACKED`] saturate into the final (overflow) bucket; the exact
//! running `min`/`max`/`sum` are kept separately, so only percentiles
//! saturate, never the extremes or the mean.
//!
//! This replaces `util::stats::Summary` in the serving metrics: `Summary`
//! stores every sample in a `Vec` (unbounded memory, allocates on the
//! record path) and derives percentiles from a clone+sort. A histogram is
//! fixed-size, mergeable across workers, and its percentiles are stable
//! under any record order.

/// Sub-buckets per octave (2^[`SUB_BITS`]).
pub const SUB_BITS: u32 = 5;
/// Number of linear sub-buckets per octave.
pub const SUB: u64 = 1 << SUB_BITS;
/// Highest MSB position tracked with full precision. 2^40 ns ≈ 18 min —
/// far beyond any request latency this system produces.
const MAX_TOP: u32 = 40;
/// Values above this saturate into the overflow bucket.
pub const MAX_TRACKED: u64 = (1u64 << (MAX_TOP + 1)) - 1;
/// Total bucket count: `SUB` exact buckets + one octave of `SUB`
/// sub-buckets for each MSB position in `SUB_BITS..=MAX_TOP`.
pub const N_BUCKETS: usize = (SUB as usize) * (1 + (MAX_TOP - SUB_BITS + 1) as usize);

/// Fixed-storage log-bucketed histogram over `u64` samples.
#[derive(Clone)]
pub struct Histogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum: f64,
    min: u64,
    max: u64,
}

/// Bucket index for a value (saturating above [`MAX_TRACKED`]).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    let v = v.min(MAX_TRACKED);
    if v < SUB {
        v as usize
    } else {
        let top = 63 - v.leading_zeros();
        let octave = (top - SUB_BITS + 1) as u64;
        (octave * SUB + ((v >> (top - SUB_BITS)) - SUB)) as usize
    }
}

/// Lowest value mapping to bucket `idx`.
pub fn bucket_low(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUB {
        idx
    } else {
        let octave = idx / SUB;
        let sub = idx % SUB;
        (SUB + sub) << (octave - 1)
    }
}

/// Highest value mapping to bucket `idx` (before saturation).
pub fn bucket_high(idx: usize) -> u64 {
    let octave = (idx as u64) / SUB;
    if octave == 0 {
        idx as u64
    } else {
        bucket_low(idx) + (1u64 << (octave - 1)) - 1
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: [0; N_BUCKETS], count: 0, sum: 0.0, min: u64::MAX, max: 0 }
    }

    /// Record one sample. O(1), allocation-free.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a duration in seconds as integer nanoseconds (negative or
    /// non-finite inputs clamp to 0).
    #[inline]
    pub fn record_secs(&mut self, s: f64) {
        let ns = s * 1e9;
        self.record(if ns.is_finite() && ns > 0.0 { ns as u64 } else { 0 });
    }

    /// Fold another histogram into this one (worker aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Samples recorded into bucket `idx` (see [`bucket_low`] /
    /// [`bucket_high`] for its value range) — the raw-bucket view the
    /// Prometheus cumulative `_bucket{le=…}` exposition walks.
    pub fn count_at(&self, idx: usize) -> u64 {
        self.counts[idx]
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Exact mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Sum of recorded values.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Percentile `p` in `[0, 100]`: the upper bound of the first bucket
    /// whose cumulative count reaches `ceil(p/100 · count)` — the highest
    /// value equivalent (within bucket resolution) to the nearest-rank
    /// sample. Returns 0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let target = target.min(self.count);
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                // The overflow bucket's nominal bound *under*-reports
                // saturated samples — report the exact max there. In every
                // other bucket, never report beyond the exact max (tightens
                // the top occupied bucket).
                if idx == N_BUCKETS - 1 {
                    return self.max;
                }
                return bucket_high(idx).min(self.max);
            }
        }
        self.max
    }

    /// Condensed view: count, exact min/max/mean, p50/p95/p99 — all value
    /// fields multiplied by `scale` (e.g. `1e-9` to report nanosecond
    /// samples in seconds).
    pub fn summary(&self, scale: f64) -> HistSummary {
        HistSummary {
            count: self.count,
            min: self.min() as f64 * scale,
            max: self.max() as f64 * scale,
            mean: self.mean() * scale,
            p50: self.percentile(50.0) as f64 * scale,
            p95: self.percentile(95.0) as f64 * scale,
            p99: self.percentile(99.0) as f64 * scale,
        }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count)
            .field("min", &self.min())
            .field("max", &self.max)
            .field("mean", &self.mean())
            .finish()
    }
}

/// Snapshot of a [`Histogram`] with values in caller units.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_below_two_sub() {
        // Values below 2·SUB get their own bucket: low == high == value.
        for v in 0..(2 * SUB) {
            let idx = bucket_index(v);
            assert_eq!(bucket_low(idx), v, "low({v})");
            assert_eq!(bucket_high(idx), v, "high({v})");
        }
        // Bucket index is monotone and the low/high ranges tile the axis.
        let mut prev_high = None;
        for idx in 0..N_BUCKETS {
            let (lo, hi) = (bucket_low(idx), bucket_high(idx));
            assert!(lo <= hi, "bucket {idx} inverted");
            assert_eq!(bucket_index(lo), idx, "low of {idx} maps back");
            assert_eq!(bucket_index(hi), idx, "high of {idx} maps back");
            if let Some(ph) = prev_high {
                assert_eq!(lo, ph + 1, "gap before bucket {idx}");
            }
            prev_high = Some(hi);
        }
        assert_eq!(prev_high, Some(MAX_TRACKED));
    }

    #[test]
    fn relative_error_is_bounded() {
        for v in [100u64, 1_000, 12_345, 1 << 20, (1 << 30) + 7] {
            let idx = bucket_index(v);
            let err = (bucket_high(idx) - bucket_low(idx)) as f64 / v as f64;
            assert!(err <= 1.0 / SUB as f64 + 1e-12, "bucket width at {v}: {err}");
        }
    }

    #[test]
    fn empty_histogram_edge_cases() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        let s = h.summary(1.0);
        assert_eq!((s.count, s.p50, s.p99), (0, 0.0, 0.0));
    }

    #[test]
    fn single_sample_percentiles() {
        let mut h = Histogram::new();
        h.record(42);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 42, "p{p}");
        }
        assert_eq!((h.min(), h.max()), (42, 42));
        assert_eq!(h.mean(), 42.0);
    }

    #[test]
    fn overflow_bucket_saturates_without_losing_extremes() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(MAX_TRACKED + 1);
        h.record(7);
        // Exact extremes survive saturation...
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.min(), 7);
        // ...and high percentiles land in the overflow bucket, clamped to
        // the exact max rather than the (smaller) bucket bound.
        assert_eq!(h.percentile(99.0), u64::MAX);
        assert_eq!(h.percentile(0.0), 7);
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        assert_eq!(bucket_index(MAX_TRACKED + 1), N_BUCKETS - 1);
    }

    #[test]
    fn percentiles_track_nearest_rank_within_bucket_error() {
        let mut h = Histogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        for (p, exact) in [(50.0, 500u64), (95.0, 950), (99.0, 990)] {
            let got = h.percentile(p);
            let err = (got as f64 - exact as f64).abs() / exact as f64;
            assert!(err <= 2.0 / SUB as f64, "p{p}: got {got}, exact {exact}");
        }
        assert_eq!(h.percentile(100.0), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_combined_recording() {
        let (mut a, mut b, mut both) = (Histogram::new(), Histogram::new(), Histogram::new());
        for v in 0..500u64 {
            a.record(v * 3);
            both.record(v * 3);
        }
        for v in 0..300u64 {
            b.record(v * 7 + 1);
            both.record(v * 7 + 1);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        assert_eq!(a.sum(), both.sum());
        for p in [1.0, 25.0, 50.0, 75.0, 95.0, 99.0, 100.0] {
            assert_eq!(a.percentile(p), both.percentile(p), "p{p}");
        }
    }

    #[test]
    fn record_secs_clamps_and_converts() {
        let mut h = Histogram::new();
        h.record_secs(1.5e-6); // 1500 ns
        h.record_secs(-3.0); // clamps to 0
        h.record_secs(f64::NAN); // clamps to 0
        assert_eq!(h.count(), 3);
        assert_eq!(h.max(), 1500);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn order_independence() {
        let mut fwd = Histogram::new();
        let mut rev = Histogram::new();
        for v in 0..1000u64 {
            fwd.record(v);
            rev.record(999 - v);
        }
        for p in [10.0, 50.0, 90.0, 99.0] {
            assert_eq!(fwd.percentile(p), rev.percentile(p));
        }
    }
}
