//! Request admission and variant routing.
//!
//! Compiled PJRT artifacts have static shapes, so serving works vLLM-
//! style with shape buckets: each [`Variant`] is one compiled entry
//! point (model, T queries, S context); the router sends a request to
//! the smallest variant that fits it and rejects what fits nowhere.

use crate::tensor::Mat;

/// An inference request: `t` query rows over a context of `s` keys.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    /// Query rows this request contributes to the LTPP batch.
    pub t: usize,
    /// Context (key/value) length.
    pub s: usize,
    /// Arrival timestamp, seconds (caller-provided monotonic clock).
    pub arrival_s: f64,
    /// Optional payload: the actual Q rows (used by the native and PJRT
    /// backends).
    pub q: Option<Mat>,
    /// Decode-session id: when set, the native backend appends `kv` to
    /// this session's paged KV-cache and decodes against the cached
    /// context instead of the variant's static context.
    pub session: Option<u64>,
    /// The new tokens' (K, V) rows for a decode request.
    pub kv: Option<(Mat, Mat)>,
}

impl Request {
    pub fn new(id: u64, model: &str, t: usize, s: usize, arrival_s: f64) -> Request {
        Request { id, model: model.to_string(), t, s, arrival_s, q: None, session: None, kv: None }
    }

    /// A decode-step request: append one chunk of tokens (`q`/`k`/`v`
    /// rows) to `session` and attend causally against its cached
    /// context. `s` **must** equal the session length *after* the append
    /// — it routes the shape bucket AND serves as the ordering guard:
    /// the backend rejects a step whose claimed context length does not
    /// match the session (e.g. two same-session steps racing through
    /// different batches), turning silent context permutation into a
    /// per-request error.
    pub fn decode(
        id: u64,
        model: &str,
        session: u64,
        q: Mat,
        k: Mat,
        v: Mat,
        s: usize,
        arrival_s: f64,
    ) -> Request {
        let t = q.rows;
        let mut req = Request::new(id, model, t, s, arrival_s);
        req.q = Some(q);
        req.session = Some(session);
        req.kv = Some((k, v));
        req
    }

    /// Whether this request decodes against a session (vs stateless
    /// prefill).
    pub fn is_decode(&self) -> bool {
        self.session.is_some()
    }
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Output rows (empty in simulation mode).
    pub output: Option<Mat>,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Queueing share of the latency.
    pub queue_s: f64,
    /// Which variant served it.
    pub variant: String,
}

/// One compiled shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    /// Artifact entry name, e.g. `"sparse_attention"`.
    pub name: String,
    pub model: String,
    /// Maximum query rows per batch (the accelerator's T, e.g. 128).
    pub max_t: usize,
    /// Context length the artifact was lowered for.
    pub s: usize,
}

/// Routing error.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteError {
    UnknownModel(String),
    TooLong { s: usize, max: usize },
    TooWide { t: usize, max: usize },
    /// More query rows than the batcher's target: such a request could
    /// never seal a within-target batch (split it into chunks instead).
    OverTarget { t: usize, target: usize },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
            RouteError::TooLong { s, max } => write!(f, "context {s} exceeds max {max}"),
            RouteError::TooWide { t, max } => write!(f, "batch rows {t} exceed max {max}"),
            RouteError::OverTarget { t, target } => {
                write!(f, "request rows {t} exceed batch target {target}; split into chunks")
            }
        }
    }
}

/// Routes requests to variants.
#[derive(Clone, Debug, Default)]
pub struct Router {
    variants: Vec<Variant>,
}

impl Router {
    pub fn new(variants: Vec<Variant>) -> Router {
        let mut v = variants;
        // Prefer the tightest context bucket.
        v.sort_by_key(|x| x.s);
        Router { variants: v }
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// Pick the smallest variant of the request's model that fits.
    pub fn route(&self, req: &Request) -> Result<&Variant, RouteError> {
        let of_model: Vec<&Variant> =
            self.variants.iter().filter(|v| v.model == req.model).collect();
        if of_model.is_empty() {
            return Err(RouteError::UnknownModel(req.model.clone()));
        }
        let max_s = of_model.iter().map(|v| v.s).max().unwrap();
        let max_t = of_model.iter().map(|v| v.max_t).max().unwrap();
        if req.t > max_t {
            return Err(RouteError::TooWide { t: req.t, max: max_t });
        }
        of_model
            .into_iter()
            .find(|v| v.s >= req.s && v.max_t >= req.t)
            .ok_or(RouteError::TooLong { s: req.s, max: max_s })
    }

    /// Route plus batch-level admission: additionally reject requests
    /// whose query rows exceed the batcher's `target_t` — previously
    /// such a request flowed through unchecked and sealed an over-target
    /// batch via [`super::batcher::Batcher`]'s oversize escape hatch.
    /// `target_t = 0` disables the check.
    pub fn admit(&self, req: &Request, target_t: usize) -> Result<&Variant, RouteError> {
        if target_t > 0 && req.t > target_t {
            return Err(RouteError::OverTarget { t: req.t, target: target_t });
        }
        self.route(req)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![
            Variant { name: "attn_s2048".into(), model: "tiny".into(), max_t: 128, s: 2048 },
            Variant { name: "attn_s512".into(), model: "tiny".into(), max_t: 128, s: 512 },
            Variant { name: "attn_gpt2".into(), model: "gpt2".into(), max_t: 64, s: 1024 },
        ])
    }

    #[test]
    fn routes_to_tightest_bucket() {
        let r = router();
        let v = r.route(&Request::new(1, "tiny", 16, 300, 0.0)).unwrap();
        assert_eq!(v.name, "attn_s512");
        let v = r.route(&Request::new(2, "tiny", 16, 600, 0.0)).unwrap();
        assert_eq!(v.name, "attn_s2048");
    }

    #[test]
    fn rejects_unknown_model() {
        let r = router();
        let e = r.route(&Request::new(1, "llama", 1, 10, 0.0)).unwrap_err();
        assert_eq!(e, RouteError::UnknownModel("llama".into()));
    }

    #[test]
    fn admit_enforces_batch_target() {
        let r = router();
        // Routable by shape (max_t = 128) but wider than the batch
        // target: admission must reject it.
        let req = Request::new(1, "tiny", 48, 300, 0.0);
        assert_eq!(
            r.admit(&req, 32).unwrap_err(),
            RouteError::OverTarget { t: 48, target: 32 }
        );
        // Within target: admit behaves exactly like route.
        assert_eq!(r.admit(&req, 64).unwrap().name, "attn_s512");
        // target 0 disables the check.
        assert!(r.admit(&req, 0).is_ok());
    }

    #[test]
    fn decode_request_carries_session_payload() {
        let q = Mat::zeros(2, 4);
        let k = Mat::zeros(2, 4);
        let v = Mat::zeros(2, 4);
        let req = Request::decode(5, "tiny", 9, q, k, v, 34, 0.0);
        assert!(req.is_decode());
        assert_eq!(req.session, Some(9));
        assert_eq!(req.t, 2);
        assert_eq!(req.s, 34);
        assert!(req.kv.is_some() && req.q.is_some());
        assert!(!Request::new(1, "tiny", 2, 34, 0.0).is_decode());
    }

    #[test]
    fn rejects_oversize() {
        let r = router();
        assert_eq!(
            r.route(&Request::new(1, "tiny", 16, 4096, 0.0)).unwrap_err(),
            RouteError::TooLong { s: 4096, max: 2048 }
        );
        assert_eq!(
            r.route(&Request::new(1, "gpt2", 256, 100, 0.0)).unwrap_err(),
            RouteError::TooWide { t: 256, max: 64 }
        );
    }
}
