//! Request admission and variant routing.
//!
//! Compiled PJRT artifacts have static shapes, so serving works vLLM-
//! style with shape buckets: each [`Variant`] is one compiled entry
//! point (model, T queries, S context); the router sends a request to
//! the smallest variant that fits it and rejects what fits nowhere.

use crate::tensor::Mat;

/// An inference request: `t` query rows over a context of `s` keys.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen request id (responses echo it).
    pub id: u64,
    /// Model name, matched against [`Variant::model`].
    pub model: String,
    /// Query rows this request contributes to the LTPP batch.
    pub t: usize,
    /// Context (key/value) length.
    pub s: usize,
    /// Arrival timestamp, seconds (caller-provided monotonic clock).
    pub arrival_s: f64,
    /// Optional payload: the actual Q rows (used by the native and PJRT
    /// backends).
    pub q: Option<Mat>,
    /// Decode-session id: when set, the native backend appends `kv` to
    /// this session's paged KV-cache and decodes against the cached
    /// context instead of the variant's static context.
    pub session: Option<u64>,
    /// The new tokens' (K, V) rows for a decode request.
    pub kv: Option<(Mat, Mat)>,
}

impl Request {
    /// A stateless prefill request (attach Q via the `q` field).
    pub fn new(id: u64, model: &str, t: usize, s: usize, arrival_s: f64) -> Request {
        Request { id, model: model.to_string(), t, s, arrival_s, q: None, session: None, kv: None }
    }

    /// A decode-step request: append one chunk of tokens (`q`/`k`/`v`
    /// rows) to `session` and attend causally against its cached
    /// context. `s` **must** equal the session length *after* the append
    /// — it routes the shape bucket AND serves as the ordering guard:
    /// the backend rejects a step whose claimed context length does not
    /// match the session (e.g. two same-session steps racing through
    /// different batches), turning silent context permutation into a
    /// per-request error.
    pub fn decode(
        id: u64,
        model: &str,
        session: u64,
        q: Mat,
        k: Mat,
        v: Mat,
        s: usize,
        arrival_s: f64,
    ) -> Request {
        let t = q.rows;
        let mut req = Request::new(id, model, t, s, arrival_s);
        req.q = Some(q);
        req.session = Some(session);
        req.kv = Some((k, v));
        req
    }

    /// Whether this request decodes against a session (vs stateless
    /// prefill).
    pub fn is_decode(&self) -> bool {
        self.session.is_some()
    }
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// Output rows (empty in simulation mode).
    pub output: Option<Mat>,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Queueing share of the latency.
    pub queue_s: f64,
    /// Which variant served it.
    pub variant: String,
}

/// One compiled shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    /// Artifact entry name, e.g. `"sparse_attention"`.
    pub name: String,
    /// Model this variant serves.
    pub model: String,
    /// Maximum query rows per batch (the accelerator's T, e.g. 128).
    pub max_t: usize,
    /// Context length the artifact was lowered for.
    pub s: usize,
}

/// Routing error.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteError {
    /// No variant is loaded for the requested model.
    UnknownModel(String),
    /// The context exceeds every variant of the model.
    TooLong { s: usize, max: usize },
    /// The request's query rows exceed every variant's compiled batch.
    TooWide { t: usize, max: usize },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
            RouteError::TooLong { s, max } => write!(f, "context {s} exceeds max {max}"),
            RouteError::TooWide { t, max } => write!(f, "batch rows {t} exceed max {max}"),
        }
    }
}

/// Routes requests to variants.
#[derive(Clone, Debug, Default)]
pub struct Router {
    variants: Vec<Variant>,
}

impl Router {
    /// A router over the loaded variants (kept sorted by context size).
    pub fn new(variants: Vec<Variant>) -> Router {
        let mut v = variants;
        // Prefer the tightest context bucket.
        v.sort_by_key(|x| x.s);
        Router { variants: v }
    }

    /// The loaded variants, ascending by context length.
    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// All variants of `model`, ascending by context length
    /// ([`RouteError::UnknownModel`] when none is loaded).
    fn buckets_of(&self, model: &str) -> Result<Vec<&Variant>, RouteError> {
        let of_model: Vec<&Variant> =
            self.variants.iter().filter(|v| v.model == model).collect();
        if of_model.is_empty() {
            return Err(RouteError::UnknownModel(model.to_string()));
        }
        Ok(of_model)
    }

    /// Pick the smallest variant of the request's model that fits.
    pub fn route(&self, req: &Request) -> Result<&Variant, RouteError> {
        let of_model = self.buckets_of(&req.model)?;
        let max_s = of_model.iter().map(|v| v.s).max().unwrap();
        let max_t = of_model.iter().map(|v| v.max_t).max().unwrap();
        if req.t > max_t {
            return Err(RouteError::TooWide { t: req.t, max: max_t });
        }
        of_model
            .into_iter()
            .find(|v| v.s >= req.s && v.max_t >= req.t)
            .ok_or(RouteError::TooLong { s: req.s, max: max_s })
    }

    /// Context-only routing for the sharded path: the smallest bucket of
    /// the model that fits `req.s`, ignoring `max_t` — the sharded
    /// engine partitions query rows itself.
    fn route_by_context(&self, req: &Request) -> Result<&Variant, RouteError> {
        let of_model = self.buckets_of(&req.model)?;
        let max_s = of_model.iter().map(|v| v.s).max().unwrap();
        of_model
            .into_iter()
            .find(|v| v.s >= req.s)
            .ok_or(RouteError::TooLong { s: req.s, max: max_s })
    }

    /// Route plus batch-level admission. Within the batcher's `target_t`
    /// the request enters the dynamic batcher as usual
    /// ([`Admission::Batched`]). A request too wide for that path —
    /// wider than `target_t`, or wider than every variant's compiled
    /// `max_t` — is admitted onto the sharded execution path instead of
    /// being rejected ([`Admission::Sharded`]): stateless prefill is
    /// served by [`crate::pipeline::ShardedPipeline::run_pooled`],
    /// decode steps by the partitioned-cache
    /// [`crate::pipeline::ShardedPipeline::decode_step_pooled`] (both
    /// bit-identical to their single-core counterparts). A sharded
    /// request bypasses the batcher (it alone exceeds a whole batch)
    /// and is routed by context length only, because the sharded engine
    /// partitions query rows itself. Admission is therefore monotone in
    /// `t`: no width is ever rejected, only an unknown model or an
    /// impossible context. `target_t = 0` disables the over-target
    /// check (compiled width still falls back to the sharded path).
    pub fn admit(&self, req: &Request, target_t: usize) -> Result<Admission<'_>, RouteError> {
        let over_target = target_t > 0 && req.t > target_t;
        if !over_target {
            return match self.route(req) {
                Ok(v) => Ok(Admission::Batched(v)),
                // A request wider than every compiled variant can still
                // execute sharded — without this fallback a t between
                // max_t and target_t would be rejected while a wider
                // one is served.
                Err(RouteError::TooWide { .. }) => {
                    self.route_by_context(req).map(Admission::Sharded)
                }
                Err(e) => Err(e),
            };
        }
        self.route_by_context(req).map(Admission::Sharded)
    }
}

/// How an admitted request will execute (see [`Router::admit`]).
#[derive(Clone, Debug, PartialEq)]
pub enum Admission<'a> {
    /// Within the batch target: enters the dynamic batcher for this
    /// variant.
    Batched(&'a Variant),
    /// Over-target stateless prefill: bypasses the batcher and executes
    /// on the sequence-sharded pipeline against this variant's context.
    Sharded(&'a Variant),
}

impl<'a> Admission<'a> {
    /// The variant serving the request, whichever path it takes.
    pub fn variant(&self) -> &'a Variant {
        match self {
            Admission::Batched(v) | Admission::Sharded(v) => v,
        }
    }

    /// Whether the request takes the sequence-sharded path.
    pub fn is_sharded(&self) -> bool {
        matches!(self, Admission::Sharded(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![
            Variant { name: "attn_s2048".into(), model: "tiny".into(), max_t: 128, s: 2048 },
            Variant { name: "attn_s512".into(), model: "tiny".into(), max_t: 128, s: 512 },
            Variant { name: "attn_gpt2".into(), model: "gpt2".into(), max_t: 64, s: 1024 },
        ])
    }

    #[test]
    fn routes_to_tightest_bucket() {
        let r = router();
        let v = r.route(&Request::new(1, "tiny", 16, 300, 0.0)).unwrap();
        assert_eq!(v.name, "attn_s512");
        let v = r.route(&Request::new(2, "tiny", 16, 600, 0.0)).unwrap();
        assert_eq!(v.name, "attn_s2048");
    }

    #[test]
    fn rejects_unknown_model() {
        let r = router();
        let e = r.route(&Request::new(1, "llama", 1, 10, 0.0)).unwrap_err();
        assert_eq!(e, RouteError::UnknownModel("llama".into()));
    }

    #[test]
    fn admit_routes_over_target_prefill_to_the_sharded_path() {
        let r = router();
        // Wider than the batch target: a stateless prefill is admitted,
        // but onto the sharded path (routed by context only).
        let req = Request::new(1, "tiny", 48, 300, 0.0);
        let adm = r.admit(&req, 32).unwrap();
        assert!(adm.is_sharded());
        assert_eq!(adm.variant().name, "attn_s512");
        // Even wider than every variant's max_t: still sharded — the
        // sharded engine partitions query rows itself.
        let wide = Request::new(2, "tiny", 4096, 300, 0.0);
        assert!(r.admit(&wide, 32).unwrap().is_sharded());
        // But an impossible context still fails.
        let long = Request::new(3, "tiny", 4096, 9999, 0.0);
        assert_eq!(r.admit(&long, 32).unwrap_err(), RouteError::TooLong { s: 9999, max: 2048 });
        // Within target: admit behaves exactly like route.
        let adm = r.admit(&req, 64).unwrap();
        assert!(!adm.is_sharded());
        assert_eq!(adm.variant().name, "attn_s512");
        // target 0 disables the check.
        assert!(!r.admit(&req, 0).unwrap().is_sharded());
    }

    // Inverted from the pre-distributed-decode behavior: an over-target
    // decode used to be the one rejection (`RouteError::OverTarget`,
    // since removed); with the partitioned-cache decode path it is
    // admitted sharded instead, so no width is ever rejected.
    #[test]
    fn admit_routes_over_target_decode_to_the_sharded_path() {
        let r = router();
        let q = Mat::zeros(48, 4);
        let k = Mat::zeros(48, 4);
        let v = Mat::zeros(48, 4);
        let req = Request::decode(9, "tiny", 5, q, k, v, 300, 0.0);
        let adm = r.admit(&req, 32).unwrap();
        assert!(adm.is_sharded());
        assert_eq!(adm.variant().name, "attn_s512");
        // Under-target decode still batches as before.
        let (q, k, v) = (Mat::zeros(8, 4), Mat::zeros(8, 4), Mat::zeros(8, 4));
        let small = Request::decode(10, "tiny", 5, q, k, v, 300, 0.0);
        assert!(!r.admit(&small, 32).unwrap().is_sharded());
    }

    #[test]
    fn admission_is_monotone_in_width_for_prefill() {
        let r = router();
        // Wider than every compiled max_t (128) but within the batch
        // target (256): without the TooWide fallback this narrower
        // request would be rejected while a t > 256 one is served.
        let mid = Request::new(4, "tiny", 200, 300, 0.0);
        let adm = r.admit(&mid, 256).unwrap();
        assert!(adm.is_sharded());
        assert_eq!(adm.variant().name, "attn_s512");
        // Same with the over-target check disabled: width never rejects
        // a stateless prefill.
        assert!(r.admit(&mid, 0).unwrap().is_sharded());
        // A decode step wider than max_t (but within target) also rides
        // the sharded path now that decode shards too.
        let (q, k, v) = (Mat::zeros(200, 4), Mat::zeros(200, 4), Mat::zeros(200, 4));
        let wd = Request::decode(5, "tiny", 3, q, k, v, 300, 0.0);
        assert!(r.admit(&wd, 256).unwrap().is_sharded());
    }

    #[test]
    fn decode_request_carries_session_payload() {
        let q = Mat::zeros(2, 4);
        let k = Mat::zeros(2, 4);
        let v = Mat::zeros(2, 4);
        let req = Request::decode(5, "tiny", 9, q, k, v, 34, 0.0);
        assert!(req.is_decode());
        assert_eq!(req.session, Some(9));
        assert_eq!(req.t, 2);
        assert_eq!(req.s, 34);
        assert!(req.kv.is_some() && req.q.is_some());
        assert!(!Request::new(1, "tiny", 2, 34, 0.0).is_decode());
    }

    #[test]
    fn rejects_oversize() {
        let r = router();
        assert_eq!(
            r.route(&Request::new(1, "tiny", 16, 4096, 0.0)).unwrap_err(),
            RouteError::TooLong { s: 4096, max: 2048 }
        );
        assert_eq!(
            r.route(&Request::new(1, "gpt2", 256, 100, 0.0)).unwrap_err(),
            RouteError::TooWide { t: 256, max: 64 }
        );
    }
}
