//! Request admission and variant routing.
//!
//! Compiled PJRT artifacts have static shapes, so serving works vLLM-
//! style with shape buckets: each [`Variant`] is one compiled entry
//! point (model, T queries, S context); the router sends a request to
//! the smallest variant that fits it and rejects what fits nowhere.

use crate::tensor::Mat;

/// An inference request: `t` query rows over a context of `s` keys.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub model: String,
    /// Query rows this request contributes to the LTPP batch.
    pub t: usize,
    /// Context (key/value) length.
    pub s: usize,
    /// Arrival timestamp, seconds (caller-provided monotonic clock).
    pub arrival_s: f64,
    /// Optional payload: the actual Q rows (used by the PJRT backend).
    pub q: Option<Mat>,
}

impl Request {
    pub fn new(id: u64, model: &str, t: usize, s: usize, arrival_s: f64) -> Request {
        Request { id, model: model.to_string(), t, s, arrival_s, q: None }
    }
}

/// A served response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Output rows (empty in simulation mode).
    pub output: Option<Mat>,
    /// End-to-end latency, seconds.
    pub latency_s: f64,
    /// Queueing share of the latency.
    pub queue_s: f64,
    /// Which variant served it.
    pub variant: String,
}

/// One compiled shape bucket.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    /// Artifact entry name, e.g. `"sparse_attention"`.
    pub name: String,
    pub model: String,
    /// Maximum query rows per batch (the accelerator's T, e.g. 128).
    pub max_t: usize,
    /// Context length the artifact was lowered for.
    pub s: usize,
}

/// Routing error.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteError {
    UnknownModel(String),
    TooLong { s: usize, max: usize },
    TooWide { t: usize, max: usize },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
            RouteError::TooLong { s, max } => write!(f, "context {s} exceeds max {max}"),
            RouteError::TooWide { t, max } => write!(f, "batch rows {t} exceed max {max}"),
        }
    }
}

/// Routes requests to variants.
#[derive(Clone, Debug, Default)]
pub struct Router {
    variants: Vec<Variant>,
}

impl Router {
    pub fn new(variants: Vec<Variant>) -> Router {
        let mut v = variants;
        // Prefer the tightest context bucket.
        v.sort_by_key(|x| x.s);
        Router { variants: v }
    }

    pub fn variants(&self) -> &[Variant] {
        &self.variants
    }

    /// Pick the smallest variant of the request's model that fits.
    pub fn route(&self, req: &Request) -> Result<&Variant, RouteError> {
        let of_model: Vec<&Variant> =
            self.variants.iter().filter(|v| v.model == req.model).collect();
        if of_model.is_empty() {
            return Err(RouteError::UnknownModel(req.model.clone()));
        }
        let max_s = of_model.iter().map(|v| v.s).max().unwrap();
        let max_t = of_model.iter().map(|v| v.max_t).max().unwrap();
        if req.t > max_t {
            return Err(RouteError::TooWide { t: req.t, max: max_t });
        }
        of_model
            .into_iter()
            .find(|v| v.s >= req.s && v.max_t >= req.t)
            .ok_or(RouteError::TooLong { s: req.s, max: max_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        Router::new(vec![
            Variant { name: "attn_s2048".into(), model: "tiny".into(), max_t: 128, s: 2048 },
            Variant { name: "attn_s512".into(), model: "tiny".into(), max_t: 128, s: 512 },
            Variant { name: "attn_gpt2".into(), model: "gpt2".into(), max_t: 64, s: 1024 },
        ])
    }

    #[test]
    fn routes_to_tightest_bucket() {
        let r = router();
        let v = r.route(&Request::new(1, "tiny", 16, 300, 0.0)).unwrap();
        assert_eq!(v.name, "attn_s512");
        let v = r.route(&Request::new(2, "tiny", 16, 600, 0.0)).unwrap();
        assert_eq!(v.name, "attn_s2048");
    }

    #[test]
    fn rejects_unknown_model() {
        let r = router();
        let e = r.route(&Request::new(1, "llama", 1, 10, 0.0)).unwrap_err();
        assert_eq!(e, RouteError::UnknownModel("llama".into()));
    }

    #[test]
    fn rejects_oversize() {
        let r = router();
        assert_eq!(
            r.route(&Request::new(1, "tiny", 16, 4096, 0.0)).unwrap_err(),
            RouteError::TooLong { s: 4096, max: 2048 }
        );
        assert_eq!(
            r.route(&Request::new(1, "gpt2", 256, 100, 0.0)).unwrap_err(),
            RouteError::TooWide { t: 256, max: 64 }
        );
    }
}
