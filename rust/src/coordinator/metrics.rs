//! Serving metrics: latency distributions, throughput, batching quality.
//!
//! Every latency-shaped quantity is a fixed-storage log-bucketed
//! [`Histogram`] (`crate::obs::hist`) rather than a point average or an
//! unbounded sample vector: recording is O(1) and allocation-free under
//! the metrics mutex, percentiles are order-independent, and the same
//! snapshot drives the human-readable [`MetricsSnapshot::render`] footer
//! and the Prometheus-style [`MetricsSnapshot::render_prometheus`]
//! exposition.

use crate::obs::{HistSummary, Histogram, SchedStats, TrafficCounter};
use std::sync::Mutex;

/// Which serving path produced a response — selects the per-class
/// histogram: TTFT (time-to-first-token, the full prefill latency) for
/// the single-core and sharded prefill paths, TPOT (time per output
/// token) for decode steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestClass {
    /// Single-core batched prefill.
    Prefill,
    /// Autoregressive decode step against the paged KV-cache.
    Decode,
    /// Over-target prefill served on the sequence-sharded pipeline.
    Sharded,
}

/// Pipeline stage names, in the order of the per-stage histogram arrays
/// ([`MetricsSnapshot::stage_hist`]).
pub const STAGE_NAMES: [&str; 4] = ["predict", "topk", "kv_gen", "formal"];

/// Thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latency: Histogram,
    queue: Histogram,
    batch_rows: Histogram,
    // Per-class latency: TTFT for the two prefill paths, TPOT for decode.
    ttft_prefill: Histogram,
    ttft_sharded: Histogram,
    tpot_decode: Histogram,
    // Per-batch stage busy time, nanoseconds, indexed by STAGE_NAMES.
    stage_ns: [Histogram; 4],
    requests: u64,
    rejected: u64,
    failed: u64,
    batches: u64,
    rows: u64,
    first_s: Option<f64>,
    last_s: f64,
    // Per-stage busy time of the native sparse-attention pipeline.
    stage_predict_s: f64,
    stage_topk_s: f64,
    stage_kv_gen_s: f64,
    stage_formal_s: f64,
    stalls: u64,
    // Decode/KV-cache counters (session-aware native backend).
    decode_steps: u64,
    decode_tokens: u64,
    cache_page_hits: u64,
    cache_pages_rematerialized: u64,
    cache_sessions_evicted: u64,
    // Page-granular cache counters: high-water of the cumulative store
    // stats carried on each decode report (monotone even when several
    // stores report in turn).
    cache_pages_evicted: u64,
    cache_pages_shared: u64,
    cache_cow_splits: u64,
    // Latest-wins residency gauges from the most recent decode report.
    kv_resident_pages: u64,
    kv_shared_pages: u64,
    kv_resident_bytes: u64,
    kv_logical_bytes: u64,
    // Peak per-worker tile-workspace residency (bytes) seen so far.
    workspace_bytes: usize,
    // Sequence-sharded over-target prefill path.
    sharded_prefills: u64,
    // Page-partitioned over-target decode path.
    sharded_decodes: u64,
    ring_steps: u64,
    ring_payload_bytes: u64,
    gathered_kv_rows: u64,
    /// Per-shard stage busy times, indexed by ring position (grown on
    /// demand to the largest worker count seen).
    shard_stage_s: Vec<crate::pipeline::StageTiming>,
    // Cumulative measured byte traffic + scheduler stats across every
    // served batch (all zeros unless counting was enabled — see
    // `crate::obs::traffic::set_enabled`).
    traffic: TrafficCounter,
    sched: SchedStats,
}

impl Inner {
    /// Fold one decode report's KV-cache residency view in: the
    /// point-in-time gauges are latest-wins, the cumulative per-store
    /// counters are folded as high-water marks so the exposition stays
    /// monotone even when several stores report interleaved.
    fn record_kvcache_residency(
        &mut self,
        residency: &crate::kvcache::ResidencySnapshot,
        stats: &crate::kvcache::CacheStats,
    ) {
        self.kv_resident_pages = residency.resident_pages as u64;
        self.kv_shared_pages = residency.shared_pages as u64;
        self.kv_resident_bytes = residency.resident_bytes as u64;
        self.kv_logical_bytes = residency.logical_bytes as u64;
        self.cache_pages_evicted = self.cache_pages_evicted.max(stats.pages_evicted);
        self.cache_pages_shared = self.cache_pages_shared.max(stats.pages_shared);
        self.cache_cow_splits = self.cache_cow_splits.max(stats.cow_splits);
    }
}

/// A point-in-time copy for reporting. Histogram fields are
/// [`HistSummary`] snapshots in base units (seconds for latencies, rows
/// for batch occupancy).
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Responses delivered (including error responses).
    pub requests: u64,
    /// Requests rejected at admission.
    pub rejected: u64,
    /// Batches (or individual decode requests) whose backend execution
    /// errored — the responses carried no output and the error text went
    /// to the `Response::variant` field.
    pub failed: u64,
    /// Batches dispatched to the worker pool.
    pub batches: u64,
    /// Query rows across all dispatched batches.
    pub rows: u64,
    /// End-to-end request latency, seconds.
    pub latency: HistSummary,
    /// Queueing share of the latency, seconds.
    pub queue: HistSummary,
    /// Query rows per sealed batch (batching quality).
    pub batch_rows: HistSummary,
    /// Time-to-first-token of single-core prefill responses, seconds.
    pub ttft_prefill: HistSummary,
    /// Time-to-first-token of sequence-sharded prefill responses, seconds.
    pub ttft_sharded: HistSummary,
    /// Time per output token of decode responses, seconds.
    pub tpot_decode: HistSummary,
    /// Per-batch stage busy time, seconds, indexed by [`STAGE_NAMES`].
    pub stage_hist: [HistSummary; 4],
    /// Served query rows per second over the observation window.
    pub rows_per_s: f64,
    /// Aggregate predict-stage busy seconds (native backend only; all
    /// stage times are zero for the PJRT/simulator backends).
    pub stage_predict_s: f64,
    /// Aggregate top-k-stage busy seconds.
    pub stage_topk_s: f64,
    /// Aggregate KV-generation busy seconds.
    pub stage_kv_gen_s: f64,
    /// Aggregate formal-compute busy seconds.
    pub stage_formal_s: f64,
    /// SU-FA max-misprediction recoveries across all served batches.
    pub stalls: u64,
    /// Decode steps served against the paged KV-cache.
    pub decode_steps: u64,
    /// Tokens appended across those decode steps.
    pub decode_tokens: u64,
    /// Distinct already-resident pages read per decode step, summed
    /// (cache hits; same per-step page units as the misses below).
    pub cache_page_hits: u64,
    /// Pages rebuilt from history after eviction (cache misses).
    pub cache_pages_rematerialized: u64,
    /// Sessions an eviction took from fully resident to partial (the
    /// page-granular successor of the old whole-session eviction count).
    pub cache_sessions_evicted: u64,
    /// Page references dropped by page-granular eviction (high-water of
    /// the per-store cumulative counter).
    pub cache_pages_evicted: u64,
    /// Prefix share-attaches: sessions that mapped an existing page
    /// instead of building their own (high-water, cumulative).
    pub cache_pages_shared: u64,
    /// Copy-on-write splits of shared pages on divergence (high-water,
    /// cumulative).
    pub cache_cow_splits: u64,
    /// Pages resident in the pool right now, shared pages counted once
    /// (gauge from the latest decode report).
    pub kv_resident_pages: u64,
    /// Resident pages currently referenced by more than one session
    /// (gauge from the latest decode report).
    pub kv_shared_pages: u64,
    /// Measured heap bytes of all resident page payloads (gauge).
    pub kv_resident_bytes: u64,
    /// f32 K+V bytes a flat per-session cache would hold for the same
    /// logical tokens; `kv_logical_bytes / kv_resident_bytes` is the
    /// compression ratio sharing + quantized residency buy (gauge).
    pub kv_logical_bytes: u64,
    /// Peak bytes of tile-workspace capacity a single pool worker held
    /// (the native pipelines' preallocated stage scratch —
    /// `crate::pipeline::engine`). Reported next to the modeled SRAM
    /// budget ([`crate::sim::sram::Sram::STAR_BUDGET_BYTES`]) so the
    /// serving working set is checkable against the hardware model.
    pub workspace_bytes: usize,
    /// Over-target prefill requests served on the sequence-sharded
    /// pipeline.
    pub sharded_prefills: u64,
    /// Over-target decode steps served on the page-partitioned sharded
    /// pipeline ([`crate::pipeline::ShardedPipeline::decode_step`]);
    /// each also counts into `decode_steps` and the KV-cache counters.
    pub sharded_decodes: u64,
    /// Ring steps executed across all sharded runs (prefill ring hops
    /// plus decode candidate-scatter rounds).
    pub ring_steps: u64,
    /// Modeled bytes forwarded on the worker ring across all sharded
    /// runs.
    pub ring_payload_bytes: u64,
    /// Selected KV rows gathered to Q-block home workers across all
    /// sharded runs.
    pub gathered_kv_rows: u64,
    /// Per-shard stage busy times (ring position → timing), summed over
    /// all sharded runs.
    pub shard_stage_s: Vec<crate::pipeline::StageTiming>,
    /// Cumulative measured byte traffic across served batches (zeros
    /// unless counting is enabled — `crate::obs::traffic::set_enabled`).
    pub traffic: TrafficCounter,
    /// Cumulative work-stealing scheduler stats across served batches.
    pub sched: SchedStats,
    /// Full request-latency histogram (nanosecond samples) — drives the
    /// Prometheus cumulative `_bucket` exposition; `latency` above is
    /// the condensed summary of the same data.
    pub latency_hist: Histogram,
    /// Full per-stage busy-time histograms (nanosecond samples), indexed
    /// by [`STAGE_NAMES`] — the bucket-level view behind `stage_hist`.
    pub stage_ns_hist: [Histogram; 4],
}

impl Metrics {
    /// An empty metrics sink.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Account one delivered response and its latency split. `tokens` is
    /// the output size of the response (tokens appended for decode, query
    /// rows for prefill); it normalizes the decode latency into TPOT.
    pub fn record_response(
        &self,
        latency_s: f64,
        queue_s: f64,
        now: f64,
        class: RequestClass,
        tokens: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.latency.record_secs(latency_s);
        m.queue.record_secs(queue_s);
        match class {
            RequestClass::Prefill => m.ttft_prefill.record_secs(latency_s),
            RequestClass::Sharded => m.ttft_sharded.record_secs(latency_s),
            RequestClass::Decode => {
                m.tpot_decode.record_secs(latency_s / tokens.max(1) as f64)
            }
        }
        m.requests += 1;
        if m.first_s.is_none() {
            m.first_s = Some(now);
        }
        m.last_s = m.last_s.max(now);
    }

    /// Account one dispatched batch of `rows` query rows.
    pub fn record_batch(&self, rows: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batch_rows.record(rows as u64);
        m.batches += 1;
        m.rows += rows as u64;
    }

    /// Account one admission rejection.
    pub fn record_rejection(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// One batch whose backend execution failed.
    pub fn record_failure(&self) {
        self.inner.lock().unwrap().failed += 1;
    }

    /// Accumulate one batch's per-stage pipeline timing (native backend).
    pub fn record_stage_times(&self, t: &crate::pipeline::StageTiming, stalls: u64) {
        let mut m = self.inner.lock().unwrap();
        m.stage_predict_s += t.predict_s;
        m.stage_topk_s += t.topk_s;
        m.stage_kv_gen_s += t.kv_gen_s;
        m.stage_formal_s += t.formal_s;
        for (h, s) in m
            .stage_ns
            .iter_mut()
            .zip([t.predict_s, t.topk_s, t.kv_gen_s, t.formal_s])
        {
            h.record_secs(s);
        }
        m.stalls += stalls;
    }

    /// Record one worker's tile-workspace pool residency (bytes); the
    /// snapshot keeps the peak.
    pub fn record_workspace_bytes(&self, bytes: usize) {
        let mut m = self.inner.lock().unwrap();
        m.workspace_bytes = m.workspace_bytes.max(bytes);
    }

    /// Account one sequence-sharded prefill run: per-shard stage busy
    /// times plus ring-step/payload/gather counters.
    pub fn record_sharded(&self, r: &crate::pipeline::ShardedReport) {
        let mut m = self.inner.lock().unwrap();
        m.sharded_prefills += 1;
        m.ring_steps += r.ring_steps as u64;
        m.ring_payload_bytes += r.ring_payload_bytes;
        m.gathered_kv_rows += r.union_rows as u64;
        if m.shard_stage_s.len() < r.per_shard.len() {
            m.shard_stage_s.resize(r.per_shard.len(), crate::pipeline::StageTiming::default());
        }
        for st in &r.per_shard {
            m.shard_stage_s[st.shard].merge(&st.timing);
        }
    }

    /// Fold one run's measured traffic counters and scheduler stats into
    /// the cumulative window. Cheap no-op folds when counting was off
    /// (the report carries zeros).
    pub fn record_traffic(&self, t: &TrafficCounter, sched: &SchedStats) {
        let mut m = self.inner.lock().unwrap();
        m.traffic.merge(t);
        m.sched.merge(sched);
    }

    /// Account one distributed decode step served on the
    /// page-partitioned sharded pipeline: the decode/KV-cache counters
    /// of [`Metrics::record_decode`] plus the communication counters of
    /// [`Metrics::record_sharded`] (candidate-scatter rounds feed the
    /// same ring totals as prefill ring hops).
    pub fn record_sharded_decode(&self, r: &crate::pipeline::ShardedDecodeReport) {
        let mut m = self.inner.lock().unwrap();
        m.sharded_decodes += 1;
        m.decode_steps += 1;
        m.decode_tokens += r.positions.len() as u64;
        m.cache_page_hits += r.page_hits as u64;
        m.cache_pages_rematerialized += r.rematerialized_pages as u64;
        m.cache_sessions_evicted += r.evicted_sessions.len() as u64;
        m.record_kvcache_residency(&r.residency, &r.cache_stats);
        m.ring_steps += r.ring_steps as u64;
        m.ring_payload_bytes += r.ring_payload_bytes;
        m.gathered_kv_rows += r.union_rows as u64;
        if m.shard_stage_s.len() < r.per_shard.len() {
            m.shard_stage_s.resize(r.per_shard.len(), crate::pipeline::StageTiming::default());
        }
        for st in &r.per_shard {
            m.shard_stage_s[st.shard].merge(&st.timing);
        }
    }

    /// Account one decode step served against the paged KV-cache.
    pub fn record_decode(&self, r: &crate::pipeline::DecodeReport) {
        let mut m = self.inner.lock().unwrap();
        m.decode_steps += 1;
        m.decode_tokens += r.positions.len() as u64;
        m.cache_page_hits += r.page_hits as u64;
        m.cache_pages_rematerialized += r.rematerialized_pages as u64;
        m.cache_sessions_evicted += r.evicted_sessions.len() as u64;
        m.record_kvcache_residency(&r.residency, &r.cache_stats);
    }

    /// A point-in-time copy of every counter.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().unwrap();
        let window = (m.last_s - m.first_s.unwrap_or(0.0)).max(1e-9);
        MetricsSnapshot {
            requests: m.requests,
            rejected: m.rejected,
            failed: m.failed,
            batches: m.batches,
            rows: m.rows,
            latency: m.latency.summary(1e-9),
            queue: m.queue.summary(1e-9),
            batch_rows: m.batch_rows.summary(1.0),
            ttft_prefill: m.ttft_prefill.summary(1e-9),
            ttft_sharded: m.ttft_sharded.summary(1e-9),
            tpot_decode: m.tpot_decode.summary(1e-9),
            stage_hist: std::array::from_fn(|i| m.stage_ns[i].summary(1e-9)),
            rows_per_s: m.rows as f64 / window,
            stage_predict_s: m.stage_predict_s,
            stage_topk_s: m.stage_topk_s,
            stage_kv_gen_s: m.stage_kv_gen_s,
            stage_formal_s: m.stage_formal_s,
            stalls: m.stalls,
            decode_steps: m.decode_steps,
            decode_tokens: m.decode_tokens,
            cache_page_hits: m.cache_page_hits,
            cache_pages_rematerialized: m.cache_pages_rematerialized,
            cache_sessions_evicted: m.cache_sessions_evicted,
            cache_pages_evicted: m.cache_pages_evicted,
            cache_pages_shared: m.cache_pages_shared,
            cache_cow_splits: m.cache_cow_splits,
            kv_resident_pages: m.kv_resident_pages,
            kv_shared_pages: m.kv_shared_pages,
            kv_resident_bytes: m.kv_resident_bytes,
            kv_logical_bytes: m.kv_logical_bytes,
            workspace_bytes: m.workspace_bytes,
            sharded_prefills: m.sharded_prefills,
            sharded_decodes: m.sharded_decodes,
            ring_steps: m.ring_steps,
            ring_payload_bytes: m.ring_payload_bytes,
            gathered_kv_rows: m.gathered_kv_rows,
            shard_stage_s: m.shard_stage_s.clone(),
            traffic: m.traffic,
            sched: m.sched,
            latency_hist: m.latency.clone(),
            stage_ns_hist: m.stage_ns.clone(),
        }
    }
}

impl MetricsSnapshot {
    /// One-paragraph human-readable summary (the `star serve` footer).
    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} rejected={} failed={} batches={} rows={} \
             p50={:.3}ms p95={:.3}ms p99={:.3}ms mean={:.3}ms queue={:.3}ms \
             batch_rows={:.1} throughput={:.0} rows/s",
            self.requests,
            self.rejected,
            self.failed,
            self.batches,
            self.rows,
            self.latency.p50 * 1e3,
            self.latency.p95 * 1e3,
            self.latency.p99 * 1e3,
            self.latency.mean * 1e3,
            self.queue.mean * 1e3,
            self.batch_rows.mean,
            self.rows_per_s
        );
        let classed = [
            ("ttft_prefill", &self.ttft_prefill),
            ("ttft_sharded", &self.ttft_sharded),
            ("tpot_decode", &self.tpot_decode),
        ];
        if classed.iter().any(|(_, h)| h.count > 0) {
            s.push_str("\nclasses:");
            for (name, h) in classed {
                if h.count > 0 {
                    s.push_str(&format!(
                        " {name} p50={:.3}ms p99={:.3}ms (n={})",
                        h.p50 * 1e3,
                        h.p99 * 1e3,
                        h.count
                    ));
                }
            }
        }
        let stage_total =
            self.stage_predict_s + self.stage_topk_s + self.stage_kv_gen_s + self.stage_formal_s;
        if stage_total > 0.0 {
            s.push_str(&format!(
                "\nstages: predict={:.3}ms topk={:.3}ms kv_gen={:.3}ms formal={:.3}ms stalls={}",
                self.stage_predict_s * 1e3,
                self.stage_topk_s * 1e3,
                self.stage_kv_gen_s * 1e3,
                self.stage_formal_s * 1e3,
                self.stalls
            ));
        }
        if self.workspace_bytes > 0 {
            let budget = crate::sim::sram::Sram::STAR_BUDGET_BYTES;
            s.push_str(&format!(
                "\nworkspace: {} peak per worker (sim SRAM budget {}, {})",
                crate::util::fmt_bytes(self.workspace_bytes as f64),
                crate::util::fmt_bytes(budget as f64),
                if self.workspace_bytes <= budget { "fits" } else { "exceeds" }
            ));
        }
        if self.decode_steps > 0 {
            s.push_str(&format!(
                "\nkvcache: steps={} tokens={} page_hits={} rematerialized={} evicted={}",
                self.decode_steps,
                self.decode_tokens,
                self.cache_page_hits,
                self.cache_pages_rematerialized,
                self.cache_sessions_evicted
            ));
            if self.kv_logical_bytes > 0 {
                s.push_str(&format!(
                    " pages_resident={} pages_shared={} pages_evicted={} cow_splits={} \
                     resident={} logical={} compression={:.2}x",
                    self.kv_resident_pages,
                    self.kv_shared_pages,
                    self.cache_pages_evicted,
                    self.cache_cow_splits,
                    crate::util::fmt_bytes(self.kv_resident_bytes as f64),
                    crate::util::fmt_bytes(self.kv_logical_bytes as f64),
                    self.kv_logical_bytes as f64 / self.kv_resident_bytes.max(1) as f64
                ));
            }
        }
        if self.traffic.total_bytes() > 0 {
            s.push_str(&format!(
                "\ntraffic: dram={} sram={} ring={} cache_append={} remat={} \
                 steals={} imbalance={:.2}",
                crate::util::fmt_bytes(self.traffic.dram_class_bytes() as f64),
                crate::util::fmt_bytes(self.traffic.sram_class_bytes() as f64),
                crate::util::fmt_bytes(self.traffic.ring_payload_bytes as f64),
                crate::util::fmt_bytes(self.traffic.cache_append_bytes as f64),
                crate::util::fmt_bytes(self.traffic.cache_remat_bytes as f64),
                self.sched.steals,
                self.sched.imbalance()
            ));
        }
        if self.sharded_prefills > 0 || self.sharded_decodes > 0 {
            let busy: Vec<String> =
                self.shard_stage_s.iter().map(|t| format!("{:.3}ms", t.busy_s() * 1e3)).collect();
            s.push_str(&format!(
                "\nsharded: prefills={} decodes={} ring_steps={} payload={}B \
                 gathered_kv_rows={} shard_busy=[{}]",
                self.sharded_prefills,
                self.sharded_decodes,
                self.ring_steps,
                self.ring_payload_bytes,
                self.gathered_kv_rows,
                busy.join(" ")
            ));
        }
        s
    }

    /// Prometheus-style text exposition of the same snapshot — the
    /// scrape-endpoint view of [`MetricsSnapshot::render`].
    pub fn render_prometheus(&self) -> String {
        use crate::obs::prom::{
            write_histogram, write_histogram_family, write_summary, write_summary_family,
            write_value,
        };
        let mut out = String::new();
        write_value(&mut out, "star_requests_total", "responses delivered", "counter", self.requests as f64);
        write_value(&mut out, "star_rejected_total", "requests rejected at admission", "counter", self.rejected as f64);
        write_value(&mut out, "star_failed_total", "batches whose backend execution errored", "counter", self.failed as f64);
        write_value(&mut out, "star_batches_total", "batches dispatched to the worker pool", "counter", self.batches as f64);
        write_value(&mut out, "star_rows_total", "query rows across dispatched batches", "counter", self.rows as f64);
        write_value(&mut out, "star_rows_per_second", "served query rows per second over the observation window", "gauge", self.rows_per_s);
        write_summary(&mut out, "star_request_latency_seconds", "end-to-end request latency", "", &self.latency);
        write_summary(&mut out, "star_queue_wait_seconds", "queueing share of the request latency", "", &self.queue);
        write_summary(&mut out, "star_batch_rows", "query rows per sealed batch", "", &self.batch_rows);
        write_summary_family(
            &mut out,
            "star_ttft_seconds",
            "time to first token by prefill path",
            &[
                ("class=\"prefill\"", &self.ttft_prefill),
                ("class=\"sharded\"", &self.ttft_sharded),
            ],
        );
        write_summary(&mut out, "star_tpot_seconds", "time per output token of decode responses", "", &self.tpot_decode);
        let labels: Vec<String> =
            STAGE_NAMES.iter().map(|n| format!("stage=\"{n}\"")).collect();
        let series: Vec<(&str, &HistSummary)> =
            labels.iter().map(String::as_str).zip(self.stage_hist.iter()).collect();
        write_summary_family(
            &mut out,
            "star_stage_seconds",
            "per-batch pipeline-stage busy time",
            &series,
        );
        write_value(&mut out, "star_stalls_total", "SU-FA max-misprediction recoveries", "counter", self.stalls as f64);
        write_value(&mut out, "star_workspace_bytes", "peak per-worker tile-workspace capacity", "gauge", self.workspace_bytes as f64);
        write_value(&mut out, "star_decode_steps_total", "decode steps served against the paged KV-cache", "counter", self.decode_steps as f64);
        write_value(&mut out, "star_decode_tokens_total", "tokens appended across decode steps", "counter", self.decode_tokens as f64);
        write_value(&mut out, "star_cache_page_hits_total", "resident pages read per decode step, summed", "counter", self.cache_page_hits as f64);
        write_value(&mut out, "star_cache_pages_rematerialized_total", "pages rebuilt from history after eviction", "counter", self.cache_pages_rematerialized as f64);
        write_value(&mut out, "star_cache_sessions_evicted_total", "sessions an eviction took from fully resident to partial", "counter", self.cache_sessions_evicted as f64);
        write_value(&mut out, "star_kvcache_resident_bytes", "measured heap bytes of resident KV pages", "gauge", self.kv_resident_bytes as f64);
        write_value(&mut out, "star_kvcache_logical_bytes", "f32 K+V bytes a flat cache would hold for the same tokens", "gauge", self.kv_logical_bytes as f64);
        write_value(&mut out, "star_kvcache_pages_resident_total", "pages resident in the pool, shared pages counted once", "gauge", self.kv_resident_pages as f64);
        write_value(&mut out, "star_kvcache_pages_shared_total", "resident pages referenced by more than one session", "gauge", self.kv_shared_pages as f64);
        write_value(&mut out, "star_kvcache_pages_evicted_total", "page references dropped by page-granular eviction", "counter", self.cache_pages_evicted as f64);
        write_value(&mut out, "star_kvcache_cow_splits_total", "copy-on-write splits of shared pages on divergence", "counter", self.cache_cow_splits as f64);
        write_value(&mut out, "star_sharded_prefills_total", "over-target prefills served on the sharded pipeline", "counter", self.sharded_prefills as f64);
        write_value(&mut out, "star_sharded_decodes_total", "over-target decode steps served on the page-partitioned sharded pipeline", "counter", self.sharded_decodes as f64);
        write_value(&mut out, "star_ring_steps_total", "ring steps across sharded runs", "counter", self.ring_steps as f64);
        write_value(&mut out, "star_ring_payload_bytes_total", "modeled bytes forwarded on the worker ring", "counter", self.ring_payload_bytes as f64);
        write_value(&mut out, "star_gathered_kv_rows_total", "selected KV rows gathered to home workers", "counter", self.gathered_kv_rows as f64);
        // Measured byte-traffic counters (crate::obs::traffic): one
        // counter family member per TrafficCounter field — the same list
        // the BENCH_traffic.json writer emits.
        for (key, v) in self.traffic.fields() {
            write_value(
                &mut out,
                &format!("star_traffic_{key}_total"),
                "measured bytes (crate::obs::traffic)",
                "counter",
                v as f64,
            );
        }
        write_value(&mut out, "star_sched_workers", "worker threads in the widest parallel section", "gauge", self.sched.workers as f64);
        write_value(&mut out, "star_sched_chunk_grabs_total", "chunk claims off the shared cursor", "counter", self.sched.chunk_grabs as f64);
        write_value(&mut out, "star_sched_steals_total", "chunk claims beyond each worker's first", "counter", self.sched.steals as f64);
        write_value(&mut out, "star_sched_tiles_total", "tiles executed by the work-stealing scheduler", "counter", self.sched.tiles as f64);
        write_value(&mut out, "star_sched_imbalance", "busiest-worker load vs perfect split", "gauge", self.sched.imbalance());
        // Cumulative log-bucketed histograms (`_bucket{le=…}`) behind
        // the summary quantiles above.
        write_histogram(
            &mut out,
            "star_request_latency_hist_seconds",
            "end-to-end request latency histogram",
            "",
            &self.latency_hist,
            1e-9,
        );
        let labels: Vec<String> =
            STAGE_NAMES.iter().map(|n| format!("stage=\"{n}\"")).collect();
        let series: Vec<(&str, &Histogram)> =
            labels.iter().map(String::as_str).zip(self.stage_ns_hist.iter()).collect();
        write_histogram_family(
            &mut out,
            "star_stage_hist_seconds",
            "per-batch pipeline-stage busy-time histogram",
            &series,
            1e-9,
        );
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_response(0.010, 0.002, 1.0, RequestClass::Prefill, 64);
        m.record_response(0.020, 0.004, 2.0, RequestClass::Prefill, 128);
        m.record_batch(64);
        m.record_batch(128);
        m.record_rejection();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rows, 192);
        // The histogram keeps the exact sum, so the mean is exact; the
        // percentiles are bucket-quantized to ~3%.
        assert!((s.latency.mean - 0.015).abs() < 1e-12);
        assert!((s.latency.p95 - 0.020).abs() / 0.020 < 0.04, "{}", s.latency.p95);
        assert!((s.latency.min - 0.010).abs() < 1e-12);
        assert!((s.latency.max - 0.020).abs() < 1e-12);
        assert!((s.batch_rows.mean - 96.0).abs() < 1e-12);
        assert!((s.rows_per_s - 192.0).abs() < 1e-6);
        assert_eq!(s.ttft_prefill.count, 2);
        assert_eq!(s.tpot_decode.count, 0);
        assert!(s.render().contains("requests=2"));
    }

    #[test]
    fn per_class_histograms_split_ttft_and_tpot() {
        let m = Metrics::new();
        m.record_response(0.030, 0.0, 1.0, RequestClass::Sharded, 512);
        // A 10-token decode step at 10ms total → 1ms per output token.
        m.record_response(0.010, 0.0, 2.0, RequestClass::Decode, 10);
        // tokens=0 must not divide by zero.
        m.record_response(0.001, 0.0, 3.0, RequestClass::Decode, 0);
        let s = m.snapshot();
        assert_eq!(s.ttft_sharded.count, 1);
        assert!((s.ttft_sharded.mean - 0.030).abs() < 1e-12);
        assert_eq!(s.tpot_decode.count, 2);
        assert!((s.tpot_decode.max - 0.001).abs() < 1e-12);
        let line = s.render();
        assert!(line.contains("tpot_decode"), "{line}");
        assert!(line.contains("ttft_sharded"), "{line}");
    }

    #[test]
    fn stage_histograms_record_per_batch_times() {
        use crate::pipeline::StageTiming;
        let m = Metrics::new();
        let t = StageTiming {
            predict_s: 0.001,
            topk_s: 0.002,
            kv_gen_s: 0.003,
            formal_s: 0.004,
        };
        m.record_stage_times(&t, 1);
        m.record_stage_times(&t, 0);
        let s = m.snapshot();
        assert_eq!(s.stalls, 1);
        for (i, expect) in [0.001, 0.002, 0.003, 0.004].iter().enumerate() {
            assert_eq!(s.stage_hist[i].count, 2, "{}", STAGE_NAMES[i]);
            assert!(
                (s.stage_hist[i].mean - expect).abs() < 1e-12,
                "{}: {}",
                STAGE_NAMES[i],
                s.stage_hist[i].mean
            );
        }
        assert!((s.stage_predict_s - 0.002).abs() < 1e-12);
    }

    #[test]
    fn prometheus_exposition_is_complete() {
        let m = Metrics::new();
        m.record_response(0.010, 0.001, 1.0, RequestClass::Prefill, 32);
        m.record_response(0.005, 0.001, 2.0, RequestClass::Decode, 5);
        m.record_batch(32);
        let text = m.snapshot().render_prometheus();
        for family in [
            "star_requests_total 2",
            "# TYPE star_request_latency_seconds summary",
            "star_request_latency_seconds{quantile=\"0.99\"}",
            "star_ttft_seconds{class=\"prefill\",quantile=\"0.5\"}",
            "star_tpot_seconds_count 1",
            "star_stage_seconds{stage=\"formal\",quantile=\"0.95\"}",
            "star_batch_rows_count 1",
            "star_traffic_q_ingest_bytes_total",
            "star_traffic_cache_remat_bytes_total",
            "star_kvcache_resident_bytes",
            "star_kvcache_pages_resident_total",
            "star_kvcache_pages_shared_total",
            "star_kvcache_pages_evicted_total",
            "star_kvcache_cow_splits_total",
            "star_sched_steals_total",
            "star_sched_imbalance",
            "# TYPE star_request_latency_hist_seconds histogram",
            "star_request_latency_hist_seconds_bucket{le=\"+Inf\"} 2",
            "star_stage_hist_seconds_bucket{stage=\"predict\",le=\"+Inf\"}",
        ] {
            assert!(text.contains(family), "missing {family:?} in:\n{text}");
        }
        // One header per family even with several labeled series.
        assert_eq!(text.matches("# TYPE star_ttft_seconds summary").count(), 1);
        assert_eq!(text.matches("# TYPE star_stage_seconds summary").count(), 1);
        assert_eq!(text.matches("# TYPE star_stage_hist_seconds histogram").count(), 1);
    }

    #[test]
    fn traffic_counters_accumulate_and_render() {
        let m = Metrics::new();
        let mut t = TrafficCounter::new();
        t.q_ingest_bytes = 1024;
        t.ring_payload_bytes = 64;
        m.record_traffic(&t, &SchedStats::single(8));
        m.record_traffic(&t, &SchedStats::single(8));
        let s = m.snapshot();
        assert_eq!(s.traffic.q_ingest_bytes, 2048);
        assert_eq!(s.traffic.ring_payload_bytes, 128);
        assert_eq!(s.sched.tiles, 16);
        assert_eq!(s.sched.workers, 1);
        let line = s.render();
        assert!(line.contains("traffic: dram="), "{line}");
        let prom = s.render_prometheus();
        assert!(prom.contains("star_traffic_q_ingest_bytes_total 2048"), "{prom}");
        assert!(prom.contains("star_sched_tiles_total 16"), "{prom}");
    }

    #[test]
    fn workspace_gauge_keeps_peak_and_renders_budget() {
        let m = Metrics::new();
        m.record_workspace_bytes(4096);
        m.record_workspace_bytes(1024);
        let s = m.snapshot();
        assert_eq!(s.workspace_bytes, 4096);
        let line = s.render();
        assert!(line.contains("workspace:"), "{line}");
        assert!(line.contains("fits"), "{line}");
        m.record_workspace_bytes(400 * 1024 * 1024);
        assert!(m.snapshot().render().contains("exceeds"));
    }

    #[test]
    fn records_sharded_runs() {
        use crate::pipeline::{PipelineConfig, PipelineInputs, ShardedPipeline};
        use crate::tensor::Mat;
        use crate::util::Rng;
        let mut rng = Rng::new(3);
        let q = Mat::randn(8, 8, 1.0, &mut rng);
        let k = Mat::randn(32, 8, 1.0, &mut rng);
        let v = Mat::randn(32, 8, 1.0, &mut rng);
        let r = ShardedPipeline::new(PipelineConfig::star().with_keep(0.25), 2)
            .run(&PipelineInputs::qkv(&q, &k, &v));
        assert_eq!(r.shards, 2);
        let m = Metrics::new();
        m.record_sharded(&r);
        m.record_sharded(&r);
        let s = m.snapshot();
        assert_eq!(s.sharded_prefills, 2);
        assert_eq!(s.ring_steps, 2 * r.ring_steps as u64);
        assert_eq!(s.gathered_kv_rows, 2 * r.union_rows as u64);
        assert_eq!(s.shard_stage_s.len(), r.shards);
        assert!(s.render().contains("sharded: prefills=2"));
    }

    #[test]
    fn records_sharded_decode_steps() {
        use crate::kvcache::{SessionConfig, SessionStore};
        use crate::pipeline::{PipelineConfig, ShardedPipeline};
        use crate::tensor::Mat;
        use crate::util::Rng;
        let cfg = PipelineConfig::star().with_keep(0.25).with_threads(1);
        let mut rng = Rng::new(5);
        let q = Mat::randn(24, 16, 1.0, &mut rng);
        let k = Mat::randn(24, 16, 1.0, &mut rng);
        let v = Mat::randn(24, 16, 1.0, &mut rng);
        let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, 16, 0));
        let r = ShardedPipeline::new(cfg, 2).decode_step(&mut store, 7, &q, &k, &v).unwrap();
        assert_eq!(r.shards, 2);
        let m = Metrics::new();
        m.record_sharded_decode(&r);
        let s = m.snapshot();
        assert_eq!(s.sharded_decodes, 1);
        assert_eq!(s.decode_steps, 1);
        assert_eq!(s.decode_tokens, 24);
        assert_eq!(s.ring_steps, r.ring_steps as u64);
        assert_eq!(s.ring_payload_bytes, r.ring_payload_bytes);
        assert_eq!(s.shard_stage_s.len(), r.shards);
        // The report carries the store's residency snapshot: 24 resident
        // tokens → non-zero gauges and a compression figure in the render.
        assert!(s.kv_resident_pages > 0);
        assert!(s.kv_resident_bytes > 0);
        assert!(s.kv_logical_bytes > 0);
        let line = s.render();
        assert!(line.contains("decodes=1"), "{line}");
        assert!(line.contains("kvcache: steps=1"), "{line}");
        assert!(line.contains("compression="), "{line}");
        let prom = s.render_prometheus();
        assert!(prom.contains("star_sharded_decodes_total 1"), "{prom}");
        assert!(prom.contains("star_kvcache_pages_resident_total"), "{prom}");
    }

    #[test]
    fn thread_safe() {
        let m = std::sync::Arc::new(Metrics::new());
        let hs: Vec<_> = (0..4)
            .map(|i| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for j in 0..100 {
                        m.record_response(0.001 * i as f64, 0.0, j as f64, RequestClass::Prefill, 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().requests, 400);
    }
}
