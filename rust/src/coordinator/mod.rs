//! The LTPP coordinator — Layer 3's serving contribution.
//!
//! STAR's architectural premise is *large-scale token parallel
//! processing*: the accelerator wants 128 queries per batch, so the
//! serving layer must aggregate requests into LTPP batches and keep the
//! stage pipeline full. The coordinator owns the event loop:
//!
//! * [`router`] — admits requests, validates them against the loaded
//!   model variants and the batcher's row target
//!   ([`Router::admit`]), and routes each to the variant queue whose
//!   compiled shape fits (artifacts have static shapes; routing = shape
//!   bucketing). Decode requests ([`Request::decode`]) carry a session
//!   id plus new-token Q/K/V rows. Stateless prefill *wider than the
//!   batch target* is admitted onto the sequence-sharded execution
//!   path ([`router::Admission::Sharded`] →
//!   [`crate::pipeline::ShardedPipeline`]) instead of being rejected.
//! * [`batcher`] — dynamic + continuous batching: emit a batch when it
//!   reaches the target query parallelism or when the oldest request
//!   exceeds the latency budget. Decode sessions re-enter the batcher
//!   on every step, so decode chunks and prefill chunks mix in one
//!   LTPP batch up to `target_t`.
//! * [`scheduler`] — the tiled out-of-order stage scheduler (the paper's
//!   "tiled & OoO scheduler", Fig. 12): stage-tiles of independent
//!   batches issue out of order so no unit idles at stage boundaries.
//! * [`server`] — the thread-based serving loop gluing the above to an
//!   execution backend: the native pipeline (session-aware — decode
//!   requests run against a shared [`crate::kvcache::SessionStore`]),
//!   the PJRT `crate::runtime::Engine` (real numerics, `pjrt`
//!   feature) or the cycle-level simulator (timing studies).
//! * [`metrics`] — latency/throughput accounting on fixed-storage
//!   log-bucketed histograms ([`crate::obs::hist`]): request-latency /
//!   queue-wait / batch-occupancy distributions, per-class TTFT and
//!   TPOT, per-stage busy times, KV-cache hit/eviction counters, the
//!   sharded path's per-shard timings + ring-step counters, and a
//!   Prometheus-style text exposition
//!   ([`MetricsSnapshot::render_prometheus`]).

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot, RequestClass};
pub use router::{Admission, Request, Response, RouteError, Router, Variant};
pub use scheduler::{Stage, StageJob, TiledScheduler};
pub use server::{Backend, Server, ServerConfig};
