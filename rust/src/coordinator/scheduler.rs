//! The tiled out-of-order stage scheduler (Fig. 12's "tiled & OoO
//! scheduler", the RASS of the ablation studies).
//!
//! A batch decomposes into stage jobs — predict → top-k → KV-gen →
//! formal — each split into tiles. Tiles of *different* batches are
//! independent, so when batch A's top-k tile waits on its predict tile,
//! a tile of batch B can issue to the same unit instead of letting it
//! idle. The scheduler tracks per-tile dependencies and issues ready
//! tiles oldest-deadline-first.

use std::collections::BTreeMap;

/// DS pipeline stages, in dependency order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Approximate score estimation (Sec. IV-A).
    Predict,
    /// Vital-key selection (Sec. IV-B).
    TopK,
    /// On-demand KV generation for the selected union.
    KvGen,
    /// Formal attention compute (SU-FA).
    Formal,
}

impl Stage {
    /// The stage that depends on this one (`None` after `Formal`).
    pub fn next(self) -> Option<Stage> {
        match self {
            Stage::Predict => Some(Stage::TopK),
            Stage::TopK => Some(Stage::KvGen),
            Stage::KvGen => Some(Stage::Formal),
            Stage::Formal => None,
        }
    }

    /// Every stage, in dependency order.
    pub const ALL: [Stage; 4] = [Stage::Predict, Stage::TopK, Stage::KvGen, Stage::Formal];
}

/// One schedulable tile of work.
#[derive(Clone, Debug, PartialEq)]
pub struct StageJob {
    /// The batch this tile belongs to.
    pub batch_id: u64,
    /// Which pipeline stage the tile runs.
    pub stage: Stage,
    /// Tile index within the batch's stage.
    pub tile: usize,
    /// Issue deadline proxy (batch arrival time) for oldest-first issue.
    pub deadline: f64,
}

/// Tracks tile completion and hands out ready work.
#[derive(Debug, Default)]
pub struct TiledScheduler {
    /// (batch, stage) → tiles remaining.
    remaining: BTreeMap<(u64, Stage), usize>,
    /// Tiles per stage for each batch.
    tiles: BTreeMap<u64, usize>,
    /// Deadline per batch.
    deadlines: BTreeMap<u64, f64>,
    /// Ready-to-issue jobs.
    ready: Vec<StageJob>,
    /// Completed batches (all formal tiles done), drained by `take_done`.
    done: Vec<u64>,
    /// Issue log length (for utilization accounting).
    issued: u64,
}

impl TiledScheduler {
    /// An empty scheduler.
    pub fn new() -> TiledScheduler {
        TiledScheduler::default()
    }

    /// Admit a batch split into `tiles` tiles per stage.
    pub fn admit(&mut self, batch_id: u64, tiles: usize, deadline: f64) {
        let tiles = tiles.max(1);
        self.tiles.insert(batch_id, tiles);
        self.deadlines.insert(batch_id, deadline);
        for stage in Stage::ALL {
            self.remaining.insert((batch_id, stage), tiles);
        }
        // Predict tiles have no dependencies: ready immediately.
        for tile in 0..tiles {
            self.ready.push(StageJob { batch_id, stage: Stage::Predict, tile, deadline });
        }
        self.sort_ready();
    }

    fn sort_ready(&mut self) {
        // Oldest deadline first; tie-break: later stages first (drain the
        // pipeline) then tile index.
        self.ready.sort_by(|a, b| {
            a.deadline
                .partial_cmp(&b.deadline)
                .unwrap()
                .then(b.stage.cmp(&a.stage))
                .then(a.tile.cmp(&b.tile))
        });
    }

    /// Number of ready jobs.
    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    /// Issue the next ready job, preferring one whose stage differs from
    /// `busy_stage` (the unit that just finished can't take another tile
    /// of the same stage while its successor is stalled — this is the
    /// out-of-order part).
    pub fn issue(&mut self, busy_stage: Option<Stage>) -> Option<StageJob> {
        if self.ready.is_empty() {
            return None;
        }
        let idx = match busy_stage {
            Some(busy) => self.ready.iter().position(|j| j.stage != busy).unwrap_or(0),
            None => 0,
        };
        self.issued += 1;
        Some(self.ready.remove(idx))
    }

    /// Mark a job complete; its successor tile becomes ready.
    pub fn complete(&mut self, job: &StageJob) {
        let key = (job.batch_id, job.stage);
        let rem = self.remaining.get_mut(&key).expect("unknown job");
        assert!(*rem > 0, "double completion of {job:?}");
        *rem -= 1;
        if let Some(next) = job.stage.next() {
            self.ready.push(StageJob {
                batch_id: job.batch_id,
                stage: next,
                tile: job.tile,
                deadline: job.deadline,
            });
            self.sort_ready();
        } else if self.remaining[&(job.batch_id, Stage::Formal)] == 0 {
            self.done.push(job.batch_id);
            self.tiles.remove(&job.batch_id);
            self.deadlines.remove(&job.batch_id);
            for stage in Stage::ALL {
                self.remaining.remove(&(job.batch_id, stage));
            }
        }
    }

    /// Drain finished batch ids.
    pub fn take_done(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.done)
    }

    /// Batches admitted but not yet fully complete.
    pub fn in_flight(&self) -> usize {
        self.tiles.len()
    }

    /// Total jobs issued so far (utilization accounting).
    pub fn issued(&self) -> u64 {
        self.issued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_batch_flows_through_all_stages() {
        let mut s = TiledScheduler::new();
        s.admit(1, 2, 0.0);
        let mut completed = 0;
        while let Some(job) = s.issue(None) {
            s.complete(&job);
            completed += 1;
        }
        assert_eq!(completed, 8, "2 tiles × 4 stages");
        assert_eq!(s.take_done(), vec![1]);
        assert_eq!(s.in_flight(), 0);
    }

    #[test]
    fn dependencies_respected() {
        let mut s = TiledScheduler::new();
        s.admit(7, 1, 0.0);
        let j1 = s.issue(None).unwrap();
        assert_eq!(j1.stage, Stage::Predict);
        assert!(s.issue(None).is_none(), "top-k must wait for predict");
        s.complete(&j1);
        assert_eq!(s.issue(None).unwrap().stage, Stage::TopK);
    }

    #[test]
    fn ooo_prefers_other_batch_when_stage_busy() {
        let mut s = TiledScheduler::new();
        s.admit(1, 1, 0.0);
        s.admit(2, 1, 1.0);
        let a = s.issue(None).unwrap();
        assert_eq!(a.batch_id, 1);
        // Predict unit busy with batch 1 → next issue should avoid
        // Predict... but only Predict tiles are ready, so it falls back.
        let b = s.issue(Some(Stage::Predict)).unwrap();
        assert_eq!(b.batch_id, 2);
        s.complete(&a);
        // Now batch 1's TopK is ready; with Predict busy it is preferred.
        let c = s.issue(Some(Stage::Predict)).unwrap();
        assert_eq!((c.batch_id, c.stage), (1, Stage::TopK));
    }

    #[test]
    fn oldest_deadline_first() {
        let mut s = TiledScheduler::new();
        s.admit(10, 1, 5.0);
        s.admit(11, 1, 1.0);
        assert_eq!(s.issue(None).unwrap().batch_id, 11);
    }

    #[test]
    fn multi_batch_all_complete() {
        let mut s = TiledScheduler::new();
        for b in 0..5u64 {
            s.admit(b, 3, b as f64);
        }
        let mut done = Vec::new();
        while let Some(job) = s.issue(None) {
            s.complete(&job);
            done.extend(s.take_done());
        }
        done.sort_unstable();
        assert_eq!(done, vec![0, 1, 2, 3, 4]);
        assert_eq!(s.issued(), 5 * 3 * 4);
    }
}
