//! Dynamic batching for LTPP.
//!
//! The accelerator processes `query_parallel` (128) queries per pass;
//! serving single requests would waste almost the entire datapath. The
//! batcher accumulates routed requests per variant and emits a batch
//! when (a) the accumulated query rows reach the target parallelism, or
//! (b) the oldest waiting request has been queued longer than the
//! latency budget (so tail latency stays bounded at low load).

use super::router::Request;
use std::collections::VecDeque;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Target query rows per batch (the accelerator's T).
    pub target_t: usize,
    /// Max queueing delay before a partial batch is flushed, seconds.
    pub max_wait_s: f64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { target_t: 128, max_wait_s: 2e-3 }
    }
}

/// An emitted batch: requests whose query rows sum to ≤ target_t.
#[derive(Clone, Debug)]
pub struct Batch {
    /// The variant the batch executes on.
    pub variant: String,
    /// The batched requests, admission order.
    pub requests: Vec<Request>,
    /// When the batch was sealed (seconds, caller clock).
    pub sealed_s: f64,
    /// Over-target prefill admitted onto the sequence-sharded path
    /// ([`super::router::Admission::Sharded`]): the batch bypassed the
    /// batcher and executes on the sharded pipeline.
    pub sharded: bool,
}

impl Batch {
    /// Total query rows across the batch's requests.
    pub fn rows(&self) -> usize {
        self.requests.iter().map(|r| r.t).sum()
    }

    /// Padding waste if executed at `target` rows.
    pub fn padding(&self, target: usize) -> usize {
        target.saturating_sub(self.rows())
    }

    /// Datapath occupancy at `target` rows, in `[0, 1]` (>1 clamps: a
    /// lone oversize request occupies the whole pass). This is the
    /// batching-quality number behind the metrics' batch-rows histogram:
    /// mean occupancy ≈ `batch_rows.mean / target`.
    pub fn occupancy(&self, target: usize) -> f64 {
        if target == 0 {
            return 1.0;
        }
        (self.rows() as f64 / target as f64).min(1.0)
    }
}

/// Per-variant dynamic batcher.
#[derive(Clone, Debug)]
pub struct Batcher {
    /// The variant whose requests this batcher accumulates.
    pub variant: String,
    cfg: BatcherConfig,
    queue: VecDeque<Request>,
    queued_rows: usize,
}

impl Batcher {
    /// An empty batcher for one variant queue.
    pub fn new(variant: &str, cfg: BatcherConfig) -> Batcher {
        Batcher { variant: variant.to_string(), cfg, queue: VecDeque::new(), queued_rows: 0 }
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Query rows currently queued.
    pub fn pending_rows(&self) -> usize {
        self.queued_rows
    }

    /// Enqueue a routed request.
    pub fn push(&mut self, req: Request) {
        self.queued_rows += req.t;
        self.queue.push_back(req);
    }

    /// Poll at time `now`: emit the next batch if the policy says so.
    pub fn poll(&mut self, now: f64) -> Option<Batch> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait = now - self.queue.front().unwrap().arrival_s;
        let full = self.queued_rows >= self.cfg.target_t;
        if !full && oldest_wait < self.cfg.max_wait_s {
            return None;
        }
        Some(self.seal(now))
    }

    /// Force-flush whatever is queued (shutdown path).
    pub fn flush(&mut self, now: f64) -> Option<Batch> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.seal(now))
        }
    }

    fn seal(&mut self, now: f64) -> Batch {
        let mut requests = Vec::new();
        let mut rows = 0;
        while let Some(front) = self.queue.front() {
            // A lone oversize request still seals alone (escape hatch for
            // direct Batcher users); the server path never reaches this —
            // `Router::admit` rejects t > target_t at admission.
            if rows + front.t > self.cfg.target_t && !requests.is_empty() {
                break;
            }
            let r = self.queue.pop_front().unwrap();
            rows += r.t;
            self.queued_rows -= r.t;
            requests.push(r);
        }
        Batch { variant: self.variant.clone(), requests, sealed_s: now, sharded: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, t: usize, at: f64) -> Request {
        Request::new(id, "tiny", t, 256, at)
    }

    #[test]
    fn emits_when_full() {
        let mut b = Batcher::new("v", BatcherConfig { target_t: 64, max_wait_s: 1.0 });
        for i in 0..3 {
            b.push(req(i, 16, 0.0));
        }
        assert!(b.poll(0.0).is_none(), "48 rows < 64 and no timeout");
        b.push(req(3, 16, 0.0));
        let batch = b.poll(0.0).expect("full batch");
        assert_eq!(batch.rows(), 64);
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn emits_partial_on_timeout() {
        let mut b = Batcher::new("v", BatcherConfig { target_t: 128, max_wait_s: 0.01 });
        b.push(req(0, 8, 0.0));
        assert!(b.poll(0.005).is_none());
        let batch = b.poll(0.02).expect("timeout flush");
        assert_eq!(batch.rows(), 8);
        assert_eq!(batch.padding(128), 120);
        assert!((batch.occupancy(128) - 8.0 / 128.0).abs() < 1e-12);
        assert_eq!(batch.occupancy(4), 1.0, "oversize clamps");
        assert_eq!(batch.occupancy(0), 1.0);
    }

    #[test]
    fn never_splits_over_target_unless_single() {
        let mut b = Batcher::new("v", BatcherConfig { target_t: 32, max_wait_s: 0.0 });
        b.push(req(0, 24, 0.0));
        b.push(req(1, 24, 0.0));
        let first = b.poll(1.0).unwrap();
        assert_eq!(first.requests.len(), 1, "24+24 > 32: second waits");
        let second = b.poll(2.0).unwrap();
        assert_eq!(second.requests.len(), 1);
        // An oversize single request still goes through alone.
        b.push(req(2, 100, 0.0));
        let third = b.poll(3.0).unwrap();
        assert_eq!(third.rows(), 100);
    }

    #[test]
    fn flush_drains() {
        let mut b = Batcher::new("v", BatcherConfig::default());
        assert!(b.flush(0.0).is_none());
        b.push(req(0, 4, 0.0));
        assert_eq!(b.flush(0.0).unwrap().rows(), 4);
        assert_eq!(b.pending_rows(), 0);
    }
}
