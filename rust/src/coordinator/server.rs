//! The thread-based serving loop (std threads + mpsc; the environment
//! has no tokio — DESIGN.md §2).
//!
//! Architecture: callers `submit()` requests through a channel to the
//! dispatcher thread, which routes (shape buckets), batches (dynamic
//! batching per variant), and hands sealed batches to a worker pool.
//! Workers execute on the configured backend — the PJRT engine for real
//! numerics, or the cycle-level simulator for timing studies — and reply
//! per-request. Python never runs anywhere in this path.

use super::batcher::{Batch, Batcher, BatcherConfig};
use super::metrics::{Metrics, MetricsSnapshot};
use super::router::{Request, Response, Router};
use crate::config::AccelConfig;
use crate::runtime::Engine;
use crate::sim::dram::DramChannel;
use crate::sim::pipeline::{simulate, FeatureSet, WorkloadShape};
use crate::tensor::Mat;
use crate::Result;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// How batches actually execute. This is pure (Send) configuration: the
/// PJRT client is **not** thread-safe, so each worker thread constructs
/// its own [`Engine`] lazily from `artifact_dir` on first use.
pub enum Backend {
    /// Execute the AOT-compiled PJRT artifact named by each variant.
    /// `contexts` maps variant name → (K, V) context matrices.
    Pjrt { artifact_dir: PathBuf, contexts: BTreeMap<String, (Mat, Mat)> },
    /// Model the accelerator: latency from the cycle-level simulator,
    /// stretched by `time_scale` wall-clock seconds per simulated second.
    Sim { feats: FeatureSet, accel: AccelConfig, dram: DramChannel, d: usize, h: usize, keep: f64, time_scale: f64 },
}

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { batcher: BatcherConfig::default(), workers: 2 }
    }
}

enum Msg {
    Submit(Request, Sender<Response>),
    Tick,
    Shutdown,
}

/// The running server.
pub struct Server {
    tx: Sender<Msg>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    started: Instant,
    stopped: Arc<AtomicBool>,
}

impl Server {
    /// Spawn the dispatcher and worker pool.
    pub fn start(router: Router, backend: Backend, cfg: ServerConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = channel::<Msg>();
        let started = Instant::now();
        let stopped = Arc::new(AtomicBool::new(false));

        // Worker pool input.
        let (work_tx, work_rx) = channel::<(Batch, Vec<Sender<Response>>)>();
        let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
        let backend = Arc::new(backend);
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = work_rx.clone();
            let be = backend.clone();
            let m = metrics.clone();
            workers.push(std::thread::spawn(move || {
                // Per-worker PJRT engine, built on first use (the client
                // is not Send; it must live on this thread).
                let mut engine: Option<Engine> = None;
                loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok((batch, replies)) => {
                            execute_batch(&be, &mut engine, batch, replies, &m, started)
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        let m = metrics.clone();
        let stop_flag = stopped.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batchers: BTreeMap<String, Batcher> = BTreeMap::new();
            let mut waiting: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
            let now = || started.elapsed().as_secs_f64();
            loop {
                // Block briefly so timeout-flushes still happen at low load.
                let msg = rx.recv_timeout(std::time::Duration::from_millis(1)).unwrap_or(Msg::Tick);
                match msg {
                    Msg::Submit(req, reply) => match router.route(&req) {
                        Ok(variant) => {
                            waiting.insert(req.id, reply);
                            batchers
                                .entry(variant.name.clone())
                                .or_insert_with(|| Batcher::new(&variant.name, cfg.batcher))
                                .push(req);
                        }
                        Err(e) => {
                            m.record_rejection();
                            let _ = reply.send(Response {
                                id: req.id,
                                output: None,
                                latency_s: 0.0,
                                queue_s: 0.0,
                                variant: format!("rejected: {e}"),
                            });
                        }
                    },
                    Msg::Tick => {}
                    Msg::Shutdown => {
                        for b in batchers.values_mut() {
                            if let Some(batch) = b.flush(now()) {
                                dispatch(batch, &mut waiting, &work_tx, &m);
                            }
                        }
                        stop_flag.store(true, Ordering::SeqCst);
                        break;
                    }
                }
                let t = now();
                for b in batchers.values_mut() {
                    while let Some(batch) = b.poll(t) {
                        dispatch(batch, &mut waiting, &work_tx, &m);
                    }
                }
            }
            drop(work_tx); // close the pool
            for w in workers {
                let _ = w.join();
            }
        });

        Server { tx, dispatcher: Some(dispatcher), metrics, started, stopped }
    }

    /// Monotonic server clock, seconds.
    pub fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, mut req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        req.arrival_s = self.now();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rx)
    }

    /// Flush, stop all threads, and return the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.stopped.load(Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(h) = self.dispatcher.take() {
                let _ = h.join();
            }
        }
    }
}

fn dispatch(
    batch: Batch,
    waiting: &mut BTreeMap<u64, Sender<Response>>,
    work_tx: &Sender<(Batch, Vec<Sender<Response>>)>,
    metrics: &Metrics,
) {
    metrics.record_batch(batch.rows());
    let replies: Vec<Sender<Response>> = batch
        .requests
        .iter()
        .map(|r| waiting.remove(&r.id).expect("reply channel registered at submit"))
        .collect();
    let _ = work_tx.send((batch, replies));
}

fn execute_batch(
    backend: &Backend,
    engine_slot: &mut Option<Engine>,
    batch: Batch,
    replies: Vec<Sender<Response>>,
    metrics: &Metrics,
    started: Instant,
) {
    let sealed = batch.sealed_s;
    match backend {
        Backend::Pjrt { artifact_dir, contexts } => {
            let out = ensure_engine(engine_slot, artifact_dir)
                .and_then(|engine| run_pjrt(engine, contexts, &batch));
            let now = started.elapsed().as_secs_f64();
            for (i, (req, reply)) in batch.requests.iter().zip(replies).enumerate() {
                let output = out.as_ref().ok().map(|rows| rows[i].clone());
                let latency = now - req.arrival_s;
                let queue = sealed - req.arrival_s;
                metrics.record_response(latency, queue, now);
                let _ = reply.send(Response {
                    id: req.id,
                    output,
                    latency_s: latency,
                    queue_s: queue,
                    variant: batch.variant.clone(),
                });
            }
        }
        Backend::Sim { feats, accel, dram, d, h, keep, time_scale } => {
            let rows = batch.rows().max(1);
            let s = batch.requests.iter().map(|r| r.s).max().unwrap_or(1);
            let shape = WorkloadShape::new(rows, s, *d, *h, *keep);
            let rep = simulate(&shape, feats, accel, dram);
            let wall = rep.total_s * *time_scale;
            if wall > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wall.min(0.050)));
            }
            let now = started.elapsed().as_secs_f64();
            for (req, reply) in batch.requests.iter().zip(replies) {
                let latency = now - req.arrival_s;
                let queue = sealed - req.arrival_s;
                metrics.record_response(latency, queue, now);
                let _ = reply.send(Response {
                    id: req.id,
                    output: None,
                    latency_s: latency,
                    queue_s: queue,
                    variant: batch.variant.clone(),
                });
            }
        }
    }
}

/// Build the worker's engine on first use.
fn ensure_engine<'a>(
    slot: &'a mut Option<Engine>,
    dir: &std::path::Path,
) -> Result<&'a Engine> {
    if slot.is_none() {
        *slot = Some(Engine::load_dir(dir)?);
    }
    Ok(slot.as_ref().unwrap())
}

/// Assemble the padded Q batch, execute the artifact, slice per request.
fn run_pjrt(
    engine: &Engine,
    contexts: &BTreeMap<String, (Mat, Mat)>,
    batch: &Batch,
) -> Result<Vec<Mat>> {
    let entry = engine
        .get(&batch.variant)
        .ok_or_else(|| anyhow::anyhow!("no artifact for variant {}", batch.variant))?;
    let (t_max, d) = (entry.entry.inputs[0][0], entry.entry.inputs[0][1]);
    let (k, v) = contexts
        .get(&batch.variant)
        .ok_or_else(|| anyhow::anyhow!("no KV context for variant {}", batch.variant))?;
    let mut q = Mat::zeros(t_max, d);
    let mut row = 0;
    for req in &batch.requests {
        if let Some(rq) = &req.q {
            for i in 0..rq.rows.min(t_max - row) {
                q.row_mut(row + i).copy_from_slice(rq.row(i));
            }
        }
        row += req.t;
    }
    let outputs = engine.run(&batch.variant, &[q, k.clone(), v.clone()])?;
    let o = &outputs[0];
    // Slice each request's rows back out.
    let mut per_req = Vec::with_capacity(batch.requests.len());
    let mut at = 0;
    for req in &batch.requests {
        let rows = req.t.min(o.rows - at);
        per_req.push(Mat::from_fn(rows, o.cols, |i, j| o.at(at + i, j)));
        at += req.t;
    }
    Ok(per_req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Variant;

    fn sim_server(target_t: usize) -> Server {
        let router = Router::new(vec![Variant {
            name: "attn".into(),
            model: "tiny".into(),
            max_t: 128,
            s: 2048,
        }]);
        let backend = Backend::Sim {
            feats: FeatureSet::star(),
            accel: AccelConfig::default(),
            dram: DramChannel::accel_256(),
            d: 64,
            h: 128,
            keep: 0.2,
            time_scale: 0.0,
        };
        let cfg = ServerConfig {
            batcher: BatcherConfig { target_t, max_wait_s: 0.005 },
            workers: 2,
        };
        Server::start(router, backend, cfg)
    }

    #[test]
    fn serves_and_batches() {
        let server = sim_server(32);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            rxs.push(server.submit(Request::new(i, "tiny", 8, 256, 0.0)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
            assert_eq!(resp.variant, "attn");
            assert!(resp.latency_s >= 0.0);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 8);
        assert!(snap.batches >= 2, "8×8 rows at target 32 → ≥2 batches, got {}", snap.batches);
        assert!(snap.mean_batch_rows <= 32.0 + 1e-9);
    }

    #[test]
    fn rejects_unroutable() {
        let server = sim_server(32);
        let rx = server.submit(Request::new(99, "nope", 1, 16, 0.0)).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert!(resp.variant.starts_with("rejected"));
        let snap = server.shutdown();
        assert_eq!(snap.rejected, 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let server = sim_server(1024); // never fills
        let rx = server.submit(Request::new(1, "tiny", 4, 128, 0.0)).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!(resp.variant, "attn");
        server.shutdown();
    }
}
