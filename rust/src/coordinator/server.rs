//! The thread-based serving loop (std threads + mpsc; the environment
//! has no tokio — DESIGN.md §2).
//!
//! Architecture: callers `submit()` requests through a channel to the
//! dispatcher thread, which routes (shape buckets), batches (dynamic
//! batching per variant), and hands sealed batches to a worker pool.
//! Workers execute on the configured backend — the native
//! [`crate::pipeline::SparseAttentionPipeline`] for real sparse-attention
//! numerics, the PJRT engine (behind the `pjrt` feature), or the
//! cycle-level simulator for timing studies — and reply per-request.
//! Python never runs anywhere in this path.
//!
//! # Run the native server
//!
//! ```
//! use star::coordinator::{Backend, Request, Router, Server, ServerConfig, Variant};
//! use star::pipeline::PipelineConfig;
//! use star::tensor::Mat;
//! use star::util::Rng;
//! use std::collections::BTreeMap;
//!
//! let mut rng = Rng::new(1);
//! let (s, d) = (128, 16);
//! let mut contexts = BTreeMap::new();
//! contexts.insert(
//!     "sparse_attention".to_string(),
//!     (Mat::randn(s, d, 1.0, &mut rng), Mat::randn(s, d, 1.0, &mut rng)),
//! );
//! let router = Router::new(vec![Variant {
//!     name: "sparse_attention".into(), model: "gpt2".into(), max_t: 128, s,
//! }]);
//! let backend = Backend::native(PipelineConfig::star().with_threads(1), contexts);
//! let server = Server::start(router, backend, ServerConfig::default());
//! let mut req = Request::new(0, "gpt2", 8, s, 0.0);
//! req.q = Some(Mat::randn(8, d, 1.0, &mut rng));
//! let out = server.submit(req).unwrap().recv().unwrap();
//! assert!(out.output.is_some());
//! println!("{}", server.shutdown().render()); // includes per-stage times
//! ```
//!
//! Requests wider than the batch target do not reject: they execute on
//! the sharded pipeline (bit-identical outputs — see
//! [`crate::pipeline::ShardedPipeline`]), with per-shard stage timings
//! and ring counters in the final [`MetricsSnapshot`]. That covers
//! *both* request kinds: over-target stateless prefill runs the
//! ring-circulated prefill engine, over-target decode runs the
//! partitioned-KV-cache decode engine
//! ([`crate::pipeline::ShardedPipeline::decode_step_pooled`]) against
//! the shared session store.

use super::batcher::{Batch, Batcher, BatcherConfig};
use super::metrics::{Metrics, MetricsSnapshot, RequestClass};
use super::router::{Admission, Request, Response, Router};
use crate::config::AccelConfig;
use crate::kvcache::SessionStore;
use crate::obs::trace::Span;
use crate::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::sim::dram::DramChannel;
use crate::sim::pipeline::{simulate, FeatureSet, WorkloadShape};
use crate::tensor::Mat;
use crate::Result;
use std::collections::BTreeMap;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// How batches actually execute. This is pure (Send) configuration: the
/// PJRT client is **not** thread-safe, so each worker thread constructs
/// its own [`Engine`] lazily from `artifact_dir` on first use.
pub enum Backend {
    /// Serve real sparse attention natively: every batch runs the tiled
    /// predict → top-k → KV-gen → SU-FA pipeline in-process. `contexts`
    /// maps variant name → (K, V) context matrices for stateless prefill
    /// requests; decode requests (`Request::decode`) run against the
    /// shared `sessions` store instead and report cache-hit/eviction
    /// metrics. Per-stage busy times and SU-FA stalls land in the server
    /// metrics. Note each server worker runs its own pipeline; set
    /// `pipeline.threads = 1` to avoid oversubscription when
    /// `ServerConfig::workers` is large.
    Native {
        pipeline: PipelineConfig,
        contexts: BTreeMap<String, (Mat, Mat)>,
        /// Shared paged KV-cache session store (`None` = prefill-only
        /// server: decode requests are answered with an error).
        sessions: Option<Arc<Mutex<SessionStore>>>,
        /// Worker count for over-target requests (prefill *and* decode)
        /// on the sharded pipeline
        /// ([`crate::pipeline::ShardedPipeline`]); 0 = auto (the server
        /// divides the available cores among its pool workers). Never
        /// changes outputs — sharded execution is bit-identical at
        /// every worker and shard count.
        shards: usize,
    },
    /// Execute the AOT-compiled PJRT artifact named by each variant.
    /// `contexts` maps variant name → (K, V) context matrices.
    #[cfg(feature = "pjrt")]
    Pjrt { artifact_dir: PathBuf, contexts: BTreeMap<String, (Mat, Mat)> },
    /// Model the accelerator: latency from the cycle-level simulator,
    /// stretched by `time_scale` wall-clock seconds per simulated second.
    Sim { feats: FeatureSet, accel: AccelConfig, dram: DramChannel, d: usize, h: usize, keep: f64, time_scale: f64 },
}

impl Backend {
    /// Prefill-only native backend (no session store).
    pub fn native(pipeline: PipelineConfig, contexts: BTreeMap<String, (Mat, Mat)>) -> Backend {
        Backend::Native { pipeline, contexts, sessions: None, shards: 0 }
    }

    /// Session-aware native backend: decode requests share `store`'s
    /// paged KV-cache across all workers.
    pub fn native_with_sessions(
        pipeline: PipelineConfig,
        contexts: BTreeMap<String, (Mat, Mat)>,
        store: SessionStore,
    ) -> Backend {
        Backend::Native {
            pipeline,
            contexts,
            sessions: Some(Arc::new(Mutex::new(store))),
            shards: 0,
        }
    }

    /// Builder-style worker-count override for the sequence-sharded
    /// over-target prefill path (no-op on non-native backends).
    pub fn with_shards(mut self, n: usize) -> Backend {
        if let Backend::Native { shards, .. } = &mut self {
            *shards = n;
        }
        self
    }
}

/// Server construction knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Dynamic-batching policy (target rows, latency budget).
    pub batcher: BatcherConfig,
    /// Worker threads executing sealed batches.
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { batcher: BatcherConfig::default(), workers: 2 }
    }
}

enum Msg {
    Submit(Request, Sender<Response>),
    Tick,
    Shutdown,
}

/// Upper bound on spans the server retains (oldest dropped first) —
/// the "last N requests" capture window.
const TRACE_SINK_CAP: usize = 1 << 16;

/// The running server.
pub struct Server {
    tx: Sender<Msg>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    /// Live metrics sink (snapshot any time; final copy from
    /// [`Server::shutdown`]).
    pub metrics: Arc<Metrics>,
    /// Spans drained from the worker pools after each batch while
    /// tracing is enabled ([`crate::obs::trace::set_enabled`]) —
    /// bounded to the most recent [`TRACE_SINK_CAP`].
    trace_spans: Arc<Mutex<Vec<Span>>>,
    started: Instant,
    stopped: Arc<AtomicBool>,
}

impl Server {
    /// Spawn the dispatcher and worker pool.
    pub fn start(router: Router, backend: Backend, cfg: ServerConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        let trace_spans: Arc<Mutex<Vec<Span>>> = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel::<Msg>();
        let started = Instant::now();
        let stopped = Arc::new(AtomicBool::new(false));

        // Over-target prefills run the sharded engine inside *each* pool
        // worker: an auto (0) shard count would spawn one thread per core
        // per worker — `workers × cores` threads under a burst. Divide
        // the machine among the pool instead (outputs are worker-count
        // invariant, so this only caps contention).
        let backend = match backend {
            Backend::Native { pipeline, contexts, sessions, shards: 0 } => {
                let cores =
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                Backend::Native {
                    pipeline,
                    contexts,
                    sessions,
                    shards: (cores / cfg.workers.max(1)).max(1),
                }
            }
            b => b,
        };

        // Worker pool input.
        let (work_tx, work_rx) = channel::<(Batch, Vec<Sender<Response>>)>();
        let work_rx = Arc::new(std::sync::Mutex::new(work_rx));
        let backend = Arc::new(backend);
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = work_rx.clone();
            let be = backend.clone();
            let m = metrics.clone();
            let sink = trace_spans.clone();
            workers.push(std::thread::spawn(move || {
                // Per-worker backend state (the PJRT client is not Send;
                // it must be built lazily on this thread).
                let mut state = WorkerState::default();
                let mut drained: Vec<Span> = Vec::new();
                loop {
                    let job = rx.lock().unwrap().recv();
                    match job {
                        Ok((batch, replies)) => {
                            execute_batch(&be, &mut state, batch, replies, &m, started);
                            // Server-side capture: move the batch's spans
                            // out of the pool rings into the shared sink
                            // (bounded — oldest spans dropped first).
                            if crate::obs::trace::enabled() {
                                state.workspaces.drain_spans(&mut drained);
                                if !drained.is_empty() {
                                    let mut sink = sink.lock().unwrap();
                                    sink.append(&mut drained);
                                    if sink.len() > TRACE_SINK_CAP {
                                        let excess = sink.len() - TRACE_SINK_CAP;
                                        sink.drain(..excess);
                                    }
                                }
                            }
                        }
                        Err(_) => break,
                    }
                }
            }));
        }

        let m = metrics.clone();
        let stop_flag = stopped.clone();
        let dispatcher = std::thread::spawn(move || {
            let mut batchers: BTreeMap<String, Batcher> = BTreeMap::new();
            let mut waiting: BTreeMap<u64, Sender<Response>> = BTreeMap::new();
            let now = || started.elapsed().as_secs_f64();
            loop {
                // Block briefly so timeout-flushes still happen at low load.
                let msg = rx.recv_timeout(std::time::Duration::from_millis(1)).unwrap_or(Msg::Tick);
                match msg {
                    // Admission = routing + the batch-target check. An
                    // over-target request comes back as
                    // Admission::Sharded: it bypasses the batcher (it
                    // alone exceeds a whole batch) and dispatches
                    // immediately as a single-request batch for the
                    // sharded pipeline — prefill on the ring engine,
                    // decode on the partitioned-cache engine.
                    Msg::Submit(req, reply) => match router.admit(&req, cfg.batcher.target_t) {
                        Ok(Admission::Sharded(variant)) => {
                            waiting.insert(req.id, reply);
                            let batch = Batch {
                                variant: variant.name.clone(),
                                requests: vec![req],
                                sealed_s: now(),
                                sharded: true,
                            };
                            dispatch(batch, &mut waiting, &work_tx, &m);
                        }
                        Ok(Admission::Batched(variant)) => {
                            waiting.insert(req.id, reply);
                            batchers
                                .entry(variant.name.clone())
                                .or_insert_with(|| Batcher::new(&variant.name, cfg.batcher))
                                .push(req);
                        }
                        Err(e) => {
                            m.record_rejection();
                            let _ = reply.send(Response {
                                id: req.id,
                                output: None,
                                latency_s: 0.0,
                                queue_s: 0.0,
                                variant: format!("rejected: {e}"),
                            });
                        }
                    },
                    Msg::Tick => {}
                    Msg::Shutdown => {
                        for b in batchers.values_mut() {
                            if let Some(batch) = b.flush(now()) {
                                dispatch(batch, &mut waiting, &work_tx, &m);
                            }
                        }
                        stop_flag.store(true, Ordering::SeqCst);
                        break;
                    }
                }
                let t = now();
                for b in batchers.values_mut() {
                    while let Some(batch) = b.poll(t) {
                        dispatch(batch, &mut waiting, &work_tx, &m);
                    }
                }
            }
            drop(work_tx); // close the pool
            for w in workers {
                let _ = w.join();
            }
        });

        Server { tx, dispatcher: Some(dispatcher), metrics, trace_spans, started, stopped }
    }

    /// Take the spans captured from the worker pools so far (the most
    /// recent requests, bounded; empty unless tracing is enabled via
    /// [`crate::obs::trace::set_enabled`]). Export with
    /// [`crate::obs::chrome_trace`].
    pub fn take_trace(&self) -> Vec<Span> {
        std::mem::take(&mut *self.trace_spans.lock().unwrap())
    }

    /// Monotonic server clock, seconds.
    pub fn now(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Submit a request; the response arrives on the returned channel.
    pub fn submit(&self, mut req: Request) -> Result<Receiver<Response>> {
        let (tx, rx) = channel();
        req.arrival_s = self.now();
        self.tx
            .send(Msg::Submit(req, tx))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rx)
    }

    /// Flush, stop all threads, and return the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
        self.metrics.snapshot()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if !self.stopped.load(Ordering::SeqCst) {
            let _ = self.tx.send(Msg::Shutdown);
            if let Some(h) = self.dispatcher.take() {
                let _ = h.join();
            }
        }
    }
}

fn dispatch(
    batch: Batch,
    waiting: &mut BTreeMap<u64, Sender<Response>>,
    work_tx: &Sender<(Batch, Vec<Sender<Response>>)>,
    metrics: &Metrics,
) {
    metrics.record_batch(batch.rows());
    let replies: Vec<Sender<Response>> = batch
        .requests
        .iter()
        .map(|r| waiting.remove(&r.id).expect("reply channel registered at submit"))
        .collect();
    let _ = work_tx.send((batch, replies));
}

/// Per-worker backend state.
#[derive(Default)]
struct WorkerState {
    /// Per-worker tile-workspace pool, keyed by shape class: the native
    /// pipelines draw warm [`crate::pipeline::TileWorkspace`]s from
    /// here, so steady-state serving performs zero hot-path allocations
    /// (see `crate::pipeline::engine`). Per worker — never contended.
    workspaces: WorkspacePool,
    /// Per-worker PJRT engine, built on first use.
    #[cfg(feature = "pjrt")]
    engine: Option<Engine>,
}

/// Which per-class latency histogram a response belongs to: decode
/// requests report TPOT; prefill reports TTFT, split by whether it ran
/// on the sequence-sharded path.
fn classify(req: &Request, batch: &Batch) -> RequestClass {
    if req.is_decode() {
        RequestClass::Decode
    } else if batch.sharded {
        RequestClass::Sharded
    } else {
        RequestClass::Prefill
    }
}

fn execute_batch(
    backend: &Backend,
    state: &mut WorkerState,
    batch: Batch,
    replies: Vec<Sender<Response>>,
    metrics: &Metrics,
    started: Instant,
) {
    let sealed = batch.sealed_s;
    match backend {
        Backend::Native { pipeline, contexts, sessions, shards } => {
            let pool = &state.workspaces;
            let out = if batch.sharded {
                run_sharded_native(pipeline, *shards, contexts, sessions.as_ref(), &batch, metrics, pool)
            } else {
                run_native(pipeline, contexts, sessions.as_ref(), &batch, metrics, pool)
            };
            let now = started.elapsed().as_secs_f64();
            // Surface misconfiguration instead of silently serving empty
            // outputs: count a batch-level failure and carry the message
            // to every client of the batch (mirroring the "rejected: …"
            // path). Decode-request failures are per-request (they carry
            // per-session side effects) and arrive in `errors`.
            let error = out
                .as_ref()
                .err()
                .map(|e| {
                    metrics.record_failure();
                    eprintln!("native backend error on variant {}: {e}", batch.variant);
                    format!("error: {e}")
                });
            let (mut rows, errors) = out.unwrap_or_default();
            for (i, (req, reply)) in batch.requests.iter().zip(replies).enumerate() {
                let (output, variant) = match &error {
                    None => match errors[i].clone() {
                        None => (rows[i].take(), batch.variant.clone()),
                        Some(msg) => (None, msg),
                    },
                    Some(msg) => (None, msg.clone()),
                };
                let latency = now - req.arrival_s;
                let queue = sealed - req.arrival_s;
                metrics.record_response(latency, queue, now, classify(req, &batch), req.t as u64);
                let _ = reply.send(Response {
                    id: req.id,
                    output,
                    latency_s: latency,
                    queue_s: queue,
                    variant,
                });
            }
        }
        #[cfg(feature = "pjrt")]
        Backend::Pjrt { artifact_dir, contexts } => {
            // AOT artifacts have static shapes: a sharded over-target
            // batch cannot execute here — refuse it explicitly rather
            // than letting run_pjrt silently truncate the query rows.
            // Decode gets its own message: the partitioned-cache decode
            // path is native-only by design.
            let out = if batch.sharded {
                let kind = if batch.requests.iter().any(|r| r.is_decode()) {
                    "sharded decode"
                } else {
                    "sharded prefill"
                };
                Err(anyhow::anyhow!(
                    "{kind} is not supported on the PJRT backend \
                     (static-shape artifacts); raise target_t or serve with \
                     Backend::Native"
                ))
            } else {
                ensure_engine(&mut state.engine, artifact_dir)
                    .and_then(|engine| run_pjrt(engine, contexts, &batch))
            };
            let now = started.elapsed().as_secs_f64();
            // Same error surfacing as the Native arm: count the failed
            // batch and carry the message to every client.
            let error = out.as_ref().err().map(|e| {
                metrics.record_failure();
                eprintln!("pjrt backend error on variant {}: {e}", batch.variant);
                format!("error: {e}")
            });
            for (i, (req, reply)) in batch.requests.iter().zip(replies).enumerate() {
                let (output, variant) = match &error {
                    None => (out.as_ref().ok().map(|rows| rows[i].clone()), batch.variant.clone()),
                    Some(msg) => (None, msg.clone()),
                };
                let latency = now - req.arrival_s;
                let queue = sealed - req.arrival_s;
                metrics.record_response(latency, queue, now, classify(req, &batch), req.t as u64);
                let _ = reply.send(Response {
                    id: req.id,
                    output,
                    latency_s: latency,
                    queue_s: queue,
                    variant,
                });
            }
        }
        Backend::Sim { feats, accel, dram, d, h, keep, time_scale } => {
            let rows = batch.rows().max(1);
            let s = batch.requests.iter().map(|r| r.s).max().unwrap_or(1);
            let shape = WorkloadShape::new(rows, s, *d, *h, *keep);
            let rep = simulate(&shape, feats, accel, dram);
            let wall = rep.total_s * *time_scale;
            if wall > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(wall.min(0.050)));
            }
            let now = started.elapsed().as_secs_f64();
            for (req, reply) in batch.requests.iter().zip(replies) {
                let latency = now - req.arrival_s;
                let queue = sealed - req.arrival_s;
                metrics.record_response(latency, queue, now, classify(req, &batch), req.t as u64);
                let _ = reply.send(Response {
                    id: req.id,
                    output: None,
                    latency_s: latency,
                    queue_s: queue,
                    variant: batch.variant.clone(),
                });
            }
        }
    }
}

/// Execute one LTPP batch through the native sparse-attention pipeline.
/// The batch can mix the two request kinds continuous batching
/// interleaves: **decode steps** (a session id + new-token Q/K/V rows)
/// run one at a time against the shared paged KV-cache; **stateless
/// prefill** requests are concatenated and run once against the
/// variant's KV context, outputs sliced back per request. Requests
/// without a Q payload ride the batch for timing but get no output.
fn run_native(
    cfg: &PipelineConfig,
    contexts: &BTreeMap<String, (Mat, Mat)>,
    sessions: Option<&Arc<Mutex<SessionStore>>>,
    batch: &Batch,
    metrics: &Metrics,
    workspaces: &WorkspacePool,
) -> Result<(Vec<Option<Mat>>, Vec<Option<String>>)> {
    if let Err(e) = cfg.validate() {
        anyhow::bail!("invalid pipeline config: {e}");
    }
    let mut outs: Vec<Option<Mat>> = vec![None; batch.requests.len()];
    let mut errors: Vec<Option<String>> = vec![None; batch.requests.len()];

    // ---- Validate the stateless-prefill side BEFORE any decode step
    // runs: decode steps mutate their sessions, so a batch-level error
    // raised after them would discard outputs of appends that already
    // happened (and a retry would be rejected by the ordering guard).
    let with_q: Vec<(usize, &Mat)> = batch
        .requests
        .iter()
        .enumerate()
        .filter_map(|(i, r)| if r.is_decode() { None } else { r.q.as_ref().map(|q| (i, q)) })
        .collect();
    let prefill_ctx = if with_q.is_empty() {
        None
    } else {
        let (k, v) = contexts
            .get(&batch.variant)
            .ok_or_else(|| anyhow::anyhow!("no KV context for variant {}", batch.variant))?;
        // Validate as errors, not panics: an assert here would kill the
        // worker thread for the server's remaining lifetime and drop the
        // replies.
        anyhow::ensure!(
            k.rows == v.rows && k.cols == v.cols,
            "variant {}: malformed KV context (K {}x{}, V {}x{})",
            batch.variant,
            k.rows,
            k.cols,
            v.rows,
            v.cols
        );
        for (i, q) in &with_q {
            anyhow::ensure!(
                q.cols == k.cols,
                "request {} head dim {} != context head dim {}",
                batch.requests[*i].id,
                q.cols,
                k.cols
            );
        }
        Some((k, v))
    };

    // ---- Decode steps against the shared session store. A decode step
    // mutates its session, so a failing request must NOT fail the whole
    // batch (earlier decode requests already appended their tokens — a
    // blanket retry would duplicate context). Failures are per-request.
    for (i, req) in batch.requests.iter().enumerate() {
        let Some(sid) = req.session else { continue };
        let step = || -> Result<crate::pipeline::DecodeReport> {
            let store = sessions.ok_or_else(|| {
                anyhow::anyhow!("decode request {} but the server has no session store", req.id)
            })?;
            let (q, (kn, vn)) = match (&req.q, &req.kv) {
                (Some(q), Some(kv)) => (q, kv),
                _ => anyhow::bail!("decode request {} lacks a Q or KV payload", req.id),
            };
            let pipeline = SparseAttentionPipeline::new(*cfg);
            let mut store = store.lock().unwrap();
            // Ordering guard: `Request::decode` carries the session length
            // after the append. Concurrent same-session steps that would
            // land out of order (silently permuting the context) are
            // rejected here instead.
            let expected = store.len(sid) + q.rows;
            anyhow::ensure!(
                req.s == expected,
                "decode step out of order for session {sid}: request claims context {} but \
                 the session would be {expected} after this append",
                req.s
            );
            pipeline.decode_step_pooled(&mut store, sid, q, kn, vn, workspaces)
        };
        match step() {
            Ok(report) => {
                metrics.record_stage_times(&report.timing, report.stalls);
                metrics.record_decode(&report);
                metrics.record_traffic(&report.traffic, &report.sched);
                metrics.record_workspace_bytes(report.workspace_bytes);
                outs[i] = Some(report.out);
            }
            Err(e) => {
                metrics.record_failure();
                eprintln!("decode error on request {}: {e}", req.id);
                errors[i] = Some(format!("error: {e}"));
            }
        }
    }

    // ---- Stateless prefill requests, concatenated as one LTPP pass
    // (pre-validated above; the pipeline run itself cannot fail). ----
    let Some((k, v)) = prefill_ctx else {
        return Ok((outs, errors));
    };
    let d = k.cols;
    let total: usize = with_q.iter().map(|(_, q)| q.rows).sum();
    let mut qcat = Mat::zeros(total, d);
    let mut at = 0;
    for (_, q) in &with_q {
        for i in 0..q.rows {
            qcat.row_mut(at + i).copy_from_slice(q.row(i));
        }
        at += q.rows;
    }
    let inputs = PipelineInputs::qkv(&qcat, k, v);
    let report = SparseAttentionPipeline::new(*cfg).run_pooled(&inputs, workspaces);
    metrics.record_stage_times(&report.timing, report.stalls);
    metrics.record_traffic(&report.traffic, &report.sched);
    metrics.record_workspace_bytes(report.workspace_bytes);
    let mut at = 0;
    for (ri, q) in with_q {
        outs[ri] = Some(Mat::from_fn(q.rows, d, |i, j| report.out.at(at + i, j)));
        at += q.rows;
    }
    Ok((outs, errors))
}

/// Execute an over-target batch on the sharded pipeline
/// ([`crate::pipeline::ShardedPipeline`]). Such batches carry exactly
/// the requests `Router::admit` marked [`Admission::Sharded`] (in
/// practice one — each alone exceeds the batch target). Stateless
/// prefill runs the ring-circulated prefill engine against the
/// variant's KV context; **decode steps** run the partitioned-cache
/// decode engine against the shared session store, with the same
/// per-request failure contract as the batched decode path (a decode
/// step mutates its session, so one failing request must not fail the
/// batch). Outputs are bit-identical to what the single-core pipeline
/// would have produced at every shard count, so routing over-target
/// traffic here never changes served numerics. Per-shard stage timings
/// and ring/scatter counters land in the metrics.
fn run_sharded_native(
    cfg: &PipelineConfig,
    shards: usize,
    contexts: &BTreeMap<String, (Mat, Mat)>,
    sessions: Option<&Arc<Mutex<SessionStore>>>,
    batch: &Batch,
    metrics: &Metrics,
    workspaces: &WorkspacePool,
) -> Result<(Vec<Option<Mat>>, Vec<Option<String>>)> {
    if let Err(e) = cfg.validate() {
        anyhow::bail!("invalid pipeline config: {e}");
    }
    let mut outs: Vec<Option<Mat>> = vec![None; batch.requests.len()];
    let mut errors: Vec<Option<String>> = vec![None; batch.requests.len()];
    let pipeline = ShardedPipeline::new(*cfg, shards);

    // ---- Sharded decode steps against the shared session store. ----
    for (i, req) in batch.requests.iter().enumerate() {
        let Some(sid) = req.session else { continue };
        let step = || -> Result<crate::pipeline::ShardedDecodeReport> {
            let store = sessions.ok_or_else(|| {
                anyhow::anyhow!("decode request {} but the server has no session store", req.id)
            })?;
            let (q, (kn, vn)) = match (&req.q, &req.kv) {
                (Some(q), Some(kv)) => (q, kv),
                _ => anyhow::bail!("decode request {} lacks a Q or KV payload", req.id),
            };
            let mut store = store.lock().unwrap();
            // Same ordering guard as the batched decode path: the claimed
            // post-append context length must match the session.
            let expected = store.len(sid) + q.rows;
            anyhow::ensure!(
                req.s == expected,
                "decode step out of order for session {sid}: request claims context {} but \
                 the session would be {expected} after this append",
                req.s
            );
            pipeline.decode_step_pooled(&mut store, sid, q, kn, vn, workspaces)
        };
        match step() {
            Ok(report) => {
                metrics.record_stage_times(&report.timing, report.stalls);
                metrics.record_sharded_decode(&report);
                metrics.record_traffic(&report.traffic, &report.sched);
                metrics.record_workspace_bytes(report.workspace_bytes);
                outs[i] = Some(report.out);
            }
            Err(e) => {
                metrics.record_failure();
                eprintln!("sharded decode error on request {}: {e}", req.id);
                errors[i] = Some(format!("error: {e}"));
            }
        }
    }

    // ---- Over-target stateless prefill against the variant context
    // (fetched lazily: a decode-only sharded batch needs no context). ----
    if batch.requests.iter().any(|r| !r.is_decode() && r.q.is_some()) {
        let (k, v) = contexts
            .get(&batch.variant)
            .ok_or_else(|| anyhow::anyhow!("no KV context for variant {}", batch.variant))?;
        anyhow::ensure!(
            k.rows == v.rows && k.cols == v.cols,
            "variant {}: malformed KV context (K {}x{}, V {}x{})",
            batch.variant,
            k.rows,
            k.cols,
            v.rows,
            v.cols
        );
        for (i, req) in batch.requests.iter().enumerate() {
            if req.is_decode() {
                continue;
            }
            let Some(q) = &req.q else { continue };
            anyhow::ensure!(
                q.cols == k.cols,
                "request {} head dim {} != context head dim {}",
                req.id,
                q.cols,
                k.cols
            );
            let report = pipeline.run_pooled(&PipelineInputs::qkv(q, k, v), workspaces);
            metrics.record_stage_times(&report.timing, report.stalls);
            metrics.record_sharded(&report);
            metrics.record_traffic(&report.traffic, &report.sched);
            metrics.record_workspace_bytes(report.workspace_bytes);
            outs[i] = Some(report.out);
        }
    }
    Ok((outs, errors))
}

/// Build the worker's engine on first use.
#[cfg(feature = "pjrt")]
fn ensure_engine<'a>(
    slot: &'a mut Option<Engine>,
    dir: &std::path::Path,
) -> Result<&'a Engine> {
    if slot.is_none() {
        *slot = Some(Engine::load_dir(dir)?);
    }
    Ok(slot.as_ref().unwrap())
}

/// Assemble the padded Q batch, execute the artifact, slice per request.
#[cfg(feature = "pjrt")]
fn run_pjrt(
    engine: &Engine,
    contexts: &BTreeMap<String, (Mat, Mat)>,
    batch: &Batch,
) -> Result<Vec<Mat>> {
    let entry = engine
        .get(&batch.variant)
        .ok_or_else(|| anyhow::anyhow!("no artifact for variant {}", batch.variant))?;
    let (t_max, d) = (entry.entry.inputs[0][0], entry.entry.inputs[0][1]);
    let (k, v) = contexts
        .get(&batch.variant)
        .ok_or_else(|| anyhow::anyhow!("no KV context for variant {}", batch.variant))?;
    let mut q = Mat::zeros(t_max, d);
    let mut row = 0;
    for req in &batch.requests {
        if let Some(rq) = &req.q {
            for i in 0..rq.rows.min(t_max - row) {
                q.row_mut(row + i).copy_from_slice(rq.row(i));
            }
        }
        row += req.t;
    }
    let outputs = engine.run(&batch.variant, &[q, k.clone(), v.clone()])?;
    let o = &outputs[0];
    // Slice each request's rows back out.
    let mut per_req = Vec::with_capacity(batch.requests.len());
    let mut at = 0;
    for req in &batch.requests {
        let rows = req.t.min(o.rows - at);
        per_req.push(Mat::from_fn(rows, o.cols, |i, j| o.at(at + i, j)));
        at += req.t;
    }
    Ok(per_req)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Variant;

    fn sim_server(target_t: usize) -> Server {
        let router = Router::new(vec![Variant {
            name: "attn".into(),
            model: "tiny".into(),
            max_t: 128,
            s: 2048,
        }]);
        let backend = Backend::Sim {
            feats: FeatureSet::star(),
            accel: AccelConfig::default(),
            dram: DramChannel::accel_256(),
            d: 64,
            h: 128,
            keep: 0.2,
            time_scale: 0.0,
        };
        let cfg = ServerConfig {
            batcher: BatcherConfig { target_t, max_wait_s: 0.005 },
            workers: 2,
        };
        Server::start(router, backend, cfg)
    }

    #[test]
    fn serves_and_batches() {
        let server = sim_server(32);
        let mut rxs = Vec::new();
        for i in 0..8u64 {
            rxs.push(server.submit(Request::new(i, "tiny", 8, 256, 0.0)).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
            assert_eq!(resp.variant, "attn");
            assert!(resp.latency_s >= 0.0);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 8);
        assert!(snap.batches >= 2, "8×8 rows at target 32 → ≥2 batches, got {}", snap.batches);
        assert!(snap.batch_rows.mean <= 32.0 + 1e-9);
        assert!(snap.batch_rows.max <= 32.0 + 1e-9, "no batch may exceed the target");
        assert_eq!(snap.ttft_prefill.count, 8, "sim prefills classify as prefill TTFT");
    }

    #[test]
    fn rejects_unroutable() {
        let server = sim_server(32);
        let rx = server.submit(Request::new(99, "nope", 1, 16, 0.0)).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert!(resp.variant.starts_with("rejected"));
        let snap = server.shutdown();
        assert_eq!(snap.rejected, 1);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let server = sim_server(1024); // never fills
        let rx = server.submit(Request::new(1, "tiny", 4, 128, 0.0)).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        assert_eq!(resp.variant, "attn");
        server.shutdown();
    }

    #[test]
    fn native_backend_serves_real_outputs() {
        use crate::util::Rng;
        let (s, d) = (256usize, 32usize);
        let mut rng = Rng::new(9);
        let kctx = crate::tensor::Mat::randn(s, d, 1.0, &mut rng);
        let vctx = crate::tensor::Mat::randn(s, d, 1.0, &mut rng);
        let mut contexts = BTreeMap::new();
        contexts.insert("attn".to_string(), (kctx, vctx));
        let router = Router::new(vec![Variant {
            name: "attn".into(),
            model: "tiny".into(),
            max_t: 64,
            s,
        }]);
        let backend =
            Backend::native(crate::pipeline::PipelineConfig::star().with_threads(1), contexts);
        let server = Server::start(
            router,
            backend,
            ServerConfig { batcher: BatcherConfig { target_t: 16, max_wait_s: 1e-3 }, workers: 2 },
        );
        let mut rxs = Vec::new();
        for id in 0..6u64 {
            let mut req = Request::new(id, "tiny", 8, s, 0.0);
            req.q = Some(crate::tensor::Mat::randn(8, d, 1.0, &mut rng));
            rxs.push(server.submit(req).unwrap());
        }
        for rx in rxs {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
            let out = resp.output.expect("native backend returns real outputs");
            assert_eq!((out.rows, out.cols), (8, d));
            assert!(out.data.iter().all(|x| x.is_finite()));
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 6);
        assert!(
            snap.stage_formal_s > 0.0,
            "native serving must report per-stage times"
        );
    }

    #[test]
    fn decode_serving_reports_residency_and_prefix_sharing() {
        use crate::kvcache::{SessionConfig, SessionStore};
        use crate::util::Rng;
        let d = 16usize;
        let mut rng = Rng::new(11);
        let router = Router::new(vec![Variant {
            name: "attn".into(),
            model: "tiny".into(),
            max_t: 64,
            s: 2048,
        }]);
        // Tile 8 → 8-token pages, so the 8-token prompt is exactly one
        // page (for_pipeline draws the page size from the query tile).
        let cfg = crate::pipeline::PipelineConfig::star()
            .with_keep(0.25)
            .with_tile(8)
            .with_threads(1);
        let store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
        let backend = Backend::native_with_sessions(cfg, BTreeMap::new(), store);
        let server = Server::start(
            router,
            backend,
            ServerConfig { batcher: BatcherConfig { target_t: 16, max_wait_s: 1e-3 }, workers: 1 },
        );
        // The same 8-token prompt chunk into two sessions: exactly one
        // page each, and the second session attaches the first's page
        // instead of building its own.
        let q = crate::tensor::Mat::randn(8, d, 1.0, &mut rng);
        let k = crate::tensor::Mat::randn(8, d, 1.0, &mut rng);
        let v = crate::tensor::Mat::randn(8, d, 1.0, &mut rng);
        for (id, sid) in [(1u64, 100u64), (2, 200)] {
            let req =
                Request::decode(id, "tiny", sid, q.clone(), k.clone(), v.clone(), 8, 0.0);
            let rx = server.submit(req).unwrap();
            let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
            assert!(resp.output.is_some(), "decode failed: {}", resp.variant);
        }
        let snap = server.shutdown();
        assert_eq!(snap.decode_steps, 2);
        assert_eq!(snap.decode_tokens, 16);
        // Residency gauges come off the decode reports: one physical
        // page backs both sessions' 16 logical tokens.
        assert_eq!(snap.kv_resident_pages, 1, "identical prompts share one page");
        assert_eq!(snap.kv_shared_pages, 1);
        assert_eq!(snap.cache_pages_shared, 1, "second session attached, not rebuilt");
        assert!(snap.kv_resident_bytes > 0);
        // 16 logical tokens × 8d f32 bytes; sharing halves the physical
        // rows behind them (Exact residency also carries the quantized
        // operands, so resident bytes are not simply logical/2).
        assert_eq!(snap.kv_logical_bytes, (16 * 8 * d) as u64);
        let line = snap.render();
        assert!(line.contains("pages_shared=1"), "{line}");
        assert!(line.contains("compression="), "{line}");
        let prom = snap.render_prometheus();
        assert!(prom.contains("star_kvcache_pages_shared_total 1"), "{prom}");
    }

    #[test]
    fn captures_spans_while_tracing_enabled() {
        use crate::obs::trace::Stage;
        use crate::util::Rng;
        let (s, d) = (128usize, 16usize);
        let mut rng = Rng::new(4);
        let mut contexts = BTreeMap::new();
        contexts.insert(
            "attn".to_string(),
            (
                crate::tensor::Mat::randn(s, d, 1.0, &mut rng),
                crate::tensor::Mat::randn(s, d, 1.0, &mut rng),
            ),
        );
        let router = Router::new(vec![Variant {
            name: "attn".into(),
            model: "tiny".into(),
            max_t: 64,
            s,
        }]);
        let backend =
            Backend::native(crate::pipeline::PipelineConfig::star().with_threads(1), contexts);
        let server = Server::start(
            router,
            backend,
            ServerConfig { batcher: BatcherConfig { target_t: 8, max_wait_s: 1e-3 }, workers: 1 },
        );
        crate::obs::set_enabled(true);
        let mut req = Request::new(1, "tiny", 8, s, 0.0);
        req.q = Some(crate::tensor::Mat::randn(8, d, 1.0, &mut rng));
        let rx = server.submit(req).unwrap();
        rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        // The worker drains its pool right after the batch; give it a
        // beat (the reply is sent from inside execute_batch).
        let mut spans = Vec::new();
        for _ in 0..200 {
            spans.extend(server.take_trace());
            if !spans.is_empty() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        // Deliberately left enabled: tests share one process, and other
        // tests assert that enabled tracing records — never turn it off.
        assert!(!spans.is_empty(), "tracing enabled → server captures spans");
        assert!(spans.iter().any(|sp| sp.stage == Stage::Predict));
        assert!(spans.iter().any(|sp| sp.stage == Stage::Formal));
        assert!(spans.iter().all(|sp| sp.end_ns >= sp.start_ns));
        server.shutdown();
    }
}
