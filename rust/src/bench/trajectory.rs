//! Machine-readable benchmark output: every `star bench <name>` run
//! writes a `BENCH_<name>.json` at the repository root (override the
//! directory with `STAR_BENCH_DIR`), so the performance trajectory is
//! tracked across PRs instead of living in scrollback.
//!
//! The schema is deliberately uniform: `{bench, columns, rows}` for
//! tabular figures, with richer objects (throughput, per-stage op
//! counters, latency percentiles) for the serving-style benches like
//! `BENCH_decode.json`.

use crate::arith::OpCounter;
use crate::obs::HistSummary;
use crate::pipeline::StageOps;
use crate::util::json::Json;
use std::path::PathBuf;

/// Where `BENCH_*.json` files land: `STAR_BENCH_DIR` when set; else the
/// repository root when the binary still runs on the machine it was
/// built on (so `cargo test`/`cargo run` write there regardless of
/// cwd); else the current directory (a relocated binary must not fail
/// on the build machine's baked-in path).
pub fn out_dir() -> PathBuf {
    if let Some(dir) = std::env::var_os("STAR_BENCH_DIR") {
        return PathBuf::from(dir);
    }
    let repo = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."));
    if repo.is_dir() {
        return repo;
    }
    PathBuf::from(".")
}

/// Write `BENCH_<name>.json` into [`out_dir`]; returns the path written.
pub fn write(name: &str, payload: Json) -> crate::Result<PathBuf> {
    write_to(&out_dir(), name, payload)
}

/// Write `BENCH_<name>.json` into an explicit directory.
pub fn write_to(dir: &std::path::Path, name: &str, payload: Json) -> crate::Result<PathBuf> {
    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, payload.pretty())?;
    Ok(path)
}

/// A tabular bench payload: column names plus rows of JSON values.
pub fn table(name: &str, columns: &[&str], rows: Vec<Vec<Json>>) -> Json {
    for r in &rows {
        debug_assert_eq!(r.len(), columns.len(), "{name}: row width != column count");
    }
    Json::obj(vec![
        ("bench", Json::str(name)),
        ("columns", Json::Arr(columns.iter().map(|c| Json::str(c)).collect())),
        ("rows", Json::Arr(rows.into_iter().map(Json::Arr).collect())),
    ])
}

/// One operation counter as a JSON object.
pub fn ops_json(c: &OpCounter) -> Json {
    Json::obj(vec![
        ("add", Json::num(c.add as f64)),
        ("mul", Json::num(c.mul as f64)),
        ("cmp", Json::num(c.cmp as f64)),
        ("div", Json::num(c.div as f64)),
        ("exp", Json::num(c.exp as f64)),
        ("shift", Json::num(c.shift as f64)),
        ("lz_encode", Json::num(c.lz_encode as f64)),
        ("dram_bytes", Json::num(c.dram_bytes as f64)),
        ("sram_bytes", Json::num(c.sram_bytes as f64)),
        ("equivalent_adds", Json::num(c.equiv())),
    ])
}

/// A histogram summary (see [`crate::obs::Histogram::summary`]) as a
/// JSON object — the uniform shape every latency distribution in the
/// `BENCH_*.json` files uses.
pub fn hist_json(h: &HistSummary) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("min", Json::num(h.min)),
        ("max", Json::num(h.max)),
        ("mean", Json::num(h.mean)),
        ("p50", Json::num(h.p50)),
        ("p95", Json::num(h.p95)),
        ("p99", Json::num(h.p99)),
    ])
}

/// Per-stage operation counters as a JSON object.
pub fn stage_ops_json(s: &StageOps) -> Json {
    Json::obj(vec![
        ("predict", ops_json(&s.predict)),
        ("topk", ops_json(&s.topk)),
        ("kv_gen", ops_json(&s.kv_gen)),
        ("formal", ops_json(&s.formal)),
        ("total", ops_json(&s.total())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::OpKind;

    #[test]
    fn table_schema_and_write_round_trip() {
        let t = table("demo", &["s", "x"], vec![vec![Json::num(1.0), Json::num(2.5)]]);
        assert_eq!(t.get("bench").unwrap().as_str(), Some("demo"));
        assert_eq!(t.get("columns").unwrap().as_arr().unwrap().len(), 2);
        let dir = std::env::temp_dir().join("star_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = write_to(&dir, "demo", t).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn hist_json_carries_all_percentiles() {
        let mut h = crate::obs::Histogram::new();
        h.record_secs(0.010);
        h.record_secs(0.020);
        let j = hist_json(&h.summary(1e-9));
        assert_eq!(j.get("count").unwrap().as_f64(), Some(2.0));
        assert!((j.get("mean").unwrap().as_f64().unwrap() - 0.015).abs() < 1e-12);
        for key in ["min", "max", "p50", "p95", "p99"] {
            assert!(j.get(key).is_some(), "hist_json missing {key}");
        }
    }

    #[test]
    fn ops_json_carries_all_counters() {
        let mut s = StageOps::default();
        s.predict.tally(OpKind::Shift, 3);
        s.formal.tally(OpKind::Exp, 2);
        let j = stage_ops_json(&s);
        assert_eq!(j.get("predict").unwrap().get("shift").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("total").unwrap().get("exp").unwrap().as_f64(), Some(2.0));
    }
}
