//! Decode-throughput benchmark for the paged KV-cache subsystem: a
//! multi-turn session (prefill + N single-token decode steps) through
//! [`SparseAttentionPipeline::decode_step`], reporting tokens/s,
//! per-step latency percentiles, per-stage op counters and the cache's
//! hit/eviction accounting — plus the **sharded decode scaling sweep**:
//! the same session replayed through
//! [`crate::pipeline::ShardedPipeline::decode_step`] at each worker
//! count in [`SHARD_COUNTS`], checked bit-identical against the
//! single-core steps, with the candidate-scatter payload and the
//! tolerance-mode online-softmax combine deviation
//! ([`crate::attention::SoftmaxPartial`]) measured per count.
//! `star bench decode` writes the result to `BENCH_decode.json` at the
//! repo root (see [`super::trajectory`]).

use super::{f, header, row};
use crate::arith::{OpCounter, ReductionOrder};
use crate::attention::{merge_partials_tree, softmax_partial_into, SoftmaxPartial};
use crate::kvcache::{CacheStats, ResidencyMode, ResidencySnapshot, SessionConfig, SessionStore};
use crate::obs::{HistSummary, Histogram};
use crate::pipeline::{
    PipelineConfig, ShardedPipeline, SparseAttentionPipeline, StageOps, WorkspacePool,
};
use crate::tensor::Mat;
use crate::util::{allocmeter, Rng};

/// Everything `BENCH_decode.json` reports.
#[derive(Clone, Debug)]
pub struct DecodeBenchResult {
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub d: usize,
    pub keep_ratio: f64,
    pub page_size: usize,
    /// Decoded tokens per second of wall time.
    pub tokens_per_s: f64,
    /// Per-step wall-time percentiles, milliseconds.
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Accumulated per-stage ops across all decode steps.
    pub ops: StageOps,
    /// One full causal prefill at the final length — what a stateless
    /// server would redo per turn instead of a decode step.
    pub reprefill_ops: StageOps,
    /// Mean equivalent additions per decoded token.
    pub equiv_adds_per_token: f64,
    /// Equivalent additions of the full re-prefill baseline.
    pub reprefill_equiv_adds: f64,
    /// Cache counters at the end of the run.
    pub cache: CacheStats,
    /// Mean cached KV rows read per decode step.
    pub union_rows_mean: f64,
    /// Per-step latency distribution (log-bucketed; percentile queries
    /// come from [`Histogram::summary`]).
    pub step_wall: Histogram,
    /// Per-stage per-step latency summaries, seconds, indexed by
    /// [`crate::coordinator::metrics::STAGE_NAMES`] order
    /// (predict/topk/kv_gen/formal).
    pub stage_latency: [HistSummary; 4],
    /// Heap allocations metered inside the decode rows' stage cores,
    /// summed over the timed steps. The pool is warmed by the prefill,
    /// so steady state is **zero** — the regression guard for the
    /// allocation-free tile engine (`crate::pipeline::engine`). Real
    /// measurement only when a counting allocator is installed
    /// (`alloc_counter_on`); vacuously zero otherwise.
    pub hot_path_allocs: u64,
    /// Whether a counting allocator was observed (the `star` binary and
    /// the bench drivers install one; plain `cargo test` does not).
    pub alloc_counter_on: bool,
    /// Peak tile-workspace capacity during the timed steps, bytes
    /// (compare against `crate::sim::sram::Sram::STAR_BUDGET_BYTES`).
    pub workspace_bytes: usize,
    /// Sharded-decode scaling sweep, one row per [`SHARD_COUNTS`] entry.
    pub sharded: Vec<ShardedDecodeRow>,
    /// Cache-pressure sweep: the shared-prefix multi-session workload
    /// replayed at each pool capacity in [`PRESSURE_POOL_PAGES`]
    /// (0 = unbounded), page-granular eviction and re-materialization
    /// churning under the tight pools.
    pub pressure: Vec<CachePressureRow>,
    /// Prefix sharing on vs off on the identical workload at the fixed
    /// tight pool — the measured capacity gain of copy-on-write sharing.
    pub sharing: Vec<PrefixSharingRow>,
    /// Exact vs quantized-only residency on one session (unbounded
    /// pool): resident footprint, output deviation, selection parity.
    pub residency: Vec<ResidencyModeRow>,
}

/// Pool capacities (pages) the cache-pressure sweep visits; 0 means
/// unbounded. The workload needs 10 physical pages with sharing (16
/// logical), so 8 and 6 force page-granular eviction churn.
pub const PRESSURE_POOL_PAGES: [usize; 3] = [0, 8, 6];

/// One pool capacity of the cache-pressure sweep: 4 sessions share a
/// 40-token prefix (2.5 pages of 16) and decode 24 distinct tokens each
/// round-robin, so every round touches every session under pressure.
#[derive(Clone, Debug)]
pub struct CachePressureRow {
    /// Pool capacity in pages (0 = unbounded).
    pub capacity_pages: usize,
    /// Decoded tokens per second of summed per-step wall time.
    pub tokens_per_s: f64,
    /// Page references dropped by eviction across the run.
    pub pages_evicted: u64,
    /// Pages rebuilt from host history after eviction.
    pub pages_rematerialized: u64,
    /// Prefix share-attaches across the run.
    pub pages_shared: u64,
    /// Copy-on-write splits on divergence inside shared pages.
    pub cow_splits: u64,
    /// Physical pages resident at the end of the run.
    pub resident_pages: usize,
    /// Resident payload bytes per logical token at the end of the run.
    pub resident_bytes_per_token: f64,
    /// Heap allocations metered inside the decode stage cores (zero
    /// even under eviction churn: re-materialization runs outside the
    /// metered hot path).
    pub hot_path_allocs: u64,
}

/// Prefix sharing on vs off on the pressure workload at a fixed tight
/// pool (8 pages): sharing keeps the common prompt on refcounted pages,
/// so the same pool absorbs the same sessions with less eviction churn.
#[derive(Clone, Debug)]
pub struct PrefixSharingRow {
    /// Whether copy-on-write prefix sharing was enabled.
    pub sharing: bool,
    /// Physical pages resident at the end of the run.
    pub resident_pages: usize,
    /// Prefix share-attaches (0 with sharing off).
    pub pages_shared: u64,
    /// Copy-on-write splits (0 with sharing off).
    pub cow_splits: u64,
    /// Page references dropped by eviction across the run.
    pub pages_evicted: u64,
    /// Pages rebuilt from host history after eviction.
    pub pages_rematerialized: u64,
}

/// One residency mode of the Exact-vs-QuantizedOnly comparison on an
/// identical single-session decode (unbounded pool).
#[derive(Clone, Debug)]
pub struct ResidencyModeRow {
    /// `"exact"` or `"quantized_only"`.
    pub mode: &'static str,
    /// Resident payload bytes per logical token at the end of the run.
    pub resident_bytes_per_token: f64,
    /// Max |output − exact-mode output| over every decode step (0.0 for
    /// the exact row by definition; small and bounded by the per-row
    /// dequant scale for quantized-only).
    pub max_abs_diff_vs_exact: f64,
    /// Whether every step selected exactly the keys the exact-mode run
    /// selected (the quantized operands are bit-identical across modes,
    /// so this must hold).
    pub selection_match: bool,
}

/// Worker counts the sharded-decode scaling sweep visits.
pub const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// One worker count of the sharded-decode scaling sweep: the bit-exact
/// distributed path's throughput/communication/allocation counters,
/// plus the measured deviation of the tolerance-mode online-softmax
/// partial combine ([`crate::attention::SoftmaxPartial`]) against the
/// exact monolithic reduction on the same selection.
#[derive(Clone, Debug)]
pub struct ShardedDecodeRow {
    /// Effective worker count of this row.
    pub shards: usize,
    /// Decoded tokens per second of summed per-step wall time.
    pub tokens_per_s: f64,
    /// Mean per-step wall time, milliseconds.
    pub mean_ms: f64,
    /// Candidate-scatter bytes across the timed steps.
    pub ring_payload_bytes: u64,
    /// Heap allocations metered inside the gather + formal cores across
    /// the timed steps (zero once the pools are warm; vacuous without a
    /// counting allocator, as for [`DecodeBenchResult::hot_path_allocs`]).
    pub hot_path_allocs: u64,
    /// Max |sharded − single-core| over every timed step's output — the
    /// bit-exact contract says **exactly 0.0** (`star bench decode`
    /// fails otherwise; `rust/tests/prop_sharded_decode_parity.rs` is
    /// the exhaustive version).
    pub max_abs_diff: f64,
    /// Whether every timed step also matched the single-core selection.
    pub parity_ok: bool,
    /// Max |tree-combined partials − exact monolithic softmax| over the
    /// last step's selection, the measured rescale error of the
    /// tolerance-mode distributed formal stage (small but nonzero for
    /// `shards > 1`; exactly 0.0 for one partition).
    pub combine_max_dev: f64,
}

/// Run the decode benchmark on the STAR configuration (single host
/// thread so per-step latency is stable).
pub fn decode_throughput() -> DecodeBenchResult {
    let (prefill_tokens, decode_tokens, d) = (256usize, 192usize, 64usize);
    let cfg = PipelineConfig::star().with_keep(0.2).with_tile(16).with_threads(1);
    let pipe = SparseAttentionPipeline::new(cfg);
    let total = prefill_tokens + decode_tokens;

    let mut rng = Rng::new(2024);
    let q = Mat::randn(total, d, 1.0, &mut rng);
    let k = Mat::randn(total, d, 1.0, &mut rng);
    let v = Mat::randn(total, d, 1.0, &mut rng);
    let slice = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));

    // Session open: one prefill chunk. The workspace pool persists
    // across the whole session, exactly as a serving worker holds it —
    // the prefill warms it, so the timed decode steps run on warm
    // buffers and must meter zero hot-path allocations.
    let pool = WorkspacePool::new();
    let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
    // Prefill phase is session warm-up (buffers and cache); only decode
    // steps are timed. A prefill is one big decode chunk into the empty
    // session (`SparseAttentionPipeline::prefill` is exactly this).
    pipe.decode_step_pooled(
        &mut store,
        1,
        &slice(&q, 0, prefill_tokens),
        &slice(&k, 0, prefill_tokens),
        &slice(&v, 0, prefill_tokens),
        &pool,
    )
    .expect("prefill");

    // Decode phase: single-token steps.
    let mut ops = StageOps::default();
    let mut step_wall = Histogram::new();
    let mut stage_hist: [Histogram; 4] = Default::default();
    let mut union_rows = 0usize;
    let mut hot_path_allocs = 0u64;
    let mut workspace_bytes = 0usize;
    let t0 = std::time::Instant::now();
    for pos in prefill_tokens..total {
        let r = pipe
            .decode_step_pooled(
                &mut store,
                1,
                &slice(&q, pos, pos + 1),
                &slice(&k, pos, pos + 1),
                &slice(&v, pos, pos + 1),
                &pool,
            )
            .expect("decode step");
        step_wall.record_secs(r.wall_s);
        stage_hist[0].record_secs(r.timing.predict_s);
        stage_hist[1].record_secs(r.timing.topk_s);
        stage_hist[2].record_secs(r.timing.kv_gen_s);
        stage_hist[3].record_secs(r.timing.formal_s);
        ops.merge(&r.ops);
        union_rows += r.union_rows;
        hot_path_allocs += r.hot_path_allocs;
        workspace_bytes = workspace_bytes.max(r.workspace_bytes);
    }
    let wall = t0.elapsed().as_secs_f64();

    // Baseline: the stateless server re-prefills the whole conversation.
    let mut re_store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
    let re = pipe.prefill(&mut re_store, 1, &q, &k, &v).expect("re-prefill baseline");

    let sharded = sharded_scaling(cfg, d, &q, &k, &v);
    let pressure = cache_pressure_sweep(&cfg);
    let sharing = prefix_sharing_comparison(&cfg);
    let residency = residency_mode_comparison(&cfg);

    let wall_summary = step_wall.summary(1e-9);
    let result = DecodeBenchResult {
        prefill_tokens,
        decode_tokens,
        d,
        keep_ratio: cfg.keep_ratio,
        page_size: store.config().page_size,
        tokens_per_s: decode_tokens as f64 / wall.max(1e-12),
        p50_ms: wall_summary.p50 * 1e3,
        p95_ms: wall_summary.p95 * 1e3,
        p99_ms: wall_summary.p99 * 1e3,
        mean_ms: wall_summary.mean * 1e3,
        equiv_adds_per_token: ops.total().equiv() / decode_tokens as f64,
        reprefill_equiv_adds: re.ops.total().equiv(),
        ops,
        reprefill_ops: re.ops,
        cache: store.stats(),
        union_rows_mean: union_rows as f64 / decode_tokens as f64,
        step_wall,
        stage_latency: std::array::from_fn(|i| stage_hist[i].summary(1e-9)),
        hot_path_allocs,
        alloc_counter_on: allocmeter::installed(),
        workspace_bytes,
        sharded,
        pressure,
        sharing,
        residency,
    };

    header("decode throughput (paged KV-cache, STAR config)");
    row(
        "session",
        &[
            format!("prefill={prefill_tokens}"),
            format!("decode={decode_tokens}"),
            format!("d={d}"),
            format!("page={}", result.page_size),
        ],
    );
    row(
        "throughput",
        &[
            format!("{:.0} tok/s", result.tokens_per_s),
            format!("p50={:.3}ms", result.p50_ms),
            format!("p95={:.3}ms", result.p95_ms),
            format!("mean={:.3}ms", result.mean_ms),
        ],
    );
    row(
        "work/token",
        &[
            f(result.equiv_adds_per_token),
            "eq-adds vs".to_string(),
            f(result.reprefill_equiv_adds),
            "re-prefill".to_string(),
        ],
    );
    let stats = result.cache;
    row(
        "cache",
        &[
            format!("hits={}", stats.page_hits),
            format!("alloc={}", stats.pages_allocated),
            format!("evicted={}", stats.pages_evicted),
            format!("remat={}", stats.pages_rematerialized),
        ],
    );
    row(
        "hot path",
        &[
            format!(
                "allocs={}{}",
                result.hot_path_allocs,
                if result.alloc_counter_on { "" } else { " (no counting allocator)" }
            ),
            format!(
                "workspace={} of {} sim SRAM",
                crate::util::fmt_bytes(result.workspace_bytes as f64),
                crate::util::fmt_bytes(crate::sim::sram::Sram::STAR_BUDGET_BYTES as f64),
            ),
        ],
    );
    header("sharded decode scaling (page-partitioned, bit-exact)");
    for s in &result.sharded {
        row(
            &format!("shards={}", s.shards),
            &[
                format!("{:.0} tok/s", s.tokens_per_s),
                format!("scatter={}B", s.ring_payload_bytes),
                format!("max|Δ|={:.1e}", s.max_abs_diff),
                format!("combine_dev={:.2e}", s.combine_max_dev),
                // The exact spelling the CI smoke greps for.
                format!("hot_path_allocs: {}", s.hot_path_allocs),
            ],
        );
    }
    header(&format!(
        "cache pressure ({PRESSURE_SESSIONS} sessions, shared {PRESSURE_PREFIX}-token prefix, \
         page={PRESSURE_PAGE})"
    ));
    for p in &result.pressure {
        let pool = if p.capacity_pages == 0 {
            "pool=unbounded".to_string()
        } else {
            format!("pool={}pg", p.capacity_pages)
        };
        row(
            &pool,
            &[
                format!("{:.0} tok/s", p.tokens_per_s),
                format!("evicted={}", p.pages_evicted),
                format!("remat={}", p.pages_rematerialized),
                format!("resident={}pg", p.resident_pages),
                format!("bytes/tok={:.0}", p.resident_bytes_per_token),
                // Same CI-grepped spelling as the sharded rows: eviction
                // churn must not re-introduce hot-path allocations.
                format!("hot_path_allocs: {}", p.hot_path_allocs),
            ],
        );
    }
    header("prefix sharing (pool=8 pages, same workload)");
    for s in &result.sharing {
        row(
            &format!("sharing={}", if s.sharing { "on" } else { "off" }),
            &[
                // The exact spelling the CI smoke greps for.
                format!("pages_shared={}", s.pages_shared),
                format!("cow_splits={}", s.cow_splits),
                format!("evicted={}", s.pages_evicted),
                format!("remat={}", s.pages_rematerialized),
                format!("resident={}pg", s.resident_pages),
            ],
        );
    }
    header("residency modes (one session, unbounded pool)");
    for m in &result.residency {
        row(
            m.mode,
            &[
                format!("bytes/tok={:.0}", m.resident_bytes_per_token),
                format!("max|Δ|={:.2e}", m.max_abs_diff_vs_exact),
                format!("selection_match={}", m.selection_match),
            ],
        );
    }
    result
}

/// End state of one shared-prefix pressure run.
struct PressureRun {
    wall_s: f64,
    hot_path_allocs: u64,
    stats: CacheStats,
    residency: ResidencySnapshot,
}

/// Pressure-workload parameters: sessions × (prefix + rounds) tokens of
/// head dim [`PRESSURE_D`], paged at [`PRESSURE_PAGE`] tokens. The
/// 40-token prefix ends mid-page (2.5 pages of 16), so the first
/// divergent continuation exercises the copy-on-write split path, not
/// just boundary attaches.
const PRESSURE_SESSIONS: usize = 4;
const PRESSURE_PREFIX: usize = 40;
const PRESSURE_ROUNDS: usize = 24;
const PRESSURE_D: usize = 32;
const PRESSURE_PAGE: usize = 16;

/// Drive the shared-prefix multi-session workload once: every session
/// opens with the identical prefix chunk, then the sessions decode one
/// distinct token per round, round-robin — the adversarial access
/// pattern for whole-session LRU (every session is always about to be
/// touched again). Only the decode rounds are timed.
fn shared_prefix_run(
    cfg: &PipelineConfig,
    capacity_pages: usize,
    sharing: bool,
    mode: ResidencyMode,
) -> PressureRun {
    let d = PRESSURE_D;
    // `for_pipeline` draws the page size from the pipeline's query tile;
    // the sweep's page math assumes 16-token pages.
    assert_eq!(cfg.tile_t, PRESSURE_PAGE, "pressure sweep sized for 16-token pages");
    let pipe = SparseAttentionPipeline::new(*cfg);
    let pool = WorkspacePool::new();
    let scfg = SessionConfig::for_pipeline(cfg, d, capacity_pages)
        .with_prefix_sharing(sharing)
        .with_residency(mode);
    let mut store = SessionStore::new(scfg);
    let mut rng = Rng::new(77);
    let pq = Mat::randn(PRESSURE_PREFIX, d, 1.0, &mut rng);
    let pk = Mat::randn(PRESSURE_PREFIX, d, 1.0, &mut rng);
    let pv = Mat::randn(PRESSURE_PREFIX, d, 1.0, &mut rng);
    // Distinct per-session, per-round continuation rows (3 mats per
    // step: q, k, v), drawn from one big pool at disjoint offsets.
    let cont = Mat::randn(PRESSURE_SESSIONS * PRESSURE_ROUNDS * 3, d, 1.0, &mut rng);
    let one = |at: usize| Mat::from_fn(1, d, |_, j| cont.at(at, j));
    for sid in 1..=PRESSURE_SESSIONS as u64 {
        pipe.decode_step_pooled(&mut store, sid, &pq, &pk, &pv, &pool).expect("pressure prefix");
    }
    let (mut wall, mut hot) = (0.0f64, 0u64);
    for round in 0..PRESSURE_ROUNDS {
        for s in 0..PRESSURE_SESSIONS {
            let at = (round * PRESSURE_SESSIONS + s) * 3;
            let r = pipe
                .decode_step_pooled(
                    &mut store,
                    s as u64 + 1,
                    &one(at),
                    &one(at + 1),
                    &one(at + 2),
                    &pool,
                )
                .expect("pressure decode step");
            wall += r.wall_s;
            hot += r.hot_path_allocs;
        }
    }
    PressureRun {
        wall_s: wall,
        hot_path_allocs: hot,
        stats: store.stats(),
        residency: store.residency(),
    }
}

/// The cache-pressure sweep: the shared-prefix workload at each pool
/// capacity in [`PRESSURE_POOL_PAGES`], sharing on, exact residency.
fn cache_pressure_sweep(cfg: &PipelineConfig) -> Vec<CachePressureRow> {
    let decoded = (PRESSURE_SESSIONS * PRESSURE_ROUNDS) as f64;
    PRESSURE_POOL_PAGES
        .iter()
        .map(|&cap| {
            let r = shared_prefix_run(cfg, cap, true, ResidencyMode::Exact);
            CachePressureRow {
                capacity_pages: cap,
                tokens_per_s: decoded / r.wall_s.max(1e-12),
                pages_evicted: r.stats.pages_evicted,
                pages_rematerialized: r.stats.pages_rematerialized,
                pages_shared: r.stats.pages_shared,
                cow_splits: r.stats.cow_splits,
                resident_pages: r.residency.resident_pages,
                resident_bytes_per_token: r.residency.resident_bytes as f64
                    / r.residency.logical_tokens.max(1) as f64,
                hot_path_allocs: r.hot_path_allocs,
            }
        })
        .collect()
}

/// Prefix sharing on vs off on the identical workload at the fixed
/// 8-page pool (the workload needs 10 physical pages with sharing, 16
/// without, so both legs evict — sharing just evicts less).
fn prefix_sharing_comparison(cfg: &PipelineConfig) -> Vec<PrefixSharingRow> {
    [true, false]
        .iter()
        .map(|&sharing| {
            let r = shared_prefix_run(cfg, 8, sharing, ResidencyMode::Exact);
            PrefixSharingRow {
                sharing,
                resident_pages: r.residency.resident_pages,
                pages_shared: r.stats.pages_shared,
                cow_splits: r.stats.cow_splits,
                pages_evicted: r.stats.pages_evicted,
                pages_rematerialized: r.stats.pages_rematerialized,
            }
        })
        .collect()
}

/// Exact vs quantized-only residency on one identical decode session
/// (unbounded pool): per-step output deviation against the exact run,
/// selection parity, and the resident footprint per logical token.
fn residency_mode_comparison(cfg: &PipelineConfig) -> Vec<ResidencyModeRow> {
    let d = PRESSURE_D;
    let (prefill, decode) = (64usize, 24usize);
    let total = prefill + decode;
    let mut rng = Rng::new(4242);
    let q = Mat::randn(total, d, 1.0, &mut rng);
    let k = Mat::randn(total, d, 1.0, &mut rng);
    let v = Mat::randn(total, d, 1.0, &mut rng);
    let slice = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));
    let pipe = SparseAttentionPipeline::new(*cfg);
    let run = |mode: ResidencyMode| {
        let pool = WorkspacePool::new();
        let scfg = SessionConfig::for_pipeline(cfg, d, 0).with_residency(mode);
        let mut store = SessionStore::new(scfg);
        pipe.decode_step_pooled(
            &mut store,
            1,
            &slice(&q, 0, prefill),
            &slice(&k, 0, prefill),
            &slice(&v, 0, prefill),
            &pool,
        )
        .expect("residency prefill");
        let mut outs = Vec::new();
        let mut sels = Vec::new();
        for pos in prefill..total {
            let r = pipe
                .decode_step_pooled(
                    &mut store,
                    1,
                    &slice(&q, pos, pos + 1),
                    &slice(&k, pos, pos + 1),
                    &slice(&v, pos, pos + 1),
                    &pool,
                )
                .expect("residency decode step");
            outs.push(r.out);
            sels.push(r.selection);
        }
        let res = store.residency();
        let rbpt = res.resident_bytes as f64 / res.logical_tokens.max(1) as f64;
        (outs, sels, rbpt)
    };
    let (exact_outs, exact_sels, exact_rbpt) = run(ResidencyMode::Exact);
    let (quant_outs, quant_sels, quant_rbpt) = run(ResidencyMode::QuantizedOnly);
    let max_abs = exact_outs
        .iter()
        .zip(&quant_outs)
        .map(|(a, b)| a.max_abs_diff(b) as f64)
        .fold(0.0, f64::max);
    vec![
        ResidencyModeRow {
            mode: "exact",
            resident_bytes_per_token: exact_rbpt,
            max_abs_diff_vs_exact: 0.0,
            selection_match: true,
        },
        ResidencyModeRow {
            mode: "quantized_only",
            resident_bytes_per_token: quant_rbpt,
            max_abs_diff_vs_exact: max_abs,
            selection_match: exact_sels == quant_sels,
        },
    ]
}

/// Replay a short session through [`ShardedPipeline::decode_step`] at
/// each worker count in [`SHARD_COUNTS`], per-step bit-compared against
/// a single-core [`SparseAttentionPipeline`] twin over an identical
/// store. The session is shorter than the main timed run — the row
/// reports relative scaling, payload and parity, not absolute
/// throughput.
fn sharded_scaling(
    cfg: PipelineConfig,
    d: usize,
    q: &Mat,
    k: &Mat,
    v: &Mat,
) -> Vec<ShardedDecodeRow> {
    let (prefill, decode) = (96usize, 32usize);
    let total = prefill + decode;
    let scale = 1.0 / (d as f32).sqrt();
    let slice = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));
    SHARD_COUNTS
        .iter()
        .map(|&wreq| {
            let single = SparseAttentionPipeline::new(cfg);
            let sharded = ShardedPipeline::new(cfg, wreq);
            let (pool_s, pool_r) = (WorkspacePool::new(), WorkspacePool::new());
            let mut st_s = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
            let mut st_r = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
            let (pq, pk, pv) = (slice(q, 0, prefill), slice(k, 0, prefill), slice(v, 0, prefill));
            // The prefill chunk warms every worker's pooled workspace.
            sharded
                .decode_step_pooled(&mut st_s, 1, &pq, &pk, &pv, &pool_s)
                .expect("sharded prefill");
            single.decode_step_pooled(&mut st_r, 1, &pq, &pk, &pv, &pool_r).expect("prefill");
            let (mut wall, mut payload, mut hot) = (0.0f64, 0u64, 0u64);
            let (mut max_abs, mut parity_ok) = (0.0f64, true);
            let mut shards_eff = wreq;
            let mut last_sel: Vec<usize> = Vec::new();
            for pos in prefill..total {
                let (sq, sk, sv) =
                    (slice(q, pos, pos + 1), slice(k, pos, pos + 1), slice(v, pos, pos + 1));
                let rs = sharded
                    .decode_step_pooled(&mut st_s, 1, &sq, &sk, &sv, &pool_s)
                    .expect("sharded decode step");
                let rr = single
                    .decode_step_pooled(&mut st_r, 1, &sq, &sk, &sv, &pool_r)
                    .expect("decode step");
                wall += rs.wall_s;
                payload += rs.ring_payload_bytes;
                hot += rs.hot_path_allocs;
                max_abs = max_abs.max(rs.out.max_abs_diff(&rr.out) as f64);
                parity_ok &= rs.selection == rr.selection && rs.stalls == rr.stalls;
                shards_eff = rs.shards;
                last_sel.clear();
                last_sel.extend_from_slice(&rs.selection.rows[0]);
            }
            let combine_max_dev =
                combine_deviation(q.row(total - 1), k, v, &last_sel, scale, cfg.bc, wreq);
            ShardedDecodeRow {
                shards: shards_eff,
                tokens_per_s: decode as f64 / wall.max(1e-12),
                mean_ms: wall / decode as f64 * 1e3,
                ring_payload_bytes: payload,
                hot_path_allocs: hot,
                max_abs_diff: max_abs,
                parity_ok: parity_ok && max_abs == 0.0,
                combine_max_dev,
            }
        })
        .collect()
}

/// Measured rescale error of the tolerance-mode distributed formal
/// stage: partition the selection (ascending key order) into `w`
/// contiguous chunks, accumulate one [`SoftmaxPartial`] per chunk, fold
/// them with the fixed pairwise tree and compare the finalized row
/// against the exact single-partition reduction over the same keys (the
/// serving path never does this — it gathers and runs the monolithic
/// kernel — so the deviation is reported, not shipped; DESIGN.md §12).
fn combine_deviation(
    q_row: &[f32],
    k: &Mat,
    v: &Mat,
    keys: &[usize],
    scale: f32,
    bc: usize,
    w: usize,
) -> f64 {
    let d = q_row.len();
    let mut c = OpCounter::new();
    let mut keys = keys.to_vec();
    keys.sort_unstable();
    let mut exact = SoftmaxPartial::empty(d);
    softmax_partial_into(q_row, k, v, &keys, scale, bc, ReductionOrder::Strict, &mut c, &mut exact);
    let mut exact_out = vec![0.0f32; d];
    exact.finalize_into(&mut c, &mut exact_out);
    let n = keys.len();
    let w = w.max(1);
    let mut parts: Vec<SoftmaxPartial> = (0..w)
        .map(|j| {
            let (lo, hi) = (j * n / w, (j + 1) * n / w);
            let mut p = SoftmaxPartial::empty(d);
            softmax_partial_into(
                q_row,
                k,
                v,
                &keys[lo..hi],
                scale,
                bc,
                ReductionOrder::Strict,
                &mut c,
                &mut p,
            );
            p
        })
        .collect();
    let merged = merge_partials_tree(&mut parts, &mut c);
    let mut dist_out = vec![0.0f32; d];
    merged.finalize_into(&mut c, &mut dist_out);
    dist_out.iter().zip(&exact_out).map(|(a, b)| (a - b).abs() as f64).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_bench_runs_and_beats_reprefill() {
        let r = decode_throughput();
        assert!(r.tokens_per_s > 0.0);
        assert!(r.p95_ms >= r.p50_ms);
        assert_eq!(r.step_wall.count(), r.decode_tokens as u64);
        for (i, s) in r.stage_latency.iter().enumerate() {
            assert_eq!(s.count, r.decode_tokens as u64, "stage {i} sampled every step");
            assert!(s.p99 >= s.p50, "stage {i} percentiles must be monotone");
        }
        // A decode step must cost far less than re-prefilling the whole
        // conversation — the point of caching across time.
        assert!(
            r.equiv_adds_per_token * 10.0 < r.reprefill_equiv_adds,
            "decode token {} eq-adds !<< re-prefill {}",
            r.equiv_adds_per_token,
            r.reprefill_equiv_adds
        );
        assert!(r.cache.page_hits > 0);
        assert_eq!(r.cache.pages_evicted, 0, "unbounded pool never evicts");
        // DLZS prediction dominates shifts; formal pays the exponentials.
        assert!(r.ops.predict.shift > 0 && r.ops.formal.exp > 0);
        // The zero-allocation contract: the prefill warms the pooled
        // workspace, so the timed decode steps' stage cores must meter
        // zero heap allocations (vacuously true without a counting
        // allocator; the release bench run installs one and CI checks
        // the JSON).
        assert_eq!(
            r.hot_path_allocs, 0,
            "steady-state decode hot loop allocated on the heap"
        );
        assert!(r.workspace_bytes > 0, "decode rows ran inside a workspace");
        // The sharded scaling sweep: bit-exact and allocation-free at
        // every worker count, communication only when there is more
        // than one worker, and the single-partition combine exact.
        assert_eq!(r.sharded.len(), SHARD_COUNTS.len());
        for s in &r.sharded {
            assert!(
                s.parity_ok && s.max_abs_diff == 0.0,
                "sharded decode diverged at {} shards (max|Δ|={})",
                s.shards,
                s.max_abs_diff
            );
            assert_eq!(s.hot_path_allocs, 0, "shards={} allocated in the hot loop", s.shards);
            assert!(s.tokens_per_s > 0.0 && s.mean_ms > 0.0);
            assert!(s.combine_max_dev < 1e-4, "combine deviation blew up: {}", s.combine_max_dev);
        }
        assert_eq!(r.sharded[0].shards, 1);
        assert_eq!(r.sharded[0].ring_payload_bytes, 0, "one worker scatters nothing");
        assert!(r.sharded.iter().skip(1).all(|s| s.ring_payload_bytes > 0));
        assert_eq!(
            r.sharded[0].combine_max_dev, 0.0,
            "a single partition is the exact reduction"
        );

        // Cache-pressure sweep: the unbounded row never evicts but
        // shares the prefix; the bounded rows churn pages — and none of
        // them may allocate inside the metered decode cores
        // (re-materialization runs outside the hot path).
        assert_eq!(r.pressure.len(), PRESSURE_POOL_PAGES.len());
        let unbounded = &r.pressure[0];
        assert_eq!(unbounded.capacity_pages, 0);
        assert_eq!(unbounded.pages_evicted, 0, "unbounded pool never evicts");
        assert!(unbounded.pages_shared > 0, "prefix pages must be shared");
        assert!(unbounded.cow_splits > 0, "mid-page divergence must split");
        assert!(
            unbounded.resident_pages < PRESSURE_SESSIONS * 4,
            "sharing must keep fewer physical pages than the 16 logical ones, got {}",
            unbounded.resident_pages
        );
        for p in &r.pressure[1..] {
            assert!(p.pages_evicted > 0, "pool={} must evict", p.capacity_pages);
            assert!(p.pages_rematerialized > 0, "pool={} must rematerialize", p.capacity_pages);
            assert!(
                p.resident_pages <= p.capacity_pages,
                "pool={} overflowed to {} resident pages",
                p.capacity_pages,
                p.resident_pages
            );
        }
        for p in &r.pressure {
            assert_eq!(
                p.hot_path_allocs, 0,
                "pool={} allocated in the decode hot loop",
                p.capacity_pages
            );
            assert!(p.tokens_per_s > 0.0);
        }

        // Prefix sharing on vs off at the same tight pool: sharing must
        // measurably reduce eviction churn (the capacity gain).
        let on = &r.sharing[0];
        let off = &r.sharing[1];
        assert!(on.sharing && !off.sharing);
        assert!(on.pages_shared > 0 && on.cow_splits > 0);
        assert_eq!(off.pages_shared, 0, "sharing off must never attach");
        assert_eq!(off.cow_splits, 0, "sharing off must never split");
        assert!(
            on.pages_evicted < off.pages_evicted,
            "sharing must evict less at the same pool: on={} off={}",
            on.pages_evicted,
            off.pages_evicted
        );
        assert!(
            on.pages_rematerialized < off.pages_rematerialized,
            "sharing must rematerialize less: on={} off={}",
            on.pages_rematerialized,
            off.pages_rematerialized
        );

        // Residency modes: quantized-only drops the resident footprint
        // ≥3× while selecting exactly the same keys; the exact row is
        // the bit-exact default.
        assert_eq!(r.residency.len(), 2);
        let exact = &r.residency[0];
        let quant = &r.residency[1];
        assert_eq!(exact.mode, "exact");
        assert_eq!(quant.mode, "quantized_only");
        assert_eq!(exact.max_abs_diff_vs_exact, 0.0);
        assert!(quant.selection_match, "quantized residency changed the selection");
        let ratio = exact.resident_bytes_per_token / quant.resident_bytes_per_token;
        assert!(
            ratio >= 3.0,
            "quantized-only must shrink resident bytes/token ≥3×, got {ratio:.2}× \
             (exact {:.0}, quantized {:.0})",
            exact.resident_bytes_per_token,
            quant.resident_bytes_per_token
        );
        assert!(
            quant.max_abs_diff_vs_exact < 0.5,
            "quantized-only gather deviated too far: {}",
            quant.max_abs_diff_vs_exact
        );
    }

    #[test]
    fn bench_decode_writes_trajectory_json() {
        // `cargo test` itself materializes the repo-root trajectory file
        // (the acceptance artifact), and this guards its schema.
        crate::bench::run("decode").unwrap();
        let path = crate::bench::trajectory::out_dir().join("BENCH_decode.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("decode"));
        assert!(j.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("stage_ops").unwrap().get("predict").is_some());
        assert!(j.get("step_latency_ms").unwrap().get("p95").is_some());
        // Per-stage latency percentiles (histogram summaries, seconds).
        let sl = j.get("stage_latency").unwrap();
        for stage in ["predict", "topk", "kv_gen", "formal"] {
            let s = sl.get(stage).unwrap_or_else(|| panic!("stage_latency.{stage} missing"));
            assert!(s.get("p95").is_some() && s.get("p99").is_some() && s.get("p50").is_some());
        }
        let cache = j.get("cache").unwrap();
        assert!(cache.get("page_hits").is_some());
        // Page-granular residency counters (this PR's split of the old
        // whole-session accounting).
        assert!(cache.get("pages_shared").is_some());
        assert!(cache.get("cow_splits").is_some());
        // Cache-pressure sweep rows: one per pool capacity, allocation-
        // free even under eviction churn.
        let pressure = j.get("pressure").unwrap().as_arr().unwrap();
        assert_eq!(pressure.len(), PRESSURE_POOL_PAGES.len());
        for (p, &cap) in pressure.iter().zip(PRESSURE_POOL_PAGES.iter()) {
            assert_eq!(p.get("capacity_pages").unwrap().as_f64(), Some(cap as f64));
            assert_eq!(p.get("hot_path_allocs").unwrap().as_f64(), Some(0.0));
            assert!(p.get("resident_bytes_per_token").unwrap().as_f64().unwrap() > 0.0);
        }
        // Prefix-sharing capacity comparison (on/off).
        let sharing = j.get("prefix_sharing").unwrap().as_arr().unwrap();
        assert_eq!(sharing.len(), 2);
        assert!(sharing[0].get("pages_shared").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(sharing[1].get("pages_shared").unwrap().as_f64(), Some(0.0));
        // Residency-mode rows + the headline compression ratio the
        // acceptance bar reads (quantized-only ≥3× smaller).
        let modes = j.get("residency_modes").unwrap().as_arr().unwrap();
        assert_eq!(modes.len(), 2);
        assert!(
            j.get("quantized_residency_ratio").unwrap().as_f64().unwrap() >= 3.0,
            "quantized-only residency ratio below the 3x bar"
        );
        // Sharded scaling rows: one per SHARD_COUNTS entry, parity field
        // frozen at exactly zero.
        let sharded = j.get("sharded").unwrap().as_arr().unwrap();
        assert_eq!(sharded.len(), SHARD_COUNTS.len());
        for (s, &w) in sharded.iter().zip(SHARD_COUNTS.iter()) {
            assert_eq!(s.get("shards").unwrap().as_f64(), Some(w as f64));
            assert_eq!(s.get("max_abs_diff").unwrap().as_f64(), Some(0.0));
            assert_eq!(s.get("hot_path_allocs").unwrap().as_f64(), Some(0.0));
            assert!(s.get("tokens_per_s").unwrap().as_f64().unwrap() > 0.0);
            assert!(s.get("combine_max_dev").is_some());
        }
        // The zero-allocation regression guard the CI smoke greps for.
        assert_eq!(j.get("hot_path_allocs").unwrap().as_f64(), Some(0.0));
        assert!(j.get("workspace_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("sram_budget_bytes").unwrap().as_f64(),
            Some((316 * 1024) as f64)
        );
    }
}
