//! `star bench traffic` — measured-vs-modeled memory-traffic
//! reconciliation — and `star bench check` — the perf-regression gate
//! driver (DESIGN.md §11).
//!
//! # Reconciliation
//!
//! The tile engine meters *measured* byte traffic
//! ([`crate::obs::traffic::TrafficCounter`]) while the cycle simulator
//! *predicts* per-stage DRAM streams for the same shape
//! ([`crate::sim::pipeline::StageTime::dram_bytes`]). This bench runs
//! prefill, decode and sharded prefill at a paper-relevant shape with
//! counting enabled, maps both sides to a common unit and hard-fails
//! when they diverge beyond tolerance.
//!
//! The common unit is **elements**, not raw bytes: the software model
//! stores every tensor as f32 (4 B/element) while the simulator charges
//! the accelerator's wire formats (int8 activations at 1 B/element,
//! INT16 KV/outputs at 2 B/element). Dividing each side by its element
//! width makes the comparison exact:
//!
//! | stage | measured (elements) | modeled (elements) |
//! |---|---|---|
//! | predict | (`q_ingest` + `key_ingest`) / 4 | `predict.dram_bytes` (1 B/elem) |
//! | top-k | 0 (on-chip only) | `topk.dram_bytes` (= 0) |
//! | kv_gen (prefill/sharded) | `x_ingest` / 4 | `kv_gen.dram_bytes` (1 B/elem) |
//! | kv_gen (decode) | `cache_append` / 4 | `kv_resident_bytes` / 2 |
//! | formal | `out_egress` / 4 | `formal.dram_bytes` / 2 |
//!
//! The prefill/sharded KV-generation comparison only closes because the
//! *measured* union ratio is injected back into the simulator's
//! [`WorkloadShape`] ([`WorkloadShape::with_union_ratio`]): the model
//! then predicts the exact per-tile KV regeneration the execution
//! performed, instead of its closed-form heuristic.
//!
//! # The gate
//!
//! [`check`] re-runs every gated bench into a temp directory
//! (`STAR_BENCH_DIR`), compares the fresh `BENCH_*.json` against the
//! committed ones with [`compare_benches`]'s noise-aware per-class
//! tolerances, and fails (→ `star bench check` exits nonzero) on any
//! regression. With no committed baselines it soft-warns and passes, so
//! the gate can be adopted before the first baseline lands.

use super::{header, row};
use crate::config::{AccelConfig, ModelConfig};
use crate::kvcache::{SessionConfig, SessionStore};
use crate::obs::baseline::compare_benches;
use crate::obs::traffic::{self, SchedStats, TrafficCounter};
use crate::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
use crate::sim::dram::DramChannel;
use crate::sim::pipeline::{
    simulate, FeatureSet, FormalKind, PredictKind, SimReport, TopkKind, WorkloadShape,
};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::{allocmeter, Rng};
use crate::workload::AttnWorkload;
use std::path::Path;

/// Relative per-stage divergence tolerated between measured and modeled
/// element counts.
pub const TOL_REL: f64 = 0.02;
/// Absolute element-count floor of the tolerance (covers the ±1-row
/// rounding of the injected union ratio on tiny shapes).
pub const TOL_ABS_ELEMS: f64 = 64.0;

/// Benches `star bench check` gates, in `bench::run` spelling. Only the
/// measurement-style benches are gated: the figure tables replay the
/// analytical model and cannot regress at runtime.
pub const GATED_BENCHES: [&str; 4] = ["decode", "spatial-exec", "kernels", "traffic"];

/// Shapes: paper-relevant in release, shrunk in debug so `cargo test`
/// stays fast (same convention as [`super::kernels`]).
/// `(t, s, hidden, decode_prefill, decode_steps)`; 4 heads throughout.
fn dims() -> (usize, usize, usize, usize, usize) {
    if cfg!(debug_assertions) {
        (24, 256, 128, 48, 16)
    } else {
        (128, 1024, 256, 192, 64)
    }
}

/// One stage's measured-vs-modeled comparison, in elements.
#[derive(Clone, Copy, Debug)]
pub struct StageCheck {
    pub stage: &'static str,
    pub measured_elems: f64,
    pub modeled_elems: f64,
}

impl StageCheck {
    /// measured / modeled (1.0 when both are zero).
    pub fn ratio(&self) -> f64 {
        if self.modeled_elems == 0.0 {
            if self.measured_elems == 0.0 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            self.measured_elems / self.modeled_elems
        }
    }

    fn tolerance(&self) -> f64 {
        TOL_ABS_ELEMS.max(TOL_REL * self.modeled_elems)
    }

    /// Within tolerance?
    pub fn ok(&self) -> bool {
        (self.measured_elems - self.modeled_elems).abs() <= self.tolerance()
    }
}

/// One execution path's reconciliation record.
struct PathRecon {
    path: &'static str,
    t: usize,
    s: usize,
    d: usize,
    h: usize,
    keep_ratio: f64,
    /// Union ratio injected into the simulator (measured Σunion / S for
    /// the on-demand paths; 1.0 where KV is cache-resident).
    union_ratio: f64,
    measured: TrafficCounter,
    sched: SchedStats,
    sim: SimReport,
    checks: Vec<StageCheck>,
    hot_path_allocs: u64,
}

fn accel() -> (AccelConfig, DramChannel) {
    (AccelConfig::default(), DramChannel::accel_256())
}

/// Batch prefill on the full STAR stack (cross-phase DLZS from X,
/// on-demand KV, SU-FA).
fn run_prefill(wl: &AttnWorkload) -> PathRecon {
    let inputs = PipelineInputs::from_workload(wl);
    let (t, s, d) = (inputs.t(), inputs.s(), inputs.d());
    let h = wl.x.cols;
    let cfg = PipelineConfig::star().with_keep(0.2).with_tile(16);
    let pipe = SparseAttentionPipeline::new(cfg);
    let pool = WorkspacePool::new();
    // Warm the pool uncounted, then measure: the counted run must stay
    // allocation-free (counting sites are pure integer arithmetic).
    pipe.run_pooled(&inputs, &pool);
    traffic::set_enabled(true);
    let r = pipe.run_pooled(&inputs, &pool);
    traffic::set_enabled(false);
    let measured = r.traffic;

    // Inject the *measured* union ratio (Σ per-tile union rows / S;
    // deliberately may exceed 1 — a key regenerates once per query tile
    // that selects it).
    let ru = measured.x_ingest_bytes as f64 / 4.0 / h as f64 / s as f64;
    let shape = WorkloadShape::new(t, s, d, h, cfg.keep_ratio).with_union_ratio(ru);
    let (acfg, dram) = accel();
    let sim = simulate(&shape, &FeatureSet::star(), &acfg, &dram);

    let checks = vec![
        StageCheck {
            stage: "predict",
            measured_elems: (measured.q_ingest_bytes + measured.key_ingest_bytes) as f64 / 4.0,
            modeled_elems: sim.predict.dram_bytes as f64,
        },
        StageCheck { stage: "topk", measured_elems: 0.0, modeled_elems: sim.topk.dram_bytes as f64 },
        StageCheck {
            stage: "kv_gen",
            measured_elems: measured.x_ingest_bytes as f64 / 4.0,
            modeled_elems: sim.kv_gen.dram_bytes as f64,
        },
        StageCheck {
            stage: "formal",
            measured_elems: measured.out_egress_bytes as f64 / 4.0,
            modeled_elems: sim.formal.dram_bytes as f64 / 2.0,
        },
    ];
    PathRecon {
        path: "prefill",
        t,
        s,
        d,
        h,
        keep_ratio: cfg.keep_ratio,
        union_ratio: ru,
        measured,
        sched: r.sched,
        sim,
        checks,
        hot_path_allocs: r.hot_path_allocs,
    }
}

/// Decode session (prefill chunk + single-token steps) on the paged KV
/// cache. The simulator sees the whole causal session as one job: every
/// token is a query row (t = total) against the final context
/// (s = total). Prediction scores the *frozen cached operands* (SLZS
/// class — symmetric, no X in the loop) and KV is cache-resident, so
/// the KV-generation comparison runs against the modeled resident-KV
/// footprint rather than an on-demand generation stream.
fn run_decode(d: usize, prefill_tokens: usize, steps: usize) -> crate::Result<PathRecon> {
    let cfg = PipelineConfig::star().with_keep(0.2).with_tile(16).with_threads(1);
    let pipe = SparseAttentionPipeline::new(cfg);
    let total = prefill_tokens + steps;
    let mut rng = Rng::new(0x5452_4146); // "TRAF"
    let q = Mat::randn(total, d, 1.0, &mut rng);
    let k = Mat::randn(total, d, 1.0, &mut rng);
    let v = Mat::randn(total, d, 1.0, &mut rng);
    let slice = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));

    let pool = WorkspacePool::new();
    // Warm pass: a throwaway session warms the pooled workspaces for
    // this shape class, uncounted.
    {
        let mut warm = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
        pipe.decode_step_pooled(
            &mut warm,
            1,
            &slice(&q, 0, prefill_tokens),
            &slice(&k, 0, prefill_tokens),
            &slice(&v, 0, prefill_tokens),
            &pool,
        )?;
    }

    traffic::set_enabled(true);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
    let mut measured = TrafficCounter::new();
    let mut sched = SchedStats::default();
    let mut hot_path_allocs = 0u64;
    let r0 = pipe.decode_step_pooled(
        &mut store,
        7,
        &slice(&q, 0, prefill_tokens),
        &slice(&k, 0, prefill_tokens),
        &slice(&v, 0, prefill_tokens),
        &pool,
    )?;
    measured.merge(&r0.traffic);
    sched.merge(&r0.sched);
    hot_path_allocs += r0.hot_path_allocs;
    for pos in prefill_tokens..total {
        let r = pipe.decode_step_pooled(
            &mut store,
            7,
            &slice(&q, pos, pos + 1),
            &slice(&k, pos, pos + 1),
            &slice(&v, pos, pos + 1),
            &pool,
        )?;
        measured.merge(&r.traffic);
        sched.merge(&r.sched);
        hot_path_allocs += r.hot_path_allocs;
    }
    traffic::set_enabled(false);

    let feats = FeatureSet {
        predict: PredictKind::Slzs,
        topk: TopkKind::Sads,
        formal: FormalKind::SufaDescend,
        on_demand_kv: false,
        tiled_dataflow: true,
        oo_scheduler: true,
        sufa_tailored: true,
    };
    // h = 0: the decode loop never touches X (KV arrives with the chunk
    // and lives in the cache), so no upstream activation stream exists.
    let shape = WorkloadShape::new(total, total, d, 0, cfg.keep_ratio);
    let (acfg, dram) = accel();
    let sim = simulate(&shape, &feats, &acfg, &dram);

    let checks = vec![
        StageCheck {
            stage: "predict",
            measured_elems: (measured.q_ingest_bytes + measured.key_ingest_bytes) as f64 / 4.0,
            modeled_elems: sim.predict.dram_bytes as f64,
        },
        StageCheck { stage: "topk", measured_elems: 0.0, modeled_elems: sim.topk.dram_bytes as f64 },
        StageCheck {
            stage: "kv_gen",
            measured_elems: measured.cache_append_bytes as f64 / 4.0,
            modeled_elems: sim.kv_resident_bytes as f64 / 2.0,
        },
        StageCheck {
            stage: "formal",
            measured_elems: measured.out_egress_bytes as f64 / 4.0,
            modeled_elems: sim.formal.dram_bytes as f64 / 2.0,
        },
    ];
    Ok(PathRecon {
        path: "decode",
        t: total,
        s: total,
        d,
        h: 0,
        keep_ratio: cfg.keep_ratio,
        union_ratio: 1.0,
        measured,
        sched,
        sim,
        checks,
        hot_path_allocs,
    })
}

/// Sequence-sharded prefill (executable Spatial-STAR). Same DRAM-class
/// accounting as the single-core prefill — the per-hop score tiles are
/// SRAM-class, the ring payload is isolated in `ring_payload_bytes` —
/// so the same reconciliation closes, with the sharded run's own
/// measured union ratio (home Q blocks partition differently than query
/// tiles, so Σunion legitimately differs).
fn run_sharded(wl: &AttnWorkload) -> PathRecon {
    let inputs = PipelineInputs::from_workload(wl);
    let (t, s, d) = (inputs.t(), inputs.s(), inputs.d());
    let h = wl.x.cols;
    let cfg = PipelineConfig::star().with_keep(0.2).with_tile(16);
    let pipe = ShardedPipeline::new(cfg, 4);
    let pool = WorkspacePool::new();
    pipe.run_pooled(&inputs, &pool);
    traffic::set_enabled(true);
    let r = pipe.run_pooled(&inputs, &pool);
    traffic::set_enabled(false);
    let measured = r.traffic;

    let ru = measured.x_ingest_bytes as f64 / 4.0 / h as f64 / s as f64;
    let shape = WorkloadShape::new(t, s, d, h, cfg.keep_ratio).with_union_ratio(ru);
    let (acfg, dram) = accel();
    let sim = simulate(&shape, &FeatureSet::star(), &acfg, &dram);

    let checks = vec![
        StageCheck {
            stage: "predict",
            measured_elems: (measured.q_ingest_bytes + measured.key_ingest_bytes) as f64 / 4.0,
            modeled_elems: sim.predict.dram_bytes as f64,
        },
        StageCheck { stage: "topk", measured_elems: 0.0, modeled_elems: sim.topk.dram_bytes as f64 },
        StageCheck {
            stage: "kv_gen",
            measured_elems: measured.x_ingest_bytes as f64 / 4.0,
            modeled_elems: sim.kv_gen.dram_bytes as f64,
        },
        StageCheck {
            stage: "formal",
            measured_elems: measured.out_egress_bytes as f64 / 4.0,
            modeled_elems: sim.formal.dram_bytes as f64 / 2.0,
        },
    ];
    PathRecon {
        path: "sharded",
        t,
        s,
        d,
        h,
        keep_ratio: cfg.keep_ratio,
        union_ratio: ru,
        measured,
        sched: r.sched,
        sim,
        checks,
        hot_path_allocs: r.hot_path_allocs,
    }
}

fn n(x: f64) -> Json {
    Json::num(x)
}

fn path_json(p: &PathRecon) -> Json {
    let mut m: Vec<(&str, Json)> =
        p.measured.fields().iter().map(|&(k, v)| (k, n(v as f64))).collect();
    m.push(("dram_class_bytes", n(p.measured.dram_class_bytes() as f64)));
    m.push(("sram_class_bytes", n(p.measured.sram_class_bytes() as f64)));
    Json::obj(vec![
        (
            "shape",
            Json::obj(vec![
                ("t", n(p.t as f64)),
                ("s", n(p.s as f64)),
                ("d", n(p.d as f64)),
                ("h", n(p.h as f64)),
                ("keep_ratio", n(p.keep_ratio)),
                ("union_ratio", n(p.union_ratio)),
            ]),
        ),
        ("measured", Json::obj(m)),
        (
            "sched",
            Json::obj(vec![
                ("workers", n(p.sched.workers as f64)),
                ("chunk_grabs", n(p.sched.chunk_grabs as f64)),
                ("steals", n(p.sched.steals as f64)),
                ("tiles", n(p.sched.tiles as f64)),
                ("max_worker_tiles", n(p.sched.max_worker_tiles as f64)),
                ("imbalance", n(p.sched.imbalance())),
            ]),
        ),
        (
            "modeled",
            Json::obj(vec![
                ("predict_dram_bytes", n(p.sim.predict.dram_bytes as f64)),
                ("topk_dram_bytes", n(p.sim.topk.dram_bytes as f64)),
                ("kv_gen_dram_bytes", n(p.sim.kv_gen.dram_bytes as f64)),
                ("formal_dram_bytes", n(p.sim.formal.dram_bytes as f64)),
                ("total_dram_bytes", n(p.sim.dram_bytes as f64)),
                ("kv_resident_bytes", n(p.sim.kv_resident_bytes as f64)),
            ]),
        ),
        (
            "stages",
            Json::obj(
                p.checks
                    .iter()
                    .map(|c| {
                        (
                            c.stage,
                            Json::obj(vec![
                                ("measured_elems", n(c.measured_elems)),
                                ("modeled_elems", n(c.modeled_elems)),
                                ("ratio", n(c.ratio())),
                            ]),
                        )
                    })
                    .collect(),
            ),
        ),
        ("hot_path_allocs", n(p.hot_path_allocs as f64)),
    ])
}

/// Run the reconciliation on all three execution paths; hard-fails on
/// any out-of-tolerance stage or a metered hot-path allocation. Returns
/// the `BENCH_traffic.json` payload.
pub fn traffic_reconcile() -> crate::Result<Json> {
    let (t, s, hidden, decode_prefill, decode_steps) = dims();
    let model = ModelConfig {
        name: "traffic".to_string(),
        hidden,
        heads: 4,
        layers: 2,
        seq_len: s,
        causal: true,
    };
    let mut rng = Rng::new(0x5452_4146); // "TRAF"
    let wl = AttnWorkload::generate(&model, s, t, &mut rng);

    let prefill = run_prefill(&wl);
    let decode = run_decode(hidden / 4, decode_prefill, decode_steps)?;
    let sharded = run_sharded(&wl);
    let paths = [&prefill, &decode, &sharded];

    header("traffic reconciliation (measured vs simulator-modeled, elements)");
    row(
        "path/stage",
        &[
            format!("{:>12}", "measured"),
            format!("{:>12}", "modeled"),
            format!("{:>8}", "ratio"),
            format!("{:>6}", "ok"),
        ],
    );
    for p in paths {
        for c in &p.checks {
            row(
                &format!("{}/{}", p.path, c.stage),
                &[
                    format!("{:>12.0}", c.measured_elems),
                    format!("{:>12.0}", c.modeled_elems),
                    format!("{:>8.4}", c.ratio()),
                    format!("{:>6}", if c.ok() { "ok" } else { "FAIL" }),
                ],
            );
        }
        row(
            &format!("{} bytes", p.path),
            &[
                format!("dram={}", p.measured.dram_class_bytes()),
                format!("sram={}", p.measured.sram_class_bytes()),
                format!("ring={}", p.measured.ring_payload_bytes),
                format!("steals={}", p.sched.steals),
                format!("imbalance={:.2}", p.sched.imbalance()),
            ],
        );
    }

    let mut hot_path_allocs = 0u64;
    for p in paths {
        for c in &p.checks {
            anyhow::ensure!(
                c.ok(),
                "traffic: {}/{} measured {:.0} elems vs modeled {:.0} \
                 (ratio {:.4}, tolerance ±{:.0})",
                p.path,
                c.stage,
                c.measured_elems,
                c.modeled_elems,
                c.ratio(),
                c.tolerance(),
            );
        }
        hot_path_allocs += p.hot_path_allocs;
    }
    anyhow::ensure!(
        hot_path_allocs == 0,
        "traffic: counted warm runs metered {hot_path_allocs} hot-path allocations \
         (counting must be allocation-free)"
    );

    Ok(Json::obj(vec![
        ("bench", Json::str("traffic")),
        (
            "tolerance",
            Json::obj(vec![("rel", n(TOL_REL)), ("abs_elems", n(TOL_ABS_ELEMS))]),
        ),
        (
            "paths",
            Json::obj(vec![
                ("prefill", path_json(&prefill)),
                ("decode", path_json(&decode)),
                ("sharded", path_json(&sharded)),
            ]),
        ),
        ("hot_path_allocs", n(hot_path_allocs as f64)),
        ("alloc_counter_on", Json::Bool(allocmeter::installed())),
    ]))
}

fn bench_file(name: &str) -> String {
    format!("BENCH_{}.json", name.replace('-', "_"))
}

fn read_json(path: &Path) -> crate::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
}

/// Compare `BENCH_*.json` pairs from two directories under the
/// per-metric-class tolerances; prints one line per bench (plus every
/// regression) and returns whether all passed. Pure over the two
/// directories — [`check`] owns the re-run; tests doctor the files.
pub fn check_dirs(baseline_dir: &Path, fresh_dir: &Path, names: &[&str]) -> crate::Result<bool> {
    let mut all_ok = true;
    for nm in names {
        let file = bench_file(nm);
        let base = read_json(&baseline_dir.join(&file))?;
        let fresh = read_json(&fresh_dir.join(&file))?;
        let rep = compare_benches(nm, &base, &fresh);
        if rep.is_ok() {
            println!("bench check: {nm}: ok ({} gated metrics compared)", rep.compared);
        } else {
            all_ok = false;
            for r in &rep.regressions {
                println!("bench check: {nm}: REGRESSION {r}");
            }
            for m in &rep.missing {
                println!("bench check: {nm}: MISSING {m}");
            }
        }
    }
    Ok(all_ok)
}

/// `star bench check`: re-run every gated bench whose committed
/// `BENCH_*.json` baseline exists, into a temp directory, and compare
/// fresh vs committed. `Err` (→ nonzero exit) on any regression; soft
/// pass with a warning when no baselines are committed yet.
pub fn check() -> crate::Result<()> {
    let baseline_dir = super::trajectory::out_dir();
    let present: Vec<&str> = GATED_BENCHES
        .iter()
        .copied()
        .filter(|nm| baseline_dir.join(bench_file(nm)).is_file())
        .collect();
    if present.is_empty() {
        println!(
            "bench check: no committed BENCH_*.json baselines in {} — nothing to gate \
             (run `star bench all` and commit the files to arm the gate)",
            baseline_dir.display()
        );
        return Ok(());
    }

    let tmp = std::env::temp_dir().join(format!("star-bench-check-{}", std::process::id()));
    std::fs::create_dir_all(&tmp)?;
    // Point the writers at the temp dir for the fresh runs, restoring
    // the previous value (baselines were already located above).
    let prev = std::env::var_os("STAR_BENCH_DIR");
    std::env::set_var("STAR_BENCH_DIR", &tmp);
    let ran: crate::Result<()> = (|| {
        for nm in &present {
            super::run(nm)?;
        }
        Ok(())
    })();
    match prev {
        Some(v) => std::env::set_var("STAR_BENCH_DIR", v),
        None => std::env::remove_var("STAR_BENCH_DIR"),
    }
    ran?;

    let ok = check_dirs(&baseline_dir, &tmp, &present)?;
    anyhow::ensure!(ok, "bench check: performance regression against committed baselines");
    println!("bench check: all gated metrics within tolerance ({} baselines)", present.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_bench_reconciles_and_writes_schema() {
        crate::bench::run("traffic").unwrap();
        let path = crate::bench::trajectory::out_dir().join("BENCH_traffic.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("traffic"));
        assert_eq!(j.get("hot_path_allocs").unwrap().as_f64(), Some(0.0));
        let paths = j.get("paths").unwrap();
        for pname in ["prefill", "decode", "sharded"] {
            let p = paths.get(pname).unwrap_or_else(|| panic!("paths.{pname} missing"));
            let measured = p.get("measured").unwrap();
            // Every counter field is present (the python cross-reader
            // and the Prometheus exposition share this list).
            for (key, _) in TrafficCounter::new().fields() {
                assert!(measured.get(key).is_some(), "{pname}: measured.{key} missing");
            }
            for stage in ["predict", "topk", "kv_gen", "formal"] {
                let c = p.get("stages").unwrap().get(stage).unwrap();
                let ratio = c.get("ratio").unwrap().as_f64().unwrap();
                let modeled = c.get("modeled_elems").unwrap().as_f64().unwrap();
                // In-tolerance already hard-checked by run(); re-derive
                // loosely from the written numbers.
                if modeled > 0.0 {
                    assert!(
                        (ratio - 1.0).abs() <= 0.05,
                        "{pname}/{stage}: written ratio {ratio} too far from 1"
                    );
                }
            }
            assert_eq!(p.get("hot_path_allocs").unwrap().as_f64(), Some(0.0));
            let sched = p.get("sched").unwrap();
            assert!(sched.get("workers").unwrap().as_f64().unwrap() >= 1.0);
            assert!(sched.get("imbalance").unwrap().as_f64().unwrap() >= 1.0 - 1e-9);
        }
        // The sharded path reports ring traffic; single-core paths none.
        let ring = |p: &str| {
            paths
                .get(p)
                .unwrap()
                .get("measured")
                .unwrap()
                .get("ring_payload_bytes")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert_eq!(ring("prefill"), 0.0);
        assert!(ring("sharded") > 0.0, "4-shard ring forwarded payloads");
    }

    #[test]
    fn check_dirs_passes_identical_and_flags_injected_regression() {
        use crate::bench::trajectory::write_to;
        let base_dir = std::env::temp_dir().join("star_check_base_test");
        let fresh_dir = std::env::temp_dir().join("star_check_fresh_test");
        std::fs::create_dir_all(&base_dir).unwrap();
        std::fs::create_dir_all(&fresh_dir).unwrap();
        let doc = |tokens: f64, hot: f64| {
            Json::obj(vec![
                ("bench", Json::str("decode")),
                ("tokens_per_s", Json::num(tokens)),
                ("hot_path_allocs", Json::num(hot)),
                (
                    "traffic",
                    Json::obj(vec![("q_ingest_bytes", Json::num(4096.0))]),
                ),
            ])
        };
        write_to(&base_dir, "decode", doc(100.0, 0.0)).unwrap();
        // Identical fresh run passes.
        write_to(&fresh_dir, "decode", doc(100.0, 0.0)).unwrap();
        assert!(check_dirs(&base_dir, &fresh_dir, &["decode"]).unwrap());
        // Injected throughput regression (−30%) trips the gate.
        write_to(&fresh_dir, "decode", doc(70.0, 0.0)).unwrap();
        assert!(!check_dirs(&base_dir, &fresh_dir, &["decode"]).unwrap());
        // Injected hot-path allocation trips the gate even at full speed.
        write_to(&fresh_dir, "decode", doc(100.0, 2.0)).unwrap();
        assert!(!check_dirs(&base_dir, &fresh_dir, &["decode"]).unwrap());
        // Missing fresh file is an error, not a silent pass.
        assert!(check_dirs(&base_dir, &fresh_dir, &["kernels"]).is_err());
    }
}
