//! The benchmark harness: one function per table/figure of the paper's
//! evaluation (plus the motivation figures), each printing the same
//! rows/series the paper reports and returning the numbers for
//! assertions. `cargo bench` and `star bench <name>` both route here.
//!
//! | Paper artifact | Function |
//! |---|---|
//! | Fig. 1 (memory/compute growth) | [`motivation::fig1_memory_compute`] |
//! | Fig. 3 (MAT vs TP) | [`motivation::fig3_mat_breakdown`] |
//! | Fig. 4 (operation intensity) | [`motivation::fig4_operation_intensity`] |
//! | Fig. 5 (FA-2 overhead) | [`motivation::fig5_fa2_overhead`] |
//! | Fig. 7 (QKV vs attention) | [`motivation::fig7_qkv_crossover`] |
//! | Fig. 9 (Type I/II/III mix) | [`algorithm::fig9_distribution_mix`] |
//! | Fig. 11 (update orders) | [`algorithm::fig11_update_orders`] |
//! | Fig. 16 (LP computation reduction) | [`algorithm::fig16_lp_reduction`] |
//! | Fig. 17 (top-k hit rates) | [`algorithm::fig17_hit_rates`] |
//! | Fig. 18 (ablation + RC trade-off) | [`algorithm::fig18_ablation`] |
//! | Table II (accuracy proxy) | [`algorithm::table2_accuracy`] |
//! | Fig. 19 (throughput vs A100) | [`arch::fig19_throughput_vs_gpu`] |
//! | Fig. 20 (gain breakdown) | [`arch::fig20_gain_breakdown`] |
//! | Fig. 21 (area/power) | [`arch::fig21_area_power`] |
//! | Fig. 22 (memory + energy) | [`arch::fig22_memory_energy`] |
//! | Fig. 23(a) (SRAM, single core) | [`arch::fig23a_sram_single_core`] |
//! | Table III (SOTA comparison) | [`arch::table3_comparison`] |
//! | Fig. 23(b) (SRAM, multi-core) | [`spatial_eval::fig23b_sram_multicore`] |
//! | Fig. 24 (spatial ablation/lateral) | [`spatial_eval::fig24_spatial`] |

pub mod algorithm;
pub mod arch;
pub mod motivation;
pub mod spatial_eval;

use crate::Result;

/// Print a section header.
pub(crate) fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Render one row of right-aligned cells after a label.
pub(crate) fn row(label: &str, cells: &[String]) {
    let cells = cells.join("  ");
    println!("{label:<26} {cells}");
}

/// Format a float with 3 significant-ish digits, right aligned.
pub(crate) fn f(x: f64) -> String {
    if x == 0.0 {
        format!("{:>9}", "0")
    } else if x.abs() >= 1000.0 || x.abs() < 0.01 {
        format!("{x:>9.2e}")
    } else {
        format!("{x:>9.3}")
    }
}

/// All bench names, in paper order.
pub const ALL: [&str; 18] = [
    "fig1", "fig3", "fig4", "fig5", "fig7", "fig9", "fig11", "fig16", "fig17", "fig18",
    "table2", "fig19", "fig20", "fig21", "fig22", "fig23", "table3", "fig24",
];

/// Run one named bench (or `all`).
pub fn run(name: &str) -> Result<()> {
    match name {
        "fig1" => drop(motivation::fig1_memory_compute()),
        "fig3" => drop(motivation::fig3_mat_breakdown()),
        "fig4" => drop(motivation::fig4_operation_intensity()),
        "fig5" => drop(motivation::fig5_fa2_overhead()),
        "fig7" => drop(motivation::fig7_qkv_crossover()),
        "fig9" => drop(algorithm::fig9_distribution_mix()),
        "fig11" => drop(algorithm::fig11_update_orders()),
        "fig16" => drop(algorithm::fig16_lp_reduction()),
        "fig17" => drop(algorithm::fig17_hit_rates()),
        "fig18" => drop(algorithm::fig18_ablation()),
        "table2" => drop(algorithm::table2_accuracy()),
        "fig19" => drop(arch::fig19_throughput_vs_gpu()),
        "fig20" => drop(arch::fig20_gain_breakdown()),
        "fig21" => drop(arch::fig21_area_power()),
        "fig22" => drop(arch::fig22_memory_energy()),
        "fig23" => {
            drop(arch::fig23a_sram_single_core());
            drop(spatial_eval::fig23b_sram_multicore());
        }
        "table3" => drop(arch::table3_comparison()),
        "fig24" => drop(spatial_eval::fig24_spatial()),
        "all" => {
            for n in ALL {
                run(n)?;
            }
        }
        other => anyhow::bail!("unknown bench {other:?}; try one of {ALL:?} or `all`"),
    }
    Ok(())
}
