//! The benchmark harness: one function per table/figure of the paper's
//! evaluation (plus the motivation figures), each printing the same
//! rows/series the paper reports and returning the numbers for
//! assertions. `cargo bench` and `star bench <name>` both route here.
//!
//! | Paper artifact | Function |
//! |---|---|
//! | Fig. 1 (memory/compute growth) | [`motivation::fig1_memory_compute`] |
//! | Fig. 3 (MAT vs TP) | [`motivation::fig3_mat_breakdown`] |
//! | Fig. 4 (operation intensity) | [`motivation::fig4_operation_intensity`] |
//! | Fig. 5 (FA-2 overhead) | [`motivation::fig5_fa2_overhead`] |
//! | Fig. 7 (QKV vs attention) | [`motivation::fig7_qkv_crossover`] |
//! | Fig. 9 (Type I/II/III mix) | [`algorithm::fig9_distribution_mix`] |
//! | Fig. 11 (update orders) | [`algorithm::fig11_update_orders`] |
//! | Fig. 16 (LP computation reduction) | [`algorithm::fig16_lp_reduction`] |
//! | Fig. 17 (top-k hit rates) | [`algorithm::fig17_hit_rates`] |
//! | Fig. 18 (ablation + RC trade-off) | [`algorithm::fig18_ablation`] |
//! | Table II (accuracy proxy) | [`algorithm::table2_accuracy`] |
//! | Fig. 19 (throughput vs A100) | [`arch::fig19_throughput_vs_gpu`] |
//! | Fig. 20 (gain breakdown) | [`arch::fig20_gain_breakdown`] |
//! | Fig. 21 (area/power) | [`arch::fig21_area_power`] |
//! | Fig. 22 (memory + energy) | [`arch::fig22_memory_energy`] |
//! | Fig. 23(a) (SRAM, single core) | [`arch::fig23a_sram_single_core`] |
//! | Table III (SOTA comparison) | [`arch::table3_comparison`] |
//! | Fig. 23(b) (SRAM, multi-core) | [`spatial_eval::fig23b_sram_multicore`] |
//! | Fig. 24 (spatial ablation/lateral) | [`spatial_eval::fig24_spatial`] |
//! | Decode throughput (KV-cache) | [`decode::decode_throughput`] |
//! | Spatial-exec (measured sharding) | [`spatial_exec::spatial_exec`] |
//! | Kernel layer (scalar vs lanes) | [`kernels::kernel_benches`] |
//! | Traffic reconciliation (measured vs modeled) | [`traffic::traffic_reconcile`] |
//! | Perf-regression gate | [`traffic::check`] |
//!
//! Every subcommand also writes its numbers to `BENCH_<name>.json` at
//! the repo root ([`trajectory`]), so the perf trajectory is tracked
//! across PRs. `star bench check` is the one exception: it *reads* the
//! committed `BENCH_*.json` baselines, re-runs the gated benches into a
//! temp directory and exits nonzero on regression (DESIGN.md §11) —
//! it never overwrites a baseline.

pub mod algorithm;
pub mod arch;
pub mod decode;
pub mod kernels;
pub mod motivation;
pub mod spatial_eval;
pub mod spatial_exec;
pub mod traffic;
pub mod trajectory;

use crate::util::json::Json;
use crate::Result;
use trajectory::{hist_json, stage_ops_json, table};

/// Print a section header.
pub(crate) fn header(title: &str) {
    println!("\n=== {title} ===");
}

/// Render one row of right-aligned cells after a label.
pub(crate) fn row(label: &str, cells: &[String]) {
    let cells = cells.join("  ");
    println!("{label:<26} {cells}");
}

/// Format a float with 3 significant-ish digits, right aligned.
pub(crate) fn f(x: f64) -> String {
    if x == 0.0 {
        format!("{:>9}", "0")
    } else if x.abs() >= 1000.0 || x.abs() < 0.01 {
        format!("{x:>9.2e}")
    } else {
        format!("{x:>9.3}")
    }
}

/// All bench names, in paper order (plus the serving-side `decode`, the
/// measured-sharding `spatial-exec`, the kernel-layer `kernels` and the
/// measured-vs-modeled `traffic` reconciliation).
pub const ALL: [&str; 22] = [
    "fig1", "fig3", "fig4", "fig5", "fig7", "fig9", "fig11", "fig16", "fig17", "fig18",
    "table2", "fig19", "fig20", "fig21", "fig22", "fig23", "table3", "fig24", "decode",
    "spatial-exec", "kernels", "traffic",
];

fn n(x: f64) -> Json {
    Json::num(x)
}

/// Run one named bench (or `all`), writing its machine-readable payload
/// to `BENCH_<name>.json` (see [`trajectory`]).
pub fn run(name: &str) -> Result<()> {
    // `check` gates against the committed baselines instead of
    // producing one — it must not write a trajectory file.
    if name == "check" {
        return traffic::check();
    }
    // CLI spelling `spatial-exec` ↔ file `BENCH_spatial_exec.json`.
    let name = if name == "spatial-exec" { "spatial_exec" } else { name };
    let payload: Json = match name {
        "fig1" => {
            let rows = motivation::fig1_memory_compute();
            table(
                name,
                &["seq_len", "attn_mem_norm", "attn_ffn_ops"],
                rows.into_iter().map(|(s, m, c)| vec![n(s as f64), n(m), n(c)]).collect(),
            )
        }
        "fig3" => {
            let rows = motivation::fig3_mat_breakdown();
            table(
                name,
                &["accel", "token_parallelism", "mat_fraction"],
                rows.into_iter()
                    .map(|(a, tp, mf)| vec![Json::str(a), n(tp as f64), n(mf)])
                    .collect(),
            )
        }
        "fig4" => {
            let rows = motivation::fig4_operation_intensity();
            table(
                name,
                &["label", "ops_per_byte"],
                rows.into_iter().map(|(l, oi)| vec![Json::str(&l), n(oi)]).collect(),
            )
        }
        "fig5" => {
            let rows = motivation::fig5_fa2_overhead();
            table(
                name,
                &["seq_len", "extra_exp", "extra_cmp", "extra_equiv_adds"],
                rows.into_iter()
                    .map(|(s, e, c, a)| vec![n(s as f64), n(e as f64), n(c as f64), n(a)])
                    .collect(),
            )
        }
        "fig7" => {
            let rows = motivation::fig7_qkv_crossover();
            table(
                name,
                &["model", "crossover_seq_len"],
                rows.into_iter().map(|(m, s)| vec![Json::str(&m), n(s as f64)]).collect(),
            )
        }
        "fig9" => {
            let rows = algorithm::fig9_distribution_mix();
            table(
                name,
                &["family", "share_type1", "share_type2", "share_type3"],
                rows.into_iter()
                    .map(|(f, sh)| vec![Json::str(&f), n(sh[0]), n(sh[1]), n(sh[2])])
                    .collect(),
            )
        }
        "fig11" => {
            let rows = algorithm::fig11_update_orders();
            table(
                name,
                &["order", "mul", "exp"],
                rows.into_iter()
                    .map(|(o, m, e)| vec![Json::str(o), n(m as f64), n(e as f64)])
                    .collect(),
            )
        }
        "fig16" => {
            let rows = algorithm::fig16_lp_reduction();
            table(
                name,
                &["task", "loss_pct", "attn_reduction", "attn_plus_qkv_reduction"],
                rows.into_iter()
                    .map(|(t, l, a, aq)| vec![Json::str(&t), n(l as f64), n(a), n(aq)])
                    .collect(),
            )
        }
        "fig17" => {
            let rows = algorithm::fig17_hit_rates();
            table(
                name,
                &["scheme", "layer", "topk_pct", "hit_rate"],
                rows.into_iter()
                    .map(|(s, l, k, h)| vec![Json::str(s), n(l as f64), n(k as f64), n(h)])
                    .collect(),
            )
        }
        "fig18" => {
            let rows = algorithm::fig18_ablation();
            table(
                name,
                &["config", "equiv_adds", "reduction_vs_baseline"],
                rows.into_iter().map(|(c, a, r)| vec![Json::str(&c), n(a), n(r)]).collect(),
            )
        }
        "table2" => {
            let rows = algorithm::table2_accuracy();
            table(
                name,
                &["model", "config", "rel_err", "hit_rate"],
                rows.into_iter()
                    .map(|(m, c, e, h)| vec![Json::str(&m), Json::str(c), n(e), n(h)])
                    .collect(),
            )
        }
        "fig19" => {
            let rows = arch::fig19_throughput_vs_gpu();
            table(
                name,
                &["model", "loss_idx", "speedup_vs_a100"],
                rows.into_iter()
                    .map(|(m, l, s)| vec![Json::str(&m), n(l as f64), n(s)])
                    .collect(),
            )
        }
        "fig20" => {
            let rows = arch::fig20_gain_breakdown();
            table(
                name,
                &["step", "cumulative_gain"],
                rows.into_iter().map(|(s, g)| vec![Json::str(s), n(g)]).collect(),
            )
        }
        "fig21" => {
            let rows = arch::fig21_area_power();
            table(
                name,
                &["unit", "area_mm2", "power_mw"],
                rows.into_iter().map(|(u, a, p)| vec![Json::str(&u), n(a), n(p)]).collect(),
            )
        }
        "fig22" => {
            let ((r_rass, r_full), gains) = arch::fig22_memory_energy();
            Json::obj(vec![
                ("bench", Json::str(name)),
                ("memory_reduction_rass", n(r_rass)),
                ("memory_reduction_full", n(r_full)),
                (
                    "energy_eff_gain_by_loss",
                    Json::Arr(gains.iter().map(|&g| n(g)).collect()),
                ),
            ])
        }
        "fig23" => {
            let single = arch::fig23a_sram_single_core();
            let multi = spatial_eval::fig23b_sram_multicore();
            Json::obj(vec![
                ("bench", Json::str(name)),
                (
                    "single_core",
                    table(
                        "fig23a",
                        &["sram_kb", "star_gops", "baseline_gops"],
                        single
                            .into_iter()
                            .map(|(kb, s, b)| vec![n(kb as f64), n(s), n(b)])
                            .collect(),
                    ),
                ),
                (
                    "multi_core",
                    table(
                        "fig23b",
                        &["sram_kb", "optimized_tops", "baseline_tops"],
                        multi
                            .into_iter()
                            .map(|(kb, o, b)| vec![n(kb as f64), n(o), n(b)])
                            .collect(),
                    ),
                ),
            ])
        }
        "table3" => {
            let (gops, gops_w) = arch::table3_comparison();
            Json::obj(vec![
                ("bench", Json::str(name)),
                ("star_gops", n(gops)),
                ("star_gops_per_w", n(gops_w)),
            ])
        }
        "fig24" => {
            let rows = spatial_eval::fig24_spatial();
            table(
                name,
                &["mesh", "dra_gain", "mrca_gain_total", "spatten_gain", "star_gain"],
                rows.into_iter()
                    .map(|(m, a, b, c, d)| vec![Json::str(&m), n(a), n(b), n(c), n(d)])
                    .collect(),
            )
        }
        "decode" => {
            let r = decode::decode_throughput();
            for s in &r.sharded {
                anyhow::ensure!(
                    s.parity_ok && s.max_abs_diff == 0.0,
                    "decode: sharded path diverged from single-core at {} shards (max|Δ|={})",
                    s.shards,
                    s.max_abs_diff
                );
            }
            Json::obj(vec![
                ("bench", Json::str(name)),
                ("prefill_tokens", n(r.prefill_tokens as f64)),
                ("decode_tokens", n(r.decode_tokens as f64)),
                ("head_dim", n(r.d as f64)),
                ("keep_ratio", n(r.keep_ratio)),
                ("page_size", n(r.page_size as f64)),
                ("tokens_per_s", n(r.tokens_per_s)),
                (
                    "step_latency_ms",
                    Json::obj(vec![
                        ("p50", n(r.p50_ms)),
                        ("p95", n(r.p95_ms)),
                        ("p99", n(r.p99_ms)),
                        ("mean", n(r.mean_ms)),
                    ]),
                ),
                // Per-stage per-step latency distributions, seconds
                // (log-bucketed histogram summaries; see `crate::obs`).
                (
                    "stage_latency",
                    Json::obj(vec![
                        ("predict", hist_json(&r.stage_latency[0])),
                        ("topk", hist_json(&r.stage_latency[1])),
                        ("kv_gen", hist_json(&r.stage_latency[2])),
                        ("formal", hist_json(&r.stage_latency[3])),
                    ]),
                ),
                ("equiv_adds_per_token", n(r.equiv_adds_per_token)),
                ("reprefill_equiv_adds", n(r.reprefill_equiv_adds)),
                ("union_rows_mean", n(r.union_rows_mean)),
                // Zero-allocation hot-path guard (counting allocator) +
                // workspace/SRAM correspondence (DESIGN.md §8).
                ("hot_path_allocs", n(r.hot_path_allocs as f64)),
                ("alloc_counter_on", Json::Bool(r.alloc_counter_on)),
                ("workspace_bytes", n(r.workspace_bytes as f64)),
                (
                    "sram_budget_bytes",
                    n(crate::sim::sram::Sram::STAR_BUDGET_BYTES as f64),
                ),
                ("stage_ops", stage_ops_json(&r.ops)),
                ("reprefill_stage_ops", stage_ops_json(&r.reprefill_ops)),
                // Sharded-decode scaling sweep: one row per worker count
                // (page-partitioned distributed decode, bit-exact by the
                // ensure above; `combine_max_dev` is the measured
                // tolerance-mode online-softmax rescale error).
                (
                    "sharded",
                    Json::Arr(
                        r.sharded
                            .iter()
                            .map(|s| {
                                Json::obj(vec![
                                    ("shards", n(s.shards as f64)),
                                    ("tokens_per_s", n(s.tokens_per_s)),
                                    ("mean_ms", n(s.mean_ms)),
                                    ("ring_payload_bytes", n(s.ring_payload_bytes as f64)),
                                    ("hot_path_allocs", n(s.hot_path_allocs as f64)),
                                    ("max_abs_diff", n(s.max_abs_diff)),
                                    ("combine_max_dev", n(s.combine_max_dev)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "cache",
                    Json::obj(vec![
                        ("appended_tokens", n(r.cache.appended_tokens as f64)),
                        ("pages_allocated", n(r.cache.pages_allocated as f64)),
                        ("pages_evicted", n(r.cache.pages_evicted as f64)),
                        ("sessions_evicted", n(r.cache.sessions_evicted as f64)),
                        ("pages_rematerialized", n(r.cache.pages_rematerialized as f64)),
                        ("page_hits", n(r.cache.page_hits as f64)),
                        ("pages_shared", n(r.cache.pages_shared as f64)),
                        ("cow_splits", n(r.cache.cow_splits as f64)),
                    ]),
                ),
                // Cache-pressure sweep: shared-prefix multi-session
                // decode at each pool capacity (0 = unbounded); page-
                // granular eviction/remat churn must stay allocation-
                // free on the hot path.
                (
                    "pressure",
                    Json::Arr(
                        r.pressure
                            .iter()
                            .map(|p| {
                                Json::obj(vec![
                                    ("capacity_pages", n(p.capacity_pages as f64)),
                                    ("tokens_per_s", n(p.tokens_per_s)),
                                    ("pages_evicted", n(p.pages_evicted as f64)),
                                    ("pages_rematerialized", n(p.pages_rematerialized as f64)),
                                    ("pages_shared", n(p.pages_shared as f64)),
                                    ("cow_splits", n(p.cow_splits as f64)),
                                    ("resident_pages", n(p.resident_pages as f64)),
                                    (
                                        "resident_bytes_per_token",
                                        n(p.resident_bytes_per_token),
                                    ),
                                    ("hot_path_allocs", n(p.hot_path_allocs as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                // Copy-on-write prefix sharing on vs off at the fixed
                // tight pool — the measured capacity gain.
                (
                    "prefix_sharing",
                    Json::Arr(
                        r.sharing
                            .iter()
                            .map(|s| {
                                Json::obj(vec![
                                    ("sharing", Json::Bool(s.sharing)),
                                    ("resident_pages", n(s.resident_pages as f64)),
                                    ("pages_shared", n(s.pages_shared as f64)),
                                    ("cow_splits", n(s.cow_splits as f64)),
                                    ("pages_evicted", n(s.pages_evicted as f64)),
                                    ("pages_rematerialized", n(s.pages_rematerialized as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                // Exact vs quantized-only residency on one session; the
                // headline ratio is what the acceptance bar reads.
                (
                    "residency_modes",
                    Json::Arr(
                        r.residency
                            .iter()
                            .map(|m| {
                                Json::obj(vec![
                                    ("mode", Json::str(m.mode)),
                                    (
                                        "resident_bytes_per_token",
                                        n(m.resident_bytes_per_token),
                                    ),
                                    ("max_abs_diff_vs_exact", n(m.max_abs_diff_vs_exact)),
                                    ("selection_match", Json::Bool(m.selection_match)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "quantized_residency_ratio",
                    n(r.residency[0].resident_bytes_per_token
                        / r.residency[1].resident_bytes_per_token.max(1e-12)),
                ),
            ])
        }
        "spatial_exec" => {
            let r = spatial_exec::spatial_exec();
            anyhow::ensure!(r.parity_ok, "spatial-exec: sharded output diverged from single-core");
            spatial_exec::payload(&r)
        }
        "kernels" => {
            let rows = kernels::kernel_benches();
            for r in &rows {
                anyhow::ensure!(
                    r.parity_ok,
                    "kernels: {} lanes spelling diverged from scalar ({})",
                    r.kernel,
                    r.shape
                );
            }
            table(
                name,
                &[
                    "kernel",
                    "shape",
                    "flops",
                    "scalar_gflops",
                    "lanes_gflops",
                    "speedup",
                    "bytes",
                    "intensity_flops_per_byte",
                    "scalar_gbytes_per_s",
                    "lanes_gbytes_per_s",
                ],
                rows.iter()
                    .map(|r| {
                        vec![
                            Json::str(r.kernel),
                            Json::str(&r.shape),
                            n(r.flops),
                            n(r.scalar_gflops()),
                            n(r.lanes_gflops()),
                            n(r.speedup()),
                            n(r.bytes),
                            n(r.intensity()),
                            n(r.scalar_gbytes_per_s()),
                            n(r.lanes_gbytes_per_s()),
                        ]
                    })
                    .collect(),
            )
        }
        "traffic" => traffic::traffic_reconcile()?,
        "all" => {
            for bench in ALL {
                run(bench)?;
            }
            return Ok(());
        }
        other => anyhow::bail!("unknown bench {other:?}; try one of {ALL:?} or `all`"),
    };
    let path = trajectory::write(name, payload)?;
    println!("[trajectory: {}]", path.display());
    Ok(())
}
