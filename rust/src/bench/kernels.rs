//! `star bench kernels` — microbenchmarks for the lane-spelled hot
//! kernels (DESIGN.md §10): cache-blocked matmul, DLZS block scoring,
//! row quantization, top-k extraction and the SU-FA inner loops, each
//! timed in both spellings ([`KernelPath::Scalar`] vs
//! [`KernelPath::Lanes`]) in one binary.
//!
//! Every kernel is re-checked for bit identity between the two
//! spellings on every run — a speedup measured against a diverged
//! baseline is meaningless, so parity failure fails the bench, exactly
//! like `spatial-exec`'s sharded-vs-single-core parity gate. Timings
//! are best-of-[`REPS`] wall clock; shapes shrink under
//! `debug_assertions` so `cargo test` stays fast while `--release`
//! runs paper-relevant sizes (d = 128 heads, 1k–4k key contexts).

use crate::arith::{quantize_row_into_with, IntBits, KernelPath, OpCounter};
use crate::attention::{sufa_attention_rows_into_with, AttnInputs, SufaParams, SufaScratch};
use crate::sparsity::{vanilla_topk_into_with, PredictScheme, Predictor, TopkScratch};
use crate::tensor::Mat;
use crate::util::rng::Rng;
use std::time::Instant;

/// Timing repetitions per (kernel, path); the minimum is reported so a
/// stray scheduler preemption cannot masquerade as a slowdown.
const REPS: usize = 5;

/// One kernel's scalar-vs-lanes measurement.
#[derive(Clone, Debug)]
pub struct KernelBench {
    pub kernel: &'static str,
    pub shape: String,
    /// Primitive-op estimate for the workload (MACs count as 2).
    pub flops: f64,
    /// Estimated bytes moved through the memory hierarchy per run
    /// (operand reads + result writes at their stored widths); the
    /// denominator of the arithmetic-intensity / roofline columns.
    pub bytes: f64,
    pub scalar_s: f64,
    pub lanes_s: f64,
    /// Both spellings produced bit-identical buffers (and identical op
    /// tallies where the kernel meters them).
    pub parity_ok: bool,
}

impl KernelBench {
    pub fn scalar_gflops(&self) -> f64 {
        self.flops / self.scalar_s / 1e9
    }

    pub fn lanes_gflops(&self) -> f64 {
        self.flops / self.lanes_s / 1e9
    }

    pub fn speedup(&self) -> f64 {
        self.scalar_s / self.lanes_s
    }

    /// Arithmetic intensity (FLOP per byte moved) — the x-axis of the
    /// roofline plot; path-independent since both spellings touch the
    /// same operands.
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes
    }

    /// Achieved scalar memory bandwidth, GB/s (roofline y via bytes).
    pub fn scalar_gbytes_per_s(&self) -> f64 {
        self.bytes / self.scalar_s / 1e9
    }

    /// Achieved lanes memory bandwidth, GB/s.
    pub fn lanes_gbytes_per_s(&self) -> f64 {
        self.bytes / self.lanes_s / 1e9
    }
}

/// Best-of-[`REPS`] wall-clock seconds for `f` (after one warmup call).
fn time_best(mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn fill(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.range_f32(-1.0, 1.0))
}

/// Benchmark shapes: paper-relevant in release, shrunk in debug so the
/// in-tree schema test doesn't dominate `cargo test` time.
fn dims() -> (usize, usize, usize, usize, usize) {
    // (matmul m/k/n share these) t, d, s, topk_len, topk_k
    if cfg!(debug_assertions) {
        (24, 32, 160, 512, 48)
    } else {
        (64, 128, 1024, 4096, 256)
    }
}

fn bench_matmul(rng: &mut Rng) -> KernelBench {
    let (t, d, s, _, _) = dims();
    // KV-gen shape: X[t, d] × W[d, s-wide] column block.
    let (m, k, n) = (t, d, s);
    let a = fill(rng, m, k);
    let b = fill(rng, k, n);
    let mut out_s = Mat::zeros(1, 1);
    let mut out_l = Mat::zeros(1, 1);
    let scalar_s = time_best(|| a.matmul_cols_into_with(&b, 0, n, &mut out_s, KernelPath::Scalar));
    let lanes_s = time_best(|| a.matmul_cols_into_with(&b, 0, n, &mut out_l, KernelPath::Lanes));
    let parity_ok = mats_bit_eq(&out_s, &out_l);
    KernelBench {
        kernel: "matmul_cols_into",
        shape: format!("{m}x{k}x{n}"),
        flops: 2.0 * (m * k * n) as f64,
        // f32 A + B reads and C writes (compulsory traffic, no reuse).
        bytes: 4.0 * (m * k + k * n + m * n) as f64,
        scalar_s,
        lanes_s,
        parity_ok,
    }
}

fn bench_score(rng: &mut Rng) -> KernelBench {
    let (t, d, s, _, _) = dims();
    let q = fill(rng, t, d);
    let k = fill(rng, s, d);
    let mut c = OpCounter::default();
    let prep = Predictor::new(PredictScheme::Dlzs, 7).prepare(&q, &k, &mut c);
    let mut out_s = Mat::zeros(1, 1);
    let mut out_l = Mat::zeros(1, 1);
    let mut ops_s = OpCounter::default();
    let mut ops_l = OpCounter::default();
    let scalar_s = time_best(|| {
        prep.score_block_into_with(0, t, 0, s, &mut ops_s, &mut out_s, KernelPath::Scalar)
    });
    let lanes_s = time_best(|| {
        prep.score_block_into_with(0, t, 0, s, &mut ops_l, &mut out_l, KernelPath::Lanes)
    });
    let parity_ok = mats_bit_eq(&out_s, &out_l);
    KernelBench {
        kernel: "score_block_into",
        shape: format!("{t}x{s} d={d} dlzs"),
        flops: 2.0 * (t * s * d) as f64,
        // int8 prepared Q and K operands + f32 score writes.
        bytes: ((t + s) * d) as f64 + 4.0 * (t * s) as f64,
        scalar_s,
        lanes_s,
        parity_ok,
    }
}

fn bench_quantize(rng: &mut Rng) -> KernelBench {
    let (t, _, _, len, _) = dims();
    let rows: Vec<Vec<f32>> = (0..t)
        .map(|_| (0..len).map(|_| rng.range_f32(-4.0, 4.0)).collect())
        .collect();
    let mut q_s: Vec<i32> = Vec::new();
    let mut q_l: Vec<i32> = Vec::new();
    let mut scales_s = Vec::new();
    let mut scales_l = Vec::new();
    let scalar_s = time_best(|| {
        scales_s.clear();
        for row in &rows {
            scales_s.push(quantize_row_into_with(row, IntBits::Int8, &mut q_s, KernelPath::Scalar));
        }
    });
    let lanes_s = time_best(|| {
        scales_l.clear();
        for row in &rows {
            scales_l.push(quantize_row_into_with(row, IntBits::Int8, &mut q_l, KernelPath::Lanes));
        }
    });
    // The timing loops end on the same final row, so comparing the last
    // quantized buffer plus every per-row scale covers both phases
    // (amax fold and the divide/round fill).
    let parity_ok = q_s == q_l
        && scales_s.len() == scales_l.len()
        && scales_s
            .iter()
            .zip(&scales_l)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    KernelBench {
        kernel: "quantize_row_into",
        shape: format!("{t} rows x {len} int8"),
        // amax + div + round + clamp ≈ 4 primitive ops per element.
        flops: 4.0 * (t * len) as f64,
        // f32 read + i32 write per element, plus one scale per row.
        bytes: 8.0 * (t * len) as f64 + 4.0 * t as f64,
        scalar_s,
        lanes_s,
        parity_ok,
    }
}

fn bench_topk(rng: &mut Rng) -> KernelBench {
    let (_, _, _, len, k) = dims();
    let row: Vec<f32> = (0..len).map(|_| rng.range_f32(-8.0, 8.0)).collect();
    let mut scratch = TopkScratch::default();
    let mut sel_s = Vec::new();
    let mut sel_l = Vec::new();
    let mut ops_s = OpCounter::default();
    let mut ops_l = OpCounter::default();
    let scalar_s = time_best(|| {
        vanilla_topk_into_with(&row, k, &mut ops_s, &mut scratch, &mut sel_s, KernelPath::Scalar)
    });
    let lanes_s = time_best(|| {
        vanilla_topk_into_with(&row, k, &mut ops_l, &mut scratch, &mut sel_l, KernelPath::Lanes)
    });
    let parity_ok = sel_s == sel_l;
    KernelBench {
        kernel: "vanilla_topk_into",
        shape: format!("len={len} k={k}"),
        // k passes, one comparison per untaken candidate per pass.
        flops: (k * len) as f64,
        // Each pass re-reads the f32 candidate row.
        bytes: 4.0 * (k * len) as f64,
        scalar_s,
        lanes_s,
        parity_ok,
    }
}

fn bench_sufa(rng: &mut Rng) -> KernelBench {
    let (t, d, s, _, k) = dims();
    let q = fill(rng, t, d);
    let km = fill(rng, s, d);
    let v = fill(rng, s, d);
    let inp = AttnInputs::new(&q, &km, &v);
    let rows: Vec<Vec<usize>> = (0..t)
        .map(|_| {
            let mut sel = rng.sample_indices(s, k.min(s));
            sel.sort_unstable();
            sel
        })
        .collect();
    let p = SufaParams::default();
    let mut scratch = SufaScratch::default();
    let mut out_s = Mat::zeros(1, 1);
    let mut out_l = Mat::zeros(1, 1);
    let mut ops_s = OpCounter::default();
    let mut ops_l = OpCounter::default();
    let mut stalls = [0u64; 2];
    let scalar_s = time_best(|| {
        stalls[0] = sufa_attention_rows_into_with(
            &inp,
            &rows,
            &p,
            &mut ops_s,
            &mut scratch,
            &mut out_s,
            KernelPath::Scalar,
        );
    });
    let lanes_s = time_best(|| {
        stalls[1] = sufa_attention_rows_into_with(
            &inp,
            &rows,
            &p,
            &mut ops_l,
            &mut scratch,
            &mut out_l,
            KernelPath::Lanes,
        );
    });
    let parity_ok = mats_bit_eq(&out_s, &out_l) && stalls[0] == stalls[1];
    let nnz: usize = rows.iter().map(|r| r.len()).sum();
    KernelBench {
        kernel: "sufa_attention_rows_into",
        shape: format!("t={t} s={s} d={d} k={k}"),
        // Per selected pair: q·k dot (2d) + exp-weighted axpy (2d).
        flops: 4.0 * (nnz * d) as f64,
        // Gathered K and V rows per selected pair + one q read and one
        // accumulator write per query row, all f32.
        bytes: 4.0 * (2 * nnz * d) as f64 + 4.0 * (2 * t * d) as f64,
        scalar_s,
        lanes_s,
        parity_ok,
    }
}

fn mats_bit_eq(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Run every kernel microbenchmark; prints the scalar-vs-lanes table.
pub fn kernel_benches() -> Vec<KernelBench> {
    let mut rng = Rng::new(0x5747_4152); // "STAR"
    let rows = vec![
        bench_matmul(&mut rng),
        bench_score(&mut rng),
        bench_quantize(&mut rng),
        bench_topk(&mut rng),
        bench_sufa(&mut rng),
    ];
    super::header(&format!(
        "kernel microbenchmarks (active path: {:?}, best of {REPS})",
        KernelPath::active()
    ));
    super::row(
        "kernel",
        &[
            format!("{:>22}", "shape"),
            format!("{:>10}", "scalar GF/s"),
            format!("{:>10}", "lanes GF/s"),
            format!("{:>8}", "speedup"),
            format!("{:>9}", "FLOP/B"),
            format!("{:>10}", "lanes GB/s"),
            format!("{:>6}", "parity"),
        ],
    );
    for r in &rows {
        super::row(
            r.kernel,
            &[
                format!("{:>22}", r.shape),
                super::f(r.scalar_gflops()),
                super::f(r.lanes_gflops()),
                format!("{:>8.2}x", r.speedup()),
                super::f(r.intensity()),
                super::f(r.lanes_gbytes_per_s()),
                format!("{:>6}", if r.parity_ok { "ok" } else { "FAIL" }),
            ],
        );
    }
    rows
}

#[cfg(test)]
mod tests {
    #[test]
    fn kernels_bench_writes_schema_and_holds_parity() {
        crate::bench::run("kernels").unwrap();
        let path = crate::bench::trajectory::out_dir().join("BENCH_kernels.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("kernels"));
        let cols: Vec<String> = j
            .get("columns")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        for want in [
            "kernel",
            "shape",
            "flops",
            "scalar_gflops",
            "lanes_gflops",
            "speedup",
            "bytes",
            "intensity_flops_per_byte",
            "scalar_gbytes_per_s",
            "lanes_gbytes_per_s",
        ] {
            assert!(cols.contains(&want.to_string()), "missing column {want}");
        }
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 5, "one row per hot kernel");
        // run() already hard-fails on parity loss; double-check the
        // emitted numbers are finite and positive.
        let gf = cols.iter().position(|c| c == "lanes_gflops").unwrap();
        for r in rows {
            let v = r.as_arr().unwrap()[gf].as_f64().unwrap();
            assert!(v.is_finite() && v > 0.0, "bogus lanes_gflops {v}");
        }
    }
}
