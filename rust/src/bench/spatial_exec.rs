//! `star bench spatial-exec` — **measured** multi-worker Spatial-STAR.
//!
//! The spatial simulator ([`crate::spatial::sim`]) predicts the
//! DRAttention/MRCA speedups analytically; this bench *executes* the
//! same sequence-sharded dataflow ([`crate::pipeline::ShardedPipeline`])
//! on real worker threads and measures wall-clock, so the analytic
//! model and the execution engine cross-validate each other in one
//! `BENCH_spatial_exec.json`: per worker count, the measured wall time
//! and speedup next to the analytic DRAttention+MRCA prediction on a
//! 1×N mesh. Every sharded run is also checked bit-identical against
//! the single-core pipeline (the `parity_ok` field), so the trajectory
//! can never silently report speedup from wrong numerics.

use super::{header, row};
use crate::bench::trajectory::{hist_json, stage_ops_json};
use crate::config::SpatialConfig;
use crate::obs::{HistSummary, Histogram};
use crate::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
use crate::spatial::sim::{spatial_run, CoreKind, Dataflow};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::Rng;
use std::time::Instant;

/// One worker-count measurement.
#[derive(Clone, Debug)]
pub struct ExecPoint {
    /// Effective worker count.
    pub shards: usize,
    /// Measured wall time, seconds (best of [`RUNS`] runs).
    pub wall_s: f64,
    /// Single-core wall / this wall.
    pub speedup: f64,
    /// Ring steps of one run.
    pub ring_steps: usize,
    /// Modeled ring payload bytes of one run.
    pub ring_payload_bytes: u64,
    /// Selected KV rows gathered to home workers in one run.
    pub gathered_kv_rows: usize,
    /// Analytic DRAttention+MRCA latency on a 1×shards mesh, seconds.
    pub analytic_total_s: f64,
    /// Analytic 1-worker latency / analytic latency at this count.
    pub analytic_speedup: f64,
}

/// Full report of one bench invocation.
#[derive(Clone, Debug)]
pub struct SpatialExecReport {
    pub t: usize,
    pub s: usize,
    pub d: usize,
    pub keep: f64,
    /// Single-core `SparseAttentionPipeline` wall time (1 thread).
    pub single_wall_s: f64,
    /// Per-stage op counters of the largest-worker-count run (identical
    /// to the single-core run for predict/top-k by construction).
    pub ops: crate::pipeline::StageOps,
    pub points: Vec<ExecPoint>,
    /// Every sharded output/selection matched the single-core run
    /// bit for bit.
    pub parity_ok: bool,
    /// Heap allocations metered inside the workers' home-phase stage
    /// cores across all measured runs (warm-pool steady state is zero;
    /// the first run of each worker count warms cold workspaces).
    pub hot_path_allocs: u64,
    /// Peak per-worker tile-workspace capacity seen, bytes (compare
    /// against `crate::sim::sram::Sram::STAR_BUDGET_BYTES`).
    pub workspace_bytes: usize,
    /// Per-shard per-run stage busy-time distributions (seconds) across
    /// every measured sharded run, predict/topk/kv_gen/formal order —
    /// one sample per worker per run, so imbalance across the ring
    /// shows up as percentile spread.
    pub stage_latency: [HistSummary; 4],
}

/// Wall-clock samples per configuration (best-of, to shed scheduler
/// noise).
pub const RUNS: usize = 2;

fn best_wall<T>(runs: usize, mut job: impl FnMut() -> T) -> (T, f64) {
    let mut best = f64::INFINITY;
    let mut out = None;
    for _ in 0..runs.max(1) {
        let t0 = Instant::now();
        let r = job();
        best = best.min(t0.elapsed().as_secs_f64());
        out = Some(r);
    }
    (out.unwrap(), best)
}

/// Run the executable spatial study on a `t × s` head-`d` workload at
/// `keep`, for each worker count in `shard_counts`.
pub fn spatial_exec_with(
    t: usize,
    s: usize,
    d: usize,
    keep: f64,
    shard_counts: &[usize],
) -> SpatialExecReport {
    header(&format!(
        "Spatial-exec — measured sequence-sharded prefill (T={t} S={s} d={d} keep={keep})"
    ));
    let mut rng = Rng::new(2024);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    // One thread on the single-core pipeline: the sharded engine's
    // parallelism must come from its workers, not a second thread pool.
    let cfg = PipelineConfig::star().with_keep(keep).with_threads(1);

    let (single, single_wall_s) =
        best_wall(RUNS, || SparseAttentionPipeline::new(cfg).run(&inputs));
    row(
        "single-core",
        &[format!("{:>9.1} ms", single_wall_s * 1e3), "1.00x".into(), "(baseline)".into()],
    );

    // Analytic 1-worker reference for the simulator column.
    let analytic_base = analytic(1, s, d, keep).total_s;

    let mut parity_ok = true;
    let mut ops = None;
    let mut points = Vec::with_capacity(shard_counts.len());
    // One pool across every measured run, as a serving worker would
    // hold it: later runs reuse the earlier runs' warm workspaces.
    let pool = WorkspacePool::new();
    let mut hot_path_allocs = 0u64;
    let mut workspace_bytes = 0usize;
    let mut stage_hist: [Histogram; 4] = Default::default();
    for &w in shard_counts {
        let pipe = ShardedPipeline::new(cfg, w);
        let (r, wall_s) = best_wall(RUNS, || {
            let r = pipe.run_pooled(&inputs, &pool);
            hot_path_allocs += r.hot_path_allocs;
            workspace_bytes = workspace_bytes.max(r.workspace_bytes);
            for s in &r.per_shard {
                stage_hist[0].record_secs(s.timing.predict_s);
                stage_hist[1].record_secs(s.timing.topk_s);
                stage_hist[2].record_secs(s.timing.kv_gen_s);
                stage_hist[3].record_secs(s.timing.formal_s);
            }
            r
        });
        let ok = r.out.max_abs_diff(&single.out) == 0.0 && r.selection == single.selection;
        if !ok {
            eprintln!("spatial-exec: PARITY FAILURE at {w} workers");
        }
        parity_ok &= ok;
        let a = analytic(r.shards, s, d, keep);
        let point = ExecPoint {
            shards: r.shards,
            wall_s,
            speedup: single_wall_s / wall_s,
            ring_steps: r.ring_steps,
            ring_payload_bytes: r.ring_payload_bytes,
            gathered_kv_rows: r.union_rows,
            analytic_total_s: a.total_s,
            analytic_speedup: analytic_base / a.total_s,
        };
        row(
            &format!("{} workers", point.shards),
            &[
                format!("{:>9.1} ms", point.wall_s * 1e3),
                format!("{:>5.2}x", point.speedup),
                format!(
                    "analytic {:>5.2}x  ring {} steps / {} B  parity {}",
                    point.analytic_speedup,
                    point.ring_steps,
                    point.ring_payload_bytes,
                    if ok { "ok" } else { "FAIL" }
                ),
            ],
        );
        ops = Some(r.ops);
        points.push(point);
    }

    row(
        "hot path",
        &[
            format!("allocs={hot_path_allocs} (incl. cold-workspace warm-up)"),
            format!(
                "workspace={} of {} sim SRAM",
                crate::util::fmt_bytes(workspace_bytes as f64),
                crate::util::fmt_bytes(crate::sim::sram::Sram::STAR_BUDGET_BYTES as f64),
            ),
        ],
    );

    SpatialExecReport {
        t,
        s,
        d,
        keep,
        single_wall_s,
        ops: ops.unwrap_or_default(),
        points,
        parity_ok,
        hot_path_allocs,
        workspace_bytes,
        stage_latency: std::array::from_fn(|i| stage_hist[i].summary(1e-9)),
    }
}

/// The default study: an over-target sequence (T = 256 query rows — two
/// LTPP batches wide — over a 4096-key context) across 1/2/4 workers.
pub fn spatial_exec() -> SpatialExecReport {
    spatial_exec_with(256, 4096, 64, 0.2, &[1, 2, 4])
}

/// Analytic DRAttention+MRCA prediction for `w` workers on a 1×w mesh
/// (the ring the executable engine realizes), same context length.
fn analytic(w: usize, s: usize, d: usize, keep: f64) -> crate::spatial::sim::SpatialReport {
    let mut cfg = SpatialConfig::mesh5x5();
    cfg.mesh_rows = 1;
    cfg.mesh_cols = w.max(1);
    spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, d, 768, keep)
}

/// The `BENCH_spatial_exec.json` payload.
pub fn payload(r: &SpatialExecReport) -> Json {
    let n = Json::num;
    Json::obj(vec![
        ("bench", Json::str("spatial_exec")),
        ("t", n(r.t as f64)),
        ("s", n(r.s as f64)),
        ("d", n(r.d as f64)),
        ("keep_ratio", n(r.keep)),
        ("single_core_wall_s", n(r.single_wall_s)),
        ("parity_ok", Json::Bool(r.parity_ok)),
        ("hot_path_allocs", n(r.hot_path_allocs as f64)),
        ("workspace_bytes", n(r.workspace_bytes as f64)),
        ("sram_budget_bytes", n(crate::sim::sram::Sram::STAR_BUDGET_BYTES as f64)),
        (
            "columns",
            Json::Arr(
                [
                    "shards",
                    "wall_s",
                    "speedup",
                    "ring_steps",
                    "ring_payload_bytes",
                    "gathered_kv_rows",
                    "analytic_total_s",
                    "analytic_speedup",
                ]
                .iter()
                .map(|c| Json::str(c))
                .collect(),
            ),
        ),
        (
            "rows",
            Json::Arr(
                r.points
                    .iter()
                    .map(|p| {
                        Json::Arr(vec![
                            n(p.shards as f64),
                            n(p.wall_s),
                            n(p.speedup),
                            n(p.ring_steps as f64),
                            n(p.ring_payload_bytes as f64),
                            n(p.gathered_kv_rows as f64),
                            n(p.analytic_total_s),
                            n(p.analytic_speedup),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("stage_ops", stage_ops_json(&r.ops)),
        // Per-shard per-run stage busy-time distributions (seconds).
        (
            "stage_latency",
            Json::obj(vec![
                ("predict", hist_json(&r.stage_latency[0])),
                ("topk", hist_json(&r.stage_latency[1])),
                ("kv_gen", hist_json(&r.stage_latency[2])),
                ("formal", hist_json(&r.stage_latency[3])),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_study_is_parity_clean_and_monotone_in_axis() {
        // Tiny sizes: this is a schema/parity test, not a perf test —
        // wall-clock ordering is asserted nowhere (CI machines are
        // noisy), only correctness and the shard axis.
        let r = spatial_exec_with(16, 128, 16, 0.25, &[1, 2, 4]);
        assert!(r.parity_ok, "sharded runs must match the single-core pipeline");
        assert_eq!(r.points.len(), 3);
        for pair in r.points.windows(2) {
            assert!(pair[0].shards < pair[1].shards, "shard axis must ascend");
        }
        for p in &r.points {
            assert_eq!(p.ring_steps, p.shards);
            assert!(p.wall_s > 0.0 && p.analytic_total_s > 0.0);
            assert!(p.shards > 1 || p.ring_payload_bytes == 0);
        }
        assert!(r.workspace_bytes > 0, "sharded workers ran inside workspaces");
        // 1+2+4 shards × RUNS runs = one stage-time sample per shard-run.
        let samples = (1 + 2 + 4) * RUNS;
        for (i, s) in r.stage_latency.iter().enumerate() {
            assert_eq!(s.count, samples as u64, "stage {i} sampled per shard per run");
            assert!(s.p99 >= s.p50, "stage {i} percentiles must be monotone");
        }
        let j = payload(&r);
        for stage in ["predict", "topk", "kv_gen", "formal"] {
            let s = j.get("stage_latency").unwrap().get(stage);
            assert!(s.unwrap().get("p95").is_some(), "stage_latency.{stage}.p95 missing");
        }
        assert_eq!(j.get("bench").unwrap().as_str(), Some("spatial_exec"));
        assert_eq!(j.get("parity_ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rows").unwrap().as_arr().unwrap().len(), 3);
        assert!(j.get("hot_path_allocs").is_some());
        assert!(j.get("workspace_bytes").unwrap().as_f64().unwrap() > 0.0);
    }
}
