//! Architecture-evaluation figures: Fig. 19–23(a), Table III.

use super::{f, header, row};
use crate::config::{AccelConfig, ModelConfig};
use crate::sim::area::ChipBudget;
use crate::sim::baselines::{table3_specs, Baseline};
use crate::sim::dram::DramChannel;
use crate::sim::gpu::GpuModel;
use crate::sim::pipeline::{simulate, FeatureSet, FormalKind, PredictKind, SimReport, TopkKind, WorkloadShape};
use crate::util::stats::geomean;

fn ltpp_shape(m: &ModelConfig, keep: f64) -> WorkloadShape {
    WorkloadShape::new(128, m.seq_len, m.head_dim(), m.hidden, keep)
}

/// Fig. 19: STAR throughput gain over LP-on-A100 per task/model at
/// 0/1/2% loss budgets. Returns (model, loss_idx, speedup).
pub fn fig19_throughput_vs_gpu() -> Vec<(String, usize, f64)> {
    header("Fig. 19 — STAR speedup over LP on A100");
    let gpu = GpuModel::a100();
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    let keeps = [0.25, 0.2, 0.15]; // 0/1/2% loss budgets
    let mut out = Vec::new();
    row("model", &["0% loss".into(), "1% loss".into(), "2% loss".into()]);
    for m in ModelConfig::suite() {
        let mut cells = Vec::new();
        for (li, keep) in keeps.iter().enumerate() {
            let shape = ltpp_shape(&m, *keep);
            let star = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
            let gpu_t = gpu.lp_job_time(&shape);
            let speedup = gpu_t / star.total_s;
            cells.push(format!("{speedup:>8.1}x"));
            out.push((m.name.clone(), li, speedup));
        }
        row(&m.name, &cells);
    }
    for li in 0..3 {
        let v: Vec<f64> = out.iter().filter(|r| r.1 == li).map(|r| r.2).collect();
        row(&format!("geomean @{li}% loss"), &[format!("{:>8.1}x", geomean(&v))]);
    }
    out
}

/// Fig. 20: cumulative throughput-gain breakdown over the dense-GPU
/// baseline. Returns (step, cumulative_gain).
pub fn fig20_gain_breakdown() -> Vec<(&'static str, f64)> {
    header("Fig. 20 — throughput gain breakdown (vs dense A100)");
    let gpu = GpuModel::a100();
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    let m = ModelConfig::preset("gpt2").unwrap();
    let shape = ltpp_shape(&m, 0.2);
    let gpu_t = gpu.dense_job_time(&shape);

    let steps: [(&'static str, FeatureSet); 5] = [
        ("dense ASIC", FeatureSet::dense_asic()),
        (
            "+LP (no engines)",
            FeatureSet {
                predict: PredictKind::LowBitMul,
                topk: TopkKind::Vanilla,
                formal: FormalKind::Dense,
                on_demand_kv: true,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: false,
            },
        ),
        (
            "+DLZS/SADS engines",
            FeatureSet {
                predict: PredictKind::DlzsCross,
                topk: TopkKind::Sads,
                formal: FormalKind::Dense,
                on_demand_kv: true,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: false,
            },
        ),
        (
            "+SU-FA (tailored)",
            FeatureSet {
                predict: PredictKind::DlzsCross,
                topk: TopkKind::Sads,
                formal: FormalKind::SufaDescend,
                on_demand_kv: true,
                tiled_dataflow: false,
                oo_scheduler: false,
                sufa_tailored: true,
            },
        ),
        ("+RASS + tiled (STAR)", FeatureSet::star()),
    ];
    let mut out = Vec::new();
    row("configuration", &["gain vs GPU".into(), "step gain".into()]);
    let mut prev = gpu_t;
    for (name, feats) in steps {
        // The paper's "dedicated ASIC datapath" reference point is an
        // NVDLA-class dense MAC array (~4 TOPS), not a STAR-sized chip:
        // Table III's implied GPU throughput (24423/9.2 ≈ 2.7 TOPS) and
        // the 1.5× dense-ASIC step are only mutually consistent at that
        // size. Later steps use the STAR configuration.
        let step_cfg = if name == "dense ASIC" {
            AccelConfig { pe_macs_per_cycle: 2048, sufa_exp_units: 32, ..cfg.clone() }
        } else {
            cfg.clone()
        };
        let r = simulate(&shape, &feats, &step_cfg, &dram);
        let cum = gpu_t / r.total_s;
        let step = prev / r.total_s;
        row(name, &[format!("{cum:>8.2}x"), format!("{step:>8.2}x")]);
        out.push((name, cum));
        prev = r.total_s;
    }
    out
}

/// Fig. 21: area & power breakdown of the STAR accelerator. Returns
/// (unit, area_mm2, power_mw).
pub fn fig21_area_power() -> Vec<(String, f64, f64)> {
    header("Fig. 21 — area & power breakdown (TSMC 28 nm)");
    let b = ChipBudget::for_config(&AccelConfig::default());
    let mut out = Vec::new();
    row("unit", &["area mm²".into(), "power mW".into()]);
    for u in &b.units {
        row(u.name, &[f(u.area_mm2), f(u.power_mw)]);
        out.push((u.name.to_string(), u.area_mm2, u.power_mw));
    }
    row("TOTAL", &[f(b.total_area_mm2()), f(b.total_power_mw())]);
    row(
        "LP share",
        &[
            format!("{:>8.1}%", 100.0 * b.lp_area_share()),
            format!("{:>8.1}%", 100.0 * b.lp_power_share()),
        ],
    );
    out
}

/// Fig. 22: (a) memory-access reduction vs the vanilla-DS baseline and
/// (b) energy-efficiency gain over the A100. Returns
/// ((reduction_rass, reduction_full), [gain_0, gain_1, gain_2]).
pub fn fig22_memory_energy() -> ((f64, f64), [f64; 3]) {
    header("Fig. 22 — memory-access reduction & energy-efficiency gain");
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    let m = ModelConfig::preset("gpt2").unwrap();
    // LTPP regime for the traffic comparison (T = 512).
    let shape = WorkloadShape::new(512, m.seq_len, m.head_dim(), m.hidden, 0.2);

    let base = simulate(&shape, &FeatureSet::ds_baseline(), &cfg, &dram);
    let mut rass_only = FeatureSet::star();
    rass_only.tiled_dataflow = false; // RASS scheduling without full tiling
    let rass = simulate(&shape, &rass_only, &cfg, &dram);
    let full = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
    let red_rass = 1.0 - rass.dram_bytes as f64 / base.dram_bytes as f64;
    let red_full = 1.0 - full.dram_bytes as f64 / base.dram_bytes as f64;
    row("mem reduction (RASS)", &[format!("{:>8.1}%", 100.0 * red_rass)]);
    row("mem reduction (+SU-FA+tiled)", &[format!("{:>8.1}%", 100.0 * red_full)]);

    let gpu = GpuModel::a100();
    let mut gains = [0.0f64; 3];
    for (li, keep) in [0.25, 0.2, 0.15].iter().enumerate() {
        let mut per_model = Vec::new();
        for m in ModelConfig::suite() {
            let shape = ltpp_shape(&m, *keep);
            let star = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
            let star_eff = star.energy_eff_gops_w();
            let gpu_eff = gpu.dense_gops_per_w(&shape);
            per_model.push(star_eff / gpu_eff);
        }
        gains[li] = geomean(&per_model);
        row(&format!("energy-eff gain @{li}% loss"), &[format!("{:>8.1}x", gains[li])]);
    }
    ((red_rass, red_full), gains)
}

/// Fig. 23(a): single-core throughput vs SRAM capacity, STAR vs the
/// untiled baseline, 256 GB/s DRAM. Returns (kb, star_gops, base_gops).
pub fn fig23a_sram_single_core() -> Vec<(usize, f64, f64)> {
    header("Fig. 23(a) — SRAM sweep, single core (256 GB/s DRAM)");
    let dram = DramChannel::accel_256();
    let m = ModelConfig::preset("gpt2").unwrap();
    let shape = ltpp_shape(&m, 0.2);
    let mut base_feats = FeatureSet::star();
    base_feats.formal = FormalKind::Dense; // no softmax tiling
    base_feats.tiled_dataflow = false;
    base_feats.oo_scheduler = false;
    base_feats.sufa_tailored = false;
    let mut out = Vec::new();
    row("SRAM kB", &["STAR GOPS".into(), "baseline GOPS".into()]);
    for kb in [64usize, 128, 192, 256, 316, 412, 512] {
        let cfg = AccelConfig { sram_bytes: kb * 1024, ..AccelConfig::default() };
        let star = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
        let base = simulate(&shape, &base_feats, &cfg, &dram);
        row(&format!("{kb}"), &[f(star.eff_gops), f(base.eff_gops)]);
        out.push((kb, star.eff_gops, base.eff_gops));
    }
    out
}

/// Table III: SOTA comparison — published rows plus our simulator's
/// measured row for STAR. Returns the measured STAR (gops, gops/w).
pub fn table3_comparison() -> (f64, f64) {
    header("Table III — comparison with SOTA accelerators (28 nm norm.)");
    row(
        "design",
        &[
            "tech".into(),
            "area".into(),
            "power".into(),
            "GOPS".into(),
            "GOPS/W".into(),
            "GOPS/mm²".into(),
        ],
    );
    for s in table3_specs() {
        row(
            s.name,
            &[
                format!("{:>6.0}nm", s.tech_nm),
                f(s.area_mm2),
                f(s.power_w),
                f(s.throughput_gops),
                f(s.energy_eff_28nm()),
                f(s.area_eff_28nm()),
            ],
        );
    }
    // Our simulator's measured STAR numbers on a representative LTPP job.
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    let shape = WorkloadShape::new(128, 4096, 128, 4096, 0.2);
    let r = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
    let budget = ChipBudget::for_config(&cfg);
    let gops = r.eff_gops;
    let gops_w = r.energy_eff_gops_w();
    row(
        "STAR (this sim)",
        &[
            "28nm".into(),
            f(budget.total_area_mm2()),
            f(budget.total_power_mw() / 1e3),
            f(gops),
            f(gops_w),
            f(gops / budget.total_area_mm2()),
        ],
    );
    (gops, gops_w)
}

/// Helper shared by tests: STAR report on a model's LTPP job.
pub fn star_report(model: &str, keep: f64) -> SimReport {
    let m = ModelConfig::preset(model).unwrap();
    simulate(
        &ltpp_shape(&m, keep),
        &FeatureSet::star(),
        &AccelConfig::default(),
        &DramChannel::accel_256(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig19_speedups_in_paper_band() {
        // Paper: average 6.3×/7.0×/9.2× at 0/1/2% loss. Shape check:
        // monotone in loss budget and within ~2× of the paper's averages.
        let rows = fig19_throughput_vs_gpu();
        let avg = |li: usize| {
            let v: Vec<f64> = rows.iter().filter(|r| r.1 == li).map(|r| r.2).collect();
            geomean(&v)
        };
        let (a0, a1, a2) = (avg(0), avg(1), avg(2));
        assert!(a0 < a1 && a1 < a2, "monotone in loss: {a0} {a1} {a2}");
        assert!((3.0..20.0).contains(&a0), "0% gain {a0}");
        assert!((4.0..25.0).contains(&a2), "2% gain {a2}");
    }

    #[test]
    fn fig20_every_step_helps() {
        let rows = fig20_gain_breakdown();
        for w in rows.windows(2) {
            assert!(
                w[1].1 > w[0].1 * 0.98,
                "{} ({}) should not regress from {} ({})",
                w[1].0,
                w[1].1,
                w[0].0,
                w[0].1
            );
        }
        // Dense ASIC ≈ 1.5× over GPU; full STAR ≈ 10× (paper's chain).
        assert!((0.8..3.0).contains(&rows[0].1), "dense ASIC {}", rows[0].1);
        assert!(rows.last().unwrap().1 > 4.0, "full STAR {}", rows.last().unwrap().1);
    }

    #[test]
    fn fig21_matches_paper_totals() {
        let rows = fig21_area_power();
        let area: f64 = rows.iter().map(|r| r.1).sum();
        let power: f64 = rows.iter().map(|r| r.2).sum();
        assert!((area - 5.69).abs() < 0.05, "area {area}");
        assert!((power - 949.85).abs() < 5.0, "power {power}");
    }

    #[test]
    fn fig22_reductions_and_gains() {
        let ((rass, full), gains) = fig22_memory_energy();
        // Paper: 23% with RASS, 79% with SU-FA + tiled dataflow.
        assert!(rass > 0.05, "RASS reduction {rass}");
        assert!(full > 0.35, "full reduction {full}");
        assert!(full > rass);
        // Paper: 49.8×/51.6×/71.2× energy-efficiency gains.
        assert!(gains[0] > 15.0, "gain@0% {}", gains[0]);
        assert!(gains[2] > gains[0], "gains rise with sparsity");
    }

    #[test]
    fn fig23a_star_saturates_baseline_stays_bound() {
        let rows = fig23a_sram_single_core();
        let star316 = rows.iter().find(|r| r.0 == 316).unwrap().1;
        let star512 = rows.iter().find(|r| r.0 == 512).unwrap().1;
        assert!((star512 - star316).abs() / star512 < 0.05, "STAR saturates by 316 kB");
        // Baseline below STAR everywhere.
        for (kb, star, base) in &rows {
            assert!(star > base, "kb={kb}: star {star} !> base {base}");
        }
    }

    #[test]
    fn table3_measured_star_near_published() {
        let (gops, gops_w) = table3_comparison();
        // Published: 24423 GOPS / 7183 GOPS/W. Accept a 2.5× band.
        assert!((10_000.0..60_000.0).contains(&gops), "GOPS {gops}");
        assert!((2_800.0..18_000.0).contains(&gops_w), "GOPS/W {gops_w}");
    }
}
