//! Algorithm-performance figures: Fig. 9, 11, 16, 17, 18 and Table II.
//!
//! Every figure/table that exercises the predict → top-k → KV-gen →
//! formal sequence runs it through [`SparseAttentionPipeline`] — the
//! harness configures stages, it no longer hand-wires them.

use super::{f, header, row};
use crate::arith::{EquivWeights, OpCounter};
use crate::attention::{dense_attention, AttnInputs};
use crate::config::{ModelConfig, SparsityConfig};
use crate::pipeline::{PipelineConfig, PipelineInputs, SparseAttentionPipeline};
use crate::sim::pipeline::{FormalKind, PredictKind, TopkKind};
use crate::sparsity::distribution::TypeMix;
use crate::sparsity::{hit_rate, DistType};
use crate::tensor::{topk_indices, Mat};
use crate::util::stats::geomean;
use crate::util::Rng;
use crate::workload::{AttnWorkload, ScoreGen, TypeMixSpec};

/// Fig. 9: Type I/II/III shares measured on generated score rows per
/// model family. Returns (family, [share_I, share_II, share_III]).
pub fn fig9_distribution_mix() -> Vec<(String, [f64; 3])> {
    header("Fig. 9 — attention row-distribution taxonomy");
    let mut rng = Rng::new(9);
    let mut out = Vec::new();
    row("family", &["Type I".into(), "Type II".into(), "Type III".into()]);
    for (family, spec) in [
        ("decoder (GPT/LLaMA/ViT)", TypeMixSpec::decoder()),
        ("encoder (BERT)", TypeMixSpec::encoder()),
        ("average", TypeMixSpec::average()),
    ] {
        let gen = ScoreGen { mix: spec, ..Default::default() };
        let rows: Vec<Vec<f32>> = gen.rows(512, 1024, &mut rng);
        let mix = TypeMix::of(&rows, &gen.classify_params());
        let shares = [mix.type1, mix.type2, mix.type3];
        row(
            family,
            &[
                format!("{:>8.1}%", 100.0 * shares[0]),
                format!("{:>8.1}%", 100.0 * shares[1]),
                format!("{:>8.1}%", 100.0 * shares[2]),
            ],
        );
        out.push((family.to_string(), shares));
    }
    out
}

/// Fig. 11: multiplication/exponential counts of ascend vs descend
/// updating. Returns (order, mul, exp) for an 8k-token selection. The
/// pipeline runs with oracle scores (`PredictKind::None`) so the
/// selection is the true top-25%, exactly the figure's setup.
pub fn fig11_update_orders() -> Vec<(&'static str, u64, u64)> {
    header("Fig. 11 — SU-FA update orders (S=8192, keep 25%)");
    let mut rng = Rng::new(11);
    let (t, s, d) = (16usize, 8192usize, 64usize);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let mut out = Vec::new();
    row("order", &["mul".into(), "exp".into(), "cmp".into()]);
    for (name, formal) in
        [("descend", FormalKind::SufaDescend), ("ascend", FormalKind::SufaAscend)]
    {
        let cfg = PipelineConfig {
            predict: PredictKind::None,
            topk: TopkKind::Vanilla,
            formal,
            ..PipelineConfig::star().with_keep(0.25)
        };
        let r = SparseAttentionPipeline::new(cfg).run(&PipelineInputs::qkv(&q, &k, &v));
        let c = &r.ops.formal;
        row(name, &[f(c.mul as f64), f(c.exp as f64), f(c.cmp as f64)]);
        out.push((name, c.mul, c.exp));
    }
    out
}

/// Fig. 16: computation reduction by the LP (sparsity prediction)
/// mechanism under 0/1/2% loss budgets. The loss budget maps to the
/// keep ratio (standard/1%/aggressive). Returns per-task rows:
/// (task, loss%, attn_reduction, attn_plus_qkv_reduction).
pub fn fig16_lp_reduction() -> Vec<(String, usize, f64, f64)> {
    header("Fig. 16 — computation reduction by LP (vs dense)");
    // Keep ratios calibrated per loss budget: text tasks are sparser
    // than vision (TakeAway2).
    let tasks: [(&str, [f64; 3]); 5] = [
        ("sst2 (text cls)", [0.09, 0.05, 0.025]),
        ("stsb (text sim)", [0.10, 0.06, 0.03]),
        ("wikitext (lm)", [0.20, 0.12, 0.06]),
        ("squad (qa)", [0.16, 0.10, 0.05]),
        ("imagenet (vision)", [0.28, 0.17, 0.09]),
    ];
    let mut out = Vec::new();
    row("task", &["loss".into(), "attn reduc".into(), "attn+qkv".into()]);
    for (task, keeps) in tasks {
        for (li, keep) in keeps.iter().enumerate() {
            // Attention reduction ≈ (1 − keep) on score+AV work, minus the
            // prediction overhead (DLZS is shift-only: ≈2% of dense work).
            let attn_red = (1.0 - keep) - 0.02;
            // QKV side: on-demand generation keeps union ≈ 1.5·keep rows.
            let union = (1.5 * keep).min(1.0);
            let qkv_red = 1.0 - union;
            // Weighted whole-module reduction (attention-heavy at S=1024).
            let both = 0.6 * attn_red + 0.4 * qkv_red;
            if li == 1 {
                row(task, &[format!("{li}%"), f(attn_red), f(both)]);
            }
            out.push((task.to_string(), li, attn_red, both));
        }
    }
    // Summary means per loss budget (the paper's headline numbers).
    for li in 0..3 {
        let attn: Vec<f64> = out.iter().filter(|r| r.1 == li).map(|r| r.2).collect();
        let both: Vec<f64> = out.iter().filter(|r| r.1 == li).map(|r| r.3).collect();
        row(
            &format!("mean @{li}% loss"),
            &[
                format!("{:>8.1}%", 100.0 * crate::util::stats::mean(&attn)),
                format!("{:>8.1}%", 100.0 * crate::util::stats::mean(&both)),
            ],
        );
    }
    out
}

/// Fig. 17: layer-wise top-k hit rates for SLZS vs DLZS on GPT-2-shaped
/// workloads. Returns (scheme, layer, topk_pct, hit_rate).
pub fn fig17_hit_rates() -> Vec<(&'static str, usize, usize, f64)> {
    header("Fig. 17 — predicted vs true top-k hit rates (GPT-2 shapes)");
    let model = ModelConfig::preset("gpt2").unwrap();
    let mut out = Vec::new();
    row("scheme/layer", &["top-20%".into(), "top-10%".into(), "top-5%".into()]);
    for predict in [PredictKind::Slzs, PredictKind::DlzsCross] {
        let name = match predict {
            PredictKind::Slzs => "SLZS",
            _ => "DLZS",
        };
        for layer in [0usize, 5, 11] {
            // Deeper layers have sharper score distributions (the paper's
            // explanation for rising hit rates with depth).
            let sigma = 1.0 + 0.15 * layer as f32;
            let mut rng = Rng::new(17 + layer as u64);
            let wl = AttnWorkload::generate(&model, 256, 64, &mut rng);
            let q = scale(&wl.q, sigma);
            let exact = q.matmul(&wl.k.transpose());
            // One pipeline run at the widest keep: vanilla selections come
            // back in descending estimated-score order, so the top-10%/5%
            // selections are exact prefixes of the top-20% one.
            let cfg = PipelineConfig {
                predict,
                topk: TopkKind::Vanilla,
                ..PipelineConfig::star().with_keep(0.20)
            };
            let r = SparseAttentionPipeline::new(cfg)
                .run(&PipelineInputs::qkv(&q, &wl.k, &wl.v));
            let s = exact.cols;
            let mut cells = Vec::new();
            for pct in [20usize, 10, 5] {
                let keep = ((s as f64 * pct as f64 / 100.0).round() as usize).clamp(1, r.keep);
                let hr = (0..exact.rows)
                    .map(|i| {
                        hit_rate(&r.selection.rows[i][..keep], &topk_indices(exact.row(i), keep))
                    })
                    .sum::<f64>()
                    / exact.rows as f64;
                cells.push(format!("{:>8.1}%", 100.0 * hr));
                out.push((name, layer, pct, hr));
            }
            row(&format!("{name} L{layer}"), &cells);
        }
    }
    out
}

fn scale(m: &Mat, s: f32) -> Mat {
    let mut out = m.clone();
    out.scale(s);
    out
}

/// Fig. 18(a): complexity reduction of DLZS, +SADS, +SU-FA over the DS
/// baseline (4-bit mul + vanilla sort + FA-2), in equivalent adds.
/// Fig. 18(b): accuracy-proxy vs reduced-complexity trade-off over γ.
/// Returns the (a) part: (config, equiv_adds, reduction_vs_baseline).
pub fn fig18_ablation() -> Vec<(String, f64, f64)> {
    header("Fig. 18(a) — complexity reduction from DLZS / SADS / SU-FA");
    let ew = EquivWeights::default();
    let mut rng = Rng::new(18);
    let (t, s, d) = (64usize, 1024usize, 64usize);

    // Shared true attention inputs.
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);

    // Each ablation point is one pipeline configuration; the equivalent-
    // adds come from the pipeline's per-stage counters.
    let count = |dlzs: bool, sads: bool, sufa: bool| -> f64 {
        let cfg = PipelineConfig {
            predict: if dlzs { PredictKind::DlzsCross } else { PredictKind::LowBitMul },
            topk: if sads { TopkKind::Sads } else { TopkKind::Vanilla },
            formal: if sufa { FormalKind::SufaDescend } else { FormalKind::Flash2 },
            ..PipelineConfig::star().with_keep(0.25)
        };
        SparseAttentionPipeline::new(cfg).run(&inputs).equivalent_adds(&ew)
    };

    let baseline = count(false, false, false);
    let mut out = Vec::new();
    row("config", &["equiv adds".into(), "reduction".into()]);
    for (name, cfg) in [
        ("baseline (4b-mul+sort+FA)", (false, false, false)),
        ("+DLZS", (true, false, false)),
        ("+DLZS+SADS", (true, true, false)),
        ("+DLZS+SADS+SU-FA (STAR)", (true, true, true)),
    ] {
        let adds = count(cfg.0, cfg.1, cfg.2);
        let red = 1.0 - adds / baseline;
        row(name, &[f(adds), format!("{:>8.1}%", 100.0 * red)]);
        out.push((name.to_string(), adds, red));
    }

    header("Fig. 18(b) — accuracy proxy vs reduced complexity over γ");
    row("γ", &["out err".into(), "complexity kept".into()]);
    let inp = AttnInputs::new(&q, &k, &v);
    let mut cd = OpCounter::new();
    let dense = dense_attention(&inp, usize::MAX, &mut cd);
    for gamma in [0.05, 0.1, 0.15, 0.2, 0.3, 0.5] {
        let cfg = PipelineConfig {
            predict: PredictKind::DlzsCross,
            ..PipelineConfig::star().with_keep(gamma)
        };
        let r = SparseAttentionPipeline::new(cfg).run(&inputs);
        let err = r.out.rel_err(&dense);
        let kept = r.equivalent_adds(&ew) / cd.equivalent_adds(&ew);
        row(&format!("{gamma:.2}"), &[f(err as f64), f(kept)]);
    }
    out
}

/// Table II (substitution): the accuracy experiments require hosted
/// LLMs; the proxy is top-k output fidelity — the relative error the
/// sparse selection induces on attention outputs at the standard and
/// aggressive configurations, per model shape. Returns
/// (model, config, rel_err, hit_rate).
pub fn table2_accuracy() -> Vec<(String, &'static str, f64, f64)> {
    header("Table II (proxy) — sparse-output fidelity per model shape");
    let mut out = Vec::new();
    row("model", &["config".into(), "out rel-err".into(), "hit rate".into()]);
    for m in ModelConfig::suite() {
        let mut rng = Rng::new(2);
        let s = m.seq_len.min(512);
        let wl = AttnWorkload::generate(&m, s, 64, &mut rng);
        let inp = AttnInputs::new(&wl.q, &wl.k, &wl.v);
        let mut cd = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut cd);
        // Truth: exact top-k in logit units (the pipeline's estimate is
        // scaled the same way, so the SADS radius is calibrated).
        let mut exact = wl.q.matmul(&wl.k.transpose());
        exact.scale(inp.scale);
        for (cfg_name, cfg) in
            [("standard", SparsityConfig::standard()), ("aggressive", SparsityConfig::aggressive())]
        {
            let pipe = SparseAttentionPipeline::new(PipelineConfig::from_sparsity(&cfg));
            let r = pipe.run(&PipelineInputs::qkv(&wl.q, &wl.k, &wl.v));
            let hr = (0..exact.rows)
                .map(|i| hit_rate(&r.selection.rows[i], &topk_indices(exact.row(i), r.keep)))
                .sum::<f64>()
                / exact.rows as f64;
            let err = r.out.rel_err(&dense) as f64;
            row(&m.name, &[cfg_name.into(), f(err), format!("{:>8.1}%", 100.0 * hr)]);
            out.push((m.name.clone(), cfg_name, err, hr));
        }
    }
    let errs: Vec<f64> = out.iter().map(|r| r.2.max(1e-6)).collect();
    row("geomean err", &[f(geomean(&errs))]);
    out
}

/// Which distribution types SADS handles well (used by docs/tests).
pub fn sads_friendly(ty: DistType) -> bool {
    matches!(ty, DistType::TypeI | DistType::TypeII)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_type2_dominates_and_type3_rare() {
        let rows = fig9_distribution_mix();
        for (_, shares) in &rows {
            assert!(shares[1] > 0.5, "Type II should dominate: {shares:?}");
            assert!(shares[2] < 0.15, "Type III should be rare: {shares:?}");
        }
        // Decoder families show more Type I than encoder (22% vs 12%).
        let dec = rows[0].1[0];
        let enc = rows[1].1[0];
        assert!(dec > enc, "decoder Type I {dec} !> encoder {enc}");
    }

    #[test]
    fn fig11_descend_saves_muls() {
        let rows = fig11_update_orders();
        let desc = rows.iter().find(|r| r.0 == "descend").unwrap();
        let asc = rows.iter().find(|r| r.0 == "ascend").unwrap();
        // Paper: ascend pays ~2.1e6 extra muls at 8k tokens (per batch).
        assert!(asc.1 > desc.1, "ascend muls {} !> descend {}", asc.1, desc.1);
        assert!(asc.2 >= desc.2, "ascend exps should not be fewer");
    }

    #[test]
    fn fig16_reductions_match_headlines() {
        let rows = fig16_lp_reduction();
        // Paper: attention reduction 81.3/87.7/92.6% at 0/1/2% loss.
        for (li, want) in [(0usize, 0.813), (1, 0.877), (2, 0.926)] {
            let vals: Vec<f64> = rows.iter().filter(|r| r.1 == li).map(|r| r.2).collect();
            let got = crate::util::stats::mean(&vals);
            assert!((got - want).abs() < 0.08, "@{li}%: {got} vs paper {want}");
        }
        // Text tasks achieve >90% reduction at 1% loss; vision less.
        let sst = rows.iter().find(|r| r.0.starts_with("sst2") && r.1 == 1).unwrap();
        let img = rows.iter().find(|r| r.0.starts_with("imagenet") && r.1 == 1).unwrap();
        assert!(sst.2 > 0.85 && sst.2 > img.2);
    }

    #[test]
    fn fig17_dlzs_beats_slzs() {
        let rows = fig17_hit_rates();
        let avg = |scheme: &str| {
            let v: Vec<f64> =
                rows.iter().filter(|r| r.0 == scheme).map(|r| r.3).collect();
            crate::util::stats::mean(&v)
        };
        assert!(avg("DLZS") > avg("SLZS"), "DLZS {} !> SLZS {}", avg("DLZS"), avg("SLZS"));
        // Deeper layers hit better for DLZS top-20%.
        let l0 = rows.iter().find(|r| r.0 == "DLZS" && r.1 == 0 && r.2 == 20).unwrap().3;
        let l11 = rows.iter().find(|r| r.0 == "DLZS" && r.1 == 11 && r.2 == 20).unwrap().3;
        assert!(l11 >= l0 - 0.02, "depth trend: L0 {l0} L11 {l11}");
    }

    #[test]
    fn fig18_cumulative_reductions() {
        let rows = fig18_ablation();
        // Reductions must be cumulative and land near the paper's 28%.
        assert!(rows[1].2 > 0.05, "DLZS alone: {}", rows[1].2);
        assert!(rows[2].2 > rows[1].2, "SADS adds on top");
        assert!(rows[3].2 > rows[2].2, "SU-FA adds on top");
        assert!((0.15..0.6).contains(&rows[3].2), "total reduction {}", rows[3].2);
    }

    #[test]
    fn table2_standard_tighter_than_aggressive() {
        let rows = table2_accuracy();
        for m in ["gpt2", "bert-base"] {
            let std =
                rows.iter().find(|r| r.0 == m && r.1 == "standard").unwrap();
            let agg =
                rows.iter().find(|r| r.0 == m && r.1 == "aggressive").unwrap();
            assert!(std.2 <= agg.2 + 0.02, "{m}: std err {} vs agg {}", std.2, agg.2);
            assert!(std.3 > 0.7, "{m} hit rate {}", std.3);
        }
    }
}
