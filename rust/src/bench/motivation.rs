//! Motivation figures: Fig. 1, 3, 4, 5, 7.

use super::{f, header, row};
use crate::arith::{EquivWeights, OpCounter};
use crate::attention::{dense_attention, flash2_attention, AttnInputs, Flash2Params};
use crate::config::{AccelConfig, ModelConfig};
use crate::sim::baselines::Baseline;
use crate::sim::dram::DramChannel;
use crate::sim::pipeline::{simulate, WorkloadShape};
use crate::tensor::Mat;
use crate::util::Rng;

/// Fig. 1: attention memory footprint and compute share vs sequence
/// length (Llama-13B shapes). Returns (S, attn_mem_norm, attn/ffn ops).
pub fn fig1_memory_compute() -> Vec<(usize, f64, f64)> {
    header("Fig. 1 — attention memory & compute growth (Llama-13B shapes)");
    let m = ModelConfig::preset("llama-13b").unwrap();
    let h = m.hidden as f64;
    let base_mem = 512.0 * 512.0; // BERT-era S=512 attention matrix
    let mut out = Vec::new();
    row(
        "S",
        &["mem(norm)".into(), "attn GFLOP".into(), "ffn GFLOP".into(), "attn/ffn+qkv".into()],
    );
    for s in [512usize, 2048, 8192, 16384, 26000, 32768] {
        let sf = s as f64;
        let mem_norm = sf * sf / base_mem;
        // Attention: 4·S²·H ops; FFN (two 4H layers): 16·S·H²; QKV: 8·S·H².
        let attn = 4.0 * sf * sf * h;
        let ffn = 16.0 * sf * h * h;
        let qkv = 8.0 * sf * h * h;
        let ratio = attn / (ffn + qkv);
        row(&format!("{s}"), &[f(mem_norm), f(attn / 1e9), f(ffn / 1e9), f(ratio)]);
        out.push((s, mem_norm, ratio));
    }
    out
}

/// Fig. 3: latency breakdown (MAT share) for FACT/Energon vs token
/// parallelism. Returns (name, tp, mat_fraction).
pub fn fig3_mat_breakdown() -> Vec<(&'static str, usize, f64)> {
    header("Fig. 3 — MAT share of latency for SOTA DS accelerators vs TP");
    let dram = DramChannel::ddr4();
    let mut out = Vec::new();
    row("accel/TP", &["64".into(), "128".into(), "256".into(), "512".into()]);
    for b in [Baseline::Fact, Baseline::Energon] {
        let mut cells = Vec::new();
        for tp in [64usize, 128, 256, 512] {
            let r = simulate(
                &WorkloadShape::new(tp, 2048, 64, 768, 0.25),
                &b.features(),
                &b.config(),
                &dram,
            );
            cells.push(format!("{:>8.1}%", 100.0 * r.mat_fraction()));
            out.push((b.name(), tp, r.mat_fraction()));
        }
        row(b.name(), &cells);
    }
    out
}

/// Fig. 4: operation intensity (ops/byte) of FFN vs MHA, and MHA's OI
/// growth with token parallelism. Returns (label, oi).
pub fn fig4_operation_intensity() -> Vec<(String, f64)> {
    header("Fig. 4 — operation intensity (ops/byte, INT16)");
    let m = ModelConfig::preset("gpt2").unwrap();
    let (h, s) = (m.hidden as f64, m.seq_len as f64);
    let e = 2.0;
    let mut out = Vec::new();
    // FFN: 16·S·H² ops over (weights 8H² + acts ~10·S·H) bytes.
    let ffn_oi = 16.0 * s * h * h / ((8.0 * h * h + 10.0 * s * h) * e);
    out.push(("FFN".to_string(), ffn_oi));
    // MHA at TP=1 (decode): 4·S·H ops over K+V bytes.
    for tp in [1usize, 16, 64, 256] {
        let t = tp as f64;
        let ops = 4.0 * t * s * h;
        let bytes = (2.0 * s * h + 2.0 * t * h) * e; // K,V + Q,O
        out.push((format!("MHA TP={tp}"), ops / bytes));
    }
    for (label, oi) in &out {
        row(label, &[f(*oi)]);
    }
    assert!(out[0].1 > out[1].1, "FFN OI should exceed MHA at TP=1");
    out
}

/// Fig. 5: FA-2's extra exponentiations/comparisons vs the vanilla
/// baseline, by sequence length (B_c = 16). Returns
/// (S, extra_exp, extra_cmp, extra_equiv_adds).
pub fn fig5_fa2_overhead() -> Vec<(usize, u64, u64, f64)> {
    header("Fig. 5 — FlashAttention-2 overhead vs vanilla (Bc=16)");
    let ew = EquivWeights::default();
    let mut rng = Rng::new(5);
    let mut out = Vec::new();
    row("S", &["extra exp".into(), "extra cmp".into(), "extra equiv-adds".into()]);
    for s in [256usize, 512, 1024, 2048] {
        let d = 64;
        let q = Mat::randn(s, d, 1.0, &mut rng);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut cv = OpCounter::new();
        let o_ref = dense_attention(&inp, usize::MAX, &mut cv);
        let mut cf = OpCounter::new();
        let p = Flash2Params { bc: 16, count_rescale_as_exp: true, ..Default::default() };
        let o_fa = flash2_attention(&inp, &p, &mut cf);
        assert!(o_fa.max_abs_diff(&o_ref) < 1e-3, "FA2 must be exact");
        let extra_exp = cf.exp.saturating_sub(cv.exp);
        let extra_cmp = cf.cmp.saturating_sub(cv.cmp);
        let extra = cf.equivalent_adds(&ew) - cv.equivalent_adds(&ew);
        row(&format!("{s}"), &[f(extra_exp as f64), f(extra_cmp as f64), f(extra)]);
        out.push((s, extra_exp, extra_cmp, extra));
    }
    out
}

/// Fig. 7: QKV-generation vs attention computation crossover. Returns
/// (model, crossover S).
pub fn fig7_qkv_crossover() -> Vec<(String, usize)> {
    header("Fig. 7 — QKV vs attention crossover sequence length");
    let mut out = Vec::new();
    for name in ["bloom-1b7", "opt-6b7"] {
        let m = ModelConfig::preset(name).unwrap();
        let h = m.hidden as f64;
        // QKV: 6·S·H²; attention: 4·S²·H ⇒ crossover at S = 1.5·H.
        let mut cross = 0usize;
        for s in (256..=8192).step_by(64) {
            let qkv = 6.0 * s as f64 * h * h;
            let attn = 4.0 * (s as f64) * (s as f64) * h;
            if attn > qkv {
                cross = s;
                break;
            }
        }
        row(name, &[format!("{cross} tokens")]);
        out.push((name.to_string(), cross));
    }
    // Paper: Bloom-1B7 ≈ 2k, OPT-6B7 ≈ 4k.
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_attention_share_grows_and_crosses_over() {
        // Under standard FLOP accounting (attn 4S²H vs QKV+FFN 24SH²) the
        // crossover sits at S = 6H; the paper's "13× at 26k" does not
        // close with these formulas (EXPERIMENTS.md §Fig1 discusses).
        let rows = fig1_memory_compute();
        assert!(rows.windows(2).all(|w| w[1].2 > w[0].2), "ratio must grow with S");
        assert!(rows[0].2 < 0.1, "attention negligible at S=512");
        assert!(rows.last().unwrap().2 > 1.0, "attention dominates at 32k");
        // >2000× memory growth vs the 512-token era at 32k+.
        assert!(rows.last().unwrap().1 > 2000.0);
    }

    #[test]
    fn fig3_energon_mat_dominant_at_high_tp() {
        let rows = fig3_mat_breakdown();
        let energon512 = rows.iter().find(|r| r.0 == "Energon" && r.1 == 512).unwrap();
        assert!(energon512.2 > 0.5, "MAT {}", energon512.2);
    }

    #[test]
    fn fig4_mha_oi_grows_with_tp() {
        let rows = fig4_operation_intensity();
        let get = |label: &str| rows.iter().find(|r| r.0 == label).unwrap().1;
        assert!(get("MHA TP=256") > get("MHA TP=16"));
        assert!(get("FFN") > get("MHA TP=1"));
    }

    #[test]
    fn fig5_overhead_grows_with_s() {
        let rows = fig5_fa2_overhead();
        assert!(rows.windows(2).all(|w| w[1].3 > w[0].3), "monotone overhead");
        // Paper: S=2048 ⇒ millions of extra exps.
        let s2048 = rows.iter().find(|r| r.0 == 2048).unwrap();
        assert!(s2048.1 > 1_000_000, "extra exp {}", s2048.1);
    }

    #[test]
    fn fig7_crossovers_match_paper_ballpark() {
        let rows = fig7_qkv_crossover();
        let bloom = rows.iter().find(|r| r.0 == "bloom-1b7").unwrap().1;
        let opt = rows.iter().find(|r| r.0 == "opt-6b7").unwrap().1;
        assert!((2048..=4096).contains(&bloom), "bloom crossover {bloom}");
        assert!((4096..=8192).contains(&opt), "opt crossover {opt}");
    }
}
