//! Spatial-architecture figures: Fig. 23(b) and Fig. 24.

use super::{f, header, row};
use crate::config::SpatialConfig;
use crate::spatial::sim::{spatial_run, CoreKind, Dataflow};
use crate::util::stats::geomean;

const WORKLOADS: [(usize, usize, usize); 3] =
    [(16384, 64, 768), (32768, 64, 768), (16384, 128, 4096)];

/// Fig. 23(b): multi-core throughput vs per-core SRAM under the shared
/// 512 GB/s DRAM, with and without the memory-access optimizations.
/// Returns (kb, opt_tops, base_tops).
pub fn fig23b_sram_multicore() -> Vec<(usize, f64, f64)> {
    header("Fig. 23(b) — SRAM sweep, 5×5 mesh (512 GB/s shared DRAM)");
    let mut out = Vec::new();
    row("SRAM kB", &["DRAttn+MRCA TOPS".into(), "baseline TOPS".into()]);
    for kb in [128usize, 256, 412, 512] {
        let mut cfg = SpatialConfig::mesh5x5();
        cfg.core.sram_bytes = kb * 1024;
        let opt =
            spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, 16384, 64, 768, 0.2);
        let base = spatial_run(
            &cfg,
            CoreKind::StarNoMemOpt,
            Dataflow::RingAttention,
            16384,
            64,
            768,
            0.2,
        );
        row(&format!("{kb}"), &[f(opt.eff_tops()), f(base.eff_tops())]);
        out.push((kb, opt.eff_tops(), base.eff_tops()));
    }
    out
}

/// Fig. 24: (a)(b) DRAttention/MRCA ablation on 5×5 and 6×6; (c)(d)
/// lateral comparison of compute units. Returns, per mesh:
/// (mesh, dra_gain, mrca_gain_total, spatten_gain, star_gain).
pub fn fig24_spatial() -> Vec<(String, f64, f64, f64, f64)> {
    let mut out = Vec::new();
    for (mesh_name, cfg) in
        [("5x5", SpatialConfig::mesh5x5()), ("6x6", SpatialConfig::mesh6x6())]
    {
        header(&format!("Fig. 24 — {mesh_name} mesh"));
        let mut dra_gains = Vec::new();
        let mut full_gains = Vec::new();
        let mut spatten_gains = Vec::new();
        let mut star_gains = Vec::new();
        row("workload", &["DRAttn".into(), "+MRCA".into(), "SpAtten".into(), "STAR".into()]);
        for (s, d, h) in WORKLOADS {
            // (a)(b): dataflow ablation with STAR cores.
            let base =
                spatial_run(&cfg, CoreKind::Star, Dataflow::RingAttention, s, d, h, 0.2);
            let dra =
                spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionNaive, s, d, h, 0.2);
            let full =
                spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, d, h, 0.2);
            let dra_gain = base.total_s / dra.total_s;
            let full_gain = base.total_s / full.total_s;
            // (c)(d): lateral comparison, Spatial-Simba as the baseline.
            let simba =
                spatial_run(&cfg, CoreKind::Simba, Dataflow::RingAttention, s, d, h, 0.2);
            let spatten =
                spatial_run(&cfg, CoreKind::Spatten, Dataflow::RingAttention, s, d, h, 0.2);
            let spatten_gain = simba.total_s / spatten.total_s;
            let star_gain = simba.total_s / full.total_s;
            row(
                &format!("S={s} d={d} H={h}"),
                &[
                    format!("{dra_gain:>7.2}x"),
                    format!("{full_gain:>7.2}x"),
                    format!("{spatten_gain:>7.2}x"),
                    format!("{star_gain:>7.2}x"),
                ],
            );
            dra_gains.push(dra_gain);
            full_gains.push(full_gain);
            spatten_gains.push(spatten_gain);
            star_gains.push(star_gain);
        }
        let (dg, fg, sg, tg) = (
            geomean(&dra_gains),
            geomean(&full_gains),
            geomean(&spatten_gains),
            geomean(&star_gains),
        );
        row(
            "geomean",
            &[
                format!("{dg:>7.2}x"),
                format!("{fg:>7.2}x"),
                format!("{sg:>7.2}x"),
                format!("{tg:>7.2}x"),
            ],
        );
        out.push((mesh_name.to_string(), dg, fg, sg, tg));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig23b_opt_beats_baseline_everywhere() {
        let rows = fig23b_sram_multicore();
        for (kb, opt, base) in &rows {
            assert!(opt > base, "kb={kb}: {opt} !> {base}");
        }
        // Paper at 412 kB: baseline ~3 TOPS vs 24.1 TOPS (12×). Accept
        // the ordering plus a ≥3× margin.
        let at412 = rows.iter().find(|r| r.0 == 412).unwrap();
        assert!(at412.1 / at412.2 > 3.0, "gain {}", at412.1 / at412.2);
    }

    #[test]
    fn fig24_orderings_hold() {
        let rows = fig24_spatial();
        for (mesh, dra, full, spatten, star) in &rows {
            assert!(*dra > 1.0, "{mesh}: DRAttention gain {dra}");
            assert!(full > dra, "{mesh}: MRCA should add on top");
            assert!(*spatten > 1.0, "{mesh}: SpAtten gain {spatten}");
            assert!(star > spatten, "{mesh}: STAR {star} !> SpAtten {spatten}");
            // Paper: Spatial-STAR 20.1× (5×5) / 22.8× (6×6); shape check.
            assert!(*star > 4.0, "{mesh}: STAR lateral gain {star}");
        }
    }
}
