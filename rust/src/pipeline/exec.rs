//! [`SparseAttentionPipeline`] — tiled, parallel execution of
//! predict → top-k → KV-generation → formal compute.
//!
//! The paper's thesis is that the four stages must interact *tile by
//! tile*: for each query tile (B_r = [`PipelineConfig::tile_t`] rows) the
//! pipeline estimates that tile's scores, selects its vital keys, takes
//! the union of selected KV rows for on-demand generation, and runs SU-FA
//! — so intermediates stay `tile_t × S` instead of materializing the full
//! `T × S` estimate (the row-dependency spill of Sec. III-A(2)).
//!
//! Tiles are independent, so they run in parallel under
//! `std::thread::scope`. Prediction operands are prepared **once**
//! ([`crate::sparsity::PreparedPredict`]) with globally-chosen
//! quantization scales, which makes tiled execution bit-identical to
//! stage-serial execution for every tile size and thread count.
//!
//! The stage bodies themselves live in the shared tile-execution core
//! ([`super::engine`]): this module is the batch/decode *driver* —
//! prologue, tile scheduling and merge — while the engine's
//! `TileExecutor` runs each tile inside a pooled, preallocated
//! [`super::engine::TileWorkspace`]. Pass your own [`WorkspacePool`]
//! (the `*_pooled` entry points) to reuse warm workspaces across
//! requests; the plain entry points run on a throwaway pool.

use super::config::PipelineConfig;
use super::engine::{
    parallel_tiles_pooled, prepare_score_source, DecodeRowOut, ScoreSource, ShapeClass, TileCtx,
    TileExecutor, TileOut, WorkspacePool,
};
use super::report::{StageOps, StageTiming};
use crate::arith::{EquivWeights, OpCounter};
use crate::attention::Selection;
use crate::kvcache::{CacheStats, KvPage, ResidencySnapshot, SessionStore};
use crate::obs::traffic::{self, SchedStats, TrafficCounter};
use crate::sim::pipeline::PredictKind;
use crate::tensor::Mat;
use crate::workload::AttnWorkload;
use std::time::Instant;

/// Inputs to one pipeline run. `q`/`k`/`v` are always required (the
/// numerical oracle KV); `x`/`wk`/`wv` additionally enable cross-phase
/// prediction straight from the activations and on-demand KV generation
/// accounting, exactly as the STAR datapath works.
#[derive(Clone, Debug)]
pub struct PipelineInputs<'a> {
    /// Query rows `[T, d]`.
    pub q: &'a Mat,
    /// Key rows `[S, d]`.
    pub k: &'a Mat,
    /// Value rows `[S, d]`.
    pub v: &'a Mat,
    /// Input activations X `[S, H]`.
    pub x: Option<&'a Mat>,
    /// Key projection W_k `[H, d]` (pre-converted to LZ format offline).
    pub wk: Option<&'a Mat>,
    /// Value projection W_v `[H, d]`.
    pub wv: Option<&'a Mat>,
    /// Logit scale, normally 1/√d.
    pub scale: f32,
}

impl<'a> PipelineInputs<'a> {
    /// Plain Q/K/V inputs (prediction runs on Q·Kᵀ; KV counts as
    /// precomputed).
    pub fn qkv(q: &'a Mat, k: &'a Mat, v: &'a Mat) -> PipelineInputs<'a> {
        assert_eq!(q.cols, k.cols, "Q/K head-dim mismatch");
        assert_eq!(k.rows, v.rows, "K/V length mismatch");
        assert_eq!(k.cols, v.cols, "K/V head-dim mismatch (MHA layout)");
        let scale = 1.0 / (q.cols as f32).sqrt();
        PipelineInputs { q, k, v, x: None, wk: None, wv: None, scale }
    }

    /// Full workload inputs: enables cross-phase prediction from X and
    /// on-demand KV generation.
    pub fn from_workload(wl: &'a AttnWorkload) -> PipelineInputs<'a> {
        let mut inp = PipelineInputs::qkv(&wl.q, &wl.k, &wl.v);
        assert_eq!(wl.x.rows, wl.k.rows, "X/K length mismatch");
        assert_eq!(wl.x.cols, wl.wk.rows, "X/W_k inner-dim mismatch");
        assert_eq!(wl.wk.cols, wl.k.cols, "W_k/K head-dim mismatch");
        inp.x = Some(&wl.x);
        inp.wk = Some(&wl.wk);
        inp.wv = Some(&wl.wv);
        inp
    }

    /// Query rows T.
    pub fn t(&self) -> usize {
        self.q.rows
    }

    /// Context length S.
    pub fn s(&self) -> usize {
        self.k.rows
    }

    /// Head dimension d.
    pub fn d(&self) -> usize {
        self.q.cols
    }
}

/// Result of a pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Attention output `[T, d]`.
    pub out: Mat,
    /// Per-row key selections actually used (rows in the order the formal
    /// stage consumed them).
    pub selection: Selection,
    /// Per-stage operation counters.
    pub ops: StageOps,
    /// Per-stage busy times.
    pub timing: StageTiming,
    /// End-to-end wall time of the run, seconds.
    pub wall_s: f64,
    /// SU-FA max-misprediction recoveries.
    pub stalls: u64,
    /// KV rows generated/loaded, summed per tile (a key regenerates once
    /// per query tile that selects it — the cost of keeping intermediates
    /// tile-sized).
    pub union_rows: usize,
    /// Mean SADS survivor fraction ρ (0 when SADS did not run).
    pub rho_mean: f64,
    /// Query tiles executed.
    pub tiles: usize,
    /// Keys kept per row.
    pub keep: usize,
    /// Heap allocations metered inside the tile engine's stage cores
    /// (zero in steady state on a warm [`WorkspacePool`]; non-zero only
    /// while a cold workspace grows to its shape class — see
    /// [`super::engine`]). Always zero when no counting allocator is
    /// installed ([`crate::util::allocmeter`]).
    pub hot_path_allocs: u64,
    /// Peak per-worker [`super::engine::TileWorkspace`] heap capacity
    /// during this run, bytes — the software working set to compare
    /// against the modeled SRAM budget
    /// ([`crate::sim::sram::Sram::STAR_BUDGET_BYTES`]).
    pub workspace_bytes: usize,
    /// Measured byte-level traffic for the run (all fields zero unless
    /// [`crate::obs::traffic::set_enabled`] turned counting on). Merged
    /// across workers; order-independent, so identical at every thread
    /// count.
    pub traffic: TrafficCounter,
    /// Work-stealing scheduler statistics for the run's tile section.
    pub sched: SchedStats,
}

impl PipelineReport {
    /// All stage counters folded together.
    pub fn total_ops(&self) -> OpCounter {
        self.ops.total()
    }

    /// Equivalent additions of the whole run.
    pub fn equivalent_adds(&self, w: &EquivWeights) -> f64 {
        self.ops.equivalent_adds(w)
    }

    /// Selection density relative to dense `T × S` attention.
    pub fn density(&self, s: usize) -> f64 {
        self.selection.density(s)
    }
}

/// The composed four-stage pipeline. Construct once, run on many inputs.
///
/// ```
/// use star::pipeline::{PipelineInputs, SparseAttentionPipeline};
/// use star::tensor::Mat;
/// use star::util::Rng;
///
/// let mut rng = Rng::new(1);
/// let (q, k, v) = (
///     Mat::randn(8, 16, 1.0, &mut rng),
///     Mat::randn(64, 16, 1.0, &mut rng),
///     Mat::randn(64, 16, 1.0, &mut rng),
/// );
/// // The paper's STAR stack (DLZS → SADS → on-demand KV → SU-FA) at keep 25%.
/// let report = SparseAttentionPipeline::star(0.25).run(&PipelineInputs::qkv(&q, &k, &v));
/// assert_eq!((report.out.rows, report.out.cols), (8, 16));
/// assert_eq!(report.keep, 16);
/// assert!(report.density(64) <= 0.25 + 1e-9);
/// assert!(report.ops.predict.shift > 0, "DLZS prediction is multiplier-free");
/// ```
#[derive(Clone, Debug)]
pub struct SparseAttentionPipeline {
    cfg: PipelineConfig,
}

impl SparseAttentionPipeline {
    /// Build a pipeline; panics on an invalid config (servers use
    /// [`PipelineConfig::validate`] to fail softly instead).
    pub fn new(cfg: PipelineConfig) -> SparseAttentionPipeline {
        if let Err(e) = cfg.validate() {
            panic!("invalid PipelineConfig: {e}");
        }
        SparseAttentionPipeline { cfg }
    }

    /// The paper's STAR configuration at the given keep ratio.
    pub fn star(keep_ratio: f64) -> SparseAttentionPipeline {
        SparseAttentionPipeline::new(PipelineConfig::star().with_keep(keep_ratio))
    }

    /// The configuration this pipeline executes.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Execute the tiled pipeline. Output is deterministic: identical for
    /// every `tile_t` and thread count (see module docs). Runs on a
    /// throwaway [`WorkspacePool`]; serving paths use
    /// [`SparseAttentionPipeline::run_pooled`] to reuse warm workspaces
    /// across requests.
    pub fn run(&self, inp: &PipelineInputs) -> PipelineReport {
        self.run_pooled(inp, &WorkspacePool::new())
    }

    /// [`SparseAttentionPipeline::run`] drawing per-worker
    /// [`super::engine::TileWorkspace`]s from `pool` — bit-identical
    /// outputs, zero hot-path allocations once the pool is warm for this
    /// shape class.
    pub fn run_pooled(&self, inp: &PipelineInputs, pool: &WorkspacePool) -> PipelineReport {
        let started = Instant::now();
        let (t, s, d) = (inp.t(), inp.s(), inp.d());
        let keep = self.cfg.keep(s);
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // ---- Prologue (predict stage, once): prepare operands. ----
        let t0 = Instant::now();
        let score = prepare_score_source(&self.cfg, inp, &mut ops.predict);
        let kt = match score {
            ScoreSource::Exact => Some(inp.k.transpose()),
            _ => None,
        };
        // Run-level key ingest: the predict operands stream in ONCE here
        // (f32 host layout), not once per tile — that is the cross-stage
        // tiling win the reconciliation in `star bench traffic` checks.
        let mut run_traffic = TrafficCounter::new();
        if traffic::enabled() {
            run_traffic.key_ingest_bytes += match score {
                ScoreSource::None => 0,
                ScoreSource::Exact => 4 * (s * d) as u64,
                ScoreSource::Prepared(_) => {
                    if self.cfg.predict == PredictKind::DlzsCross && inp.x.is_some() {
                        // Cross-phase: K̂ is derived from X, so the ingest
                        // is the activation matrix `[S, H]`.
                        4 * (s * inp.x.unwrap().cols) as u64
                    } else {
                        4 * (s * d) as u64
                    }
                }
            };
        }
        timing.predict_s += t0.elapsed().as_secs_f64();

        // ---- Tiled parallel section on the shared tile core. ----
        let ntiles = t.div_ceil(self.cfg.tile_t.min(t.max(1)));
        let ctx = TileCtx { cfg: &self.cfg, inp, score: &score, kt: kt.as_ref(), keep };
        let exec = TileExecutor { cfg: &self.cfg };
        let class = ShapeClass::of(&self.cfg, d);
        let (mut tiles, hot_path_allocs, workspace_bytes, tile_traffic, sched) =
            parallel_tiles_pooled(ntiles, self.cfg.threads, pool, class, |ws, ti| {
                exec.prefill_tile(&ctx, ti, ws)
            });
        run_traffic.merge(&tile_traffic);
        tiles.sort_by_key(|tile| tile.lo);

        // ---- Merge. ----
        let mut out = Mat::zeros(t, d);
        let mut sel_rows = Vec::with_capacity(t);
        let mut stalls = 0u64;
        let mut union_rows = 0usize;
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        let n_tiles = tiles.len();
        for tile in tiles {
            for i in 0..tile.out.rows {
                out.row_mut(tile.lo + i).copy_from_slice(tile.out.row(i));
            }
            sel_rows.extend(tile.sel_rows);
            ops.merge(&tile.ops);
            timing.merge(&tile.timing);
            stalls += tile.stalls;
            union_rows += tile.union_rows;
            rho_sum += tile.rho_sum;
            rho_n += tile.rho_n;
        }

        PipelineReport {
            out,
            selection: Selection { rows: sel_rows },
            ops,
            timing,
            wall_s: started.elapsed().as_secs_f64(),
            stalls,
            union_rows,
            rho_mean: if rho_n > 0 { rho_sum / rho_n as f64 } else { 0.0 },
            tiles: n_tiles,
            keep,
            hot_path_allocs,
            workspace_bytes,
            traffic: run_traffic,
            sched,
        }
    }
}

/// Result of one [`SparseAttentionPipeline::decode_step`] (or causal
/// prefill chunk).
#[derive(Clone, Debug)]
pub struct DecodeReport {
    /// Attention outputs for the appended tokens `[chunk, d]`.
    pub out: Mat,
    /// Per-new-row key selections in **absolute** token positions.
    pub selection: Selection,
    /// Global positions of the appended tokens within the session.
    pub positions: std::ops::Range<usize>,
    /// Per-stage operation counters for this step.
    pub ops: StageOps,
    /// Per-stage busy times for this step.
    pub timing: StageTiming,
    /// End-to-end wall time of the step, seconds.
    pub wall_s: f64,
    /// SU-FA max-misprediction recoveries.
    pub stalls: u64,
    /// Cached KV rows read, summed per row's union.
    pub union_rows: usize,
    /// Mean SADS survivor fraction ρ (0 when SADS did not run).
    pub rho_mean: f64,
    /// Keys kept for the last (longest-context) appended row.
    pub keep_last: usize,
    /// Cache hits: distinct pages read by this step's selections,
    /// excluding pages re-materialized by this very step (those are the
    /// misses, reported in `rematerialized_pages`).
    pub page_hits: usize,
    /// Pages rebuilt from history because the session had been evicted.
    pub rematerialized_pages: usize,
    /// Sessions that lost pages (page-granular LRU) to make room for
    /// this step.
    pub evicted_sessions: Vec<u64>,
    /// Store-wide residency after this step: resident vs logical bytes,
    /// shared pages, fully resident sessions.
    pub residency: ResidencySnapshot,
    /// Store-wide lifetime cache counters after this step (pages
    /// evicted/rematerialized/shared, copy-on-write splits, hits).
    pub cache_stats: CacheStats,
    /// Heap allocations metered inside the decode rows' stage cores
    /// (zero in steady state on a warm [`WorkspacePool`]; see
    /// [`super::engine`]).
    pub hot_path_allocs: u64,
    /// Peak per-worker [`super::engine::TileWorkspace`] heap capacity
    /// during this step, bytes.
    pub workspace_bytes: usize,
    /// Measured byte-level traffic for this step (zero unless
    /// [`crate::obs::traffic::set_enabled`] turned counting on).
    pub traffic: TrafficCounter,
    /// Work-stealing scheduler statistics for this step's row tiles.
    pub sched: SchedStats,
}

impl SparseAttentionPipeline {
    /// Causal prefill of a fresh session: row `i` attends keys `0..=i`.
    /// Implemented as one big [`SparseAttentionPipeline::decode_step`]
    /// chunk — which is the point: any chunking of the same tokens
    /// through `decode_step` produces bit-identical outputs and
    /// selections (see `rust/tests/prop_decode_parity.rs`).
    pub fn prefill(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k: &Mat,
        v: &Mat,
    ) -> crate::Result<DecodeReport> {
        anyhow::ensure!(
            store.is_empty(session),
            "prefill into non-empty session {session} (use decode_step to extend it)"
        );
        self.decode_step(store, session, q, k, v)
    }

    /// One autoregressive decode step: append the chunk's K/V rows to
    /// the session's paged cache, then compute causal sparse attention
    /// for each new query row against the whole cached context — DLZS
    /// prediction runs against the *frozen* per-page operands, top-k
    /// selects over the causal prefix, and the formal stage streams the
    /// selected KV rows back out of the cache. Runs on a throwaway
    /// [`WorkspacePool`]; serving paths use
    /// [`SparseAttentionPipeline::decode_step_pooled`].
    pub fn decode_step(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k_new: &Mat,
        v_new: &Mat,
    ) -> crate::Result<DecodeReport> {
        self.decode_step_pooled(store, session, q, k_new, v_new, &WorkspacePool::new())
    }

    /// [`SparseAttentionPipeline::decode_step`] drawing per-worker
    /// [`super::engine::TileWorkspace`]s from `pool` — bit-identical
    /// outputs, zero hot-path allocations once the pool is warm for this
    /// shape class.
    pub fn decode_step_pooled(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k_new: &Mat,
        v_new: &Mat,
        pool: &WorkspacePool,
    ) -> crate::Result<DecodeReport> {
        let started = Instant::now();
        anyhow::ensure!(
            q.rows == k_new.rows && q.rows == v_new.rows,
            "decode chunk rows disagree (Q {}, K {}, V {})",
            q.rows,
            k_new.rows,
            v_new.rows
        );
        anyhow::ensure!(
            q.cols == k_new.cols && q.cols == v_new.cols,
            "decode chunk head dims disagree (Q {}, K {}, V {})",
            q.cols,
            k_new.cols,
            v_new.cols
        );
        anyhow::ensure!(
            q.cols == store.config().d,
            "chunk head dim {} != session store head dim {}",
            q.cols,
            store.config().d
        );
        // The cached key operands were quantized at the store's bitwidth;
        // scoring them at a different W would silently skew prediction.
        anyhow::ensure!(
            self.cfg.predict_bits == store.config().predict_bits,
            "pipeline predict_bits {} != session store predict_bits {}",
            self.cfg.predict_bits,
            store.config().predict_bits
        );
        if let Err(e) = self.cfg.validate() {
            anyhow::bail!("invalid pipeline config: {e}");
        }
        let d = q.cols;
        let scale = 1.0 / (d as f32).sqrt();
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // Append + re-materialize under the KV-gen stage clock.
        let t0 = Instant::now();
        let outcome = store.append(session, k_new, v_new, &mut ops)?;
        timing.kv_gen_s += t0.elapsed().as_secs_f64();

        // Cache-side traffic: the new K/V rows stream in once (and are
        // quantized into frozen page operands), the appended pages are
        // written, and any re-materialized history streams back from
        // host memory.
        let mut run_traffic = TrafficCounter::new();
        if traffic::enabled() {
            run_traffic.key_ingest_bytes += 4 * (k_new.rows * d) as u64;
            run_traffic.cache_append_bytes += 4 * (2 * k_new.rows * d) as u64;
            run_traffic.cache_remat_bytes += 4 * (2 * outcome.rematerialized_tokens * d) as u64;
        }

        let base = outcome.start;
        let rows = q.rows;
        let page_size = store.config().page_size;

        // Causal per-row section on the shared tile core: rows are
        // independent, so they tile and parallelize exactly like `run` —
        // and because every per-row quantity depends only on tokens
        // 0..=pos, the schedule can never change the math.
        let tile = self.cfg.tile_t.min(rows.max(1));
        let ntiles = rows.div_ceil(tile);
        let class = ShapeClass::of(&self.cfg, d);
        let (mut tiles_out, hot_path_allocs, workspace_bytes, tile_traffic, sched): (
            Vec<(usize, Vec<DecodeRowOut>)>,
            u64,
            usize,
            TrafficCounter,
            SchedStats,
        ) = {
            let pages: Vec<&KvPage> = store.pages_of(session);
            let exec = TileExecutor { cfg: &self.cfg };
            parallel_tiles_pooled(ntiles, self.cfg.threads, pool, class, |ws, ti| {
                // Stamp the session into this worker's trace context
                // (outside the metered row cores).
                ws.spans.session = session;
                let lo = ti * tile;
                let hi = (lo + tile).min(rows);
                let outs = (lo..hi)
                    .map(|r| exec.decode_row(&pages, q.row(r), base + r, scale, page_size, ws))
                    .collect();
                (ti, outs)
            })
        };
        run_traffic.merge(&tile_traffic);
        tiles_out.sort_by_key(|(ti, _)| *ti);

        // Merge in row order.
        let mut out = Mat::zeros(rows, d);
        let mut sel_rows = Vec::with_capacity(rows);
        let mut stalls = 0u64;
        let mut union_rows = 0usize;
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        let mut touched: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
        let mut row_i = 0usize;
        for (_, tile_rows) in tiles_out {
            for r in tile_rows {
                out.row_mut(row_i).copy_from_slice(&r.out);
                sel_rows.push(r.sel);
                ops.merge(&r.ops);
                timing.merge(&r.timing);
                stalls += r.stalls;
                union_rows += r.union_rows;
                if let Some(rho) = r.rho {
                    rho_sum += rho;
                    rho_n += 1;
                }
                touched.extend(r.pages.iter().copied());
                row_i += 1;
            }
        }
        // Hits = distinct pages read minus the pages this step had to
        // rebuild (hits and misses in the same per-step page units).
        let page_hits = touched.len().saturating_sub(outcome.rematerialized_pages);
        store.record_hits(page_hits as u64);

        Ok(DecodeReport {
            out,
            selection: Selection { rows: sel_rows },
            positions: base..base + rows,
            ops,
            timing,
            wall_s: started.elapsed().as_secs_f64(),
            stalls,
            union_rows,
            rho_mean: if rho_n > 0 { rho_sum / rho_n as f64 } else { 0.0 },
            keep_last: if base + rows > 0 { self.cfg.keep(base + rows) } else { 0 },
            page_hits,
            rematerialized_pages: outcome.rematerialized_pages,
            evicted_sessions: outcome.evicted_sessions,
            residency: store.residency(),
            cache_stats: store.stats(),
            hot_path_allocs,
            workspace_bytes,
            traffic: run_traffic,
            sched,
        })
    }
}

// The parity contract (dense-oracle equivalence, tiled == untiled,
// masked-oracle exactness) is covered once, in
// `rust/tests/integration_pipeline.rs` — the unit tests here cover only
// the per-stage accounting behaviors not visible from outside.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::pipeline::FormalKind;
    use crate::util::Rng;

    fn workload(t: usize, s: usize, seed: u64) -> AttnWorkload {
        let model = crate::config::ModelConfig::preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        AttnWorkload::generate(&model, s, t, &mut rng)
    }

    #[test]
    fn stage_ops_land_in_their_stages() {
        let wl = workload(16, 64, 4);
        let r = SparseAttentionPipeline::star(0.25).run(&PipelineInputs::from_workload(&wl));
        // DLZS prediction is multiplier-free shift/add work.
        assert!(r.ops.predict.shift > 0);
        assert_eq!(r.ops.predict.mul, 0);
        // SADS is pure comparisons.
        assert!(r.ops.topk.cmp > 0);
        assert_eq!(r.ops.topk.mul, 0);
        // On-demand generation is MAC work.
        assert!(r.ops.kv_gen.mul > 0);
        // Formal compute pays the exponentials.
        assert!(r.ops.formal.exp > 0);
        assert!(r.union_rows > 0);
        assert!(r.tiles >= 1);
        assert!(r.workspace_bytes > 0, "tile cores ran inside a workspace");
    }

    #[test]
    fn on_demand_kv_moves_formal_traffic_on_chip() {
        let wl = workload(16, 96, 5);
        let with = SparseAttentionPipeline::new(PipelineConfig::star().with_keep(0.2))
            .run(&PipelineInputs::from_workload(&wl));
        let without = SparseAttentionPipeline::new(PipelineConfig {
            on_demand_kv: false,
            ..PipelineConfig::star().with_keep(0.2)
        })
        .run(&PipelineInputs::from_workload(&wl));
        // Same selection, same numerics; traffic classified differently.
        assert_eq!(with.out.max_abs_diff(&without.out), 0.0);
        assert!(with.ops.formal.dram_bytes < without.ops.formal.dram_bytes);
        assert_eq!(without.ops.kv_gen.mul, 0);
    }

    #[test]
    fn flash2_formal_costs_more_than_sufa_descend() {
        let wl = workload(16, 128, 6);
        let inputs = PipelineInputs::from_workload(&wl);
        let star = SparseAttentionPipeline::star(0.25).run(&inputs);
        let fa = SparseAttentionPipeline::new(PipelineConfig {
            formal: FormalKind::Flash2,
            ..PipelineConfig::star().with_keep(0.25)
        })
        .run(&inputs);
        assert!(fa.ops.formal.cmp > star.ops.formal.cmp);
        assert!(fa.ops.formal.mul > star.ops.formal.mul);
    }

    #[test]
    fn empty_query_set_is_fine() {
        let wl = workload(8, 32, 7);
        let q = Mat::zeros(0, wl.d());
        let r = SparseAttentionPipeline::star(0.2).run(&PipelineInputs::qkv(&q, &wl.k, &wl.v));
        assert_eq!(r.out.rows, 0);
        assert_eq!(r.selection.rows.len(), 0);
    }

    #[test]
    fn pooled_run_is_bit_identical_and_reuses_workspaces() {
        let wl = workload(24, 96, 8);
        let inputs = PipelineInputs::from_workload(&wl);
        let pipe = SparseAttentionPipeline::new(
            PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1),
        );
        let fresh = pipe.run(&inputs);
        let pool = WorkspacePool::new();
        let warm1 = pipe.run_pooled(&inputs, &pool);
        let warm2 = pipe.run_pooled(&inputs, &pool);
        for r in [&warm1, &warm2] {
            assert_eq!(r.out.max_abs_diff(&fresh.out), 0.0, "pooled output drift");
            assert_eq!(r.selection, fresh.selection, "pooled selection drift");
            assert_eq!(r.stalls, fresh.stalls);
        }
        assert_eq!(pool.resident_workspaces(), 1, "single-thread run pools one workspace");
        assert!(pool.resident_bytes() > 0);
    }

    #[test]
    fn decode_step_is_causal_and_counts_stages() {
        use crate::kvcache::{SessionConfig, SessionStore};
        let mut rng = Rng::new(9);
        let (n, d) = (24usize, 16usize);
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let pipe = SparseAttentionPipeline::new(PipelineConfig::star().with_keep(0.5).with_tile(5));
        let mut store = SessionStore::new(SessionConfig::for_pipeline(pipe.config(), d, 0));
        let r = pipe.prefill(&mut store, 1, &q, &k, &v).unwrap();
        assert_eq!(r.positions, 0..n);
        assert_eq!(r.out.rows, n);
        assert_eq!(r.selection.rows.len(), n);
        for (i, row) in r.selection.rows.iter().enumerate() {
            assert!(!row.is_empty());
            assert!(row.iter().all(|&j| j <= i), "row {i} attends beyond its causal prefix");
        }
        assert!(r.ops.predict.shift > 0, "DLZS prediction ran");
        assert_eq!(r.ops.predict.mul, 0, "DLZS stays multiplier-free");
        assert!(r.ops.topk.cmp > 0 && r.ops.formal.exp > 0);
        assert!(r.page_hits > 0 && r.union_rows > 0);
        // Extending the session continues at position n.
        let q1 = Mat::randn(1, d, 1.0, &mut rng);
        let k1 = Mat::randn(1, d, 1.0, &mut rng);
        let v1 = Mat::randn(1, d, 1.0, &mut rng);
        let r1 = pipe.decode_step(&mut store, 1, &q1, &k1, &v1).unwrap();
        assert_eq!(r1.positions, n..n + 1);
        assert_eq!(r1.keep_last, pipe.config().keep(n + 1));
        assert!(
            pipe.prefill(&mut store, 1, &q1, &k1, &v1).is_err(),
            "prefill must refuse a non-empty session"
        );
    }

    #[test]
    fn decode_outputs_are_exact_softmax_over_their_selections() {
        use crate::attention::{masked_attention_oracle, AttnInputs};
        use crate::kvcache::{SessionConfig, SessionStore};
        let mut rng = Rng::new(10);
        let (n, d) = (32usize, 8usize);
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let pipe = SparseAttentionPipeline::star(0.4);
        let mut store = SessionStore::new(SessionConfig::for_pipeline(pipe.config(), d, 0));
        let r = pipe.prefill(&mut store, 3, &q, &k, &v).unwrap();
        // The selections are absolute positions, so the masked oracle
        // over the full (uncompacted) K/V must reproduce the outputs.
        let inp = AttnInputs::new(&q, &k, &v);
        let oracle = masked_attention_oracle(&inp, &r.selection);
        let err = r.out.max_abs_diff(&oracle);
        assert!(err < 1e-4, "masked-oracle parity err {err}");
    }
}
