//! [`SparseAttentionPipeline`] — tiled, parallel execution of
//! predict → top-k → KV-generation → formal compute.
//!
//! The paper's thesis is that the four stages must interact *tile by
//! tile*: for each query tile (B_r = [`PipelineConfig::tile_t`] rows) the
//! pipeline estimates that tile's scores, selects its vital keys, takes
//! the union of selected KV rows for on-demand generation, and runs SU-FA
//! — so intermediates stay `tile_t × S` instead of materializing the full
//! `T × S` estimate (the row-dependency spill of Sec. III-A(2)).
//!
//! Tiles are independent, so they run in parallel under
//! `std::thread::scope`. Prediction operands are prepared **once**
//! ([`crate::sparsity::PreparedPredict`]) with globally-chosen
//! quantization scales, which makes tiled execution bit-identical to
//! stage-serial execution for every tile size and thread count.

use super::config::PipelineConfig;
use super::report::{StageOps, StageTiming};
use crate::arith::{EquivWeights, OpCounter, OpKind};
use crate::attention::{sufa_attention, AttnInputs, Selection, SufaParams, UpdateOrder};
use crate::kvcache::{gather_rows, score_row, KvPage, QueryOperand, SessionStore};
use crate::sim::pipeline::{FormalKind, PredictKind, TopkKind};
use crate::sparsity::topk::{sads_topk, vanilla_topk};
use crate::sparsity::{PredictScheme, Predictor, PreparedPredict};
use crate::tensor::Mat;
use crate::workload::AttnWorkload;
use std::time::Instant;

/// Inputs to one pipeline run. `q`/`k`/`v` are always required (the
/// numerical oracle KV); `x`/`wk`/`wv` additionally enable cross-phase
/// prediction straight from the activations and on-demand KV generation
/// accounting, exactly as the STAR datapath works.
#[derive(Clone, Debug)]
pub struct PipelineInputs<'a> {
    /// Query rows `[T, d]`.
    pub q: &'a Mat,
    /// Key rows `[S, d]`.
    pub k: &'a Mat,
    /// Value rows `[S, d]`.
    pub v: &'a Mat,
    /// Input activations X `[S, H]`.
    pub x: Option<&'a Mat>,
    /// Key projection W_k `[H, d]` (pre-converted to LZ format offline).
    pub wk: Option<&'a Mat>,
    /// Value projection W_v `[H, d]`.
    pub wv: Option<&'a Mat>,
    /// Logit scale, normally 1/√d.
    pub scale: f32,
}

impl<'a> PipelineInputs<'a> {
    /// Plain Q/K/V inputs (prediction runs on Q·Kᵀ; KV counts as
    /// precomputed).
    pub fn qkv(q: &'a Mat, k: &'a Mat, v: &'a Mat) -> PipelineInputs<'a> {
        assert_eq!(q.cols, k.cols, "Q/K head-dim mismatch");
        assert_eq!(k.rows, v.rows, "K/V length mismatch");
        assert_eq!(k.cols, v.cols, "K/V head-dim mismatch (MHA layout)");
        let scale = 1.0 / (q.cols as f32).sqrt();
        PipelineInputs { q, k, v, x: None, wk: None, wv: None, scale }
    }

    /// Full workload inputs: enables cross-phase prediction from X and
    /// on-demand KV generation.
    pub fn from_workload(wl: &'a AttnWorkload) -> PipelineInputs<'a> {
        let mut inp = PipelineInputs::qkv(&wl.q, &wl.k, &wl.v);
        assert_eq!(wl.x.rows, wl.k.rows, "X/K length mismatch");
        assert_eq!(wl.x.cols, wl.wk.rows, "X/W_k inner-dim mismatch");
        assert_eq!(wl.wk.cols, wl.k.cols, "W_k/K head-dim mismatch");
        inp.x = Some(&wl.x);
        inp.wk = Some(&wl.wk);
        inp.wv = Some(&wl.wv);
        inp
    }

    /// Query rows T.
    pub fn t(&self) -> usize {
        self.q.rows
    }

    /// Context length S.
    pub fn s(&self) -> usize {
        self.k.rows
    }

    /// Head dimension d.
    pub fn d(&self) -> usize {
        self.q.cols
    }
}

/// Result of a pipeline run.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// Attention output `[T, d]`.
    pub out: Mat,
    /// Per-row key selections actually used (rows in the order the formal
    /// stage consumed them).
    pub selection: Selection,
    /// Per-stage operation counters.
    pub ops: StageOps,
    /// Per-stage busy times.
    pub timing: StageTiming,
    /// End-to-end wall time of the run, seconds.
    pub wall_s: f64,
    /// SU-FA max-misprediction recoveries.
    pub stalls: u64,
    /// KV rows generated/loaded, summed per tile (a key regenerates once
    /// per query tile that selects it — the cost of keeping intermediates
    /// tile-sized).
    pub union_rows: usize,
    /// Mean SADS survivor fraction ρ (0 when SADS did not run).
    pub rho_mean: f64,
    /// Query tiles executed.
    pub tiles: usize,
    /// Keys kept per row.
    pub keep: usize,
}

impl PipelineReport {
    /// All stage counters folded together.
    pub fn total_ops(&self) -> OpCounter {
        self.ops.total()
    }

    /// Equivalent additions of the whole run.
    pub fn equivalent_adds(&self, w: &EquivWeights) -> f64 {
        self.ops.equivalent_adds(w)
    }

    /// Selection density relative to dense `T × S` attention.
    pub fn density(&self, s: usize) -> f64 {
        self.selection.density(s)
    }
}

/// How the top-k stage obtains its scores. Shared with the sharded
/// engine ([`super::sharded`]) so both prologues are one code path.
pub(crate) enum ScoreSource {
    /// No scores: selection is the full natural-order key set.
    None,
    /// Oracle: exact Q·Kᵀ (no prediction ops charged).
    Exact,
    /// Counted approximate prediction over prepared operands.
    Prepared(PreparedPredict),
}

/// The predict-stage prologue: prepare operands once, with globally
/// chosen quantization scales. Extracted from [`SparseAttentionPipeline::run`]
/// so the sharded pipeline runs the *identical* preparation — the
/// global-scale contract is what keeps per-shard scoring bit-identical
/// to single-core scoring.
pub(crate) fn prepare_score_source(
    cfg: &PipelineConfig,
    inp: &PipelineInputs,
    c: &mut OpCounter,
) -> ScoreSource {
    // Scores feed the top-k stage only; dense execution (topk = None)
    // selects every key in natural order and skips prediction.
    if cfg.topk == TopkKind::None {
        return ScoreSource::None;
    }
    match cfg.predict {
        PredictKind::None => ScoreSource::Exact,
        PredictKind::DlzsCross => {
            let pred = Predictor::new(PredictScheme::Dlzs, cfg.predict_bits);
            match (inp.x, inp.wk) {
                (Some(x), Some(wk)) => {
                    // Phase 1.1 once; phase 1.2 runs per tile.
                    let khat = pred.khat_phase(x, wk, c);
                    ScoreSource::Prepared(pred.prepare(inp.q, &khat, c))
                }
                // No activations: plain DLZS on (Q, K).
                _ => ScoreSource::Prepared(pred.prepare(inp.q, inp.k, c)),
            }
        }
        PredictKind::Slzs => {
            let pred = Predictor::new(PredictScheme::Slzs, cfg.predict_bits);
            ScoreSource::Prepared(pred.prepare(inp.q, inp.k, c))
        }
        PredictKind::LowBitMul => {
            let pred = Predictor::new(PredictScheme::LowBitMul, cfg.predict_bits);
            ScoreSource::Prepared(pred.prepare(inp.q, inp.k, c))
        }
    }
}

/// Charge on-demand generation of `u` union KV rows from `[u, h]`
/// activations into `d` columns. Shared by the batch tile path and the
/// sharded home phase so the KV-gen accounting can never drift between
/// the two engines.
pub(crate) fn charge_on_demand_kv_gen(c: &mut OpCounter, u: usize, h: usize, d: usize) {
    // Generate K and V rows for the union only: d columns × h MACs
    // each, for two matrices. X rows stream on chip (int8).
    c.tally(OpKind::Mul, 2 * (u * h * d) as u64);
    c.tally(OpKind::Add, 2 * (u * h.saturating_sub(1) * d) as u64);
    c.dram((u * h) as u64);
    c.sram(2 * (2 * u * d) as u64); // generated INT16 KV tile
}

/// Reclassify the formal stage's KV share of DRAM traffic (`u` K+V rows
/// of `d` f32 columns) as on-chip: under cross-stage tiling the formal
/// stage streams just-generated/cached KV out of SRAM, not DRAM (Q and
/// O still move). Shared by the tile, decode-row and sharded home paths.
pub(crate) fn kv_traffic_on_chip(c: &mut OpCounter, u: usize, d: usize) {
    let kv_bytes = 4 * (2 * u * d) as u64;
    c.dram_bytes -= kv_bytes.min(c.dram_bytes);
    c.sram(kv_bytes);
}

/// Shared read-only context for tile workers.
struct TileCtx<'a> {
    cfg: &'a PipelineConfig,
    inp: &'a PipelineInputs<'a>,
    score: &'a ScoreSource,
    /// K pre-transposed for the oracle score path.
    kt: Option<&'a Mat>,
    keep: usize,
}

/// One tile's results, merged after the parallel section.
struct TileOut {
    lo: usize,
    out: Mat,
    sel_rows: Vec<Vec<usize>>,
    ops: StageOps,
    timing: StageTiming,
    stalls: u64,
    union_rows: usize,
    rho_sum: f64,
    rho_n: usize,
}

/// The composed four-stage pipeline. Construct once, run on many inputs.
///
/// ```
/// use star::pipeline::{PipelineInputs, SparseAttentionPipeline};
/// use star::tensor::Mat;
/// use star::util::Rng;
///
/// let mut rng = Rng::new(1);
/// let (q, k, v) = (
///     Mat::randn(8, 16, 1.0, &mut rng),
///     Mat::randn(64, 16, 1.0, &mut rng),
///     Mat::randn(64, 16, 1.0, &mut rng),
/// );
/// // The paper's STAR stack (DLZS → SADS → on-demand KV → SU-FA) at keep 25%.
/// let report = SparseAttentionPipeline::star(0.25).run(&PipelineInputs::qkv(&q, &k, &v));
/// assert_eq!((report.out.rows, report.out.cols), (8, 16));
/// assert_eq!(report.keep, 16);
/// assert!(report.density(64) <= 0.25 + 1e-9);
/// assert!(report.ops.predict.shift > 0, "DLZS prediction is multiplier-free");
/// ```
#[derive(Clone, Debug)]
pub struct SparseAttentionPipeline {
    cfg: PipelineConfig,
}

impl SparseAttentionPipeline {
    /// Build a pipeline; panics on an invalid config (servers use
    /// [`PipelineConfig::validate`] to fail softly instead).
    pub fn new(cfg: PipelineConfig) -> SparseAttentionPipeline {
        if let Err(e) = cfg.validate() {
            panic!("invalid PipelineConfig: {e}");
        }
        SparseAttentionPipeline { cfg }
    }

    /// The paper's STAR configuration at the given keep ratio.
    pub fn star(keep_ratio: f64) -> SparseAttentionPipeline {
        SparseAttentionPipeline::new(PipelineConfig::star().with_keep(keep_ratio))
    }

    /// The configuration this pipeline executes.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Execute the tiled pipeline. Output is deterministic: identical for
    /// every `tile_t` and thread count (see module docs).
    pub fn run(&self, inp: &PipelineInputs) -> PipelineReport {
        let started = Instant::now();
        let (t, s, d) = (inp.t(), inp.s(), inp.d());
        let keep = self.cfg.keep(s);
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // ---- Prologue (predict stage, once): prepare operands. ----
        let t0 = Instant::now();
        let score = prepare_score_source(&self.cfg, inp, &mut ops.predict);
        let kt = match score {
            ScoreSource::Exact => Some(inp.k.transpose()),
            _ => None,
        };
        timing.predict_s += t0.elapsed().as_secs_f64();

        // ---- Tiled parallel section. ----
        let ntiles = t.div_ceil(self.cfg.tile_t.min(t.max(1)));
        let ctx = TileCtx { cfg: &self.cfg, inp, score: &score, kt: kt.as_ref(), keep };
        let mut tiles: Vec<TileOut> =
            parallel_tiles(ntiles, self.cfg.threads, |ti| run_tile(&ctx, ti));
        tiles.sort_by_key(|tile| tile.lo);

        // ---- Merge. ----
        let mut out = Mat::zeros(t, d);
        let mut sel_rows = Vec::with_capacity(t);
        let mut stalls = 0u64;
        let mut union_rows = 0usize;
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        let n_tiles = tiles.len();
        for tile in tiles {
            for i in 0..tile.out.rows {
                out.row_mut(tile.lo + i).copy_from_slice(tile.out.row(i));
            }
            sel_rows.extend(tile.sel_rows);
            ops.merge(&tile.ops);
            timing.merge(&tile.timing);
            stalls += tile.stalls;
            union_rows += tile.union_rows;
            rho_sum += tile.rho_sum;
            rho_n += tile.rho_n;
        }

        PipelineReport {
            out,
            selection: Selection { rows: sel_rows },
            ops,
            timing,
            wall_s: started.elapsed().as_secs_f64(),
            stalls,
            union_rows,
            rho_mean: if rho_n > 0 { rho_sum / rho_n as f64 } else { 0.0 },
            tiles: n_tiles,
            keep,
        }
    }
}

/// Result of one [`SparseAttentionPipeline::decode_step`] (or causal
/// prefill chunk).
#[derive(Clone, Debug)]
pub struct DecodeReport {
    /// Attention outputs for the appended tokens `[chunk, d]`.
    pub out: Mat,
    /// Per-new-row key selections in **absolute** token positions.
    pub selection: Selection,
    /// Global positions of the appended tokens within the session.
    pub positions: std::ops::Range<usize>,
    /// Per-stage operation counters for this step.
    pub ops: StageOps,
    /// Per-stage busy times for this step.
    pub timing: StageTiming,
    /// End-to-end wall time of the step, seconds.
    pub wall_s: f64,
    /// SU-FA max-misprediction recoveries.
    pub stalls: u64,
    /// Cached KV rows read, summed per row's union.
    pub union_rows: usize,
    /// Mean SADS survivor fraction ρ (0 when SADS did not run).
    pub rho_mean: f64,
    /// Keys kept for the last (longest-context) appended row.
    pub keep_last: usize,
    /// Cache hits: distinct pages read by this step's selections,
    /// excluding pages re-materialized by this very step (those are the
    /// misses, reported in `rematerialized_pages`).
    pub page_hits: usize,
    /// Pages rebuilt from history because the session had been evicted.
    pub rematerialized_pages: usize,
    /// Sessions evicted (LRU) to make room for this step.
    pub evicted_sessions: Vec<u64>,
}

/// One decoded row's results, merged after the parallel section.
struct DecodeRowOut {
    out: Vec<f32>,
    sel: Vec<usize>,
    ops: StageOps,
    timing: StageTiming,
    stalls: u64,
    union_rows: usize,
    rho: Option<f64>,
    /// Distinct page indices this row's selection read (ascending).
    pages: Vec<usize>,
}

impl SparseAttentionPipeline {
    /// Causal prefill of a fresh session: row `i` attends keys `0..=i`.
    /// Implemented as one big [`SparseAttentionPipeline::decode_step`]
    /// chunk — which is the point: any chunking of the same tokens
    /// through `decode_step` produces bit-identical outputs and
    /// selections (see `rust/tests/prop_decode_parity.rs`).
    pub fn prefill(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k: &Mat,
        v: &Mat,
    ) -> crate::Result<DecodeReport> {
        anyhow::ensure!(
            store.is_empty(session),
            "prefill into non-empty session {session} (use decode_step to extend it)"
        );
        self.decode_step(store, session, q, k, v)
    }

    /// One autoregressive decode step: append the chunk's K/V rows to
    /// the session's paged cache, then compute causal sparse attention
    /// for each new query row against the whole cached context — DLZS
    /// prediction runs against the *frozen* per-page operands, top-k
    /// selects over the causal prefix, and the formal stage streams the
    /// selected KV rows back out of the cache.
    pub fn decode_step(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k_new: &Mat,
        v_new: &Mat,
    ) -> crate::Result<DecodeReport> {
        let started = Instant::now();
        anyhow::ensure!(
            q.rows == k_new.rows && q.rows == v_new.rows,
            "decode chunk rows disagree (Q {}, K {}, V {})",
            q.rows,
            k_new.rows,
            v_new.rows
        );
        anyhow::ensure!(
            q.cols == k_new.cols && q.cols == v_new.cols,
            "decode chunk head dims disagree (Q {}, K {}, V {})",
            q.cols,
            k_new.cols,
            v_new.cols
        );
        anyhow::ensure!(
            q.cols == store.config().d,
            "chunk head dim {} != session store head dim {}",
            q.cols,
            store.config().d
        );
        // The cached key operands were quantized at the store's bitwidth;
        // scoring them at a different W would silently skew prediction.
        anyhow::ensure!(
            self.cfg.predict_bits == store.config().predict_bits,
            "pipeline predict_bits {} != session store predict_bits {}",
            self.cfg.predict_bits,
            store.config().predict_bits
        );
        if let Err(e) = self.cfg.validate() {
            anyhow::bail!("invalid pipeline config: {e}");
        }
        let d = q.cols;
        let scale = 1.0 / (d as f32).sqrt();
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // Append + re-materialize under the KV-gen stage clock.
        let t0 = Instant::now();
        let outcome = store.append(session, k_new, v_new, &mut ops)?;
        timing.kv_gen_s += t0.elapsed().as_secs_f64();

        let base = outcome.start;
        let rows = q.rows;
        let page_size = store.config().page_size;

        // Causal per-row section: rows are independent, so they tile and
        // parallelize exactly like `run` — and because every per-row
        // quantity depends only on tokens 0..=pos, the schedule can never
        // change the math.
        let tile = self.cfg.tile_t.min(rows.max(1));
        let ntiles = rows.div_ceil(tile);
        let mut tiles_out: Vec<(usize, Vec<DecodeRowOut>)> = {
            let pages: Vec<&KvPage> = store.pages_of(session);
            let cfg = &self.cfg;
            parallel_tiles(ntiles, self.cfg.threads, |ti| {
                let lo = ti * tile;
                let hi = (lo + tile).min(rows);
                let outs = (lo..hi)
                    .map(|r| decode_row(cfg, &pages, q.row(r), base + r, scale, page_size))
                    .collect();
                (ti, outs)
            })
        };
        tiles_out.sort_by_key(|(ti, _)| *ti);

        // Merge in row order.
        let mut out = Mat::zeros(rows, d);
        let mut sel_rows = Vec::with_capacity(rows);
        let mut stalls = 0u64;
        let mut union_rows = 0usize;
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        let mut touched: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
        let mut row_i = 0usize;
        for (_, tile_rows) in tiles_out {
            for r in tile_rows {
                out.row_mut(row_i).copy_from_slice(&r.out);
                sel_rows.push(r.sel);
                ops.merge(&r.ops);
                timing.merge(&r.timing);
                stalls += r.stalls;
                union_rows += r.union_rows;
                if let Some(rho) = r.rho {
                    rho_sum += rho;
                    rho_n += 1;
                }
                touched.extend(r.pages.iter().copied());
                row_i += 1;
            }
        }
        // Hits = distinct pages read minus the pages this step had to
        // rebuild (hits and misses in the same per-step page units).
        let page_hits = touched.len().saturating_sub(outcome.rematerialized_pages);
        store.record_hits(page_hits as u64);

        Ok(DecodeReport {
            out,
            selection: Selection { rows: sel_rows },
            positions: base..base + rows,
            ops,
            timing,
            wall_s: started.elapsed().as_secs_f64(),
            stalls,
            union_rows,
            rho_mean: if rho_n > 0 { rho_sum / rho_n as f64 } else { 0.0 },
            keep_last: if base + rows > 0 { self.cfg.keep(base + rows) } else { 0 },
            page_hits,
            rematerialized_pages: outcome.rematerialized_pages,
            evicted_sessions: outcome.evicted_sessions,
        })
    }
}

/// Run `ntiles` independent tile jobs, strided across worker threads
/// (`threads == 0` picks `available_parallelism`) under
/// `std::thread::scope`. Shared by the batch tile path and the decode
/// row path; results come back unordered — callers sort by their tile
/// key. Determinism is the jobs' responsibility (both callers' jobs are
/// pure functions of the tile index).
fn parallel_tiles<T: Send>(
    ntiles: usize,
    threads: usize,
    job: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let workers = match threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
    .clamp(1, ntiles.max(1));
    if workers <= 1 || ntiles <= 1 {
        (0..ntiles).map(job).collect()
    } else {
        std::thread::scope(|scope| {
            let job = &job;
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    scope.spawn(move || {
                        (w..ntiles).step_by(workers).map(job).collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().expect("tile worker panicked")).collect()
        })
    }
}

/// Formal-compute dispatch shared by the batch tile path, the decode
/// row path and the sharded engine: SU-FA (descending/ascending), the
/// FA-2 approximation (ascending SU-FA plus `fa2_cmp` cross-tile max
/// comparisons — the Fig. 18a baseline accounting), or the dense masked
/// softmax. Returns (output, stalls).
pub(crate) fn formal_compute(
    cfg: &PipelineConfig,
    inp: &AttnInputs,
    sel: &Selection,
    fa2_cmp: u64,
    c: &mut OpCounter,
) -> (Mat, u64) {
    match cfg.formal {
        FormalKind::SufaDescend | FormalKind::SufaAscend => {
            let order = if cfg.formal == FormalKind::SufaDescend {
                UpdateOrder::Descend
            } else {
                UpdateOrder::Ascend
            };
            let r = sufa_attention(inp, sel, &SufaParams { bc: cfg.bc, order }, c);
            (r.out, r.stalls)
        }
        FormalKind::Flash2 => {
            let p = SufaParams { bc: cfg.bc, order: UpdateOrder::Ascend };
            let r = sufa_attention(inp, sel, &p, c);
            c.tally(OpKind::Cmp, fa2_cmp);
            (r.out, r.stalls)
        }
        FormalKind::Dense => (dense_formal(inp, sel, c), 0),
    }
}

/// Decode one query row at global position `pos` through all four
/// stages against the cached context `0..=pos`. Everything here depends
/// only on the query row and the frozen page operands of the causal
/// prefix — the invariant that makes chunking/tiling/threading
/// bit-invisible.
fn decode_row(
    cfg: &PipelineConfig,
    pages: &[&KvPage],
    qrow: &[f32],
    pos: usize,
    attn_scale: f32,
    page_size: usize,
) -> DecodeRowOut {
    let limit = pos + 1;
    let d = qrow.len();
    let mut ops = StageOps::default();
    let mut timing = StageTiming::default();

    // ---- Stage 1: predict over cached page operands. ----
    let t0 = Instant::now();
    let est: Option<Vec<f32>> = if cfg.topk == TopkKind::None {
        None
    } else {
        let qop = QueryOperand::encode(qrow, cfg.predict, cfg.predict_bits, &mut ops.predict);
        Some(score_row(&qop, pages, limit, attn_scale, &mut ops.predict))
    };
    timing.predict_s += t0.elapsed().as_secs_f64();

    // ---- Stage 2: top-k over the causal prefix. ----
    let t0 = Instant::now();
    let keep = cfg.keep(limit);
    let mut rho = None;
    let sel: Vec<usize> = match (cfg.topk, &est) {
        (TopkKind::None, _) | (_, None) => (0..limit).collect(),
        (TopkKind::Sads, Some(e)) => {
            let (idx, stats) = sads_topk(e, keep, &cfg.sads, &mut ops.topk);
            rho = Some(stats.rho);
            idx
        }
        (TopkKind::Vanilla | TopkKind::Threshold, Some(e)) => vanilla_topk(e, keep, &mut ops.topk),
    };
    timing.topk_s += t0.elapsed().as_secs_f64();

    // ---- Stage 3: cache read — gather this row's selected KV rows. ----
    let t0 = Instant::now();
    let mut union = sel.clone();
    union.sort_unstable();
    let u = union.len();
    let (ku, vu) = gather_rows(pages, page_size, &union, d);
    let mut row_pages = Vec::new();
    for &j in &union {
        if row_pages.last() != Some(&(j / page_size)) {
            row_pages.push(j / page_size);
        }
    }
    ops.kv_gen.sram(4 * (2 * u * d) as u64); // cached KV streams from SRAM
    timing.kv_gen_s += t0.elapsed().as_secs_f64();

    // ---- Stage 4: formal compute on the compacted rows. The selection
    // is remapped monotonically (ascending union order), so per-key
    // visit order — and therefore the math — is unchanged. ----
    let t0 = Instant::now();
    let remapped: Vec<usize> =
        sel.iter().map(|&j| union.binary_search(&j).expect("selected key in union")).collect();
    let q_mat = Mat::from_vec(1, d, qrow.to_vec());
    let tile_inp = AttnInputs { q: &q_mat, k: &ku, v: &vu, scale: attn_scale };
    let csel = Selection { rows: vec![remapped] };
    let (out_row, stalls) = formal_compute(cfg, &tile_inp, &csel, keep as u64, &mut ops.formal);
    // The formal stage's KV traffic came from the cache, not DRAM.
    kv_traffic_on_chip(&mut ops.formal, u, d);
    timing.formal_s += t0.elapsed().as_secs_f64();

    DecodeRowOut {
        out: out_row.row(0).to_vec(),
        sel,
        ops,
        timing,
        stalls,
        union_rows: u,
        rho,
        pages: row_pages,
    }
}

/// Execute one query tile through all four stages.
fn run_tile(ctx: &TileCtx, ti: usize) -> TileOut {
    let cfg = ctx.cfg;
    let inp = ctx.inp;
    let (t, s, d) = (inp.t(), inp.s(), inp.d());
    let lo = ti * cfg.tile_t.min(t.max(1));
    let hi = (lo + cfg.tile_t).min(t);
    let rows = hi - lo;
    let mut ops = StageOps::default();
    let mut timing = StageTiming::default();

    // ---- Stage 1: predict (per-tile phase 1.2 / oracle scores). ----
    let t0 = Instant::now();
    let est: Option<Mat> = match ctx.score {
        ScoreSource::None => None,
        ScoreSource::Exact => {
            // Oracle scores: exact logits, nothing charged.
            let q_tile = Mat::from_fn(rows, d, |i, j| inp.q.at(lo + i, j));
            let mut e = q_tile.matmul(ctx.kt.expect("kt prepared for oracle scores"));
            e.scale(inp.scale);
            Some(e)
        }
        ScoreSource::Prepared(prep) => {
            // Scale the estimate into logit units so the SADS sphere
            // radius is calibrated the way Sec. IV-B assumes.
            let mut e = prep.score_rows(lo, hi, &mut ops.predict);
            e.scale(inp.scale);
            Some(e)
        }
    };
    timing.predict_s += t0.elapsed().as_secs_f64();

    // ---- Stage 2: top-k selection. ----
    let t0 = Instant::now();
    let (mut rho_sum, mut rho_n) = (0.0, 0usize);
    let sel_rows: Vec<Vec<usize>> = match (cfg.topk, &est) {
        (TopkKind::None, _) | (_, None) => {
            // Dense execution: every key, natural order.
            (0..rows).map(|_| (0..s).collect()).collect()
        }
        (TopkKind::Sads, Some(e)) => (0..rows)
            .map(|i| {
                let (idx, stats) = sads_topk(e.row(i), ctx.keep, &cfg.sads, &mut ops.topk);
                rho_sum += stats.rho;
                rho_n += 1;
                idx
            })
            .collect(),
        // Threshold engines have no counted software implementation;
        // executed as vanilla selection (see PipelineConfig docs).
        (TopkKind::Vanilla | TopkKind::Threshold, Some(e)) => {
            (0..rows).map(|i| vanilla_topk(e.row(i), ctx.keep, &mut ops.topk)).collect()
        }
    };
    drop(est);
    timing.topk_s += t0.elapsed().as_secs_f64();

    // ---- Stage 3: KV generation for the tile's union. ----
    let t0 = Instant::now();
    let sel = Selection { rows: sel_rows };
    let union = sel.union_keys(s);
    let u = union.len();
    let on_demand = cfg.on_demand_kv && inp.x.is_some() && inp.wk.is_some() && inp.wv.is_some();
    if on_demand {
        charge_on_demand_kv_gen(&mut ops.kv_gen, u, inp.x.unwrap().cols, d);
    }
    timing.kv_gen_s += t0.elapsed().as_secs_f64();

    // ---- Stage 4: formal compute (SU-FA / FA-2 approx / dense). ----
    let t0 = Instant::now();
    let q_tile = Mat::from_fn(rows, d, |i, j| inp.q.at(lo + i, j));
    let tile_inp = AttnInputs { q: &q_tile, k: inp.k, v: inp.v, scale: inp.scale };
    let (out, stalls) =
        formal_compute(cfg, &tile_inp, &sel, (rows * ctx.keep) as u64, &mut ops.formal);
    if on_demand {
        kv_traffic_on_chip(&mut ops.formal, u, d);
    }
    timing.formal_s += t0.elapsed().as_secs_f64();

    TileOut {
        lo,
        out,
        sel_rows: sel.rows,
        ops,
        timing,
        stalls,
        union_rows: u,
        rho_sum,
        rho_n,
    }
}

/// Dense (masked) softmax over each row's selection in ascending key
/// order, with dense-attention-style op accounting. For a full selection
/// this reproduces [`crate::attention::dense_attention`]'s float
/// associativity exactly — the `keep = 1.0` parity anchor.
fn dense_formal(inp: &AttnInputs, sel: &Selection, c: &mut OpCounter) -> Mat {
    let (s, d) = (inp.s(), inp.d());
    let f = 4u64;
    let union = sel.union_keys(s).len();
    c.dram(f * (2 * inp.t() * d) as u64); // Q in, O out
    c.dram(f * (2 * union * d) as u64); // KV in
    let mut out = Mat::zeros(inp.t(), d);
    for (i, keys) in sel.rows.iter().enumerate() {
        if keys.is_empty() {
            continue;
        }
        let mut ks = keys.clone();
        ks.sort_unstable();
        let m = ks.len();
        let mut logits: Vec<f32> = ks
            .iter()
            .map(|&j| {
                assert!(j < s, "selected key {j} out of range for S={s}");
                let mut dot = 0.0f32;
                for p in 0..d {
                    dot += inp.q.at(i, p) * inp.k.at(j, p);
                }
                dot * inp.scale
            })
            .collect();
        c.tally(OpKind::Mul, (m * d + m) as u64); // QKᵀ + scale
        c.tally(OpKind::Add, (m * (d - 1)) as u64);
        c.sram(2 * f * m as u64); // tile-resident score row
        crate::tensor::softmax_inplace(&mut logits);
        c.tally(OpKind::Cmp, (m - 1) as u64); // row max
        c.tally(OpKind::Add, m as u64); // subtract max
        c.tally(OpKind::Exp, m as u64);
        c.tally(OpKind::Add, (m - 1) as u64); // denominator
        c.tally(OpKind::Div, m as u64); // normalize
        for (w, &j) in logits.iter().zip(&ks) {
            for p in 0..d {
                *out.at_mut(i, p) += w * inp.v.at(j, p);
            }
        }
        c.tally(OpKind::Mul, (m * d) as u64);
        c.tally(OpKind::Add, ((m - 1) * d) as u64);
    }
    out
}

// The parity contract (dense-oracle equivalence, tiled == untiled,
// masked-oracle exactness) is covered once, in
// `rust/tests/integration_pipeline.rs` — the unit tests here cover only
// the per-stage accounting behaviors not visible from outside.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn workload(t: usize, s: usize, seed: u64) -> AttnWorkload {
        let model = crate::config::ModelConfig::preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        AttnWorkload::generate(&model, s, t, &mut rng)
    }

    #[test]
    fn stage_ops_land_in_their_stages() {
        let wl = workload(16, 64, 4);
        let r = SparseAttentionPipeline::star(0.25).run(&PipelineInputs::from_workload(&wl));
        // DLZS prediction is multiplier-free shift/add work.
        assert!(r.ops.predict.shift > 0);
        assert_eq!(r.ops.predict.mul, 0);
        // SADS is pure comparisons.
        assert!(r.ops.topk.cmp > 0);
        assert_eq!(r.ops.topk.mul, 0);
        // On-demand generation is MAC work.
        assert!(r.ops.kv_gen.mul > 0);
        // Formal compute pays the exponentials.
        assert!(r.ops.formal.exp > 0);
        assert!(r.union_rows > 0);
        assert!(r.tiles >= 1);
    }

    #[test]
    fn on_demand_kv_moves_formal_traffic_on_chip() {
        let wl = workload(16, 96, 5);
        let with = SparseAttentionPipeline::new(PipelineConfig::star().with_keep(0.2))
            .run(&PipelineInputs::from_workload(&wl));
        let without = SparseAttentionPipeline::new(PipelineConfig {
            on_demand_kv: false,
            ..PipelineConfig::star().with_keep(0.2)
        })
        .run(&PipelineInputs::from_workload(&wl));
        // Same selection, same numerics; traffic classified differently.
        assert_eq!(with.out.max_abs_diff(&without.out), 0.0);
        assert!(with.ops.formal.dram_bytes < without.ops.formal.dram_bytes);
        assert_eq!(without.ops.kv_gen.mul, 0);
    }

    #[test]
    fn flash2_formal_costs_more_than_sufa_descend() {
        let wl = workload(16, 128, 6);
        let inputs = PipelineInputs::from_workload(&wl);
        let star = SparseAttentionPipeline::star(0.25).run(&inputs);
        let fa = SparseAttentionPipeline::new(PipelineConfig {
            formal: FormalKind::Flash2,
            ..PipelineConfig::star().with_keep(0.25)
        })
        .run(&inputs);
        assert!(fa.ops.formal.cmp > star.ops.formal.cmp);
        assert!(fa.ops.formal.mul > star.ops.formal.mul);
    }

    #[test]
    fn empty_query_set_is_fine() {
        let wl = workload(8, 32, 7);
        let q = Mat::zeros(0, wl.d());
        let r = SparseAttentionPipeline::star(0.2).run(&PipelineInputs::qkv(&q, &wl.k, &wl.v));
        assert_eq!(r.out.rows, 0);
        assert_eq!(r.selection.rows.len(), 0);
    }

    #[test]
    fn decode_step_is_causal_and_counts_stages() {
        use crate::kvcache::{SessionConfig, SessionStore};
        let mut rng = Rng::new(9);
        let (n, d) = (24usize, 16usize);
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let pipe = SparseAttentionPipeline::new(PipelineConfig::star().with_keep(0.5).with_tile(5));
        let mut store = SessionStore::new(SessionConfig::for_pipeline(pipe.config(), d, 0));
        let r = pipe.prefill(&mut store, 1, &q, &k, &v).unwrap();
        assert_eq!(r.positions, 0..n);
        assert_eq!(r.out.rows, n);
        assert_eq!(r.selection.rows.len(), n);
        for (i, row) in r.selection.rows.iter().enumerate() {
            assert!(!row.is_empty());
            assert!(row.iter().all(|&j| j <= i), "row {i} attends beyond its causal prefix");
        }
        assert!(r.ops.predict.shift > 0, "DLZS prediction ran");
        assert_eq!(r.ops.predict.mul, 0, "DLZS stays multiplier-free");
        assert!(r.ops.topk.cmp > 0 && r.ops.formal.exp > 0);
        assert!(r.page_hits > 0 && r.union_rows > 0);
        // Extending the session continues at position n.
        let q1 = Mat::randn(1, d, 1.0, &mut rng);
        let k1 = Mat::randn(1, d, 1.0, &mut rng);
        let v1 = Mat::randn(1, d, 1.0, &mut rng);
        let r1 = pipe.decode_step(&mut store, 1, &q1, &k1, &v1).unwrap();
        assert_eq!(r1.positions, n..n + 1);
        assert_eq!(r1.keep_last, pipe.config().keep(n + 1));
        assert!(
            pipe.prefill(&mut store, 1, &q1, &k1, &v1).is_err(),
            "prefill must refuse a non-empty session"
        );
    }

    #[test]
    fn decode_outputs_are_exact_softmax_over_their_selections() {
        use crate::attention::{masked_attention_oracle, AttnInputs};
        use crate::kvcache::{SessionConfig, SessionStore};
        let mut rng = Rng::new(10);
        let (n, d) = (32usize, 8usize);
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let pipe = SparseAttentionPipeline::star(0.4);
        let mut store = SessionStore::new(SessionConfig::for_pipeline(pipe.config(), d, 0));
        let r = pipe.prefill(&mut store, 3, &q, &k, &v).unwrap();
        // The selections are absolute positions, so the masked oracle
        // over the full (uncompacted) K/V must reproduce the outputs.
        let inp = AttnInputs::new(&q, &k, &v);
        let oracle = masked_attention_oracle(&inp, &r.selection);
        let err = r.out.max_abs_diff(&oracle);
        assert!(err < 1e-4, "masked-oracle parity err {err}");
    }
}
