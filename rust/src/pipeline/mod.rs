//! The sparse-attention **pipeline subsystem**: the paper's four stages —
//! prediction (Sec. IV-A), top-k (Sec. IV-B), on-demand KV generation and
//! formal compute (Sec. IV-C) — composed behind one config-driven API and
//! executed with cross-stage tiling.
//!
//! * [`config`] — [`PipelineConfig`]: predict scheme × top-k engine ×
//!   formal kernel × keep ratio × tile size, sharing its stage-axis enums
//!   with the cycle-level simulator's
//!   [`crate::sim::pipeline::FeatureSet`] so algorithm runs and
//!   cycle-level runs speak one config vocabulary.
//! * [`engine`] — the **tile-execution core**: one allocation-free
//!   implementation of the four-stage loop (the crate-internal
//!   `TileExecutor`) working inside preallocated per-worker scratch
//!   ([`TileWorkspace`], pooled per [`ShapeClass`] by
//!   [`WorkspacePool`]). All three front-ends below drive it; none
//!   keeps its own copy of the stage bodies. Workspace capacity is
//!   reported next to the simulator's SRAM budget (DESIGN.md §8).
//! * [`exec`] — [`SparseAttentionPipeline`]: tiled execution (per query
//!   tile: predict → SADS → union-KV-gen → SU-FA, intermediates stay
//!   tile-sized), parallel over independent tiles with
//!   `std::thread::scope`, deterministic for every tile size and thread
//!   count. Also the autoregressive entry points
//!   [`SparseAttentionPipeline::prefill`] /
//!   [`SparseAttentionPipeline::decode_step`], which run the same four
//!   stages *causally* over a [`crate::kvcache::SessionStore`] — cached
//!   prediction operands and KV pages instead of per-run preparation,
//!   with N single-token steps bit-identical to one length-N prefill.
//! * [`sharded`] — [`ShardedPipeline`]: **executable Spatial-STAR**.
//!   Prefill for sequences beyond one worker's reach runs the
//!   DRAttention dataflow for real: the KV/context dimension is
//!   partitioned across N snake-placed workers, Q sub-blocks circulate
//!   on a thread ring, top-k merges distributedly, and the gathered
//!   formal stage reproduces the single-core output **bit for bit** at
//!   every worker count (`rust/tests/prop_sharded_parity.rs`). Decode
//!   for sessions beyond one worker's reach partitions the *cached*
//!   pages the same way ([`ShardedPipeline::decode_step`]): shards
//!   propose candidates from their key ranges, the row's home worker
//!   merges and runs the unchanged stage-3/4 core — bit-identical to
//!   [`SparseAttentionPipeline::decode_step`] at every shard count
//!   (`rust/tests/prop_sharded_decode_parity.rs`).
//! * [`report`] — per-stage [`StageOps`] counters and [`StageTiming`]
//!   breakdowns aggregated across tiles.
//!
//! Every layer runs sparse attention through this module: the bench
//! harness ([`crate::bench::algorithm`],
//! [`crate::bench::spatial_exec`]), the native serving backend
//! ([`crate::coordinator::server::Backend::Native`]) and the examples.

pub mod config;
pub mod engine;
pub mod exec;
pub mod report;
pub mod sharded;

pub use config::PipelineConfig;
pub use engine::{ShapeClass, TileWorkspace, WorkspacePool};
pub use exec::{DecodeReport, PipelineInputs, PipelineReport, SparseAttentionPipeline};
pub use report::{StageOps, StageTiming};
pub use sharded::{ShardPlan, ShardStats, ShardedDecodeReport, ShardedPipeline, ShardedReport};
