//! Per-stage accounting for pipeline runs: operation counters and wall
//! times broken down by the four paper stages.

use crate::arith::{EquivWeights, OpCounter};

/// Operation counters per pipeline stage.
#[derive(Clone, Debug, Default)]
pub struct StageOps {
    /// Prediction-stage ops (quantize/encode/score).
    pub predict: OpCounter,
    /// Top-k-stage ops (comparisons).
    pub topk: OpCounter,
    /// KV-generation ops (on-demand MACs, cache traffic).
    pub kv_gen: OpCounter,
    /// Formal-compute ops (SU-FA / FA-2 / dense).
    pub formal: OpCounter,
}

impl StageOps {
    /// Merge another breakdown into this one (tile/worker aggregation).
    pub fn merge(&mut self, other: &StageOps) {
        self.predict.merge(&other.predict);
        self.topk.merge(&other.topk);
        self.kv_gen.merge(&other.kv_gen);
        self.formal.merge(&other.formal);
    }

    /// All stages folded into one counter.
    pub fn total(&self) -> OpCounter {
        let mut c = self.predict.clone();
        c.merge(&self.topk);
        c.merge(&self.kv_gen);
        c.merge(&self.formal);
        c
    }

    /// Equivalent additions of the whole run under `w`.
    pub fn equivalent_adds(&self, w: &EquivWeights) -> f64 {
        self.total().equivalent_adds(w)
    }
}

/// Wall time per stage, in seconds. Under multi-threaded execution these
/// are *aggregate busy times* summed across workers (they can exceed the
/// end-to-end wall clock); ratios between stages remain meaningful.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTiming {
    /// Prediction-stage busy time, seconds.
    pub predict_s: f64,
    /// Top-k-stage busy time, seconds.
    pub topk_s: f64,
    /// KV-generation busy time, seconds.
    pub kv_gen_s: f64,
    /// Formal-compute busy time, seconds.
    pub formal_s: f64,
}

impl StageTiming {
    /// Add another breakdown into this one (tile/worker aggregation).
    pub fn merge(&mut self, other: &StageTiming) {
        self.predict_s += other.predict_s;
        self.topk_s += other.topk_s;
        self.kv_gen_s += other.kv_gen_s;
        self.formal_s += other.formal_s;
    }

    /// Total busy time across stages.
    pub fn busy_s(&self) -> f64 {
        self.predict_s + self.topk_s + self.kv_gen_s + self.formal_s
    }

    /// The stage dominating busy time: (name, seconds).
    pub fn bottleneck(&self) -> (&'static str, f64) {
        let stages = [
            ("predict", self.predict_s),
            ("topk", self.topk_s),
            ("kv_gen", self.kv_gen_s),
            ("formal", self.formal_s),
        ];
        stages
            .into_iter()
            .fold(("predict", 0.0), |best, s| if s.1 > best.1 { s } else { best })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arith::OpKind;

    #[test]
    fn stage_ops_total_merges_all_stages() {
        let mut s = StageOps::default();
        s.predict.tally(OpKind::Shift, 5);
        s.topk.tally(OpKind::Cmp, 7);
        s.kv_gen.tally(OpKind::Mul, 2);
        s.formal.tally(OpKind::Exp, 3);
        let t = s.total();
        assert_eq!((t.shift, t.cmp, t.mul, t.exp), (5, 7, 2, 3));
        let mut s2 = StageOps::default();
        s2.merge(&s);
        s2.merge(&s);
        assert_eq!(s2.total().cmp, 14);
    }

    #[test]
    fn timing_bottleneck_picks_max() {
        let t = StageTiming { predict_s: 0.1, topk_s: 0.4, kv_gen_s: 0.2, formal_s: 0.3 };
        assert_eq!(t.bottleneck().0, "topk");
        assert!((t.busy_s() - 1.0).abs() < 1e-12);
    }
}
