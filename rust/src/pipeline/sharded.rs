//! [`ShardedPipeline`] — **executable Spatial-STAR**: sequence-sharded
//! multi-worker prefill running the DRAttention dataflow for real
//! (threads and channels), not just analytically ([`crate::spatial`]).
//!
//! The serving problem it solves: the single-core
//! [`super::SparseAttentionPipeline`] executes a whole request on one
//! logical core, so the batcher's `target_t` caps the query rows one
//! request may carry. This engine partitions the **KV/context dimension**
//! across N workers (each owning a contiguous key range, placed on a
//! logical mesh in snake order — [`crate::spatial::snake_coords`]) and
//! circulates **Q sub-blocks** around the worker ring, exactly as
//! DRAttention circulates Q while X/KV stays column-resident
//! (Sec. V-B-1). Per ring step a worker runs the *local* half of the
//! stages for the visiting block — predict over its key range, the SADS
//! per-segment pass over its sub-segments — and forwards the block (with
//! its accumulated candidate state, the executable stand-in for the
//! circulating running-softmax payload) to its ring neighbor. After N
//! steps the block is home with every shard's candidates; the home
//! worker then
//!
//! 1. **merges** the distributed top-k ([`crate::sparsity::sads_merge`]
//!    for SADS, [`crate::sparsity::merge_topk_candidates`] for the exact
//!    engines) into the global per-row selection,
//! 2. **gathers** the selected KV rows from their owning shards (the
//!    sparse win: only `keep ≪ S` rows per query cross the ring), and
//! 3. runs the **formal stage** (SU-FA) over the gathered rows in the
//!    merged order.
//!
//! # The bit-identity contract
//!
//! Output, selection and stalls equal the single-core
//! [`super::SparseAttentionPipeline::run`] **bit for bit, for every
//! worker count** (`rust/tests/prop_sharded_parity.rs`). Three design
//! decisions carry the proof:
//!
//! * **Global quantization.** The predict prologue is the *same code*
//!   as the single-core path ([`super::engine`]'s score-source
//!   preparation): operand scales are chosen from the full tensors, so
//!   a shard scoring its key sub-range computes the identical dot
//!   products ([`crate::sparsity::PreparedPredict::score_block`]).
//! * **Segment-aligned sharding.** Key ranges are unions of whole SADS
//!   sub-segments ([`crate::sparsity::sads_geometry`]), so each worker
//!   runs the per-segment pass on exactly the slices the single-core
//!   SADS would form, and the merge — whose tie-breaking depends only
//!   on the global segment order — is shard-count invariant.
//! * **Order-preserving gather.** The formal stage consumes the merged
//!   selection remapped monotonically onto the gathered rows, so SU-FA
//!   visits the same key *values* in the same order as the single-core
//!   run over the full K/V — the same float sequence, stalls included.
//!
//! # Distributed decode
//!
//! [`ShardedPipeline::decode_step`] extends the same two-phase scheme
//! across **time**: a session whose paged KV cache has outgrown one
//! worker is decoded by partitioning the cached context across N
//! workers (contiguous key ranges over the frozen pages), running the
//! *local* predict + per-segment top-k halves against each worker's
//! key range, and gathering every shard's candidates at the query row's
//! **home** worker in one scatter step (Star Attention's phase-2
//! "global query against distributed KV" topology, PAPERS.md
//! arxiv 2411.17116 — the query is tiny, so it is the candidates, not
//! the KV, that travel). The home worker merges with the identical
//! distributed-merge kernels and then runs the *unchanged* single-core
//! stage-3/4 decode core
//! ([`super::engine`]'s shared gather + formal row body), which is what
//! makes N sharded decode steps **bit-identical to single-core
//! [`super::SparseAttentionPipeline::decode_step`] at every shard
//! count** (`rust/tests/prop_sharded_decode_parity.rs`). The
//! tolerance-mode alternative — per-shard SU-FA partials combined by
//! online-softmax rescaling ([`crate::attention::partials`]) — is
//! measured in `star bench decode --sharded` and documented in
//! DESIGN.md §12.

use super::config::PipelineConfig;
use super::engine::{
    prepare_score_source, ScoreSource, ShapeClass, TileExecutor, TileWorkspace, WorkspacePool,
};
use super::exec::PipelineInputs;
use super::report::{StageOps, StageTiming};
use crate::attention::Selection;
use crate::kvcache::{
    score_row_range_into, CacheStats, KvPage, QueryOperand, ResidencySnapshot, SessionStore,
};
use crate::obs::trace::{ExecPath, Stage};
use crate::obs::traffic::{self, SchedStats, TrafficCounter};
use crate::sim::pipeline::TopkKind;
use crate::sparsity::topk::{
    merge_topk_candidates, sads_geometry, sads_merge, sads_segment_winners_scratch,
    vanilla_topk_into, SegmentWinners,
};
use crate::spatial::drattention::q_payload_bytes;
use crate::spatial::mesh::{snake_coords, Coord};
use crate::tensor::Mat;
use std::sync::mpsc::channel;
use std::time::Instant;

/// How one sharded run partitions keys, queries and workers.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// Contiguous, ascending `[lo, hi)` key ranges, one per worker.
    pub key_ranges: Vec<(usize, usize)>,
    /// Global SADS sub-segment id range `[lo, hi)` per worker (all
    /// `(0, 0)` when the top-k engine is not SADS).
    pub seg_ranges: Vec<(usize, usize)>,
    /// SADS sub-segment length (0 when SADS is off).
    pub seg_len: usize,
    /// Q sub-block row ranges, one per worker; block `b` is *homed* on
    /// worker `b` and circulates from there.
    pub q_blocks: Vec<(usize, usize)>,
    /// Snake-ordered mesh placement, one coordinate per worker.
    pub coords: Vec<Coord>,
}

impl ShardPlan {
    /// Partition `t` query rows and `s` keys for `requested` workers
    /// (0 = `available_parallelism`). The worker count is clamped so
    /// every key range is non-empty, and — when SADS is the top-k
    /// engine — so ranges align with whole sub-segments (the atomic
    /// unit that keeps distributed selection bit-identical).
    pub fn new(cfg: &PipelineConfig, t: usize, s: usize, requested: usize) -> ShardPlan {
        let req = match requested {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
        .max(1);
        let (key_ranges, seg_ranges, seg_len) = if cfg.topk == TopkKind::Sads {
            let (nseg, seg_len) = sads_geometry(s, &cfg.sads);
            let w = req.min(nseg.max(1));
            let mut keys = Vec::with_capacity(w);
            let mut segs = Vec::with_capacity(w);
            for j in 0..w {
                let (slo, shi) = (j * nseg / w, (j + 1) * nseg / w);
                segs.push((slo, shi));
                keys.push((slo * seg_len, (shi * seg_len).min(s)));
            }
            (keys, segs, seg_len)
        } else {
            let w = req.min(s.max(1));
            let keys = (0..w).map(|j| (j * s / w, (j + 1) * s / w)).collect();
            (keys, vec![(0, 0); w], 0)
        };
        let w = key_ranges.len();
        let q_blocks = (0..w).map(|j| (j * t / w, (j + 1) * t / w)).collect();
        // Square-ish logical mesh, snake-filled so ring neighbors are
        // mesh neighbors.
        let cols = (w as f64).sqrt().ceil() as usize;
        let rows = w.div_ceil(cols.max(1));
        let mut coords = snake_coords(rows, cols.max(1));
        coords.truncate(w);
        ShardPlan { key_ranges, seg_ranges, seg_len, q_blocks, coords }
    }

    /// Effective worker count (after clamping).
    pub fn workers(&self) -> usize {
        self.key_ranges.len()
    }

    /// Partition a decode step — `t` new query rows against `s` cached
    /// keys — for `requested` workers (0 = `available_parallelism`).
    /// Decode key ranges are plain contiguous splits for *every* top-k
    /// engine: each query row has its own causal limit and therefore its
    /// own SADS sub-segment geometry, so segment ownership is resolved
    /// per row by the first-key rule (a segment belongs to the shard
    /// whose key range contains the segment's first key — see
    /// [`ShardedPipeline::decode_step`]) instead of being baked into the
    /// partition. Query rows are homed in contiguous blocks, one per
    /// worker, like the prefill plan.
    pub fn for_decode(t: usize, s: usize, requested: usize) -> ShardPlan {
        let req = match requested {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        }
        .max(1);
        let w = req.min(s.max(1));
        let key_ranges: Vec<(usize, usize)> = (0..w).map(|j| (j * s / w, (j + 1) * s / w)).collect();
        let q_blocks = (0..w).map(|j| (j * t / w, (j + 1) * t / w)).collect();
        let cols = (w as f64).sqrt().ceil() as usize;
        let rows = w.div_ceil(cols.max(1));
        let mut coords = snake_coords(rows, cols.max(1));
        coords.truncate(w);
        ShardPlan { key_ranges, seg_ranges: vec![(0, 0); w], seg_len: 0, q_blocks, coords }
    }
}

/// One worker's contribution, carried in the circulating payload.
#[derive(Clone, Debug, Default)]
struct RowCandidates {
    /// SADS: per-sub-segment winner lists (global segment ids).
    sads: Vec<SegmentWinners>,
    /// Exact engines: `(score, global key index)` proposals, in
    /// per-shard extraction order (the home merge sorts by index).
    exact: Vec<(f32, usize)>,
}

/// The circulating Q sub-block: row range plus accumulated candidates —
/// the executable counterpart of DRAttention's Q + running-state
/// payload.
struct QBlockPayload {
    block: usize,
    lo: usize,
    hi: usize,
    rows: Vec<RowCandidates>,
}

impl QBlockPayload {
    fn home(block: usize, lo: usize, hi: usize) -> QBlockPayload {
        QBlockPayload { block, lo, hi, rows: vec![RowCandidates::default(); hi - lo] }
    }

    /// Modeled wire size: the Q sub-block + running softmax state
    /// ([`q_payload_bytes`]) plus ~8 bytes per accumulated candidate.
    fn wire_bytes(&self, d: usize) -> u64 {
        let cands: usize = self
            .rows
            .iter()
            .map(|r| r.exact.len() + r.sads.iter().map(|l| l.winners.len()).sum::<usize>())
            .sum();
        q_payload_bytes(self.hi - self.lo, d) + 8 * cands as u64
    }
}

/// Per-worker execution statistics of one sharded run.
#[derive(Clone, Debug)]
pub struct ShardStats {
    /// Worker index (= ring position).
    pub shard: usize,
    /// Snake-order mesh placement.
    pub coord: Coord,
    /// Owned key range start (inclusive).
    pub key_lo: usize,
    /// Owned key range end (exclusive).
    pub key_hi: usize,
    /// Query rows homed on this worker.
    pub q_rows: usize,
    /// Stage busy times on this worker (local passes + home phase).
    pub timing: StageTiming,
    /// Ring payloads this worker forwarded.
    pub ring_sends: u64,
    /// Modeled bytes of those payloads.
    pub payload_bytes: u64,
}

/// Result of one [`ShardedPipeline::run`].
#[derive(Clone, Debug)]
pub struct ShardedReport {
    /// Attention output `[T, d]` — bit-identical to the single-core
    /// pipeline's output on the same inputs.
    pub out: Mat,
    /// Per-row key selections (absolute indices, merged order).
    pub selection: Selection,
    /// Per-stage operation counters summed over all workers.
    pub ops: StageOps,
    /// Per-stage busy times summed over all workers.
    pub timing: StageTiming,
    /// End-to-end wall time, seconds.
    pub wall_s: f64,
    /// SU-FA max-misprediction recoveries.
    pub stalls: u64,
    /// KV rows gathered, summed per Q block's union.
    pub union_rows: usize,
    /// Mean SADS survivor fraction ρ (0 when SADS did not run).
    pub rho_mean: f64,
    /// Keys kept per row.
    pub keep: usize,
    /// Effective worker count.
    pub shards: usize,
    /// Ring steps executed (= worker count; each block visits every
    /// shard once, plus the homecoming hop folded into the last step).
    pub ring_steps: usize,
    /// Modeled bytes forwarded on the ring across all workers.
    pub ring_payload_bytes: u64,
    /// Per-worker statistics, ascending shard index.
    pub per_shard: Vec<ShardStats>,
    /// Heap allocations metered inside the workers' stage cores (home
    /// gather + formal; zero in steady state on a warm
    /// [`super::WorkspacePool`] — the ring payload is excluded by
    /// design: candidates traveling between threads must own their
    /// storage; see [`super::engine`]).
    pub hot_path_allocs: u64,
    /// Peak per-worker [`super::TileWorkspace`] heap capacity during
    /// this run, bytes.
    pub workspace_bytes: usize,
    /// Measured byte-level traffic merged over all workers (zero unless
    /// [`crate::obs::traffic::set_enabled`] turned counting on). The
    /// ring payload is counted in `ring_payload_bytes` inside the
    /// counter, so sharded DRAM-class totals stay comparable with the
    /// single-core run.
    pub traffic: TrafficCounter,
    /// Scheduler statistics: the ring schedule is static (one homed Q
    /// block per worker), so `steals` is always 0 here.
    pub sched: SchedStats,
}

impl ShardedReport {
    /// Selection density relative to dense `T × S` attention.
    pub fn density(&self, s: usize) -> f64 {
        self.selection.density(s)
    }
}

/// One home worker's finished block plus that worker's statistics.
struct WorkerOut {
    block: usize,
    lo: usize,
    out: Mat,
    sel_rows: Vec<Vec<usize>>,
    ops: StageOps,
    timing: StageTiming,
    stalls: u64,
    union_rows: usize,
    rho_sum: f64,
    rho_n: usize,
    ring_sends: u64,
    payload_bytes: u64,
}

/// Shared read-only context for the worker threads.
struct ShardCtx<'a> {
    cfg: &'a PipelineConfig,
    inp: &'a PipelineInputs<'a>,
    score: &'a ScoreSource,
    /// K pre-transposed, for the oracle score path only.
    kt: Option<&'a Mat>,
    plan: &'a ShardPlan,
    keep: usize,
    /// SADS per-segment quota ⌈k/n⌉ (computed for every config; read
    /// only when the top-k engine is SADS).
    per_seg: usize,
    s: usize,
    d: usize,
}

/// The sequence-sharded pipeline. Construct once, run on many inputs;
/// the worker count never changes the math (see module docs), only the
/// wall clock.
///
/// ```
/// use star::pipeline::{PipelineConfig, PipelineInputs, ShardedPipeline,
///     SparseAttentionPipeline};
/// use star::tensor::Mat;
/// use star::util::Rng;
///
/// let mut rng = Rng::new(7);
/// let (q, k, v) = (
///     Mat::randn(12, 16, 1.0, &mut rng),
///     Mat::randn(96, 16, 1.0, &mut rng),
///     Mat::randn(96, 16, 1.0, &mut rng),
/// );
/// let inputs = PipelineInputs::qkv(&q, &k, &v);
/// let cfg = PipelineConfig::star().with_keep(0.25).with_threads(1);
/// let single = SparseAttentionPipeline::new(cfg).run(&inputs);
/// let sharded = ShardedPipeline::new(cfg, 4).run(&inputs);
/// assert_eq!(sharded.out.max_abs_diff(&single.out), 0.0);
/// assert_eq!(sharded.selection, single.selection);
/// assert!(sharded.shards >= 1 && sharded.ring_steps == sharded.shards);
/// ```
#[derive(Clone, Debug)]
pub struct ShardedPipeline {
    cfg: PipelineConfig,
    shards: usize,
}

impl ShardedPipeline {
    /// Build a sharded pipeline with `shards` workers (0 = one worker
    /// per available core). Panics on an invalid config, like
    /// [`super::SparseAttentionPipeline::new`].
    pub fn new(cfg: PipelineConfig, shards: usize) -> ShardedPipeline {
        if let Err(e) = cfg.validate() {
            panic!("invalid PipelineConfig: {e}");
        }
        ShardedPipeline { cfg, shards }
    }

    /// The paper's STAR configuration at the given keep ratio.
    pub fn star(keep_ratio: f64, shards: usize) -> ShardedPipeline {
        ShardedPipeline::new(PipelineConfig::star().with_keep(keep_ratio), shards)
    }

    /// The configuration every worker executes.
    pub fn config(&self) -> &PipelineConfig {
        &self.cfg
    }

    /// Requested worker count (0 = auto).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The partition this pipeline would use for a `t × s` problem.
    pub fn plan(&self, t: usize, s: usize) -> ShardPlan {
        ShardPlan::new(&self.cfg, t, s, self.shards)
    }

    /// Execute sequence-sharded prefill. Output, selection and stalls
    /// are bit-identical to [`super::SparseAttentionPipeline::run`] on
    /// the same inputs, for every worker count. Runs on a throwaway
    /// [`WorkspacePool`]; serving paths use
    /// [`ShardedPipeline::run_pooled`] to reuse warm workspaces.
    pub fn run(&self, inp: &PipelineInputs) -> ShardedReport {
        self.run_pooled(inp, &WorkspacePool::new())
    }

    /// [`ShardedPipeline::run`] with each worker drawing its
    /// [`TileWorkspace`] from `pool` — bit-identical outputs, warm
    /// buffers across requests.
    pub fn run_pooled(&self, inp: &PipelineInputs, pool: &WorkspacePool) -> ShardedReport {
        let started = Instant::now();
        let (t, s, d) = (inp.t(), inp.s(), inp.d());
        let keep = self.cfg.keep(s);
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        if t == 0 || s == 0 {
            return ShardedReport {
                out: Mat::zeros(t, d),
                selection: Selection { rows: vec![Vec::new(); t] },
                ops,
                timing,
                wall_s: started.elapsed().as_secs_f64(),
                stalls: 0,
                union_rows: 0,
                rho_mean: 0.0,
                keep,
                shards: 0,
                ring_steps: 0,
                ring_payload_bytes: 0,
                per_shard: Vec::new(),
                hot_path_allocs: 0,
                workspace_bytes: 0,
                traffic: TrafficCounter::new(),
                sched: SchedStats::default(),
            };
        }

        // ---- Prologue: identical operand preparation (global scales)
        // as the single-core pipeline — the quantization half of the
        // bit-identity contract. ----
        let t0 = Instant::now();
        let score = prepare_score_source(&self.cfg, inp, &mut ops.predict);
        let kt = match score {
            ScoreSource::Exact => Some(inp.k.transpose()),
            _ => None,
        };
        // Run-level key ingest, identical to the single-core prologue:
        // the predict operands stream in once for the whole run (the
        // per-hop score tiles are SRAM-class operand reads), which is
        // what keeps sharded DRAM-class totals equal to the single-core
        // run's — a property `star bench traffic` checks.
        let mut run_traffic = TrafficCounter::new();
        if traffic::enabled() {
            run_traffic.key_ingest_bytes += match score {
                ScoreSource::None => 0,
                ScoreSource::Exact => 4 * (s * d) as u64,
                ScoreSource::Prepared(_) => {
                    use crate::sim::pipeline::PredictKind;
                    if self.cfg.predict == PredictKind::DlzsCross && inp.x.is_some() {
                        4 * (s * inp.x.unwrap().cols) as u64
                    } else {
                        4 * (s * d) as u64
                    }
                }
            };
        }
        timing.predict_s += t0.elapsed().as_secs_f64();

        let plan = self.plan(t, s);
        let w = plan.workers();
        let n_for_quota = self.cfg.sads.segments.max(1).min(s);
        let ctx = ShardCtx {
            cfg: &self.cfg,
            inp,
            score: &score,
            kt: kt.as_ref(),
            plan: &plan,
            keep,
            per_seg: keep.min(s).div_ceil(n_for_quota),
            s,
            d,
        };

        // ---- Ring circulation: one thread per worker, mpsc links to
        // the next ring neighbor, one pooled workspace per worker.
        // Every thread computes its local pass on the payload it holds,
        // forwards it, and receives the next — after `w` steps each
        // block has visited every shard and is back home for merge +
        // gather + formal. ----
        let class = ShapeClass::of(&self.cfg, d);
        let worker_outs: Vec<(WorkerOut, u64, usize, TrafficCounter)> =
            std::thread::scope(|scope| {
            let (txs, rxs): (Vec<_>, Vec<_>) =
                (0..w).map(|_| channel::<QBlockPayload>()).unzip();
            let ctx = &ctx;
            let mut handles = Vec::with_capacity(w);
            for (j, rx) in rxs.into_iter().enumerate() {
                let tx_next = txs[(j + 1) % w].clone();
                handles.push(scope.spawn(move || {
                    let mut ws = pool.checkout(class);
                    // Trace context for this shard: reserve span storage
                    // here (outside the metered stage cores) and stamp the
                    // ring position as the worker id.
                    ws.spans.reserve_if_enabled();
                    ws.spans.worker = j as u32;
                    ws.spans.session = 0;
                    let mut my_ops = StageOps::default();
                    let mut my_timing = StageTiming::default();
                    let (blo, bhi) = ctx.plan.q_blocks[j];
                    let mut payload = QBlockPayload::home(j, blo, bhi);
                    let mut ring_sends = 0u64;
                    let mut payload_bytes = 0u64;
                    for _step in 0..w {
                        shard_local_pass(
                            ctx,
                            j,
                            &mut payload,
                            &mut my_ops,
                            &mut my_timing,
                            &mut ws,
                        );
                        if w > 1 {
                            let wb = payload.wire_bytes(ctx.d);
                            payload_bytes += wb;
                            ring_sends += 1;
                            if traffic::enabled() {
                                ws.traffic.ring_payload_bytes += wb;
                            }
                            let sent_block = payload.block as u32;
                            let t0 = Instant::now();
                            tx_next.send(payload).expect("ring receiver alive");
                            payload = rx.recv().expect("ring sender alive");
                            // Forward + wait-for-neighbor time: the ring
                            // phase of the DRAttention timeline.
                            ws.spans.record(
                                Stage::Ring,
                                ExecPath::Sharded,
                                sent_block,
                                t0,
                                Instant::now(),
                                wb,
                            );
                        }
                    }
                    debug_assert_eq!(payload.block, j, "payload did not come home");
                    let out = home_phase(
                        ctx,
                        payload,
                        my_ops,
                        my_timing,
                        ring_sends,
                        payload_bytes,
                        &mut ws,
                    );
                    let (hot, bytes, tr) =
                        (ws.take_hot_allocs(), ws.capacity_bytes(), ws.take_traffic());
                    pool.checkin(ws);
                    (out, hot, bytes, tr)
                }));
            }
            drop(txs);
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        });
        let mut hot_path_allocs = 0u64;
        let mut workspace_bytes = 0usize;
        let mut outs: Vec<WorkerOut> = Vec::with_capacity(w);
        for (o, hot, bytes, tr) in worker_outs {
            hot_path_allocs += hot;
            workspace_bytes = workspace_bytes.max(bytes);
            run_traffic.merge(&tr);
            outs.push(o);
        }
        outs.sort_by_key(|o| o.block);

        // ---- Merge worker results in block order. ----
        let mut out = Mat::zeros(t, d);
        let mut sel_rows = Vec::with_capacity(t);
        let mut stalls = 0u64;
        let mut union_rows = 0usize;
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        let mut ring_payload_bytes = 0u64;
        let mut per_shard = Vec::with_capacity(w);
        for o in outs {
            for i in 0..o.out.rows {
                out.row_mut(o.lo + i).copy_from_slice(o.out.row(i));
            }
            sel_rows.extend(o.sel_rows);
            ops.merge(&o.ops);
            timing.merge(&o.timing);
            stalls += o.stalls;
            union_rows += o.union_rows;
            rho_sum += o.rho_sum;
            rho_n += o.rho_n;
            ring_payload_bytes += o.payload_bytes;
            let (key_lo, key_hi) = plan.key_ranges[o.block];
            per_shard.push(ShardStats {
                shard: o.block,
                coord: plan.coords[o.block],
                key_lo,
                key_hi,
                q_rows: o.out.rows,
                timing: o.timing,
                ring_sends: o.ring_sends,
                payload_bytes: o.payload_bytes,
            });
        }

        ShardedReport {
            out,
            selection: Selection { rows: sel_rows },
            ops,
            timing,
            wall_s: started.elapsed().as_secs_f64(),
            stalls,
            union_rows,
            rho_mean: if rho_n > 0 { rho_sum / rho_n as f64 } else { 0.0 },
            keep,
            shards: w,
            ring_steps: w,
            ring_payload_bytes,
            per_shard,
            hot_path_allocs,
            workspace_bytes,
            traffic: run_traffic,
            sched: SchedStats {
                workers: w as u64,
                chunk_grabs: w as u64,
                steals: 0,
                tiles: w as u64,
                max_worker_tiles: 1,
            },
        }
    }
}

/// One ring step on worker `j`: run the shard-local halves of the
/// predict and top-k stages for the visiting Q sub-block, over this
/// worker's key range only. The score tile lands in the worker's
/// [`TileWorkspace`] (the shared stage-1 kernel of
/// [`TileExecutor::score_block_into`]); the proposed candidates are
/// pushed into the ring payload, which must own its storage.
fn shard_local_pass(
    ctx: &ShardCtx,
    j: usize,
    payload: &mut QBlockPayload,
    ops: &mut StageOps,
    timing: &mut StageTiming,
    ws: &mut TileWorkspace,
) {
    if ctx.cfg.topk == TopkKind::None || payload.hi == payload.lo {
        return; // dense execution needs no scores; empty block carries nothing
    }
    let (lo, hi) = (payload.lo, payload.hi);
    let (key_lo, key_hi) = ctx.plan.key_ranges[j];
    let rows = hi - lo;
    let kw = key_hi - key_lo;

    // ---- Predict (local): score this block's rows against the owned
    // key range. Bit-identical to the same elements of the single-core
    // estimate (global scales / independent dot products) — the same
    // stage-1 kernel the batch tile path runs, not a loop kept in sync
    // by hand. ----
    let t0 = Instant::now();
    let b0 = ws.traffic.total_bytes();
    let exec = TileExecutor { cfg: ctx.cfg };
    let have_est = exec.score_block_into(
        ctx.score,
        ctx.inp,
        ctx.kt,
        lo,
        hi,
        key_lo,
        key_hi,
        ws,
        &mut ops.predict,
    );
    debug_assert!(have_est, "topk != None implies a score source");
    let t1 = Instant::now();
    timing.predict_s += (t1 - t0).as_secs_f64();
    let tb = ws.traffic.total_bytes() - b0;
    ws.spans.record(Stage::Predict, ExecPath::Sharded, lo as u32, t0, t1, tb);

    // ---- Top-k (local): propose candidates from the owned range. ----
    let t0 = Instant::now();
    let b0 = ws.traffic.total_bytes();
    let (est, topk, tmp) = ws.est_topk_and_tmp();
    match ctx.cfg.topk {
        TopkKind::None => unreachable!(),
        TopkKind::Sads => {
            let (seg_lo, seg_hi) = ctx.plan.seg_ranges[j];
            let seg_len = ctx.plan.seg_len;
            for i in 0..rows {
                let row = est.row(i);
                for seg in seg_lo..seg_hi {
                    let glo = seg * seg_len;
                    let ghi = (glo + seg_len).min(ctx.s);
                    payload.rows[i].sads.push(sads_segment_winners_scratch(
                        &row[glo - key_lo..ghi - key_lo],
                        glo,
                        seg,
                        ctx.per_seg,
                        ctx.cfg.sads.radius,
                        &mut ops.topk,
                        topk,
                    ));
                }
            }
        }
        // Threshold engines execute as vanilla selection, as in the
        // single-core pipeline (see PipelineConfig docs).
        TopkKind::Vanilla | TopkKind::Threshold => {
            for i in 0..rows {
                vanilla_topk_into(est.row(i), ctx.keep.min(kw), &mut ops.topk, topk, tmp);
                // Proposal order is irrelevant here: the home phase sorts
                // the full accumulated list by global index (the tie
                // contract) before merging.
                payload.rows[i]
                    .exact
                    .extend(tmp.iter().map(|&jj| (est.at(i, jj), key_lo + jj)));
            }
        }
    }
    if traffic::enabled() {
        // The local score tile is re-read once by the proposal pass.
        ws.traffic.score_read_bytes += 4 * (rows * kw) as u64;
    }
    let t1 = Instant::now();
    timing.topk_s += (t1 - t0).as_secs_f64();
    let tb = ws.traffic.total_bytes() - b0;
    ws.spans.record(Stage::Topk, ExecPath::Sharded, lo as u32, t0, t1, tb);
}

/// The home phase for a block that has visited every shard: merge the
/// distributed top-k, then hand the merged selection to the shared
/// stage-3/4 core ([`TileExecutor::gather_formal_block`]) — gather the
/// selected KV rows, run the formal stage in the merged order.
fn home_phase(
    ctx: &ShardCtx,
    payload: QBlockPayload,
    mut ops: StageOps,
    mut timing: StageTiming,
    ring_sends: u64,
    payload_bytes: u64,
    ws: &mut TileWorkspace,
) -> WorkerOut {
    let (lo, hi, block) = (payload.lo, payload.hi, payload.block);
    let rows = hi - lo;
    let (s, d) = (ctx.s, ctx.d);

    // ---- Top-k (merge): the global budget over all shards' proposals.
    let t0 = Instant::now();
    let (mut rho_sum, mut rho_n) = (0.0, 0usize);
    let mut sel_rows: Vec<Vec<usize>> = Vec::with_capacity(rows);
    for mut rc in payload.rows {
        match ctx.cfg.topk {
            TopkKind::None => sel_rows.push((0..s).collect()),
            TopkKind::Sads => {
                // Ascending segment order restores the single-core merge's
                // tie-breaking regardless of the ring visit order.
                rc.sads.sort_by_key(|l| l.seg);
                let survivors: usize = rc.sads.iter().map(|l| l.survivors).sum();
                rho_sum += survivors as f64 / s as f64;
                rho_n += 1;
                let (sel, _) = sads_merge(&rc.sads, ctx.keep.min(s), &mut ops.topk);
                sel_rows.push(sel);
            }
            TopkKind::Vanilla | TopkKind::Threshold => {
                rc.exact.sort_by_key(|&(_, idx)| idx);
                sel_rows.push(merge_topk_candidates(&rc.exact, ctx.keep, &mut ops.topk));
            }
        }
    }
    let t1 = Instant::now();
    timing.topk_s += (t1 - t0).as_secs_f64();
    // The distributed-selection merge is still accounted under the
    // top-k clock (it *is* stage 2), but traced as its own span so the
    // home phase is visible on the timeline. It reads only the payload
    // candidates already counted at the ring hops, so its byte delta is
    // legitimately 0.
    ws.spans.record(Stage::Merge, ExecPath::Sharded, lo as u32, t0, t1, 0);

    // ---- Stages 3 + 4 on the shared tile core: union → gather (only
    // the union crosses the ring — the sparse-attention win) → monotone
    // remap → formal compute, inside this worker's workspace.
    let exec = TileExecutor { cfg: ctx.cfg };
    let mut out = Mat::zeros(rows, d);
    let (stalls, u) = exec.gather_formal_block(
        ctx.inp,
        lo,
        &sel_rows,
        ctx.keep,
        ws,
        &mut ops,
        &mut timing,
        &mut out,
    );

    WorkerOut {
        block,
        lo,
        out,
        sel_rows,
        ops,
        timing,
        stalls,
        union_rows: u,
        rho_sum,
        rho_n,
        ring_sends,
        payload_bytes,
    }
}

/// Result of one [`ShardedPipeline::decode_step`]. The decode-side
/// fields carry the exact [`super::DecodeReport`] semantics (and are
/// bit-identical to the single-core step's at every shard count — see
/// the module docs); the sharded extras mirror [`ShardedReport`].
#[derive(Clone, Debug)]
pub struct ShardedDecodeReport {
    /// Attention output for the new rows `[rows, d]` — bit-identical to
    /// [`super::SparseAttentionPipeline::decode_step`] on the same
    /// store state and chunk.
    pub out: Mat,
    /// Per-new-row key selections in **absolute** token positions.
    pub selection: Selection,
    /// Global positions of the appended tokens within the session.
    pub positions: std::ops::Range<usize>,
    /// Per-stage operation counters summed over all workers (equal to
    /// the single-core step's for predict/KV-gen/formal; the exact
    /// top-k engines charge the distributed extraction instead of the
    /// monolithic scan — see `rust/tests/prop_sharded_decode_parity.rs`).
    pub ops: StageOps,
    /// Per-stage busy times summed over all workers.
    pub timing: StageTiming,
    /// End-to-end wall time, seconds.
    pub wall_s: f64,
    /// SU-FA max-misprediction recoveries.
    pub stalls: u64,
    /// KV rows gathered, summed over rows.
    pub union_rows: usize,
    /// Mean SADS survivor fraction ρ (0 when SADS did not run).
    pub rho_mean: f64,
    /// Keys kept for the last (longest-context) row.
    pub keep_last: usize,
    /// Distinct resident pages this step's gathers touched, excluding
    /// pages re-materialized by this very step.
    pub page_hits: usize,
    /// Pages rebuilt from history because the session had been evicted.
    pub rematerialized_pages: usize,
    /// Sessions that lost pages (page-granular LRU) to make room for
    /// this step.
    pub evicted_sessions: Vec<u64>,
    /// Store-wide residency after this step (see
    /// [`super::DecodeReport::residency`]).
    pub residency: ResidencySnapshot,
    /// Store-wide lifetime cache counters after this step.
    pub cache_stats: CacheStats,
    /// Effective worker count.
    pub shards: usize,
    /// Candidate-scatter rounds executed: 1 when more than one worker
    /// took part (the one-shot all-to-all of the module docs), else 0.
    pub ring_steps: usize,
    /// Modeled bytes of home-bound candidate batches across all workers.
    pub ring_payload_bytes: u64,
    /// Per-worker statistics, ascending shard index.
    pub per_shard: Vec<ShardStats>,
    /// Heap allocations metered inside the per-row gather + formal
    /// cores (zero in steady state on a warm [`super::WorkspacePool`];
    /// candidate batches traveling between threads own their storage
    /// and are excluded by design, like the prefill ring payload).
    pub hot_path_allocs: u64,
    /// Peak per-worker [`super::TileWorkspace`] heap capacity during
    /// this step, bytes.
    pub workspace_bytes: usize,
    /// Measured byte-level traffic merged over all workers (zero unless
    /// [`crate::obs::traffic::set_enabled`] turned counting on). All
    /// DRAM/SRAM-class totals equal the single-core step's; the
    /// scatter bytes are the `ring_payload_bytes` field inside the
    /// counter.
    pub traffic: TrafficCounter,
    /// Scheduler statistics: the decode schedule is static (one homed
    /// row block per worker), so `steals` is always 0 here.
    pub sched: SchedStats,
}

/// One worker's per-row candidate proposals for a decode step,
/// traveling from the proposing shard to the row's home worker in the
/// one-shot scatter — the decode counterpart of the prefill ring
/// payload, and like it the batch must own its storage.
#[derive(Clone, Debug, Default)]
struct DecodeRowProposals {
    /// Chunk-relative row index.
    row: usize,
    /// SADS: winner lists of the row sub-segments this shard owns
    /// (per-row geometry; global segment ids).
    sads: Vec<SegmentWinners>,
    /// Exact engines: `(score, absolute key index)` proposals.
    exact: Vec<(f32, usize)>,
}

/// Modeled wire size of one home-bound proposal batch: ~8 bytes per
/// candidate (f32 score + packed index) plus a 16-byte per-row header.
fn decode_wire_bytes(batch: &[DecodeRowProposals]) -> u64 {
    batch
        .iter()
        .map(|p| {
            let cands = p.exact.len() + p.sads.iter().map(|l| l.winners.len()).sum::<usize>();
            16 + 8 * cands as u64
        })
        .sum()
}

/// One home worker's finished decode rows plus that worker's statistics.
struct DecodeWorkerOut {
    block: usize,
    lo: usize,
    out: Mat,
    sel_rows: Vec<Vec<usize>>,
    ops: StageOps,
    timing: StageTiming,
    stalls: u64,
    union_rows: usize,
    rho_sum: f64,
    rho_n: usize,
    ring_sends: u64,
    payload_bytes: u64,
    /// Distinct page indices this block's gathers touched (ascending).
    touched_pages: Vec<usize>,
}

/// Shared read-only context for the decode worker threads.
struct DecodeCtx<'a> {
    cfg: &'a PipelineConfig,
    plan: &'a ShardPlan,
    /// The session's frozen pages, shared read-only by every shard.
    pages: &'a [&'a KvPage],
    /// Pre-encoded per-row prediction operands (empty when the top-k
    /// engine is `None` — dense execution scores nothing).
    qops: &'a [QueryOperand],
    q: &'a Mat,
    /// Global position of the chunk's first row.
    base: usize,
    scale: f32,
    page_size: usize,
    d: usize,
}

impl ShardedPipeline {
    /// Decode one chunk of a session whose paged KV cache is
    /// partitioned across this pipeline's workers — sharded counterpart
    /// of [`super::SparseAttentionPipeline::decode_step`], bit-identical
    /// to it at every shard count (see the module docs for why). Runs on
    /// a throwaway [`WorkspacePool`]; serving paths use
    /// [`ShardedPipeline::decode_step_pooled`].
    ///
    /// ```
    /// use star::kvcache::{SessionConfig, SessionStore};
    /// use star::pipeline::{PipelineConfig, ShardedPipeline, SparseAttentionPipeline};
    /// use star::tensor::Mat;
    /// use star::util::Rng;
    ///
    /// let cfg = PipelineConfig::star().with_keep(0.25).with_threads(1);
    /// let mut rng = Rng::new(11);
    /// let (q, k, v) = (
    ///     Mat::randn(48, 16, 1.0, &mut rng),
    ///     Mat::randn(48, 16, 1.0, &mut rng),
    ///     Mat::randn(48, 16, 1.0, &mut rng),
    /// );
    /// let mut single = SessionStore::new(SessionConfig::for_pipeline(&cfg, 16, 0));
    /// let mut sharded = SessionStore::new(SessionConfig::for_pipeline(&cfg, 16, 0));
    /// let a = SparseAttentionPipeline::new(cfg).decode_step(&mut single, 1, &q, &k, &v).unwrap();
    /// let b = ShardedPipeline::new(cfg, 3).decode_step(&mut sharded, 1, &q, &k, &v).unwrap();
    /// assert_eq!(b.out.max_abs_diff(&a.out), 0.0);
    /// assert_eq!(b.selection, a.selection);
    /// assert_eq!(b.stalls, a.stalls);
    /// ```
    pub fn decode_step(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k_new: &Mat,
        v_new: &Mat,
    ) -> crate::Result<ShardedDecodeReport> {
        self.decode_step_pooled(store, session, q, k_new, v_new, &WorkspacePool::new())
    }

    /// [`ShardedPipeline::decode_step`] with each worker drawing its
    /// [`TileWorkspace`] from `pool` — bit-identical outputs, zero
    /// hot-path allocations once the pool is warm for this shape class.
    pub fn decode_step_pooled(
        &self,
        store: &mut SessionStore,
        session: u64,
        q: &Mat,
        k_new: &Mat,
        v_new: &Mat,
        pool: &WorkspacePool,
    ) -> crate::Result<ShardedDecodeReport> {
        let started = Instant::now();
        anyhow::ensure!(
            q.rows == k_new.rows && q.rows == v_new.rows,
            "decode chunk rows disagree (Q {}, K {}, V {})",
            q.rows,
            k_new.rows,
            v_new.rows
        );
        anyhow::ensure!(
            q.cols == k_new.cols && q.cols == v_new.cols,
            "decode chunk head dims disagree (Q {}, K {}, V {})",
            q.cols,
            k_new.cols,
            v_new.cols
        );
        anyhow::ensure!(
            q.cols == store.config().d,
            "chunk head dim {} != session store head dim {}",
            q.cols,
            store.config().d
        );
        // The cached key operands were quantized at the store's bitwidth;
        // scoring them at a different W would silently skew prediction.
        anyhow::ensure!(
            self.cfg.predict_bits == store.config().predict_bits,
            "pipeline predict_bits {} != session store predict_bits {}",
            self.cfg.predict_bits,
            store.config().predict_bits
        );
        if let Err(e) = self.cfg.validate() {
            anyhow::bail!("invalid pipeline config: {e}");
        }
        let d = q.cols;
        let scale = 1.0 / (d as f32).sqrt();
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // Append + re-materialize under the KV-gen stage clock —
        // identical driver prologue to the single-core step.
        let t0 = Instant::now();
        let outcome = store.append(session, k_new, v_new, &mut ops)?;
        timing.kv_gen_s += t0.elapsed().as_secs_f64();

        let mut run_traffic = TrafficCounter::new();
        if traffic::enabled() {
            run_traffic.key_ingest_bytes += 4 * (k_new.rows * d) as u64;
            run_traffic.cache_append_bytes += 4 * (2 * k_new.rows * d) as u64;
            run_traffic.cache_remat_bytes += 4 * (2 * outcome.rematerialized_tokens * d) as u64;
        }

        let base = outcome.start;
        let rows = q.rows;
        let s_total = base + rows;
        let page_size = store.config().page_size;
        let keep_last = if s_total > 0 { self.cfg.keep(s_total) } else { 0 };

        if rows == 0 {
            return Ok(ShardedDecodeReport {
                out: Mat::zeros(0, d),
                selection: Selection { rows: Vec::new() },
                positions: base..base,
                ops,
                timing,
                wall_s: started.elapsed().as_secs_f64(),
                stalls: 0,
                union_rows: 0,
                rho_mean: 0.0,
                keep_last,
                page_hits: 0,
                rematerialized_pages: outcome.rematerialized_pages,
                evicted_sessions: outcome.evicted_sessions,
                residency: store.residency(),
                cache_stats: store.stats(),
                shards: 0,
                ring_steps: 0,
                ring_payload_bytes: 0,
                per_shard: Vec::new(),
                hot_path_allocs: 0,
                workspace_bytes: 0,
                traffic: run_traffic,
                sched: SchedStats::default(),
            });
        }

        // ---- Prologue: encode every new row's prediction operand once
        // (per-row quantization scales — the decode bit-identity
        // contract), shared read-only by all shards, so the encode
        // charges equal the single-core step's. ----
        let t0 = Instant::now();
        let qops: Vec<QueryOperand> = if self.cfg.topk == TopkKind::None {
            Vec::new() // dense execution scores nothing
        } else {
            (0..rows)
                .map(|r| {
                    QueryOperand::encode(
                        q.row(r),
                        self.cfg.predict,
                        self.cfg.predict_bits,
                        &mut ops.predict,
                    )
                })
                .collect()
        };
        if traffic::enabled() && self.cfg.topk != TopkKind::None {
            // One f32 query row read per row at encode time. (The shards'
            // operand-page streaming is charged at their local spans;
            // together the byte totals equal the single-core step's.)
            run_traffic.operand_read_bytes += 4 * (rows * d) as u64;
        }
        timing.predict_s += t0.elapsed().as_secs_f64();

        let plan = ShardPlan::for_decode(rows, s_total, self.shards);
        let w = plan.workers();
        let pages: Vec<&KvPage> = store.pages_of(session);
        let ctx = DecodeCtx {
            cfg: &self.cfg,
            plan: &plan,
            pages: &pages,
            qops: &qops,
            q,
            base,
            scale,
            page_size,
            d,
        };
        let class = ShapeClass::of(&self.cfg, d);

        // ---- One-shot scatter/gather: every worker runs the local pass
        // for every row over its own key range, sends each home worker
        // its rows' proposals (unbounded channels — all sends complete
        // before any worker blocks on receive), then serves as home for
        // its own row block: merge, gather, formal on the unchanged
        // single-core row core. ----
        let worker_outs: Vec<(DecodeWorkerOut, u64, usize, TrafficCounter)> =
            std::thread::scope(|scope| {
                let (txs, rxs): (Vec<_>, Vec<_>) =
                    (0..w).map(|_| channel::<Vec<DecodeRowProposals>>()).unzip();
                let ctx = &ctx;
                let mut handles = Vec::with_capacity(w);
                for (j, rx) in rxs.into_iter().enumerate() {
                    let my_txs: Vec<_> = txs.clone();
                    handles.push(scope.spawn(move || {
                        let mut ws = pool.checkout(class);
                        // Trace context for this shard: reserve span
                        // storage outside the metered cores, stamp the
                        // worker id and session.
                        ws.spans.reserve_if_enabled();
                        ws.spans.worker = j as u32;
                        ws.spans.session = session;
                        let mut my_ops = StageOps::default();
                        let mut my_timing = StageTiming::default();
                        let mut ring_sends = 0u64;
                        let mut payload_bytes = 0u64;
                        let mut batches: Vec<Vec<DecodeRowProposals>> = Vec::with_capacity(w);
                        for h in 0..w {
                            let (rlo, rhi) = ctx.plan.q_blocks[h];
                            let batch: Vec<DecodeRowProposals> = (rlo..rhi)
                                .map(|r| {
                                    decode_local_row(ctx, j, r, &mut my_ops, &mut my_timing, &mut ws)
                                })
                                .collect();
                            if h == j {
                                batches.push(batch);
                            } else {
                                let wb = decode_wire_bytes(&batch);
                                payload_bytes += wb;
                                ring_sends += 1;
                                if traffic::enabled() {
                                    ws.traffic.ring_payload_bytes += wb;
                                }
                                let t0 = Instant::now();
                                my_txs[h].send(batch).expect("home receiver alive");
                                ws.spans.record(
                                    Stage::Ring,
                                    ExecPath::Sharded,
                                    h as u32,
                                    t0,
                                    Instant::now(),
                                    wb,
                                );
                            }
                        }
                        drop(my_txs);
                        // Home phase: every other shard contributes one
                        // batch for this worker's rows.
                        for _ in 0..w.saturating_sub(1) {
                            batches.push(rx.recv().expect("proposal sender alive"));
                        }
                        let out = decode_home_phase(
                            ctx,
                            j,
                            batches,
                            my_ops,
                            my_timing,
                            ring_sends,
                            payload_bytes,
                            &mut ws,
                        );
                        let (hot, bytes, tr) =
                            (ws.take_hot_allocs(), ws.capacity_bytes(), ws.take_traffic());
                        pool.checkin(ws);
                        (out, hot, bytes, tr)
                    }));
                }
                drop(txs);
                handles.into_iter().map(|h| h.join().expect("decode shard worker panicked")).collect()
            });

        let mut hot_path_allocs = 0u64;
        let mut workspace_bytes = 0usize;
        let mut outs: Vec<DecodeWorkerOut> = Vec::with_capacity(w);
        for (o, hot, bytes, tr) in worker_outs {
            hot_path_allocs += hot;
            workspace_bytes = workspace_bytes.max(bytes);
            run_traffic.merge(&tr);
            outs.push(o);
        }
        outs.sort_by_key(|o| o.block);

        // ---- Merge worker results in block (= row) order. ----
        let mut out = Mat::zeros(rows, d);
        let mut sel_rows = Vec::with_capacity(rows);
        let mut stalls = 0u64;
        let mut union_rows = 0usize;
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        let mut ring_payload_bytes = 0u64;
        let mut per_shard = Vec::with_capacity(w);
        let mut touched: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
        for o in outs {
            for i in 0..o.out.rows {
                out.row_mut(o.lo + i).copy_from_slice(o.out.row(i));
            }
            sel_rows.extend(o.sel_rows);
            ops.merge(&o.ops);
            timing.merge(&o.timing);
            stalls += o.stalls;
            union_rows += o.union_rows;
            rho_sum += o.rho_sum;
            rho_n += o.rho_n;
            ring_payload_bytes += o.payload_bytes;
            touched.extend(o.touched_pages.iter().copied());
            let (key_lo, key_hi) = plan.key_ranges[o.block];
            per_shard.push(ShardStats {
                shard: o.block,
                coord: plan.coords[o.block],
                key_lo,
                key_hi,
                q_rows: o.out.rows,
                timing: o.timing,
                ring_sends: o.ring_sends,
                payload_bytes: o.payload_bytes,
            });
        }
        drop(ctx);
        drop(pages);
        // Hits = distinct pages read minus the pages this step had to
        // rebuild (hits and misses in the same per-step page units).
        let page_hits = touched.len().saturating_sub(outcome.rematerialized_pages);
        store.record_hits(page_hits as u64);

        Ok(ShardedDecodeReport {
            out,
            selection: Selection { rows: sel_rows },
            positions: base..base + rows,
            ops,
            timing,
            wall_s: started.elapsed().as_secs_f64(),
            stalls,
            union_rows,
            rho_mean: if rho_n > 0 { rho_sum / rho_n as f64 } else { 0.0 },
            keep_last,
            page_hits,
            rematerialized_pages: outcome.rematerialized_pages,
            evicted_sessions: outcome.evicted_sessions,
            residency: store.residency(),
            cache_stats: store.stats(),
            shards: w,
            ring_steps: if w > 1 { 1 } else { 0 },
            ring_payload_bytes,
            per_shard,
            hot_path_allocs,
            workspace_bytes,
            traffic: run_traffic,
            sched: SchedStats {
                workers: w as u64,
                chunk_grabs: w as u64,
                steals: 0,
                tiles: w as u64,
                max_worker_tiles: 1,
            },
        })
    }
}

/// The shard-local halves of the decode stages for one row on worker
/// `j`: score the owned key sub-range against the frozen page operands
/// (the same per-key kernel as the single-core row, via
/// [`score_row_range_into`]) and propose candidates from it. SADS
/// segment ownership follows the first-key rule over the row's own
/// geometry ([`sads_geometry`] at the row's causal limit), so the owned
/// sub-segments partition the row's segments across shards and each
/// per-segment pass sees exactly the slice the single-core scan forms.
fn decode_local_row(
    ctx: &DecodeCtx,
    j: usize,
    r: usize,
    ops: &mut StageOps,
    timing: &mut StageTiming,
    ws: &mut TileWorkspace,
) -> DecodeRowProposals {
    let mut prop = DecodeRowProposals { row: r, ..Default::default() };
    let cfg = ctx.cfg;
    if cfg.topk == TopkKind::None {
        return prop; // dense execution: the home phase selects 0..limit
    }
    let pos = ctx.base + r;
    let limit = pos + 1;
    let keep = cfg.keep(limit);
    let (key_lo, key_hi) = ctx.plan.key_ranges[j];
    let d = ctx.d;

    // Resolve this shard's scored span for the row; rows whose causal
    // limit ends before the owned range contribute nothing.
    let (span_lo, span_hi, sads_geom) = match cfg.topk {
        TopkKind::Sads => {
            let k_r = keep.min(limit);
            let (nseg, seg_len) = sads_geometry(limit, &cfg.sads);
            let n_quota = cfg.sads.segments.max(1).min(limit);
            let per_seg = k_r.div_ceil(n_quota);
            let seg_lo = key_lo.div_ceil(seg_len);
            let seg_hi = key_hi.div_ceil(seg_len).min(nseg);
            if k_r == 0 || seg_lo >= seg_hi {
                return prop;
            }
            let span_lo = seg_lo * seg_len;
            let span_hi = (seg_hi * seg_len).min(limit);
            (span_lo, span_hi, Some((seg_lo, seg_hi, seg_len, per_seg)))
        }
        // Threshold engines execute as vanilla selection, as in the
        // single-core pipeline (see PipelineConfig docs).
        TopkKind::Vanilla | TopkKind::Threshold => {
            let hi = key_hi.min(limit);
            if key_lo >= hi {
                return prop;
            }
            (key_lo, hi, None)
        }
        TopkKind::None => unreachable!(),
    };
    let span = span_hi - span_lo;

    // ---- Predict (local): score the owned span. Bit-identical to the
    // same elements of the single-core estimate (frozen page operands /
    // per-row scales / independent per-key dots), and the per-key
    // charges sum over the shard partition to the single-core row's. ----
    let t0 = Instant::now();
    let b0 = ws.traffic.total_bytes();
    ws.ensure_decode_shard(span, keep);
    {
        let (est_row, _, _) = ws.decode_score_topk_and_tmp();
        score_row_range_into(
            &ctx.qops[r],
            ctx.pages,
            span_lo,
            span_hi,
            ctx.scale,
            &mut ops.predict,
            est_row,
        );
    }
    if traffic::enabled() {
        // Quantized page operands (~1 B/elem) stream through the range
        // scorer, one f32 score per owned key out. The per-row f32
        // query read is charged once by the driver, not per shard.
        ws.traffic.operand_read_bytes += (span * d) as u64;
        ws.traffic.score_write_bytes += 4 * span as u64;
    }
    let t1 = Instant::now();
    timing.predict_s += (t1 - t0).as_secs_f64();
    let tb = ws.traffic.total_bytes() - b0;
    ws.spans.record(Stage::Predict, ExecPath::Sharded, pos as u32, t0, t1, tb);

    // ---- Top-k (local): propose candidates from the owned span. ----
    let t0 = Instant::now();
    let b0 = ws.traffic.total_bytes();
    let (est_row, topk, tmp) = ws.decode_score_topk_and_tmp();
    match sads_geom {
        Some((seg_lo, seg_hi, seg_len, per_seg)) => {
            for seg in seg_lo..seg_hi {
                let glo = seg * seg_len;
                let ghi = (glo + seg_len).min(limit);
                prop.sads.push(sads_segment_winners_scratch(
                    &est_row[glo - span_lo..ghi - span_lo],
                    glo,
                    seg,
                    per_seg,
                    cfg.sads.radius,
                    &mut ops.topk,
                    topk,
                ));
            }
        }
        None => {
            vanilla_topk_into(&est_row[..span], keep.min(span), &mut ops.topk, topk, tmp);
            // Proposal order is irrelevant here: the home phase sorts the
            // accumulated list by global index (the tie contract) before
            // merging.
            prop.exact.extend(tmp.iter().map(|&jj| (est_row[jj], span_lo + jj)));
        }
    }
    if traffic::enabled() {
        // The local score span is re-read once by the proposal pass.
        ws.traffic.score_read_bytes += 4 * span as u64;
    }
    let t1 = Instant::now();
    timing.topk_s += (t1 - t0).as_secs_f64();
    let tb = ws.traffic.total_bytes() - b0;
    ws.spans.record(Stage::Topk, ExecPath::Sharded, pos as u32, t0, t1, tb);
    prop
}

/// The decode home phase for worker `block`: fold every shard's
/// proposals into the global per-row selection with the identical merge
/// kernels the prefill home phase uses, then run the *unchanged*
/// single-core stage-3/4 row core
/// ([`TileExecutor::decode_gather_formal_row`]) per row — which is the
/// whole bit-identity argument: the formal math never sees the shard
/// count.
#[allow(clippy::too_many_arguments)]
fn decode_home_phase(
    ctx: &DecodeCtx,
    block: usize,
    batches: Vec<Vec<DecodeRowProposals>>,
    mut ops: StageOps,
    mut timing: StageTiming,
    ring_sends: u64,
    payload_bytes: u64,
    ws: &mut TileWorkspace,
) -> DecodeWorkerOut {
    let cfg = ctx.cfg;
    let (rlo, rhi) = ctx.plan.q_blocks[block];
    let nrows = rhi - rlo;
    let d = ctx.d;

    // ---- Top-k (merge): the global budget over all shards' proposals.
    // Ascending segment / key order restores the single-core
    // tie-breaking regardless of arrival order.
    let t0 = Instant::now();
    let mut row_sads: Vec<Vec<SegmentWinners>> = (0..nrows).map(|_| Vec::new()).collect();
    let mut row_exact: Vec<Vec<(f32, usize)>> = (0..nrows).map(|_| Vec::new()).collect();
    for batch in batches {
        for p in batch {
            debug_assert!((rlo..rhi).contains(&p.row), "proposal routed to the wrong home");
            let i = p.row - rlo;
            row_sads[i].extend(p.sads);
            row_exact[i].extend(p.exact);
        }
    }
    let (mut rho_sum, mut rho_n) = (0.0, 0usize);
    let mut sel_rows: Vec<Vec<usize>> = Vec::with_capacity(nrows);
    for i in 0..nrows {
        let limit = ctx.base + rlo + i + 1;
        let keep = cfg.keep(limit);
        match cfg.topk {
            TopkKind::None => sel_rows.push((0..limit).collect()),
            TopkKind::Sads => {
                let lists = &mut row_sads[i];
                lists.sort_by_key(|l| l.seg);
                let survivors: usize = lists.iter().map(|l| l.survivors).sum();
                rho_sum += survivors as f64 / limit as f64;
                rho_n += 1;
                let (sel, _) = sads_merge(lists, keep.min(limit), &mut ops.topk);
                sel_rows.push(sel);
            }
            TopkKind::Vanilla | TopkKind::Threshold => {
                let cands = &mut row_exact[i];
                cands.sort_by_key(|&(_, idx)| idx);
                sel_rows.push(merge_topk_candidates(cands, keep, &mut ops.topk));
            }
        }
    }
    let t1 = Instant::now();
    timing.topk_s += (t1 - t0).as_secs_f64();
    // Accounted under the top-k clock (it *is* stage 2), traced as its
    // own span; it reads only payload candidates already counted at the
    // scatter, so its byte delta is legitimately 0.
    ws.spans.record(Stage::Merge, ExecPath::Sharded, rlo as u32, t0, t1, 0);

    // ---- Stages 3 + 4 per row on the unchanged single-core decode
    // core: install the merged selection, gather from the same frozen
    // pages, run the same formal kernel in the same order.
    let exec = TileExecutor { cfg };
    let mut out = Mat::zeros(nrows, d);
    let mut stalls = 0u64;
    let mut union_rows = 0usize;
    let mut touched: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    for i in 0..nrows {
        let r = rlo + i;
        let pos = ctx.base + r;
        let limit = pos + 1;
        let keep = cfg.keep(limit);
        // Capacity maintenance outside the metered core, exactly like
        // the single-core row.
        ws.ensure_decode_row(limit, keep, d, cfg.bc, limit.div_ceil(ctx.page_size.max(1)));
        ws.spans.reserve_if_enabled();
        ws.set_decode_selection(&sel_rows[i]);
        let (st, u) = exec.decode_gather_formal_row(
            ctx.pages,
            ctx.q.row(r),
            pos,
            ctx.scale,
            ctx.page_size,
            ws,
            &mut ops,
            &mut timing,
        );
        out.row_mut(i).copy_from_slice(ws.decode_out_row());
        stalls += st;
        union_rows += u;
        touched.extend(ws.decode_row_pages().iter().copied());
    }

    DecodeWorkerOut {
        block,
        lo: rlo,
        out,
        sel_rows,
        ops,
        timing,
        stalls,
        union_rows,
        rho_sum,
        rho_n,
        ring_sends,
        payload_bytes,
        touched_pages: touched.into_iter().collect(),
    }
}

// The parity contract (bit-identical to the single-core pipeline across
// worker counts, tile sizes and sequence lengths) lives in
// `rust/tests/prop_sharded_parity.rs`; the unit tests here cover the
// partitioning geometry the contract rests on.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_aligns_with_sads_segments() {
        let cfg = PipelineConfig::star(); // 4 sub-segments
        for s in [64usize, 130, 257] {
            for req in [1usize, 2, 3, 4, 9] {
                let plan = ShardPlan::new(&cfg, 32, s, req);
                let w = plan.workers();
                assert!(w <= 4, "clamped to the segment count");
                let (nseg, seg_len) = sads_geometry(s, &cfg.sads);
                // Ranges tile 0..s contiguously and start on segment
                // boundaries.
                let mut at = 0usize;
                let mut segs = 0usize;
                for (j, &(lo, hi)) in plan.key_ranges.iter().enumerate() {
                    assert_eq!(lo, at, "s={s} req={req}: gap before shard {j}");
                    assert!(hi > lo, "s={s} req={req}: empty shard {j}");
                    assert_eq!(lo % seg_len, 0, "s={s} req={req}: misaligned shard {j}");
                    let (slo, shi) = plan.seg_ranges[j];
                    assert_eq!(slo * seg_len, lo);
                    segs += shi - slo;
                    at = hi;
                }
                assert_eq!(at, s);
                assert_eq!(segs, nseg, "every segment owned exactly once");
            }
        }
    }

    #[test]
    fn plan_covers_queries_and_mesh() {
        let cfg = PipelineConfig::star();
        let plan = ShardPlan::new(&cfg, 50, 256, 4);
        let w = plan.workers();
        assert_eq!(w, 4);
        assert_eq!(plan.coords.len(), w);
        // Q blocks tile 0..t; ring neighbors are mesh neighbors.
        let mut at = 0;
        for &(lo, hi) in &plan.q_blocks {
            assert_eq!(lo, at);
            at = hi;
        }
        assert_eq!(at, 50);
        for pair in plan.coords.windows(2) {
            assert_eq!(pair[0].manhattan(&pair[1]), 1, "snake placement broken");
        }
    }

    #[test]
    fn dense_and_exact_plans_split_evenly() {
        let cfg = PipelineConfig::dense_oracle();
        let plan = ShardPlan::new(&cfg, 16, 103, 4);
        assert_eq!(plan.workers(), 4);
        let mut at = 0;
        for &(lo, hi) in &plan.key_ranges {
            assert_eq!(lo, at);
            assert!(hi - lo >= 103 / 4);
            at = hi;
        }
        assert_eq!(at, 103);
    }

    #[test]
    fn empty_problems_short_circuit() {
        let pipe = ShardedPipeline::star(0.2, 4);
        let q = Mat::zeros(0, 8);
        let k = Mat::zeros(16, 8);
        let v = Mat::zeros(16, 8);
        let r = pipe.run(&PipelineInputs::qkv(&q, &k, &v));
        assert_eq!(r.out.rows, 0);
        assert_eq!(r.shards, 0);
        assert_eq!(r.ring_steps, 0);
    }
}
