//! [`PipelineConfig`] — the one config vocabulary for sparse-attention
//! execution, shared with the cycle-level simulator.
//!
//! The simulator's [`crate::sim::pipeline::FeatureSet`] names the same
//! three stage axes (prediction scheme × top-k engine × formal kernel);
//! `PipelineConfig` reuses those enums verbatim and adds the *algorithm*
//! knobs the simulator abstracts away: keep ratio, query-tile size, SU-FA
//! key-tile size, SADS parameters and the prediction bitwidth. The two
//! convert losslessly over the shared axes ([`PipelineConfig::feature_set`]
//! / [`PipelineConfig::from_features`]), so an algorithm run and a
//! cycle-level run of the same configuration are one struct apart.

use crate::config::SparsityConfig;
use crate::sim::pipeline::{FeatureSet, FormalKind, PredictKind, TopkKind};
use crate::sparsity::topk::SadsParams;

/// Full configuration of a [`super::SparseAttentionPipeline`].
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Prediction-stage scheme. [`PredictKind::None`] means *oracle*
    /// scores: exact Q·Kᵀ feeds the top-k stage (no prediction ops are
    /// charged) — the upper-bound configuration of Fig. 11 / Fig. 18(b).
    pub predict: PredictKind,
    /// Top-k engine. [`TopkKind::Threshold`] has no counted software
    /// implementation and is executed as `Vanilla` (the threshold engines
    /// it models are only distinguished at the cycle level).
    pub topk: TopkKind,
    /// Formal-compute kernel. [`FormalKind::Flash2`] is approximated as
    /// ascending SU-FA plus the cross-tile max-comparison stream FA-2
    /// pays (the Fig. 18a baseline accounting).
    pub formal: FormalKind,
    /// Top-k keep ratio γ ∈ (0, 1]; 1.0 keeps every key.
    pub keep_ratio: f64,
    /// Query-tile size B_r: how many query rows flow through
    /// predict → top-k → KV-gen → formal together. Intermediates stay
    /// `tile_t × S` instead of `T × S`.
    pub tile_t: usize,
    /// SU-FA key-tile size B_c.
    pub bc: usize,
    /// Magnitude bitwidth W of the prediction datapath.
    pub predict_bits: u32,
    /// SADS sub-segment count and sphere radius (radius in logit units —
    /// estimated scores are scaled by 1/√d before top-k).
    pub sads: SadsParams,
    /// Generate only the union of selected KV rows (charged as on-chip
    /// generation instead of a DRAM KV load) when activations are given.
    pub on_demand_kv: bool,
    /// Worker threads for independent query tiles (`std::thread::scope`);
    /// 0 picks `available_parallelism`. Results are deterministic and
    /// identical for every thread count.
    pub threads: usize,
}

impl PipelineConfig {
    /// The paper's STAR configuration: cross-phase DLZS prediction, SADS
    /// top-k, descending SU-FA, on-demand KV, γ = 0.2.
    pub fn star() -> PipelineConfig {
        PipelineConfig {
            predict: PredictKind::DlzsCross,
            topk: TopkKind::Sads,
            formal: FormalKind::SufaDescend,
            keep_ratio: 0.2,
            tile_t: 64,
            bc: 16,
            predict_bits: 7,
            sads: SadsParams::default(),
            on_demand_kv: true,
            threads: 0,
        }
    }

    /// Generic DS-accelerator baseline (Fig. 18a "baseline"): low-bit
    /// multiply prediction, vanilla sorting, FA-2-style formal compute,
    /// precomputed KV.
    pub fn ds_baseline() -> PipelineConfig {
        PipelineConfig {
            predict: PredictKind::LowBitMul,
            topk: TopkKind::Vanilla,
            formal: FormalKind::Flash2,
            on_demand_kv: false,
            ..PipelineConfig::star()
        }
    }

    /// Dense oracle: no prediction, no top-k, exact dense softmax. With
    /// `keep_ratio = 1.0` this reproduces
    /// [`crate::attention::dense_attention`] bit-for-bit per row.
    pub fn dense_oracle() -> PipelineConfig {
        PipelineConfig {
            predict: PredictKind::None,
            topk: TopkKind::None,
            formal: FormalKind::Dense,
            keep_ratio: 1.0,
            on_demand_kv: false,
            ..PipelineConfig::star()
        }
    }

    /// STAR pipeline parameterized by a serving [`SparsityConfig`].
    pub fn from_sparsity(cfg: &SparsityConfig) -> PipelineConfig {
        PipelineConfig {
            keep_ratio: cfg.topk_ratio,
            predict_bits: cfg.predict_bits,
            sads: SadsParams { segments: cfg.segments, radius: cfg.radius },
            ..PipelineConfig::star()
        }
    }

    /// Algorithm-side view of a simulator [`FeatureSet`] (the shared axes
    /// carry over; algorithm knobs take their STAR defaults).
    pub fn from_features(f: &FeatureSet, keep_ratio: f64) -> PipelineConfig {
        PipelineConfig {
            predict: f.predict,
            topk: f.topk,
            formal: f.formal,
            on_demand_kv: f.on_demand_kv,
            keep_ratio,
            ..PipelineConfig::star()
        }
    }

    /// Simulator view of this configuration. The algorithm layer always
    /// executes cross-stage tiled with out-of-order tile issue and
    /// stall-absorbing SU-FA, so those architectural flags are always
    /// set — `threads` is a *host* knob (how many CPU workers run the
    /// software model) and deliberately does not alter the simulated
    /// hardware features.
    pub fn feature_set(&self) -> FeatureSet {
        FeatureSet {
            predict: self.predict,
            topk: self.topk,
            formal: self.formal,
            on_demand_kv: self.on_demand_kv,
            tiled_dataflow: true,
            oo_scheduler: true,
            sufa_tailored: true,
        }
    }

    /// Check the invariants [`super::SparseAttentionPipeline::new`]
    /// enforces. `Err` carries the violation, letting servers treat a
    /// misconfiguration as a recoverable error instead of a panic; the
    /// constructor and the serving backend share this single source of
    /// truth.
    pub fn validate(&self) -> Result<(), String> {
        if self.tile_t == 0 {
            return Err("tile_t must be positive".into());
        }
        if self.bc == 0 {
            return Err("bc must be positive".into());
        }
        if !(self.keep_ratio > 0.0 && self.keep_ratio <= 1.0) {
            return Err(format!("keep_ratio must be in (0, 1], got {}", self.keep_ratio));
        }
        Ok(())
    }

    /// Keys retained for a context of `s` keys (≥ 1, ≤ s; matches
    /// [`SparsityConfig::keep`]).
    pub fn keep(&self, s: usize) -> usize {
        if s == 0 {
            return 0;
        }
        if self.topk == TopkKind::None {
            return s;
        }
        ((s as f64 * self.keep_ratio).round() as usize).clamp(1, s)
    }

    /// Builder-style keep-ratio override.
    pub fn with_keep(mut self, keep_ratio: f64) -> PipelineConfig {
        self.keep_ratio = keep_ratio;
        self
    }

    /// Builder-style tile-size override.
    pub fn with_tile(mut self, tile_t: usize) -> PipelineConfig {
        assert!(tile_t > 0, "tile_t must be positive");
        self.tile_t = tile_t;
        self
    }

    /// Builder-style thread-count override.
    pub fn with_threads(mut self, threads: usize) -> PipelineConfig {
        self.threads = threads;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn star_roundtrips_through_feature_set() {
        let cfg = PipelineConfig::star();
        let feats = cfg.feature_set();
        assert_eq!(feats.predict, PredictKind::DlzsCross);
        assert_eq!(feats.topk, TopkKind::Sads);
        assert_eq!(feats.formal, FormalKind::SufaDescend);
        assert!(feats.on_demand_kv && feats.tiled_dataflow && feats.sufa_tailored);
        let back = PipelineConfig::from_features(&feats, cfg.keep_ratio);
        assert_eq!(back.predict, cfg.predict);
        assert_eq!(back.topk, cfg.topk);
        assert_eq!(back.formal, cfg.formal);
        assert_eq!(back.on_demand_kv, cfg.on_demand_kv);
        assert_eq!(back.keep_ratio, cfg.keep_ratio);
    }

    #[test]
    fn ds_baseline_matches_sim_ds_baseline_axes() {
        let cfg = PipelineConfig::ds_baseline();
        let sim = FeatureSet::ds_baseline();
        assert_eq!(cfg.predict, sim.predict);
        assert_eq!(cfg.topk, sim.topk);
        assert_eq!(cfg.formal, sim.formal);
        assert_eq!(cfg.on_demand_kv, sim.on_demand_kv);
    }

    #[test]
    fn keep_clamps_and_dense_keeps_all() {
        let cfg = PipelineConfig::star().with_keep(0.25);
        assert_eq!(cfg.keep(1024), 256);
        assert_eq!(cfg.keep(1), 1);
        assert_eq!(cfg.keep(0), 0);
        assert_eq!(PipelineConfig::dense_oracle().keep(77), 77);
        let tiny = PipelineConfig::star().with_keep(1e-9);
        assert_eq!(tiny.keep(1000), 1);
    }

    #[test]
    fn from_sparsity_carries_knobs() {
        let sc = SparsityConfig { topk_ratio: 0.15, segments: 8, radius: 3.0, predict_bits: 5 };
        let cfg = PipelineConfig::from_sparsity(&sc);
        assert_eq!(cfg.keep_ratio, 0.15);
        assert_eq!(cfg.sads.segments, 8);
        assert_eq!(cfg.sads.radius, 3.0);
        assert_eq!(cfg.predict_bits, 5);
    }
}
