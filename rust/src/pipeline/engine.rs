//! The tile-execution core: one allocation-free implementation of the
//! predict → top-k → KV-gen → SU-FA stage loop, driven by all three
//! pipeline front-ends.
//!
//! STAR's central claim is that cross-stage coordinated tiling keeps a
//! tile's operands resident in **fixed on-chip buffers** across all four
//! stages (PAPER.md §IV). This module is the software realization of
//! those buffers:
//!
//! * [`TileWorkspace`] — one preallocated, config-sized scratch set per
//!   worker thread: the staged Q tile, the score tile, the top-k
//!   candidate arena, the gathered-KV staging buffers and the SU-FA
//!   accumulators. Reused across tiles *and across requests*; buffers
//!   only ever grow, so the steady-state stage core performs **zero
//!   heap allocations** (metered per thread by
//!   [`crate::util::allocmeter`] and reported as
//!   `hot_path_allocs` in every pipeline report).
//! * `TileExecutor` (crate-internal) — the stage bodies themselves. The batch prefill
//!   path ([`super::SparseAttentionPipeline::run`]), the autoregressive
//!   decode path ([`super::SparseAttentionPipeline::decode_step`]) and
//!   the sequence-sharded path ([`super::ShardedPipeline`]) all drive
//!   these methods instead of keeping three divergent copies of the
//!   loop.
//! * [`WorkspacePool`] — workspaces keyed by [`ShapeClass`], so a
//!   serving worker reuses one warm workspace per shape class across
//!   requests and steady-state serving allocates nothing on the hot
//!   path.
//!
//! # Workspace ↔ SRAM correspondence
//!
//! [`TileWorkspace::capacity_bytes`] is the software working set of one
//! tile in flight — the direct analogue of the modeled on-chip SRAM
//! residency ([`crate::sim::sram`]). Reports carry it as
//! `workspace_bytes` next to the simulator's budget
//! ([`crate::sim::sram::Sram::STAR_BUDGET_BYTES`]) so the reproduction's
//! working set is checkable against the modeled hardware (DESIGN.md §8).
//!
//! # What "zero hot-path allocations" means
//!
//! The metered region is the four-stage compute core per tile/row. Three
//! things are deliberately *outside* it and documented as such:
//! capacity maintenance (`reserve`-style growth as a decode context
//! lengthens — amortized, monotone), result materialization (the
//! returned report's output matrix and selection rows must outlive the
//! workspace), and the sharded ring payload (candidate lists that travel
//! between threads must own their storage).

use super::config::PipelineConfig;
use super::exec::PipelineInputs;
use super::report::{StageOps, StageTiming};
use crate::arith::{OpCounter, OpKind};
use crate::attention::{sufa_attention_rows_into, AttnInputs, SufaParams, SufaScratch, UpdateOrder};
use crate::kvcache::{gather_rows_into, score_row_into, KvPage, QueryOperand};
use crate::obs::trace::{ExecPath, Span, SpanRing, Stage};
use crate::obs::traffic::{self, SchedStats, TrafficCounter};
use crate::sim::pipeline::{FormalKind, PredictKind, TopkKind};
use crate::sparsity::topk::{sads_topk_into, vanilla_topk_into, TopkScratch};
use crate::sparsity::{PredictScheme, Predictor, PreparedPredict};
use crate::tensor::Mat;
use crate::util::allocmeter;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// How the top-k stage obtains its scores. Shared by the batch, decode
/// and sharded front-ends so the predict prologue is one code path.
pub(crate) enum ScoreSource {
    /// No scores: selection is the full natural-order key set.
    None,
    /// Oracle: exact Q·Kᵀ (no prediction ops charged).
    Exact,
    /// Counted approximate prediction over prepared operands.
    Prepared(PreparedPredict),
}

/// The predict-stage prologue: prepare operands once, with globally
/// chosen quantization scales. The global-scale contract is what keeps
/// per-tile (and per-shard) scoring bit-identical to whole-matrix
/// scoring.
pub(crate) fn prepare_score_source(
    cfg: &PipelineConfig,
    inp: &PipelineInputs,
    c: &mut OpCounter,
) -> ScoreSource {
    // Scores feed the top-k stage only; dense execution (topk = None)
    // selects every key in natural order and skips prediction.
    if cfg.topk == TopkKind::None {
        return ScoreSource::None;
    }
    match cfg.predict {
        PredictKind::None => ScoreSource::Exact,
        PredictKind::DlzsCross => {
            let pred = Predictor::new(PredictScheme::Dlzs, cfg.predict_bits);
            match (inp.x, inp.wk) {
                (Some(x), Some(wk)) => {
                    // Phase 1.1 once; phase 1.2 runs per tile.
                    let khat = pred.khat_phase(x, wk, c);
                    ScoreSource::Prepared(pred.prepare(inp.q, &khat, c))
                }
                // No activations: plain DLZS on (Q, K).
                _ => ScoreSource::Prepared(pred.prepare(inp.q, inp.k, c)),
            }
        }
        PredictKind::Slzs => {
            let pred = Predictor::new(PredictScheme::Slzs, cfg.predict_bits);
            ScoreSource::Prepared(pred.prepare(inp.q, inp.k, c))
        }
        PredictKind::LowBitMul => {
            let pred = Predictor::new(PredictScheme::LowBitMul, cfg.predict_bits);
            ScoreSource::Prepared(pred.prepare(inp.q, inp.k, c))
        }
    }
}

/// Charge on-demand generation of `u` union KV rows from `[u, h]`
/// activations into `d` columns. Shared by the batch tile path and the
/// sharded home phase so the KV-gen accounting can never drift between
/// the front-ends.
pub(crate) fn charge_on_demand_kv_gen(c: &mut OpCounter, u: usize, h: usize, d: usize) {
    // Generate K and V rows for the union only: d columns × h MACs
    // each, for two matrices. X rows stream on chip (int8).
    c.tally(OpKind::Mul, 2 * (u * h * d) as u64);
    c.tally(OpKind::Add, 2 * (u * h.saturating_sub(1) * d) as u64);
    c.dram((u * h) as u64);
    c.sram(2 * (2 * u * d) as u64); // generated INT16 KV tile
}

/// Reclassify the formal stage's KV share of DRAM traffic (`u` K+V rows
/// of `d` f32 columns) as on-chip: under cross-stage tiling the formal
/// stage streams just-generated/cached KV out of SRAM, not DRAM (Q and
/// O still move). Shared by the tile, decode-row and sharded home paths.
pub(crate) fn kv_traffic_on_chip(c: &mut OpCounter, u: usize, d: usize) {
    let kv_bytes = 4 * (2 * u * d) as u64;
    c.dram_bytes -= kv_bytes.min(c.dram_bytes);
    c.sram(kv_bytes);
}

/// The shape class a workspace is sized for. Pools key workspaces by
/// class so a giant sharded-prefill workspace is never handed to a tiny
/// decode request (and vice versa) — capacity stays proportional to the
/// traffic that class actually sees.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeClass {
    /// Head dimension d.
    pub d: usize,
    /// Query-tile size B_r.
    pub tile_t: usize,
    /// SU-FA key-tile size B_c.
    pub bc: usize,
}

impl ShapeClass {
    /// The class a pipeline of this config serves at head dimension `d`.
    pub fn of(cfg: &PipelineConfig, d: usize) -> ShapeClass {
        ShapeClass { d, tile_t: cfg.tile_t, bc: cfg.bc }
    }
}

/// Reusable per-row selection storage: a vector of index rows whose
/// inner buffers survive `begin` (cleared, capacity retained), so
/// selections are assembled without per-tile allocations.
#[derive(Clone, Debug, Default)]
struct SelArena {
    rows: Vec<Vec<usize>>,
    used: usize,
}

impl SelArena {
    /// Start a tile of `n` rows: grow the arena if needed, clear the
    /// first `n` rows, keep their capacity.
    fn begin(&mut self, n: usize) {
        while self.rows.len() < n {
            self.rows.push(Vec::new());
        }
        for r in &mut self.rows[..n] {
            r.clear();
        }
        self.used = n;
    }

    /// The active rows of the current tile.
    fn rows(&self) -> &[Vec<usize>] {
        &self.rows[..self.used]
    }

    fn row_mut(&mut self, i: usize) -> &mut Vec<usize> {
        debug_assert!(i < self.used);
        &mut self.rows[i]
    }

    /// Pre-grow `n` rows to `per_row` capacity each.
    fn reserve(&mut self, n: usize, per_row: usize) {
        while self.rows.len() < n {
            self.rows.push(Vec::new());
        }
        for r in &mut self.rows[..n] {
            if r.capacity() < per_row {
                r.reserve(per_row - r.len());
            }
        }
    }

    fn capacity_bytes(&self) -> usize {
        self.rows.capacity() * std::mem::size_of::<Vec<usize>>()
            + self.rows.iter().map(|r| r.capacity() * std::mem::size_of::<usize>()).sum::<usize>()
    }
}

/// Reusable scratch for the formal stage: the SU-FA buffers plus the
/// dense kernel's sort/logit/membership buffers.
#[derive(Clone, Debug, Default)]
pub(crate) struct FormalScratch {
    sufa: SufaScratch,
    /// Sorted copy of an unsorted selection row (dense kernel fallback).
    sort: Vec<usize>,
    /// Dense kernel's per-row logits.
    logits: Vec<f32>,
    /// Dense kernel's union-membership flags (traffic accounting).
    needed: Vec<bool>,
}

impl FormalScratch {
    fn reserve(&mut self, d: usize, bc: usize, s: usize) {
        self.sufa.reserve(d, bc, s);
        reserve_to(&mut self.sort, s);
        reserve_to(&mut self.logits, s);
        reserve_to(&mut self.needed, s);
    }

    fn capacity_bytes(&self) -> usize {
        self.sufa.capacity_bytes()
            + self.sort.capacity() * std::mem::size_of::<usize>()
            + self.logits.capacity() * std::mem::size_of::<f32>()
            + self.needed.capacity() * std::mem::size_of::<bool>()
    }
}

/// Grow `v`'s capacity to at least `n` elements (never shrinks).
fn reserve_to<T>(v: &mut Vec<T>, n: usize) {
    if v.capacity() < n {
        v.reserve(n - v.len());
    }
}

/// One worker thread's preallocated stage scratch: quantized/encoded
/// query operands, the score tile, the top-k candidate arena, gathered
/// KV staging, SU-FA accumulators and the output staging row. Construct
/// via [`WorkspacePool::checkout`] (or [`TileWorkspace::new`] directly);
/// reuse across tiles and requests of the same [`ShapeClass`].
#[derive(Debug)]
pub struct TileWorkspace {
    class: ShapeClass,
    /// Staged query rows of the tile in flight.
    q_tile: Mat,
    /// Score tile Â[tile rows × key span].
    est: Mat,
    /// Per-row score vector (decode path).
    est_row: Vec<f32>,
    /// Reusable encoded query operand (decode path).
    qop: QueryOperand,
    /// Top-k extraction scratch.
    topk: TopkScratch,
    /// Selection rows of the tile in flight.
    sel: SelArena,
    /// Monotone remap of the selection onto the gathered rows.
    remap: SelArena,
    /// Union-membership flags over the context.
    needed: Vec<bool>,
    /// Sorted union of selected keys.
    union: Vec<usize>,
    /// Gathered K staging.
    ku: Mat,
    /// Gathered V staging.
    vu: Mat,
    /// Distinct page indices a decode row's union touched.
    row_pages: Vec<usize>,
    /// Formal-stage scratch.
    formal: FormalScratch,
    /// Output staging for paths whose result row is copied out.
    out_tile: Mat,
    /// Heap allocations observed inside metered stage cores since the
    /// last [`TileWorkspace::take_hot_allocs`].
    hot_allocs: u64,
    /// This worker's span ring (tracing). Storage is reserved in the
    /// front-end preambles only while tracing is enabled, so recording
    /// from inside the metered stage cores never allocates.
    pub(crate) spans: SpanRing,
    /// This worker's measured byte-traffic counters. Plain `u64` fields
    /// bumped with pure arithmetic inside the metered stage cores (one
    /// relaxed atomic load gates each site), drained per run via the
    /// pool — see [`crate::obs::traffic`].
    pub(crate) traffic: TrafficCounter,
}

impl TileWorkspace {
    /// A cold workspace for the given shape class. Buffers warm (grow to
    /// their steady-state capacity) over the first tiles they serve.
    pub fn new(class: ShapeClass) -> TileWorkspace {
        TileWorkspace {
            class,
            q_tile: Mat::zeros(0, 0),
            est: Mat::zeros(0, 0),
            est_row: Vec::new(),
            qop: QueryOperand::reusable(),
            topk: TopkScratch::default(),
            sel: SelArena::default(),
            remap: SelArena::default(),
            needed: Vec::new(),
            union: Vec::new(),
            ku: Mat::zeros(0, 0),
            vu: Mat::zeros(0, 0),
            row_pages: Vec::new(),
            formal: FormalScratch::default(),
            out_tile: Mat::zeros(0, 0),
            hot_allocs: 0,
            spans: SpanRing::new(),
            traffic: TrafficCounter::new(),
        }
    }

    /// The shape class this workspace is pooled under.
    pub fn class(&self) -> ShapeClass {
        self.class
    }

    /// Total heap capacity currently held by every *stage* buffer, in
    /// bytes — the software working set reported next to the modeled
    /// SRAM budget ([`crate::sim::sram::Sram::STAR_BUDGET_BYTES`]). The
    /// span ring is excluded: it is observability state, not part of the
    /// tile's modeled on-chip residency (the traffic counter holds no
    /// heap at all).
    pub fn capacity_bytes(&self) -> usize {
        let mat = |m: &Mat| m.data.capacity() * std::mem::size_of::<f32>();
        mat(&self.q_tile)
            + mat(&self.est)
            + mat(&self.ku)
            + mat(&self.vu)
            + mat(&self.out_tile)
            + self.est_row.capacity() * std::mem::size_of::<f32>()
            + self.qop.capacity_bytes()
            + self.topk.capacity_bytes()
            + self.sel.capacity_bytes()
            + self.remap.capacity_bytes()
            + self.needed.capacity() * std::mem::size_of::<bool>()
            + self.union.capacity() * std::mem::size_of::<usize>()
            + self.row_pages.capacity() * std::mem::size_of::<usize>()
            + self.formal.capacity_bytes()
    }

    /// Drain the metered hot-path allocation count (reset to zero).
    /// Zero in steady state; warm-up growth of a cold workspace is the
    /// only expected non-zero reading.
    pub fn take_hot_allocs(&mut self) -> u64 {
        std::mem::take(&mut self.hot_allocs)
    }

    /// Append this workspace's captured spans to `out` (oldest first)
    /// and reset its ring. Ring storage stays reserved.
    pub fn drain_spans(&mut self, out: &mut Vec<Span>) {
        self.spans.drain_into(out);
    }

    /// Drain the measured byte-traffic counters (reset to zero).
    pub fn take_traffic(&mut self) -> TrafficCounter {
        self.traffic.take()
    }

    /// Split borrow for the sharded local pass: the stage-1 score tile
    /// (read-only), the top-k scratch, and a reusable index row for
    /// local proposals (the union buffer, which is free until the home
    /// phase).
    pub(crate) fn est_topk_and_tmp(&mut self) -> (&Mat, &mut TopkScratch, &mut Vec<usize>) {
        (&self.est, &mut self.topk, &mut self.union)
    }

    /// Capacity maintenance ahead of a prefill tile of `rows × span`
    /// scores over a context of `s` keys (outside the metered core).
    #[allow(clippy::too_many_arguments)]
    fn ensure_tile(
        &mut self,
        rows: usize,
        span: usize,
        s: usize,
        keep: usize,
        d: usize,
        bc: usize,
    ) {
        self.q_tile.reset(rows, d);
        self.est.reset(rows, span);
        self.topk.reserve(span);
        self.sel.reserve(rows, keep.max(1));
        self.remap.reserve(rows, keep.max(1));
        reserve_to(&mut self.needed, s);
        reserve_to(&mut self.union, s);
        self.formal.reserve(d, bc, s);
    }

    /// Capacity maintenance ahead of one decode row at causal context
    /// `limit` (outside the metered core). `pub(crate)` so the sharded
    /// decode home phase can warm the same buffers before its metered
    /// merge + formal core.
    pub(crate) fn ensure_decode_row(
        &mut self,
        limit: usize,
        keep: usize,
        d: usize,
        bc: usize,
        pages: usize,
    ) {
        reserve_to(&mut self.est_row, limit);
        self.qop.reserve(d);
        self.topk.reserve(limit);
        self.sel.reserve(1, keep.max(1));
        self.remap.reserve(1, keep.max(1));
        reserve_to(&mut self.union, keep.max(1));
        reserve_to(&mut self.row_pages, pages);
        self.q_tile.reset(1, d);
        self.ku.reset(keep, d);
        self.vu.reset(keep, d);
        self.out_tile.reset(1, d);
        self.formal.reserve(d, bc, keep.max(1));
    }

    /// Capacity maintenance ahead of one sharded-decode local pass over
    /// a key span of `span` scores proposing at most `keep` candidates
    /// (outside the metered core).
    pub(crate) fn ensure_decode_shard(&mut self, span: usize, keep: usize) {
        reserve_to(&mut self.est_row, span);
        self.topk.reserve(span);
        reserve_to(&mut self.union, keep.max(1));
    }

    /// Split borrow for the sharded-decode local pass: the per-row score
    /// buffer, the top-k scratch and a reusable index row for local
    /// proposals (the union buffer, free until the home phase).
    pub(crate) fn decode_score_topk_and_tmp(
        &mut self,
    ) -> (&mut Vec<f32>, &mut TopkScratch, &mut Vec<usize>) {
        (&mut self.est_row, &mut self.topk, &mut self.union)
    }

    /// Install a merged selection as the current single decode row (the
    /// sharded home phase's entry into [`TileExecutor::decode_gather_formal_row`]).
    /// The row buffer must already be reserved via
    /// [`TileWorkspace::ensure_decode_row`].
    pub(crate) fn set_decode_selection(&mut self, keys: &[usize]) {
        self.sel.begin(1);
        self.sel.row_mut(0).extend_from_slice(keys);
    }

    /// The current single decode row's selection (as installed by stage 2
    /// or [`TileWorkspace::set_decode_selection`]).
    pub(crate) fn decode_selection(&self) -> &[usize] {
        &self.sel.rows()[0]
    }

    /// The output row staged by the last
    /// [`TileExecutor::decode_gather_formal_row`].
    pub(crate) fn decode_out_row(&self) -> &[f32] {
        self.out_tile.row(0)
    }

    /// Distinct page indices the last decode row's union touched
    /// (ascending) — the cache-hit accounting input.
    pub(crate) fn decode_row_pages(&self) -> &[usize] {
        &self.row_pages
    }
}

/// A pool of [`TileWorkspace`]s keyed by [`ShapeClass`]. Serving
/// workers hold one pool each and check a workspace out per run — after
/// the first request of a shape class, the checked-out workspace is
/// warm and the run's stage cores allocate nothing.
///
/// ```
/// use star::pipeline::engine::{ShapeClass, WorkspacePool};
/// use star::pipeline::PipelineConfig;
///
/// let pool = WorkspacePool::new();
/// let class = ShapeClass::of(&PipelineConfig::star(), 64);
/// let ws = pool.checkout(class);      // cold: fresh workspace
/// pool.checkin(ws);
/// let ws = pool.checkout(class);      // warm: the same buffers return
/// assert_eq!(ws.class(), class);
/// pool.checkin(ws);
/// assert_eq!(pool.resident_workspaces(), 1);
/// ```
#[derive(Debug, Default)]
pub struct WorkspacePool {
    slots: Mutex<BTreeMap<ShapeClass, Vec<TileWorkspace>>>,
}

impl WorkspacePool {
    /// An empty pool.
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Take a workspace of the given class (warm if one is pooled,
    /// freshly constructed otherwise).
    pub fn checkout(&self, class: ShapeClass) -> TileWorkspace {
        self.slots
            .lock()
            .unwrap()
            .get_mut(&class)
            .and_then(Vec::pop)
            .unwrap_or_else(|| TileWorkspace::new(class))
    }

    /// Return a workspace for reuse by later runs of its class.
    pub fn checkin(&self, ws: TileWorkspace) {
        self.slots.lock().unwrap().entry(ws.class()).or_default().push(ws);
    }

    /// Workspaces currently checked in.
    pub fn resident_workspaces(&self) -> usize {
        self.slots.lock().unwrap().values().map(Vec::len).sum()
    }

    /// Total heap capacity of the checked-in workspaces, in bytes — the
    /// steady-state software working set a server holds per worker,
    /// reported next to the modeled SRAM budget.
    pub fn resident_bytes(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .flat_map(|v| v.iter())
            .map(TileWorkspace::capacity_bytes)
            .sum()
    }

    /// Drain captured spans from every checked-in workspace into `out`.
    /// Workspaces currently checked out (runs in flight) contribute on
    /// their next drain after checkin.
    pub fn drain_spans(&self, out: &mut Vec<Span>) {
        for ws in self.slots.lock().unwrap().values_mut().flat_map(|v| v.iter_mut()) {
            ws.drain_spans(out);
        }
    }

    /// Drain and merge the measured byte-traffic counters of every
    /// checked-in workspace. The run drivers drain per run (reports
    /// carry per-run traffic), so this collects only counts from paths
    /// that bypassed a driver (diagnostics / direct engine use).
    pub fn drain_traffic(&self) -> TrafficCounter {
        let mut t = TrafficCounter::new();
        for ws in self.slots.lock().unwrap().values_mut().flat_map(|v| v.iter_mut()) {
            t.merge(&ws.traffic.take());
        }
        t
    }
}

/// Shared read-only context for tile workers.
pub(crate) struct TileCtx<'a> {
    pub(crate) cfg: &'a PipelineConfig,
    pub(crate) inp: &'a PipelineInputs<'a>,
    pub(crate) score: &'a ScoreSource,
    /// K pre-transposed for the oracle score path.
    pub(crate) kt: Option<&'a Mat>,
    pub(crate) keep: usize,
}

/// One prefill tile's results, merged after the parallel section.
pub(crate) struct TileOut {
    pub(crate) lo: usize,
    pub(crate) out: Mat,
    pub(crate) sel_rows: Vec<Vec<usize>>,
    pub(crate) ops: StageOps,
    pub(crate) timing: StageTiming,
    pub(crate) stalls: u64,
    pub(crate) union_rows: usize,
    pub(crate) rho_sum: f64,
    pub(crate) rho_n: usize,
}

/// One decoded row's results, merged after the parallel section.
pub(crate) struct DecodeRowOut {
    pub(crate) out: Vec<f32>,
    pub(crate) sel: Vec<usize>,
    pub(crate) ops: StageOps,
    pub(crate) timing: StageTiming,
    pub(crate) stalls: u64,
    pub(crate) union_rows: usize,
    pub(crate) rho: Option<f64>,
    /// Distinct page indices this row's selection read (ascending).
    pub(crate) pages: Vec<usize>,
}

/// The one place a score row becomes a selection row — both the prefill
/// and the decode selection paths assemble their `sel_rows` through
/// this helper, so the two can never drift. `scores == None` (or a
/// dense `topk == None` config) selects the full natural-order prefix
/// `0..limit`; SADS and the exact engines select `keep` of it.
/// Returns the SADS survivor fraction ρ when SADS ran.
pub(crate) fn select_into(
    cfg: &PipelineConfig,
    scores: Option<&[f32]>,
    limit: usize,
    keep: usize,
    scratch: &mut TopkScratch,
    out: &mut Vec<usize>,
    c: &mut OpCounter,
) -> Option<f64> {
    match (cfg.topk, scores) {
        (TopkKind::None, _) | (_, None) => {
            // Dense execution: every key, natural order.
            out.clear();
            out.extend(0..limit);
            None
        }
        (TopkKind::Sads, Some(e)) => Some(sads_topk_into(e, keep, &cfg.sads, c, scratch, out).rho),
        // Threshold engines have no counted software implementation;
        // executed as vanilla selection (see PipelineConfig docs).
        (TopkKind::Vanilla | TopkKind::Threshold, Some(e)) => {
            vanilla_topk_into(e, keep, c, scratch, out);
            None
        }
    }
}

/// Ascending union of the selected keys over `rows` — exactly
/// [`crate::attention::Selection::union_keys`], assembled into reusable
/// buffers (the KV rows the on-demand generation stage must produce).
pub(crate) fn union_rows_into(
    rows: &[Vec<usize>],
    s: usize,
    needed: &mut Vec<bool>,
    out: &mut Vec<usize>,
) {
    needed.clear();
    needed.resize(s, false);
    for row in rows {
        for &j in row {
            needed[j] = true;
        }
    }
    out.clear();
    out.extend((0..s).filter(|&j| needed[j]));
}

/// Formal-compute dispatch shared by all three front-ends: SU-FA
/// (descending/ascending), the FA-2 approximation (ascending SU-FA plus
/// `fa2_cmp` cross-tile max comparisons — the Fig. 18a baseline
/// accounting), or the dense masked softmax. Writes the output into
/// `out` (reset to the row count × d) and returns the stall count.
pub(crate) fn formal_compute_rows_into(
    cfg: &PipelineConfig,
    inp: &AttnInputs,
    rows: &[Vec<usize>],
    fa2_cmp: u64,
    scratch: &mut FormalScratch,
    out: &mut Mat,
    c: &mut OpCounter,
) -> u64 {
    match cfg.formal {
        FormalKind::SufaDescend | FormalKind::SufaAscend => {
            let order = if cfg.formal == FormalKind::SufaDescend {
                UpdateOrder::Descend
            } else {
                UpdateOrder::Ascend
            };
            let p = SufaParams { bc: cfg.bc, order, ..Default::default() };
            sufa_attention_rows_into(inp, rows, &p, c, &mut scratch.sufa, out)
        }
        FormalKind::Flash2 => {
            let p = SufaParams { bc: cfg.bc, order: UpdateOrder::Ascend, ..Default::default() };
            let stalls = sufa_attention_rows_into(inp, rows, &p, c, &mut scratch.sufa, out);
            c.tally(OpKind::Cmp, fa2_cmp);
            stalls
        }
        FormalKind::Dense => {
            dense_formal_rows_into(inp, rows, scratch, out, c);
            0
        }
    }
}

/// Dense (masked) softmax over each row's selection in ascending key
/// order, with dense-attention-style op accounting. For a full selection
/// this reproduces [`crate::attention::dense_attention`]'s float
/// associativity exactly — the `keep = 1.0` parity anchor. Rows that
/// already ascend (every dense-execution selection does) are consumed
/// as a view; only genuinely unsorted rows are staged into the sort
/// scratch.
fn dense_formal_rows_into(
    inp: &AttnInputs,
    rows: &[Vec<usize>],
    scratch: &mut FormalScratch,
    out: &mut Mat,
    c: &mut OpCounter,
) {
    let (s, d) = (inp.s(), inp.d());
    let f = 4u64;
    let FormalScratch { sort, logits, needed, .. } = &mut *scratch;
    needed.clear();
    needed.resize(s, false);
    for row in rows {
        for &j in row {
            assert!(j < s, "selected key {j} out of range for S={s}");
            needed[j] = true;
        }
    }
    let union = needed.iter().filter(|&&n| n).count();
    c.dram(f * (2 * inp.t() * d) as u64); // Q in, O out
    c.dram(f * (2 * union * d) as u64); // KV in
    out.reset(inp.t(), d);
    for (i, keys) in rows.iter().enumerate() {
        if keys.is_empty() {
            continue;
        }
        let ks: &[usize] = if keys.windows(2).all(|w| w[0] < w[1]) {
            keys // already ascending: no copy
        } else {
            sort.clear();
            sort.extend_from_slice(keys);
            sort.sort_unstable();
            sort
        };
        let m = ks.len();
        logits.clear();
        logits.extend(ks.iter().map(|&j| {
            let mut dot = 0.0f32;
            for p in 0..d {
                dot += inp.q.at(i, p) * inp.k.at(j, p);
            }
            dot * inp.scale
        }));
        c.tally(OpKind::Mul, (m * d + m) as u64); // QKᵀ + scale
        c.tally(OpKind::Add, (m * (d - 1)) as u64);
        c.sram(2 * f * m as u64); // tile-resident score row
        crate::tensor::softmax_inplace(logits);
        c.tally(OpKind::Cmp, (m - 1) as u64); // row max
        c.tally(OpKind::Add, m as u64); // subtract max
        c.tally(OpKind::Exp, m as u64);
        c.tally(OpKind::Add, (m - 1) as u64); // denominator
        c.tally(OpKind::Div, m as u64); // normalize
        for (w, &j) in logits.iter().zip(ks) {
            for p in 0..d {
                *out.at_mut(i, p) += w * inp.v.at(j, p);
            }
        }
        c.tally(OpKind::Mul, (m * d) as u64);
        c.tally(OpKind::Add, ((m - 1) * d) as u64);
    }
}

/// The tile-execution core. One instance per run; every method works
/// entirely inside the caller's [`TileWorkspace`].
pub(crate) struct TileExecutor<'a> {
    pub(crate) cfg: &'a PipelineConfig,
}

impl TileExecutor<'_> {
    /// Stage 1 for a `(lo..hi) × (key_lo..key_hi)` block: estimate (or
    /// exactly compute, for the oracle source) the score tile into
    /// `ws.est`, in logit units. Shared by the batch tile path (full key
    /// span) and the sharded local pass (one worker's key range).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn score_block_into(
        &self,
        score: &ScoreSource,
        inp: &PipelineInputs,
        kt: Option<&Mat>,
        lo: usize,
        hi: usize,
        key_lo: usize,
        key_hi: usize,
        ws: &mut TileWorkspace,
        c: &mut OpCounter,
    ) -> bool {
        match score {
            ScoreSource::None => false,
            ScoreSource::Exact => {
                // Oracle scores: exact logits, nothing charged.
                // matmul_cols_into slices the single-core q_tile × Kᵀ
                // product bit for bit (one shared kernel).
                ws.q_tile.stage_rows(inp.q, lo, hi - lo);
                let kt = kt.expect("kt prepared for oracle scores");
                ws.q_tile.matmul_cols_into(kt, key_lo, key_hi, &mut ws.est);
                ws.est.scale(inp.scale);
                if traffic::enabled() {
                    let (rows, span, d) = (hi - lo, key_hi - key_lo, inp.d());
                    // f32 Q rows + Kᵀ columns stream through the score
                    // kernel; the score tile is written once.
                    ws.traffic.operand_read_bytes += 4 * ((rows + span) * d) as u64;
                    ws.traffic.score_write_bytes += 4 * (rows * span) as u64;
                }
                true
            }
            ScoreSource::Prepared(prep) => {
                // Scale the estimate into logit units so the SADS sphere
                // radius is calibrated the way Sec. IV-B assumes.
                prep.score_block_into(lo, hi, key_lo, key_hi, c, &mut ws.est);
                ws.est.scale(inp.scale);
                if traffic::enabled() {
                    let (rows, span, d) = (hi - lo, key_hi - key_lo, inp.d());
                    // Quantized operands: ~1 byte per element per side.
                    ws.traffic.operand_read_bytes += ((rows + span) * d) as u64;
                    ws.traffic.score_write_bytes += 4 * (rows * span) as u64;
                }
                true
            }
        }
    }

    /// Execute one prefill query tile through all four stages — the
    /// batch path's tile body, metered as the zero-allocation hot core.
    pub(crate) fn prefill_tile(&self, ctx: &TileCtx, ti: usize, ws: &mut TileWorkspace) -> TileOut {
        let cfg = self.cfg;
        let inp = ctx.inp;
        let (t, s, d) = (inp.t(), inp.s(), inp.d());
        let lo = ti * cfg.tile_t.min(t.max(1));
        let hi = (lo + cfg.tile_t).min(t);
        let rows = hi - lo;
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // Capacity maintenance + output allocation, outside the metered
        // core: the returned tile must own its output. Dense execution
        // (no score source) skips the score tile entirely.
        let span = if matches!(ctx.score, ScoreSource::None) { 0 } else { s };
        ws.ensure_tile(rows, span, s, ctx.keep, d, cfg.bc);
        ws.spans.reserve_if_enabled();
        let mut out = Mat::zeros(rows, d);
        let a0 = allocmeter::thread_allocs();

        // ---- Stage 1: predict (per-tile phase 1.2 / oracle scores). ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        let have_est =
            self.score_block_into(ctx.score, inp, ctx.kt, lo, hi, 0, s, ws, &mut ops.predict);
        let t1 = Instant::now();
        timing.predict_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Predict, ExecPath::Prefill, ti as u32, t0, t1, tb);

        // ---- Stage 2: top-k selection. ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        let (mut rho_sum, mut rho_n) = (0.0, 0usize);
        ws.sel.begin(rows);
        {
            let TileWorkspace { est, topk, sel, .. } = &mut *ws;
            for i in 0..rows {
                let scores = if have_est { Some(est.row(i)) } else { None };
                if let Some(rho) =
                    select_into(cfg, scores, s, ctx.keep, topk, sel.row_mut(i), &mut ops.topk)
                {
                    rho_sum += rho;
                    rho_n += 1;
                }
            }
        }
        if traffic::enabled() && have_est {
            ws.traffic.score_read_bytes += 4 * (rows * s) as u64;
        }
        let t1 = Instant::now();
        timing.topk_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Topk, ExecPath::Prefill, ti as u32, t0, t1, tb);

        // ---- Stage 3: KV generation for the tile's union. ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        {
            let TileWorkspace { sel, needed, union, .. } = &mut *ws;
            union_rows_into(sel.rows(), s, needed, union);
        }
        let u = ws.union.len();
        let on_demand = cfg.on_demand_kv && inp.x.is_some() && inp.wk.is_some() && inp.wv.is_some();
        if on_demand {
            charge_on_demand_kv_gen(&mut ops.kv_gen, u, inp.x.unwrap().cols, d);
            if traffic::enabled() {
                // X rows of the union stream in once (f32 host layout).
                ws.traffic.x_ingest_bytes += 4 * (u * inp.x.unwrap().cols) as u64;
            }
        }
        let t1 = Instant::now();
        timing.kv_gen_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::KvGen, ExecPath::Prefill, ti as u32, t0, t1, tb);

        // ---- Stage 4: formal compute (SU-FA / FA-2 approx / dense). ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        let stalls = {
            let TileWorkspace { q_tile, sel, formal, .. } = &mut *ws;
            q_tile.stage_rows(inp.q, lo, rows);
            let tile_inp = AttnInputs { q: q_tile, k: inp.k, v: inp.v, scale: inp.scale };
            formal_compute_rows_into(
                cfg,
                &tile_inp,
                sel.rows(),
                (rows * ctx.keep) as u64,
                formal,
                &mut out,
                &mut ops.formal,
            )
        };
        if on_demand {
            kv_traffic_on_chip(&mut ops.formal, u, d);
        }
        if traffic::enabled() {
            let picked: u64 = ws.sel.rows().iter().map(|r| r.len() as u64).sum();
            ws.traffic.q_ingest_bytes += 4 * (rows * d) as u64;
            ws.traffic.formal_kv_bytes += 8 * picked * d as u64;
            ws.traffic.accum_bytes += 8 * picked;
            ws.traffic.out_egress_bytes += 4 * (rows * d) as u64;
        }
        let t1 = Instant::now();
        timing.formal_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Formal, ExecPath::Prefill, ti as u32, t0, t1, tb);
        ws.hot_allocs += allocmeter::thread_allocs() - a0;

        TileOut {
            lo,
            out,
            sel_rows: ws.sel.rows().to_vec(),
            ops,
            timing,
            stalls,
            union_rows: u,
            rho_sum,
            rho_n,
        }
    }

    /// Decode one query row at global position `pos` through all four
    /// stages against the cached context `0..=pos`. Everything here
    /// depends only on the query row and the frozen page operands of the
    /// causal prefix — the invariant that makes chunking/tiling/
    /// threading bit-invisible.
    pub(crate) fn decode_row(
        &self,
        pages: &[&KvPage],
        qrow: &[f32],
        pos: usize,
        attn_scale: f32,
        page_size: usize,
        ws: &mut TileWorkspace,
    ) -> DecodeRowOut {
        let cfg = self.cfg;
        let limit = pos + 1;
        let d = qrow.len();
        let keep = cfg.keep(limit);
        let mut ops = StageOps::default();
        let mut timing = StageTiming::default();

        // Capacity maintenance outside the metered core (the decode
        // context grows monotonically; reserves amortize).
        ws.ensure_decode_row(limit, keep, d, cfg.bc, limit.div_ceil(page_size.max(1)));
        ws.spans.reserve_if_enabled();
        let a0 = allocmeter::thread_allocs();

        // ---- Stage 1: predict over cached page operands. ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        let have_est = if cfg.topk == TopkKind::None {
            false
        } else {
            let TileWorkspace { qop, est_row, .. } = &mut *ws;
            qop.encode_into(qrow, cfg.predict, cfg.predict_bits, &mut ops.predict);
            score_row_into(qop, pages, limit, attn_scale, &mut ops.predict, est_row);
            true
        };
        if traffic::enabled() && have_est {
            // One f32 query row in, quantized page operands (~1 B/elem)
            // streamed, one f32 score per key out.
            ws.traffic.operand_read_bytes += (4 * d + limit * d) as u64;
            ws.traffic.score_write_bytes += 4 * limit as u64;
        }
        let t1 = Instant::now();
        timing.predict_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Predict, ExecPath::Decode, pos as u32, t0, t1, tb);

        // ---- Stage 2: top-k over the causal prefix. ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        ws.sel.begin(1);
        let rho = {
            let TileWorkspace { est_row, topk, sel, .. } = &mut *ws;
            let scores = if have_est { Some(est_row.as_slice()) } else { None };
            select_into(cfg, scores, limit, keep, topk, sel.row_mut(0), &mut ops.topk)
        };
        if traffic::enabled() && have_est {
            ws.traffic.score_read_bytes += 4 * limit as u64;
        }
        let t1 = Instant::now();
        timing.topk_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Topk, ExecPath::Decode, pos as u32, t0, t1, tb);

        ws.hot_allocs += allocmeter::thread_allocs() - a0;

        // ---- Stages 3 + 4: the shared gather + formal core (brackets
        // its own allocmeter region, so the sharded home phase meters
        // identically). ----
        let (stalls, u) = self.decode_gather_formal_row(
            pages,
            qrow,
            pos,
            attn_scale,
            page_size,
            ws,
            &mut ops,
            &mut timing,
        );

        DecodeRowOut {
            out: ws.out_tile.row(0).to_vec(),
            sel: ws.sel.rows()[0].clone(),
            ops,
            timing,
            stalls,
            union_rows: u,
            rho,
            pages: ws.row_pages.clone(),
        }
    }

    /// Decode stages 3 + 4 for the single row whose selection is already
    /// installed in the workspace (stage 2's `select_into`, or the
    /// sharded home phase's merged candidates via
    /// [`TileWorkspace::set_decode_selection`]): sort the selection into
    /// the ascending union, gather the selected KV rows from the frozen
    /// pages, remap monotonically and run the unchanged formal kernel.
    /// Because the kernel, visit order and accounting are byte-for-byte
    /// the single-core stage bodies, any front-end that feeds this the
    /// single-core selection reproduces the single-core output — and its
    /// op/traffic charges — bit for bit. Returns (stalls, union rows).
    /// Brackets its own allocmeter region; this core allocates nothing
    /// once [`TileWorkspace::ensure_decode_row`] has warmed the buffers.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn decode_gather_formal_row(
        &self,
        pages: &[&KvPage],
        qrow: &[f32],
        pos: usize,
        attn_scale: f32,
        page_size: usize,
        ws: &mut TileWorkspace,
        ops: &mut StageOps,
        timing: &mut StageTiming,
    ) -> (u64, usize) {
        let cfg = self.cfg;
        let d = qrow.len();
        let keep = cfg.keep(pos + 1);
        let a0 = allocmeter::thread_allocs();

        // ---- Stage 3: cache read — gather this row's selected KV rows. ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        {
            let TileWorkspace { sel, union, ku, vu, row_pages, .. } = &mut *ws;
            union.clear();
            union.extend_from_slice(&sel.rows()[0]);
            union.sort_unstable();
            gather_rows_into(pages, page_size, union, d, ku, vu);
            row_pages.clear();
            for &j in union.iter() {
                if row_pages.last() != Some(&(j / page_size)) {
                    row_pages.push(j / page_size);
                }
            }
        }
        let u = ws.union.len();
        ops.kv_gen.sram(4 * (2 * u * d) as u64); // staged f32 KV lands in SRAM either way
        if traffic::enabled() {
            // What the gather *read* depends on the pages' residency
            // mode: 8d f32 per row from exact pages (byte-identical to
            // the pre-residency accounting), 2d+8 from quantized-only
            // pages (the i8 operands + two scales it dequantizes).
            let row_bytes = pages.first().map(|p| p.gather_row_bytes()).unwrap_or(8 * d);
            ws.traffic.kv_gather_bytes += (u * row_bytes) as u64;
        }
        let t1 = Instant::now();
        timing.kv_gen_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::KvGen, ExecPath::Decode, pos as u32, t0, t1, tb);

        // ---- Stage 4: formal compute on the compacted rows. The
        // selection is remapped monotonically (ascending union order),
        // so per-key visit order — and therefore the math — is
        // unchanged. ----
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        ws.remap.begin(1);
        let stalls = {
            let TileWorkspace { sel, remap, union, q_tile, ku, vu, formal, out_tile, .. } =
                &mut *ws;
            remap.row_mut(0).extend(
                sel.rows()[0]
                    .iter()
                    .map(|&j| union.binary_search(&j).expect("selected key in union")),
            );
            q_tile.reset(1, d);
            q_tile.row_mut(0).copy_from_slice(qrow);
            let tile_inp = AttnInputs { q: q_tile, k: ku, v: vu, scale: attn_scale };
            formal_compute_rows_into(
                cfg,
                &tile_inp,
                remap.rows(),
                keep as u64,
                formal,
                out_tile,
                &mut ops.formal,
            )
        };
        // The formal stage's KV traffic came from the cache, not DRAM.
        kv_traffic_on_chip(&mut ops.formal, u, d);
        if traffic::enabled() {
            let picked = ws.sel.rows()[0].len() as u64;
            ws.traffic.q_ingest_bytes += 4 * d as u64;
            ws.traffic.formal_kv_bytes += 8 * picked * d as u64;
            ws.traffic.accum_bytes += 8 * picked;
            ws.traffic.out_egress_bytes += 4 * d as u64;
        }
        let t1 = Instant::now();
        timing.formal_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Formal, ExecPath::Decode, pos as u32, t0, t1, tb);
        ws.hot_allocs += allocmeter::thread_allocs() - a0;
        (stalls, u)
    }

    /// Stages 3 + 4 for a block whose per-row selection is already
    /// merged (the sharded home phase): ascending union → gather the
    /// selected KV rows (skipped when the union is the identity) →
    /// monotone remap → formal compute into `out`. Returns (stalls,
    /// union rows).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn gather_formal_block(
        &self,
        inp: &PipelineInputs,
        lo: usize,
        sel_rows: &[Vec<usize>],
        keep: usize,
        ws: &mut TileWorkspace,
        ops: &mut StageOps,
        timing: &mut StageTiming,
        out: &mut Mat,
    ) -> (u64, usize) {
        let cfg = self.cfg;
        let (s, d) = (inp.s(), inp.d());
        let rows = sel_rows.len();
        ws.spans.reserve_if_enabled();

        // ---- KV gen + gather: produce the union of selected rows and
        // stream them to this home worker — only the union crosses the
        // ring (the sparse-attention win).
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        {
            let TileWorkspace { needed, union, .. } = &mut *ws;
            union_rows_into(sel_rows, s, needed, union);
        }
        let u = ws.union.len();
        let on_demand = cfg.on_demand_kv && inp.x.is_some() && inp.wk.is_some() && inp.wv.is_some();
        if on_demand {
            // Union KV rows are generated on their owning shards; the
            // charge is the single-core stage-3 accounting, shared so it
            // cannot drift between the engines.
            charge_on_demand_kv_gen(&mut ops.kv_gen, u, inp.x.unwrap().cols, d);
            if traffic::enabled() {
                ws.traffic.x_ingest_bytes += 4 * (u * inp.x.unwrap().cols) as u64;
            }
        }
        // When every key is selected (dense execution, keep = 1.0) the
        // gather is the identity: attend the original K/V directly
        // instead of copying the whole context per Q block.
        let identity_union = u == s;
        if !identity_union {
            // Capacity maintenance for the staging buffers, then the
            // metered gather.
            ws.ku.reset(u, d);
            ws.vu.reset(u, d);
            let a0 = allocmeter::thread_allocs();
            {
                let TileWorkspace { union, ku, vu, .. } = &mut *ws;
                for (i, &key) in union.iter().enumerate() {
                    ku.row_mut(i).copy_from_slice(inp.k.row(key));
                    vu.row_mut(i).copy_from_slice(inp.v.row(key));
                }
            }
            ws.hot_allocs += allocmeter::thread_allocs() - a0;
            if traffic::enabled() {
                ws.traffic.kv_gather_bytes += 4 * (2 * u * d) as u64;
            }
        }
        let t1 = Instant::now();
        timing.kv_gen_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::KvGen, ExecPath::Sharded, lo as u32, t0, t1, tb);

        // ---- Formal: SU-FA over the gathered rows, selection remapped
        // monotonically (ascending union order) so the per-key visit
        // order — and therefore every float — matches the single-core
        // run. An identity union needs no remap: positions already equal
        // indices.
        let t0 = Instant::now();
        let b0 = ws.traffic.total_bytes();
        ws.remap.reserve(rows, keep.max(1));
        ws.q_tile.reset(rows, d);
        ws.formal.reserve(d, cfg.bc, s);
        let a0 = allocmeter::thread_allocs();
        let stalls = {
            let TileWorkspace { remap, union, q_tile, ku, vu, formal, .. } = &mut *ws;
            let formal_rows: &[Vec<usize>] = if identity_union {
                sel_rows
            } else {
                remap.begin(rows);
                for (i, row) in sel_rows.iter().enumerate() {
                    remap.row_mut(i).extend(
                        row.iter()
                            .map(|&jj| union.binary_search(&jj).expect("selected key in union")),
                    );
                }
                remap.rows()
            };
            q_tile.stage_rows(inp.q, lo, rows);
            let (kk, vv): (&Mat, &Mat) =
                if identity_union { (inp.k, inp.v) } else { (ku, vu) };
            let block_inp = AttnInputs { q: q_tile, k: kk, v: vv, scale: inp.scale };
            formal_compute_rows_into(
                cfg,
                &block_inp,
                formal_rows,
                (rows * keep) as u64,
                formal,
                out,
                &mut ops.formal,
            )
        };
        if on_demand {
            // Under the sharded dataflow the formal stage streams the
            // gathered KV out of on-chip buffers, not DRAM.
            kv_traffic_on_chip(&mut ops.formal, u, d);
        }
        if traffic::enabled() {
            let picked: u64 = sel_rows.iter().map(|r| r.len() as u64).sum();
            ws.traffic.q_ingest_bytes += 4 * (rows * d) as u64;
            ws.traffic.formal_kv_bytes += 8 * picked * d as u64;
            ws.traffic.accum_bytes += 8 * picked;
            ws.traffic.out_egress_bytes += 4 * (rows * d) as u64;
        }
        let t1 = Instant::now();
        timing.formal_s += (t1 - t0).as_secs_f64();
        let tb = ws.traffic.total_bytes() - b0;
        ws.spans.record(Stage::Formal, ExecPath::Sharded, lo as u32, t0, t1, tb);
        ws.hot_allocs += allocmeter::thread_allocs() - a0;
        (stalls, u)
    }
}

/// Chunks each worker claims per grab from the shared tile cursor in
/// [`parallel_tiles_pooled`]: `ntiles / (workers · TILE_CHUNKS_PER_GRAB)`
/// tiles, floored at 1. Four average grabs per worker keeps the
/// `fetch_add` contention negligible while letting fast workers absorb
/// the skew dynamic sparsity produces (a tile whose rows selected many
/// keys costs a multiple of a sparse one).
const TILE_CHUNKS_PER_GRAB: usize = 4;

/// Run `ntiles` independent tile jobs across worker threads
/// (`threads == 0` picks `available_parallelism`) under
/// `std::thread::scope`, each worker driving one pooled [`TileWorkspace`]
/// for everything it claims.
///
/// Scheduling is **work-stealing** over a shared atomic cursor: workers
/// repeatedly `fetch_add` a chunk of tile indices and run them, so a
/// worker that drew cheap tiles comes back for more instead of idling
/// behind a static stripe — exactly the skew profile dynamic sparsity
/// produces. The cursor is a single `AtomicUsize` (no deque, no heap):
/// claiming allocates nothing, preserving the zero-allocation hot-path
/// contract the allocmeter enforces. Results come back unordered —
/// callers sort by their tile key; *outputs* stay deterministic at every
/// thread count because each job is a pure function of its tile index
/// and each tile runs exactly once. Returns the results plus the metered
/// hot-path allocation total, the peak workspace bytes, the merged
/// measured-traffic counters, and the scheduler statistics (chunk grabs,
/// steals, per-worker tile imbalance).
pub(crate) fn parallel_tiles_pooled<T: Send>(
    ntiles: usize,
    threads: usize,
    pool: &WorkspacePool,
    class: ShapeClass,
    job: impl Fn(&mut TileWorkspace, usize) -> T + Sync,
) -> (Vec<T>, u64, usize, TrafficCounter, SchedStats) {
    use std::sync::atomic::{AtomicUsize, Ordering};
    if ntiles == 0 {
        return (Vec::new(), 0, 0, TrafficCounter::new(), SchedStats::default());
    }
    let workers = match threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
    .clamp(1, ntiles);
    if workers <= 1 {
        let mut ws = pool.checkout(class);
        ws.spans.worker = 0;
        ws.spans.session = 0;
        let outs = (0..ntiles).map(|ti| job(&mut ws, ti)).collect();
        let (hot, bytes, tr) = (ws.take_hot_allocs(), ws.capacity_bytes(), ws.take_traffic());
        pool.checkin(ws);
        (outs, hot, bytes, tr, SchedStats::single(ntiles as u64))
    } else {
        let chunk = (ntiles / (workers * TILE_CHUNKS_PER_GRAB)).max(1);
        let cursor = AtomicUsize::new(0);
        let per_worker: Vec<(Vec<T>, u64, usize, TrafficCounter, u64, u64)> =
            std::thread::scope(|scope| {
                let (job, cursor) = (&job, &cursor);
                let handles: Vec<_> = (0..workers)
                    .map(|w| {
                        scope.spawn(move || {
                            let mut ws = pool.checkout(class);
                            ws.spans.worker = w as u32;
                            ws.spans.session = 0;
                            let mut outs: Vec<T> = Vec::with_capacity(chunk);
                            let (mut grabs, mut tiles) = (0u64, 0u64);
                            loop {
                                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                                if start >= ntiles {
                                    break;
                                }
                                let end = (start + chunk).min(ntiles);
                                grabs += 1;
                                tiles += (end - start) as u64;
                                outs.extend((start..end).map(|ti| job(&mut ws, ti)));
                            }
                            let (hot, bytes, tr) =
                                (ws.take_hot_allocs(), ws.capacity_bytes(), ws.take_traffic());
                            pool.checkin(ws);
                            (outs, hot, bytes, tr, grabs, tiles)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("tile worker panicked")).collect()
            });
        let mut outs = Vec::with_capacity(ntiles);
        let mut hot = 0u64;
        let mut bytes = 0usize;
        let mut traffic = TrafficCounter::new();
        let mut sched = SchedStats { workers: workers as u64, ..SchedStats::default() };
        for (o, h, b, tr, grabs, tiles) in per_worker {
            outs.extend(o);
            hot += h;
            bytes = bytes.max(b);
            traffic.merge(&tr);
            sched.chunk_grabs += grabs;
            // Every grab past a worker's first claimed work the static
            // striping would have handed to someone else: count it as a
            // steal.
            sched.steals += grabs.saturating_sub(1);
            sched.tiles += tiles;
            sched.max_worker_tiles = sched.max_worker_tiles.max(tiles);
        }
        (outs, hot, bytes, traffic, sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_workspaces_per_class() {
        let pool = WorkspacePool::new();
        let a = ShapeClass { d: 16, tile_t: 8, bc: 16 };
        let b = ShapeClass { d: 64, tile_t: 64, bc: 16 };
        let mut ws = pool.checkout(a);
        ws.ensure_tile(8, 128, 128, 32, 16, 16);
        let warmed = ws.capacity_bytes();
        assert!(warmed > 0);
        pool.checkin(ws);
        pool.checkin(pool.checkout(b));
        assert_eq!(pool.resident_workspaces(), 2);
        assert!(pool.resident_bytes() >= warmed);
        // Checking the same class out again returns the warm buffers.
        let ws = pool.checkout(a);
        assert_eq!(ws.capacity_bytes(), warmed);
        assert_eq!(ws.class(), a);
        pool.checkin(ws);
    }

    #[test]
    fn ensure_makes_second_tile_capacity_stable() {
        let mut ws = TileWorkspace::new(ShapeClass { d: 16, tile_t: 8, bc: 16 });
        ws.ensure_tile(8, 96, 96, 24, 16, 16);
        let warm = ws.capacity_bytes();
        ws.ensure_tile(8, 96, 96, 24, 16, 16);
        assert_eq!(ws.capacity_bytes(), warm, "steady-state ensure must not grow");
        ws.ensure_decode_row(96, 24, 16, 16, 6);
        let warm = ws.capacity_bytes();
        ws.ensure_decode_row(96, 24, 16, 16, 6);
        assert_eq!(ws.capacity_bytes(), warm);
    }

    #[test]
    fn take_hot_allocs_drains() {
        let mut ws = TileWorkspace::new(ShapeClass { d: 8, tile_t: 8, bc: 16 });
        ws.hot_allocs = 7;
        assert_eq!(ws.take_hot_allocs(), 7);
        assert_eq!(ws.take_hot_allocs(), 0);
    }

    #[test]
    fn union_rows_into_matches_selection_union_keys() {
        use crate::attention::Selection;
        let rows = vec![vec![3usize, 1], vec![1, 5], vec![]];
        let sel = Selection { rows: rows.clone() };
        let mut needed = Vec::new();
        let mut out = vec![99usize]; // dirty
        union_rows_into(&rows, 8, &mut needed, &mut out);
        assert_eq!(out, sel.union_keys(8));
    }
}
