//! Minimal dense-tensor substrate: a row-major f32 matrix with the handful
//! of operations the algorithm layer needs (matmul, transpose, row softmax,
//! row top-k). Kept deliberately small — numerics on the request path can
//! run through the AOT-compiled HLO artifacts (`crate::runtime`, behind the
//! `pjrt` feature); this type exists for oracles, simulators and workload
//! generation.

use crate::arith::lanes::{F32x8, KernelPath, LANES};
use crate::util::Rng;

/// Rows per register micro-tile of the lane matmul: 4 × `F32x8`
/// accumulators live in registers across a whole p-panel.
const MAT_MR: usize = 4;

/// p-panel depth of the lane matmul. One panel of the streamed `other`
/// column block is `MAT_KC × LANES × 4 B` = 16 kB — half a typical 32 kB
/// L1, leaving room for the `self` panel rows (DESIGN.md §10). At the
/// paper's shapes (`k = d ≤ 128`) a single panel covers the whole
/// reduction, so accumulators never spill.
const MAT_KC: usize = 512;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// I.i.d. normal entries (mean 0, std as given).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal_f32(0.0, std))
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape in place to `rows × cols`, zero-filled. Reuses the
    /// existing heap buffer whenever its capacity suffices — the
    /// workspace substrate of the allocation-free tile engine
    /// ([`crate::pipeline::engine`]): a staged Q tile, score tile or
    /// gathered-KV buffer is `reset` per tile instead of reallocated.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Copy `rows` rows of `src` starting at `src_lo` into this matrix
    /// (which is `reset` to `rows × src.cols` first). The staging step of
    /// a query tile: same values [`Mat::from_fn`] over `src.at(lo + i,
    /// j)` would produce, without the per-tile allocation.
    pub fn stage_rows(&mut self, src: &Mat, src_lo: usize, rows: usize) {
        debug_assert!(
            src_lo + rows <= src.rows,
            "stage_rows: source rows {src_lo}..{} out of range (src has {} rows)",
            src_lo + rows,
            src.rows
        );
        debug_assert_eq!(
            src.data.len(),
            src.rows * src.cols,
            "stage_rows: source shape/data mismatch"
        );
        self.reset(rows, src.cols);
        for i in 0..rows {
            self.row_mut(i).copy_from_slice(src.row(src_lo + i));
        }
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// Dense matmul: self [m,k] × other [k,n] → [m,n].
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_cols(other, 0, other.cols)
    }

    /// Columns `[col_lo, col_hi)` of `self × other`. Each element is
    /// computed with exactly [`Mat::matmul`]'s accumulation order
    /// (ikj, skip-zero), so a column block slices the full product bit
    /// for bit — the sharded pipeline's oracle-score path relies on
    /// this to score one worker's key range.
    pub fn matmul_cols(&self, other: &Mat, col_lo: usize, col_hi: usize) -> Mat {
        let mut out = Mat::zeros(self.rows, col_hi.saturating_sub(col_lo));
        self.matmul_cols_into(other, col_lo, col_hi, &mut out);
        out
    }

    /// [`Mat::matmul_cols`] writing into a caller-provided buffer (which
    /// is [`Mat::reset`] to the product shape — no allocation once `out`
    /// has the capacity). This is the only matmul kernel in the crate;
    /// the allocating entry points wrap it, so "into" and "fresh" results
    /// are bit-identical by construction. Dispatches on the `simd` cargo
    /// feature ([`KernelPath::active`]); both spellings are bit-identical
    /// — see [`Mat::matmul_cols_into_with`].
    pub fn matmul_cols_into(&self, other: &Mat, col_lo: usize, col_hi: usize, out: &mut Mat) {
        self.matmul_cols_into_with(other, col_lo, col_hi, out, KernelPath::active());
    }

    /// [`Mat::matmul_cols_into`] with an explicit kernel path, so benches
    /// and parity tests can run both spellings in one binary.
    ///
    /// Both paths perform, for every output element `(i, j)`, the same
    /// sequence of f32 operations: ascending-`p` accumulation, the
    /// skip-zero test on `self[i, p]`, and a separate multiply then add
    /// (never a fused mul-add). The lane path only re-tiles *which*
    /// elements are in flight together (a [`MAT_MR`]×[`LANES`] register
    /// micro-tile over [`MAT_KC`]-deep panels), so the two spellings are
    /// bit-identical for every shape, including remainder columns.
    pub fn matmul_cols_into_with(
        &self,
        other: &Mat,
        col_lo: usize,
        col_hi: usize,
        out: &mut Mat,
        path: KernelPath,
    ) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert!(col_lo <= col_hi && col_hi <= other.cols, "column block out of range");
        debug_assert_eq!(self.data.len(), self.rows * self.cols, "matmul: lhs shape/data mismatch");
        debug_assert_eq!(
            other.data.len(),
            other.rows * other.cols,
            "matmul: rhs shape/data mismatch"
        );
        let (m, k, n) = (self.rows, self.cols, col_hi - col_lo);
        out.reset(m, n);
        match path {
            KernelPath::Scalar => {
                // ikj loop order: streams `other` rows, vectorizes the
                // inner j loop.
                for i in 0..m {
                    let orow = &mut out.data[i * n..(i + 1) * n];
                    for p in 0..k {
                        let a = self.data[i * k + p];
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &other.data[p * other.cols + col_lo..p * other.cols + col_hi];
                        for (o, &b) in orow.iter_mut().zip(brow) {
                            *o += a * b;
                        }
                    }
                }
            }
            KernelPath::Lanes => {
                let full_n = n - n % LANES;
                for p0 in (0..k).step_by(MAT_KC) {
                    let p1 = (p0 + MAT_KC).min(k);
                    for i0 in (0..m).step_by(MAT_MR) {
                        let mr = (m - i0).min(MAT_MR);
                        // Register micro-kernel: mr×8 accumulators held
                        // across the p-panel, loaded from / stored to
                        // `out` at the panel boundary (stored f32 ==
                        // register f32, so panel splits stay exact).
                        for j0 in (0..full_n).step_by(LANES) {
                            let mut acc = [F32x8::zero(); MAT_MR];
                            for (r, a) in acc.iter_mut().enumerate().take(mr) {
                                *a = F32x8::load(&out.data[(i0 + r) * n + j0..]);
                            }
                            for p in p0..p1 {
                                let b = F32x8::load(&other.data[p * other.cols + col_lo + j0..]);
                                for (r, a) in acc.iter_mut().enumerate().take(mr) {
                                    let aval = self.data[(i0 + r) * k + p];
                                    if aval == 0.0 {
                                        continue;
                                    }
                                    *a = a.add(F32x8::splat(aval).mul(b));
                                }
                            }
                            for (r, a) in acc.iter().enumerate().take(mr) {
                                a.store(&mut out.data[(i0 + r) * n + j0..]);
                            }
                        }
                        // Remainder columns: the scalar spelling over the
                        // same panel, so per-element op order is unchanged.
                        for i in i0..i0 + mr {
                            for p in p0..p1 {
                                let a = self.data[i * k + p];
                                if a == 0.0 {
                                    continue;
                                }
                                let brow = &other.data
                                    [p * other.cols + col_lo + full_n..p * other.cols + col_hi];
                                let orow = &mut out.data[i * n + full_n..(i + 1) * n];
                                for (o, &b) in orow.iter_mut().zip(brow) {
                                    *o += a * b;
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Scale every element in place.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        )
    }

    /// Row-wise numerically-stable softmax (Eq. 1 of the paper).
    pub fn softmax_rows(&self) -> Mat {
        let mut out = self.clone();
        for i in 0..self.rows {
            softmax_inplace(out.row_mut(i));
        }
        out
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Relative Frobenius error ‖a−b‖/‖b‖ (b taken as reference).
    pub fn rel_err(&self, reference: &Mat) -> f32 {
        let mut num = 0.0f32;
        for (a, b) in self.data.iter().zip(&reference.data) {
            num += (a - b) * (a - b);
        }
        let den = reference.fro_norm().max(1e-30);
        num.sqrt() / den
    }
}

/// In-place numerically stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Indices of the `k` largest values of `xs` (ties broken by lower index),
/// returned in descending value order. This is the oracle the top-k stage
/// is measured against.
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k.min(xs.len()));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(4, 7, 1.0, &mut rng);
        let eye = Mat::from_fn(7, 7, |i, j| if i == j { 1.0 } else { 0.0 });
        let b = a.matmul(&eye);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn matmul_cols_slices_the_full_product_bit_for_bit() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(5, 16, 1.0, &mut rng);
        let b = Mat::randn(16, 23, 1.0, &mut rng);
        let full = a.matmul(&b);
        for (lo, hi) in [(0usize, 23usize), (0, 7), (7, 20), (20, 23), (5, 5)] {
            let block = a.matmul_cols(&b, lo, hi);
            assert_eq!((block.rows, block.cols), (5, hi - lo));
            for i in 0..5 {
                for j in lo..hi {
                    assert_eq!(block.at(i, j - lo), full.at(i, j), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn reset_reuses_capacity_and_zero_fills() {
        let mut m = Mat::randn(8, 8, 1.0, &mut Rng::new(13));
        let cap = m.data.capacity();
        m.reset(4, 6);
        assert_eq!((m.rows, m.cols), (4, 6));
        assert!(m.data.iter().all(|&x| x == 0.0), "reset must zero-fill");
        assert_eq!(m.data.capacity(), cap, "smaller reset must not reallocate");
    }

    #[test]
    fn stage_rows_matches_from_fn_slice() {
        let mut rng = Rng::new(17);
        let src = Mat::randn(9, 5, 1.0, &mut rng);
        let want = Mat::from_fn(3, 5, |i, j| src.at(4 + i, j));
        let mut staged = Mat::zeros(0, 0);
        staged.stage_rows(&src, 4, 3);
        assert_eq!(staged, want);
    }

    #[test]
    fn matmul_lanes_path_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(23);
        // Shapes straddle the micro-tile: remainder rows (m % 4), remainder
        // columns (n % 8), degenerate dims, and k past one p-panel.
        let shapes: [(usize, usize, usize); 6] =
            [(1, 1, 1), (4, 8, 8), (5, 13, 23), (3, 64, 7), (7, 600, 17), (6, 32, 40)];
        for (m, k, n) in shapes {
            let mut a = Mat::randn(m, k, 1.0, &mut rng);
            // Sprinkle exact zeros so the skip-zero branch is exercised.
            for (idx, v) in a.data.iter_mut().enumerate() {
                if idx % 5 == 0 {
                    *v = 0.0;
                }
            }
            let b = Mat::randn(k, n, 1.0, &mut rng);
            for (lo, hi) in [(0, n), (n / 3, n), (0, n - n / 4)] {
                let mut scalar = Mat::randn(3, 3, 1.0, &mut rng); // dirty
                let mut lanes = Mat::randn(2, 5, 1.0, &mut rng); // dirty
                a.matmul_cols_into_with(&b, lo, hi, &mut scalar, KernelPath::Scalar);
                a.matmul_cols_into_with(&b, lo, hi, &mut lanes, KernelPath::Lanes);
                assert_eq!(scalar, lanes, "({m},{k},{n}) cols {lo}..{hi}");
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "stage_rows: source rows")]
    fn stage_rows_rejects_out_of_range_sources() {
        let src = Mat::zeros(4, 3);
        let mut dst = Mat::zeros(0, 0);
        dst.stage_rows(&src, 2, 3);
    }

    #[test]
    fn matmul_cols_into_equals_matmul_cols_on_dirty_buffer() {
        let mut rng = Rng::new(19);
        let a = Mat::randn(4, 12, 1.0, &mut rng);
        let b = Mat::randn(12, 10, 1.0, &mut rng);
        let mut out = Mat::randn(7, 7, 1.0, &mut rng); // dirty, wrong shape
        a.matmul_cols_into(&b, 2, 9, &mut out);
        assert_eq!(out, a.matmul_cols(&b, 2, 9));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(3, 8, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(7);
        let a = Mat::randn(6, 33, 4.0, &mut rng);
        let s = a.softmax_rows();
        for i in 0..s.rows {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {i} sums to {sum}");
            assert!(s.row(i).iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn softmax_shift_invariance() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        let mut b = vec![1001.0f32, 1002.0, 1003.0];
        softmax_inplace(&mut a);
        softmax_inplace(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn topk_descending_and_ties() {
        let xs = [0.5f32, 2.0, 2.0, -1.0, 3.0];
        assert_eq!(topk_indices(&xs, 3), vec![4, 1, 2]);
        assert_eq!(topk_indices(&xs, 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&xs, 99).len(), 5);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(5, 5, 1.0, &mut rng);
        assert_eq!(a.rel_err(&a), 0.0);
    }
}
