//! Minimal dense-tensor substrate: a row-major f32 matrix with the handful
//! of operations the algorithm layer needs (matmul, transpose, row softmax,
//! row top-k). Kept deliberately small — numerics on the request path can
//! run through the AOT-compiled HLO artifacts (`crate::runtime`, behind the
//! `pjrt` feature); this type exists for oracles, simulators and workload
//! generation.

use crate::util::Rng;

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// I.i.d. normal entries (mean 0, std as given).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal_f32(0.0, std))
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Reshape in place to `rows × cols`, zero-filled. Reuses the
    /// existing heap buffer whenever its capacity suffices — the
    /// workspace substrate of the allocation-free tile engine
    /// ([`crate::pipeline::engine`]): a staged Q tile, score tile or
    /// gathered-KV buffer is `reset` per tile instead of reallocated.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Copy `rows` rows of `src` starting at `src_lo` into this matrix
    /// (which is `reset` to `rows × src.cols` first). The staging step of
    /// a query tile: same values [`Mat::from_fn`] over `src.at(lo + i,
    /// j)` would produce, without the per-tile allocation.
    pub fn stage_rows(&mut self, src: &Mat, src_lo: usize, rows: usize) {
        self.reset(rows, src.cols);
        for i in 0..rows {
            self.row_mut(i).copy_from_slice(src.row(src_lo + i));
        }
    }

    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// Dense matmul: self [m,k] × other [k,n] → [m,n].
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_cols(other, 0, other.cols)
    }

    /// Columns `[col_lo, col_hi)` of `self × other`. Each element is
    /// computed with exactly [`Mat::matmul`]'s accumulation order
    /// (ikj, skip-zero), so a column block slices the full product bit
    /// for bit — the sharded pipeline's oracle-score path relies on
    /// this to score one worker's key range.
    pub fn matmul_cols(&self, other: &Mat, col_lo: usize, col_hi: usize) -> Mat {
        let mut out = Mat::zeros(self.rows, col_hi.saturating_sub(col_lo));
        self.matmul_cols_into(other, col_lo, col_hi, &mut out);
        out
    }

    /// [`Mat::matmul_cols`] writing into a caller-provided buffer (which
    /// is [`Mat::reset`] to the product shape — no allocation once `out`
    /// has the capacity). This is the only matmul kernel in the crate;
    /// the allocating entry points wrap it, so "into" and "fresh" results
    /// are bit-identical by construction.
    pub fn matmul_cols_into(&self, other: &Mat, col_lo: usize, col_hi: usize, out: &mut Mat) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        assert!(col_lo <= col_hi && col_hi <= other.cols, "column block out of range");
        let (m, k, n) = (self.rows, self.cols, col_hi - col_lo);
        out.reset(m, n);
        // ikj loop order: streams `other` rows, vectorizes the inner j loop.
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * other.cols + col_lo..p * other.cols + col_hi];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
    }

    /// Scale every element in place.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat::from_vec(
            self.rows,
            self.cols,
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        )
    }

    /// Row-wise numerically-stable softmax (Eq. 1 of the paper).
    pub fn softmax_rows(&self) -> Mat {
        let mut out = self.clone();
        for i in 0..self.rows {
            softmax_inplace(out.row_mut(i));
        }
        out
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Relative Frobenius error ‖a−b‖/‖b‖ (b taken as reference).
    pub fn rel_err(&self, reference: &Mat) -> f32 {
        let mut num = 0.0f32;
        for (a, b) in self.data.iter().zip(&reference.data) {
            num += (a - b) * (a - b);
        }
        let den = reference.fro_norm().max(1e-30);
        num.sqrt() / den
    }
}

/// In-place numerically stable softmax over a slice.
pub fn softmax_inplace(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Indices of the `k` largest values of `xs` (ties broken by lower index),
/// returned in descending value order. This is the oracle the top-k stage
/// is measured against.
pub fn topk_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx.truncate(k.min(xs.len()));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(4, 7, 1.0, &mut rng);
        let eye = Mat::from_fn(7, 7, |i, j| if i == j { 1.0 } else { 0.0 });
        let b = a.matmul(&eye);
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn matmul_cols_slices_the_full_product_bit_for_bit() {
        let mut rng = Rng::new(9);
        let a = Mat::randn(5, 16, 1.0, &mut rng);
        let b = Mat::randn(16, 23, 1.0, &mut rng);
        let full = a.matmul(&b);
        for (lo, hi) in [(0usize, 23usize), (0, 7), (7, 20), (20, 23), (5, 5)] {
            let block = a.matmul_cols(&b, lo, hi);
            assert_eq!((block.rows, block.cols), (5, hi - lo));
            for i in 0..5 {
                for j in lo..hi {
                    assert_eq!(block.at(i, j - lo), full.at(i, j), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn reset_reuses_capacity_and_zero_fills() {
        let mut m = Mat::randn(8, 8, 1.0, &mut Rng::new(13));
        let cap = m.data.capacity();
        m.reset(4, 6);
        assert_eq!((m.rows, m.cols), (4, 6));
        assert!(m.data.iter().all(|&x| x == 0.0), "reset must zero-fill");
        assert_eq!(m.data.capacity(), cap, "smaller reset must not reallocate");
    }

    #[test]
    fn stage_rows_matches_from_fn_slice() {
        let mut rng = Rng::new(17);
        let src = Mat::randn(9, 5, 1.0, &mut rng);
        let want = Mat::from_fn(3, 5, |i, j| src.at(4 + i, j));
        let mut staged = Mat::zeros(0, 0);
        staged.stage_rows(&src, 4, 3);
        assert_eq!(staged, want);
    }

    #[test]
    fn matmul_cols_into_equals_matmul_cols_on_dirty_buffer() {
        let mut rng = Rng::new(19);
        let a = Mat::randn(4, 12, 1.0, &mut rng);
        let b = Mat::randn(12, 10, 1.0, &mut rng);
        let mut out = Mat::randn(7, 7, 1.0, &mut rng); // dirty, wrong shape
        a.matmul_cols_into(&b, 2, 9, &mut out);
        assert_eq!(out, a.matmul_cols(&b, 2, 9));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Mat::randn(3, 8, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(7);
        let a = Mat::randn(6, 33, 4.0, &mut rng);
        let s = a.softmax_rows();
        for i in 0..s.rows {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {i} sums to {sum}");
            assert!(s.row(i).iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn softmax_shift_invariance() {
        let mut a = vec![1.0f32, 2.0, 3.0];
        let mut b = vec![1001.0f32, 1002.0, 1003.0];
        softmax_inplace(&mut a);
        softmax_inplace(&mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn topk_descending_and_ties() {
        let xs = [0.5f32, 2.0, 2.0, -1.0, 3.0];
        assert_eq!(topk_indices(&xs, 3), vec![4, 1, 2]);
        assert_eq!(topk_indices(&xs, 0), Vec::<usize>::new());
        assert_eq!(topk_indices(&xs, 99).len(), 5);
    }

    #[test]
    fn rel_err_zero_for_identical() {
        let mut rng = Rng::new(11);
        let a = Mat::randn(5, 5, 1.0, &mut rng);
        assert_eq!(a.rel_err(&a), 0.0);
    }
}
