//! Portable lane-based SIMD layer: fixed 8-wide f32/i64 lane types the hot
//! buffer-writing kernels are spelled in, plus the [`KernelPath`] dispatch
//! switch and the [`ReductionOrder`] bit-identity contract.
//!
//! # Why no intrinsics or crates
//!
//! The offline image vendors no crates (`rust/vendor/` policy) and
//! `std::simd` is nightly-only, so the lane types here are plain
//! `#[repr(align(32))]` arrays with per-lane loops written so LLVM's
//! autovectorizer maps them onto the target's vector units (AVX2 =
//! exactly one `F32x8` per register; NEON/SSE = two). Every operation is
//! per-lane IEEE-754 f32 arithmetic — the same operations the scalar
//! kernels perform, just batched — which is what makes the bit-identity
//! contract below provable rather than approximate.
//!
//! # Dispatch: both spellings always compiled
//!
//! Each hot kernel has a `*_with(.., KernelPath)` spelling taking the path
//! explicitly, and its public name dispatches on [`KernelPath::active`]
//! (compile-time: the `simd` cargo feature). Both paths are *always
//! compiled* — `star bench kernels` measures scalar vs lanes in one
//! binary, and `tests/prop_simd_parity.rs` asserts their bit-identity in
//! one build, regardless of which one the feature selects as default.
//!
//! # The bit-identity contract
//!
//! Lane kernels must be bit-identical to their scalar spellings wherever
//! the reduction order is preserved:
//!
//! * elementwise maps (quantize, axpy, rescale) — trivially identical;
//! * integer accumulation (the predictor's i64 score sums) — addition is
//!   associative, so lane-splitting is unconditionally identical;
//! * `f32::max` reductions (quantize amax, SU-FA tile max, top-k scan
//!   maxima) — max is associative and commutative (and the kernels never
//!   feed it NaN by construction), so lane-splitting is identical;
//! * f32 *sums* are **not** reorderable. Kernels keep them sequential
//!   under [`ReductionOrder::Strict`] (the default) and may lane-split
//!   them only under [`ReductionOrder::Lanes`] — see the enum docs and
//!   DESIGN.md §10.
//!
//! Accordingly, Strict-path kernels never use [`F32x8::mul_add`]: a fused
//! multiply-add rounds once where the scalar spelling rounds twice.

/// Lane width of the portable vector types. 8 × f32 = 256 bits, one AVX2
/// register; chosen to match the paper's tile granularity (`tile_t` and
/// `d` are multiples of 8 in every preset).
pub const LANES: usize = 8;

/// Which spelling of a dual-spelled kernel to run.
///
/// Carried as a runtime value so benches and parity tests can run both in
/// one binary; the public kernel entry points pass [`KernelPath::active`],
/// which the `simd` cargo feature decides at compile time (so the branch
/// folds away in the hot path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// The reference scalar loops (the pre-SIMD kernel bodies).
    Scalar,
    /// The lane-based spellings in this module's types.
    Lanes,
}

impl KernelPath {
    /// The path the `simd` cargo feature selects: `Lanes` with
    /// `--features simd`, `Scalar` otherwise.
    #[inline]
    pub fn active() -> KernelPath {
        if cfg!(feature = "simd") {
            KernelPath::Lanes
        } else {
            KernelPath::Scalar
        }
    }
}

/// How a kernel may order floating-point *sum* reductions.
///
/// `Strict` (the default everywhere) keeps every f32 sum in the scalar
/// kernel's sequential order, so lane kernels are bit-identical to scalar
/// — the property `tests/prop_simd_parity.rs` pins. `Lanes` permits the
/// SU-FA q·k dot product to accumulate in 8 independent lanes combined by
/// a fixed pairwise tree ([`F32x8::hsum`]): typically ~1 ulp different
/// and *more* accurate in expectation (shorter dependency chains), but no
/// longer bit-comparable against Strict history. See DESIGN.md §10 for
/// when `Lanes` is acceptable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReductionOrder {
    /// Sequential scalar-order f32 sums; lane output bit-identical to
    /// scalar output.
    #[default]
    Strict,
    /// Lane-split f32 sums (fixed pairwise combine). Deterministic for a
    /// given build, but not bit-comparable with `Strict`.
    Lanes,
}

/// Eight f32 lanes. 32-byte aligned so a warm workspace loads it with one
/// aligned vector move on AVX2.
#[derive(Clone, Copy, Debug, PartialEq)]
#[repr(align(32))]
pub struct F32x8(pub [f32; LANES]);

impl F32x8 {
    /// All lanes zero.
    #[inline]
    pub fn zero() -> F32x8 {
        F32x8([0.0; LANES])
    }

    /// All lanes `v`.
    #[inline]
    pub fn splat(v: f32) -> F32x8 {
        F32x8([v; LANES])
    }

    /// Load 8 contiguous lanes from `xs` (must hold at least 8).
    #[inline]
    pub fn load(xs: &[f32]) -> F32x8 {
        let mut v = [0.0; LANES];
        v.copy_from_slice(&xs[..LANES]);
        F32x8(v)
    }

    /// Load up to 8 lanes from `xs`, filling missing tail lanes with
    /// `fill` — the remainder-lane idiom: `fill` is chosen as the
    /// reduction identity (0.0 for sums/amax over |x|, −∞ for maxima) so
    /// the tail lanes are no-ops in the combine.
    #[inline]
    pub fn load_or(xs: &[f32], fill: f32) -> F32x8 {
        let mut v = [fill; LANES];
        let n = xs.len().min(LANES);
        v[..n].copy_from_slice(&xs[..n]);
        F32x8(v)
    }

    /// Store all 8 lanes into `out` (must hold at least 8).
    #[inline]
    pub fn store(self, out: &mut [f32]) {
        out[..LANES].copy_from_slice(&self.0);
    }

    /// Lanewise `self + rhs`.
    #[inline]
    pub fn add(self, rhs: F32x8) -> F32x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&rhs.0) {
            *a += b;
        }
        F32x8(v)
    }

    /// Lanewise `self * rhs`.
    #[inline]
    pub fn mul(self, rhs: F32x8) -> F32x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&rhs.0) {
            *a *= b;
        }
        F32x8(v)
    }

    /// Lanewise fused `self * b + c` (single rounding per lane). **Not**
    /// bit-identical to `mul` + `add`; Strict-order kernels must not use
    /// it — it exists for `Lanes`-mode reductions and future non-contract
    /// paths.
    #[inline]
    pub fn mul_add(self, b: F32x8, c: F32x8) -> F32x8 {
        let mut v = self.0;
        for i in 0..LANES {
            v[i] = v[i].mul_add(b.0[i], c.0[i]);
        }
        F32x8(v)
    }

    /// Lanewise `self / rhs` (exact IEEE division — *not* a reciprocal
    /// multiply, so `x / s` matches the scalar spelling bit for bit).
    #[inline]
    pub fn div(self, rhs: F32x8) -> F32x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&rhs.0) {
            *a /= b;
        }
        F32x8(v)
    }

    /// Lanewise `|x|` (sign-bit clear; `|-0.0| = 0.0`, `|NaN| = NaN`).
    #[inline]
    pub fn abs(self) -> F32x8 {
        let mut v = self.0;
        for a in v.iter_mut() {
            *a = a.abs();
        }
        F32x8(v)
    }

    /// Lanewise IEEE `f32::max` (NaN-ignoring on either side, like the
    /// scalar kernels' `fold(…, f32::max)`).
    #[inline]
    pub fn max(self, rhs: F32x8) -> F32x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&rhs.0) {
            *a = a.max(*b);
        }
        F32x8(v)
    }

    /// Horizontal max over the lanes, seeded with `seed` (ascending lane
    /// order, `f32::max` at every step — associative + commutative, so
    /// this equals any scalar max-fold over the same values).
    #[inline]
    pub fn hmax(self, seed: f32) -> f32 {
        self.0.iter().fold(seed, |m, &x| m.max(x))
    }

    /// Horizontal sum in a **fixed pairwise tree**
    /// (`((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`-shaped): deterministic,
    /// but a different rounding order than a sequential fold — only
    /// [`ReductionOrder::Lanes`] kernels may use it.
    #[inline]
    pub fn hsum(self) -> f32 {
        let v = self.0;
        let a = [v[0] + v[4], v[1] + v[5], v[2] + v[6], v[3] + v[7]];
        let b = [a[0] + a[2], a[1] + a[3]];
        b[0] + b[1]
    }

    /// The lanes as an array.
    #[inline]
    pub fn to_array(self) -> [f32; LANES] {
        self.0
    }
}

/// Eight i64 accumulator lanes for the predictor's integer score sums
/// (DLZS/SLZS/low-bit all accumulate exactly in i64, so lane-splitting is
/// unconditionally bit-identical — integer addition is associative).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(align(32))]
pub struct I64x8(pub [i64; LANES]);

impl I64x8 {
    /// All lanes zero.
    #[inline]
    pub fn zero() -> I64x8 {
        I64x8([0; LANES])
    }

    /// Lanewise `self + rhs`.
    #[inline]
    pub fn add(self, rhs: I64x8) -> I64x8 {
        let mut v = self.0;
        for (a, b) in v.iter_mut().zip(&rhs.0) {
            *a += b;
        }
        I64x8(v)
    }

    /// Exact horizontal sum (order-free: integer addition).
    #[inline]
    pub fn hsum(self) -> i64 {
        self.0.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_path_tracks_the_feature() {
        let want = if cfg!(feature = "simd") { KernelPath::Lanes } else { KernelPath::Scalar };
        assert_eq!(KernelPath::active(), want);
    }

    #[test]
    fn reduction_order_defaults_to_strict() {
        assert_eq!(ReductionOrder::default(), ReductionOrder::Strict);
    }

    #[test]
    fn elementwise_ops_match_scalar_bit_for_bit() {
        let xs = [1.5f32, -2.25, 3.0e-7, 1.0e8, -0.0, 0.0, f32::MIN_POSITIVE, -1.0];
        let ys = [0.1f32, 7.5, -3.0e7, 2.0e-8, 4.0, -0.0, 2.5, 1.0e-3];
        let (a, b) = (F32x8(xs), F32x8(ys));
        for i in 0..LANES {
            assert_eq!(a.add(b).0[i].to_bits(), (xs[i] + ys[i]).to_bits());
            assert_eq!(a.mul(b).0[i].to_bits(), (xs[i] * ys[i]).to_bits());
            assert_eq!(a.max(b).0[i].to_bits(), xs[i].max(ys[i]).to_bits());
            assert_eq!(
                a.mul_add(b, F32x8::splat(0.5)).0[i].to_bits(),
                xs[i].mul_add(ys[i], 0.5).to_bits()
            );
        }
    }

    #[test]
    fn load_or_fills_tail_with_identity() {
        let xs = [1.0f32, 2.0, 3.0];
        let v = F32x8::load_or(&xs, f32::NEG_INFINITY);
        assert_eq!(&v.0[..3], &xs);
        assert!(v.0[3..].iter().all(|&x| x == f32::NEG_INFINITY));
        assert_eq!(v.hmax(f32::NEG_INFINITY), 3.0);
    }

    #[test]
    fn hmax_equals_scalar_fold_any_seed() {
        let xs = [0.5f32, -1.0, 7.25, 7.25, -0.0, 0.0, 3.5, 2.0];
        let v = F32x8(xs);
        for seed in [f32::NEG_INFINITY, 0.0, 100.0] {
            assert_eq!(v.hmax(seed).to_bits(), xs.iter().fold(seed, |m, &x| m.max(x)).to_bits());
        }
    }

    #[test]
    fn hmax_ignores_nan_like_scalar_max_fold() {
        let mut xs = [1.0f32; LANES];
        xs[3] = f32::NAN;
        // f32::max(m, NaN) == m — identical in lane and scalar folds.
        assert_eq!(F32x8(xs).hmax(f32::NEG_INFINITY), 1.0);
    }

    #[test]
    fn hsum_is_the_documented_pairwise_tree() {
        let xs = [1.0f32, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        let v = F32x8(xs);
        let want = ((xs[0] + xs[4]) + (xs[2] + xs[6])) + ((xs[1] + xs[5]) + (xs[3] + xs[7]));
        assert_eq!(v.hsum().to_bits(), want.to_bits());
    }

    #[test]
    fn i64_lane_sum_is_exact() {
        let a = I64x8([1, -2, 3, -4, 5, -6, 7, -8]);
        let b = I64x8([10, 20, 30, 40, 50, 60, 70, 80]);
        assert_eq!(a.add(b).hsum(), (1 - 2 + 3 - 4 + 5 - 6 + 7 - 8) + 360);
    }

    #[test]
    fn store_roundtrips() {
        let xs = [9.0f32, 8.0, 7.0, 6.0, 5.0, 4.0, 3.0, 2.0];
        let mut out = [0.0f32; LANES];
        F32x8(xs).store(&mut out);
        assert_eq!(out, xs);
    }
}
