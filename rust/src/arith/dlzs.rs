//! DLZS — the Differential Leading-Zero Scheme (Sec. IV-A) and the
//! symmetric baseline SLZS (as used by FACT [9]).
//!
//! DLZS approximates `x · y` by LZ-encoding only **one** operand (`y`) and
//! shifting the other: `x·y ≈ sign(x)⊕sign(y) · |x| << (W − LZ_y)` (Eq. 4b).
//! SLZS encodes both operands: `x·y ≈ ± 2^(W−LZ_x) · 2^(W−LZ_y)` — cheaper
//! conversion hardware per operand pair but twice the encoding work and a
//! larger error.
//!
//! The PSP (pre-flipping via symbol prediction) trick is functional-identity
//! at this level: instead of shifting `x` and conditionally negating the
//! product (which flips every bit of a wide result), the *input* `x` is
//! negated before the shift when `y` is negative. We model its benefit in
//! the energy model ([`crate::sim::energy`]); here we expose the operand
//! pre-flip so the datapath is bit-faithful.

use super::lz::LzCode;

/// A weight (or activation) pre-converted to LZ format. The paper
//  pre-converts `W_k` offline, so the Key-prediction phase loads only these
/// codes (≈4 bits each) instead of full 8-bit operands.
pub type LzWeight = LzCode;

/// DLZS approximate multiply: `x` stays in plain integer form, `y_code` is
/// the LZ-encoded operand. Implements Eq. (4b) with PSP: the sign of the
/// result is applied by pre-flipping `x`, never by post-negating the
/// shifted result.
#[inline]
pub fn dlzs_mul(x: i32, y_code: LzCode) -> i64 {
    match y_code.shift_amount() {
        None => 0,
        Some(sh) => {
            // PSP: pre-flip x when y is negative.
            let pre = if y_code.negative { -(x as i64) } else { x as i64 };
            pre << sh
        }
    }
}

/// SLZS approximate multiply: both operands LZ-encoded.
#[inline]
pub fn slzs_mul(x_code: LzCode, y_code: LzCode) -> i64 {
    match (x_code.shift_amount(), y_code.shift_amount()) {
        (Some(sx), Some(sy)) => {
            let mag = 1i64 << (sx + sy);
            if x_code.negative != y_code.negative {
                -mag
            } else {
                mag
            }
        }
        _ => 0,
    }
}

/// Dot product of a plain integer row with a row of LZ-encoded weights
/// (DLZS). Add-only accumulation; every product is a shift.
pub fn dlzs_dot(xs: &[i32], ys: &[LzCode]) -> i64 {
    debug_assert_eq!(xs.len(), ys.len());
    let mut acc = 0i64;
    for (&x, &y) in xs.iter().zip(ys) {
        acc += dlzs_mul(x, y);
    }
    acc
}

/// Dot product with both sides LZ-encoded (SLZS).
pub fn slzs_dot(xs: &[LzCode], ys: &[LzCode]) -> i64 {
    debug_assert_eq!(xs.len(), ys.len());
    let mut acc = 0i64;
    for (&x, &y) in xs.iter().zip(ys) {
        acc += slzs_mul(x, y);
    }
    acc
}

/// Encode a slice of integers to LZ format with magnitude width `w`.
pub fn encode_slice(xs: &[i32], w: u32) -> Vec<LzCode> {
    xs.iter().map(|&x| LzCode::encode(x, w)).collect()
}

/// Worst-case multiplicative error bounds of the two schemes for non-zero
/// operands: the true product lies in [approx, bound_factor × approx).
pub fn error_bound_factor(symmetric: bool) -> f64 {
    if symmetric {
        4.0 // both mantissas ∈ (0.5,1] dropped → up to 2 × 2
    } else {
        2.0 // only M_y dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const W: u32 = 7;

    #[test]
    fn dlzs_matches_shift_semantics() {
        // y = 3 → LZ=6 → shift by 1 → approx y = 2.
        let y = LzCode::encode(3, W);
        assert_eq!(dlzs_mul(10, y), 20);
        // y = 4 (exact power of two) → exact.
        let y4 = LzCode::encode(4, W);
        assert_eq!(dlzs_mul(10, y4), 40);
    }

    #[test]
    fn sign_rules() {
        let yp = LzCode::encode(4, W);
        let yn = LzCode::encode(-4, W);
        assert_eq!(dlzs_mul(3, yn), -12);
        assert_eq!(dlzs_mul(-3, yn), 12);
        assert_eq!(dlzs_mul(-3, yp), -12);
        let xn = LzCode::encode(-8, W);
        assert_eq!(slzs_mul(xn, yn), 32);
        assert_eq!(slzs_mul(xn, yp), -32);
    }

    #[test]
    fn zero_short_circuits() {
        let z = LzCode::encode(0, W);
        assert_eq!(dlzs_mul(123, z), 0);
        assert_eq!(slzs_mul(z, LzCode::encode(9, W)), 0);
    }

    #[test]
    fn dlzs_error_within_2x_slzs_within_4x() {
        let mut rng = Rng::new(42);
        for _ in 0..2000 {
            let x = rng.range(1, 127) as i32;
            let y = rng.range(1, 127) as i32;
            let exact = (x * y) as i64;
            let d = dlzs_mul(x, LzCode::encode(y, W));
            let s = slzs_mul(LzCode::encode(x, W), LzCode::encode(y, W));
            assert!(d <= exact && exact < 2 * d, "dlzs: {x}*{y}={exact} est={d}");
            assert!(s <= exact && exact < 4 * s, "slzs: {x}*{y}={exact} est={s}");
        }
    }

    #[test]
    fn dlzs_strictly_more_accurate_on_average() {
        let mut rng = Rng::new(43);
        let (mut derr, mut serr) = (0.0f64, 0.0f64);
        let n = 5000;
        for _ in 0..n {
            let x = rng.range(1, 127) as i32;
            let y = rng.range(1, 127) as i32;
            let exact = (x * y) as f64;
            let d = dlzs_mul(x, LzCode::encode(y, W)) as f64;
            let s = slzs_mul(LzCode::encode(x, W), LzCode::encode(y, W)) as f64;
            derr += ((exact - d) / exact).abs();
            serr += ((exact - s) / exact).abs();
        }
        let (dmean, smean) = (derr / n as f64, serr / n as f64);
        assert!(dmean < smean, "dlzs mean err {dmean} !< slzs mean err {smean}");
    }

    #[test]
    fn dot_products_accumulate() {
        let xs = [1, 2, 3, 4];
        let ys = encode_slice(&[4, 4, 4, 4], W); // exact powers of two
        assert_eq!(dlzs_dot(&xs, &ys), 40);
    }
}
