//! Symmetric fixed-point quantization.
//!
//! The paper's pipeline quantizes activations/weights to INT16 for the
//! formal-compute stage and to low precision (e.g. 4-bit MSBs) for the
//! pre-compute stage. We model per-tensor symmetric quantization:
//! `q = clamp(round(x / scale))`, `x̂ = q · scale`.

use crate::arith::lanes::{F32x8, KernelPath, LANES};
use crate::tensor::Mat;

/// Supported integer widths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntBits {
    Int4,
    Int8,
    Int16,
}

impl IntBits {
    /// Number of bits (including sign).
    pub fn bits(self) -> u32 {
        match self {
            IntBits::Int4 => 4,
            IntBits::Int8 => 8,
            IntBits::Int16 => 16,
        }
    }

    /// Magnitude bitwidth W (bits excluding sign) — the `W` of Eq. (3).
    pub fn magnitude_bits(self) -> u32 {
        self.bits() - 1
    }

    /// Largest representable positive value.
    pub fn qmax(self) -> i32 {
        (1 << self.magnitude_bits()) - 1
    }
}

/// Quantize one row with its own max-abs scale (per-row symmetric
/// quantization). The result depends only on the row's contents — never
/// on neighbouring rows — which is what lets the paged KV-cache
/// ([`crate::kvcache`]) freeze a key's quantized operand at append time
/// and still match what a later full prefill would compute bit for bit.
pub fn quantize_row(row: &[f32], bits: IntBits) -> (Vec<i32>, f32) {
    let mut q = Vec::with_capacity(row.len());
    let scale = quantize_row_into(row, bits, &mut q);
    (q, scale)
}

/// [`quantize_row`] writing into a caller-provided buffer (cleared, then
/// filled — no allocation once `out` has the capacity). Returns the
/// per-row scale. This is the only per-row quantizer; the allocating
/// entry point wraps it, so buffered and fresh results are bit-identical
/// by construction. Dispatches on the `simd` cargo feature; both
/// spellings are bit-identical — see [`quantize_row_into_with`].
pub fn quantize_row_into(row: &[f32], bits: IntBits, out: &mut Vec<i32>) -> f32 {
    quantize_row_into_with(row, bits, out, KernelPath::active())
}

/// [`quantize_row_into`] with an explicit kernel path, for benches and
/// parity tests.
///
/// Bit-identity argument: the amax reduction is a fold of the
/// NaN-ignoring, associative and commutative `f32::max` over `|x|`
/// (remainder lanes filled with the identity 0.0), so lane-splitting
/// yields the same scale; the quantization itself is an elementwise map
/// (`(x / scale).round()` then clamp — exact IEEE division in both
/// spellings), so every output element is identical.
pub fn quantize_row_into_with(
    row: &[f32],
    bits: IntBits,
    out: &mut Vec<i32>,
    path: KernelPath,
) -> f32 {
    let amax = match path {
        KernelPath::Scalar => row.iter().fold(0.0f32, |a, &x| a.max(x.abs())),
        KernelPath::Lanes => {
            let mut acc = F32x8::zero();
            let mut chunks = row.chunks_exact(LANES);
            for c in &mut chunks {
                acc = acc.max(F32x8::load(c).abs());
            }
            acc.max(F32x8::load_or(chunks.remainder(), 0.0).abs()).hmax(0.0)
        }
    };
    let scale = if amax == 0.0 { 1.0 } else { amax / bits.qmax() as f32 };
    let qmax = bits.qmax();
    out.clear();
    match path {
        KernelPath::Scalar => {
            out.extend(row.iter().map(|&x| ((x / scale).round() as i32).clamp(-qmax, qmax)));
        }
        KernelPath::Lanes => {
            let s = F32x8::splat(scale);
            let mut chunks = row.chunks_exact(LANES);
            for c in &mut chunks {
                for x in F32x8::load(c).div(s).to_array() {
                    out.push((x.round() as i32).clamp(-qmax, qmax));
                }
            }
            for &x in chunks.remainder() {
                out.push(((x / scale).round() as i32).clamp(-qmax, qmax));
            }
        }
    }
    scale
}

/// Keep only the top `msb` magnitude bits of one signed value (the scalar
/// core of [`QuantMat::truncate_to_msb`], shared with the decode-path
/// low-bit predictor).
pub fn truncate_msb(v: i32, msb: u32) -> i32 {
    let mag = v.unsigned_abs();
    if mag == 0 {
        return 0;
    }
    let top = 32 - mag.leading_zeros(); // highest set bit position
    let drop = top.saturating_sub(msb);
    let t = ((mag >> drop) << drop) as i32;
    if v < 0 {
        -t
    } else {
        t
    }
}

/// A quantized matrix: `i32` storage plus the common scale.
#[derive(Clone, Debug)]
pub struct QuantMat {
    pub rows: usize,
    pub cols: usize,
    pub q: Vec<i32>,
    pub scale: f32,
    pub bits: IntBits,
}

impl QuantMat {
    /// Quantize with a scale chosen from the max-abs of `m`.
    pub fn quantize(m: &Mat, bits: IntBits) -> QuantMat {
        let amax = m.data.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let scale = if amax == 0.0 { 1.0 } else { amax / bits.qmax() as f32 };
        Self::quantize_with_scale(m, bits, scale)
    }

    /// Quantize with an explicit scale (shared scales across tensors keep
    /// log-domain shifts consistent).
    pub fn quantize_with_scale(m: &Mat, bits: IntBits, scale: f32) -> QuantMat {
        let qmax = bits.qmax();
        let q = m
            .data
            .iter()
            .map(|&x| ((x / scale).round() as i32).clamp(-qmax, qmax))
            .collect();
        QuantMat { rows: m.rows, cols: m.cols, q, scale, bits }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> i32 {
        self.q[i * self.cols + j]
    }

    pub fn row(&self, i: usize) -> &[i32] {
        &self.q[i * self.cols..(i + 1) * self.cols]
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Mat {
        Mat::from_vec(
            self.rows,
            self.cols,
            self.q.iter().map(|&q| q as f32 * self.scale).collect(),
        )
    }

    /// Exact integer matmul (the INT16 baseline path): self [m,k] × other
    /// [k,n], result dequantized with the product scale.
    pub fn matmul_exact(&self, other: &QuantMat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = vec![0i64; m * n];
        for i in 0..m {
            for p in 0..k {
                let a = self.q[i * k + p] as i64;
                if a == 0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += a * other.q[p * n + j] as i64;
                }
            }
        }
        let s = self.scale * other.scale;
        Mat::from_vec(m, n, out.into_iter().map(|v| v as f32 * s).collect())
    }

    /// Keep only the top `msb` magnitude bits of each value (the "4-bit MSB"
    /// style low-precision estimate some DS baselines use).
    pub fn truncate_to_msb(&self, msb: u32) -> QuantMat {
        let w = self.bits.magnitude_bits();
        assert!(msb <= w);
        let q = self.q.iter().map(|&v| truncate_msb(v, msb)).collect();
        QuantMat { rows: self.rows, cols: self.cols, q, scale: self.scale, bits: self.bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn quantize_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(8, 8, 1.0, &mut rng);
        for bits in [IntBits::Int8, IntBits::Int16] {
            let q = QuantMat::quantize(&m, bits);
            let back = q.dequantize();
            // Max error is half a quantization step.
            let step = q.scale;
            assert!(m.max_abs_diff(&back) <= 0.51 * step, "bits={bits:?}");
        }
    }

    #[test]
    fn int16_matmul_close_to_f32() {
        let mut rng = Rng::new(2);
        let a = Mat::randn(6, 12, 1.0, &mut rng);
        let b = Mat::randn(12, 5, 1.0, &mut rng);
        let qa = QuantMat::quantize(&a, IntBits::Int16);
        let qb = QuantMat::quantize(&b, IntBits::Int16);
        let exact = a.matmul(&b);
        let approx = qa.matmul_exact(&qb);
        assert!(approx.rel_err(&exact) < 1e-3);
    }

    #[test]
    fn qmax_values() {
        assert_eq!(IntBits::Int4.qmax(), 7);
        assert_eq!(IntBits::Int8.qmax(), 127);
        assert_eq!(IntBits::Int16.qmax(), 32767);
    }

    #[test]
    fn msb_truncation_keeps_leading_bits() {
        let m = Mat::from_vec(1, 4, vec![100.0, -100.0, 3.0, 0.0]);
        let q = QuantMat::quantize_with_scale(&m, IntBits::Int8, 1.0);
        let t = q.truncate_to_msb(2);
        // 100 = 0b1100100 → keep top-2 bits → 0b1100000 = 96.
        assert_eq!(t.q, vec![96, -96, 3, 0]);
    }

    #[test]
    fn quantize_row_matches_single_row_matrix_quantization() {
        let mut rng = Rng::new(7);
        let m = Mat::randn(1, 16, 1.5, &mut rng);
        let q = QuantMat::quantize(&m, IntBits::Int8);
        let (qr, s) = quantize_row(m.row(0), IntBits::Int8);
        assert_eq!(qr, q.q);
        assert_eq!(s, q.scale);
    }

    #[test]
    fn quantize_lanes_path_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(31);
        for cols in [1usize, 7, 8, 9, 16, 23, 64, 65] {
            let m = Mat::randn(1, cols, 2.0, &mut rng);
            for bits in [IntBits::Int4, IntBits::Int8, IntBits::Int16] {
                let mut qs = vec![7i32; 3]; // dirty
                let mut ql = Vec::new();
                let ss = quantize_row_into_with(m.row(0), bits, &mut qs, KernelPath::Scalar);
                let sl = quantize_row_into_with(m.row(0), bits, &mut ql, KernelPath::Lanes);
                assert_eq!(ss.to_bits(), sl.to_bits(), "cols={cols} bits={bits:?}");
                assert_eq!(qs, ql, "cols={cols} bits={bits:?}");
            }
        }
        // All-zero row (scale fallback) and a -0.0 amax candidate.
        for row in [vec![0.0f32; 11], vec![-0.0f32, 0.0, -0.0]] {
            let (mut qs, mut ql) = (Vec::new(), Vec::new());
            let ss = quantize_row_into_with(&row, IntBits::Int8, &mut qs, KernelPath::Scalar);
            let sl = quantize_row_into_with(&row, IntBits::Int8, &mut ql, KernelPath::Lanes);
            assert_eq!((ss.to_bits(), qs), (sl.to_bits(), ql));
        }
    }

    #[test]
    fn zero_matrix_scale_is_finite() {
        let m = Mat::zeros(2, 2);
        let q = QuantMat::quantize(&m, IntBits::Int8);
        assert!(q.scale.is_finite() && q.scale > 0.0);
        assert!(q.q.iter().all(|&v| v == 0));
    }
}
