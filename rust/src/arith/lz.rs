//! Leading-zero (LZ) codec — Eq. (3) of the paper.
//!
//! An integer `x` with magnitude bitwidth `W` is written
//! `x = sign · M · 2^(W − LZ)` where `LZ ∈ [1, W]` is the number of leading
//! zeros of |x| within the W-bit field and `M ∈ (0.5, 1]` is the mantissa.
//! The log-domain approximation replaces |x| with `2^(W − LZ)` (i.e. M ≈ 1),
//! turning multiplications into shifts.

/// Count leading zeros of `mag` in a `w`-bit field. For `mag == 0` we return
/// `w + 1` as a sentinel meaning "value is exactly zero" (the paper's LZ
/// range [1, W] covers only non-zero values).
pub fn lz_count(mag: u32, w: u32) -> u32 {
    debug_assert!(w <= 31);
    debug_assert!(mag < (1 << w), "magnitude {mag} does not fit in {w} bits");
    if mag == 0 {
        return w + 1;
    }
    let top = 32 - mag.leading_zeros(); // index (1-based) of highest set bit
    w - top + 1
}

/// LZ-format encoding of one signed integer: `(sign, LZ)` plus the field
/// width. Storage cost is ~`ceil(log2(W)) + 1` bits — e.g. 4 bits for W=7/8
/// as the paper notes (vs loading the full 8-bit operand under SLZS).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LzCode {
    pub negative: bool,
    /// Leading zeros in the W-bit magnitude; `w + 1` encodes zero.
    pub lz: u32,
    /// Magnitude field width W.
    pub w: u32,
}

impl LzCode {
    /// Encode a signed integer whose magnitude fits `w` bits.
    pub fn encode(x: i32, w: u32) -> LzCode {
        let mag = x.unsigned_abs();
        LzCode { negative: x < 0, lz: lz_count(mag, w), w }
    }

    /// True if the encoded value was exactly zero.
    pub fn is_zero(&self) -> bool {
        self.lz == self.w + 1
    }

    /// The log-domain magnitude approximation `2^(W − LZ)` (0 for zero).
    /// For a non-zero x this is within (|x|/2, |x|]... precisely it is the
    /// value of the highest set bit of |x|, so `approx ≤ |x| < 2·approx`.
    pub fn magnitude_approx(&self) -> i64 {
        if self.is_zero() {
            0
        } else {
            1i64 << (self.w - self.lz)
        }
    }

    /// Signed approximate value.
    pub fn value_approx(&self) -> i64 {
        let m = self.magnitude_approx();
        if self.negative {
            -m
        } else {
            m
        }
    }

    /// Shift amount applied to the *other* operand under DLZS: `W − LZ`.
    /// Returns None for zero (the product is zero; no shift happens).
    pub fn shift_amount(&self) -> Option<u32> {
        if self.is_zero() {
            None
        } else {
            Some(self.w - self.lz)
        }
    }

    /// Bits needed to store this code (sign + LZ field).
    pub fn storage_bits(&self) -> u32 {
        // LZ ranges over w+1 values (1..=w plus the zero sentinel).
        1 + (32 - (self.w + 1).leading_zeros())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lz_count_examples() {
        // W = 7 (INT8 magnitude field).
        assert_eq!(lz_count(0b1000000, 7), 1);
        assert_eq!(lz_count(0b0000001, 7), 7);
        assert_eq!(lz_count(0b0000011, 7), 6);
        assert_eq!(lz_count(0, 7), 8); // zero sentinel
    }

    #[test]
    fn approx_bounds_nonzero() {
        let w = 7;
        for x in 1..128i32 {
            let c = LzCode::encode(x, w);
            let a = c.magnitude_approx();
            assert!(a <= x as i64 && (x as i64) < 2 * a, "x={x} approx={a}");
        }
    }

    #[test]
    fn sign_carried() {
        let c = LzCode::encode(-5, 7);
        assert!(c.negative);
        assert_eq!(c.value_approx(), -4);
        let p = LzCode::encode(5, 7);
        assert_eq!(p.value_approx(), 4);
    }

    #[test]
    fn zero_handling() {
        let c = LzCode::encode(0, 7);
        assert!(c.is_zero());
        assert_eq!(c.value_approx(), 0);
        assert_eq!(c.shift_amount(), None);
    }

    #[test]
    fn storage_bits_small() {
        // W=7 → LZ in [1..8] → 4 bits + sign = 5; the paper quotes "4-bit LZ
        // value" for the LZ field itself.
        let c = LzCode::encode(42, 7);
        assert_eq!(c.storage_bits(), 5);
        assert_eq!(c.storage_bits() - 1, 4);
    }

    #[test]
    fn lz_monotone_decreasing_in_magnitude() {
        let w = 15;
        let mut last = w + 2;
        for x in [1, 2, 4, 100, 5000, 32000] {
            let lz = lz_count(x, w);
            assert!(lz < last || lz == last);
            last = lz;
        }
    }
}
