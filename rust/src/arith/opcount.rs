//! Operation accounting and the equivalent-additions normalization.
//!
//! The paper unifies heterogeneous operation mixes into "equivalent
//! additions" (footnote 1):
//! `C = α·N_add + β·N_mul + γ·N_cmp + δ·N_div + ε·N_exp` with
//! `α,β,γ,δ,ε = 1, 3, 1, 8, 25` (after Brent & Zimmermann [15]).
//! Shifts are counted separately and weighted like additions — they are the
//! currency of the DLZS multiplier-free datapath.

/// Kinds of primitive operations the algorithm layer counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Add,
    Mul,
    Cmp,
    Div,
    Exp,
    Shift,
    /// Leading-zero encode of one operand (priority encoder).
    LzEncode,
}

/// Weights for the equivalent-additions normalization.
#[derive(Clone, Copy, Debug)]
pub struct EquivWeights {
    pub add: f64,
    pub mul: f64,
    pub cmp: f64,
    pub div: f64,
    pub exp: f64,
    pub shift: f64,
    pub lz_encode: f64,
}

impl Default for EquivWeights {
    fn default() -> Self {
        // α..ε from the paper; shift/LZ-encode ≈ one add of datapath work.
        EquivWeights { add: 1.0, mul: 3.0, cmp: 1.0, div: 8.0, exp: 25.0, shift: 1.0, lz_encode: 1.0 }
    }
}

/// Mutable operation counter threaded through the counted attention /
/// sparsity implementations.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpCounter {
    pub add: u64,
    pub mul: u64,
    pub cmp: u64,
    pub div: u64,
    pub exp: u64,
    pub shift: u64,
    pub lz_encode: u64,
    /// Bytes moved to/from off-chip memory (model-level, not cycle-level —
    /// the cycle-level memory system lives in [`crate::sim`]).
    pub dram_bytes: u64,
    /// Bytes moved to/from on-chip SRAM.
    pub sram_bytes: u64,
}

impl OpCounter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn tally(&mut self, kind: OpKind, n: u64) {
        match kind {
            OpKind::Add => self.add += n,
            OpKind::Mul => self.mul += n,
            OpKind::Cmp => self.cmp += n,
            OpKind::Div => self.div += n,
            OpKind::Exp => self.exp += n,
            OpKind::Shift => self.shift += n,
            OpKind::LzEncode => self.lz_encode += n,
        }
    }

    #[inline]
    pub fn dram(&mut self, bytes: u64) {
        self.dram_bytes += bytes;
    }

    #[inline]
    pub fn sram(&mut self, bytes: u64) {
        self.sram_bytes += bytes;
    }

    /// Equivalent additions under `w`.
    pub fn equivalent_adds(&self, w: &EquivWeights) -> f64 {
        self.add as f64 * w.add
            + self.mul as f64 * w.mul
            + self.cmp as f64 * w.cmp
            + self.div as f64 * w.div
            + self.exp as f64 * w.exp
            + self.shift as f64 * w.shift
            + self.lz_encode as f64 * w.lz_encode
    }

    /// Equivalent additions under the paper's default weights.
    pub fn equiv(&self) -> f64 {
        self.equivalent_adds(&EquivWeights::default())
    }

    /// Total primitive operation count (unweighted), matmul + non-matmul.
    pub fn total_ops(&self) -> u64 {
        self.add + self.mul + self.cmp + self.div + self.exp + self.shift + self.lz_encode
    }

    /// Non-matmul operations (everything but add/mul — the FLOPs FA-2's
    /// "each non-matmul FLOP is ~16× more costly" remark is about).
    pub fn non_matmul_ops(&self) -> u64 {
        self.cmp + self.div + self.exp
    }

    /// Merge another counter into this one.
    pub fn merge(&mut self, other: &OpCounter) {
        self.add += other.add;
        self.mul += other.mul;
        self.cmp += other.cmp;
        self.div += other.div;
        self.exp += other.exp;
        self.shift += other.shift;
        self.lz_encode += other.lz_encode;
        self.dram_bytes += other.dram_bytes;
        self.sram_bytes += other.sram_bytes;
    }

    /// Difference (saturating) — used to report "extra ops vs baseline".
    pub fn delta(&self, baseline: &OpCounter) -> OpCounter {
        OpCounter {
            add: self.add.saturating_sub(baseline.add),
            mul: self.mul.saturating_sub(baseline.mul),
            cmp: self.cmp.saturating_sub(baseline.cmp),
            div: self.div.saturating_sub(baseline.div),
            exp: self.exp.saturating_sub(baseline.exp),
            shift: self.shift.saturating_sub(baseline.shift),
            lz_encode: self.lz_encode.saturating_sub(baseline.lz_encode),
            dram_bytes: self.dram_bytes.saturating_sub(baseline.dram_bytes),
            sram_bytes: self.sram_bytes.saturating_sub(baseline.sram_bytes),
        }
    }
}

impl std::fmt::Display for OpCounter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "add={} mul={} cmp={} div={} exp={} shift={} lzenc={} dram={}B sram={}B (equiv-adds={:.3e})",
            self.add,
            self.mul,
            self.cmp,
            self.div,
            self.exp,
            self.shift,
            self.lz_encode,
            self.dram_bytes,
            self.sram_bytes,
            self.equiv()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equivalent_adds_uses_paper_weights() {
        let mut c = OpCounter::new();
        c.tally(OpKind::Add, 10);
        c.tally(OpKind::Mul, 10);
        c.tally(OpKind::Cmp, 10);
        c.tally(OpKind::Div, 10);
        c.tally(OpKind::Exp, 10);
        // 10·1 + 10·3 + 10·1 + 10·8 + 10·25 = 380
        assert_eq!(c.equiv(), 380.0);
    }

    #[test]
    fn merge_and_delta() {
        let mut a = OpCounter::new();
        a.tally(OpKind::Exp, 5);
        a.dram(100);
        let mut b = OpCounter::new();
        b.tally(OpKind::Exp, 3);
        b.dram(40);
        let mut m = a.clone();
        m.merge(&b);
        assert_eq!(m.exp, 8);
        assert_eq!(m.dram_bytes, 140);
        let d = m.delta(&a);
        assert_eq!(d.exp, 3);
        assert_eq!(d.dram_bytes, 40);
    }

    #[test]
    fn exp_dominates_equiv() {
        // 1 exp ≈ 25 adds: the reason FA's extra exponentiations matter.
        let mut exp1 = OpCounter::new();
        exp1.tally(OpKind::Exp, 1);
        let mut add24 = OpCounter::new();
        add24.tally(OpKind::Add, 24);
        assert!(exp1.equiv() > add24.equiv());
    }

    #[test]
    fn shift_counts_like_add() {
        let mut c = OpCounter::new();
        c.tally(OpKind::Shift, 7);
        c.tally(OpKind::LzEncode, 3);
        assert_eq!(c.equiv(), 10.0);
        assert_eq!(c.total_ops(), 10);
        assert_eq!(c.non_matmul_ops(), 0);
    }
}
